"""Fixed-(P,Q) vs closed-loop adaptive HSGD — bytes-to-target-loss.

The paper's headline adaptive claim (Figs. 7–9 distilled): the §VI controller
should reach the fixed-interval baseline's loss while spending *fewer modeled
communication bytes* (eq. (19) cost model). This benchmark runs both on the
same data/seed/step budget and records the comparison into BENCH_adaptive.json:

  * fixed     — HSGDRunner at a constant (P, Q, η), uncompressed messages;
  * adaptive  — AdaptiveHSGDRunner re-picking P = Q and η every round from
                online ρ/δ/‖∇F‖² probes, with the byte governor holding the
                run under ``--budget-frac`` × the fixed run's bill.

``--figs`` additionally reprints the legacy Fig. 7/8/9 sweep tables.

  PYTHONPATH=src python benchmarks/bench_adaptive.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import csv_row, setup_experiment, sizes_for
import jax

from repro.common.config import FederationConfig
from repro.common.io import atomic_write_json
from repro.core import comm_model as CM
from repro.core.controller import AdaptiveConfig, AdaptiveHSGDRunner
from repro.core.hsgd import HSGDRunner, init_state, make_group_weights
from repro.core.metrics import smoothed_losses, steps_to_target


def run_fixed(exp, total_steps):
    """Constant-(P,Q) baseline; returns (losses, per-step cumulative bytes)."""
    model, fed, train = exp["model"], exp["fed"], exp["train"]
    runner = HSGDRunner(model, fed, train)
    data, w = exp["data"], make_group_weights(exp["data"])
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    rounds = max(1, total_steps // fed.global_interval)
    state, losses = runner.run(state, data, w, rounds=rounds)
    losses = np.asarray(jax.device_get(losses))

    sizes = sizes_for(exp, "hsgd")  # the suite's shared uncompressed size model
    per_iter = CM.comm_cost_per_iteration(sizes, fed) * fed.num_groups
    bytes_curve = per_iter * np.arange(1, len(losses) + 1)
    return losses, bytes_curve


def run_adaptive(exp, total_steps, byte_budget, max_interval):
    model, fed, train = exp["model"], exp["fed"], exp["train"]
    data, w = exp["data"], make_group_weights(exp["data"])
    cfg = AdaptiveConfig(total_steps=total_steps, byte_budget=byte_budget,
                         max_interval=max_interval,
                         eta_max=max(train.learning_rate * 10, 0.05))
    controller = AdaptiveHSGDRunner(model, fed, train, cfg)
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    state, losses, history = controller.run(state, data, w,
                                            probe_key=jax.random.PRNGKey(1))
    # per-step cumulative bytes: each round's bill amortized over its P steps
    steps_bytes = np.concatenate([
        np.full(h["P"], h["round_bytes"] / h["P"]) for h in history])
    bytes_curve = np.cumsum(steps_bytes)
    return np.asarray(losses), bytes_curve, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mimic3")
    ap.add_argument("--steps", type=int, default=48)
    ap.add_argument("--p", type=int, default=1)
    ap.add_argument("--q", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--samples", type=int, default=512)
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--devices", type=int, default=32)
    ap.add_argument("--budget-frac", type=float, default=0.5,
                    help="adaptive byte budget as a fraction of the fixed bill")
    ap.add_argument("--max-interval", type=int, default=16)
    ap.add_argument("--smooth", type=int, default=4)
    ap.add_argument("--figs", action="store_true",
                    help="also print the legacy Fig. 7/8/9 sweep tables")
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..",
                                                  "BENCH_adaptive.json"))
    args = ap.parse_args(argv)

    exp = setup_experiment(dataset=args.dataset, n=args.samples, groups=args.groups,
                           devices=args.devices, alpha=0.25, q=args.q, p=args.p,
                           lr=args.lr)
    # both runs must spend the SAME step budget: round down to whole fixed rounds
    steps = max(1, args.steps // args.p) * args.p
    print(f"# fixed (P={args.p}, Q={args.q}) vs adaptive, {args.dataset}, "
          f"{steps} steps")
    fixed_losses, fixed_bytes = run_fixed(exp, steps)
    budget = float(fixed_bytes[-1]) * args.budget_frac
    ad_losses, ad_bytes, history = run_adaptive(exp, steps, budget,
                                                args.max_interval)

    target = float(smoothed_losses(fixed_losses, args.smooth)[-1])
    ad_hit = steps_to_target(ad_losses, target, args.smooth)
    fx_hit = steps_to_target(fixed_losses, target, args.smooth)

    summary = {
        "target_loss": target,
        "fixed_final_loss": float(smoothed_losses(fixed_losses, args.smooth)[-1]),
        "adaptive_final_loss": float(smoothed_losses(ad_losses, args.smooth)[-1]),
        "fixed_total_bytes": float(fixed_bytes[-1]),
        "adaptive_total_bytes": float(ad_bytes[-1]),
        "adaptive_byte_budget": budget,
        "fixed_steps_to_target": fx_hit,
        "adaptive_steps_to_target": ad_hit,
        "fixed_bytes_to_target": float(fixed_bytes[fx_hit]) if fx_hit is not None else None,
        "adaptive_bytes_to_target": float(ad_bytes[ad_hit]) if ad_hit is not None else None,
        "adaptive_reaches_target": ad_hit is not None,
        "adaptive_bytes_lower": float(ad_bytes[-1]) < float(fixed_bytes[-1]),
    }

    csv_row("run", "final_loss", "total_MB", "steps_to_target", "MB_to_target")
    csv_row("fixed", round(summary["fixed_final_loss"], 4),
            round(summary["fixed_total_bytes"] / 1e6, 3), fx_hit,
            round((summary["fixed_bytes_to_target"] or 0) / 1e6, 3))
    csv_row("adaptive", round(summary["adaptive_final_loss"], 4),
            round(summary["adaptive_total_bytes"] / 1e6, 3), ad_hit,
            round((summary["adaptive_bytes_to_target"] or 0) / 1e6, 3)
            if ad_hit is not None else None)
    for h in history:
        print(f"#   round {h['round']:3d}: P=Q={h['P']:3d} eta={h['eta']:.4g} "
              f"rung={h['rung']} bytes={h['bytes_total'] / 1e6:.2f}MB "
              f"loss={h['loss_last']:.4f}")

    result = {
        "config": {"dataset": args.dataset, "steps": steps, "p": args.p,
                   "q": args.q, "lr": args.lr, "samples": args.samples,
                   "groups": args.groups, "devices": args.devices,
                   "budget_frac": args.budget_frac,
                   "max_interval": args.max_interval, "smooth": args.smooth},
        "summary": summary,
        "fixed": {"losses": fixed_losses.tolist(),
                  "bytes": fixed_bytes.tolist()},
        "adaptive": {"losses": ad_losses.tolist(),
                     "bytes": ad_bytes.tolist(),
                     "history": history},
    }
    atomic_write_json(args.out, result)
    print(f"# wrote {os.path.abspath(args.out)}")

    if args.figs:
        from benchmarks.bench_adaptive_figs import fig7, fig8, fig9

        fig7(args.dataset)
        fig8(args.dataset)
        fig9(args.dataset)
    return result


if __name__ == "__main__":
    main()
