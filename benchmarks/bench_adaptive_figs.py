"""Paper Figs. 7-9 sweep tables (legacy offline-strategy views).

Fig. 7 (strategy 1): P=Q minimizes comm cost to a target AUC vs P>Q settings.
Fig. 8 (strategy 2): comm cost vs P=Q sweep is U-shaped; the strategy-2
                     optimum lands near the bottom.
Fig. 9 (strategy 3): the better learning rate flips as P (or Q) grows.

The closed-loop comparison lives in ``bench_adaptive.py``; these tables are
kept for reproducing the paper's static sweeps (``bench_adaptive.py --figs``).
"""
from __future__ import annotations

from benchmarks.common import (
    comm_bytes_at_step,
    csv_row,
    eval_model,
    run_algorithm,
    setup_experiment,
    sizes_for,
)
from repro.core.adaptive import estimate_rho_delta, recommend_settings
import jax


def auc_step_curve(exp, rounds):
    out = run_algorithm(exp, "hsgd", rounds)
    m = eval_model(exp, out["global_model"])
    return out, m


def fig7(dataset="mimic3", total_steps=48):
    print(f"# Fig. 7 analogue ({dataset}): strategy 1 — P=Q beats P>Q at equal step budget")
    csv_row("P", "Q", "final_loss", "auc", "comm_MB_per_group")
    for (p, q) in ((1, 1), (2, 1), (4, 1), (2, 2), (4, 2), (4, 4), (8, 4), (8, 8)):
        exp = setup_experiment(dataset=dataset, n=512, groups=4, devices=32,
                              alpha=0.25, q=q, p=p, lr=0.02)
        out, m = auc_step_curve(exp, rounds=total_steps // p)
        sizes = sizes_for(exp, "hsgd")
        mb = comm_bytes_at_step(exp, "hsgd", sizes, len(out["losses"])) / 1e6
        csv_row(p, q, round(float(out["losses"][-1]), 4), round(m["auc_roc"], 4), round(mb, 3))


def fig8(dataset="mimic3", total_steps=48):
    print(f"# Fig. 8 analogue ({dataset}): strategy 2 — sweep P=Q")
    csv_row("PQ", "final_loss", "auc", "comm_MB_per_group")
    for pq in (1, 2, 4, 8, 16):
        exp = setup_experiment(dataset=dataset, n=512, groups=4, devices=32,
                              alpha=0.25, q=pq, p=pq, lr=0.02)
        out, m = auc_step_curve(exp, rounds=max(1, total_steps // pq))
        sizes = sizes_for(exp, "hsgd")
        mb = comm_bytes_at_step(exp, "hsgd", sizes, len(out["losses"])) / 1e6
        csv_row(pq, round(float(out["losses"][-1]), 4), round(m["auc_roc"], 4), round(mb, 3))
    # strategy-2 recommendation from the probes
    exp = setup_experiment(dataset=dataset, n=512, groups=4, devices=32)
    params0 = exp["model"].init(jax.random.PRNGKey(0))
    probe = estimate_rho_delta(exp["model"], params0, exp["data"], jax.random.PRNGKey(1))
    rec = recommend_settings(probe, total_steps, 0.02, exp["fed"])
    csv_row("strategy2_recommendation", rec["P"], round(rec["eta"], 5), round(probe["rho"], 3))


def fig9(dataset="mimic3", total_steps=40):
    print(f"# Fig. 9 analogue ({dataset}): strategy 3 — eta should shrink as P (or Q) grows")
    csv_row("P", "Q", "eta", "final_loss", "auc")
    for (p, q) in ((10, 5), (20, 5), (10, 10), (20, 10)):
        for eta in (0.0025, 0.005, 0.01):
            exp = setup_experiment(dataset=dataset, n=512, groups=4, devices=32,
                                  alpha=0.25, q=q, p=p, lr=eta)
            out, m = auc_step_curve(exp, rounds=max(1, total_steps // p))
            csv_row(p, q, eta, round(float(out["losses"][-1]), 4), round(m["auc_roc"], 4))


def main():
    fig7()
    fig8()
    fig9()


if __name__ == "__main__":
    main()
