"""Paper Fig. 5 + Table II: communication cost (per group) to reach target
training requirements (loss / precision / recall), HSGD vs baselines."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    comm_bytes_at_step,
    csv_row,
    eval_model,
    run_algorithm,
    setup_experiment,
    sizes_for,
)


def first_step_reaching(losses, target):
    hits = np.where(np.asarray(losses) <= target)[0]
    return int(hits[0]) + 1 if len(hits) else None


def table2(dataset="organamnist", rounds=40):
    exp = setup_experiment(dataset=dataset, n=512, groups=4, devices=32,
                          alpha=0.25, q=1, p=2, lr=0.02)
    loss_targets = {"organamnist": (1.5, 0.5), "esr": (1.2, 0.8), "mimic3": (0.5, 0.3)}[dataset]
    print(f"# Table II analogue: {dataset} — comm cost (MB/group) to reach targets")
    csv_row("algo", "metric", "target", "steps_to_target", "comm_MB_per_group", "final_auc")
    for algo in ("hsgd", "jfl", "tdcd", "c-hsgd", "c-tdcd"):
        out = run_algorithm(exp, algo, rounds)
        sizes = sizes_for(exp, algo)
        m = eval_model(exp, out["global_model"])
        for target in loss_targets:
            s = first_step_reaching(out["losses"], target)
            if s is None:
                csv_row(algo, "train_loss", target, "-", "-", round(m["auc_roc"], 3))
            else:
                mb = comm_bytes_at_step(exp, algo, sizes, s) / 1e6
                csv_row(algo, "train_loss", target, s, round(mb, 3), round(m["auc_roc"], 3))
    return True


def fig5(dataset="organamnist", rounds=40):
    """F1-vs-communication curves (Fig. 5)."""
    exp = setup_experiment(dataset=dataset, n=512, groups=4, devices=32,
                          alpha=0.25, q=1, p=2, lr=0.02)
    print(f"# Fig. 5 analogue: {dataset} — comm bytes (MB/group) at checkpoints")
    csv_row("algo", "frac_of_run", "comm_MB_per_group", "train_loss")
    for algo in ("hsgd", "jfl", "tdcd", "c-hsgd", "c-tdcd"):
        out = run_algorithm(exp, algo, rounds)
        sizes = sizes_for(exp, algo)
        n = len(out["losses"])
        for frac in (0.25, 0.5, 1.0):
            s = max(1, int(n * frac))
            mb = comm_bytes_at_step(exp, algo, sizes, s) / 1e6
            csv_row(algo, frac, round(mb, 3), round(float(out["losses"][s - 1]), 4))


def main():
    for ds in ("organamnist", "esr", "mimic3"):
        table2(ds)
        fig5(ds)


if __name__ == "__main__":
    main()
