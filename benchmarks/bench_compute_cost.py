"""Paper Tables III + IV: memory / FLOPs per device to reach the target, and
per-round computational time. Memory and FLOPs are measured analytically from
parameter/activation sizes (the paper's per-iteration cost x steps-to-target);
per-round wall time measured on this host."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import csv_row, eval_model, run_algorithm, setup_experiment
from repro.common.pytree import tree_bytes, tree_size


def flops_per_device_step(model, fed):
    """Rough per-device-step FLOPs: 2x params touched (fwd) + 4x (bwd)."""
    params = model.init(jax.random.PRNGKey(0))
    n_dev = tree_size(params["theta2"]) + tree_size(params["theta0"])
    return 6 * n_dev  # single-sample device batch


def table3_and_4(dataset="organamnist", rounds=30, auc_target=0.75):
    exp = setup_experiment(dataset=dataset, n=512, groups=4, devices=32,
                          alpha=0.25, q=1, p=2, lr=0.02)
    model, fed = exp["model"], exp["fed"]
    print(f"# Table III/IV analogue: {dataset} (AUC target {auc_target})")
    csv_row("algo", "steps", "per_round_s", "mem_MB_per_device", "MFLOPs_per_device", "auc")
    for algo in ("hsgd", "jfl", "tdcd", "c-hsgd", "c-tdcd"):
        out = run_algorithm(exp, algo, rounds)
        m = eval_model(exp, out["global_model"])
        steps = len(out["losses"])
        per_round = out["wall"] / max(1, steps // fed.global_interval)
        params = model.init(jax.random.PRNGKey(0))
        # device-resident state: θ2 (+ full triple for JFL's per-pair models)
        if algo == "jfl":
            mem = tree_bytes(params)
        else:
            mem = tree_bytes(params["theta2"]) + tree_bytes(params["theta0"])
        fl = flops_per_device_step(model, fed) * steps / 1e6
        csv_row(algo, steps, round(per_round, 3), round(mem / 1e6, 3),
                round(fl, 2), round(m["auc_roc"], 3))


def main():
    for ds in ("organamnist", "mimic3"):
        table3_and_4(ds)


if __name__ == "__main__":
    main()
