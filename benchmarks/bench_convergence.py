"""Paper Fig. 4 + Fig. 6: training performance (AUC of ROC) versus wall time
for HSGD and the four baselines, on all three (synthetic) datasets, under the
paper's WAN link model; Fig. 6's compute-time scaling (0.1x / 10x) included.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    comm_bytes_at_step,
    csv_row,
    eval_model,
    run_algorithm,
    setup_experiment,
    sizes_for,
)
from repro.core import comm_model as CM

ALGOS = ["hsgd", "jfl", "tdcd", "c-hsgd", "c-tdcd"]
# measured per-step compute time (s) at paper scale (Table IV shows 0.05-0.8)
T_COMPUTE = {"hsgd": 0.06, "jfl": 0.48, "tdcd": 0.06, "c-hsgd": 0.06, "c-tdcd": 0.06}


def fig4(dataset="organamnist", rounds=40, compute_scale=1.0, tag="fig4"):
    exp = setup_experiment(dataset=dataset, n=512, groups=4, devices=32,
                          alpha=0.25, q=1, p=2, lr=0.02)
    print(f"# {tag}: {dataset} AUC-vs-time (WAN link model, compute x{compute_scale})")
    csv_row("algo", "steps", "auc_roc", "f1", "train_loss", "sim_time_s", "wall_s")
    results = {}
    for algo in ALGOS:
        out = run_algorithm(exp, algo, rounds)
        m = eval_model(exp, out["global_model"])
        sizes = sizes_for(exp, algo)
        steps = len(out["losses"])
        t_c = T_COMPUTE[algo] * compute_scale
        sim_t = CM.time_to_step(sizes, out["fed"], t_c, steps) \
            if algo not in ("jfl",) else steps * (t_c + (sizes.theta0 + sizes.z1 + sizes.z2) / CM.WAN.dev_down)
        csv_row(algo, steps, round(m["auc_roc"], 4), round(m["f1"], 4),
                round(float(out["losses"][-1]), 4), round(sim_t, 1), round(out["wall"], 1))
        results[algo] = (m, sim_t)
    return results


def main():
    for ds in ("organamnist", "esr", "mimic3"):
        fig4(ds, tag=f"fig4-{ds}")
    # Fig. 6: compute-time sensitivity on OrganAMNIST
    fig4("organamnist", compute_scale=0.1, tag="fig6-compute-x0.1")
    fig4("organamnist", compute_scale=10.0, tag="fig6-compute-x10")


if __name__ == "__main__":
    main()
