"""Fault injection vs the robust federation runtime — same seeded trace.

The fault-tolerance claim (ROADMAP robustness item): under one seeded fault
schedule (device dropouts, NaN / outlier-scaled gradients, corrupted uplink
payloads), the NAIVE stack (plain masked-mean aggregation, no screening)
diverges or stalls, while the ROBUST stack (compiled finite/norm screening +
robust aggregation + divergence rollback) still reaches the fault-free
baseline's target loss — and its defense costs < 10% steps/s when nothing is
faulty (screening is jnp.where masks inside the same one-executor-per-bucket
compiled round). This benchmark runs all four configurations and records the
comparison into BENCH_faults.json:

  * baseline   — fault-free, plain cohort executor (sets the target loss and
                 the reference steps/s);
  * defended   — fault-free, robust executor (screening armed, nothing to
                 catch: bit-identical trajectory, bounded overhead);
  * naive      — faults on, defense off;
  * robust     — faults on, defense on (same seeded FaultPlan as naive).

  PYTHONPATH=src python benchmarks/bench_faults.py
"""
from __future__ import annotations

import argparse
import math
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import csv_row, setup_experiment

import jax

from repro.common.io import atomic_write_json
from repro.core.faults import FaultPlan
from repro.core.metrics import smoothed_losses, steps_to_target
from repro.core.population import (
    PopulationConfig,
    run_population,
    run_population_resilient,
)


def _timed(fn, repeats=3):
    """(result, best wall seconds over ``repeats``) with the device pipeline
    drained before each second timestamp — async dispatch would otherwise
    time the enqueue. Best-of-N because single passes on a shared host are
    ±10% noisy, the same margin the overhead acceptance bound allows."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = fn()
        jax.block_until_ready(res["state"])
        best = min(best, time.perf_counter() - t0)
    return res, best


def _clean(values):
    """JSON-safe loss list: NaN/Inf (the naive run's whole point) -> None."""
    return [float(v) if math.isfinite(v) else None for v in np.asarray(values)]


def summarize(res, target, smooth):
    # NaN/Inf -> huge finite sentinel: a diverged (naive) run just never
    # reaches the target (and the smoother never computes inf - inf)
    finite = np.nan_to_num(np.asarray(res["losses"], np.float64),
                           nan=1e30, posinf=1e30, neginf=1e30)
    sm = smoothed_losses(finite, smooth)
    hit = steps_to_target(finite, target, smooth)
    final = float(np.asarray(res["losses"])[-1])
    fl = res.get("fault_log", [])
    return {
        "final_loss": final if math.isfinite(final) else None,
        "steps": int(len(res["losses"])),
        "steps_to_target": None if hit is None else int(hit),
        "reached_target": hit is not None,
        "sim_seconds": float(res["sim_seconds"]),
        "rollbacks": int(res.get("rollbacks", 0)),
        "devices_dropped": int(sum(r["dropped"] for r in fl)),
        "grad_faults": int(sum(r["grad_faulted"] for r in fl)),
        "msg_faults": int(sum(r["msg_faulted"] for r in fl)),
        "updates_flagged": float(sum(r["flagged_updates"] for r in fl)),
        "executors_compiled": len(res["runner"]._round_cache),
        "min_smoothed_loss": (float(np.min(sm)) if np.isfinite(np.min(sm))
                              else None),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mimic3")
    ap.add_argument("--steps", type=int, default=48)
    ap.add_argument("--p", type=int, default=2)
    ap.add_argument("--q", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--samples", type=int, default=512)
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--devices", type=int, default=32)
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--fault-seed", type=int, default=7)
    ap.add_argument("--pop-devices", type=int, default=64)
    ap.add_argument("--cohort", type=int, default=8)
    ap.add_argument("--fault-dropout", type=float, default=0.10)
    ap.add_argument("--fault-nan", type=float, default=0.12)
    ap.add_argument("--fault-outlier", type=float, default=0.05)
    ap.add_argument("--fault-msg-corrupt", type=float, default=0.15)
    ap.add_argument("--robust-agg", default="median",
                    choices=["mean", "median", "trimmed"])
    ap.add_argument("--t-compute", type=float, default=0.05)
    ap.add_argument("--target-frac", type=float, default=0.75,
                    help="target = baseline's smoothed loss this far in")
    ap.add_argument("--smooth", type=int, default=4)
    ap.add_argument("--max-overhead", type=float, default=0.10,
                    help="accepted fault-free slowdown of the robust executor")
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..",
                                                  "BENCH_faults.json"))
    args = ap.parse_args(argv)

    exp = setup_experiment(dataset=args.dataset, n=args.samples,
                           groups=args.groups, devices=args.devices,
                           alpha=0.25, q=args.q, p=args.p, lr=args.lr,
                           robust_agg=args.robust_agg)
    model, fed, train, data = exp["model"], exp["fed"], exp["train"], exp["data"]
    pop = PopulationConfig(seed=args.trace_seed,
                           devices_per_group=args.pop_devices,
                           target_cohort=args.cohort)
    steps = max(1, args.steps // args.p) * args.p
    rounds = steps // args.p
    plan = FaultPlan(seed=args.fault_seed,
                     dropout_rate=args.fault_dropout,
                     nan_rate=args.fault_nan,
                     outlier_rate=args.fault_outlier,
                     msg_corrupt_rate=args.fault_msg_corrupt)
    print(f"# naive vs robust under seeded faults, {args.dataset}, "
          f"{rounds} rounds x P={args.p} (trace seed {args.trace_seed}, "
          f"fault seed {args.fault_seed})")

    kw = dict(mode="semi_async", t_compute=args.t_compute)
    # best-of-3 each; the first pass compiles and loses the min anyway
    run_plain = lambda: run_population(model, fed, train, data, pop,
                                       rounds=rounds, **kw)
    run_defended = lambda: run_population_resilient(
        model, fed, train, data, pop, rounds=rounds, faults=None,
        robust=True, monitor=False, **kw)
    res_base, t_plain = _timed(run_plain)
    res_def, t_def = _timed(run_defended)
    res_naive = run_population_resilient(
        model, fed, train, data, pop, rounds=rounds, faults=plan,
        robust=False, monitor=False, **kw)
    res_robust = run_population_resilient(
        model, fed, train, data, pop, rounds=rounds, faults=plan,
        robust=True, monitor=False, **kw)

    sm_base = smoothed_losses(res_base["losses"], args.smooth)
    target = float(sm_base[min(len(sm_base) - 1,
                               int(args.target_frac * len(sm_base)))])
    runs = {
        "baseline": summarize(res_base, target, args.smooth),
        "defended_clean": summarize(res_def, target, args.smooth),
        "naive": summarize(res_naive, target, args.smooth),
        "robust": summarize(res_robust, target, args.smooth),
    }
    sps_plain = steps / t_plain
    sps_def = steps / t_def
    overhead = sps_plain / sps_def - 1.0
    # loss-curve parity to float32 resolution: the PARAMETER trajectory is
    # bit-identical (pinned by tests/test_faults.py); the reported per-step
    # loss scalar may differ in the final ULP across the two executors
    parity = bool(np.allclose(np.asarray(res_base["losses"]),
                              np.asarray(res_def["losses"]),
                              rtol=1e-6, atol=0.0))
    summary = {
        "target_loss": target,
        "fault_seed": args.fault_seed,
        "robust_reaches_target": runs["robust"]["reached_target"],
        "naive_misses_target": not runs["naive"]["reached_target"],
        "defense_overhead_frac": overhead,
        "defense_overhead_ok": overhead < args.max_overhead,
        "fault_free_losses_match": parity,
        "steps_per_s_plain": sps_plain,
        "steps_per_s_defended": sps_def,
    }

    csv_row("run", "final_loss", "steps_to_target", "flagged", "rollbacks",
            "executors")
    for name, r in runs.items():
        csv_row(name, None if r["final_loss"] is None
                else round(r["final_loss"], 4),
                r["steps_to_target"], r["updates_flagged"], r["rollbacks"],
                r["executors_compiled"])
    print(f"# defense overhead fault-free: {100 * overhead:.1f}% "
          f"({sps_plain:.1f} -> {sps_def:.1f} steps/s)")

    result = {
        "config": {"dataset": args.dataset, "steps": steps, "p": args.p,
                   "q": args.q, "lr": args.lr, "samples": args.samples,
                   "groups": args.groups, "devices": args.devices,
                   "trace_seed": args.trace_seed,
                   "fault_seed": args.fault_seed,
                   "pop_devices": args.pop_devices, "cohort": args.cohort,
                   "fault_dropout": args.fault_dropout,
                   "fault_nan": args.fault_nan,
                   "fault_outlier": args.fault_outlier,
                   "fault_msg_corrupt": args.fault_msg_corrupt,
                   "robust_agg": args.robust_agg,
                   "t_compute": args.t_compute,
                   "target_frac": args.target_frac, "smooth": args.smooth,
                   "max_overhead": args.max_overhead},
        "summary": summary,
        "runs": runs,
        "curves": {
            "baseline": _clean(res_base["losses"]),
            "naive": _clean(res_naive["losses"]),
            "robust": _clean(res_robust["losses"]),
        },
    }
    atomic_write_json(args.out, result)
    print(f"# wrote {os.path.abspath(args.out)}")
    return result


if __name__ == "__main__":
    main()
