"""HSGD hot-path benchmark: the fused/donating loop vs the pre-PR loop.

Measures, on the quickstart federation with C-HSGD compression enabled
(top-k 0.25 + b=128 quantization), three variants:

  * ``pre_pr``       — the seed hot path: lax-conv towers with
                       reduce_window max pooling (SelectAndScatter backward),
                       leaf-wise sort-based top-k + separate quantize.
  * ``sort_compress``— the optimized model (im2col GEMM convs, reshape-max
                       pool) but the pre-fusion compression path; isolates
                       the compression fusion win.
  * ``fused``        — the full new hot path: one fused top-k+quantize
                       row-matrix call per exchange + donated state.

Reported per variant: steps/s of the full jitted training loop, µs per
exchange event, and the compiled peak-memory estimate when the backend
reports one. Results land in BENCH_hsgd.json so the speedup stays in the
perf trajectory.

  PYTHONPATH=src python benchmarks/bench_hsgd_hotpath.py [--rounds N]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, setup_experiment
from repro.common.io import atomic_write_json
from repro.core.hsgd import HSGDRunner, exchange, init_state, make_group_weights
from repro.models import cnn as C
from repro.models import layers as L
from repro.models.split_model import HybridModel


# ---------------------------------------------------------------------------
# The seed (pre-PR) CNN hot path, reconstructed for an honest baseline
# ---------------------------------------------------------------------------


def _legacy_conv2d(params, x):
    y = jax.lax.conv_general_dilated(
        x, params["w"].astype(x.dtype), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + params["b"].astype(x.dtype)


def _legacy_tower(params, x_flat, in_rows, width=28, n_conv=2):
    B = x_flat.shape[0]
    x = x_flat.reshape(B, in_rows, width, 1)
    for i in range(n_conv):
        x = jax.nn.relu(_legacy_conv2d(params[f"conv{i}"], x))
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    return L.dense(params["proj"], x.reshape(B, -1))


def legacy_cnn_hybrid(h_rows=11, width=28, n_classes=11, embed_dim=64):
    d_rows = width - h_rows

    def predict(t0, z1, z2):
        return C.combined_forward(t0, z1, z2)

    return HybridModel(
        name="paper_cnn_pre_pr",
        specs0=C.combined_specs(embed_dim, n_classes),
        specs1=C.tower_specs(h_rows, width, embed_dim=embed_dim),
        specs2=C.tower_specs(d_rows, width, embed_dim=embed_dim),
        h1=lambda t, x1: _legacy_tower(t, x1, h_rows, width),
        h2=lambda t, x2: _legacy_tower(t, x2, d_rows, width),
        loss=lambda t0, z1, z2, y: C.classification_loss(predict(t0, z1, z2), y),
        predict=predict,
    )


# ---------------------------------------------------------------------------
# Timing helpers
# ---------------------------------------------------------------------------


def time_run(runner, state, data, w, rounds, repeats=5):
    """Median wall time of a full jitted run (first call compiles)."""
    times = []
    for i in range(repeats + 1):
        s = jax.tree.map(jnp.copy, state)  # run() donates its input
        t0 = time.perf_counter()
        out, losses = runner.run(s, data, w, rounds=rounds)
        jax.block_until_ready(losses)
        if i:  # discard the compile call
            times.append(time.perf_counter() - t0)
    return float(np.median(times)), out


def time_exchange(model, state, data, fed, train, fused, iters=20):
    fn = jax.jit(lambda s: exchange(model, s, data, fed, train.compression_k,
                                    train.quantization_bits, fused=fused))
    state = fn(state)  # compile
    jax.block_until_ready(state.stale["z1"])
    t0 = time.perf_counter()
    for _ in range(iters):
        state = fn(state)
    jax.block_until_ready(state.stale["z1"])
    return (time.perf_counter() - t0) / iters * 1e6


def peak_memory_bytes(runner, state, data, w):
    """Compiled temp+output size estimate; None when the backend is silent."""
    try:
        lowered = jax.jit(
            lambda s, d, gw: runner._round(s, d, gw, lambda _: 0.01),
        ).lower(state, data, w)
        mem = lowered.compile().memory_analysis()
        if mem is None:
            return None
        return int(getattr(mem, "temp_size_in_bytes", 0)
                   + getattr(mem, "output_size_in_bytes", 0))
    except Exception:
        return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..",
                                                  "BENCH_hsgd.json"))
    args = ap.parse_args()
    if args.rounds < 1:
        ap.error("--rounds must be >= 1")

    # quickstart federation + C-HSGD compression (paper: k=0.25, b=128)
    exp = setup_experiment(dataset="organamnist", n=1024, groups=4, devices=64,
                           alpha=0.25, q=2, p=4, lr=0.02,
                           compression_k=0.25, quant=128)
    fed, train, data = exp["fed"], exp["train"], exp["data"]
    model_new = exp["model"]
    model_pre = legacy_cnn_hybrid(h_rows=11, n_classes=exp["spec"].n_classes)
    w = make_group_weights(data)
    steps_per_round = fed.global_interval

    variants = (
        ("pre_pr", model_pre, False),
        ("sort_compress", model_new, False),
        ("fused", model_new, True),
    )

    results = {"config": {"groups": fed.num_groups, "devices": fed.devices_per_group,
                          "alpha": fed.alpha, "Q": fed.local_interval,
                          "P": fed.global_interval, "rounds": args.rounds,
                          "compression_k": train.compression_k,
                          "quantization_b": train.quantization_bits,
                          "backend": jax.default_backend()}}

    print("# HSGD hot path: fused vs pre-PR loop "
          f"({jax.default_backend()}, {args.rounds} rounds)")
    csv_row("variant", "steps_per_s", "exchange_us", "peak_mem_bytes")
    for name, model, fused in variants:
        state = init_state(jax.random.PRNGKey(0), model, fed, data)
        runner = HSGDRunner(model, fed, train, fused_compression=fused)
        wall, _ = time_run(runner, state, data, w, args.rounds)
        steps_s = args.rounds * steps_per_round / wall
        exch_us = time_exchange(model, state, data, fed, train, fused)
        mem = peak_memory_bytes(runner, state, data, w)
        results[name] = {"steps_per_s": round(steps_s, 2),
                         "exchange_us": round(exch_us, 1),
                         "peak_mem_bytes": mem,
                         "wall_s": round(wall, 4)}
        csv_row(name, round(steps_s, 2), round(exch_us, 1), mem)

    results["speedup_steps_per_s"] = round(
        results["fused"]["steps_per_s"] / results["pre_pr"]["steps_per_s"], 3)
    results["speedup_exchange"] = round(
        results["pre_pr"]["exchange_us"] / max(results["fused"]["exchange_us"], 1e-9), 3)
    results["speedup_compression_only"] = round(
        results["fused"]["steps_per_s"] / results["sort_compress"]["steps_per_s"], 3)
    pre_m, fus_m = results["pre_pr"]["peak_mem_bytes"], results["fused"]["peak_mem_bytes"]
    if pre_m and fus_m:
        results["peak_mem_delta_bytes"] = pre_m - fus_m
    print(f"# steps/s speedup vs pre-PR: {results['speedup_steps_per_s']:.2f}x, "
          f"exchange: {results['speedup_exchange']:.2f}x")

    atomic_write_json(args.out, results, indent=2)
    print(f"# wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
