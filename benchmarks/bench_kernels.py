"""Kernel microbenchmarks: interpret-mode Pallas kernel vs jnp oracle,
us/call + correctness deltas (wall numbers are CPU-interpret; the BlockSpec
tiling is the TPU story)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.kernels import ops, ref


def timeit(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))  # compile + drain: keep warmup out of t0
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def main():
    print("# kernel microbenchmarks (CPU interpret mode)")
    csv_row("kernel", "shape", "us_per_call_kernel", "us_per_call_ref", "max_err")
    key = jax.random.PRNGKey(0)

    x = jax.random.normal(key, (64, 512))
    t_k = timeit(lambda a: ops.topk_sparsify(a, 0.1), x)
    t_r = timeit(lambda a: ref.topk_sparsify_ref(a, 51), x)
    err = float(jnp.max(jnp.abs(ops.topk_sparsify(x, 0.1) - ref.topk_sparsify_ref(x, 51))))
    csv_row("topk_sparsify", "64x512", round(t_k, 1), round(t_r, 1), err)

    # fused top-k + b-level quantize vs the pre-fusion two-pass sort path
    from repro.core.compression import compress_message_sort

    fused = jax.jit(lambda a: ops.fused_compress(a, 0.1, 128))
    sortp = jax.jit(lambda a: compress_message_sort(a, 0.1, 128))
    t_k = timeit(fused, x)
    t_r = timeit(sortp, x)
    err = float(jnp.max(jnp.abs(fused(x) - ref.compress_rows_ref(x, 51, 128))))
    csv_row("fused_compress", "64x512", round(t_k, 1), round(t_r, 1), err)

    # interpret-mode Pallas twin of the fused kernel (validation path),
    # timed against the jitted fused reference it must match bit-for-bit
    from repro.kernels.compress import fused_compress_pallas

    ref_jit = jax.jit(lambda a: ref.compress_rows_ref(a, 51, 128))
    t_k = timeit(lambda a: fused_compress_pallas(a, 51, 128, interpret=True), x)
    t_r = timeit(ref_jit, x)
    err = float(jnp.max(jnp.abs(fused_compress_pallas(x, 51, 128, interpret=True) - ref_jit(x))))
    csv_row("fused_compress_pallas", "64x512", round(t_k, 1), round(t_r, 1), err)

    B, S, H, D = 1, 256, 4, 64
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    t_k = timeit(lambda a, b, c: ops.flash_attention(a, b, c), q, k, v)
    t_r = timeit(lambda a, b, c: ref.flash_attention_ref(a, b, c), qf, kf, vf)
    out = ops.flash_attention(q, k, v).transpose(0, 2, 1, 3).reshape(B * H, S, D)
    err = float(jnp.max(jnp.abs(out - ref.flash_attention_ref(qf, kf, vf))))
    csv_row("flash_attention", f"{B}x{S}x{H}x{D}", round(t_k, 1), round(t_r, 1), err)

    Bs, T, C = 2, 256, 128
    a = jax.nn.sigmoid(jax.random.normal(key, (Bs, T, C)))
    b = jax.random.normal(jax.random.PRNGKey(3), (Bs, T, C))
    h0 = jnp.zeros((Bs, C))
    t_k = timeit(lambda x1, x2, x3: ops.ssm_scan(x1, x2, x3)[0], a, b, h0)
    t_r = timeit(lambda x1, x2, x3: ref.ssm_scan_ref(x1, x2, x3)[0], a, b, h0)
    err = float(jnp.max(jnp.abs(ops.ssm_scan(a, b, h0)[0] - ref.ssm_scan_ref(a, b, h0)[0])))
    csv_row("ssm_scan", f"{Bs}x{T}x{C}", round(t_k, 1), round(t_r, 1), err)


if __name__ == "__main__":
    main()
