"""Fixed-cadence vs §VI-adaptive HSGD on the LLM-scale ``llm_hybrid`` path.

The e-health claim (BENCH_adaptive.json), rerun where communication actually
bites: a smoke-scale assigned architecture trained through the compiled
federated rounds of ``launch/steps.py`` on resampled synthetic token streams.

  * fixed    — ``LLMRoundRunner.run_fixed`` at a constant (P, Q, η),
               uncompressed messages (exchange every step at P = Q = 1);
  * adaptive — ``AdaptiveLLMRunner`` re-picking P = Q and η every round from
               the step's own gradient probes, with the byte governor holding
               the run under ``--budget-frac`` × the fixed run's eq. (19) bill.

Writes BENCH_llm_adaptive.json (schema in benchmarks/README.md). The headline
acceptance: ``summary.adaptive_reaches_target`` with
``summary.adaptive_bytes_to_target`` strictly below the fixed run's bill, and
one compiled executor per distinct (P, Q, k, b) bucket.

  PYTHONPATH=src python benchmarks/bench_llm_adaptive.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import csv_row
import jax

from repro.common.config import get_config
from repro.common.io import atomic_write_json
from repro.core import comm_model as CM
from repro.core.controller import AdaptiveConfig
from repro.core.metrics import smoothed_losses, steps_to_target
from repro.data.synthetic import llm_batch_fn
from repro.launch.steps import AdaptiveLLMRunner, LLMRoundRunner, init_llm_params
from repro.models.split_model import llm_hybrid


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--full", action="store_true",
                    help="full config instead of the smoke reduction")
    ap.add_argument("--steps", type=int, default=192)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--p", type=int, default=1, help="fixed-cadence P")
    ap.add_argument("--q", type=int, default=1, help="fixed-cadence Q")
    ap.add_argument("--lr", type=float, default=0.06,
                    help="fixed-cadence η AND the adaptive seed; keep within "
                         "Theorem 1's η ≤ 1/(8Pρ) regime (ρ ≈ 1-2 here) or "
                         "the comparison is theory-vs-folklore")
    ap.add_argument("--budget-frac", type=float, default=0.2,
                    help="adaptive byte budget as a fraction of the fixed bill")
    ap.add_argument("--max-interval", type=int, default=8)
    ap.add_argument("--smooth", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..",
                                                  "BENCH_llm_adaptive.json"))
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=not args.full)
    model = llm_hybrid(cfg, n_tower=1, remat=False)
    G = args.pods
    mk_params = lambda: init_llm_params(jax.random.PRNGKey(args.seed), model,
                                        n_pods=G)
    mk_batches = lambda: llm_batch_fn(cfg, args.batch, args.seq, n_pods=G,
                                      seed=args.seed)

    # shared eq. (19) size model (live ζ shapes), via the adaptive runner;
    # abstract param shapes only — no throwaway init at --full scale
    adaptive = AdaptiveLLMRunner(model, n_pods=G, learning_rate=args.lr)
    params_sds = jax.eval_shape(
        lambda k: init_llm_params(k, model, n_pods=G), jax.random.PRNGKey(0))
    sizes_of = adaptive._sizes_of(params_sds, mk_batches()(0, 1))

    # ---- fixed-cadence baseline (uncompressed) -----------------------------
    steps = max(1, args.steps // args.p) * args.p  # whole rounds, same budget
    fixed_runner = LLMRoundRunner(model, n_pods=G)
    _, fixed_losses = fixed_runner.run_fixed(
        mk_params(), mk_batches(), steps=steps, P=args.p, Q=args.q, lr=args.lr)
    per_iter = CM.per_round_bytes(sizes_of(0.0, 0), args.p, args.q, G) / args.p
    fixed_bytes = per_iter * np.arange(1, len(fixed_losses) + 1)

    # ---- adaptive under budget-frac × the fixed bill -----------------------
    budget = float(fixed_bytes[-1]) * args.budget_frac
    # eta_min is the anti-stall floor: the controller never drops η below 80%
    # of the practitioner's seed UNLESS Theorem 1's 1/(8Pρ) cap demands it
    # (the floor yields to the cap in plan_round's eta_for)
    adaptive.cfg = AdaptiveConfig(total_steps=steps, byte_budget=budget,
                                  max_interval=args.max_interval,
                                  eta_min=0.8 * args.lr,
                                  eta_max=max(args.lr, 0.05))
    _, ad_losses, history = adaptive.run(mk_params(), mk_batches())
    steps_bytes = np.concatenate([
        np.full(h["P"], h["round_bytes"] / h["P"]) for h in history])
    ad_bytes = np.cumsum(steps_bytes)

    target = float(smoothed_losses(fixed_losses, args.smooth)[-1])
    fx_hit = steps_to_target(fixed_losses, target, args.smooth)
    ad_hit = steps_to_target(ad_losses, target, args.smooth)
    buckets = {k[:4] for k in adaptive.runner._round_cache}

    summary = {
        "target_loss": target,
        "fixed_final_loss": float(smoothed_losses(fixed_losses, args.smooth)[-1]),
        "adaptive_final_loss": float(smoothed_losses(ad_losses, args.smooth)[-1]),
        "fixed_total_bytes": float(fixed_bytes[-1]),
        "adaptive_total_bytes": float(ad_bytes[-1]),
        "adaptive_byte_budget": budget,
        "fixed_steps_to_target": fx_hit,
        "adaptive_steps_to_target": ad_hit,
        "fixed_bytes_to_target": float(fixed_bytes[fx_hit]) if fx_hit is not None else None,
        "adaptive_bytes_to_target": float(ad_bytes[ad_hit]) if ad_hit is not None else None,
        "adaptive_reaches_target": ad_hit is not None,
        "adaptive_bytes_lower": float(ad_bytes[-1]) < float(fixed_bytes[-1]),
        "compiled_executors": len(adaptive.runner._round_cache),
        "distinct_buckets": len(buckets),
    }

    csv_row("run", "final_loss", "total_MB", "steps_to_target", "MB_to_target")
    csv_row("fixed", round(summary["fixed_final_loss"], 4),
            round(summary["fixed_total_bytes"] / 1e6, 3), fx_hit,
            round((summary["fixed_bytes_to_target"] or 0) / 1e6, 3))
    csv_row("adaptive", round(summary["adaptive_final_loss"], 4),
            round(summary["adaptive_total_bytes"] / 1e6, 3), ad_hit,
            round((summary["adaptive_bytes_to_target"] or 0) / 1e6, 3)
            if ad_hit is not None else None)
    for h in history:
        print(f"#   round {h['round']:3d}: P=Q={h['P']:3d} eta={h['eta']:.4g} "
              f"rung={h['rung']} bytes={h['bytes_total'] / 1e6:.2f}MB "
              f"loss={h['loss_last']:.4f}")

    result = {
        "config": {"arch": args.arch, "smoke": not args.full, "steps": steps,
                   "batch": args.batch, "seq": args.seq, "pods": G,
                   "p": args.p, "q": args.q, "lr": args.lr,
                   "budget_frac": args.budget_frac,
                   "max_interval": args.max_interval, "smooth": args.smooth,
                   "seed": args.seed},
        "summary": summary,
        "fixed": {"losses": fixed_losses.tolist(), "bytes": fixed_bytes.tolist()},
        "adaptive": {"losses": ad_losses.tolist(), "bytes": ad_bytes.tolist(),
                     "history": history},
    }
    atomic_write_json(args.out, result)
    print(f"# wrote {os.path.abspath(args.out)}")
    return result


if __name__ == "__main__":
    main()
