"""Sync vs semi-async vs adaptive population federation — time-to-target.

ROADMAP item 1's headline claim: on the SAME seeded device trace (availability
windows + lognormal straggler tails over the non-IID split), closing rounds at
a deadline quantile with staleness-damped late updates (semi-async), and
additionally letting the §VI controller plan against the wall-clock model
(adaptive + ``time_budget``), should reach a fixed-(P, Q) synchronous
baseline's loss in LESS simulated wall-clock. This benchmark runs all three
and records the comparison into BENCH_population.json:

  * sync       — every round waits for the slowest sampled cohort;
  * semi_async — rounds close at ``--deadline-quantile``; late groups'
                 updates land next round damped by ``damping**staleness``;
  * adaptive   — semi-async scheduling + ControllerCore re-picking
                 (P, Q, η, compression rung) each round against byte AND
                 wall-clock ledgers (budget = ``--time-budget-frac`` × the
                 sync run's total simulated seconds).

  PYTHONPATH=src python benchmarks/bench_population.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import csv_row, setup_experiment

from repro.common.io import atomic_write_json
from repro.core.controller import AdaptiveConfig
from repro.core.metrics import smoothed_losses, steps_to_target
from repro.core.population import (
    PopulationConfig,
    run_population,
    run_population_adaptive,
)


def time_to_target(res, target, smooth):
    """(simulated seconds to reach target, step index) — (None, None) if missed."""
    hit = steps_to_target(res["losses"], target, smooth)
    if hit is None:
        return None, None
    return float(res["times"][hit]), int(hit)


def summarize(res, target, smooth):
    tt, hit = time_to_target(res, target, smooth)
    return {
        "final_loss": float(smoothed_losses(res["losses"], smooth)[-1]),
        "sim_seconds": float(res["sim_seconds"]),
        "steps": int(len(res["losses"])),
        "time_to_target": tt,
        "steps_to_target": hit,
        "staleness_hist": {str(k): v for k, v in sorted(res["staleness_hist"].items())},
        "cohort_buckets": sorted({h["bucket"] for h in res["history"]
                                  if "bucket" in h}),
        "executors_compiled": len(res["runner"]._round_cache),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mimic3")
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--p", type=int, default=2)
    ap.add_argument("--q", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--samples", type=int, default=512)
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--devices", type=int, default=32)
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--pop-devices", type=int, default=64,
                    help="simulated population per group")
    ap.add_argument("--cohort", type=int, default=8,
                    help="devices sampled per group per round")
    ap.add_argument("--deadline-quantile", type=float, default=0.8)
    ap.add_argument("--staleness-damping", type=float, default=0.6)
    ap.add_argument("--max-staleness", type=int, default=4)
    ap.add_argument("--t-compute", type=float, default=0.05)
    ap.add_argument("--time-budget-frac", type=float, default=0.75,
                    help="adaptive wall-clock budget as a fraction of sync's")
    ap.add_argument("--adaptive-steps-frac", type=float, default=1.0,
                    help="adaptive step CEILING as a fraction of --steps; the "
                    "binding constraint is the wall-clock budget (the "
                    "controller trades cheap compressed steps for time)")
    ap.add_argument("--max-interval", type=int, default=16)
    ap.add_argument("--target-frac", type=float, default=0.75,
                    help="target = sync's smoothed loss this far into its run")
    ap.add_argument("--smooth", type=int, default=4)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..",
                                                  "BENCH_population.json"))
    args = ap.parse_args(argv)

    exp = setup_experiment(dataset=args.dataset, n=args.samples,
                           groups=args.groups, devices=args.devices,
                           alpha=0.25, q=args.q, p=args.p, lr=args.lr)
    model, fed, train = exp["model"], exp["fed"], exp["train"]
    pop = PopulationConfig(seed=args.trace_seed,
                           devices_per_group=args.pop_devices,
                           target_cohort=args.cohort,
                           deadline_quantile=args.deadline_quantile,
                           staleness_damping=args.staleness_damping,
                           max_staleness=args.max_staleness)
    steps = max(1, args.steps // args.p) * args.p
    rounds = steps // args.p
    print(f"# sync vs semi-async vs adaptive population, {args.dataset}, "
          f"{rounds} rounds x P={args.p} (trace seed {args.trace_seed}, "
          f"{args.pop_devices} devices/group, cohort {args.cohort})")

    kw = dict(t_compute=args.t_compute)
    res_sync = run_population(model, fed, train, exp["data"], pop,
                              rounds=rounds, mode="sync", **kw)
    res_semi = run_population(model, fed, train, exp["data"], pop,
                              rounds=rounds, mode="semi_async", **kw)
    cfg = AdaptiveConfig(total_steps=int(steps * args.adaptive_steps_frac),
                         time_budget=float(res_sync["sim_seconds"])
                         * args.time_budget_frac,
                         max_interval=args.max_interval,
                         eta_max=max(train.learning_rate * 10, 0.05),
                         init_probe=False)
    res_ad = run_population_adaptive(model, fed, train, exp["data"], pop, cfg,
                                     **kw)

    # target: the loss sync has reached target_frac of the way through its
    # run — every mode gets the full step budget to reach the same bar
    sm_sync = smoothed_losses(res_sync["losses"], args.smooth)
    target = float(sm_sync[min(len(sm_sync) - 1,
                               int(args.target_frac * len(sm_sync)))])
    modes = {
        "sync": summarize(res_sync, target, args.smooth),
        "semi_async": summarize(res_semi, target, args.smooth),
        "adaptive": summarize(res_ad, target, args.smooth),
    }
    tt = {m: modes[m]["time_to_target"] for m in modes}
    summary = {
        "target_loss": target,
        "trace_seed": args.trace_seed,
        "semi_async_faster_than_sync": (
            tt["semi_async"] is not None
            and (tt["sync"] is None or tt["semi_async"] < tt["sync"])),
        "adaptive_faster_than_sync": (
            tt["adaptive"] is not None
            and (tt["sync"] is None or tt["adaptive"] < tt["sync"])),
        "adaptive_time_budget": cfg.time_budget,
    }

    csv_row("mode", "final_loss", "sim_s", "time_to_target_s", "executors")
    for m in ("sync", "semi_async", "adaptive"):
        r = modes[m]
        csv_row(m, round(r["final_loss"], 4), round(r["sim_seconds"], 2),
                None if r["time_to_target"] is None
                else round(r["time_to_target"], 2),
                r["executors_compiled"])
    for h in res_ad["history"]:
        print(f"#   round {h['round']:3d}: P=Q={h['P']:3d} eta={h['eta']:.4g} "
              f"rung={h['rung']} sim={h['seconds_total']:.2f}s "
              f"loss={h['loss_last']:.4f}")

    result = {
        "config": {"dataset": args.dataset, "steps": steps, "p": args.p,
                   "q": args.q, "lr": args.lr, "samples": args.samples,
                   "groups": args.groups, "devices": args.devices,
                   "trace_seed": args.trace_seed,
                   "pop_devices": args.pop_devices, "cohort": args.cohort,
                   "deadline_quantile": args.deadline_quantile,
                   "staleness_damping": args.staleness_damping,
                   "max_staleness": args.max_staleness,
                   "t_compute": args.t_compute,
                   "time_budget_frac": args.time_budget_frac,
                   "adaptive_steps_frac": args.adaptive_steps_frac,
                   "max_interval": args.max_interval,
                   "target_frac": args.target_frac, "smooth": args.smooth},
        "summary": summary,
        "modes": modes,
        "curves": {
            "sync": {"losses": res_sync["losses"].tolist(),
                     "times": res_sync["times"].tolist()},
            "semi_async": {"losses": res_semi["losses"].tolist(),
                           "times": res_semi["times"].tolist()},
            "adaptive": {"losses": res_ad["losses"].tolist(),
                         "times": res_ad["times"].tolist(),
                         "history": res_ad["history"]},
        },
    }
    atomic_write_json(args.out, result)
    print(f"# wrote {os.path.abspath(args.out)}")
    return result


if __name__ == "__main__":
    main()
