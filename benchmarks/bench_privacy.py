"""Privacy-hardened exchange: utility vs ε at fixed wire bytes + overheads.

The privacy claim (ROADMAP item 3): the fused compression kernel absorbs the
Gaussian mechanism (per-row L2 clip + noise BEFORE sparsification, so the
released message is a post-processing of a DP output) at < 10% kernel-pass
overhead, the σ = 0 / large-clip configuration is BIT-IDENTICAL to the
non-DP pass, and secure-aggregation masking changes the aggregate by nothing
at all (fixed-point ring: wrapping int32 sums are exact, so the pairwise
antisymmetric masks cancel to the bit). This benchmark pins all three and
sweeps the noise multiplier σ at the paper's C-HSGD operating point
(k = 0.25, b = 128 — every run ships IDENTICAL bytes) to record the
loss-vs-ε utility curve into BENCH_privacy.json:

  * baseline      — C-HSGD, no DP, no masking (reference loss + kernel time);
  * secure        — same trajectory, ring-masked uplinks (bit parity check);
  * dp @ σ        — fused DP at each ladder σ, (ε, δ) from zCDP composition.

  PYTHONPATH=src python benchmarks/bench_privacy.py
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import csv_row, setup_experiment

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.io import atomic_write_json
from repro.core import federation as F
from repro.core.baselines import make_runner
from repro.core.compression import compressed_bytes
from repro.core.controller import epsilon_of, gaussian_rho
from repro.core.hsgd import init_state, make_group_weights
from repro.kernels.compress import compress_rows


def _timed_ratio(fn_a, fn_b, inner=10, trials=9):
    """(best seconds of a, best seconds of b, best-b / best-a ratio).

    Each trial times ``inner`` back-to-back dispatches, with the device
    pipeline drained before the second timestamp — async dispatch would
    otherwise time the enqueue. The two sides are INTERLEAVED and each keeps
    its best-of-N region (the quiet-window estimate): single regions on a
    shared host are ±15% noisy, the same reasoning as ``bench_faults``'s
    best-of-N, and far noisier than the < 10% margin the acceptance bound
    allows. Warm-up absorbs compilation."""
    jax.block_until_ready(fn_a())
    jax.block_until_ready(fn_b())

    def region(fn):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn()
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / inner

    ta, tb = [], []
    for _ in range(trials):
        ta.append(region(fn_a))
        tb.append(region(fn_b))
    return float(min(ta)), float(min(tb)), float(min(tb) / min(ta))


def kernel_overhead(args):
    """Fused kernel pass with vs without the DP stage on one row matrix.

    The workload mirrors ``compress_pytree``'s actual call: a padded ragged
    row matrix with per-row valid lengths and per-row k. The noise rows are
    precomputed operands (that is how the exchange path feeds the kernel —
    the PRNG runs outside), so this isolates the marginal in-kernel cost:
    one row reduction + one multiply-add."""
    key = jax.random.PRNGKey(args.seed)
    kx, kn, kl = jax.random.split(key, 3)
    x = jax.random.normal(kx, (args.bench_rows, args.bench_cols), jnp.float32)
    noise = jax.random.normal(kn, x.shape, jnp.float32)
    row_len = jax.random.randint(kl, (args.bench_rows,), args.bench_cols // 2,
                                 args.bench_cols + 1, jnp.int32)
    k = jnp.maximum(1, row_len // 4)
    clip = jnp.asarray(1.0, jnp.float32)
    sigma = jnp.asarray(1.0, jnp.float32)

    t_plain, t_dp, ratio = _timed_ratio(
        lambda: compress_rows(x, k, 128, row_len=row_len),
        lambda: compress_rows(x, k, 128, row_len=row_len, dp_clip=clip,
                              dp_sigma=sigma, dp_noise=noise),
        trials=args.repeats)

    # σ = 0 with a clip above every row norm multiplies by exactly 1.0 and
    # adds exactly 0.0 — the DP trace must reproduce the non-DP pass bitwise
    y_plain = jax.block_until_ready(compress_rows(x, k, 128, row_len=row_len))
    y_dp0 = jax.block_until_ready(
        compress_rows(x, k, 128, row_len=row_len,
                      dp_clip=jnp.asarray(1e9, jnp.float32),
                      dp_sigma=jnp.asarray(0.0, jnp.float32), dp_noise=noise))
    return {
        "rows": args.bench_rows, "cols": args.bench_cols,
        "seconds_plain": t_plain, "seconds_dp": t_dp,
        "overhead_frac": ratio - 1.0,
        "sigma0_bit_identical": bool(
            np.array_equal(np.asarray(y_plain), np.asarray(y_dp0))),
    }


def masking_parity(model, fed, data, seed):
    """Ring-masked aggregation vs the zero-mask ring pipeline (bitwise) and
    vs the plain float mean (fixed-point resolution 2^-16 per slot)."""
    state = init_state(jax.random.PRNGKey(seed), model, fed, data)
    masks = F.secure_agg_masks(state.theta2, seed, round_idx=0)
    zeros = jax.tree.map(lambda m: jnp.zeros_like(m), masks)
    agg_masked = F.secure_local_aggregate(
        F.secure_mask_uplink(state.theta2, masks), state.theta2)
    agg_unmasked = F.secure_local_aggregate(
        F.secure_mask_uplink(state.theta2, zeros), state.theta2)
    agg_float = F.local_aggregate(state.theta2)
    bit = all(np.array_equal(np.asarray(a), np.asarray(b))
              for a, b in zip(jax.tree.leaves(agg_masked),
                              jax.tree.leaves(agg_unmasked)))
    tol = 2.0 ** -15  # rounding to the ring grid costs <= 2^-17 per slot
    close = all(np.max(np.abs(np.asarray(a) - np.asarray(b))) <= tol
                for a, b in zip(jax.tree.leaves(agg_masked),
                                jax.tree.leaves(agg_float)))
    return {"masked_sum_bit_identical": bool(bit),
            "masked_vs_float_within_tol": bool(close), "tolerance": tol}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="organamnist")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--p", type=int, default=2)
    ap.add_argument("--q", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--samples", type=int, default=256)
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--devices", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dp-clip", type=float, default=1.0)
    ap.add_argument("--sigmas", type=float, nargs="+",
                    default=[4.0, 2.0, 1.0, 0.5])
    ap.add_argument("--delta", type=float, default=1e-5)
    ap.add_argument("--bench-rows", type=int, default=1024)
    ap.add_argument("--bench-cols", type=int, default=1024)
    ap.add_argument("--repeats", type=int, default=9,
                    help="timed trials per configuration (median is kept)")
    ap.add_argument("--max-overhead", type=float, default=0.10,
                    help="accepted DP slowdown of the fused kernel pass")
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__),
                                                  "..", "BENCH_privacy.json"))
    args = ap.parse_args(argv)

    exp = setup_experiment(dataset=args.dataset, n=args.samples,
                           groups=args.groups, devices=args.devices,
                           alpha=0.25, q=args.q, p=args.p, lr=args.lr,
                           seed=args.seed)
    model, fed = exp["model"], exp["fed"]
    runner, eff_fed = make_runner("c-hsgd", model, fed, exp["train"])
    data = exp["data"]
    w = make_group_weights(data)
    lam = eff_fed.lam
    releases = args.rounds * lam  # one Gaussian release per exchange

    print(f"# loss vs ε at fixed bytes (C-HSGD k=0.25 b=128), {args.dataset}, "
          f"{args.rounds} rounds x P={args.p}, δ={args.delta}")
    runs = {}

    def private_run(name, dp_sigma, secure):
        state = init_state(jax.random.PRNGKey(args.seed), model, eff_fed, data)
        t0 = time.perf_counter()
        state, losses = runner.run_private(
            state, data, w, rounds=args.rounds, seed=args.seed,
            dp_clip=args.dp_clip if dp_sigma > 0 else 0.0,
            dp_sigma=dp_sigma, secure_agg=secure)
        losses = np.asarray(jax.block_until_ready(losses))
        eps = (epsilon_of(releases * gaussian_rho(dp_sigma), args.delta)
               if dp_sigma > 0 else None)
        runs[name] = {
            "dp_sigma": dp_sigma, "secure_agg": secure, "epsilon": eps,
            "loss_first": float(losses[0]), "loss_last": float(losses[-1]),
            "steps": int(len(losses)),
            "executors_compiled": len(runner._round_cache),
            "wall_s": round(time.perf_counter() - t0, 3),
        }
        return losses

    state0 = init_state(jax.random.PRNGKey(args.seed), model, eff_fed, data)
    state0, base_losses = runner.run(state0, data, w, rounds=args.rounds)
    base_losses = np.asarray(jax.block_until_ready(base_losses))
    runs["baseline"] = {"dp_sigma": 0.0, "secure_agg": False, "epsilon": None,
                       "loss_first": float(base_losses[0]),
                       "loss_last": float(base_losses[-1]),
                       "steps": int(len(base_losses)),
                       "executors_compiled": len(runner._round_cache),
                       "wall_s": None}
    curves = {"baseline": [float(v) for v in base_losses]}
    sec_losses = private_run("secure", 0.0, True)
    curves["secure"] = [float(v) for v in sec_losses]
    for sigma in args.sigmas:
        losses = private_run(f"dp_sigma_{sigma:g}", sigma, True)
        curves[f"dp_sigma_{sigma:g}"] = [float(v) for v in losses]

    ko = kernel_overhead(args)
    mp = masking_parity(model, eff_fed, data, args.seed)

    # every executed configuration shares ONE (P, Q, k, b) bucket; the private
    # runs add exactly one more executor (the dp/secure variant of the bucket)
    buckets = 2  # plain c-hsgd round + the private round
    executors = len(runner._round_cache)

    csv_row("run", "sigma", "epsilon", "loss_last", "executors")
    for name, r in runs.items():
        csv_row(name, r["dp_sigma"],
                None if r["epsilon"] is None else round(r["epsilon"], 3),
                round(r["loss_last"], 4), r["executors_compiled"])
    print(f"# DP kernel overhead: {100 * ko['overhead_frac']:.1f}% "
          f"({ko['seconds_plain'] * 1e3:.2f} -> {ko['seconds_dp'] * 1e3:.2f} ms)")

    n_ref = 1 << 20
    summary = {
        "fixed_bytes_per_message": compressed_bytes(n_ref, 0.25, 128) / n_ref,
        "dp_overhead_frac": ko["overhead_frac"],
        "dp_overhead_ok": ko["overhead_frac"] < args.max_overhead,
        "sigma0_bit_identical": ko["sigma0_bit_identical"],
        "masked_sum_bit_identical": mp["masked_sum_bit_identical"],
        "masked_vs_float_within_tol": mp["masked_vs_float_within_tol"],
        "executors_compiled": executors,
        "executors_match_buckets": executors == buckets,
        "releases_per_run": releases,
        "delta": args.delta,
    }
    result = {
        "config": {"dataset": args.dataset, "rounds": args.rounds,
                   "p": args.p, "q": args.q, "lr": args.lr,
                   "samples": args.samples, "groups": args.groups,
                   "devices": args.devices, "seed": args.seed,
                   "dp_clip": args.dp_clip, "sigmas": list(args.sigmas),
                   "delta": args.delta, "bench_rows": args.bench_rows,
                   "bench_cols": args.bench_cols, "repeats": args.repeats,
                   "max_overhead": args.max_overhead},
        "summary": summary,
        "kernel": ko,
        "masking": mp,
        "runs": runs,
        "curves": curves,
    }
    atomic_write_json(args.out, result)
    print(f"# wrote {os.path.abspath(args.out)}")
    return result


if __name__ == "__main__":
    main()
