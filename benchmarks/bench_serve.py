"""Serving benchmark: compiled engine vs the reconstructed pre-PR path.

For each smoke family (gemma3-1b dense, falcon-mamba SSM, whisper audio)
measures, after one warmup pass each (compile excluded from both sides):

  * ``sequential`` — the pre-PR serving loop: token-by-token prefill through
    jitted ``decode_step`` (S dispatches) + one un-donated dispatch and a
    host-side sample per decode token.
  * ``engine``     — batched single-pass prefill (one ``dynamic_update_slice``
    per layer), the generate loop staged as a donating jitted ``lax.scan``
    per (batch, cache-bucket, block) with on-device sampling, continuous
    batching on top.

Reported per variant: prefill seconds, decode tokens/s, ms per decode step;
plus engine compile counts (one executor per bucket) and the speedups the
acceptance criteria pin (gemma3-1b: >= 10x prefill, >= 3x decode).
Results land in BENCH_serve.json (schema in benchmarks/README.md).

  PYTHONPATH=src python benchmarks/bench_serve.py [--gen 64] [--batch 4]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.common.config import get_config
from repro.launch.engine import (ServeEngine, sequential_decode,
                                 sequential_generate, sequential_prefill,
                                 sequential_step_fn)
from repro.launch.serve import build_inputs

ARCHS = ("gemma3-1b", "falcon-mamba-7b", "whisper-medium")


def _best(fn, reps):
    """Best-of-N wall time (the CI runner is a noisy 2-core box)."""
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_sequential(cfg, params, prompts, extra, gen, cache_dtype, reps):
    B, S = prompts.shape
    prompts_j = jnp.asarray(prompts)
    # ONE shared step executor + a full-size warmup run: the timed phases
    # below re-dispatch the already-compiled step (steady state, matching
    # the engine side — compiles excluded from BOTH variants)
    step = sequential_step_fn(cfg)
    sequential_generate(cfg, params, prompts_j, gen, extra_embeds=extra,
                        cache_dtype=cache_dtype, step=step)

    def prefill():
        out = sequential_prefill(cfg, params, prompts_j, S + gen, extra,
                                 cache_dtype, step=step)
        jax.block_until_ready(out[0])
        return out

    prefill_s, (logits, caches) = _best(prefill, reps)
    decode_s, toks = _best(
        lambda: sequential_decode(cfg, params, logits, caches, S, gen, step=step),
        reps)
    return {
        "prefill_s": round(prefill_s, 4),
        "decode_tok_per_s": round(B * gen / decode_s, 1),
        "ms_per_decode_step": round(1000 * decode_s / gen, 3),
    }, np.asarray(toks)


def bench_engine(cfg, params, prompts, extra, gen, cache_dtype, decode_block, reps):
    B = prompts.shape[0]
    engine = ServeEngine(cfg, params, max_batch=B, cache_dtype=cache_dtype,
                         decode_block=decode_block, temperature=0.0)
    engine.generate(list(prompts), gen, extra_embeds=extra)  # warmup/compile
    best, best_rep, toks, prefill_s = float("inf"), None, None, 0.0
    for _ in range(reps):
        t0 = time.perf_counter()
        toks, rep = engine.generate(list(prompts), gen, extra_embeds=extra)
        wall = time.perf_counter() - t0
        if wall < best:  # every reported metric comes from the SAME best rep
            best, best_rep = wall, rep
            prefill_s = max(r["prefill_s"] for r in rep["requests"])
    decode_s = max(best - prefill_s, 1e-9)
    return {
        "prefill_s": round(prefill_s, 4),
        "decode_tok_per_s": round(B * gen / decode_s, 1),
        "ms_per_decode_step": round(1000 * decode_s / gen, 3),
        "tokens_per_s_e2e": best_rep["tokens_per_s"],
        "compiled_executors": best_rep["compiled_executors"],
    }, toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--decode-block", type=int, default=32)
    ap.add_argument("--reps", type=int, default=5, help="best-of-N timing")
    ap.add_argument("--cache-dtype", choices=("bf16", "f32"), default="f32",
                    help="f32 keeps the parity check exact on CPU")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    cache_dtype = jnp.float32 if args.cache_dtype == "f32" else jnp.bfloat16

    results = {"config": {"batch": args.batch, "prompt_len": args.prompt_len,
                          "gen": args.gen, "decode_block": args.decode_block,
                          "cache_dtype": args.cache_dtype,
                          "backend": jax.default_backend()}}
    print(f"# serving: engine vs pre-PR sequential loop ({jax.default_backend()})")
    csv_row("arch", "variant", "prefill_s", "decode_tok_per_s", "ms_per_step")
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        params, prompts, extra = build_inputs(cfg, args.batch, args.prompt_len)
        seq, seq_toks = bench_sequential(cfg, params, prompts, extra, args.gen,
                                         cache_dtype, args.reps)
        eng, eng_toks = bench_engine(cfg, params, prompts, extra, args.gen,
                                     cache_dtype, args.decode_block, args.reps)
        parity = eng_toks == seq_toks.tolist()
        entry = {
            "sequential": seq,
            "engine": eng,
            "speedup_prefill": round(seq["prefill_s"] / max(eng["prefill_s"], 1e-9), 2),
            "speedup_decode": round(
                eng["decode_tok_per_s"] / max(seq["decode_tok_per_s"], 1e-9), 2),
            "greedy_tokens_match": bool(parity),
        }
        results[arch] = entry
        csv_row(arch, "sequential", seq["prefill_s"], seq["decode_tok_per_s"],
                seq["ms_per_decode_step"])
        csv_row(arch, "engine", eng["prefill_s"], eng["decode_tok_per_s"],
                eng["ms_per_decode_step"])
        print(f"# {arch}: prefill {entry['speedup_prefill']:.1f}x, "
              f"decode {entry['speedup_decode']:.1f}x, "
              f"greedy parity: {parity}")

    g = results["gemma3-1b"]
    results["acceptance"] = {
        "prefill_speedup_ge_10x": g["speedup_prefill"] >= 10.0,
        "decode_speedup_ge_3x": g["speedup_decode"] >= 3.0,
        "greedy_tokens_match_all": all(results[a]["greedy_tokens_match"] for a in ARCHS),
    }
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {os.path.abspath(args.out)}")
    return results


if __name__ == "__main__":
    main()
