"""Serving benchmark: compiled engine vs the reconstructed pre-PR path.

For each smoke family (gemma3-1b dense, falcon-mamba SSM, whisper audio)
measures, after one warmup pass each (compile excluded from both sides):

  * ``sequential`` — the pre-PR serving loop: token-by-token prefill through
    jitted ``decode_step`` (S dispatches) + one un-donated dispatch and a
    host-side sample per decode token.
  * ``engine``     — batched single-pass prefill (one ``dynamic_update_slice``
    per layer), the generate loop staged as a donating jitted ``lax.scan``
    per (batch, cache-bucket, block) with on-device sampling, continuous
    batching on top.

Reported per variant: prefill seconds, decode tokens/s, ms per decode step;
plus engine compile counts (one executor per bucket) and the speedups the
acceptance criteria pin (gemma3-1b: >= 10x prefill, >= 3x decode).
Results land in BENCH_serve.json (schema in benchmarks/README.md).

  PYTHONPATH=src python benchmarks/bench_serve.py [--gen 64] [--batch 4]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.common.config import get_config
from repro.common.io import atomic_write_json
from repro.launch.engine import (ServeEngine, sequential_decode,
                                 sequential_generate, sequential_prefill,
                                 sequential_step_fn)
from repro.launch.loadgen import poisson_trace, run_load
from repro.launch.serve import build_inputs

ARCHS = ("gemma3-1b", "falcon-mamba-7b", "whisper-medium")


def _best(fn, reps):
    """Best-of-N wall time (the CI runner is a noisy 2-core box)."""
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)  # async dispatch: time execution, not enqueue
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_sequential(cfg, params, prompts, extra, gen, cache_dtype, reps):
    B, S = prompts.shape
    prompts_j = jnp.asarray(prompts)
    # ONE shared step executor + a full-size warmup run: the timed phases
    # below re-dispatch the already-compiled step (steady state, matching
    # the engine side — compiles excluded from BOTH variants)
    step = sequential_step_fn(cfg)
    sequential_generate(cfg, params, prompts_j, gen, extra_embeds=extra,
                        cache_dtype=cache_dtype, step=step)

    def prefill():
        out = sequential_prefill(cfg, params, prompts_j, S + gen, extra,
                                 cache_dtype, step=step)
        jax.block_until_ready(out[0])
        return out

    prefill_s, (logits, caches) = _best(prefill, reps)
    decode_s, toks = _best(
        lambda: sequential_decode(cfg, params, logits, caches, S, gen, step=step),
        reps)
    return {
        "prefill_s": round(prefill_s, 4),
        "decode_tok_per_s": round(B * gen / decode_s, 1),
        "ms_per_decode_step": round(1000 * decode_s / gen, 3),
    }, np.asarray(toks)


def bench_engine(cfg, params, prompts, extra, gen, cache_dtype, decode_block, reps):
    B = prompts.shape[0]
    engine = ServeEngine(cfg, params, max_batch=B, cache_dtype=cache_dtype,
                         decode_block=decode_block, temperature=0.0)
    engine.generate(list(prompts), gen, extra_embeds=extra)  # warmup/compile
    best, best_rep, toks, prefill_s = float("inf"), None, None, 0.0
    for _ in range(reps):
        t0 = time.perf_counter()
        toks, rep = engine.generate(list(prompts), gen, extra_embeds=extra)
        wall = time.perf_counter() - t0  # reprolint: disable=RP6 — generate() returns host tokens, synced internally
        if wall < best:  # every reported metric comes from the SAME best rep
            best, best_rep = wall, rep
            prefill_s = max(r["prefill_s"] for r in rep["requests"])
    decode_s = max(best - prefill_s, 1e-9)
    return {
        "prefill_s": round(prefill_s, 4),
        "decode_tok_per_s": round(B * gen / decode_s, 1),
        "ms_per_decode_step": round(1000 * decode_s / gen, 3),
        "tokens_per_s_e2e": best_rep["tokens_per_s"],
        "compiled_executors": best_rep["compiled_executors"],
    }, toks


def int8_logit_drift(cfg, params, prompts, extra):
    """Max |logit(int8 cache) - logit(f32 cache)| over a prefill + one decode
    step — the documented tolerance behind the int8 greedy-parity claim."""
    import repro.models.transformer as T

    B, S = prompts.shape
    drifts = []
    for dt in (jnp.float32, jnp.int8):
        caches = T.init_decode_caches(cfg, B, _pow2(S + 2), dt)
        if cfg.family == "audio":
            caches = T.seed_audio_caches(cfg, params, caches, jnp.asarray(extra))
        logits, caches = T.decode_step(cfg, params, jnp.asarray(prompts), caches,
                                       jnp.int32(0), fresh_cache=True)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        logits2, _ = T.decode_step(cfg, params, nxt, caches,
                                   jnp.full((B,), S, jnp.int32))
        drifts.append(np.asarray(logits2[:, -1], np.float32))
    return float(np.max(np.abs(drifts[0] - drifts[1])))


def _pow2(n):
    from repro.common.buckets import pow2_ceil
    return pow2_ceil(n)


def bench_load(args):
    """Trace-driven comparison: the PR-4 engine (f32 caches, no speculative
    decoding, no prefix cache) vs the optimized stack (int8 + spec + prefix)
    replaying the SAME Poisson trace. Each variant keeps ONE long-lived
    engine — a warmup replay pays the executor compiles and seeds the prefix
    store, then every measured rep runs against the warm server, which is the
    steady-state a real deployment sits in (a fresh engine per rep would time
    XLA compilation, not serving). Reports best-of-N sustained tokens/s with
    min/max spread (the CI runner is a noisy 2-core box)."""
    cfg = get_config(args.arch, smoke=True)
    params, prompts, extra = build_inputs(cfg, args.batch, args.prompt_len)
    trace = poisson_trace(args.requests, args.rate, args.prompt_len, args.gen,
                          cfg.vocab_size, args.seed,
                          shared_prefix_frac=args.shared_prefix_frac)

    def engine_pr4():
        return ServeEngine(cfg, params, max_batch=args.max_batch,
                           cache_dtype=jnp.float32,
                           decode_block=args.load_decode_block, temperature=0.0)

    def engine_opt():
        return ServeEngine(cfg, params, max_batch=args.max_batch,
                           cache_dtype=jnp.int8,
                           decode_block=args.load_decode_block, temperature=0.0,
                           spec_gamma=args.spec_gamma, prefix_cache=True)

    def engine_int8_ref():
        # untimed parity reference: same int8 caches as `optimized` but no
        # speculation / prefix cache — optimized must match it EXACTLY
        # (those two features are lossless); pr4 (f32) may differ from it
        # within the documented int8 logit drift
        return ServeEngine(cfg, params, max_batch=args.max_batch,
                           cache_dtype=jnp.int8,
                           decode_block=args.load_decode_block, temperature=0.0)

    eng = engine_int8_ref()
    run_load(eng, trace, args.slo_first_token_s)
    ref_toks = [r.tokens for r in sorted(eng.done, key=lambda r: r.rid)]

    variants = {}
    tokens = {}
    for name, mk in (("pr4_engine", engine_pr4), ("optimized", engine_opt)):
        eng = mk()
        run_load(eng, trace, args.slo_first_token_s)  # warmup: compiles + store
        reps, toks = [], None
        for _ in range(args.reps):
            done_before = len(eng.done)
            rep = run_load(eng, trace, args.slo_first_token_s)
            reps.append(rep)
            by_id = sorted(eng.done[done_before:], key=lambda r: r.rid)
            toks = [r.tokens for r in by_id]
        rates = [r["sustained_tokens_per_s"] for r in reps]
        best = reps[int(np.argmax(rates))]
        best["spread"] = {
            "reps": args.reps,
            "sustained_tokens_per_s_min": min(rates),
            "sustained_tokens_per_s_max": max(rates),
        }
        variants[name] = best
        tokens[name] = toks
        print(f"# load/{name}: sustained {best['sustained_tokens_per_s']} tok/s "
              f"(spread {min(rates)}..{max(rates)}), "
              f"p99 first-token {best['first_token_s']['p99']}s, "
              f"SLO {best['slo_attainment']}")

    drift = int8_logit_drift(cfg, params, prompts, extra)
    pr4, opt = variants["pr4_engine"], variants["optimized"]
    return {
        "trace": {"arch": args.arch, "requests": args.requests,
                  "rate_req_per_s": args.rate, "prompt_len": args.prompt_len,
                  "gen": args.gen, "seed": args.seed,
                  "shared_prefix_frac": args.shared_prefix_frac,
                  "slo_first_token_s": args.slo_first_token_s,
                  "max_batch": args.max_batch,
                  "decode_block": args.load_decode_block,
                  "spec_gamma": args.spec_gamma},
        "pr4_engine": pr4,
        "optimized": opt,
        "int8_max_logit_drift": round(drift, 6),
        # speculation + prefix caching are lossless: optimized must equal the
        # plain int8 engine token-for-token. int8 vs f32 may differ when the
        # logit drift crosses an argmax margin — reported, not required.
        "lossless_tokens_match": tokens["optimized"] == ref_toks,
        "int8_tokens_match_f32": tokens["pr4_engine"] == ref_toks,
        "speedup_sustained": round(
            opt["sustained_tokens_per_s"] / max(pr4["sustained_tokens_per_s"], 1e-9), 2),
        "p99_first_token_ratio": round(
            opt["first_token_s"]["p99"] / max(pr4["first_token_s"]["p99"], 1e-9), 2),
    }


def _load_acceptance(results):
    """Refresh the load acceptance bits from results["load"] (used by both
    the full run and --load-only so a merged file never keeps stale bits)."""
    acc = results.setdefault("acceptance", {})
    load = results["load"]
    acc["load_sustained_speedup_gt_1"] = load["speedup_sustained"] > 1.0
    acc["load_p99_first_token_le_1x"] = load["p99_first_token_ratio"] <= 1.0
    acc["load_lossless_tokens_match"] = load["lossless_tokens_match"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--decode-block", type=int, default=32)
    ap.add_argument("--reps", type=int, default=5, help="best-of-N timing")
    ap.add_argument("--cache-dtype", choices=("bf16", "f32"), default="f32",
                    help="f32 keeps the parity check exact on CPU")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--load", action="store_true",
                    help="also run the trace-driven load comparison")
    ap.add_argument("--load-only", action="store_true",
                    help="skip the steady-state sweep; merge the load section "
                         "into an existing --out file")
    ap.add_argument("--arch", default="gemma3-1b", help="load-mode arch")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="req/s; default saturates the engine so sustained "
                         "tokens/s measures capacity, not the arrival rate")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shared-prefix-frac", type=float, default=0.75)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--spec-gamma", type=int, default=1,
                    help="draft length; 1 is best for shallow smoke models "
                         "(draft = half the layers), raise for deep models")
    ap.add_argument("--load-decode-block", type=int, default=16,
                    help="decode block for the load comparison (shorter than "
                         "the steady-state sweep so admissions stay frequent)")
    ap.add_argument("--slo-first-token-s", type=float, default=1.0)
    args = ap.parse_args(argv)
    cache_dtype = jnp.float32 if args.cache_dtype == "f32" else jnp.bfloat16

    if args.load_only:
        results = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                results = json.load(f)
        results["load"] = bench_load(args)
        _load_acceptance(results)
        atomic_write_json(args.out, results, indent=2)
        print(f"# wrote {os.path.abspath(args.out)} (load section only)")
        return results

    results = {"config": {"batch": args.batch, "prompt_len": args.prompt_len,
                          "gen": args.gen, "decode_block": args.decode_block,
                          "cache_dtype": args.cache_dtype,
                          "backend": jax.default_backend()}}
    print(f"# serving: engine vs pre-PR sequential loop ({jax.default_backend()})")
    csv_row("arch", "variant", "prefill_s", "decode_tok_per_s", "ms_per_step")
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        params, prompts, extra = build_inputs(cfg, args.batch, args.prompt_len)
        seq, seq_toks = bench_sequential(cfg, params, prompts, extra, args.gen,
                                         cache_dtype, args.reps)
        eng, eng_toks = bench_engine(cfg, params, prompts, extra, args.gen,
                                     cache_dtype, args.decode_block, args.reps)
        parity = eng_toks == seq_toks.tolist()
        entry = {
            "sequential": seq,
            "engine": eng,
            "speedup_prefill": round(seq["prefill_s"] / max(eng["prefill_s"], 1e-9), 2),
            "speedup_decode": round(
                eng["decode_tok_per_s"] / max(seq["decode_tok_per_s"], 1e-9), 2),
            "greedy_tokens_match": bool(parity),
        }
        results[arch] = entry
        csv_row(arch, "sequential", seq["prefill_s"], seq["decode_tok_per_s"],
                seq["ms_per_decode_step"])
        csv_row(arch, "engine", eng["prefill_s"], eng["decode_tok_per_s"],
                eng["ms_per_decode_step"])
        print(f"# {arch}: prefill {entry['speedup_prefill']:.1f}x, "
              f"decode {entry['speedup_decode']:.1f}x, "
              f"greedy parity: {parity}")

    g = results["gemma3-1b"]
    results["acceptance"] = {
        "prefill_speedup_ge_10x": g["speedup_prefill"] >= 10.0,
        "decode_speedup_ge_3x": g["speedup_decode"] >= 3.0,
        "greedy_tokens_match_all": all(results[a]["greedy_tokens_match"] for a in ARCHS),
    }
    if args.load:
        results["load"] = bench_load(args)
        _load_acceptance(results)
    atomic_write_json(args.out, results, indent=2)
    print(f"# wrote {os.path.abspath(args.out)}")
    return results


if __name__ == "__main__":
    main()
