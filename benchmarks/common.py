"""Shared benchmark harness utilities."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FederationConfig, TrainConfig
from repro.common.pytree import tree_bytes
from repro.core import comm_model as CM
from repro.core.baselines import make_runner, merge_groups_for_tdcd
from repro.core.hsgd import global_model, init_state, make_group_weights
from repro.core.metrics import evaluate_global
from repro.data.partition import hybrid_partition
from repro.data.synthetic import DATASETS, flatten_for_tower, make_dataset, vertical_split
from repro.models.split_model import cnn_hybrid, lstm_hybrid


def setup_experiment(dataset="organamnist", n=1024, groups=4, devices=32, alpha=0.25,
                     q=1, p=1, lr=0.02, seed=0, compression_k=0.0, quant=0,
                     robust_agg="mean"):
    spec = DATASETS[dataset]
    fed = FederationConfig(num_groups=groups, devices_per_group=devices, alpha=alpha,
                           local_interval=q, global_interval=p,
                           robust_agg=robust_agg)
    train = TrainConfig(learning_rate=lr, compression_k=compression_k,
                        quantization_bits=quant)
    X, y = make_dataset(spec, n, seed=seed)
    fdata = hybrid_partition(spec, X, y, fed, seed=seed)
    data = {k: jnp.asarray(v) for k, v in fdata.stacked().items()}
    if dataset == "organamnist":
        model = cnn_hybrid(h_rows=11, n_classes=spec.n_classes)
    elif dataset == "esr":
        model = lstm_hybrid(n_features=178, hospital_features=89, n_classes=spec.n_classes)
    else:
        model = lstm_hybrid(n_features=76, hospital_features=36, n_classes=spec.n_classes)
    return dict(spec=spec, fed=fed, train=train, model=model, data=data, X=X, y=y)


def run_algorithm(exp, algo, rounds, seed=0):
    """Run one algorithm; returns dict with losses, metrics, sizes, fed."""
    model, fed, train = exp["model"], exp["fed"], exp["train"]
    runner, eff_fed = make_runner(algo, model, fed, train)
    data = exp["data"]
    if algo in ("tdcd", "c-tdcd", "centralized"):
        raw = merge_groups_for_tdcd({k: np.asarray(v) for k, v in data.items()})
        data = {k: jnp.asarray(v) for k, v in raw.items()}
    w = make_group_weights(data)
    key = jax.random.PRNGKey(seed)
    state = runner.init(key) if algo == "jfl" else init_state(key, model, eff_fed, data)
    t0 = time.time()
    state, losses = runner.run(state, data, w, rounds=rounds)
    losses = np.asarray(jax.device_get(losses))
    wall = time.time() - t0
    gm = runner.global_model(state, w) if algo == "jfl" else global_model(state, w)
    return dict(losses=losses, wall=wall, global_model=gm, fed=eff_fed, data=data)


def eval_model(exp, gm):
    spec = exp["spec"]
    X1, X2 = vertical_split(spec, exp["X"])
    return evaluate_global(exp["model"], gm,
                           flatten_for_tower(spec, X1), flatten_for_tower(spec, X2),
                           exp["y"])


def sizes_for(exp, algo):
    """Per-event message sizes for the comm model."""
    model, fed, train, spec = exp["model"], exp["fed"], exp["train"], exp["spec"]
    params = model.init(jax.random.PRNGKey(0))
    embed_dim = 64
    batch = fed.sampled_devices
    z_el = batch * embed_dim
    comp_k = train.compression_k if algo in ("c-hsgd", "c-tdcd") else 0.0
    quant = train.quantization_bits if algo in ("c-hsgd", "c-tdcd") else 0
    if algo in ("c-hsgd", "c-tdcd") and not comp_k:
        comp_k, quant = 0.25, 128
    raw_upfront = 0.0
    if algo in ("tdcd", "c-tdcd"):
        raw_upfront = spec.raw_size_mb * 1e6
    return CM.message_sizes(params, z_el, z_el, fed.sampled_devices,
                            comp_k, quant, raw_upfront)


def comm_bytes_at_step(exp, algo, sizes, step):
    fed = exp["fed"]
    if algo == "jfl":
        # VFL exchange EVERY step per pair + model sync every P
        per_iter = (sizes.theta0 + sizes.z1 + sizes.z2) * sizes.n_active \
            + (sizes.theta0 + sizes.theta1 + sizes.theta2) * sizes.n_active / fed.global_interval
        return per_iter * step
    if algo in ("tdcd", "c-tdcd"):
        # no global phase: P -> "infinity" (a huge multiple of Q, so the
        # validated FederationConfig still has an integral Λ)
        eff = FederationConfig(local_interval=fed.local_interval,
                               global_interval=fed.local_interval * 10**8)
        return CM.comm_cost_per_iteration(sizes, eff) * step + sizes.raw_upfront
    return CM.total_comm_cost(sizes, fed, step)


def csv_row(*cols):
    print(",".join(str(c) for c in cols))
