"""§Roofline: build the per-(arch x shape) roofline table from the dry-run
artifacts (artifacts/dryrun/*.json) and emit the EXPERIMENTS.md section.

Terms (per chip, TPU v5e): compute = FLOPs/197e12, memory = bytes/819e9,
collective = collective_bytes/50e9. Training combines the three programs with
the paper's amortization: step + exchange/Q + global_agg/P (default P=8, Q=4).
MODEL_FLOPS = 6*N*D (train) or 2*N_active*D (decode/prefill fwd-only).
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.common.config import INPUT_SHAPES

PEAK = {"compute": 197e12, "memory": 819e9, "collective": 50e9}
P_DEFAULT, Q_DEFAULT = 8, 4


def model_flops_per_device(rec, shape_name, n_chips):
    shape = INPUT_SHAPES[shape_name]
    n_active = rec["active_params"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6 * n_active * tokens / n_chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2 * n_active * tokens / n_chips
    tokens = shape.global_batch  # one new token per request
    return 2 * n_active * tokens / n_chips


def combined_terms(rec, P=P_DEFAULT, Q=Q_DEFAULT):
    progs = rec["programs"]
    out = {}
    if "train_step" in progs:
        for key in ("compute_s", "memory_s", "collective_s", "traced_flops_per_device",
                    "flops_per_device", "bytes_per_device", "collective_bytes_per_device"):
            out[key] = (progs["train_step"][key] + progs["exchange"][key] / Q
                        + progs["global_agg"][key] / P)
    else:
        p = progs["serve_step"]
        out = {k: p[k] for k in ("compute_s", "memory_s", "collective_s",
                                 "traced_flops_per_device",
                                 "flops_per_device", "bytes_per_device",
                                 "collective_bytes_per_device")}
    return out


def load(art_dir="artifacts/dryrun", mesh_tag="pod"):
    rows = []
    for f in sorted(glob.glob(os.path.join(art_dir, f"*__{mesh_tag}.json"))):
        rec = json.load(open(f))
        rows.append(rec)
    return rows


def fmt_table(rows, P=P_DEFAULT, Q=Q_DEFAULT):
    lines = [
        f"| arch | shape | compute_s | memory_s | collective_s | dominant | model/HLO flops | note |",
        f"|---|---|---|---|---|---|---|---|",
    ]
    for rec in rows:
        arch, shape = rec["arch"], rec["shape"]
        if rec.get("status") == "skipped":
            lines.append(f"| {arch} | {shape} | - | - | - | - | - | SKIP: {rec['reason']} |")
            continue
        if rec.get("status") != "ok":
            lines.append(f"| {arch} | {shape} | - | - | - | - | - | ERROR |")
            continue
        t = combined_terms(rec, P, Q)
        terms = {"compute": t["compute_s"], "memory": t["memory_s"],
                 "collective": t["collective_s"]}
        dom = max(terms, key=terms.get)
        mf = model_flops_per_device(rec, shape, rec["n_chips"])
        ratio = mf / max(t["traced_flops_per_device"], 1)
        lines.append(
            f"| {arch} | {shape} | {terms['compute']:.2e} | {terms['memory']:.2e} "
            f"| {terms['collective']:.2e} | **{dom}** | {ratio:.2f} | |"
        )
    return "\n".join(lines)


def main(art: str = "artifacts/dryrun"):
    rows = load(art, "pod")
    print("## Roofline (single-pod 16x16, P=8 Q=4)\n")
    print(fmt_table(rows))
    # bottleneck recommendations
    print("\n### Dominant-term movers\n")
    for rec in rows:
        if rec.get("status") != "ok":
            continue
        t = combined_terms(rec)
        terms = {"compute": t["compute_s"], "memory": t["memory_s"], "collective": t["collective_s"]}
        dom = max(terms, key=terms.get)
        hint = {
            "compute": "raise per-chip arithmetic intensity (larger microbatch, fused ops); compute-bound is the roofline goal",
            "memory": "cut HBM traffic: bf16 remat saves, fuse norms/rope into matmuls, blockwise attention tiles",
            "collective": "amortize further with larger P/Q (paper strategy 1-2) or compress exchanged ζ (C-HSGD top-k kernel)",
        }[dom]
        print(f"- {rec['arch']} × {rec['shape']}: {dom}-bound -> {hint}")


if __name__ == "__main__":
    main()
