"""Benchmark harness entry point: one module per paper table/figure.

  python -m benchmarks.run            # quick suite (all benches, small sizes)
  python -m benchmarks.run --only bench_kernels

Each bench prints ``name,us_per_call,derived`` style CSV blocks.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="bench module name (bench_convergence, bench_comm_cost, "
                         "bench_compute_cost, bench_adaptive, bench_kernels, roofline)")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_adaptive,
        bench_comm_cost,
        bench_compute_cost,
        bench_convergence,
        bench_kernels,
        roofline,
    )

    benches = {
        "bench_kernels": bench_kernels.main,
        "bench_convergence": bench_convergence.main,
        "bench_comm_cost": bench_comm_cost.main,
        "bench_compute_cost": bench_compute_cost.main,
        "bench_adaptive": lambda: bench_adaptive.main([]),  # own argparse: don't leak run.py's argv
        "roofline": roofline.main,
    }
    todo = [args.only] if args.only else list(benches)
    for name in todo:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            benches[name]()
        except FileNotFoundError as e:  # roofline artifacts may be absent
            print(f"skipped ({e})")
        print(f"===== {name} done in {time.time()-t0:.1f}s =====")


if __name__ == "__main__":
    main()
