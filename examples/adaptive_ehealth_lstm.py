"""Adaptive-strategy walkthrough on the MIMIC-III-like LSTM task:

1. probe ρ, δ, F(θ⁰) with a short pre-training pass (paper §VI-B),
2. apply strategies 1-3 to pick P = Q and η,
3. train with the recommended settings vs a naive (P=Q=1) run and compare
   the communication bill for the same final quality.

  PYTHONPATH=src python examples/adaptive_ehealth_lstm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.common.config import FederationConfig, TrainConfig
from repro.core.adaptive import estimate_rho_delta, recommend_settings
from repro.core.comm_model import message_sizes, total_comm_cost
from repro.core.hsgd import HSGDRunner, global_model, init_state, make_group_weights
from repro.core.metrics import evaluate_global
from repro.data.partition import hybrid_partition
from repro.data.synthetic import MIMIC3, make_dataset, vertical_split
from repro.models.split_model import lstm_hybrid

TOTAL_STEPS = 64


def run(fed, lr, data, model, weights):
    runner = HSGDRunner(model, fed, TrainConfig(learning_rate=lr))
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    rounds = max(1, TOTAL_STEPS // fed.global_interval)
    state, losses = runner.run(state, data, weights, rounds=rounds)
    return global_model(state, weights), losses


def main():
    fed0 = FederationConfig(num_groups=4, devices_per_group=32, alpha=0.25,
                            local_interval=1, global_interval=1)
    X, y = make_dataset(MIMIC3, 512, seed=0)
    fdata = hybrid_partition(MIMIC3, X, y, fed0, seed=0)
    data = {k: jnp.asarray(v) for k, v in fdata.stacked().items()}
    model = lstm_hybrid(n_features=76, hospital_features=36, n_classes=MIMIC3.n_classes)
    weights = make_group_weights(data)

    # 1) probe
    params0 = model.init(jax.random.PRNGKey(0))
    probe = estimate_rho_delta(model, params0, data, jax.random.PRNGKey(1))
    print(f"probe: rho={probe['rho']:.3f} delta={probe['delta']:.3f} F0={probe['F0']:.3f}")

    # 2) strategies 1-3
    rec = recommend_settings(probe, TOTAL_STEPS, eta=0.01, fed=fed0)
    print(f"recommended: P=Q={rec['P']}  eta={rec['eta']:.4g} (cap {rec['eta_max']:.4g})")

    # 3) naive vs adaptive
    sizes = message_sizes(params0, 32 * 64, 32 * 64, fed0.sampled_devices)
    gm_naive, losses_naive = run(fed0, 0.01, data, model, weights)
    fed_star = FederationConfig(num_groups=4, devices_per_group=32, alpha=0.25,
                                local_interval=rec["P"], global_interval=rec["P"])
    gm_star, losses_star = run(fed_star, min(rec["eta"], 0.05), data, model, weights)

    X1, X2 = vertical_split(MIMIC3, X)
    m_naive = evaluate_global(model, gm_naive, X1, X2, y)
    m_star = evaluate_global(model, gm_star, X1, X2, y)
    c_naive = total_comm_cost(sizes, fed0, TOTAL_STEPS) / 1e6
    c_star = total_comm_cost(sizes, fed_star, TOTAL_STEPS) / 1e6
    print(f"naive   P=Q=1 : auc={m_naive['auc_roc']:.3f}  comm={c_naive:.2f} MB/group")
    print(f"adaptive P=Q={rec['P']}: auc={m_star['auc_roc']:.3f}  comm={c_star:.2f} MB/group")
    print(f"communication saved: {100 * (1 - c_star / c_naive):.0f}%")


if __name__ == "__main__":
    main()
