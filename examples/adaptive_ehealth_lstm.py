"""Closed-loop adaptive HSGD on the MIMIC-III-like LSTM task (paper §VI):

1. the controller seeds ρ, δ, F(θ⁰) with a short pre-training probe (§VI-B),
2. every global round it re-estimates ρ/δ/‖∇F‖² from that round's own
   gradients and re-picks P = Q (strategies 1-2) and η (strategy 3),
3. a byte governor walks the compression ladder so the whole run stays under
   a user byte budget (here: 40% of the naive P=Q=1 bill),
4. we compare quality + modeled communication against the naive fixed run.

  PYTHONPATH=src python examples/adaptive_ehealth_lstm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.common.config import FederationConfig, TrainConfig
from repro.core.comm_model import comm_cost_per_iteration, message_sizes
from repro.core.controller import AdaptiveConfig, AdaptiveHSGDRunner
from repro.core.hsgd import HSGDRunner, global_model, init_state, make_group_weights
from repro.core.metrics import evaluate_global
from repro.data.partition import hybrid_partition
from repro.data.synthetic import MIMIC3, make_dataset, vertical_split
from repro.models.split_model import lstm_hybrid

TOTAL_STEPS = 64


def main():
    fed = FederationConfig(num_groups=4, devices_per_group=32, alpha=0.25,
                           local_interval=1, global_interval=1)
    train = TrainConfig(learning_rate=0.01)
    X, y = make_dataset(MIMIC3, 512, seed=0)
    fdata = hybrid_partition(MIMIC3, X, y, fed, seed=0)
    data = {k: jnp.asarray(v) for k, v in fdata.stacked().items()}
    model = lstm_hybrid(n_features=76, hospital_features=36, n_classes=MIMIC3.n_classes)
    weights = make_group_weights(data)
    X1, X2 = vertical_split(MIMIC3, X)

    # naive fixed baseline: P = Q = 1, uncompressed
    runner = HSGDRunner(model, fed, train)
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    state, losses_naive = runner.run(state, data, weights, rounds=TOTAL_STEPS)
    gm_naive = global_model(state, weights)

    params0 = model.init(jax.random.PRNGKey(0))
    sizes = message_sizes(params0, 8 * 64, 8 * 64, fed.sampled_devices)
    naive_bytes = comm_cost_per_iteration(sizes, fed) * fed.num_groups * TOTAL_STEPS

    # closed loop under a 40% byte budget
    cfg = AdaptiveConfig(total_steps=TOTAL_STEPS, byte_budget=0.4 * naive_bytes,
                         max_interval=16, eta_max=0.05)
    controller = AdaptiveHSGDRunner(model, fed, train, cfg)
    state2 = init_state(jax.random.PRNGKey(0), model, fed, data)
    state2, losses_ad, history = controller.run(state2, data, weights,
                                                probe_key=jax.random.PRNGKey(1))
    gm_ad = global_model(state2, weights)

    print("round  P=Q   eta      rung  Γ(P,Q)    bytes(MB)  loss")
    for h in history:
        print(f"{h['round']:5d} {h['P']:4d}  {h['eta']:.5f}  {h['rung']:4d}  "
              f"{h['gamma']:8.3g}  {h['bytes_total'] / 1e6:8.2f}  {h['loss_last']:.4f}")

    m_naive = evaluate_global(model, gm_naive, X1, X2, y)
    m_ad = evaluate_global(model, gm_ad, X1, X2, y)
    ad_bytes = history[-1]["bytes_total"]
    print(f"\nnaive    P=Q=1   : loss={float(losses_naive[-1]):.4f} "
          f"auc={m_naive['auc_roc']:.3f}  comm={naive_bytes / 1e6:.2f} MB")
    print(f"adaptive (closed): loss={float(losses_ad[-1]):.4f} "
          f"auc={m_ad['auc_roc']:.3f}  comm={ad_bytes / 1e6:.2f} MB")
    print(f"communication saved: {100 * (1 - ad_bytes / naive_bytes):.0f}%")


if __name__ == "__main__":
    main()
