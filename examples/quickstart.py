"""Quickstart: train the paper's CNN on a synthetic OrganAMNIST-like e-health
federation with HSGD (Algorithm 1), then evaluate the global model.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.common.config import FederationConfig, TrainConfig
from repro.core.hsgd import HSGDRunner, global_model, init_state, make_group_weights
from repro.core.metrics import evaluate_global
from repro.data.partition import hybrid_partition
from repro.data.synthetic import ORGANAMNIST, flatten_for_tower, make_dataset, vertical_split
from repro.models.split_model import cnn_hybrid


def main():
    # --- the 3-tier e-health federation (paper §III) ---------------------
    fed = FederationConfig(
        num_groups=4,          # M hospital-patient groups
        devices_per_group=64,  # K_m wearable devices (1 sample each)
        alpha=0.25,            # fraction sampled into A_m
        local_interval=2,      # Q: local agg + ζ exchange every 2 steps
        global_interval=4,     # P: cloud aggregation every 4 steps
    )
    train = TrainConfig(learning_rate=0.02)

    # --- data: horizontal (non-iid groups) -> vertical -> horizontal -----
    X, y = make_dataset(ORGANAMNIST, 1024, seed=0)
    fdata = hybrid_partition(ORGANAMNIST, X, y, fed, seed=0)
    data = {k: jnp.asarray(v) for k, v in fdata.stacked().items()}

    # --- model: hospital tower h1, device tower h2, combined f -----------
    model = cnn_hybrid(h_rows=11, n_classes=ORGANAMNIST.n_classes)

    # --- HSGD ------------------------------------------------------------
    runner = HSGDRunner(model, fed, train)
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    weights = make_group_weights(data)
    state, losses = runner.run(state, data, weights, rounds=25)
    print(f"train loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")

    # --- evaluate the global model (eq. 2) --------------------------------
    gm = global_model(state, weights)
    X1, X2 = vertical_split(ORGANAMNIST, X)
    metrics = evaluate_global(model, gm,
                              flatten_for_tower(ORGANAMNIST, X1),
                              flatten_for_tower(ORGANAMNIST, X2), y)
    for k, v in metrics.items():
        print(f"{k:10s} {v:.4f}")
    assert metrics["auc_roc"] > 0.6, "expected the federation to learn"
    print("quickstart OK")


if __name__ == "__main__":
    main()
