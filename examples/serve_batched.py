"""Batched serving example: run a reduced gemma3-style model through prefill +
autoregressive decode with a sliding-window KV cache, for a batch of requests.

  PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main


def main():
    report = serve_main([
        "--arch", "gemma3-1b",
        "--batch", "4",
        "--prompt-len", "24",
        "--gen", "12",
        "--temperature", "0.8",
    ])
    assert report["decode_tok_per_s"] > 0
    print("serve_batched OK")


if __name__ == "__main__":
    main()
