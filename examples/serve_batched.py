"""Serving example: drive the compiled engine with the trace-driven load
generator and print a latency/SLO report.

A reduced gemma3-style model serves a seeded Poisson trace (shared prompt
heads exercise the prefix cache) with the full optimized stack — int8
decode caches, self-speculative scan decode, prefix caching — and the run
reports p50/p99 queue / first-token / total latency, sustained tokens/s,
and SLO attainment. A plain batched `serve` run is kept at the end as the
minimal non-load usage.

  PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.common.config import get_config
from repro.launch.engine import ServeEngine
from repro.launch.loadgen import poisson_trace, run_load
from repro.launch.serve import build_inputs
from repro.launch.serve import main as serve_main


def main():
    cfg = get_config("gemma3-1b", smoke=True)
    params, _, _ = build_inputs(cfg, 1, 24)
    trace = poisson_trace(n=16, rate=50.0, prompt_len=24, max_new=8,
                          vocab_size=cfg.vocab_size, seed=0,
                          shared_prefix_frac=0.75)
    engine = ServeEngine(cfg, params, max_batch=4, cache_dtype=jnp.int8,
                         decode_block=8, temperature=0.0,
                         spec_gamma=1, prefix_cache=True)
    # warmup replays: pay the per-bucket XLA compiles and seed the prefix
    # store, so the printed report shows steady-state serving latency (two
    # passes because admission group sizes — and thus executor buckets —
    # depend on wall-clock arrival timing)
    for _ in range(2):
        run_load(engine, trace, slo_first_token_s=1.0)
    rep = run_load(engine, trace, slo_first_token_s=1.0)

    print(f"requests          {rep['requests']}  "
          f"({rep['generated_tokens']} tokens in {rep['span_s']:.2f}s)")
    print(f"sustained         {rep['sustained_tokens_per_s']} tok/s")
    for name, key in (("queue", "queue_s"), ("first token", "first_token_s"),
                      ("total", "total_s")):
        p = rep[key]
        print(f"{name:<17} p50 {p['p50'] * 1e3:8.1f} ms   "
              f"p99 {p['p99'] * 1e3:8.1f} ms")
    print(f"SLO attainment    {rep['slo_attainment']:.0%} "
          f"(first token <= {rep['slo_first_token_s']}s)")
    eng = rep["engine"]
    print(f"speculative       acceptance {eng['speculative']['acceptance']}")
    print(f"prefix cache      {eng['prefix_cache']['hits']} hits / "
          f"{eng['prefix_cache']['misses']} misses "
          f"({eng['prefix_cache']['seeded_tokens']} tokens seeded)")
    assert rep["requests"] == 16 and rep["sustained_tokens_per_s"] > 0

    # minimal non-load usage: one fixed batch through the same engine path
    report = serve_main([
        "--arch", "gemma3-1b",
        "--batch", "4",
        "--prompt-len", "24",
        "--gen", "12",
        "--temperature", "0.8",
    ])
    assert report["decode_tok_per_s"] > 0
    print("serve_batched OK")


if __name__ == "__main__":
    main()
