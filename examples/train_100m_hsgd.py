"""End-to-end driver: train a ~100M-parameter dense transformer with the
paper's HSGD federation through the COMPILED round runner (hospital tower /
device tower / combined backbone; ζ exchange every Q inside one donating
jitted executor, fresh synthetic stream per exchange).

By default the §VI adaptive controller drives the run — it re-picks P = Q and
η every round from the step's own gradient probes and ratchets the
compression ladder until --byte-budget-mb is honored — and prints the
per-round trace. --fixed reverts to a constant cadence.

  PYTHONPATH=src python examples/train_100m_hsgd.py                 # 300 steps
  PYTHONPATH=src python examples/train_100m_hsgd.py --steps 20      # smoke
  PYTHONPATH=src python examples/train_100m_hsgd.py --fixed --q 4   # baseline
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.checkpoint import save_checkpoint
from repro.common.config import ModelConfig
from repro.common.pytree import tree_size
from repro.core.controller import AdaptiveConfig
from repro.core.metrics import smoothed_losses
from repro.data.synthetic import llm_batch_fn
from repro.launch.steps import (AdaptiveLLMRunner, LLMRoundRunner,
                                global_llm_params, init_llm_params)
from repro.models.split_model import llm_hybrid


def config_100m() -> ModelConfig:
    return ModelConfig(
        name="dense-100m", family="dense", num_layers=12, d_model=640,
        num_heads=10, num_kv_heads=5, d_ff=2560, vocab_size=32768,
        mlp="swiglu", source="examples/train_100m_hsgd.py",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--pods", type=int, default=1, help="pod groups G")
    ap.add_argument("--fixed", action="store_true",
                    help="constant cadence instead of the adaptive controller")
    ap.add_argument("--q", type=int, default=4, help="fixed exchange interval Q")
    ap.add_argument("--max-interval", type=int, default=16,
                    help="adaptive cap on P = Q")
    ap.add_argument("--byte-budget-mb", type=float, default=float("inf"))
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = config_100m()
    model = llm_hybrid(cfg, n_tower=2, remat=False)
    params = init_llm_params(jax.random.PRNGKey(0), model, n_pods=args.pods)
    n_params = sum(tree_size(params[k]) // args.pods for k in params)
    print(f"hybrid model: {n_params/1e6:.1f}M params "
          f"(combined {tree_size(params['theta0'])/args.pods/1e6:.1f}M)")
    batch_fn = llm_batch_fn(cfg, args.batch, args.seq, n_pods=args.pods, seed=0)

    t0 = time.time()
    if args.fixed:
        steps = max(1, args.steps // args.q) * args.q  # whole compiled rounds
        runner = LLMRoundRunner(model, n_pods=args.pods)
        params, losses = runner.run_fixed(params, batch_fn, steps=steps,
                                          P=args.q, Q=args.q, lr=args.lr)
        # the compiled rounds return after the run: report one overall rate
        rate = (time.time() - t0) / len(losses)
        for t in range(0, len(losses), 10):
            print(f"step {t:4d}  loss {losses[t]:7.4f}")
        print(f"{rate:.2f}s/step overall (compile included)")
    else:
        acfg = AdaptiveConfig(total_steps=args.steps,
                              byte_budget=args.byte_budget_mb * 1e6,
                              max_interval=args.max_interval,
                              # anti-stall floor at half the seed η (yields to
                              # Theorem 1's 1/(8Pρ) cap inside plan_round)
                              eta_min=0.5 * args.lr,
                              eta_max=max(args.lr, 0.05))
        runner = AdaptiveLLMRunner(model, acfg, n_pods=args.pods,
                                   learning_rate=args.lr)
        params, losses, history = runner.run(params, batch_fn)
        for h in history:
            print(f"round {h['round']:3d}: P=Q={h['P']:3d} eta={h['eta']:.4g} "
                  f"rung={h['rung']} bytes={h['bytes_total']/1e6:.1f}MB "
                  f"loss={h['loss_last']:7.4f} rho={h['rho']:.3g} "
                  f"delta={h['delta']:.3g}")
        print(f"compiled executors: {len(runner.runner._round_cache)} "
              f"(one per distinct (P, Q, k, b) bucket)")

    sm = smoothed_losses(losses, window=8)
    assert sm[-1] < sm[0], "training must reduce the smoothed loss"
    print(f"done: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(smoothed {sm[0]:.3f} -> {sm[-1]:.3f}) in {time.time()-t0:.0f}s")
    if args.checkpoint:
        # flat {θ0, θ1, θ2} global model (pod mean), as before PR 3
        save_checkpoint(args.checkpoint, global_llm_params(params),
                        step=len(losses))
        print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
