"""End-to-end driver: train a ~100M-parameter dense transformer with the
paper's HSGD federation (hospital tower / device tower / combined backbone,
stale ζ exchange every Q steps) on synthetic token streams for a few hundred
steps.

  PYTHONPATH=src python examples/train_100m_hsgd.py            # 300 steps
  PYTHONPATH=src python examples/train_100m_hsgd.py --steps 20 # smoke
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.common.config import ModelConfig
from repro.launch.steps import make_exchange_step, make_hsgd_train_step
from repro.models.split_model import llm_hybrid


def config_100m() -> ModelConfig:
    return ModelConfig(
        name="dense-100m", family="dense", num_layers=12, d_model=640,
        num_heads=10, num_kv_heads=5, d_ff=2560, vocab_size=32768,
        mlp="swiglu", source="examples/train_100m_hsgd.py",
    )


def synthetic_stream(rng, vocab, batch, seq):
    """Markov-ish synthetic tokens: next token correlated with previous."""
    base = rng.randint(0, vocab, (batch, seq + 1))
    drift = (base[:, :-1] + rng.randint(0, 17, (batch, seq))) % vocab
    mask = rng.rand(batch, seq) < 0.7
    toks = np.where(mask, drift, base[:, 1:])
    return base[:, :-1], toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--q", type=int, default=4, help="exchange interval Q")
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = config_100m()
    model = llm_hybrid(cfg, n_tower=2, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    from repro.common.pytree import tree_size

    n_params = sum(tree_size(params[k]) for k in params)
    print(f"hybrid model: {n_params/1e6:.1f}M params "
          f"(combined {tree_size(params['theta0'])/1e6:.1f}M)")

    step = jax.jit(make_hsgd_train_step(model, lr=args.lr))
    exch = jax.jit(make_exchange_step(model))
    rng = np.random.RandomState(0)

    stale = None
    t0 = time.time()
    losses = []
    for t in range(args.steps):
        if t % args.q == 0:
            inp, tgt = synthetic_stream(rng, cfg.vocab_size, args.batch, args.seq)
            s1 = args.seq // 2
            batch = {
                "x1": jnp.asarray(inp[:, :s1]),
                "x2": jnp.asarray(inp[:, s1:]),
                "y": jnp.asarray(tgt),
            }
            stale = exch(params, batch)
        params, loss = step(params, stale, batch)
        losses.append(float(loss))
        if t % 10 == 0 or t == args.steps - 1:
            dt = time.time() - t0
            print(f"step {t:4d}  loss {losses[-1]:7.4f}  ({dt/(t+1):.2f}s/step)")
    assert losses[-1] < losses[0], "training must reduce loss"
    print(f"done: {losses[0]:.3f} -> {losses[-1]:.3f} in {time.time()-t0:.0f}s")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, step=args.steps)
        print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
