"""repro: communication-efficient hybrid federated learning (HSGD) in JAX."""
__version__ = "1.0.0"
