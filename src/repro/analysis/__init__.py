"""reprolint: static JIT-discipline analysis + runtime compile budgets.

Every performance claim in this repo rests on invariants the compiler does
not check: one XLA compile per executor bucket, donation-safe state
threading, traced-η never recompiling, single-seed determinism, honest
benchmark timing. ``reprolint`` proves the lexically-checkable half of
those invariants at review time (see ``rules.py`` for the catalogue, one
rule per historical bug class), and ``compile_guard`` asserts the runtime
half — exact compile counts per named executor — uniformly across tests.

Usage:

  python -m repro.analysis src benchmarks examples        # lint, human output
  python -m repro.analysis --check --json src             # CI: fail on findings
  python -m repro.analysis --write-baseline src ...       # accept current findings

  from repro.analysis import compile_guard
  with compile_guard(track=r"hsgd_round") as g:
      runner.round_fn(4, 2)(state, data, w, 0.05)
  assert g.total == 1
"""
from repro.analysis.compile_guard import CompileBudgetError, CompileGuard, compile_guard
from repro.analysis.linter import Finding, lint_paths, lint_source
from repro.analysis.rules import RULES

__all__ = [
    "CompileBudgetError",
    "CompileGuard",
    "compile_guard",
    "Finding",
    "lint_paths",
    "lint_source",
    "RULES",
]
