"""``python -m repro.analysis`` — the reprolint CLI.

  python -m repro.analysis src benchmarks examples     lint, human output
  python -m repro.analysis --check src ...             exit 1 on non-baselined
  python -m repro.analysis --json src ...              machine-readable report
  python -m repro.analysis --write-baseline src ...    accept current findings
  python -m repro.analysis --list-rules                rule catalogue
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.linter import (apply_baseline, lint_paths, load_baseline,
                                   write_baseline)
from repro.analysis.report import render_json, render_rule_list, render_terminal

DEFAULT_BASELINE = "reprolint_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: JIT-discipline static analysis")
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero on any non-baselined finding (CI mode)")
    ap.add_argument("--json", action="store_true", help="JSON report on stdout")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline file of accepted findings "
                         f"(default: {DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file entirely")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write all current findings to the baseline and exit 0")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of rule ids to run")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        render_rule_list(sys.stdout)
        return 0
    if not args.paths:
        ap.error("no paths given (try: python -m repro.analysis src)")

    only = [r.strip() for r in args.rules.split(",")] if args.rules else None
    findings = lint_paths(args.paths, only=only)

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = {} if args.no_baseline else load_baseline(args.baseline)
    new, stale = apply_baseline(findings, baseline)

    if args.json:
        render_json(new, stale, sys.stdout)
    else:
        render_terminal(new, stale, sys.stdout)

    if new:
        return 1
    if args.check and stale:
        # keep the debt ledger honest: a fixed finding must leave the baseline
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
