"""Runtime compile budgets: the dynamic half of reprolint.

The static rules prove lexical discipline; ``compile_guard`` proves the
invariant that actually matters at runtime — **how many times XLA compiled
each named executor** inside a region. It rides JAX's own compile logging
(``jax_log_compiles`` makes the lowering path emit one
"Compiling <name> with global shapes..." record per cache miss, carrying
the jitted function's ``__name__``), so there is no dependence on private
cache internals and no interference with donation or sharding.

Trivial primitive compiles (``jnp.ones`` → ``broadcast_in_dim`` etc.) also
log; pass ``track=`` with a regex over the executor names you care about —
this repo names its executors distinctively (``hsgd_round``,
``serve_decode``, ``llm_round``, ...) precisely so budgets are attributable.

    with compile_guard(track=r"hsgd_cohort_round") as g:
        for A in (2, 4, 8, 4, 2):
            runner.cohort_round_fn(2, 1, A)(state, data, w, idx, 0.05)
    assert g.total == 3          # one compile per pow2 cohort bucket

Budgets can be declared up front and enforced at region exit:

    with compile_guard(track=r"serve_", exact={"serve_decode": 1}):
        engine.generate(prompts, 8)   # raises CompileBudgetError on miss

``jax`` is imported lazily at region entry so the lint CLI (and the CI
lint job) never pays for — or requires — a jax import.
"""
from __future__ import annotations

import logging
import re
import threading
from collections import Counter
from typing import Dict, List, Optional, Union

__all__ = ["CompileBudgetError", "CompileGuard", "compile_guard"]


class CompileBudgetError(AssertionError):
    """A compile_guard region compiled more (or other) than budgeted."""


_COMPILE_RE = re.compile(r"Compiling\s+([^\s]+)")
_LOGGER_NAMES = ("jax._src.interpreters.pxla", "jax._src.dispatch")


class _CompileLogHandler(logging.Handler):
    """Fans each compile event out to every active guard (guards nest)."""

    def __init__(self):
        super().__init__(level=logging.DEBUG)
        self.guards: List["CompileGuard"] = []

    def emit(self, record: logging.LogRecord) -> None:
        try:
            msg = record.getMessage()
        except Exception:
            return
        if "with global shapes" not in msg:
            return
        m = _COMPILE_RE.search(msg)
        if not m:
            return
        name = m.group(1)
        for g in list(self.guards):
            g._record(name)


_lock = threading.Lock()
_handler = _CompileLogHandler()
_saved: Optional[dict] = None


def _install() -> None:
    """First guard in: flip jax_log_compiles on, attach the handler, and
    mute console propagation for the region (restored on last guard out)."""
    global _saved
    import jax

    saved = {"log_compiles": jax.config.jax_log_compiles, "loggers": []}
    jax.config.update("jax_log_compiles", True)
    for name in _LOGGER_NAMES:
        logger = logging.getLogger(name)
        saved["loggers"].append((logger, logger.propagate))
        logger.addHandler(_handler)
        logger.propagate = False
    _saved = saved


def _uninstall() -> None:
    global _saved
    import jax

    if _saved is None:
        return
    jax.config.update("jax_log_compiles", _saved["log_compiles"])
    for logger, propagate in _saved["loggers"]:
        logger.removeHandler(_handler)
        logger.propagate = propagate
    _saved = None


class CompileGuard:
    """Context manager counting XLA compiles by executor name.

    Parameters
    ----------
    track:
        Regex; only compile events whose function name matches are counted.
        Without it every compile in the region counts, including trivial
        primitive compiles — fine for "nothing compiled here" assertions
        (``exact=0``), noisy for anything else.
    exact:
        Budget enforced at region exit. An int pins the total tracked
        count; a dict maps name-regexes to pinned counts. Violations raise
        :class:`CompileBudgetError` (an AssertionError, so pytest reports
        it as a plain failure).
    max_compiles:
        Upper bound on the total tracked count, enforced at exit.

    After exit, ``total``, ``names``, ``by_name`` and ``count(pattern)``
    remain readable for ≤-style assertions the budgets can't express.
    """

    def __init__(self, track: Optional[str] = None,
                 exact: Optional[Union[int, Dict[str, int]]] = None,
                 max_compiles: Optional[int] = None):
        self._track = re.compile(track) if track else None
        self._exact = exact
        self._max = max_compiles
        self.names: List[str] = []

    # -- recording ----------------------------------------------------------

    def _record(self, name: str) -> None:
        if self._track is not None and not self._track.search(name):
            return
        self.names.append(name)

    @property
    def total(self) -> int:
        return len(self.names)

    @property
    def by_name(self) -> Counter:
        return Counter(self.names)

    def count(self, pattern: str) -> int:
        pat = re.compile(pattern)
        return sum(1 for n in self.names if pat.search(n))

    # -- context protocol ---------------------------------------------------

    def __enter__(self) -> "CompileGuard":
        with _lock:
            if not _handler.guards:
                _install()
            _handler.guards.append(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        with _lock:
            if self in _handler.guards:
                _handler.guards.remove(self)
            if not _handler.guards:
                _uninstall()
        if exc_type is not None:
            return False
        self._enforce()
        return False

    # -- budgets ------------------------------------------------------------

    def _enforce(self) -> None:
        seen = dict(self.by_name)
        if self._max is not None and self.total > self._max:
            raise CompileBudgetError(
                f"compile budget exceeded: {self.total} compiles > "
                f"max_compiles={self._max}; saw {seen}")
        if self._exact is None:
            return
        if isinstance(self._exact, int):
            if self.total != self._exact:
                raise CompileBudgetError(
                    f"compile budget missed: expected exactly {self._exact} "
                    f"compile(s), saw {self.total}: {seen}")
            return
        for pattern, want in self._exact.items():
            got = self.count(pattern)
            if got != want:
                raise CompileBudgetError(
                    f"compile budget missed for /{pattern}/: expected "
                    f"{want}, saw {got}; all tracked compiles: {seen}")


def compile_guard(track: Optional[str] = None,
                  exact: Optional[Union[int, Dict[str, int]]] = None,
                  max_compiles: Optional[int] = None) -> CompileGuard:
    """Build a :class:`CompileGuard` region. See the class for semantics."""
    return CompileGuard(track=track, exact=exact, max_compiles=max_compiles)
