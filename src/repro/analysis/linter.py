"""File walking, suppression handling, and baseline bookkeeping for
reprolint.

Suppressions:

  x = np.random.randn(3)        # reprolint: disable=RP5
  # reprolint: disable=RP4,RP6      (several rules, same line)
  # reprolint: disable                (every rule, that line)
  # reprolint: disable-file=RP6      (anywhere in the file: whole file)

Baseline: a JSON file of accepted findings keyed by a line-number-free
fingerprint (rule, path, stripped source text), so unrelated edits above a
baselined site don't resurrect it. ``--check`` fails only on findings not
in the baseline; stale baseline entries are reported so the file shrinks
as debt is paid down.
"""
from __future__ import annotations

import ast
import hashlib
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.rules import RULES, FileContext, Finding

__all__ = [
    "Finding", "lint_source", "lint_paths", "iter_python_files",
    "fingerprint", "load_baseline", "write_baseline", "apply_baseline",
]

_SUPPRESS_RE = re.compile(r"#\s*reprolint:\s*disable(?:=([A-Z0-9,\s]+))?")
_SUPPRESS_FILE_RE = re.compile(r"#\s*reprolint:\s*disable-file=([A-Z0-9,\s]+)")

_SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules",
              ".venv", "venv", "build", "dist"}


def _parse_suppressions(source: str) -> Tuple[Dict[int, Optional[Set[str]]], Set[str]]:
    """Returns (line -> suppressed rule ids or None for "all", file-wide set)."""
    per_line: Dict[int, Optional[Set[str]]] = {}
    file_wide: Set[str] = set()
    for i, line in enumerate(source.splitlines(), start=1):
        if "reprolint" not in line:
            continue
        m = _SUPPRESS_FILE_RE.search(line)
        if m:
            file_wide |= {r.strip() for r in m.group(1).split(",") if r.strip()}
            continue
        m = _SUPPRESS_RE.search(line)
        if m:
            if m.group(1):
                per_line[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
            else:
                per_line[i] = None  # all rules
    return per_line, file_wide


def _suppressed(f: Finding, per_line: Dict[int, Optional[Set[str]]],
                file_wide: Set[str]) -> bool:
    if f.rule in file_wide:
        return True
    if f.line in per_line:
        rules = per_line[f.line]
        return rules is None or f.rule in rules
    return False


def lint_source(source: str, path: str = "<string>",
                only: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint one module's source. ``only`` restricts to a subset of rule ids.
    Syntax errors yield a single synthetic ``SYNTAX`` finding rather than
    raising, so one broken file can't take down a CI sweep."""
    try:
        ctx = FileContext(path, source)
    except SyntaxError as e:
        return [Finding("SYNTAX", path, e.lineno or 1, e.offset or 0,
                        f"file does not parse: {e.msg}")]
    per_line, file_wide = _parse_suppressions(source)
    findings: List[Finding] = []
    for rule_id, rule in RULES.items():
        if only and rule_id not in only:
            continue
        for f in rule.check(ctx):
            if not _suppressed(f, per_line, file_wide):
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def iter_python_files(paths: Iterable[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        root = Path(p)
        if root.is_file() and root.suffix == ".py":
            out.append(root)
        elif root.is_dir():
            for f in sorted(root.rglob("*.py")):
                if not (_SKIP_DIRS & set(f.parts)):
                    out.append(f)
    return out


def lint_paths(paths: Iterable[str],
               only: Optional[Sequence[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_python_files(paths):
        try:
            source = f.read_text()
        except (OSError, UnicodeDecodeError):
            continue
        findings.extend(lint_source(source, str(f), only=only))
    return findings


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------


def fingerprint(f: Finding) -> str:
    """Line-number-free identity: survives edits elsewhere in the file."""
    h = hashlib.sha1()
    h.update(f"{f.rule}|{f.path}|{f.source}".encode())
    return h.hexdigest()[:16]


def load_baseline(path: str) -> Dict[str, dict]:
    p = Path(path)
    if not p.exists():
        return {}
    data = json.loads(p.read_text())
    return {e["fingerprint"]: e for e in data.get("findings", [])}


def write_baseline(path: str, findings: Sequence[Finding]) -> None:
    entries = [
        {"fingerprint": fingerprint(f), "rule": f.rule, "path": f.path,
         "line": f.line, "message": f.message, "source": f.source}
        for f in findings
    ]
    # stable order + dedup (several findings can share one source line)
    seen: Set[str] = set()
    unique = []
    for e in sorted(entries, key=lambda e: (e["path"], e["line"], e["rule"])):
        if e["fingerprint"] not in seen:
            seen.add(e["fingerprint"])
            unique.append(e)
    Path(path).write_text(json.dumps(
        {"comment": "reprolint accepted findings — shrink me, don't grow me",
         "findings": unique}, indent=2) + "\n")


def apply_baseline(findings: Sequence[Finding],
                   baseline: Dict[str, dict]
                   ) -> Tuple[List[Finding], List[dict]]:
    """Split into (new findings, stale baseline entries)."""
    current = {fingerprint(f) for f in findings}
    new = [f for f in findings if fingerprint(f) not in baseline]
    stale = [e for fp, e in baseline.items() if fp not in current]
    return new, stale
