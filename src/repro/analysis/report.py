"""Terminal and JSON reporters for reprolint findings."""
from __future__ import annotations

import json
from collections import Counter
from typing import List, Sequence, TextIO

from repro.analysis.rules import RULES, Finding


def render_terminal(findings: Sequence[Finding], stale: Sequence[dict],
                    out: TextIO) -> None:
    last_path = None
    for f in findings:
        if f.path != last_path:
            out.write(f"\n{f.path}\n")
            last_path = f.path
        out.write(f"  {f.line}:{f.col}  {f.rule}  {f.message}\n")
        if f.source:
            out.write(f"      | {f.source}\n")
    if stale:
        out.write("\nstale baseline entries (fixed or moved — remove them):\n")
        for e in stale:
            out.write(f"  {e['path']}:{e.get('line', '?')}  {e['rule']}  "
                      f"{e.get('source', '')}\n")
    by_rule = Counter(f.rule for f in findings)
    if findings:
        parts = ", ".join(f"{r}×{n}" for r, n in sorted(by_rule.items()))
        out.write(f"\n{len(findings)} finding(s): {parts}\n")
    else:
        out.write("reprolint: clean\n")


def render_json(findings: Sequence[Finding], stale: Sequence[dict],
                out: TextIO) -> None:
    payload = {
        "findings": [
            {"rule": f.rule, "path": f.path, "line": f.line, "col": f.col,
             "message": f.message, "source": f.source}
            for f in findings
        ],
        "stale_baseline": list(stale),
        "counts": dict(Counter(f.rule for f in findings)),
        "total": len(findings),
    }
    json.dump(payload, out, indent=2)
    out.write("\n")


def render_rule_list(out: TextIO) -> None:
    for rule_id, rule in sorted(RULES.items()):
        out.write(f"{rule_id}  {rule.title}\n")
        if rule.doc:
            for line in rule.doc.splitlines():
                out.write(f"      {line.strip()}\n")
        out.write("\n")
