"""The reprolint rule catalogue — one rule per bug class this repo has
actually shipped (or nearly shipped). Each rule is a pure function over a
parsed module (``FileContext``) yielding ``Finding``s; the registry maps
rule ids to checkers so the linter, the CLI ``--list-rules`` output, and the
fixture tests all read from one place.

Rules (see README.md for the war stories):

  RP1  jit-in-loop            — ``jax.jit``/``pjit`` evaluated per iteration
  RP2  use-after-donate       — a name read after a donating executor ate it
  RP3  loop-varying-capture   — jitted closure over a loop-rebound Python value
  RP4  host-sync-in-compiled  — ``.item()``/``np.asarray``/... in jit, scan
                                bodies, or engine ``step()`` paths
  RP5  unseeded-rng           — global ``np.random.*`` state / bare
                                ``default_rng()`` outside data/ fixtures
  RP6  unsynced-benchmark-timer — ``time.time()`` spans async device work with
                                no ``block_until_ready``/``device_get``
  RP7  mutable-default        — mutable arg defaults; array-valued dataclass
                                field defaults
  RP8  unregistered-state     — ``*State`` NamedTuple never passed to
                                ``checkpoint.register_state_class``
  RP9  torn-artifact-write    — bare ``open(path, "w")`` of a JSON/manifest
                                run artifact outside an atomic-write helper
  RP10 unregistered-rng-stream — structured ``default_rng([seed, N, ...])``
                                seed whose stream index N is not in the
                                reserved-stream registry
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Set, Tuple

# ---------------------------------------------------------------------------
# Finding + registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    source: str = ""  # stripped source line (baseline fingerprinting)

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"


@dataclass(frozen=True)
class Rule:
    id: str
    title: str
    check: Callable[["FileContext"], Iterator[Finding]]
    doc: str = ""


RULES: Dict[str, Rule] = {}


def rule(rule_id: str, title: str):
    def register(fn):
        RULES[rule_id] = Rule(rule_id, title, fn, doc=(fn.__doc__ or "").strip())
        return fn

    return register


# ---------------------------------------------------------------------------
# Parsed-module context shared by every rule
# ---------------------------------------------------------------------------

_JIT_NAMES = {"jax.jit", "jax.pjit", "jax.experimental.pjit.pjit"}
_SCAN_HOFS = {
    "jax.lax.scan": (0,),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.fori_loop": (2,),
    "jax.lax.cond": (1, 2),
    "jax.lax.switch": None,  # every arg past the index may be a branch
    "jax.lax.map": (0,),
}
_HOST_SYNC_CALLS = {"jax.device_get", "numpy.asarray", "numpy.array"}
_NP_GLOBAL_DISTS = {
    "rand", "randn", "randint", "random", "random_sample", "normal",
    "uniform", "choice", "permutation", "shuffle", "exponential", "poisson",
    "binomial", "beta", "gamma", "standard_normal", "sample",
}
_TIMER_CALLS = {"time.time", "time.perf_counter", "time.monotonic"}
_SYNC_EVIDENCE = {"jax.device_get", "numpy.asarray", "numpy.array",
                  "jax.block_until_ready"}


class FileContext:
    """One parsed file: tree + parent links + import-alias resolution."""

    def __init__(self, path: str, source: str, tree: Optional[ast.Module] = None):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree if tree is not None else ast.parse(source, filename=path)
        self.parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node
        self.aliases = self._collect_aliases()

    # -- imports ------------------------------------------------------------

    def _collect_aliases(self) -> Dict[str, str]:
        """local name -> canonical dotted prefix (``jnp`` -> ``jax.numpy``)."""
        out: Dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    out[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
                for a in node.names:
                    out[a.asname or a.name] = f"{node.module}.{a.name}"
        self._imported = set(out.values())
        # normalize the two ubiquitous shorthands even without imports
        out.setdefault("np", "numpy")
        out.setdefault("jnp", "jax.numpy")
        return out

    def imports_jax(self) -> bool:
        return any(v == "jax" or v.startswith("jax.") for v in self._imported)

    # -- name resolution ----------------------------------------------------

    def dotted(self, node: ast.AST) -> Optional[str]:
        """``ast.Name``/``ast.Attribute`` chain -> dotted string, else None."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(node.id)
        return ".".join(reversed(parts))

    def canonical(self, node: ast.AST) -> Optional[str]:
        """Dotted name with the leading import alias expanded."""
        name = self.dotted(node)
        if name is None:
            return None
        head, _, rest = name.partition(".")
        full = self.aliases.get(head, head)
        return f"{full}.{rest}" if rest else full

    def call_canonical(self, call: ast.Call) -> Optional[str]:
        return self.canonical(call.func)

    # -- structure helpers ---------------------------------------------------

    def source_line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule_id: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule_id, self.path, node.lineno, node.col_offset,
                       message, self.source_line(node.lineno))

    def is_jit_expr(self, node: ast.AST) -> bool:
        """``jax.jit``/``pjit`` reference, a call to one, or a
        ``partial(jax.jit, ...)`` wrapper (decorator or value position)."""
        if isinstance(node, (ast.Name, ast.Attribute)):
            return self.canonical(node) in _JIT_NAMES
        if isinstance(node, ast.Call):
            fn = self.call_canonical(node)
            if fn in _JIT_NAMES:
                return True
            if fn in ("functools.partial", "partial") and node.args:
                return self.is_jit_expr(node.args[0])
        return False

    def jit_decorated(self, fn: ast.AST) -> bool:
        return isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) and any(
            self.is_jit_expr(d) for d in fn.decorator_list)

    def donate_positions(self, node: ast.AST) -> Optional[Tuple[int, ...]]:
        """Positions named by ``donate_argnums`` if ``node`` is a jit/partial
        expression carrying one; None otherwise."""
        if not isinstance(node, ast.Call):
            return None
        fn = self.call_canonical(node)
        if fn in ("functools.partial", "partial") and node.args:
            if not self.is_jit_expr(node.args[0]):
                return None
        elif fn not in _JIT_NAMES:
            return None
        for kw in node.keywords:
            if kw.arg == "donate_argnums":
                try:
                    v = ast.literal_eval(kw.value)
                except (ValueError, SyntaxError):
                    return None
                return tuple(v) if isinstance(v, (tuple, list)) else (int(v),)
        return None

    def enclosing(self, node: ast.AST, kinds) -> Optional[ast.AST]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, kinds):
                return cur
            cur = self.parents.get(cur)
        return None

    def executes_inside_loop(self, node: ast.AST) -> bool:
        """True when ``node`` is evaluated per iteration of a lexical loop:
        there is a For/While between it and its nearest enclosing function
        body. Decorator expressions belong to the ENCLOSING scope, so a
        decorated def inside a loop still counts."""
        cur, prev = self.parents.get(node), node
        while cur is not None:
            if isinstance(cur, (ast.For, ast.While, ast.AsyncFor)):
                return True
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                in_decorators = not isinstance(cur, ast.Lambda) and any(
                    prev is d or _contains(d, prev) for d in cur.decorator_list)
                if not in_decorators:
                    return False  # inner scope: not evaluated at loop time
            prev, cur = cur, self.parents.get(cur)
        return False


def _contains(root: ast.AST, target: ast.AST) -> bool:
    return any(n is target for n in ast.walk(root))


def _assigned_names(target: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(target):
        if isinstance(n, ast.Name) and isinstance(n.ctx, (ast.Store, ast.Del)):
            out.add(n.id)
    return out


def _scope_functions(ctx: FileContext):
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


# ---------------------------------------------------------------------------
# RP1 — jit evaluated inside a loop
# ---------------------------------------------------------------------------


@rule("RP1", "jax.jit/pjit evaluated inside a loop")
def check_jit_in_loop(ctx: FileContext) -> Iterator[Finding]:
    """Each evaluation of ``jax.jit`` builds a FRESH compile cache: calling
    it per round/iteration recompiles every time and silently destroys the
    one-executor-per-bucket discipline. Hoist the jit (or use a cached
    executor factory like ``HSGDRunner.round_fn``)."""
    for node in ast.walk(ctx.tree):
        is_jit_call = isinstance(node, ast.Call) and ctx.is_jit_expr(node)
        is_jit_deco = (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                       and ctx.jit_decorated(node))
        if not (is_jit_call or is_jit_deco):
            continue
        probe = node.decorator_list[0] if is_jit_deco else node
        if ctx.executes_inside_loop(probe):
            yield ctx.finding(
                "RP1", node,
                "jax.jit evaluated per loop iteration — a fresh compile "
                "cache every pass; hoist it or cache the executor per bucket")


# ---------------------------------------------------------------------------
# RP2 — use after donation
# ---------------------------------------------------------------------------


def _donating_names(ctx: FileContext, scope: ast.AST) -> Dict[str, Tuple[int, ...]]:
    """Names bound (in ``scope``'s immediate statements) to donating jitted
    callables: ``f = jax.jit(g, donate_argnums=...)`` assignments and
    ``@partial(jax.jit, donate_argnums=...)`` decorated defs."""
    out: Dict[str, Tuple[int, ...]] = {}
    body = scope.body if hasattr(scope, "body") else []
    for stmt in body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            pos = ctx.donate_positions(stmt.value)
            if pos is not None and isinstance(stmt.targets[0], ast.Name):
                out[stmt.targets[0].id] = pos
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for d in stmt.decorator_list:
                pos = ctx.donate_positions(d)
                if pos is not None:
                    out[stmt.name] = pos
    return out


@rule("RP2", "value used after being donated to a jitted executor")
def check_use_after_donate(ctx: FileContext) -> Iterator[Finding]:
    """``donate_argnums`` hands the buffer to XLA: the Python name still
    points at a deleted array, and touching it raises (or worse, on some
    backends, reads freed memory). Rebind the name from the executor's
    return value — every runner in this repo threads state that way."""
    for fn in list(_scope_functions(ctx)) + [ctx.tree]:
        donating = _donating_names(ctx, fn)
        if not donating:
            continue
        # (line, order, kind, name) — within one line, loads happen first
        # (call args), then the donation consumes, then the assignment of
        # the return value rebinds: `state, l = fn(state, ...)` is safe.
        events: List[Tuple[int, int, str, str]] = []
        body = fn.body if hasattr(fn, "body") else []
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                    events.append((node.lineno, 0, "load", node.id))
                elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
                    events.append((node.lineno, 2, "rebind", node.id))
                if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                    pos = donating.get(node.func.id)
                    if pos is None:
                        continue
                    for p in pos:
                        if p < len(node.args) and isinstance(node.args[p], ast.Name):
                            events.append((node.lineno, 1, "consume",
                                           node.args[p].id))
        consumed: Dict[str, int] = {}
        for line, _, kind, name in sorted(events):
            if kind == "load" and name in consumed:
                if line > consumed[name]:
                    src = ctx.source_line(line)
                    yield Finding(
                        "RP2", ctx.path, line, 0,
                        f"'{name}' was donated to a jitted executor on line "
                        f"{consumed[name]} and is read again — the buffer is "
                        f"gone; rebind it from the executor's return value",
                        src)
                    del consumed[name]  # one report per donation
            elif kind == "rebind":
                consumed.pop(name, None)
            elif kind == "consume":
                consumed[name] = line


# ---------------------------------------------------------------------------
# RP3 — jitted closure over a loop-varying Python value
# ---------------------------------------------------------------------------


def _local_bindings(fn: ast.AST) -> Set[str]:
    """Parameters + names assigned anywhere in ``fn`` (its own scope)."""
    names: Set[str] = set()
    args = fn.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs
              + ([args.vararg] if args.vararg else [])
              + ([args.kwarg] if args.kwarg else [])):
        names.add(a.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            names.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node is not fn:
                names.add(node.name)
    return names


@rule("RP3", "jitted closure captures a loop-varying Python scalar")
def check_loop_varying_capture(ctx: FileContext) -> Iterator[Finding]:
    """A Python value captured by closure is baked into the trace as a
    constant: when the enclosing loop rebinds it each iteration, the jitted
    function either recompiles every pass or (if the jit object survived the
    loop) silently keeps the stale first value. This is the traced-η bug
    class — η must ride through as a traced ARGUMENT, never a capture."""
    for outer in _scope_functions(ctx):
        loop_rebound: Set[str] = set()
        for node in ast.walk(outer):
            if isinstance(node, (ast.For, ast.AsyncFor)):
                loop_rebound |= _assigned_names(node.target)
            if isinstance(node, (ast.For, ast.While, ast.AsyncFor)):
                for sub in node.body + getattr(node, "orelse", []):
                    for n in ast.walk(sub):
                        if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                            tgt = n.targets if isinstance(n, ast.Assign) else [n.target]
                            for t in tgt:
                                loop_rebound |= _assigned_names(t)
        if not loop_rebound:
            continue
        for inner in ast.walk(outer):
            if not isinstance(inner, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if inner is outer or not ctx.jit_decorated(inner):
                continue
            local = _local_bindings(inner)
            for node in ast.walk(inner):
                if (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)
                        and node.id in loop_rebound and node.id not in local):
                    yield ctx.finding(
                        "RP3", inner,
                        f"jitted '{inner.name}' closes over '{node.id}', which "
                        f"the enclosing loop rebinds — recompile (or stale "
                        f"constant) every iteration; pass it as a traced "
                        f"argument instead")
                    break  # one finding per jitted def


# ---------------------------------------------------------------------------
# RP4 — host sync inside compiled bodies / engine step paths
# ---------------------------------------------------------------------------


def _compiled_bodies(ctx: FileContext) -> List[Tuple[ast.AST, bool]]:
    """(body, is_traced) pairs worth auditing for host syncs: traced bodies
    (jit-decorated; passed to lax control-flow HOFs) and the host-side
    serving hot path — class ``step()`` methods plus the same-class helpers
    they call (one level: ``self._decode_block_run()`` style)."""
    out: List[Tuple[ast.AST, bool]] = []
    seen: Set[int] = set()

    def add(body: ast.AST, traced: bool) -> None:
        if id(body) not in seen:
            seen.add(id(body))
            out.append((body, traced))

    local_defs: Dict[str, ast.AST] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            local_defs[node.name] = node
            if ctx.jit_decorated(node):
                add(node, True)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = ctx.call_canonical(node)
        if fn not in _SCAN_HOFS:
            continue
        positions = _SCAN_HOFS[fn]
        args = (node.args if positions is None
                else [node.args[p] for p in positions if p < len(node.args)])
        for a in args:
            if isinstance(a, ast.Lambda):
                add(a, True)
            elif isinstance(a, ast.Name) and a.id in local_defs:
                add(local_defs[a.id], True)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        methods = {m.name: m for m in node.body
                   if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}
        step = methods.get("step")
        if step is None or ctx.jit_decorated(step):
            continue
        add(step, False)
        for sub in ast.walk(step):
            if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute)
                    and isinstance(sub.func.value, ast.Name)
                    and sub.func.value.id == "self"
                    and sub.func.attr in methods):
                add(methods[sub.func.attr], False)
    return out


@rule("RP4", "host synchronization inside a compiled body or step() path")
def check_host_sync(ctx: FileContext) -> Iterator[Finding]:
    """``.item()``/``float()``/``np.asarray()`` on a traced value either
    aborts tracing (ConcretizationTypeError) or, on the host side of an
    engine ``step()``, stalls the dispatch pipeline once per token instead
    of once per block. Keep device values on device; sync once per block
    at a documented point."""
    sync_msg = {
        "item": ".item() forces a device->host sync",
        "tolist": ".tolist() forces a device->host sync",
    }
    for body_fn, inside_jit in _compiled_bodies(ctx):
        for node in ast.walk(body_fn):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) and node.func.attr in sync_msg \
                    and not node.args:
                yield ctx.finding("RP4", node, sync_msg[node.func.attr]
                                  + " inside a compiled/hot body")
                continue
            fn = ctx.call_canonical(node)
            if fn in _HOST_SYNC_CALLS:
                yield ctx.finding(
                    "RP4", node,
                    f"{fn}() materializes the operand on host inside a "
                    f"compiled/hot body — sync once per block, outside")
            elif inside_jit and fn in ("float", "int") and node.args and not \
                    isinstance(node.args[0], ast.Constant):
                yield ctx.finding(
                    "RP4", node,
                    f"{fn}() on a traced value concretizes it — aborts "
                    f"tracing or bakes in a stale constant")


# ---------------------------------------------------------------------------
# RP5 — unseeded / global-state RNG
# ---------------------------------------------------------------------------


@rule("RP5", "unseeded or global-state numpy RNG")
def check_unseeded_rng(ctx: FileContext) -> Iterator[Finding]:
    """Every trace, cohort, and benchmark in this repo reproduces from ONE
    seed; a module-level ``np.random.*`` draw or a bare ``default_rng()``
    injects hidden global state that breaks replay (and the paper-parity
    claims with it). Thread an explicit seeded Generator/RandomState."""
    if "data" in ctx.path.replace("\\", "/").split("/"):
        return  # data fixtures own their seeding policy
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = ctx.call_canonical(node)
        if fn is None:
            continue
        if fn == "numpy.random.seed":
            yield ctx.finding("RP5", node,
                              "np.random.seed mutates GLOBAL RNG state — "
                              "pass an explicit Generator/RandomState")
        elif fn.startswith("numpy.random.") and fn.split(".")[-1] in _NP_GLOBAL_DISTS:
            yield ctx.finding(
                "RP5", node,
                f"{fn} draws from the global numpy RNG — unseeded and "
                f"order-dependent; use np.random.default_rng(seed)")
        elif fn in ("numpy.random.default_rng", "numpy.random.RandomState") \
                and not node.args and not node.keywords:
            yield ctx.finding(
                "RP5", node,
                f"bare {fn}() seeds from the OS — every run differs; "
                f"derive the seed from the experiment config")


# ---------------------------------------------------------------------------
# RP6 — benchmark timing without a device sync
# ---------------------------------------------------------------------------


@rule("RP6", "benchmark timer spans async device work without a sync")
def check_unsynced_timer(ctx: FileContext) -> Iterator[Finding]:
    """JAX dispatch is async: ``time.time()`` around un-synced device calls
    measures enqueue latency, not execution. Every timed region in
    ``benchmarks/`` must force completion (``jax.block_until_ready``,
    ``device_get``, or a host materialization) before the second timestamp."""
    if "benchmarks" not in ctx.path.replace("\\", "/").split("/"):
        return
    if not ctx.imports_jax():
        return
    for fn in _scope_functions(ctx):
        timers: List[ast.Call] = []
        synced = False
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = ctx.call_canonical(node)
            if name in _TIMER_CALLS:
                timers.append(node)
            elif name in _SYNC_EVIDENCE or (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("block_until_ready", "device_get")):
                synced = True
        if len(timers) >= 2 and not synced:
            yield ctx.finding(
                "RP6", timers[-1],
                "timed region has no block_until_ready/device_get — with "
                "async dispatch this measures enqueue, not execution")


# ---------------------------------------------------------------------------
# RP7 — mutable defaults
# ---------------------------------------------------------------------------


_ARRAY_FACTORY_PREFIXES = ("jax.numpy.", "numpy.")


@rule("RP7", "mutable default argument / array dataclass default")
def check_mutable_default(ctx: FileContext) -> Iterator[Finding]:
    """A mutable default is one object shared by every call; an array-valued
    dataclass default is one buffer shared by every instance (and it makes
    the config unhashable, which silently breaks executor-cache keys).
    Use ``None`` + construct inside, or ``field(default_factory=...)``."""
    for fn in _scope_functions(ctx):
        for default in list(fn.args.defaults) + [
                d for d in fn.args.kw_defaults if d is not None]:
            if isinstance(default, (ast.List, ast.Dict, ast.Set)):
                yield ctx.finding(
                    "RP7", default,
                    f"mutable default in '{fn.name}' — one shared object "
                    f"across all calls; use None and construct inside")
            elif isinstance(default, ast.Call):
                name = ctx.call_canonical(default)
                if name in ("list", "dict", "set") or (
                        name and name.startswith(_ARRAY_FACTORY_PREFIXES)
                        and not name.endswith((".float32", ".float64", ".int32",
                                               ".int64", ".bfloat16"))):
                    yield ctx.finding(
                        "RP7", default,
                        f"call-valued default in '{fn.name}' evaluates ONCE "
                        f"at def time and is shared; use None or "
                        f"field(default_factory=...)")
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        is_dc = any(ctx.canonical(d if not isinstance(d, ast.Call) else d.func)
                    in ("dataclasses.dataclass", "dataclass")
                    for d in node.decorator_list)
        if not is_dc:
            continue
        for stmt in node.body:
            if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.value, ast.Call):
                name = ctx.call_canonical(stmt.value)
                if name and name.startswith(_ARRAY_FACTORY_PREFIXES):
                    yield ctx.finding(
                        "RP7", stmt,
                        f"dataclass field default '{name}' is one array "
                        f"shared by every instance (and unhashable); use "
                        f"field(default_factory=...)")


# ---------------------------------------------------------------------------
# RP8 — state NamedTuple not registered for checkpoint restore
# ---------------------------------------------------------------------------


@rule("RP8", "*State NamedTuple not registered with register_state_class")
def check_unregistered_state(ctx: FileContext) -> Iterator[Finding]:
    """``checkpoint.load_checkpoint`` rebuilds containers from a structure
    descriptor; a NamedTuple class that never called
    ``register_state_class`` restores as an anonymous lookalike — code that
    isinstance-checks or relies on methods breaks one restart later (the
    ``__seq{i}`` checkpoint-loss bug class)."""
    registered: Set[str] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            fn = ctx.call_canonical(node) or ""
            if fn.endswith("register_state_class") and node.args and \
                    isinstance(node.args[0], ast.Name):
                registered.add(node.args[0].id)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        if not node.name.endswith("State"):
            continue
        bases = {ctx.canonical(b) for b in node.bases}
        if not ({"NamedTuple", "typing.NamedTuple"} & bases):
            continue
        decorated = any((ctx.canonical(d) or "").endswith("register_state_class")
                        for d in node.decorator_list)
        if node.name not in registered and not decorated:
            yield ctx.finding(
                "RP8", node,
                f"'{node.name}' is a state NamedTuple but is never passed to "
                f"checkpoint.register_state_class — a checkpoint restore "
                f"returns an anonymous lookalike")


# ---------------------------------------------------------------------------
# RP9 — torn run-artifact writes (non-atomic open(path, "w"))
# ---------------------------------------------------------------------------


def _rp9_artifact_evidence(ctx: FileContext, call: ast.Call) -> Optional[str]:
    """Why this ``open(...)`` looks like a durable run-artifact write:
    a ``.json``/manifest path constant, or a ``json.dump`` into the handle
    inside the enclosing ``with``. None = not an artifact write."""
    if call.args:
        for node in ast.walk(call.args[0]):
            if isinstance(node, ast.Constant) and isinstance(node.value, str):
                s = node.value
                if ".tmp" in s:
                    return None  # temp-then-replace staging file
                if s.endswith(".json") or "manifest" in s:
                    return f"path {s!r}"
    w = ctx.enclosing(call, ast.With)
    if w is not None:
        for node in ast.walk(w):
            if isinstance(node, ast.Call) and \
                    ctx.call_canonical(node) in ("json.dump", "json.dumps"):
                if ctx.call_canonical(node) == "json.dump":
                    return "json.dump into the handle"
    return None


@rule("RP9", "non-atomic write of a JSON/manifest run artifact")
def check_torn_artifact_write(ctx: FileContext) -> Iterator[Finding]:
    """A bare ``open(path, "w")`` truncates the artifact FIRST and fills it
    as serialization proceeds: a crash (or a coordinator preemption — the
    fault class the resilient runtime injects on purpose) between those two
    moments leaves a torn half-file where a resumable checkpoint manifest or
    benchmark result used to be. Durable JSON artifacts must stage to a temp
    file and commit with one atomic ``os.replace`` —
    ``repro.common.io.atomic_write_json`` is the repo's helper. Functions
    named ``atomic_*`` (the helpers themselves) and writes whose enclosing
    function commits via ``os.replace`` are exempt."""
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call) or ctx.call_canonical(node) != "open":
            continue
        mode = None
        if len(node.args) >= 2:
            mode = node.args[1]
        for kw in node.keywords:
            if kw.arg == "mode":
                mode = kw.value
        if not (isinstance(mode, ast.Constant) and isinstance(mode.value, str)
                and mode.value in ("w", "wt", "w+")):
            continue
        fn = ctx.enclosing(node, (ast.FunctionDef, ast.AsyncFunctionDef))
        if fn is not None:
            if fn.name.startswith("atomic_"):
                continue  # the atomic-write helper itself
            if any(isinstance(n, ast.Call)
                   and ctx.call_canonical(n) == "os.replace"
                   for n in ast.walk(fn)):
                continue  # stages + commits atomically in place
        evidence = _rp9_artifact_evidence(ctx, node)
        if evidence is None:
            continue
        yield ctx.finding(
            "RP9", node,
            f"bare open(..., \"w\") of a run artifact ({evidence}) — a crash "
            f"mid-write leaves a torn file; stage to a temp file and commit "
            f"with os.replace (repro.common.io.atomic_write_json)")


# ---------------------------------------------------------------------------
# RP10 — structured RNG seed with an unregistered stream index
# ---------------------------------------------------------------------------

# The repo's host-side RNG discipline: every independent random subsystem owns
# ONE stream index in the structured seed ``default_rng([seed, STREAM, ...])``.
# Two subsystems sharing an index draw CORRELATED values from the same run
# seed — the secure-aggregation masks, for example, must never correlate with
# the fault injector's dropout pattern, or "mask cancellation under dropout"
# quietly tests a measure-zero slice. New streams register here first.
RESERVED_STREAMS: Dict[int, str] = {
    0: "population traits / experiment registry (core/population.py)",
    1: "per-round cohort sampling (core/population.py)",
    2: "typical-tails straggler model (core/population.py)",
    3: "fault injection (core/faults.py)",
    4: "secure-aggregation pairwise masks (core/federation.py)",
}


@rule("RP10", "structured RNG seed uses an unregistered stream index")
def check_unregistered_rng_stream(ctx: FileContext) -> Iterator[Finding]:
    """A structured seed ``np.random.default_rng([seed, N, ...])`` carves the
    run seed into independent streams keyed by N. The index must be an int
    literal registered in ``RESERVED_STREAMS`` (or a module constant named
    ``*_STREAM`` that documents its registry entry): an unregistered literal
    is a silent collision waiting for the next subsystem, and a VARIABLE
    index defeats the registry entirely — nobody can audit which streams a
    run actually touches."""
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        if ctx.call_canonical(node) != "numpy.random.default_rng":
            continue
        if not node.args or not isinstance(node.args[0], (ast.List, ast.Tuple)):
            continue
        elts = node.args[0].elts
        if len(elts) < 2:
            continue  # [seed]-only: no stream index to audit
        stream = elts[1]
        if isinstance(stream, ast.Constant):
            if isinstance(stream.value, int) and not isinstance(stream.value, bool) \
                    and stream.value in RESERVED_STREAMS:
                continue
            yield ctx.finding(
                "RP10", node,
                f"stream index {stream.value!r} of a structured default_rng "
                f"seed is not in the reserved-stream registry "
                f"(analysis/rules.py RESERVED_STREAMS) — register it before "
                f"use, or two subsystems will draw correlated values")
        else:
            name = ctx.dotted(stream)
            if name is not None and name.split(".")[-1].endswith("_STREAM"):
                continue  # registered module constant, self-documenting
            yield ctx.finding(
                "RP10", node,
                "stream index of a structured default_rng seed is neither a "
                "registered int literal nor a *_STREAM constant — the "
                "reserved-stream registry (analysis/rules.py) cannot audit it")
