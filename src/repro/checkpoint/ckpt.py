"""Minimal dependency-free checkpointing: flattened pytree -> .npz + manifest.

Multi-host note: in a real pod deployment each host saves its addressable
shards under a per-host suffix; here (single-host container) we gather to
host numpy. The format is stable across restarts and tested round-trip.

Container structure survives the trip: tuples/lists are flattened to
``__seq{i}`` keys for the .npz (stable, order-preserving), and the manifest
records a structure descriptor from which ``load_checkpoint`` rebuilds the
original python containers — dict vs list vs tuple vs namedtuple — exactly.
NamedTuple state classes (``HSGDState``, optimizer states, ...) register via
``register_state_class`` so a restore returns the real class, not an
anonymous lookalike; unregistered names degrade to a dynamically created
namedtuple with the recorded fields. Manifests written before the descriptor
existed load the old way (nested dicts with ``__seq{i}`` keys).
"""
from __future__ import annotations

import io
import json
import os
from collections import namedtuple
from typing import Any, Dict, Tuple, Type

import jax
import numpy as np

from repro.common.io import atomic_write_json
from repro.common.pytree import flatten_dict, unflatten_dict

# name -> class for namedtuple restoration (populated by the state owners,
# e.g. core/hsgd.py registers HSGDState at import time)
_STATE_CLASSES: Dict[str, Type] = {}


def register_state_class(cls: Type) -> Type:
    """Register a NamedTuple class for checkpoint restoration (idempotent;
    usable as a decorator)."""
    _STATE_CLASSES[cls.__name__] = cls
    return cls


def _is_namedtuple(x) -> bool:
    return isinstance(x, tuple) and hasattr(x, "_fields")


def _structure_of(tree) -> Dict[str, Any]:
    """JSON-able descriptor of the container skeleton (leaves are opaque)."""
    if isinstance(tree, dict):
        keys = list(tree.keys())
        return {"kind": "dict", "keys": keys,
                "children": [_structure_of(tree[k]) for k in keys]}
    if _is_namedtuple(tree):
        return {"kind": "namedtuple", "class": type(tree).__name__,
                "fields": list(tree._fields),
                "children": [_structure_of(v) for v in tree]}
    if isinstance(tree, (list, tuple)):
        return {"kind": type(tree).__name__,
                "children": [_structure_of(v) for v in tree]}
    return {"kind": "leaf"}


def _rebuild(nested, desc):
    """Reapply a structure descriptor to ``unflatten_dict``'s nested dicts."""
    kind = desc["kind"]
    if kind == "leaf":
        return nested
    if kind == "dict":
        return {k: _rebuild(nested[str(k)], d)
                for k, d in zip(desc["keys"], desc["children"])}
    items = [_rebuild(nested[f"__seq{i}"], d)
             for i, d in enumerate(desc["children"])]
    if kind == "list":
        return items
    if kind == "tuple":
        return tuple(items)
    cls = _STATE_CLASSES.get(desc["class"])
    if cls is None:  # unregistered: a faithful stand-in with the same fields
        cls = namedtuple(desc["class"], desc["fields"])
    return cls(*items)


def save_checkpoint(path: str, params: Any, step: int = 0, extra: Dict | None = None):
    """Atomically commit a checkpoint to directory ``path``.

    A preemption mid-save must leave the previous checkpoint loadable, so the
    save never touches a file the current manifest references: arrays go to a
    fresh step-stamped ``.npz`` (via a temp file + ``os.replace``), and the
    manifest — whose replacement is the single atomic commit point — is
    written last through ``atomic_write_json``. Only after the commit are
    array files from superseded checkpoints pruned (best-effort).
    """
    os.makedirs(path, exist_ok=True)
    leaves = flatten_dict(_to_nested_dict(params))
    arrays = {k: np.asarray(v) for k, v in leaves.items()}
    arrays_file = f"arrays-{int(step):012d}.npz"
    buf = io.BytesIO()
    np.savez(buf, **arrays)  # a file object defeats savez's ".npz" renaming
    tmp = os.path.join(path, arrays_file + f".tmp.{os.getpid()}")
    try:
        with open(tmp, "wb") as f:
            f.write(buf.getvalue())
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(path, arrays_file))
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise
    manifest = {
        "step": int(step),
        "keys": sorted(arrays),
        "extra": extra or {},
        "arrays_file": arrays_file,
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "structure": _structure_of(params),
    }
    atomic_write_json(os.path.join(path, "manifest.json"), manifest)
    for name in os.listdir(path):  # prune superseded/orphaned array files
        if name.startswith("arrays") and name != arrays_file:
            try:
                os.remove(os.path.join(path, name))
            except OSError:
                pass


def load_checkpoint(path: str) -> Tuple[Any, int, Dict]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    # pre-atomic checkpoints recorded no arrays_file and used a fixed name
    arrays_file = manifest.get("arrays_file", "arrays.npz")
    with np.load(os.path.join(path, arrays_file)) as z:
        flat = {k: z[k] for k in manifest["keys"]}
    params = unflatten_dict(flat)
    if "structure" in manifest:  # pre-descriptor checkpoints stay dicts
        params = _rebuild(params, manifest["structure"])
    return params, manifest["step"], manifest.get("extra", {})


def _to_nested_dict(tree):
    """Convert tuples/lists in a pytree to indexed dicts for stable flattening."""
    if isinstance(tree, dict):
        return {str(k): _to_nested_dict(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return {f"__seq{i}": _to_nested_dict(v) for i, v in enumerate(tree)}
    return tree
