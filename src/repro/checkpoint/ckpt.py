"""Minimal dependency-free checkpointing: flattened pytree -> .npz + manifest.

Multi-host note: in a real pod deployment each host saves its addressable
shards under a per-host suffix; here (single-host container) we gather to
host numpy. The format is stable across restarts and tested round-trip.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np

from repro.common.pytree import flatten_dict, unflatten_dict


def save_checkpoint(path: str, params: Any, step: int = 0, extra: Dict | None = None):
    os.makedirs(path, exist_ok=True)
    leaves = flatten_dict(_to_nested_dict(params))
    arrays = {k: np.asarray(v) for k, v in leaves.items()}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {
        "step": int(step),
        "keys": sorted(arrays),
        "extra": extra or {},
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str) -> Tuple[Any, int, Dict]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in manifest["keys"]}
    params = unflatten_dict(flat)
    return params, manifest["step"], manifest.get("extra", {})


def _to_nested_dict(tree):
    """Convert tuples/lists in a pytree to indexed dicts for stable flattening."""
    if isinstance(tree, dict):
        return {str(k): _to_nested_dict(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return {f"__seq{i}": _to_nested_dict(v) for i, v in enumerate(tree)}
    return tree
