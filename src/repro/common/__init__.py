from repro.common import config, pytree, sharding  # noqa: F401
