"""Pallas backend selection (leaf module — safe to import from anywhere).

Compiled Mosaic kernels on TPU, interpret mode elsewhere (interpret executes
the same kernel body for validation). ``REPRO_PALLAS_COMPILED=1/0`` forces
the choice. Lives under ``repro.common`` so model code can consult it
without importing kernel modules (kernels transitively import core/model
code — doing it the other way round is an import cycle).
"""
from __future__ import annotations

import os

import jax


def default_interpret() -> bool:
    """Interpret only off-TPU; ``REPRO_PALLAS_COMPILED=1/0`` forces it."""
    env = os.environ.get("REPRO_PALLAS_COMPILED")
    if env is not None:
        return env != "1"
    return jax.default_backend() != "tpu"
