"""Power-of-two bucket helpers.

Shared by the adaptive controller (interval snapping keeps the per-(P, Q)
executor cache bounded) and the serving engine (batch/cache/block shape
buckets keep the per-bucket executor cache bounded) — one rounding policy,
one place to change it.
"""
from __future__ import annotations


def pow2_floor(n: int) -> int:
    """Largest power of two <= n (n >= 1)."""
    return 1 << max(int(n).bit_length() - 1, 0)


def pow2_ceil(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    n = int(n)
    return 1 if n <= 1 else 1 << (n - 1).bit_length()
