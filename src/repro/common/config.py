"""Config system: dataclass model/arch configs + a string registry + CLI overrides.

Every assigned architecture registers a ``ModelConfig`` under its public id
(e.g. ``gemma3-1b``). Configs are plain frozen dataclasses so they are
hashable and safe to close over in jitted functions.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description covering all 6 assigned families."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm | cnn | lstm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # --- attention flavour ---
    attention: str = "gqa"  # gqa | mla | none
    sliding_window: int = 0  # 0 -> full attention
    local_global_ratio: int = 0  # gemma3: 5 local per 1 global
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) splits
    # --- MLA (deepseek) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- MLP flavour ---
    mlp: str = "swiglu"  # swiglu | geglu | squared_relu | gelu
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # expert hidden size if != d_ff
    first_dense_layers: int = 0  # deepseek: first k layers dense
    # --- SSM (mamba) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 1  # 1 = mamba1 (falcon-mamba), 2 = mamba2 (zamba2)
    ssm_headdim: int = 64  # mamba2 head dim
    hybrid_attn_every: int = 0  # zamba2: shared attention block period
    # --- structure ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0  # stubbed frontend sequence length (whisper frames / ViT patches)
    frontend: str = ""  # "audio" | "vision" stub marker
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = True
    max_seq_len: int = 131072
    dtype: str = "bfloat16"
    # provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs roofline)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        attn = 0
        if self.attention == "gqa" and self.num_heads:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            attn = q + kv + o
        elif self.attention == "mla":
            attn = d * self.q_lora_rank + self.q_lora_rank * self.num_heads * (hd + self.qk_rope_head_dim)
            attn += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            attn += self.kv_lora_rank * self.num_heads * (hd + self.v_head_dim)
            attn += self.num_heads * self.v_head_dim * d
        if self.family != "hybrid":
            per_layer += attn
        if self.family in ("ssm", "hybrid"):
            d_in = self.ssm_expand * d
            N = self.ssm_state
            if self.ssm_version == 1:
                dt_rank = max(1, d // 16)
                per_layer += d * 2 * d_in + d_in * (2 * N + dt_rank) + dt_rank * d_in
            else:
                H = d_in // max(self.ssm_headdim, 1)
                per_layer += d * (2 * d_in + 2 * N + H)
            per_layer += d_in * self.ssm_conv + d_in * d
        if self.num_experts > 0:
            eff = self.moe_d_ff or self.d_ff
            mults = 3 if self.mlp in ("swiglu", "geglu") else 2
            per_layer += self.num_experts * mults * d * eff
            per_layer += self.num_shared_experts * mults * d * eff
            per_layer += d * self.num_experts  # router
        elif self.d_ff > 0 and self.family != "hybrid":
            mults = 3 if self.mlp in ("swiglu", "geglu") else 2
            per_layer += mults * d * self.d_ff
        per_layer += 2 * d  # norms
        total = emb + L * per_layer
        if self.family == "hybrid":
            # shared attention+mlp block: ONE parameter set reused
            mults = 3 if self.mlp in ("swiglu", "geglu") else 2
            total += attn + mults * d * self.d_ff
        if self.is_encoder_decoder:
            # encoder self-attn + decoder cross-attn stacks
            total += self.encoder_layers * per_layer + L * per_layer
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.num_experts == 0:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        eff = self.moe_d_ff or self.d_ff
        mults = 3 if self.mlp in ("swiglu", "geglu") else 2
        dense_moe = self.num_experts * mults * d * eff
        active_moe = (self.experts_per_token + self.num_shared_experts) * mults * d * eff
        return self.param_count() - L * dense_moe + L * active_moe

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_config(name: str, full: Callable[[], ModelConfig], smoke: Callable[[], ModelConfig]):
    _REGISTRY[name] = full
    _SMOKE_REGISTRY[name] = smoke


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers registration)

    reg = _SMOKE_REGISTRY if smoke else _REGISTRY
    if name not in reg:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(reg)}")
    return reg[name]()


def list_configs():
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Federation / training configuration (the paper's hyper-parameters)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FederationConfig:
    """Paper §III: M groups, K_m devices each, sampling fraction alpha."""

    num_groups: int = 10  # M
    devices_per_group: int = 8  # K_m (uniform; paper uses 3458/1468/920)
    alpha: float = 0.25  # fraction of devices sampled into A_m
    local_interval: int = 1  # Q
    global_interval: int = 1  # P  (P = Λ·Q)
    # vertical feature split fraction held by the hospital
    hospital_feature_frac: float = 0.5
    non_iid_labels_per_group: int = 2
    # --- robust aggregation (fault-tolerant federation layer) ---
    # how a screened round combines the surviving device towers in eq. (1):
    # "mean" keeps the masked mean over trusted slots; "median"/"trimmed"
    # use the coordinate-wise robust statistic. Groups whose screening
    # passes always fall back to the existing masked-mean path bit-exactly.
    robust_agg: str = "mean"
    trim_frac: float = 0.1      # per-side trim fraction for "trimmed"
    screen_zmax: float = 8.0    # norm-outlier cut: ||g|| > zmax * median norm

    def __post_init__(self):
        if self.local_interval < 1 or self.global_interval < 1:
            raise ValueError(
                f"intervals must be >= 1, got Q={self.local_interval} P={self.global_interval}")
        if self.global_interval % self.local_interval:
            raise ValueError(
                f"global_interval P={self.global_interval} must be a multiple of "
                f"local_interval Q={self.local_interval} (Λ = P/Q is integral in Alg. 1)")
        if self.robust_agg not in ("mean", "median", "trimmed"):
            raise ValueError(
                f"robust_agg must be mean|median|trimmed, got {self.robust_agg!r}")
        if not 0.0 <= self.trim_frac < 0.5:
            raise ValueError(f"trim_frac must be in [0, 0.5), got {self.trim_frac}")
        if self.screen_zmax <= 1.0:
            raise ValueError(f"screen_zmax must be > 1, got {self.screen_zmax}")

    @property
    def lam(self) -> int:
        return self.global_interval // self.local_interval

    @property
    def sampled_devices(self) -> int:
        return max(1, int(round(self.alpha * self.devices_per_group)))


@dataclass(frozen=True)
class TrainConfig:
    seed: int = 0
    steps: int = 100
    batch_size: int = 32  # per-group mini-batch |ξ_m|
    learning_rate: float = 0.01
    lr_halve_every: int = 0  # T0; 0 disables (paper: decays halved per T0)
    optimizer: str = "sgd"  # sgd | momentum | adam
    weight_decay: float = 0.0
    algorithm: str = "hsgd"  # hsgd | jfl | tdcd | c-hsgd | c-tdcd | centralized
    compression_k: float = 0.0  # top-k fraction for C-* variants (0 = off)
    quantization_bits: int = 0  # b-level quantization (paper: b=128 -> log2(b) bits)
    remat: bool = True


def apply_overrides(cfg, overrides: Dict[str, Any]):
    """Apply ``key=value`` CLI overrides to a dataclass config."""
    valid = {f.name: f.type for f in dataclasses.fields(cfg)}
    kw = {}
    for k, v in overrides.items():
        if k not in valid:
            raise KeyError(f"unknown config field '{k}' for {type(cfg).__name__}")
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            kw[k] = str(v).lower() in ("1", "true", "yes")
        elif isinstance(cur, int):
            kw[k] = int(v)
        elif isinstance(cur, float):
            kw[k] = float(v)
        else:
            kw[k] = v
    return dataclasses.replace(cfg, **kw)


def parse_kv_list(items) -> Dict[str, str]:
    out = {}
    for it in items or []:
        if "=" not in it:
            raise ValueError(f"override must be key=value, got {it!r}")
        k, v = it.split("=", 1)
        out[k] = v
    return out
