"""Crash-safe small-file IO: write-to-temp + ``os.replace`` commit.

A coordinator preemption mid-write must never leave a torn manifest or a
half-serialized ``BENCH_*.json`` behind — ``os.replace`` is atomic on POSIX
(and on Windows for same-volume paths), so readers observe either the old
file or the complete new one, never a prefix. Every JSON/manifest writer in
the repo goes through these helpers (reprolint RP9 flags bare
``open(path, "w")`` writers of such files).
"""
from __future__ import annotations

import json
import os
from typing import Any


def atomic_write_bytes(path: str, payload: bytes) -> None:
    """Write ``payload`` to ``path`` atomically (temp file + ``os.replace``)."""
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def atomic_write_text(path: str, text: str) -> None:
    atomic_write_bytes(path, text.encode("utf-8"))


def atomic_write_json(path: str, obj: Any, indent: int = 1) -> None:
    """Serialize ``obj`` and commit it to ``path`` in one atomic rename."""
    atomic_write_text(path, json.dumps(obj, indent=indent))
