"""Pytree utilities shared across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    """Total number of scalar elements in a pytree of arrays."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays (or ShapeDtypeStructs)."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(a, x, y):
    """a*x + y elementwise over pytrees."""
    return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)


def tree_dot(a, b):
    """Inner product of two pytrees."""
    leaves = jax.tree.map(lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    return sum(jax.tree_util.tree_leaves(leaves))


def tree_norm(tree):
    return jnp.sqrt(tree_dot(tree, tree))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_weighted_mean(trees, weights):
    """Weighted mean of a list of pytrees (paper eq. (2))."""
    wsum = sum(weights)
    out = tree_scale(trees[0], weights[0] / wsum)
    for t, w in zip(trees[1:], weights[1:]):
        out = tree_axpy(w / wsum, t, out)
    return out


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    oks = jax.tree.map(lambda x, y: bool(jnp.allclose(x, y, rtol=rtol, atol=atol)), a, b)
    return all(jax.tree_util.tree_leaves(oks))


def tree_has_nan(tree) -> bool:
    bad = jax.tree.map(lambda x: bool(jnp.any(jnp.isnan(x))), tree)
    return any(jax.tree_util.tree_leaves(bad))


def flatten_dict(d: dict, prefix: str = "", sep: str = "/") -> dict:
    out = {}
    for k, v in d.items():
        key = f"{prefix}{sep}{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(flatten_dict(v, key, sep))
        else:
            out[key] = v
    return out


def unflatten_dict(flat: dict, sep: str = "/") -> dict:
    out: dict = {}
    for k, v in flat.items():
        parts = k.split(sep)
        cur = out
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return out
