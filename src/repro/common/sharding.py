"""Sharding helpers: logical-axis rules -> NamedSharding, plus mesh-aware utils.

We use a MaxText-style logical axis annotation scheme: every parameter and
activation is tagged with logical axis names; a rule table maps logical names
to mesh axes. Changing the sharding scheme (e.g. during §Perf hillclimbing)
means swapping the rule table, not touching model code.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default logical->physical rules for the production mesh.
# "data" carries the horizontal (group) partition of the paper;
# "model" carries the vertical partition + tensor parallelism;
# "pod" is the second horizontal tier (multi-pod).
DEFAULT_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "group": ("pod", "data"),
    # FSDP: parameter d_model dims shard over "data"; activations tag "batch"
    # first so the duplicate-axis filter keeps activations data-sharded on
    # batch while parameters ZeRO-shard on embed. NOT sharded over "pod" —
    # each pod holds its own HSGD local model replica (see DESIGN §2).
    "embed": ("data",),
    "seq": None,
    "cache_seq": ("model",),  # decode KV caches shard their length over model
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "vocab": ("model",),
    "experts": ("model",),
    "expert_tokens": ("data",),
    "expert_mlp": None,
    "ssm_inner": ("model",),
    "ssm_state": None,
    "conv": None,
    "device_slot": None,  # tier-1 vmapped devices stay local
    "pod_group": ("pod",),  # per-pod HSGD local-model replicas (leading G dim)
    "pod_batch": ("pod", "data"),  # inference batch scale-out across pods
    "stack": None,  # scan-stacked layer dimension
}

# Fully-replicated-model variant (pure data parallel) for small models.
DP_ONLY_RULES: Dict[str, Optional[Tuple[str, ...]]] = {k: None for k in DEFAULT_RULES}
DP_ONLY_RULES["batch"] = ("pod", "data", "model")
DP_ONLY_RULES["group"] = ("pod", "data", "model")


def logical_to_spec(axes: Sequence[Optional[str]], rules=None, mesh: Optional[Mesh] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec via the rules."""
    rules = rules or DEFAULT_RULES
    mesh_axes = set(mesh.axis_names) if mesh is not None else None
    spec = []
    used = set()
    for ax in axes:
        if ax is None:
            spec.append(None)
            continue
        phys = rules.get(ax)
        if phys is None:
            spec.append(None)
            continue
        if mesh_axes is not None:
            phys = tuple(p for p in phys if p in mesh_axes)
        phys = tuple(p for p in phys if p not in used)
        used.update(phys)
        if not phys:
            spec.append(None)
        elif len(phys) == 1:
            spec.append(phys[0])
        else:
            spec.append(phys)
    return P(*spec)


def shard_tree(tree_axes, mesh: Mesh, rules=None):
    """Map a pytree of logical-axis tuples to a pytree of NamedShardings."""
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, logical_to_spec(axes, rules, mesh)),
        tree_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def mesh_context(mesh: Mesh):
    """``jax.set_mesh(mesh)`` where available; older jax (< 0.5) falls back
    to the ``Mesh`` context manager. Use for every ``with <mesh>:`` block so
    lowering code runs across jax versions."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def group_sharding(shape, mesh: Mesh, rules=None) -> NamedSharding:
    """NamedSharding putting a leading group axis M on the mesh's horizontal
    axes (logical "group" rule), everything else replicated.

    Used to shard HSGDState / federated data leaves ([M, ...]) so eq. (1)/(2)
    aggregations lower to collectives. Falls back to full replication when
    the leading dim does not divide the mesh axes (trivial-mesh path).
    """
    axes = ("group",) + (None,) * (max(len(shape), 1) - 1)
    spec = logical_to_spec(axes[: len(shape)], rules, mesh)
    spec = divisible_spec(shape, spec, mesh)
    return NamedSharding(mesh, spec)


def divisible_spec(shape, spec: P, mesh: Mesh) -> P:
    """Drop mesh axes from a spec wherever the dim is not divisible.

    Keeps dry-runs robust when a reduced config's dim < mesh axis size.
    """
    new = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            new.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        new.append(entry if dim % size == 0 and dim >= size else None)
    return P(*new)


def constrain(x, axes, rules=None):
    """with_sharding_constraint by logical axes (no-op outside a mesh ctx).

    Mesh- and shape-aware: absent mesh axes are filtered (not the whole
    entry), non-divisible dims are left unconstrained, and a rank mismatch
    is a silent no-op (some call sites see flattened tensors).
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:  # pragma: no cover
            return x
    except Exception:  # pragma: no cover
        return x
    if len(axes) != x.ndim:
        return x
    rules = rules or DEFAULT_RULES
    names = set(mesh.axis_names)
    entries = []
    used = set()
    for dim, ax in zip(x.shape, axes):
        phys = rules.get(ax) if ax is not None else None
        if phys is None:
            entries.append(None)
            continue
        phys = tuple(p for p in phys if p in names and p not in used)
        size = 1
        for p in phys:
            size *= mesh.shape[p]
        if not phys or size == 1 or dim % size != 0:
            entries.append(None)
            continue
        used.update(phys)
        entries.append(phys if len(phys) > 1 else phys[0])
    return jax.lax.with_sharding_constraint(x, P(*entries))


def _axes_in(mesh, entry) -> bool:
    names = set(mesh.axis_names)
    axes = entry if isinstance(entry, tuple) else (entry,)
    return all(a in names for a in axes)


import contextlib

_WEIGHT_MODE = "gather"


@contextlib.contextmanager
def weight_mode(mode: str):
    """'gather' (train/prefill: ZeRO-3 gather-at-use) or 'fsdp' (decode:
    activations are tiny, so leave weights sharded and let XLA compute
    partial matmuls + reduce — §Perf iteration 2)."""
    global _WEIGHT_MODE
    prev = _WEIGHT_MODE
    _WEIGHT_MODE = mode
    try:
        yield
    finally:
        _WEIGHT_MODE = prev


def use_weight(w, axes, rules=None):
    if _WEIGHT_MODE == "fsdp":
        return w
    """ZeRO-3 weight use: parameters are STORED FSDP-sharded over "data"
    (their 'embed'-like dims), but at their use site we constrain them to the
    gathered layout (data dropped, tensor-parallel axes kept). XLA then emits
    one small weight all-gather per step instead of re-sharding activations —
    the difference between 100s-of-GB activation all-gathers and MB-scale
    weight gathers (see DESIGN §Perf iteration 0).
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:  # pragma: no cover
            return w
    except Exception:  # pragma: no cover
        return w
    rules = rules or DEFAULT_RULES
    names = set(mesh.axis_names)
    entries = []
    used = set()
    for dim, ax in zip(w.shape, axes):
        phys = rules.get(ax) if ax is not None else None
        if phys is None:
            entries.append(None)
            continue
        phys = tuple(p for p in phys if p != "data" and p in names and p not in used)
        size = 1
        for p in phys:
            size *= mesh.shape[p]
        if not phys or size == 1 or dim % size != 0:
            entries.append(None)
            continue
        used.update(phys)
        entries.append(phys if len(phys) > 1 else phys[0])
    return jax.lax.with_sharding_constraint(w, P(*entries))
