"""Architecture registry: import every config module to register it."""
from repro.configs import (  # noqa: F401
    deepseek_v3_671b,
    falcon_mamba_7b,
    gemma3_1b,
    gemma3_4b,
    grok_1_314b,
    nemotron_4_15b,
    paper_models,
    qwen2_vl_72b,
    stablelm_1_6b,
    whisper_medium,
    zamba2_2_7b,
)

ASSIGNED = [
    "gemma3-1b", "zamba2-2.7b", "falcon-mamba-7b", "whisper-medium",
    "stablelm-1.6b", "nemotron-4-15b", "deepseek-v3-671b", "grok-1-314b",
    "qwen2-vl-72b", "gemma3-4b",
]
