"""deepseek-v3-671b [moe] — 61L d=7168 128H MLA, 1 shared + 256 routed top-8
experts (moe ff=2048), V=129280, first 3 layers dense. [arXiv:2412.19437]

MLA dims per the paper: q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64,
v_head 128. MTP (multi-token prediction) is exposed via the serve path's
speculative hooks but not part of the dry-run step.
"""
from repro.common.config import ModelConfig, register_config


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe", num_layers=61, d_model=7168,
        num_heads=128, num_kv_heads=128, head_dim=128, d_ff=18432,
        vocab_size=129280, attention="mla",
        q_lora_rank=1536, kv_lora_rank=512, qk_rope_head_dim=64, v_head_dim=128,
        num_experts=256, num_shared_experts=1, experts_per_token=8,
        moe_d_ff=2048, first_dense_layers=3, mlp="swiglu",
        tie_embeddings=False, source="arXiv:2412.19437",
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=3, d_model=128, num_heads=4, num_kv_heads=4,
                          head_dim=32, q_lora_rank=32, kv_lora_rank=32,
                          qk_rope_head_dim=16, v_head_dim=32, d_ff=256,
                          vocab_size=512, num_experts=4, experts_per_token=2,
                          moe_d_ff=64, first_dense_layers=1)


register_config("deepseek-v3-671b", full, smoke)
