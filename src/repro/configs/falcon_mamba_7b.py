"""falcon-mamba-7b [ssm] — 64L d=4096 attention-free Mamba-1, V=65024,
ssm_state=16. [arXiv:2410.05355]"""
from repro.common.config import ModelConfig, register_config


def full() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", family="ssm", num_layers=64, d_model=4096,
        num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=65024,
        attention="none", ssm_state=16, ssm_version=1, ssm_expand=2, ssm_conv=4,
        source="arXiv:2410.05355",
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=128, vocab_size=512, ssm_state=8)


register_config("falcon-mamba-7b", full, smoke)
