"""gemma3-1b [dense] — 26L d=1152 4H (GQA kv=1) ff=6912 V=262144;
5:1 local:global attention, 128k context. [hf:google/gemma-3-1b-pt]"""
from repro.common.config import ModelConfig, register_config


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense", num_layers=26, d_model=1152,
        num_heads=4, num_kv_heads=1, head_dim=256, d_ff=6912, vocab_size=262144,
        sliding_window=1024, local_global_ratio=5, qk_norm=True,
        rope_theta=1_000_000.0, mlp="geglu", max_seq_len=131072,
        source="hf:google/gemma-3-1b-pt",
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=128, num_heads=4, num_kv_heads=1,
                          head_dim=32, d_ff=256, vocab_size=512, sliding_window=32)


register_config("gemma3-1b", full, smoke)
