"""gemma3-4b [dense] — 34L d=2560 8H (GQA kv=4) ff=10240 V=262144;
5:1 local:global, 128k. [hf:google/gemma-3-1b-pt]"""
from repro.common.config import ModelConfig, register_config


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", family="dense", num_layers=34, d_model=2560,
        num_heads=8, num_kv_heads=4, head_dim=256, d_ff=10240, vocab_size=262144,
        sliding_window=1024, local_global_ratio=5, qk_norm=True,
        rope_theta=1_000_000.0, mlp="geglu", max_seq_len=131072,
        source="hf:google/gemma-3-1b-pt",
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                          head_dim=32, d_ff=256, vocab_size=512, sliding_window=32)


register_config("gemma3-4b", full, smoke)
