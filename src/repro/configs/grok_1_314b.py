"""grok-1-314b [moe] — 64L d=6144 48H (GQA kv=8) ff=32768, 8 experts top-2,
V=131072. [hf:xai-org/grok-1]"""
from repro.common.config import ModelConfig, register_config


def full() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe", num_layers=64, d_model=6144,
        num_heads=48, num_kv_heads=8, d_ff=32768, vocab_size=131072,
        num_experts=8, experts_per_token=2, moe_d_ff=32768,
        mlp="geglu", tie_embeddings=False, source="hf:xai-org/grok-1",
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                          d_ff=256, vocab_size=512, num_experts=4,
                          experts_per_token=2, moe_d_ff=256)


register_config("grok-1-314b", full, smoke)
