"""nemotron-4-15b [dense] — 32L d=6144 48H (GQA kv=8) ff=24576 V=256000;
squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.common.config import ModelConfig, register_config


def full() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense", num_layers=32, d_model=6144,
        num_heads=48, num_kv_heads=8, d_ff=24576, vocab_size=256000,
        mlp="squared_relu", norm="layernorm", rope_theta=10000.0,
        tie_embeddings=False, source="arXiv:2402.16819",
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=192, num_heads=6, num_kv_heads=2,
                          d_ff=384, vocab_size=512)


register_config("nemotron-4-15b", full, smoke)
