"""The paper's own e-health models (Fig. 10): CNN for OrganAMNIST,
LSTM for MIMIC-III and ESR, as hybrid-split configs."""
from repro.common.config import ModelConfig, register_config


def paper_cnn() -> ModelConfig:
    return ModelConfig(
        name="paper-cnn", family="cnn", num_layers=2, d_model=64, num_heads=0,
        num_kv_heads=0, d_ff=128, vocab_size=11, source="paper Fig. 10",
    )


def paper_lstm() -> ModelConfig:
    return ModelConfig(
        name="paper-lstm", family="lstm", num_layers=1, d_model=64, num_heads=0,
        num_kv_heads=0, d_ff=128, vocab_size=2, source="paper Fig. 10",
    )


register_config("paper-cnn", paper_cnn, paper_cnn)
register_config("paper-lstm", paper_lstm, paper_lstm)
