"""qwen2-vl-72b [vlm] — 80L d=8192 64H (GQA kv=8) ff=29568 V=152064; M-RoPE
(sections 16/24/24 of head_dim/2=64); ViT frontend STUBBED (input_specs feeds
patch embeddings). [arXiv:2409.12191]"""
from repro.common.config import ModelConfig, register_config


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm", num_layers=80, d_model=8192,
        num_heads=64, num_kv_heads=8, d_ff=29568, vocab_size=152064,
        mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
        mlp="swiglu", tie_embeddings=False, frontend="vision",
        source="arXiv:2409.12191",
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                          d_ff=256, vocab_size=512, mrope_sections=(8, 4, 4))


register_config("qwen2-vl-72b", full, smoke)
