"""stablelm-1.6b [dense] — 24L d=2048 32H (GQA kv=32) ff=5632 V=100352.
[hf:stabilityai/stablelm-2-1_6b]"""
from repro.common.config import ModelConfig, register_config


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense", num_layers=24, d_model=2048,
        num_heads=32, num_kv_heads=32, d_ff=5632, vocab_size=100352,
        mlp="swiglu", norm="layernorm", rope_theta=10000.0,
        source="hf:stabilityai/stablelm-2-1_6b",
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, d_model=128, num_heads=8, num_kv_heads=8,
                          d_ff=256, vocab_size=512)


register_config("stablelm-1.6b", full, smoke)
