"""whisper-medium [audio] — enc-dec 24L(+24 enc) d=1024 16H (kv=16) ff=4096
V=51865; conv/mel frontend STUBBED (input_specs feeds 1500 frame embeddings).
[arXiv:2212.04356]"""
from repro.common.config import ModelConfig, register_config


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="audio", num_layers=24, d_model=1024,
        num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=51865,
        mlp="gelu", norm="layernorm", is_encoder_decoder=True,
        encoder_layers=24, encoder_seq=1500, frontend="audio",
        tie_embeddings=True, source="arXiv:2212.04356",
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=2, encoder_layers=2, encoder_seq=16,
                          d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
                          vocab_size=512)


register_config("whisper-medium", full, smoke)
