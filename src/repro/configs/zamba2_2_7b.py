"""zamba2-2.7b [hybrid] — 54L d=2560 Mamba2 blocks + shared attention block
(32H kv=32, ff=10240 in the shared block) V=32000, ssm_state=64.
[arXiv:2411.15242]"""
from repro.common.config import ModelConfig, register_config


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
        num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=32000,
        ssm_state=64, ssm_version=2, ssm_headdim=64, ssm_expand=2,
        hybrid_attn_every=6, sliding_window=2048,
        source="arXiv:2411.15242",
    )


def smoke() -> ModelConfig:
    return full().replace(num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
                          d_ff=256, vocab_size=512, ssm_state=16, ssm_headdim=32,
                          hybrid_attn_every=2, sliding_window=32)


register_config("zamba2-2.7b", full, smoke)
