"""The paper's contribution: hybrid federated learning (HSGD) + strategies."""
from repro.core.hsgd import HSGDRunner, HSGDState, init_state, make_group_weights  # noqa: F401
from repro.core.baselines import JFLRunner, make_runner, merge_groups_for_tdcd  # noqa: F401
from repro.core.controller import AdaptiveConfig, AdaptiveHSGDRunner, plan_round  # noqa: F401
