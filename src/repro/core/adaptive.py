"""Adaptive strategies 1–3 (paper §VI) + the ρ/δ pre-training probes.

Theorem 1 (eq. 17):  with η ≤ 1/(8Pρ),
  E[ (1/R) Σ ||∇F(θ̃^{rP})||² ] ≤ 4(F(θ̃⁰) − F*)/(ηT) + 12Pρηδ² + 96Q²ρ²η²δ²

Strategy 1: minimum communication for a target bound Ξ is at Λ = P/Q = 1.
Strategy 2: P* = Q* = sqrt( F(θ̃⁰) / (24 ρ² η² δ² T) )   (E[F(θ̃^T)] ≈ 0).
Strategy 3: η* = min(η₂, 1/(8Pρ)) with η₂ the positive root of
  3aη² + 2bη − c = 0,  a = 24Q²Pρ²δ², b = 3P²ρδ², c = (P/4)||∇F||²;
  η* decreases when P grows (Q fixed) and when Q grows (P/Q fixed).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FederationConfig
from repro.common.pytree import tree_dot, tree_norm, tree_sub
from repro.core import federation as F
from repro.models.split_model import HybridModel


# ---------------------------------------------------------------------------
# Theorem 1
# ---------------------------------------------------------------------------


def convergence_bound(F0: float, FT: float, rho: float, delta: float,
                      eta: float, P: int, Q: int, T: int) -> float:
    """The right-hand side Γ(P,Q) of eq. (17)."""
    return 4.0 * (F0 - FT) / (eta * T) + 12.0 * P * rho * eta * delta**2 \
        + 96.0 * Q**2 * rho**2 * eta**2 * delta**2


def max_learning_rate(P: int, rho: float) -> float:
    """Theorem 1's step-size condition η ≤ 1/(8Pρ)."""
    return 1.0 / (8.0 * P * rho)


# ---------------------------------------------------------------------------
# Strategy 1 — P = Q
# ---------------------------------------------------------------------------


def strategy1_lambda_lower_bound(F0: float, FT: float, rho: float, delta: float,
                                 eta: float, P: int, T: int, target: float) -> float:
    """Λ ≥ 4√6·Pρηδ / sqrt(Ξ − 4(F0−FT)/(ηT) − 12Pρηδ²)  (Prop. 1)."""
    denom_sq = target - 4.0 * (F0 - FT) / (eta * T) - 12.0 * P * rho * eta * delta**2
    if denom_sq <= 0:
        return math.inf  # target unreachable at this P/η
    return 4.0 * math.sqrt(6.0) * P * rho * eta * delta / math.sqrt(denom_sq)


def strategy1_intervals(Q: int) -> Tuple[int, int]:
    """Adaptive strategy 1: set P = Q."""
    return Q, Q


# ---------------------------------------------------------------------------
# Strategy 2 — optimal P = Q
# ---------------------------------------------------------------------------


def strategy2_optimal_interval(F0: float, rho: float, delta: float, eta: float, T: int,
                               FT: float = 0.0) -> int:
    """P* = Q* = sqrt((F0 − E[F_T]) / (24 ρ² η² δ² T)), E[F_T] approximated by 0."""
    q = math.sqrt(max(F0 - FT, 1e-12) / (24.0 * rho**2 * eta**2 * delta**2 * T))
    return max(1, int(round(q)))


# ---------------------------------------------------------------------------
# Strategy 3 — learning-rate adjustment
# ---------------------------------------------------------------------------


def strategy3_learning_rate(P: int, Q: int, rho: float, delta: float,
                            grad_norm_sq: float) -> float:
    """η* = min(η₂, 1/(8Pρ)) from Prop. 3."""
    a = 24.0 * Q**2 * P * rho**2 * delta**2
    b = 3.0 * P**2 * rho * delta**2
    c = (P / 4.0) * grad_norm_sq
    if a <= 0:
        return max_learning_rate(P, rho)
    eta2 = (-2.0 * b + math.sqrt(4.0 * b**2 + 12.0 * a * c)) / (6.0 * a)
    return min(eta2, max_learning_rate(P, rho))


# ---------------------------------------------------------------------------
# ρ / δ estimation probes (pre-training, §VI-B "small number of pre-training")
# ---------------------------------------------------------------------------


def estimate_rho_delta(
    model: HybridModel,
    params,
    data: Dict[str, jnp.ndarray],
    key,
    n_probes: int = 8,
    n_perturb: int = 4,
    batch: int = 32,
    perturb: float = 1e-2,
) -> Dict[str, float]:
    """Estimate the Lipschitz constant ρ and gradient noise δ of Assumptions 1–2.

    δ²: variance of mini-batch gradients around their mean.
    ρ : max ||∇F(θ+u) − ∇F(θ)|| / ||u|| over random perturbations u.
    Returns also F0 (initial loss) for strategies 1–2.

    The whole probe is ONE jitted call: the n_probes mini-batch gradients and
    the n_perturb Lipschitz secants are vmapped over their PRNG keys instead
    of looped in Python, so the probe costs a single compile + dispatch. Batch
    sizes are clamped to the M*K available samples (``jax.random.choice(...,
    replace=False)`` raises beyond that).
    """
    M, K = data["y"].shape[:2]
    total = M * K
    batch = int(min(batch, total))
    lip_batch = int(min(4 * batch, total))
    x1 = data["x1"].reshape((total,) + data["x1"].shape[2:])
    x2 = data["x2"].reshape((total,) + data["x2"].shape[2:])
    y = data["y"].reshape(-1)

    loss_fn = lambda p, a, b, yy: model.full_loss(p, a, b, yy)

    @jax.jit
    def probe(params, x1, x2, y, key):
        k_noise, k_lip, k_pert = jax.random.split(key, 3)

        def batch_grad(k):
            idx = jax.random.choice(k, total, (batch,), replace=False)
            return jax.grad(loss_fn)(params, x1[idx], x2[idx], y[idx])

        grads = jax.vmap(batch_grad)(jax.random.split(k_noise, n_probes))
        mean_grad = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        dev = jax.tree.map(
            lambda g, m: jnp.sum((g - m[None]) ** 2, axis=tuple(range(1, g.ndim))),
            grads, mean_grad)
        delta2 = jnp.mean(sum(jax.tree_util.tree_leaves(dev)))

        # Lipschitz secants on a full-batch-ish gradient, vmapped over the
        # perturbation keys (one batched backward instead of a Python loop)
        idx = jax.random.choice(k_lip, total, (lip_batch,), replace=False)
        xb1, xb2, yb = x1[idx], x2[idx], y[idx]
        g_base = jax.grad(loss_fn)(params, xb1, xb2, yb)
        leaves, treedef = jax.tree_util.tree_flatten(params)

        def secant(k):
            ks = jax.random.split(k, len(leaves))
            u = jax.tree_util.tree_unflatten(
                treedef,
                [perturb * jax.random.normal(kk, p.shape, p.dtype)
                 for kk, p in zip(ks, leaves)])
            g2 = jax.grad(loss_fn)(jax.tree.map(jnp.add, params, u), xb1, xb2, yb)
            return tree_norm(tree_sub(g2, g_base)) / jnp.maximum(tree_norm(u), 1e-12)

        rho = jnp.max(jax.vmap(secant)(jax.random.split(k_pert, n_perturb)))
        F0 = loss_fn(params, xb1, xb2, yb)
        gnorm2 = tree_dot(g_base, g_base)
        return rho, delta2, F0, gnorm2

    rho, delta2, F0, gnorm2 = jax.device_get(probe(params, x1, x2, y, key))
    return {"rho": float(rho), "delta": math.sqrt(max(float(delta2), 1e-12)),
            "F0": float(F0), "grad_norm_sq": float(gnorm2)}


def recommend_settings(probe: Dict[str, float], T: int, eta: float,
                       fed: FederationConfig) -> Dict[str, float]:
    """One-stop application of the three strategies."""
    rho, delta, F0 = probe["rho"], probe["delta"], probe["F0"]
    Pstar = strategy2_optimal_interval(F0, rho, delta, eta, T)
    eta_star = strategy3_learning_rate(Pstar, Pstar, rho, delta, probe["grad_norm_sq"])
    return {
        "P": Pstar,
        "Q": Pstar,  # strategy 1
        "eta": eta_star,
        "eta_max": max_learning_rate(Pstar, rho),
        "bound_at_star": convergence_bound(F0, 0.0, rho, delta, eta_star, Pstar, Pstar, T),
    }
