"""Adaptive strategies 1–3 (paper §VI) + the ρ/δ pre-training probes.

Theorem 1 (eq. 17):  with η ≤ 1/(8Pρ),
  E[ (1/R) Σ ||∇F(θ̃^{rP})||² ] ≤ 4(F(θ̃⁰) − F*)/(ηT) + 12Pρηδ² + 96Q²ρ²η²δ²

Strategy 1: minimum communication for a target bound Ξ is at Λ = P/Q = 1.
Strategy 2: P* = Q* = sqrt( F(θ̃⁰) / (24 ρ² η² δ² T) )   (E[F(θ̃^T)] ≈ 0).
Strategy 3: η* = min(η₂, 1/(8Pρ)) with η₂ the positive root of
  3aη² + 2bη − c = 0,  a = 24Q²Pρ²δ², b = 3P²ρδ², c = (P/4)||∇F||²;
  η* decreases when P grows (Q fixed) and when Q grows (P/Q fixed).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FederationConfig
from repro.common.pytree import tree_dot, tree_norm, tree_sub
from repro.core import federation as F
from repro.models.split_model import HybridModel


# ---------------------------------------------------------------------------
# Theorem 1
# ---------------------------------------------------------------------------


def convergence_bound(F0: float, FT: float, rho: float, delta: float,
                      eta: float, P: int, Q: int, T: int) -> float:
    """The right-hand side Γ(P,Q) of eq. (17)."""
    return 4.0 * (F0 - FT) / (eta * T) + 12.0 * P * rho * eta * delta**2 \
        + 96.0 * Q**2 * rho**2 * eta**2 * delta**2


def max_learning_rate(P: int, rho: float) -> float:
    """Theorem 1's step-size condition η ≤ 1/(8Pρ)."""
    return 1.0 / (8.0 * P * rho)


# ---------------------------------------------------------------------------
# Strategy 1 — P = Q
# ---------------------------------------------------------------------------


def strategy1_lambda_lower_bound(F0: float, FT: float, rho: float, delta: float,
                                 eta: float, P: int, T: int, target: float) -> float:
    """Λ ≥ 4√6·Pρηδ / sqrt(Ξ − 4(F0−FT)/(ηT) − 12Pρηδ²)  (Prop. 1)."""
    denom_sq = target - 4.0 * (F0 - FT) / (eta * T) - 12.0 * P * rho * eta * delta**2
    if denom_sq <= 0:
        return math.inf  # target unreachable at this P/η
    return 4.0 * math.sqrt(6.0) * P * rho * eta * delta / math.sqrt(denom_sq)


def strategy1_intervals(Q: int) -> Tuple[int, int]:
    """Adaptive strategy 1: set P = Q."""
    return Q, Q


# ---------------------------------------------------------------------------
# Strategy 2 — optimal P = Q
# ---------------------------------------------------------------------------


def strategy2_optimal_interval(F0: float, rho: float, delta: float, eta: float, T: int,
                               FT: float = 0.0) -> int:
    """P* = Q* = sqrt((F0 − E[F_T]) / (24 ρ² η² δ² T)), E[F_T] approximated by 0."""
    q = math.sqrt(max(F0 - FT, 1e-12) / (24.0 * rho**2 * eta**2 * delta**2 * T))
    return max(1, int(round(q)))


# ---------------------------------------------------------------------------
# Strategy 3 — learning-rate adjustment
# ---------------------------------------------------------------------------


def strategy3_learning_rate(P: int, Q: int, rho: float, delta: float,
                            grad_norm_sq: float) -> float:
    """η* = min(η₂, 1/(8Pρ)) from Prop. 3."""
    a = 24.0 * Q**2 * P * rho**2 * delta**2
    b = 3.0 * P**2 * rho * delta**2
    c = (P / 4.0) * grad_norm_sq
    if a <= 0:
        return max_learning_rate(P, rho)
    eta2 = (-2.0 * b + math.sqrt(4.0 * b**2 + 12.0 * a * c)) / (6.0 * a)
    return min(eta2, max_learning_rate(P, rho))


# ---------------------------------------------------------------------------
# ρ / δ estimation probes (pre-training, §VI-B "small number of pre-training")
# ---------------------------------------------------------------------------


def estimate_rho_delta(
    model: HybridModel,
    params,
    data: Dict[str, jnp.ndarray],
    key,
    n_probes: int = 8,
    batch: int = 32,
    perturb: float = 1e-2,
) -> Dict[str, float]:
    """Estimate the Lipschitz constant ρ and gradient noise δ of Assumptions 1–2.

    δ²: variance of mini-batch gradients around their mean.
    ρ : max ||∇F(θ+u) − ∇F(θ)|| / ||u|| over random perturbations u.
    Returns also F0 (initial loss) for strategies 1–2.
    """
    M, K = data["y"].shape[:2]
    x1 = data["x1"].reshape((M * K,) + data["x1"].shape[2:])
    x2 = data["x2"].reshape((M * K,) + data["x2"].shape[2:])
    y = data["y"].reshape(-1)

    loss_fn = lambda p, a, b, yy: model.full_loss(p, a, b, yy)
    grad_fn = jax.jit(jax.grad(loss_fn))
    val_fn = jax.jit(loss_fn)

    keys = jax.random.split(key, n_probes + 1)
    grads = []
    for i in range(n_probes):
        idx = jax.random.choice(keys[i], M * K, (batch,), replace=False)
        grads.append(grad_fn(params, x1[idx], x2[idx], y[idx]))
    mean_grad = jax.tree.map(lambda *xs: sum(xs) / len(xs), *grads)
    dev = [tree_dot(tree_sub(g, mean_grad), tree_sub(g, mean_grad)) for g in grads]
    delta2 = float(sum(dev) / len(dev))

    # Lipschitz probe on the full-batch-ish gradient
    idx = jax.random.choice(keys[-1], M * K, (min(4 * batch, M * K),), replace=False)
    g_base = grad_fn(params, x1[idx], x2[idx], y[idx])
    rho_max = 0.0
    for i in range(4):
        k = jax.random.fold_in(keys[-1], i)
        leaves, treedef = jax.tree_util.tree_flatten(params)
        ks = jax.random.split(k, len(leaves))
        u = jax.tree_util.tree_unflatten(
            treedef, [perturb * jax.random.normal(kk, p.shape, p.dtype) for kk, p in zip(ks, leaves)]
        )
        p2 = jax.tree.map(jnp.add, params, u)
        g2 = grad_fn(p2, x1[idx], x2[idx], y[idx])
        num = float(tree_norm(tree_sub(g2, g_base)))
        den = float(tree_norm(u))
        rho_max = max(rho_max, num / max(den, 1e-12))

    F0 = float(val_fn(params, x1[: 4 * batch], x2[: 4 * batch], y[: 4 * batch]))
    gnorm2 = float(tree_dot(g_base, g_base))
    return {"rho": rho_max, "delta": math.sqrt(max(delta2, 1e-12)), "F0": F0,
            "grad_norm_sq": gnorm2}


def recommend_settings(probe: Dict[str, float], T: int, eta: float,
                       fed: FederationConfig) -> Dict[str, float]:
    """One-stop application of the three strategies."""
    rho, delta, F0 = probe["rho"], probe["delta"], probe["F0"]
    Pstar = strategy2_optimal_interval(F0, rho, delta, eta, T)
    eta_star = strategy3_learning_rate(Pstar, Pstar, rho, delta, probe["grad_norm_sq"])
    return {
        "P": Pstar,
        "Q": Pstar,  # strategy 1
        "eta": eta_star,
        "eta_max": max_learning_rate(Pstar, rho),
        "bound_at_star": convergence_bound(F0, 0.0, rho, delta, eta_star, Pstar, Pstar, T),
    }
