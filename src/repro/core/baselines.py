"""Baselines of §VII-A1.

* JFL  (Yu et al. 2022): VFL per (device, hospital) pair — NO local
  aggregation, so every sampled device owns a full private (θ0,θ1,θ2) triple
  and the hospital trains a unique model per device; global aggregation over
  all pairs every P steps.
* TDCD (Das et al.): two-tier — NO global aggregation. Raw data of all groups
  is merged into a single group first (the paper charges this raw-data
  transmission to TDCD's communication bill); then the HSGD machinery runs
  with M=1 and the global phase disabled.
* C-HSGD / C-TDCD: the respective algorithm with top-k + b-level quantization
  applied to the exchanged messages (core/compression.py).
* Centralized SGD: reference upper bound used in tests (== HSGD with
  M=1, α=1, P=Q=1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FederationConfig, TrainConfig
from repro.core import federation as F
from repro.core.hsgd import HSGDRunner, HSGDState, init_state
from repro.models.split_model import HybridModel
from repro.optim import halving_schedule


# ---------------------------------------------------------------------------
# JFL
# ---------------------------------------------------------------------------


class JFLState(NamedTuple):
    params: Dict[str, Any]  # each leaf [M, A, ...] — unique model per pair
    key: jnp.ndarray
    step: jnp.ndarray


@dataclass(frozen=True)
class JFLRunner:
    model: HybridModel
    fed: FederationConfig
    train: TrainConfig

    def init(self, key, dtype=jnp.float32) -> JFLState:
        k_init, k_run = jax.random.split(key)
        p = self.model.init(k_init, dtype)
        M, A = self.fed.num_groups, self.fed.sampled_devices

        def rep(x):
            return jnp.broadcast_to(x[None, None], (M, A) + x.shape)

        return JFLState(jax.tree.map(rep, p), k_run, jnp.zeros((), jnp.int32))

    def _pair_loss(self, p, x1_n, x2_n, y_n):
        return self.model.full_loss(p, x1_n[None], x2_n[None], y_n[None])

    def run(self, state: JFLState, data, group_weights, rounds: int):
        fed, train = self.fed, self.train
        P = fed.global_interval
        lr_fn = halving_schedule(train.learning_rate, train.lr_halve_every)
        grad_fn = jax.grad(self._pair_loss)

        @jax.jit
        def go(state, data, group_weights):
            def round_body(state, _):
                # global aggregation over ALL pairs (weighted by group size)
                w = group_weights / jnp.sum(group_weights)

                def agg(x):
                    wb = w.reshape((-1,) + (1,) * (x.ndim - 2)).astype(x.dtype)
                    g = jnp.sum(jnp.mean(x, axis=1) * wb, axis=0)
                    return jnp.broadcast_to(g[None, None], x.shape)

                params = jax.tree.map(agg, state.params)
                key, k_s = jax.random.split(state.key)
                idx = F.sample_participants(k_s, fed)
                batch = F.gather_batch(data, idx)

                def sgd(carry, _):
                    params, step = carry
                    lr = lr_fn(step)
                    g = jax.vmap(jax.vmap(grad_fn))(params, batch["x1"], batch["x2"], batch["y"])
                    loss = jax.vmap(jax.vmap(self._pair_loss))(params, batch["x1"], batch["x2"], batch["y"])
                    params = jax.tree.map(lambda p_, g_: p_ - lr * g_.astype(p_.dtype), params, g)
                    return (params, step + 1), jnp.mean(loss)

                (params, step), losses = jax.lax.scan(sgd, (params, state.step), None, length=P)
                return JFLState(params, key, step), losses

            state, losses = jax.lax.scan(round_body, state, None, length=rounds)
            return state, losses.reshape(-1)

        return go(state, data, group_weights)

    def global_model(self, state: JFLState, group_weights):
        w = group_weights / jnp.sum(group_weights)

        def agg(x):
            wb = w.reshape((-1,) + (1,) * (x.ndim - 2)).astype(x.dtype)
            return jnp.sum(jnp.mean(x, axis=1) * wb, axis=0)

        return jax.tree.map(agg, state.params)


# ---------------------------------------------------------------------------
# TDCD: merged two-tier run
# ---------------------------------------------------------------------------


def merge_groups_for_tdcd(data: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Combine all hospital-patient groups into one (raw-data transmission)."""
    return {k: np.asarray(v).reshape((1, -1) + v.shape[2:]) for k, v in data.items()}


def tdcd_runner(model: HybridModel, fed: FederationConfig, train: TrainConfig) -> Tuple[HSGDRunner, FederationConfig]:
    merged_fed = FederationConfig(
        num_groups=1,
        devices_per_group=fed.devices_per_group * fed.num_groups,
        alpha=fed.alpha,
        local_interval=fed.local_interval,
        global_interval=fed.local_interval,  # Λ=1; global phase disabled anyway
        hospital_feature_frac=fed.hospital_feature_frac,
        non_iid_labels_per_group=fed.non_iid_labels_per_group,
    )
    return HSGDRunner(model, merged_fed, train, do_global_agg=False), merged_fed


# ---------------------------------------------------------------------------
# Centralized SGD reference
# ---------------------------------------------------------------------------


def centralized_runner(model: HybridModel, fed: FederationConfig, train: TrainConfig):
    cfed = FederationConfig(
        num_groups=1,
        devices_per_group=fed.devices_per_group * fed.num_groups,
        alpha=1.0,
        local_interval=1,
        global_interval=1,
        hospital_feature_frac=fed.hospital_feature_frac,
    )
    return HSGDRunner(model, cfed, train), cfed


def make_runner(name: str, model: HybridModel, fed: FederationConfig, train: TrainConfig):
    """Algorithm registry: hsgd | c-hsgd | jfl | tdcd | c-tdcd | centralized."""
    name = name.lower()
    if name in ("hsgd", "c-hsgd"):
        if name == "c-hsgd" and not (train.compression_k or train.quantization_bits):
            train = TrainConfig(**{**train.__dict__, "compression_k": 0.25, "quantization_bits": 128})
        return HSGDRunner(model, fed, train), fed
    if name == "jfl":
        return JFLRunner(model, fed, train), fed
    if name in ("tdcd", "c-tdcd"):
        if name == "c-tdcd" and not (train.compression_k or train.quantization_bits):
            train = TrainConfig(**{**train.__dict__, "compression_k": 0.25, "quantization_bits": 128})
        return tdcd_runner(model, fed, train)
    if name == "centralized":
        return centralized_runner(model, fed, train)
    raise ValueError(f"unknown algorithm {name}")


# checkpoint restores return a real JFLState, not an anonymous namedtuple
from repro.checkpoint.ckpt import register_state_class as _register_state_class  # noqa: E402

_register_state_class(JFLState)
