"""Communication-cost and wall-time models (paper §VI Prop. 1 and §VII-A3).

Two link models:
  * WAN  — the paper's e-health network (mobile 110/14 Mbps down/up between
    devices and edge; broadband 204/74 Mbps among edge/hospital/cloud), used
    to reproduce Figs. 4–9 and Table II;
  * ICI  — the TPU-pod adaptation (symmetric ~50 GB/s links), used by the
    roofline (§Roofline) where the same 1/P and 1/Q amortization governs the
    collective term.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.common.config import FederationConfig
from repro.common.pytree import tree_bytes
from repro.core.compression import compressed_bytes

MBIT = 1e6 / 8.0  # bytes per second per Mbps


@dataclass(frozen=True)
class LinkModel:
    dev_up: float  # device -> edge (B/s)
    dev_down: float  # edge -> device
    bb_up: float  # edge/hospital -> cloud
    bb_down: float  # cloud -> edge/hospital


WAN = LinkModel(dev_up=14 * MBIT, dev_down=110 * MBIT, bb_up=74 * MBIT, bb_down=204 * MBIT)
ICI = LinkModel(dev_up=50e9, dev_down=50e9, bb_up=50e9, bb_down=50e9)


@dataclass(frozen=True)
class MessageSizes:
    """Per-event wire sizes (bytes) for one hospital-patient group."""

    theta0: float
    theta1: float
    theta2: float
    z1: float  # hospital -> devices intermediate results (whole mini-batch)
    z2: float  # devices -> hospital
    n_active: int  # |A_m|
    raw_upfront: float = 0.0  # TDCD's raw-data merge


def message_sizes(
    model_params: Dict,
    z1_elements: int,
    z2_elements: int,
    n_active: int,
    compression_k: float = 0.0,
    quant_levels: int = 0,
    raw_upfront: float = 0.0,
    bytes_per_el: int = 4,
) -> MessageSizes:
    t0 = tree_bytes(model_params["theta0"])
    t1 = tree_bytes(model_params["theta1"])
    t2 = tree_bytes(model_params["theta2"])
    if compression_k or quant_levels:
        t0_el = t0 // bytes_per_el
        t0 = compressed_bytes(t0_el, compression_k or 1.0, quant_levels, bytes_per_el)
        z1b = compressed_bytes(z1_elements, compression_k or 1.0, quant_levels, bytes_per_el)
        z2b = compressed_bytes(z2_elements, compression_k or 1.0, quant_levels, bytes_per_el)
    else:
        z1b = z1_elements * bytes_per_el
        z2b = z2_elements * bytes_per_el
    return MessageSizes(t0, t1, t2, z1b, z2b, n_active, raw_upfront)


def comm_cost_per_iteration(sizes: MessageSizes, fed: FederationConfig) -> float:
    """Eq. (19)'s integrand: C(P,Q)/T for a single group, in bytes/iteration.

      C(P,Q) = ( |θ1|/P + (|A||θ2| + |θ0| + |Z1| + |Z2|)/Q ) · M · T
    """
    P, Q = fed.global_interval, fed.local_interval
    per_global = sizes.theta1 / P
    per_local = (sizes.n_active * sizes.theta2 + sizes.theta0 + sizes.z1 + sizes.z2) / Q
    return per_global + per_local


def total_comm_cost(sizes: MessageSizes, fed: FederationConfig, iterations: int) -> float:
    """Total bytes for one group over ``iterations`` steps (+ TDCD upfront)."""
    return comm_cost_per_iteration(sizes, fed) * iterations + sizes.raw_upfront


def per_round_bytes(sizes: MessageSizes, P: int, Q: int, num_groups: int = 1) -> float:
    """Modeled bytes of ONE global round (P iterations of eq. (19)) over all groups.

    This is the quantity the adaptive controller's byte governor charges per
    round when P/Q vary online.
    """
    fed = FederationConfig(local_interval=Q, global_interval=P)
    return comm_cost_per_iteration(sizes, fed) * P * num_groups


def round_time(
    sizes: MessageSizes,
    fed: FederationConfig,
    t_compute: float,
    links: LinkModel = WAN,
) -> float:
    """§VII-A3: t = t_g + (P/Q)(t_l + t_e) + P · t_c for one global round.

    Devices transmit in parallel (time = one device's payload / link speed);
    hospital/cloud payloads aggregate the group's models. Symmetric fleet:
    every device sits on the nominal WAN link and computes at nominal speed —
    the degenerate (tail = 1) case of ``round_time_hetero``.
    """
    return round_time_hetero(sizes, fed, t_compute, links)


def round_time_hetero(
    sizes: MessageSizes,
    fed: FederationConfig,
    t_compute: float,
    links: LinkModel = WAN,
    dev_tail: float = 1.0,
    compute_tail: float = 1.0,
) -> float:
    """§VII-A3 round time under device heterogeneity (straggler tails).

    Every device-parallel event (θ2 local aggregation, ζ exchange legs that
    touch a device link) completes when the SLOWEST sampled device does, so
    those terms scale by ``dev_tail`` — the max latency multiplier over the
    round's cohort (from a seeded trace, see ``core/population.py``).
    ``compute_tail`` scales the P·t_c term the same way (slowest device gates
    each lockstep SGD iteration). Backbone (edge/hospital↔cloud) legs are not
    device-gated and stay at the nominal broadband constants. Tails of 1.0
    reproduce the paper's symmetric model exactly.
    """
    P = fed.global_interval
    lam = fed.lam  # FederationConfig validates P % Q == 0 (no silent flooring)
    # global aggregation: hospital uploads (θ0,θ1,θ2), cloud returns them
    up = sizes.theta0 + sizes.theta1 + sizes.theta2
    t_g = up / links.bb_up + up / links.bb_down
    # local aggregation: each device uploads θ2 (parallel), edge returns θ2
    t_l = sizes.theta2 / links.dev_up + sizes.theta2 / links.dev_down
    # exchange: devices upload ζ2 (their own sample's share, parallel);
    # edge sends θ0 + Z1 down to devices; hospital<->edge over broadband
    z2_per_dev = sizes.z2 / max(sizes.n_active, 1)
    t_e_dev = z2_per_dev / links.dev_up + (sizes.theta0 + sizes.z1) / links.dev_down
    t_e_bb = (sizes.z1 + sizes.z2 + sizes.theta0) / links.bb_up
    return (
        t_g
        + lam * ((t_l + t_e_dev) * dev_tail + t_e_bb)
        + P * t_compute * compute_tail
    )


def time_to_step(
    sizes: MessageSizes,
    fed: FederationConfig,
    t_compute: float,
    steps: int,
    links: LinkModel = WAN,
    include_upfront: bool = True,
) -> float:
    """Wall-clock time after ``steps`` iterations (rounds may be partial)."""
    P = fed.global_interval
    rounds = steps / P
    t = rounds * round_time(sizes, fed, t_compute, links)
    if include_upfront and sizes.raw_upfront:
        t += sizes.raw_upfront / links.bb_up
    return t
