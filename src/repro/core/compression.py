"""Message compression for the C-HSGD / C-TDCD baselines (paper §VII-A1).

Top-k sparsification (Compressed-VFL, Castiglia et al.) keeps the k largest-
magnitude entries of the exchanged tensor; the b-level quantization (paper:
b = 128 -> log2(b)/32 compression of surviving values) snaps values to a
uniform grid. Differentiable straight-through behaviour is NOT needed — the
paper compresses *messages*, not gradients, so we compress forward values.

The Pallas kernel twin lives in kernels/topk_sparsify.py; this module is the
always-available jnp implementation (also the kernel's oracle, re-exported by
kernels/ref.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def topk_sparsify(x: jnp.ndarray, k_frac: float) -> jnp.ndarray:
    """Keep the ceil(k_frac * n) largest-|x| entries of each row; zero the rest.

    Operates on the last axis. k_frac >= 1 is a no-op.
    """
    if k_frac >= 1.0:
        return x
    n = x.shape[-1]
    k = max(1, int(round(k_frac * n)))
    mag = jnp.abs(x)
    thresh = jax.lax.top_k(mag, k)[0][..., -1:]
    return jnp.where(mag >= thresh, x, 0).astype(x.dtype)


def quantize(x: jnp.ndarray, levels: int) -> jnp.ndarray:
    """Uniform b-level quantize/dequantize per row (last axis)."""
    if levels <= 1:
        return x
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-12) / (levels - 1)
    q = jnp.round((x - lo) / scale)
    return (q * scale + lo).astype(x.dtype)


def compress_message(x: jnp.ndarray, k_frac: float, levels: int = 0) -> jnp.ndarray:
    y = topk_sparsify(x, k_frac) if 0.0 < k_frac < 1.0 else x
    if levels and levels > 1:
        y = quantize(y, levels)
    return y


def compressed_bytes(n_elements: int, k_frac: float, levels: int, dense_bytes_per_el: int = 4) -> float:
    """Wire size of a compressed message.

    top-k: k values + k indices (32-bit); quantization: log2(b) bits/value.
    Matches the paper's 'compression ratio log2(b)/32' accounting.
    """
    k = n_elements if not (0.0 < k_frac < 1.0) else max(1, int(round(k_frac * n_elements)))
    bits_per_val = dense_bytes_per_el * 8
    if levels and levels > 1:
        bits_per_val = max(1, int(jnp.ceil(jnp.log2(levels))))
    value_bytes = k * bits_per_val / 8.0
    index_bytes = 0.0 if k == n_elements else k * 4.0
    return value_bytes + index_bytes
