"""Message compression for the C-HSGD / C-TDCD baselines (paper §VII-A1).

Top-k sparsification (Compressed-VFL, Castiglia et al.) keeps the k largest-
magnitude entries of the exchanged tensor; the b-level quantization (paper:
b = 128 -> log2(b)/32 compression of surviving values) snaps values to a
uniform grid. Differentiable straight-through behaviour is NOT needed — the
paper compresses *messages*, not gradients, so we compress forward values.

This module is the canonical *math* for the compression pipeline. Two
implementations share it bit-for-bit:

  * ``compress_rows_ref`` — the pure-jnp fused reference (also the oracle for
    the Pallas kernel, re-exported by ``kernels/ref.py``). Ragged-aware: a
    per-row valid length lets many pytree leaves of different widths be
    compressed in ONE padded row-matrix call.
  * ``kernels/compress.py::fused_compress_pallas`` — the TPU kernel twin,
    which applies the same threshold refinement + quantization in a single
    VMEM-resident pass (one read, one write per message row).

Top-k uses the TPU-native *threshold refinement* formulation (fixed-iteration
binary search on the magnitude threshold against the row max) rather than a
sort: pure elementwise VPU work + row reductions, keeping >= k survivors
(exact top-k support always preserved; ties can add a few). The legacy
sort-based path is kept as ``topk_sparsify_sort`` for benchmarking the pre-
fusion hot path.
"""
from __future__ import annotations

import math
from typing import Optional, Union

import jax
import jax.numpy as jnp

N_REFINE = 16  # threshold tight to max|x| / 2^16


# ---------------------------------------------------------------------------
# Canonical fused math (fp32 internally; the kernel runs the same ops)
# ---------------------------------------------------------------------------


def compress_rows_ref(
    x: jnp.ndarray,
    k: Union[int, jnp.ndarray],
    levels: int = 0,
    row_len: Optional[jnp.ndarray] = None,
    dp_clip: Optional[jnp.ndarray] = None,
    dp_sigma: Optional[jnp.ndarray] = None,
    dp_noise: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Fused top-k sparsify + b-level quantize over the last axis of ``x``.

    x: [rows, n]. k: scalar or [rows]/[rows,1] per-row keep count (k >= n is a
    per-row no-op). levels <= 1 disables quantization. row_len: optional
    [rows]/[rows,1] int32 valid length for ragged rows — entries at column
    >= row_len are excluded from thresholds/extrema and zeroed in the output.

    Optional fused DP stage (``dp_noise is not None``): each row is L2-clipped
    to ``dp_clip`` then perturbed with ``dp_sigma * dp_clip * dp_noise`` BEFORE
    sparsification, so the released message is a post-processing of a Gaussian-
    mechanism output. ``dp_noise`` [rows, n] is precomputed standard-normal
    (threaded PRNG outside the kernel) so the Pallas twin and this fallback see
    identical operands and stay bit-identical; clip/σ are traced scalars. The
    stage is gated at the Python level: the non-DP trace is unchanged.

    This is the jnp fallback used off-TPU and the bit-exact oracle for the
    Pallas kernel (identical op sequence, all reductions in fp32).
    """
    k = jnp.asarray(k, jnp.int32).reshape(-1, 1) if not isinstance(k, int) else k
    xf = x.astype(jnp.float32)
    if row_len is None:
        valid = jnp.ones(x.shape, bool)
    else:
        row_len = jnp.asarray(row_len, jnp.int32).reshape(-1, 1)
        valid = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) < row_len
    if dp_noise is not None:
        # one extra VMEM-resident op on the row matrix: scale = min(1, C/‖x‖₂)
        # per row, then add σ·C·noise. With σ=0 and C >= ‖x‖₂ this multiplies
        # by exactly 1.0 and adds exactly 0.0 — bit-identical to the non-DP
        # pass (pinned by a property test).
        nrm2 = jnp.sum(jnp.where(valid, xf * xf, 0.0), axis=-1, keepdims=True)
        coef = jnp.minimum(1.0, dp_clip / jnp.maximum(jnp.sqrt(nrm2), 1e-12))
        xf = xf * coef + (dp_sigma * dp_clip) * dp_noise.astype(jnp.float32)
    mag = jnp.where(valid, jnp.abs(xf), 0.0)
    hi = jnp.max(mag, axis=-1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def refine(_, carry):
        # invariant: count(lo) >= k > count(hi); converge on the largest
        # threshold still keeping >= k survivors (count >= k, NOT > k — the
        # strict form would settle one element low and keep k+1 per row)
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        count = jnp.sum(((mag >= mid) & valid).astype(jnp.int32), axis=-1, keepdims=True)
        return jnp.where(count >= k, mid, lo), jnp.where(count >= k, hi, mid)

    lo, hi = jax.lax.fori_loop(0, N_REFINE, refine, (lo, hi))
    kept = (mag >= lo) & valid  # >= k survivors (exactly k up to ties)
    y = jnp.where(kept, xf, 0.0)
    if levels and levels > 1:
        # Quantize over the SURVIVORS' value range and re-mask zeros after.
        # Taking extrema over all valid entries (the old grid) anchors qlo at
        # the row min of the sparsified row, so whenever a kept value is
        # negative the zeroed entries snap to round((0-qlo)/scale)*scale+qlo
        # != 0 and quantization silently re-densifies the message.
        qlo = jnp.min(jnp.where(kept, y, jnp.inf), axis=-1, keepdims=True)
        qhi = jnp.max(jnp.where(kept, y, -jnp.inf), axis=-1, keepdims=True)
        scale = jnp.maximum(qhi - qlo, 1e-12) / (levels - 1)
        y = jnp.where(kept, jnp.round((y - qlo) / scale) * scale + qlo, 0.0)
    return jnp.where(valid, y, 0.0).astype(x.dtype)


# ---------------------------------------------------------------------------
# Standalone primitives (property-test surface; same refinement math)
# ---------------------------------------------------------------------------


def topk_sparsify(x: jnp.ndarray, k_frac: float) -> jnp.ndarray:
    """Keep ~ceil(k_frac * n) largest-|x| entries of each row; zero the rest.

    Operates on the last axis via the threshold-refinement formulation (>= k
    survivors, exact top-k support preserved). k_frac >= 1 is a no-op.
    """
    if k_frac >= 1.0:
        return x
    n = x.shape[-1]
    k = max(1, int(round(k_frac * n)))
    return compress_rows_ref(x.reshape(-1, n), k, levels=0).reshape(x.shape)


def topk_sparsify_sort(x: jnp.ndarray, k_frac: float) -> jnp.ndarray:
    """Legacy sort-based exact top-k (jax.lax.top_k) — pre-fusion baseline."""
    if k_frac >= 1.0:
        return x
    n = x.shape[-1]
    k = max(1, int(round(k_frac * n)))
    mag = jnp.abs(x)
    thresh = jax.lax.top_k(mag, k)[0][..., -1:]
    return jnp.where(mag >= thresh, x, 0).astype(x.dtype)


def quantize(x: jnp.ndarray, levels: int) -> jnp.ndarray:
    """Uniform b-level quantize/dequantize per row (last axis).

    The grid is anchored at zero (points are integer multiples of the row's
    step), so already-sparsified rows stay sparse: 0 maps to exactly 0. The
    step is still the row's (max-min)/(levels-1), keeping the error bound at
    step/2; the zero-anchored grid can spend one extra code at a span edge,
    which the byte model ignores.
    """
    if levels <= 1:
        return x
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-12) / (levels - 1)
    q = jnp.round(x / scale)
    return (q * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Message entry points
# ---------------------------------------------------------------------------


def compress_message(x: jnp.ndarray, k_frac: float, levels: int = 0) -> jnp.ndarray:
    """Compress one message tensor (any rank >= 1) along its last axis.

    Routes through the fused kernel path (Pallas on TPU, fused jnp fallback
    elsewhere) as a single [rows, n] call.
    """
    if not (0.0 < k_frac < 1.0) and not (levels and levels > 1):
        return x
    from repro.kernels.compress import compress_rows  # lazy: avoids import cycle

    n = x.shape[-1]
    k = n if not (0.0 < k_frac < 1.0) else max(1, int(round(k_frac * n)))
    return compress_rows(x.reshape(-1, n), k, levels).reshape(x.shape)


def compress_message_sort(x: jnp.ndarray, k_frac: float, levels: int = 0) -> jnp.ndarray:
    """Pre-fusion reference path: sort-based top-k, then separate quantize.

    Kept only as the baseline for ``benchmarks/bench_hsgd_hotpath.py``.
    """
    y = topk_sparsify_sort(x, k_frac) if 0.0 < k_frac < 1.0 else x
    if levels and levels > 1:
        y = quantize(y, levels)
    return y


# (k_frac, levels) rungs ordered loosest -> tightest wire size; rung 0 is the
# uncompressed message. The adaptive controller's byte governor walks DOWN
# this ladder (never up within a run) until the projected bytes fit the
# budget, so the compile-cache key set stays bounded by len(COMPRESSION_LADDER).
COMPRESSION_LADDER = (
    (0.0, 0),     # uncompressed
    (0.5, 128),   # top-50% + b=128 quantization
    (0.25, 128),  # the paper's C-HSGD operating point (§VII-A1)
    (0.1, 128),
    (0.05, 64),
)

# DP rung dimension alongside COMPRESSION_LADDER: σ multipliers the privacy
# governor walks UP (never down within a run) when the projected ε would bust
# the (ε, δ) budget. σ is a traced kernel operand, so unlike the compression
# rungs this ladder costs zero extra compiles.
DP_SIGMA_LADDER = (1.0, 2.0, 4.0, 8.0)


def compressed_bytes(n_elements: int, k_frac: float, levels: int, dense_bytes_per_el: int = 4) -> float:
    """Wire size of a compressed message.

    top-k: k values + k indices (32-bit); quantization: log2(b) bits/value.
    Matches the paper's 'compression ratio log2(b)/32' accounting. Pure-
    Python cost model — never traces.
    """
    k = n_elements if not (0.0 < k_frac < 1.0) else max(1, int(round(k_frac * n_elements)))
    bits_per_val = dense_bytes_per_el * 8
    if levels and levels > 1:
        bits_per_val = max(1, math.ceil(math.log2(levels)))
    value_bytes = k * bits_per_val / 8.0
    index_bytes = 0.0 if k == n_elements else k * 4.0
    return value_bytes + index_bytes
