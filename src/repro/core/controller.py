"""Closed-loop adaptive HSGD controller — the paper's §VI strategies, online.

``AdaptiveHSGDRunner`` turns the offline one-shot formulas of
``core/adaptive.py`` into a between-rounds control loop. Code ↔ §VI map:

  Theorem 1, eq. (17)   Γ(P,Q) = 4(F−F*)/(ηT) + 12Pρηδ² + 96Q²ρ²η²δ²
                        -> ``adaptive.convergence_bound``; the controller
                        keeps Γ ≤ the user's target Ξ (Prop. 1's accuracy
                        target) by shrinking P when the bound would overshoot.
  Strategy 1 (Prop. 1)  Λ = P/Q = 1 minimizes C(P,Q) at a given Ξ
                        -> every plan sets Q = P.
  Strategy 2 (Prop. 2)  P* = Q* = sqrt((F − E[F_T]) / (24 ρ² η² δ² T))
                        -> ``adaptive.strategy2_optimal_interval`` re-evaluated
                        every round with the *remaining* iteration budget T_rem
                        and the current loss standing in for F(θ̃⁰).
  Strategy 3 (Prop. 3)  η* = min(η₂, 1/(8Pρ))
                        -> ``adaptive.strategy3_learning_rate`` re-picked after
                        every P change from the online ‖∇F‖² estimate.
  §VI-B probes          ρ, δ estimated "with a small number of pre-training
                        iterations" -> ``adaptive.estimate_rho_delta`` seeds
                        the loop; afterwards each round's OWN gradients are
                        reused (``local_sgd_step_stats``): δ² from per-worker
                        gradient spread, ρ from within-interval secants
                        ‖ḡ_{t+1} − ḡ_t‖ / (η‖ḡ_t‖), ‖∇F‖² from ‖ḡ‖². No
                        extra forward passes — the probes are free.
  Eq. (19) governor     C(P,Q)/T per-iteration wire cost
                        -> ``comm_model.comm_cost_per_iteration`` projects the
                        end-of-run bytes; when the projection exceeds the
                        user's byte budget the governor tightens the message
                        (``COMPRESSION_LADDER`` top-k/quantization rungs, then
                        larger P = Q), never loosening within a run.

Every executed round goes through ``HSGDRunner.round_fn`` — one compiled,
state-donating executor per (P, Q, compression) bucket, so the round-varying
schedule costs one compile per bucket (P snaps to powers of two), not one per
round. PR 1's donation / mesh-sharding / fused-compression paths are reused
unchanged underneath.

The loop's bookkeeping is representation-agnostic: ``ControllerCore`` holds
the probe EMA, the step/byte ledgers, and the ladder ratchet, and only ever
sees (a) a ``sizes_of(k, b)`` callback for the eq. (19) cost model and (b) the
per-step stats dict a round executor emits. ``AdaptiveHSGDRunner`` binds it to
the e-health ``HSGDState`` path; the LLM-scale runner
(``launch/steps.py::AdaptiveLLMRunner``) binds the SAME core to the
``llm_hybrid`` compiled rounds.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import numpy as np

import jax.numpy as jnp

from repro.common.buckets import pow2_floor as _pow2_floor
from repro.common.config import FederationConfig, TrainConfig
from repro.common.pytree import tree_size
from repro.core import comm_model as CM
from repro.core import federation as F
from repro.core.adaptive import (
    convergence_bound,
    estimate_rho_delta,
    max_learning_rate,
    strategy2_optimal_interval,
    strategy3_learning_rate,
)
from repro.core.compression import (
    COMPRESSION_LADDER,
    DP_SIGMA_LADDER,
    compressed_bytes,
)
from repro.core.hsgd import (
    HSGDRunner,
    HSGDState,
    global_model,
    place_on_mesh,
)
from repro.models.split_model import HybridModel


@dataclass(frozen=True)
class AdaptiveConfig:
    """Knobs of the closed loop (all byte quantities are *modeled* wire bytes
    across ALL groups, per the eq. (19) cost model)."""

    total_steps: int = 128          # T: total SGD iterations to spend
    target_bound: float = math.inf  # Ξ: keep Γ(P,Q) ≤ this (Prop. 1 target)
    byte_budget: float = math.inf   # honor this end-of-run byte projection
    time_budget: float = math.inf   # honor this end-of-run wall-clock projection (s)
    max_interval: int = 32          # cap on P = Q
    eta_min: float = 1e-4
    eta_max: float = 0.1
    ema: float = 0.5                # probe smoothing: old*ema + new*(1-ema)
    probe_slew: float = 4.0         # per-round cap on a probe's growth/shrink ratio
    ladder: Tuple[Tuple[float, int], ...] = COMPRESSION_LADDER
    init_probe: bool = True         # §VI-B pre-training probe before round 1
    probe_batch: int = 32
    # -- privacy knobs (DP off unless clip AND sigma are positive) ----------
    privacy_budget: float = math.inf  # ε: refuse plans whose projection busts it
    privacy_delta: float = 1e-5       # δ of the (ε, δ) conversion
    dp_clip: float = 0.0              # per-row L2 clip C of the fused DP stage
    dp_sigma: float = 0.0             # base noise multiplier (noise std = σ·C)
    dp_ladder: Tuple[float, ...] = DP_SIGMA_LADDER  # σ multipliers, ratcheted up
    secure_agg: bool = False          # pairwise-mask the eq. (1) uplink


@dataclass(frozen=True)
class RoundPlan:
    """One round's settings as picked by strategies 1–3 + the governor."""

    P: int
    Q: int
    eta: float
    rung: int                 # index into the compression ladder
    gamma: float              # Γ(P,Q) at the picked settings
    projected_bytes: float    # end-of-run byte projection at these settings
    projected_seconds: float = 0.0  # end-of-run wall-clock projection (0 = unmodeled)
    dp_rung: int = 0          # index into the DP σ ladder (0 when DP is off)
    dp_sigma: float = 0.0     # effective noise multiplier this round (0 = off)
    projected_epsilon: float = 0.0  # end-of-run ε projection (0 = unmodeled)
    dp_exhausted: bool = False  # True: even the governed plan busts ε — refuse


class AdaptiveResult(NamedTuple):
    state: HSGDState
    losses: np.ndarray        # [total_steps]
    history: List[Dict[str, Any]]  # one record per executed round


def ladder_from(compression_k: float, quant_levels: int,
                base: Tuple[Tuple[float, int], ...] = COMPRESSION_LADDER,
                ) -> Tuple[Tuple[float, int], ...]:
    """Governor ladder that STARTS at an explicitly requested compression
    setting (e.g. c-hsgd's k=0.25/b=128) and only tightens from there: the
    user's (k, b) becomes rung 0, followed by the base rungs with strictly
    smaller wire size. No compression requested -> the base ladder."""
    if not (compression_k or quant_levels):
        return base
    n_ref = 1 << 20
    user_bytes = compressed_bytes(n_ref, compression_k or 1.0, quant_levels)
    tail = tuple((k, b) for k, b in base
                 if compressed_bytes(n_ref, k or 1.0, b) < user_bytes)
    return ((compression_k, quant_levels),) + tail


def gaussian_rho(sigma: float) -> float:
    """zCDP cost ρ of ONE Gaussian-mechanism release at noise multiplier σ
    (sensitivity is normalized away by the per-row clip: std = σ·C for
    sensitivity C, so ρ = 1/(2σ²)). σ ≤ 0 means no noise — infinite cost."""
    if sigma <= 0.0:
        return math.inf
    return 1.0 / (2.0 * sigma * sigma)


def epsilon_of(rho: float, delta: float) -> float:
    """(ε, δ) bound of accumulated zCDP budget ρ: ε = ρ + 2√(ρ·ln(1/δ)).

    zCDP composes additively across rounds (ρ_total = Σ ρ_i), so the ledger
    stores ρ and converts once at read time — tighter than naive (ε, δ)
    composition and monotone in both arguments, which the governor relies on."""
    if rho <= 0.0:
        return 0.0
    if not math.isfinite(rho):
        return math.inf
    return rho + 2.0 * math.sqrt(rho * math.log(1.0 / delta))


def plan_round(
    probe: Dict[str, float],
    steps_done: int,
    bytes_spent: float,
    rung: int,
    eta_prev: float,
    cfg: AdaptiveConfig,
    fed: FederationConfig,
    sizes_of,
    time_of=None,
    seconds_spent: float = 0.0,
    dp_rung: int = 0,
    privacy_spent: float = 0.0,
) -> RoundPlan:
    """Pure planning step: probes -> (P, Q, η, compression rung).

    ``sizes_of(k_frac, levels)`` returns the per-group ``MessageSizes`` at a
    ladder rung. Separated from the runner so the governor logic is unit-
    testable without training anything.

    ``time_of(P, rung)`` (optional) returns the modeled wall-clock seconds of
    ONE global round at P = Q and that ladder rung — under straggler tails
    when the caller is a population run (``population.expected_round_seconds``).
    With it, the eq. (19) byte governor becomes a joint byte + wall-clock
    governor: the projection that busts EITHER budget first ratchets the
    compression ladder, then amortizes harder with a larger P = Q (which
    divides the per-round t_g and per-interval exchange overheads over more
    SGD steps), so the loop optimizes time-to-accuracy rather than bytes
    alone.
    """
    rho = max(probe["rho"], 1e-6)
    delta = max(probe["delta"], 1e-9)
    F_cur = max(probe["F0"], 1e-9)
    gnorm2 = max(probe["grad_norm_sq"], 0.0)
    T_rem = max(cfg.total_steps - steps_done, 1)

    def eta_for(P: int) -> float:
        eta = strategy3_learning_rate(P, P, rho, delta, gnorm2)  # strategy 3
        # the anti-stall floor yields to Theorem 1's cap 1/(8Pρ): Γ's formula
        # (and the guard below) is only valid under η ≤ that cap
        floor = min(cfg.eta_min, max_learning_rate(P, rho))
        return min(max(eta, floor), cfg.eta_max)

    def gamma(P: int, eta: float) -> float:
        return convergence_bound(F_cur, 0.0, rho, delta, eta, P, P, T_rem)

    def projected(P: int, rung: int) -> float:
        k, b = cfg.ladder[rung]
        per_iter = CM.comm_cost_per_iteration(
            sizes_of(k, b),
            FederationConfig(local_interval=P, global_interval=P),
        ) * fed.num_groups
        return bytes_spent + per_iter * T_rem

    def projected_s(P: int, rung: int) -> float:
        if time_of is None:
            return 0.0
        return seconds_spent + time_of(P, rung) * (T_rem / P)

    def over_budget(P: int, rung: int) -> bool:
        return (projected(P, rung) > cfg.byte_budget
                or projected_s(P, rung) > cfg.time_budget)

    # strategies 2 + 1: optimal sync interval, with Q = P
    P = strategy2_optimal_interval(F_cur, rho, delta, eta_prev, T_rem)
    P = _pow2_floor(max(1, min(P, cfg.max_interval, T_rem)))
    eta = eta_for(P)

    # Theorem-1 guard: Γ grows with P at fixed η, so shrink P until Γ ≤ Ξ
    while P > 1 and gamma(P, eta) > cfg.target_bound:
        P //= 2
        eta = eta_for(P)

    # byte/wall-clock governor: tighten the message until both projections fit
    while over_budget(P, rung) and rung < len(cfg.ladder) - 1:
        rung += 1
    # tightest rung still over a budget -> amortize harder with a larger
    # P = Q, as long as the Theorem-1 target allows it
    while (over_budget(P, rung)
           and 2 * P <= min(cfg.max_interval, T_rem)
           and gamma(2 * P, eta_for(2 * P)) <= cfg.target_bound):
        P *= 2
        eta = eta_for(P)

    # privacy governor: each global round releases P/Q = 1 Gaussian-mechanism
    # message per group-pair (strategy 1), so the run has ceil(T_rem/P) more
    # releases ahead. Project the end-of-run ε; when it busts the budget, walk
    # the σ ladder UP (σ is a traced kernel operand — zero extra compiles),
    # then amortize with a larger P = Q (fewer releases), and only if BOTH are
    # exhausted refuse the plan outright (dp_exhausted — the caller must stop
    # training rather than silently overspend ε).
    dp = cfg.dp_clip > 0.0 and cfg.dp_sigma > 0.0
    dp_sigma, eps_proj, dp_exhausted = 0.0, 0.0, False
    if dp:
        def eps_after(P_: int, dr: int) -> float:
            releases = math.ceil(T_rem / P_)  # one release per round (Q = P)
            rho_more = releases * gaussian_rho(cfg.dp_sigma * cfg.dp_ladder[dr])
            return epsilon_of(privacy_spent + rho_more, cfg.privacy_delta)

        while (eps_after(P, dp_rung) > cfg.privacy_budget
               and dp_rung < len(cfg.dp_ladder) - 1):
            dp_rung += 1
        while (eps_after(P, dp_rung) > cfg.privacy_budget
               and 2 * P <= min(cfg.max_interval, T_rem)
               and gamma(2 * P, eta_for(2 * P)) <= cfg.target_bound):
            P *= 2
            eta = eta_for(P)
        dp_sigma = cfg.dp_sigma * cfg.dp_ladder[dp_rung]
        eps_proj = eps_after(P, dp_rung)
        dp_exhausted = eps_proj > cfg.privacy_budget

    return RoundPlan(P=P, Q=P, eta=eta, rung=rung,
                     gamma=gamma(P, eta), projected_bytes=projected(P, rung),
                     projected_seconds=projected_s(P, rung),
                     dp_rung=dp_rung, dp_sigma=dp_sigma,
                     projected_epsilon=eps_proj, dp_exhausted=dp_exhausted)


# neutral probe seed: the first plan degenerates to P = Q = 1 and the online
# stats take over from round 1 (used when no §VI-B pre-training probe runs)
NEUTRAL_PROBE = {"rho": 1.0, "delta": 1.0, "F0": 1.0, "grad_norm_sq": 1.0}


def probe_from_stats(stats, Q: int, fallback_rho: float = 1.0) -> Dict[str, float]:
    """Raw §VI-B probe measurement from one round's [P] stats arrays.

    ``stats`` is the dict every round executor emits (loss/gnorm2/delta2/rho/
    rho_ok per step) — shared by the e-health and LLM runners, so the probe
    extraction lives here, independent of either state representation.
    """
    loss = np.asarray(stats["loss"])
    rho = np.asarray(stats["rho"])
    ok = np.asarray(stats["rho_ok"]) > 0.5
    return {
        "F0": float(np.mean(loss[-Q:])),
        "delta": float(np.sqrt(max(float(np.mean(np.asarray(stats["delta2"]))), 1e-16))),
        "grad_norm_sq": float(np.mean(np.asarray(stats["gnorm2"]))),
        # median valid secant ≈ local Lipschitz constant along the
        # trajectory (median, not max: a single staleness spike must not
        # collapse η through the 1/(8Pρ) cap). Q=1 rounds have no
        # within-interval pair — the caller keeps its standing estimate.
        "rho": float(np.median(rho[ok])) if ok.any() else fallback_rho,
    }


def update_probe(probe: Dict[str, float], stats, Q: int,
                 cfg: AdaptiveConfig) -> Dict[str, float]:
    """EMA + slew-limited probe update from one round's stats."""
    new = probe_from_stats(stats, Q, fallback_rho=probe["rho"])
    e, slew = cfg.ema, cfg.probe_slew
    out = {}
    for k in probe:
        v = e * probe[k] + (1.0 - e) * new[k]
        if slew > 1.0 and probe[k] > 0:  # trust region: bounded per-round drift
            v = min(max(v, probe[k] / slew), probe[k] * slew)
        out[k] = v
    return out


class ControllerCore:
    """State-representation-agnostic §VI loop: plan -> (caller runs the
    round) -> record.

    The caller owns the model state and the compiled round executors; the core
    owns everything else — the probe EMA, the ladder ratchet, the step/byte
    ledgers, and the per-round history. One core instance is one run.
    """

    def __init__(self, cfg: AdaptiveConfig, fed: FederationConfig, sizes_of,
                 eta0: float, probe: Optional[Dict[str, float]] = None,
                 time_of=None):
        self.cfg, self.fed, self.sizes_of = cfg, fed, sizes_of
        self.time_of = time_of  # (P, rung) -> modeled seconds of one round
        self.probe = dict(probe) if probe is not None else dict(NEUTRAL_PROBE)
        self.steps_done = 0
        self.bytes_spent = 0.0
        self.seconds_spent = 0.0  # wall-clock ledger (modeled, simulated time)
        self.rung = 0
        self.eta_prev = eta0
        self.history: List[Dict[str, Any]] = []
        # (ε, δ) ledger — zCDP ρ accumulates per executed DP round; the σ
        # rung ratchets up like the compression rung; privacy_exhausted stops
        # the run BEFORE a budget-busting round executes.
        self.rho_spent = 0.0
        self.dp_rung = 0
        self.privacy_exhausted = False

    @property
    def done(self) -> bool:
        return self.steps_done >= self.cfg.total_steps or self.privacy_exhausted

    @property
    def epsilon_spent(self) -> float:
        """ε of the (ε, δ=cfg.privacy_delta) guarantee spent so far."""
        return epsilon_of(self.rho_spent, self.cfg.privacy_delta)

    def state_dict(self) -> Dict[str, Any]:
        """JSON-able ledger snapshot (everything plan/record mutate) so a
        checkpointed run resumes with bit-identical controller decisions."""
        return {
            "probe": dict(self.probe),
            "steps_done": int(self.steps_done),
            "bytes_spent": float(self.bytes_spent),
            "seconds_spent": float(self.seconds_spent),
            "rung": int(self.rung),
            "eta_prev": float(self.eta_prev),
            "rho_spent": float(self.rho_spent),
            "dp_rung": int(self.dp_rung),
            "privacy_exhausted": bool(self.privacy_exhausted),
            "history": [dict(h) for h in self.history],
        }

    def load_state_dict(self, sd: Dict[str, Any]) -> None:
        self.probe = dict(sd["probe"])
        self.steps_done = int(sd["steps_done"])
        self.bytes_spent = float(sd["bytes_spent"])
        self.seconds_spent = float(sd["seconds_spent"])
        self.rung = int(sd["rung"])
        self.eta_prev = float(sd["eta_prev"])
        # pre-privacy checkpoints carry no ledger — resume with ε = 0 spent
        self.rho_spent = float(sd.get("rho_spent", 0.0))
        self.dp_rung = int(sd.get("dp_rung", 0))
        self.privacy_exhausted = bool(sd.get("privacy_exhausted", False))
        self.history = [dict(h) for h in sd["history"]]

    def plan(self) -> Tuple[RoundPlan, Tuple[float, int]]:
        """Next round's settings + its (k_frac, levels) ladder rung."""
        plan = plan_round(self.probe, self.steps_done, self.bytes_spent,
                          self.rung, self.eta_prev, self.cfg, self.fed,
                          self.sizes_of, time_of=self.time_of,
                          seconds_spent=self.seconds_spent,
                          dp_rung=self.dp_rung,
                          privacy_spent=self.rho_spent)
        self.rung = plan.rung  # the ladder is a ratchet: never loosened
        self.dp_rung = plan.dp_rung  # σ ratchet: never lowered within a run
        if plan.dp_exhausted:
            # refuse BEFORE executing: the caller's loop sees done == True and
            # stops with the (ε, δ) guarantee intact
            self.privacy_exhausted = True
        return plan, self.cfg.ladder[plan.rung]

    def record(self, plan: RoundPlan, stats,
               seconds: Optional[float] = None) -> Dict[str, Any]:
        """Charge the executed round's eq. (19) bill, log it, update probes.

        ``seconds`` is the round's realized simulated wall-clock (e.g. the
        population scheduler's deadline); when omitted the ``time_of`` model
        at the executed (P, rung) is charged instead. Both feed the same
        ledger the planner projects against.
        """
        k_frac, levels = self.cfg.ladder[plan.rung]
        round_bytes = CM.per_round_bytes(
            self.sizes_of(k_frac, levels), plan.P, plan.Q, self.fed.num_groups)
        self.bytes_spent += round_bytes
        self.steps_done += plan.P
        if seconds is None and self.time_of is not None:
            seconds = self.time_of(plan.P, plan.rung)
        round_seconds = float(seconds) if seconds is not None else 0.0
        self.seconds_spent += round_seconds
        if plan.dp_sigma > 0.0:
            # strategy 1: one Gaussian release per executed round (P/Q = 1)
            self.rho_spent += (plan.P // plan.Q) * gaussian_rho(plan.dp_sigma)
        rec = {
            "round": len(self.history), "P": plan.P, "Q": plan.Q,
            "eta": plan.eta, "rung": plan.rung,
            "compression_k": k_frac, "quant_levels": levels,
            "gamma": plan.gamma, "target_bound": self.cfg.target_bound,
            "rho": self.probe["rho"], "delta": self.probe["delta"],
            "grad_norm_sq": self.probe["grad_norm_sq"], "F0": self.probe["F0"],
            "round_bytes": round_bytes, "bytes_total": self.bytes_spent,
            "projected_bytes": plan.projected_bytes,
            "round_seconds": round_seconds, "seconds_total": self.seconds_spent,
            "projected_seconds": plan.projected_seconds,
            "dp_sigma": plan.dp_sigma, "dp_rung": plan.dp_rung,
            "epsilon_total": self.epsilon_spent,
            "projected_epsilon": plan.projected_epsilon,
            "steps_done": self.steps_done,
            "loss_last": float(np.asarray(stats["loss"])[-1]),
        }
        self.history.append(rec)
        self.eta_prev = plan.eta
        self.probe = update_probe(self.probe, stats, plan.Q, self.cfg)
        return rec


def hsgd_sizes_of(state: HSGDState, fed: FederationConfig):
    """sizes_of(k, levels) -> per-group MessageSizes for the governor, with
    z1/z2 element counts read off the live exchange buffers (per group =
    total / M). Shared by the adaptive runner and the population runner."""
    M = fed.num_groups
    params_shapes = {
        "theta0": jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), state.theta0),
        "theta1": jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), state.theta1),
        "theta2": jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[2:], x.dtype), state.theta2),
    }
    z1_el = tree_size(state.stale["z1"]) // M
    z2_el = tree_size(state.stale["z2"]) // M

    def sizes_of(k_frac: float, levels: int):
        return CM.message_sizes(params_shapes, z1_el, z2_el,
                                fed.sampled_devices, k_frac, levels)

    return sizes_of


class AdaptiveHSGDRunner:
    """Closed-loop trainer: plan -> run one compiled round -> re-probe."""

    def __init__(
        self,
        model: HybridModel,
        fed: FederationConfig,
        train: TrainConfig,
        cfg: Optional[AdaptiveConfig] = None,
        do_global_agg: bool = True,
        fused_compression: bool = True,
    ):
        self.model, self.fed, self.train = model, fed, train
        self.cfg = cfg or AdaptiveConfig()
        self.runner = HSGDRunner(model, fed, train, do_global_agg=do_global_agg,
                                 fused_compression=fused_compression)

    # -- comm-model plumbing -------------------------------------------------

    def _sizes_of(self, state: HSGDState):
        return hsgd_sizes_of(state, self.fed)

    # -- main loop -----------------------------------------------------------

    def run(self, state: HSGDState, data, group_weights, mesh=None,
            probe_key=None) -> AdaptiveResult:
        """Drive ``cfg.total_steps`` SGD iterations adaptively.

        Donates ``state`` round-by-round (rebind the returned state). Returns
        per-step losses and a per-round history of every decision the
        controller took (P, Q, η, rung, Γ, probes, modeled bytes).
        """
        cfg = self.cfg
        state, data, group_weights = place_on_mesh(state, data, group_weights, mesh)

        if cfg.init_probe:
            key = probe_key if probe_key is not None else jax.random.PRNGKey(0)
            probe = estimate_rho_delta(self.model, global_model(state, group_weights),
                                       data, key, batch=cfg.probe_batch)
        else:
            probe = None  # NEUTRAL_PROBE: first plan degenerates to P = Q = 1

        core = ControllerCore(cfg, self.fed, self._sizes_of(state),
                              eta0=self.train.learning_rate, probe=probe)
        dp = cfg.dp_clip > 0.0 and cfg.dp_sigma > 0.0
        losses: List[np.ndarray] = []
        while not core.done:
            plan, (k_frac, levels) = core.plan()
            if core.privacy_exhausted:
                break  # refused round: executing it would bust the ε budget
            fn = self.runner.round_fn(plan.P, plan.Q, k_frac, levels,
                                      collect_stats=True,
                                      dp=dp, secure_agg=cfg.secure_agg)
            kwargs: Dict[str, Any] = {}
            if dp:
                kwargs["dp_clip"] = jnp.asarray(cfg.dp_clip, jnp.float32)
                kwargs["dp_sigma"] = jnp.asarray(plan.dp_sigma, jnp.float32)
            if cfg.secure_agg:
                kwargs["agg_masks"] = F.secure_agg_masks(
                    state.theta2, self.train.seed, len(core.history))
            state, stats = fn(state, data, group_weights, plan.eta, **kwargs)
            stats = jax.device_get(stats)
            losses.append(np.asarray(stats["loss"]))
            core.record(plan, stats)

        losses_flat = (np.concatenate(losses) if losses
                       else np.zeros((0,), np.float32))
        return AdaptiveResult(state, losses_flat, core.history)
