"""Seeded fault injection for the federation runtime.

Real e-health fleets fail in ways the paper's simulation never exercises:
patient devices vanish mid-round, wireless uplinks corrupt or drop the
compressed exchange message, sick clients emit NaN/Inf or wildly-scaled
gradients, links stall, and the coordinator itself gets preempted. This
module schedules all of those deterministically from one seed, with the same
RNG discipline as ``DeviceRegistry``: round r's faults come from
``np.random.default_rng([seed, 3, r])``, so a trace replays bit-identically
from the seed alone — and, like ``launch/loadgen.py``, every drawn round is
also recordable to a JSON trace that a replay injector serves back verbatim.

What each fault means downstream (see ``core/population.py``'s resilient run
loop for the routing):

  drop          [M, A] device gone mid-round: its participation-mask slot is
                zeroed before the round executes (missing update).
  grad_fault    [M, A] additive per-device gradient term: NaN for sick
                clients, ``outlier_scale`` for wildly-scaled updates; 0 =
                clean. Injected inside the compiled round via a jnp.where
                mask so clean devices stay bit-identical.
  msg_fault     [M] multiplier on the group's compressed uplink payload (ζ2):
                NaN or ``corrupt_scale`` for bit-flip corruption; 0 = clean.
  lost / dup    [M] the group's round update is lost (weight x0) or applied
                twice (weight x2) at the next global aggregation.
  latency_mult  [M] straggler spike: multiplies the group's simulated round
                duration before the scheduler settles the deadline.
  preempt       the coordinator dies at this round boundary (raise; resume
                from the last auto-checkpoint).
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional

import numpy as np

from repro.common.io import atomic_write_json


@dataclass(frozen=True)
class FaultPlan:
    """Fault schedule knobs; all randomness derives from ``seed``. The default
    instance is the empty plan (every rate 0, no preemption)."""

    seed: int = 0
    dropout_rate: float = 0.0        # P(device vanishes mid-round)
    nan_rate: float = 0.0            # P(device emits NaN gradients this round)
    outlier_rate: float = 0.0        # P(device emits outlier-scaled gradients)
    outlier_scale: float = 1e4       # additive magnitude of outlier gradients
    msg_corrupt_rate: float = 0.0    # P(group uplink payload corrupted)
    corrupt_scale: float = 1e6       # finite bit-flip multiplier (else NaN)
    msg_loss_rate: float = 0.0       # P(group round update lost)
    msg_dup_rate: float = 0.0        # P(group round update duplicated)
    latency_spike_rate: float = 0.0  # P(group link stalls this round)
    latency_spike_mult: float = 8.0  # stall duration multiplier
    preempt_round: int = -1          # coordinator dies at this round (-1 = never)

    def __post_init__(self):
        for name in ("dropout_rate", "nan_rate", "outlier_rate",
                     "msg_corrupt_rate", "msg_loss_rate", "msg_dup_rate",
                     "latency_spike_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.latency_spike_mult < 1.0:
            raise ValueError(
                f"latency_spike_mult must be >= 1, got {self.latency_spike_mult}")
        if self.preempt_round < -1:
            raise ValueError(
                f"preempt_round must be >= 0 (or -1 = never), got {self.preempt_round}")

    @property
    def empty(self) -> bool:
        return (self.dropout_rate == self.nan_rate == self.outlier_rate
                == self.msg_corrupt_rate == self.msg_loss_rate
                == self.msg_dup_rate == self.latency_spike_rate == 0.0
                and self.preempt_round < 0)


class RoundFaults(NamedTuple):
    """One round's realized faults (host numpy; the gradient/message terms
    ride into the compiled executor as traced arguments)."""

    drop: np.ndarray          # [M, A] 1.0 = device dropped mid-round
    grad_fault: np.ndarray    # [M, A] additive gradient term (0 = clean)
    msg_fault: np.ndarray     # [M] uplink payload multiplier (0 = clean)
    lost: np.ndarray          # [M] bool: round update lost
    dup: np.ndarray           # [M] bool: round update duplicated
    latency_mult: np.ndarray  # [M] round duration multiplier (>= 1)
    preempt: bool             # coordinator dies at this round boundary

    @property
    def any_device_fault(self) -> bool:
        return bool(self.drop.any() or (self.grad_fault != 0).any()
                    or (self.msg_fault != 0).any())


def _empty_round(M: int, A: int) -> RoundFaults:
    return RoundFaults(
        drop=np.zeros((M, A), np.float32),
        grad_fault=np.zeros((M, A), np.float32),
        msg_fault=np.zeros(M, np.float32),
        lost=np.zeros(M, bool),
        dup=np.zeros(M, bool),
        latency_mult=np.ones(M, np.float64),
        preempt=False,
    )


class FaultInjector:
    """Draws each round's faults from the plan's seeded stream and records a
    replayable trace. Construct with ``replay=`` (or via ``from_trace``) to
    serve a recorded trace back instead of drawing."""

    def __init__(self, plan: Optional[FaultPlan] = None,
                 replay: Optional[List[Dict[str, Any]]] = None):
        self.plan = plan or FaultPlan()
        self._replay = {int(r["round"]): r for r in replay} if replay else None
        self.trace: List[Dict[str, Any]] = list(replay) if replay else []

    # -- drawing / replay ----------------------------------------------------

    def faults(self, round_idx: int, M: int, A: int,
               pmask: Optional[np.ndarray] = None) -> RoundFaults:
        """Round ``round_idx``'s faults for an [M, A]-slot cohort. ``pmask``
        restricts device-level faults to real cohort slots. Deterministic in
        (seed, round): the bucket shape only crops/pads the per-slot draws."""
        if self._replay is not None:
            return self._from_record(self._replay.get(round_idx), M, A)
        plan = self.plan
        real = np.ones((M, A), bool) if pmask is None else np.asarray(pmask) > 0
        rf = _empty_round(M, A)
        if not plan.empty:
            rng = np.random.default_rng([plan.seed, 3, round_idx])
            # each field draws unconditionally, in a fixed order, so one
            # rate's value never shifts another field's stream
            drop = (rng.random((M, A)) < plan.dropout_rate) & real
            nan_dev = (rng.random((M, A)) < plan.nan_rate) & real
            out_dev = (rng.random((M, A)) < plan.outlier_rate) & real
            grad_fault = np.where(nan_dev, np.nan,
                                  np.where(out_dev, plan.outlier_scale, 0.0))
            # a dropped device's update never reaches the server — it cannot
            # also poison the aggregate with a faulty gradient
            grad_fault = np.where(drop, 0.0, grad_fault)
            corrupt = rng.random(M) < plan.msg_corrupt_rate
            corrupt_nan = rng.random(M) < 0.5
            msg_fault = np.where(
                corrupt, np.where(corrupt_nan, np.nan, plan.corrupt_scale), 0.0)
            lost = rng.random(M) < plan.msg_loss_rate
            dup = rng.random(M) < plan.msg_dup_rate
            spike = rng.random(M) < plan.latency_spike_rate
            latency = np.where(spike, plan.latency_spike_mult, 1.0)
            rf = RoundFaults(
                drop=drop.astype(np.float32),
                grad_fault=grad_fault.astype(np.float32),
                msg_fault=msg_fault.astype(np.float32),
                lost=lost, dup=dup, latency_mult=latency,
                preempt=(round_idx == plan.preempt_round),
            )
        self.trace.append(self._to_record(round_idx, rf))
        return rf

    # -- JSON trace ----------------------------------------------------------

    @staticmethod
    def _to_record(round_idx: int, rf: RoundFaults) -> Dict[str, Any]:
        def clean(a):  # JSON has no NaN literal — encode as the string "nan"
            return [["nan" if (isinstance(v, float) and math.isnan(v)) else v
                     for v in row] if isinstance(row, list) else
                    ("nan" if (isinstance(row, float) and math.isnan(row)) else row)
                    for row in a.tolist()]

        return {
            "round": int(round_idx),
            "drop": rf.drop.tolist(),
            "grad_fault": clean(rf.grad_fault.astype(float)),
            "msg_fault": clean(rf.msg_fault.astype(float)),
            "lost": rf.lost.astype(int).tolist(),
            "dup": rf.dup.astype(int).tolist(),
            "latency_mult": rf.latency_mult.tolist(),
            "preempt": bool(rf.preempt),
        }

    @staticmethod
    def _from_record(rec: Optional[Dict[str, Any]], M: int, A: int) -> RoundFaults:
        if rec is None:
            return _empty_round(M, A)

        def arr(key, dtype):
            raw = rec[key]
            a = np.array([[np.nan if v == "nan" else v for v in row]
                          if isinstance(row, list)
                          else (np.nan if row == "nan" else row)
                          for row in raw], dtype)
            return a

        def fit(a, shape):  # crop/pad a recorded array onto this bucket shape
            out = np.zeros(shape, a.dtype)
            if a.ndim == 1:
                n = min(a.shape[0], shape[0])
                out[:n] = a[:n]
            else:
                m, k = min(a.shape[0], shape[0]), min(a.shape[1], shape[1])
                out[:m, :k] = a[:m, :k]
            return out

        lat = fit(arr("latency_mult", np.float64), (M,))
        lat[lat == 0.0] = 1.0
        return RoundFaults(
            drop=fit(arr("drop", np.float32), (M, A)),
            grad_fault=fit(arr("grad_fault", np.float32), (M, A)),
            msg_fault=fit(arr("msg_fault", np.float32), (M,)),
            lost=fit(arr("lost", np.int64), (M,)) > 0,
            dup=fit(arr("dup", np.int64), (M,)) > 0,
            latency_mult=lat,
            preempt=bool(rec.get("preempt", False)),
        )

    def save_trace(self, path: str) -> None:
        """Persist the drawn rounds as a replayable JSON trace (atomic)."""
        atomic_write_json(path, {
            "plan": {k: (None if isinstance(v, float) and math.isnan(v) else v)
                     for k, v in vars(self.plan).items()},
            "rounds": self.trace,
        })

    @classmethod
    def from_trace(cls, path: str) -> "FaultInjector":
        """Replay injector serving a recorded trace back verbatim."""
        with open(path) as f:
            doc = json.load(f)
        plan = FaultPlan(**doc.get("plan", {}))
        return cls(plan, replay=doc.get("rounds", []))
