"""Federation bookkeeping: group/device sampling and weighted aggregation.

Implements eq. (1) (local aggregation over the sampled device subset A_m) and
eq. (2) (global weighted aggregation over groups) plus the A_m / mini-batch
agreement of Algorithm 1 line 13 as jit-friendly index sampling.

Sharding: every [M, ...] tensor is tagged with the logical "group" axis (see
common/sharding.py). Under a non-trivial mesh the group axis rides the
horizontal mesh axes, so eq. (2) lowers to a cross-group reduce collective
and the broadcasts keep their outputs group-sharded instead of gathering a
replicated copy per device. On a trivial mesh every constraint is a no-op.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import FederationConfig
from repro.common.sharding import constrain


def _group_axes(x):
    return ("group",) + (None,) * (x.ndim - 1)


def _constrain_grouped(tree):
    """Tag the leading group axis of every [M, ...] leaf."""
    return jax.tree.map(lambda x: constrain(x, _group_axes(x)), tree)


def local_aggregate(theta2_active, mask=None):
    """Eq. (1): θ2_m = mean over the sampled devices. [M, A, ...] -> [M, ...].

    ``mask`` ([M, A], 1 = real cohort member, 0 = padding slot) restricts the
    mean to the round's actual participants — the cohort path pads device
    slots to a power-of-two bucket, and padded slots must not dilute θ2_m.
    A group with an empty cohort falls back to the plain mean (its slots are
    uniform between rounds, so the fallback is exact; its global weight is
    zeroed by the scheduler anyway).
    """
    if mask is None:
        return _constrain_grouped(
            jax.tree.map(lambda x: jnp.mean(x, axis=1), theta2_active))
    w = mask.astype(jnp.float32)
    cnt = jnp.sum(w, axis=1)  # [M]
    safe = jnp.maximum(cnt, 1.0)

    def agg(x):
        wb = w.reshape(w.shape + (1,) * (x.ndim - 2)).astype(x.dtype)
        masked = jnp.sum(x * wb, axis=1) / safe.reshape(
            (-1,) + (1,) * (x.ndim - 2)).astype(x.dtype)
        plain = jnp.mean(x, axis=1)
        keep = (cnt > 0).reshape((-1,) + (1,) * (x.ndim - 2))
        return jnp.where(keep, masked, plain)

    return _constrain_grouped(jax.tree.map(agg, theta2_active))


def global_aggregate(theta, group_weights):
    """Eq. (2): weighted mean over groups. [M, ...] -> [...].

    With the group axis mesh-sharded this is a weighted reduce collective
    (psum of per-shard partial sums), not a replicated gather.
    """
    w = group_weights / jnp.sum(group_weights)

    def agg(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        return jnp.sum(x * wb, axis=0)

    return jax.tree.map(agg, theta)


def broadcast_to_groups(theta, M: int):
    """Send the global model back to every group. [...] -> [M, ...]."""
    return _constrain_grouped(
        jax.tree.map(lambda x: jnp.broadcast_to(x[None], (M,) + x.shape), theta))


def broadcast_to_devices(theta2_group, A: int):
    """Line 15: every sampled device restarts from the aggregated θ2_m."""
    return _constrain_grouped(jax.tree.map(
        lambda x: jnp.broadcast_to(x[:, None], (x.shape[0], A) + x.shape[1:]), theta2_group
    ))


def sample_participants(key, fed: FederationConfig) -> jnp.ndarray:
    """A_m + ξ_m: per-group device subset (== its samples). [M, A] indices."""
    M, K, A = fed.num_groups, fed.devices_per_group, fed.sampled_devices
    keys = jax.random.split(key, M)

    def pick(k):
        return jax.random.permutation(k, K)[:A]

    return jax.vmap(pick)(keys)


def gather_batch(data: Dict[str, jnp.ndarray], idx: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """data: {x1,x2,y,valid} with leading [M, K]; idx: [M, A] -> [M, A, ...]."""

    def take(x):
        return jax.vmap(lambda xi, ii: jnp.take(xi, ii, axis=0))(x, idx)

    return _constrain_grouped({k: take(v) for k, v in data.items()})
