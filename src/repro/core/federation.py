"""Federation bookkeeping: group/device sampling and weighted aggregation.

Implements eq. (1) (local aggregation over the sampled device subset A_m) and
eq. (2) (global weighted aggregation over groups) plus the A_m / mini-batch
agreement of Algorithm 1 line 13 as jit-friendly index sampling.

Sharding: every [M, ...] tensor is tagged with the logical "group" axis (see
common/sharding.py). Under a non-trivial mesh the group axis rides the
horizontal mesh axes, so eq. (2) lowers to a cross-group reduce collective
and the broadcasts keep their outputs group-sharded instead of gathering a
replicated copy per device. On a trivial mesh every constraint is a no-op.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import FederationConfig
from repro.common.sharding import constrain


def _group_axes(x):
    return ("group",) + (None,) * (x.ndim - 1)


def _constrain_grouped(tree):
    """Tag the leading group axis of every [M, ...] leaf."""
    return jax.tree.map(lambda x: constrain(x, _group_axes(x)), tree)


def local_aggregate(theta2_active, mask=None):
    """Eq. (1): θ2_m = mean over the sampled devices. [M, A, ...] -> [M, ...].

    ``mask`` ([M, A], 1 = real cohort member, 0 = padding slot) restricts the
    mean to the round's actual participants — the cohort path pads device
    slots to a power-of-two bucket, and padded slots must not dilute θ2_m.
    A group with an empty cohort falls back to the plain mean (its slots are
    uniform between rounds, so the fallback is exact; its global weight is
    zeroed by the scheduler anyway).
    """
    if mask is None:
        return _constrain_grouped(
            jax.tree.map(lambda x: jnp.mean(x, axis=1), theta2_active))
    w = mask.astype(jnp.float32)
    cnt = jnp.sum(w, axis=1)  # [M]
    safe = jnp.maximum(cnt, 1.0)

    def agg(x):
        wb = w.reshape(w.shape + (1,) * (x.ndim - 2)).astype(x.dtype)
        masked = jnp.sum(x * wb, axis=1) / safe.reshape(
            (-1,) + (1,) * (x.ndim - 2)).astype(x.dtype)
        plain = jnp.mean(x, axis=1)
        keep = (cnt > 0).reshape((-1,) + (1,) * (x.ndim - 2))
        return jnp.where(keep, masked, plain)

    return _constrain_grouped(jax.tree.map(agg, theta2_active))


def worker_sqnorm(tree, lead: int):
    """Σ_leaves ‖·‖² per worker: [M, ...] -> [M] (lead=1) or
    [M, A, ...] -> [M, A] (lead=2). NaN/Inf anywhere in a worker's slice
    poisons its entry, so ``isfinite(worker_sqnorm(g))`` is the one-reduction
    finite-value screen."""
    per = jax.tree.map(
        lambda x: jnp.sum((x * x).astype(jnp.float32),
                          axis=tuple(range(lead, x.ndim))), tree)
    return sum(jax.tree_util.tree_leaves(per))


def masked_median_values(v, w):
    """Median of the ``w > 0`` entries along axis 1: [M, A] -> [M].

    Excluded slots sort to the end behind a dtype-max sentinel; a row with no
    selected entry returns the sentinel (callers guard on their own count).
    """
    big = jnp.asarray(jnp.finfo(v.dtype).max, v.dtype)
    s = jnp.sort(jnp.where(w > 0, v, big), axis=1)
    cnt = jnp.sum((w > 0).astype(jnp.int32), axis=1)
    lo = jnp.maximum((cnt - 1) // 2, 0)
    hi = jnp.maximum(cnt // 2, 0)
    take = lambda i: jnp.take_along_axis(s, i[:, None], axis=1)[:, 0]
    med = 0.5 * (take(lo) + take(hi))
    return jnp.where(cnt > 0, med, big)


def _robust_center(x, w, method: str, trim_frac: float):
    """Robust masked center along the device axis: [M, A, ...] -> [M, ...].

    ``w`` [M, A] selects the contributing slots. "mean" is the masked mean;
    "median"/"trimmed" sort each coordinate with excluded slots pushed to the
    end behind a dtype-max sentinel and read the order statistics. Rows with
    zero contributing slots return sentinel-valued garbage — callers select
    those rows away (see ``robust_local_aggregate``).
    """
    cnt = jnp.sum(w, axis=1)  # [M]
    safe = jnp.maximum(cnt, 1.0)
    shape_m = lambda x: (-1,) + (1,) * (x.ndim - 2)
    wb = w.reshape(w.shape + (1,) * (x.ndim - 2)).astype(x.dtype)
    if method == "mean":
        return (jnp.sum(jnp.where(wb > 0, x, 0.0), axis=1)
                / safe.reshape(shape_m(x)).astype(x.dtype))
    big = jnp.asarray(jnp.finfo(x.dtype).max, x.dtype)
    s = jnp.sort(jnp.where(wb > 0, x, big), axis=1)
    if method == "median":
        cnt_i = cnt.astype(jnp.int32)
        lo = jnp.maximum((cnt_i - 1) // 2, 0).reshape((-1, 1) + shape_m(x)[1:])
        hi = jnp.maximum(cnt_i // 2, 0).reshape((-1, 1) + shape_m(x)[1:])
        take = lambda i: jnp.take_along_axis(
            s, jnp.broadcast_to(i, (x.shape[0], 1) + x.shape[2:]), axis=1)[:, 0]
        return 0.5 * (take(lo) + take(hi))
    if method != "trimmed":
        raise ValueError(f"unknown robust method {method!r}")
    t = jnp.minimum(jnp.floor(trim_frac * cnt), jnp.floor((cnt - 1.0) / 2.0))
    t = jnp.maximum(t, 0.0)  # cnt = 0 rows: keep the window empty-but-sane
    pos = jnp.arange(x.shape[1], dtype=jnp.float32).reshape(
        (1, -1) + (1,) * (x.ndim - 2))
    keep = ((pos >= t.reshape(shape_m(x))[:, None])
            & (pos < (cnt - t).reshape(shape_m(x))[:, None])).astype(x.dtype)
    denom = jnp.maximum(cnt - 2.0 * t, 1.0).reshape(shape_m(x)).astype(x.dtype)
    return jnp.sum(s * keep, axis=1) / denom


def robust_local_aggregate(theta2_active, pmask, trust, method: str = "median",
                           trim_frac: float = 0.1):
    """Eq. (1) under screening: [M, A, ...] -> [M, ...].

    ``pmask`` marks the round's real cohort slots, ``trust`` (same shape,
    1.0 = screening accepted every update this slot applied) the surviving
    ones. Per group:

      * screening passed (no real slot flagged) -> the EXACT
        ``local_aggregate(x, pmask)`` result, selected through ``jnp.where``
        — the fault-free path stays bit-identical to the masked mean;
      * flagged, with survivors -> the robust center over the surviving
        slots (masked mean / coordinate-wise median / trimmed mean);
      * flagged, no survivors -> the masked-mean fallback (the group is
        poisoned either way; its weight is zeroed upstream).
    """
    w = pmask * trust
    flagged = jnp.sum(pmask * (1.0 - trust), axis=1)  # [M] flagged real slots
    cnt = jnp.sum(w, axis=1)
    use_robust = (flagged > 0) & (cnt > 0)
    plain = local_aggregate(theta2_active, pmask)

    def robust_path(_):
        def sel(x_full, x_plain):
            rob = _robust_center(x_full, w, method, trim_frac)
            keep = use_robust.reshape((-1,) + (1,) * (x_plain.ndim - 1))
            return jnp.where(keep, rob, x_plain)

        return jax.tree.map(sel, theta2_active, plain)

    # lax.cond, not jnp.where: an XLA conditional runs ONLY the taken branch,
    # so fault-free rounds never pay for the per-coordinate sorts (the
    # measured defense overhead budget is < 10% steps/s) — and the clean
    # branch returns the plain masked mean object itself, bit-identically
    out = jax.lax.cond(jnp.any(use_robust), robust_path, lambda _: plain, None)
    return _constrain_grouped(out)


def global_aggregate(theta, group_weights):
    """Eq. (2): weighted mean over groups. [M, ...] -> [...].

    With the group axis mesh-sharded this is a weighted reduce collective
    (psum of per-shard partial sums), not a replicated gather.
    """
    w = group_weights / jnp.sum(group_weights)

    def agg(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        return jnp.sum(x * wb, axis=0)

    return jax.tree.map(agg, theta)


def broadcast_to_groups(theta, M: int):
    """Send the global model back to every group. [...] -> [M, ...]."""
    return _constrain_grouped(
        jax.tree.map(lambda x: jnp.broadcast_to(x[None], (M,) + x.shape), theta))


def broadcast_to_devices(theta2_group, A: int):
    """Line 15: every sampled device restarts from the aggregated θ2_m."""
    return _constrain_grouped(jax.tree.map(
        lambda x: jnp.broadcast_to(x[:, None], (x.shape[0], A) + x.shape[1:]), theta2_group
    ))


def sample_participants(key, fed: FederationConfig) -> jnp.ndarray:
    """A_m + ξ_m: per-group device subset (== its samples). [M, A] indices."""
    M, K, A = fed.num_groups, fed.devices_per_group, fed.sampled_devices
    keys = jax.random.split(key, M)

    def pick(k):
        return jax.random.permutation(k, K)[:A]

    return jax.vmap(pick)(keys)


def gather_batch(data: Dict[str, jnp.ndarray], idx: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """data: {x1,x2,y,valid} with leading [M, K]; idx: [M, A] -> [M, A, ...]."""

    def take(x):
        return jax.vmap(lambda xi, ii: jnp.take(xi, ii, axis=0))(x, idx)

    return _constrain_grouped({k: take(v) for k, v in data.items()})
