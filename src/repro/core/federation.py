"""Federation bookkeeping: group/device sampling and weighted aggregation.

Implements eq. (1) (local aggregation over the sampled device subset A_m) and
eq. (2) (global weighted aggregation over groups) plus the A_m / mini-batch
agreement of Algorithm 1 line 13 as jit-friendly index sampling.

Sharding: every [M, ...] tensor is tagged with the logical "group" axis (see
common/sharding.py). Under a non-trivial mesh the group axis rides the
horizontal mesh axes, so eq. (2) lowers to a cross-group reduce collective
and the broadcasts keep their outputs group-sharded instead of gathering a
replicated copy per device. On a trivial mesh every constraint is a no-op.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FederationConfig
from repro.common.sharding import constrain


def _group_axes(x):
    return ("group",) + (None,) * (x.ndim - 1)


def _constrain_grouped(tree):
    """Tag the leading group axis of every [M, ...] leaf."""
    return jax.tree.map(lambda x: constrain(x, _group_axes(x)), tree)


def local_aggregate(theta2_active, mask=None):
    """Eq. (1): θ2_m = mean over the sampled devices. [M, A, ...] -> [M, ...].

    ``mask`` ([M, A], 1 = real cohort member, 0 = padding slot) restricts the
    mean to the round's actual participants — the cohort path pads device
    slots to a power-of-two bucket, and padded slots must not dilute θ2_m.
    A group with an empty cohort falls back to the plain mean (its slots are
    uniform between rounds, so the fallback is exact; its global weight is
    zeroed by the scheduler anyway).
    """
    if mask is None:
        return _constrain_grouped(
            jax.tree.map(lambda x: jnp.mean(x, axis=1), theta2_active))
    w = mask.astype(jnp.float32)
    cnt = jnp.sum(w, axis=1)  # [M]
    safe = jnp.maximum(cnt, 1.0)

    def agg(x):
        wb = w.reshape(w.shape + (1,) * (x.ndim - 2)).astype(x.dtype)
        masked = jnp.sum(x * wb, axis=1) / safe.reshape(
            (-1,) + (1,) * (x.ndim - 2)).astype(x.dtype)
        plain = jnp.mean(x, axis=1)
        keep = (cnt > 0).reshape((-1,) + (1,) * (x.ndim - 2))
        return jnp.where(keep, masked, plain)

    return _constrain_grouped(jax.tree.map(agg, theta2_active))


# ---------------------------------------------------------------------------
# Secure aggregation (pairwise-mask simulation, Bonawitz-style)
# ---------------------------------------------------------------------------

# Reserved RNG stream index for pairwise masks: default_rng([seed, 4, r, m, i, j]).
# Streams 0 (registry), 1 (cohort), 2 (typical tails), 3 (faults) are taken —
# see the reprolint RP10 registry in analysis/rules.py.
SECURE_AGG_STREAM = 4
# Fixed-point fractional bits of the ℤ_{2^32} ring encoding. Exact-sum
# requirement: |Σ_i x_i| · 2^FRAC_BITS < 2^31 per coordinate, comfortably met
# by O(1)-magnitude parameters over cohorts of <= a few hundred slots.
SECURE_AGG_FRAC_BITS = 16


def secure_agg_masks(template, seed: int, round_idx: int, alive=None):
    """Pairwise antisymmetric int32 uplink masks for one round (host-side).

    ``template`` is the [M, A, ...] uplink pytree (θ2); the result has the
    same structure in int32. For each group m and alive pair i < j, a mask
    ``p`` is drawn from ``np.random.default_rng([seed, 4, round_idx, m, i, j])``
    (fresh reserved stream index — cannot collide with the registry / cohort /
    tails / fault streams) and slot i carries +p while slot j carries -p, so
    the ring sum over the alive slots cancels EXACTLY: integer addition mod
    2^32 is associative, unlike float. Dropout (PR 9 screening) is handled by
    re-keying per round over the surviving cohort — pass the survivors as
    ``alive`` [M, A] and dead slots get (and owe) no masks.
    """
    leaves, treedef = jax.tree_util.tree_flatten(template)
    M, A = leaves[0].shape[:2]
    if alive is None:
        alive_np = np.ones((M, A), bool)
    else:
        alive_np = np.asarray(alive) > 0
    nets = [np.zeros(l.shape, np.int64) for l in leaves]
    for m in range(M):
        for i in range(A):
            for j in range(i + 1, A):
                if not (alive_np[m, i] and alive_np[m, j]):
                    continue
                rng = np.random.default_rng(
                    [seed, SECURE_AGG_STREAM, round_idx, m, i, j])
                for li, l in enumerate(leaves):
                    p = rng.integers(-(2**31), 2**31, size=l.shape[2:],
                                     dtype=np.int64)
                    nets[li][m, i] += p
                    nets[li][m, j] -= p
    masks = [jnp.asarray((n & 0xFFFFFFFF).astype(np.uint32).view(np.int32))
             for n in nets]
    return jax.tree_util.tree_unflatten(treedef, masks)


def _ring_encode(x, frac_bits: int):
    return jnp.round(x.astype(jnp.float32) * (2.0 ** frac_bits)).astype(jnp.int32)


def secure_mask_uplink(theta2_active, masks, frac_bits: int = SECURE_AGG_FRAC_BITS):
    """Worker-side masking: fixed-point encode the uplink, add the pairwise
    mask with wrapping int32 addition. The result is what leaves the device —
    each slot's payload is uniform over the ring (mask-one-time-pad), so a
    single masked uplink is statistically uninformative about its θ2."""
    return jax.tree.map(
        lambda x, m: _ring_encode(x, frac_bits) + m, theta2_active, masks)


def secure_local_aggregate(masked_uplink, like, mask=None,
                           frac_bits: int = SECURE_AGG_FRAC_BITS):
    """Eq. (1) over ring-masked uplinks: [M, A, ...] int32 -> [M, ...] float.

    The server sums the masked integers along the device axis (wrapping mod
    2^32 — exact and associative, so the antisymmetric masks cancel to the
    bit) and only then decodes to float and divides by the participant count.
    ``like`` supplies the output dtype per leaf; ``mask`` [M, A] restricts the
    sum to the round's real cohort slots (a group with an empty cohort
    returns zeros — its global weight is zeroed upstream, matching the
    ``local_aggregate`` contract). Bit-parity with the unmasked ring pipeline
    is exact; agreement with the plain float ``local_aggregate`` holds to the
    2^-frac_bits fixed-point resolution.
    """
    leaves, treedef = jax.tree_util.tree_flatten(masked_uplink)
    like_leaves = jax.tree_util.tree_leaves(like)
    M, A = leaves[0].shape[:2]
    if mask is None:
        w = jnp.ones((M, A), jnp.int32)
    else:
        w = (mask > 0).astype(jnp.int32)
    cnt = jnp.sum(w, axis=1)  # [M]
    safe = jnp.maximum(cnt, 1).astype(jnp.float32)
    out = []
    for x, ref in zip(leaves, like_leaves):
        wb = w.reshape(w.shape + (1,) * (x.ndim - 2))
        ring_sum = jnp.sum(x * wb, axis=1)  # wrapping int32: masks cancel
        dec = ring_sum.astype(jnp.float32) / (2.0 ** frac_bits)
        mean = dec / safe.reshape((-1,) + (1,) * (dec.ndim - 1))
        keep = (cnt > 0).reshape((-1,) + (1,) * (dec.ndim - 1))
        out.append(jnp.where(keep, mean, 0.0).astype(ref.dtype))
    return _constrain_grouped(jax.tree_util.tree_unflatten(treedef, out))


def worker_sqnorm(tree, lead: int):
    """Σ_leaves ‖·‖² per worker: [M, ...] -> [M] (lead=1) or
    [M, A, ...] -> [M, A] (lead=2). NaN/Inf anywhere in a worker's slice
    poisons its entry, so ``isfinite(worker_sqnorm(g))`` is the one-reduction
    finite-value screen."""
    per = jax.tree.map(
        lambda x: jnp.sum((x * x).astype(jnp.float32),
                          axis=tuple(range(lead, x.ndim))), tree)
    return sum(jax.tree_util.tree_leaves(per))


def masked_median_values(v, w):
    """Median of the ``w > 0`` entries along axis 1: [M, A] -> [M].

    Excluded slots sort to the end behind a dtype-max sentinel; a row with no
    selected entry returns the sentinel (callers guard on their own count).
    """
    big = jnp.asarray(jnp.finfo(v.dtype).max, v.dtype)
    s = jnp.sort(jnp.where(w > 0, v, big), axis=1)
    cnt = jnp.sum((w > 0).astype(jnp.int32), axis=1)
    lo = jnp.maximum((cnt - 1) // 2, 0)
    hi = jnp.maximum(cnt // 2, 0)
    take = lambda i: jnp.take_along_axis(s, i[:, None], axis=1)[:, 0]
    med = 0.5 * (take(lo) + take(hi))
    return jnp.where(cnt > 0, med, big)


def _robust_center(x, w, method: str, trim_frac: float):
    """Robust masked center along the device axis: [M, A, ...] -> [M, ...].

    ``w`` [M, A] selects the contributing slots. "mean" is the masked mean;
    "median"/"trimmed" sort each coordinate with excluded slots pushed to the
    end behind a dtype-max sentinel and read the order statistics. Rows with
    zero contributing slots return sentinel-valued garbage — callers select
    those rows away (see ``robust_local_aggregate``).
    """
    cnt = jnp.sum(w, axis=1)  # [M]
    safe = jnp.maximum(cnt, 1.0)
    shape_m = lambda x: (-1,) + (1,) * (x.ndim - 2)
    wb = w.reshape(w.shape + (1,) * (x.ndim - 2)).astype(x.dtype)
    if method == "mean":
        return (jnp.sum(jnp.where(wb > 0, x, 0.0), axis=1)
                / safe.reshape(shape_m(x)).astype(x.dtype))
    big = jnp.asarray(jnp.finfo(x.dtype).max, x.dtype)
    s = jnp.sort(jnp.where(wb > 0, x, big), axis=1)
    if method == "median":
        cnt_i = cnt.astype(jnp.int32)
        lo = jnp.maximum((cnt_i - 1) // 2, 0).reshape((-1, 1) + shape_m(x)[1:])
        hi = jnp.maximum(cnt_i // 2, 0).reshape((-1, 1) + shape_m(x)[1:])
        take = lambda i: jnp.take_along_axis(
            s, jnp.broadcast_to(i, (x.shape[0], 1) + x.shape[2:]), axis=1)[:, 0]
        return 0.5 * (take(lo) + take(hi))
    if method != "trimmed":
        raise ValueError(f"unknown robust method {method!r}")
    t = jnp.minimum(jnp.floor(trim_frac * cnt), jnp.floor((cnt - 1.0) / 2.0))
    t = jnp.maximum(t, 0.0)  # cnt = 0 rows: keep the window empty-but-sane
    pos = jnp.arange(x.shape[1], dtype=jnp.float32).reshape(
        (1, -1) + (1,) * (x.ndim - 2))
    keep = ((pos >= t.reshape(shape_m(x))[:, None])
            & (pos < (cnt - t).reshape(shape_m(x))[:, None])).astype(x.dtype)
    denom = jnp.maximum(cnt - 2.0 * t, 1.0).reshape(shape_m(x)).astype(x.dtype)
    return jnp.sum(s * keep, axis=1) / denom


def robust_local_aggregate(theta2_active, pmask, trust, method: str = "median",
                           trim_frac: float = 0.1, agg_masks=None):
    """Eq. (1) under screening: [M, A, ...] -> [M, ...].

    ``pmask`` marks the round's real cohort slots, ``trust`` (same shape,
    1.0 = screening accepted every update this slot applied) the surviving
    ones. Per group:

      * screening passed (no real slot flagged) -> the EXACT
        ``local_aggregate(x, pmask)`` result, selected through ``jnp.where``
        — the fault-free path stays bit-identical to the masked mean;
      * flagged, with survivors -> the robust center over the surviving
        slots (masked mean / coordinate-wise median / trimmed mean);
      * flagged, no survivors -> the masked-mean fallback (the group is
        poisoned either way; its weight is zeroed upstream).

    ``agg_masks`` routes the clean-path mean through the secure-aggregation
    ring pipeline. The robust branch still reads the plaintext slots — a
    simulation privilege: coordinate-wise medians are nonlinear, so a real
    deployment cannot run them under vanilla pairwise masking and would pair
    screening with a different primitive.
    """
    w = pmask * trust
    flagged = jnp.sum(pmask * (1.0 - trust), axis=1)  # [M] flagged real slots
    cnt = jnp.sum(w, axis=1)
    use_robust = (flagged > 0) & (cnt > 0)
    if agg_masks is not None:
        plain = secure_local_aggregate(
            secure_mask_uplink(theta2_active, agg_masks), theta2_active, pmask)
    else:
        plain = local_aggregate(theta2_active, pmask)

    def robust_path(_):
        def sel(x_full, x_plain):
            rob = _robust_center(x_full, w, method, trim_frac)
            keep = use_robust.reshape((-1,) + (1,) * (x_plain.ndim - 1))
            return jnp.where(keep, rob, x_plain)

        return jax.tree.map(sel, theta2_active, plain)

    # lax.cond, not jnp.where: an XLA conditional runs ONLY the taken branch,
    # so fault-free rounds never pay for the per-coordinate sorts (the
    # measured defense overhead budget is < 10% steps/s) — and the clean
    # branch returns the plain masked mean object itself, bit-identically
    out = jax.lax.cond(jnp.any(use_robust), robust_path, lambda _: plain, None)
    return _constrain_grouped(out)


def global_aggregate(theta, group_weights):
    """Eq. (2): weighted mean over groups. [M, ...] -> [...].

    With the group axis mesh-sharded this is a weighted reduce collective
    (psum of per-shard partial sums), not a replicated gather.
    """
    w = group_weights / jnp.sum(group_weights)

    def agg(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        return jnp.sum(x * wb, axis=0)

    return jax.tree.map(agg, theta)


def broadcast_to_groups(theta, M: int):
    """Send the global model back to every group. [...] -> [M, ...]."""
    return _constrain_grouped(
        jax.tree.map(lambda x: jnp.broadcast_to(x[None], (M,) + x.shape), theta))


def broadcast_to_devices(theta2_group, A: int):
    """Line 15: every sampled device restarts from the aggregated θ2_m."""
    return _constrain_grouped(jax.tree.map(
        lambda x: jnp.broadcast_to(x[:, None], (x.shape[0], A) + x.shape[1:]), theta2_group
    ))


def sample_participants(key, fed: FederationConfig) -> jnp.ndarray:
    """A_m + ξ_m: per-group device subset (== its samples). [M, A] indices."""
    M, K, A = fed.num_groups, fed.devices_per_group, fed.sampled_devices
    keys = jax.random.split(key, M)

    def pick(k):
        return jax.random.permutation(k, K)[:A]

    return jax.vmap(pick)(keys)


def gather_batch(data: Dict[str, jnp.ndarray], idx: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """data: {x1,x2,y,valid} with leading [M, K]; idx: [M, A] -> [M, A, ...]."""

    def take(x):
        return jax.vmap(lambda xi, ii: jnp.take(xi, ii, axis=0))(x, idx)

    return _constrain_grouped({k: take(v) for k, v in data.items()})
