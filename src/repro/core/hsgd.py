"""Hybrid Stochastic Gradient Descent — the paper's Algorithm 1.

Training runs as a jitted 3-level loop mirroring the paper's timeline:

  scan over R global rounds                      (t mod P == 0 events)
    ├─ local agg (eq 1) + global agg (eq 2) + broadcasts (Alg. 1 lines 3–9)
    └─ scan over Λ = P/Q local intervals         (t mod Q == 0 events)
         ├─ local aggregation (eq 1, lines 10–12)
         ├─ A_m/ξ_m agreement + intermediate-result EXCHANGE (lines 13–21):
         │    ζ1 = h1(θ1; X1ξ), ζ2 = h2(θ2; X2ξ), stale θ0 snapshot
         │    (optionally top-k/quantize compressed — C-HSGD)
         └─ scan over Q SGD steps (lines 22–26):
              hospital: (θ0,θ1) step with FRESH ζ1, STALE ζ2   (eqs 5–6)
              devices:  θ2_n step with STALE θ0, STALE ζ1      (eq 7)

Only the sampled devices A_m are materialized ([M, A, ...]): unsampled
devices are reset to θ2_m at every local aggregation anyway (line 15), so
their state never influences the trajectory.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import FederationConfig, TrainConfig
from repro.core import federation as F
from repro.core.compression import compress_message_sort
from repro.models.split_model import HybridModel
from repro.optim import halving_schedule


class HSGDState(NamedTuple):
    theta0: Any  # [M, ...] combined models
    theta1: Any  # [M, ...] hospital towers
    theta2: Any  # [M, A, ...] sampled-device towers
    stale: Dict[str, Any]  # {"theta0": [M,...], "z1": [M,A,...], "z2": [M,A,...]}
    batch: Dict[str, jnp.ndarray]  # gathered ξ_m: x1,x2,y,valid [M,A,...]
    key: jnp.ndarray
    step: jnp.ndarray


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------


def init_state(key, model: HybridModel, fed: FederationConfig, data, dtype=jnp.float32) -> HSGDState:
    """All groups start from the same global model (Alg. 1 line 1)."""
    k_init, k_run = jax.random.split(key)
    params = model.init(k_init, dtype)
    M, A = fed.num_groups, fed.sampled_devices
    theta0 = F.broadcast_to_groups(params["theta0"], M)
    theta1 = F.broadcast_to_groups(params["theta1"], M)
    theta2 = F.broadcast_to_devices(F.broadcast_to_groups(params["theta2"], M), A)
    # placeholder stale ctx/batch: every run/round exchanges before the first
    # SGD step, so the placeholders are overwritten unread — shape them with
    # eval_shape (zero FLOPs) instead of running real forward passes.
    idx = jnp.zeros((M, A), jnp.int32)
    batch = F.gather_batch(data, idx)
    z_shapes = jax.eval_shape(
        lambda t1, t2, b: (
            _h1_groups(model, t1, b["x1"]),
            _h2_groups(model, F.local_aggregate(t2), b["x2"]),
        ),
        theta1, theta2, batch,
    )
    z1, z2 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), z_shapes)
    # distinct buffers from theta0: donation in run() must not see aliases
    stale = {"theta0": jax.tree.map(jnp.copy, theta0), "z1": z1, "z2": z2}
    return HSGDState(theta0, theta1, theta2, stale, batch, k_run, jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Forward helpers (vmapped over groups / devices)
# ---------------------------------------------------------------------------


def _h1_groups(model, theta1, x1):
    """[M,...]θ1 × [M,A,...]x1 -> ζ1 [M,A,...]."""
    return jax.vmap(model.h1)(theta1, x1)


def _h2_groups(model, theta2_group, x2):
    """[M,...]θ2_m × [M,A,...]x2 -> ζ2 [M,A,...] (device outputs from θ2_m)."""
    return jax.vmap(model.h2)(theta2_group, x2)


# ---------------------------------------------------------------------------
# The three gradient rules (eqs. (5)–(7))
# ---------------------------------------------------------------------------


def _hospital_loss(model, theta0_m, theta1_m, batch_m, stale_z2_m):
    """Group-level loss with fresh ζ1(θ1), stale ζ2 — drives eqs. (5)(6)."""
    z1 = model.h1(theta1_m, batch_m["x1"])
    return model.loss(theta0_m, z1, jax.lax.stop_gradient(stale_z2_m), batch_m["y"])


def _device_loss(model, theta2_n, x2_n, y_n, stale_theta0_m, stale_z1_n):
    """Per-device loss with stale θ0, stale ζ1, fresh ζ2(θ2_n) — eq. (7)."""
    z2 = model.h2(theta2_n, x2_n[None])
    return model.loss(
        jax.lax.stop_gradient(stale_theta0_m),
        jax.lax.stop_gradient(stale_z1_n[None]),
        z2,
        y_n[None],
    )


def local_sgd_step(model: HybridModel, state: HSGDState, lr) -> Tuple[HSGDState, jnp.ndarray]:
    """One iteration of lines 22–26 for every group and sampled device."""

    def h_loss(t0_m, t1_m, b_m, z2_m):
        return _hospital_loss(model, t0_m, t1_m, b_m, z2_m)

    h_grads = jax.vmap(jax.value_and_grad(h_loss, argnums=(0, 1)))(
        state.theta0, state.theta1, state.batch, state.stale["z2"]
    )
    (losses, (g0, g1)) = h_grads

    def d_loss(t2_n, x2_n, y_n, t0_m, z1_n):
        return _device_loss(model, t2_n, x2_n, y_n, t0_m, z1_n)

    per_device = jax.vmap(  # over devices within a group
        jax.grad(d_loss), in_axes=(0, 0, 0, None, 0)
    )
    g2 = jax.vmap(per_device)(  # over groups
        state.theta2, state.batch["x2"], state.batch["y"], state.stale["theta0"], state.stale["z1"]
    )

    upd = lambda p, g: p - lr * g.astype(p.dtype)
    theta0 = jax.tree.map(upd, state.theta0, g0)
    theta1 = jax.tree.map(upd, state.theta1, g1)
    theta2 = jax.tree.map(upd, state.theta2, g2)
    new_state = state._replace(theta0=theta0, theta1=theta1, theta2=theta2, step=state.step + 1)
    return new_state, jnp.mean(losses)


# ---------------------------------------------------------------------------
# Exchange + aggregations
# ---------------------------------------------------------------------------


def exchange(
    model: HybridModel,
    state: HSGDState,
    data,
    fed: FederationConfig,
    compression_k: float = 0.0,
    quant_levels: int = 0,
    fused: bool = True,
) -> HSGDState:
    """Local aggregation (eq 1) + A_m/ξ_m agreement + ζ/θ0 exchange.

    With compression on, the whole exchange message (θ0 snapshot pytree + ζ1
    + ζ2) is compressed in ONE fused top-k+quantize row-matrix call (Pallas
    kernel on TPU, fused jnp elsewhere). ``fused=False`` keeps the pre-fusion
    leaf-wise sort-based path for benchmarking.
    """
    key, k_sample = jax.random.split(state.key)
    theta2_group = F.local_aggregate(state.theta2)  # eq (1)
    theta2 = F.broadcast_to_devices(theta2_group, fed.sampled_devices)  # line 15

    idx = F.sample_participants(k_sample, fed)  # line 13
    batch = F.gather_batch(data, idx)

    z1 = _h1_groups(model, state.theta1, batch["x1"])
    z2 = _h2_groups(model, theta2_group, batch["x2"])
    stale_theta0 = state.theta0

    if compression_k or quant_levels:
        msg = {"theta0": stale_theta0, "z1": z1, "z2": z2}
        if fused:
            from repro.kernels.compress import compress_pytree

            msg = compress_pytree(msg, compression_k or 1.0, quant_levels)
        else:
            comp = partial(compress_message_sort, k_frac=compression_k or 1.0,
                           levels=quant_levels)
            msg = jax.tree.map(comp, msg)
        stale_theta0, z1, z2 = msg["theta0"], msg["z1"], msg["z2"]

    stale = {"theta0": stale_theta0, "z1": z1, "z2": z2}
    return state._replace(theta2=theta2, stale=stale, batch=batch, key=key)


def global_aggregation(state: HSGDState, fed: FederationConfig, group_weights) -> HSGDState:
    """Eq. (2) + broadcasts (Alg. 1 lines 3–9)."""
    M, A = fed.num_groups, fed.sampled_devices
    theta2_group = F.local_aggregate(state.theta2)
    g0 = F.global_aggregate(state.theta0, group_weights)
    g1 = F.global_aggregate(state.theta1, group_weights)
    g2 = F.global_aggregate(theta2_group, group_weights)
    return state._replace(
        theta0=F.broadcast_to_groups(g0, M),
        theta1=F.broadcast_to_groups(g1, M),
        theta2=F.broadcast_to_devices(F.broadcast_to_groups(g2, M), A),
    )


def global_model(state: HSGDState, group_weights) -> Dict[str, Any]:
    """The observable global model θ̃ (eq. (2))."""
    return {
        "theta0": F.global_aggregate(state.theta0, group_weights),
        "theta1": F.global_aggregate(state.theta1, group_weights),
        "theta2": F.global_aggregate(F.local_aggregate(state.theta2), group_weights),
    }


# ---------------------------------------------------------------------------
# Full jitted training run
# ---------------------------------------------------------------------------


def state_shardings(state: HSGDState, mesh: Mesh, rules=None) -> HSGDState:
    """NamedShardings for an HSGDState: the leading group axis M rides the
    mesh's horizontal ("data"/"pod") axes via the logical "group" rule; key
    and step stay replicated. Non-divisible leaves fall back to replication,
    so a trivial mesh degrades to the single-device layout."""
    from repro.common.sharding import group_sharding

    repl = NamedSharding(mesh, P())
    grouped = lambda tree: jax.tree.map(lambda x: group_sharding(x.shape, mesh, rules), tree)
    return HSGDState(
        theta0=grouped(state.theta0),
        theta1=grouped(state.theta1),
        theta2=grouped(state.theta2),
        stale=grouped(state.stale),
        batch=grouped(state.batch),
        key=repl,
        step=repl,
    )


@dataclass(frozen=True)
class HSGDRunner:
    """Compiled HSGD trainer for a (model, federation, train) configuration.

    ``run`` donates the state argument: the full replicated [M, A, ...] pytree
    is updated in place instead of double-buffered, so the caller's input
    state is consumed (rebind the return value, as every call site does).
    Passing a non-trivial ``mesh`` shards every leading group axis over the
    mesh's horizontal axes, lowering the eq. (1)/(2) aggregations and
    broadcasts to collectives instead of replicated gathers.
    """

    model: HybridModel
    fed: FederationConfig
    train: TrainConfig
    do_global_agg: bool = True  # False reproduces TDCD's missing phase
    fused_compression: bool = True  # False keeps the pre-fusion sort path

    def _round(self, state: HSGDState, data, group_weights, lr_fn):
        fed, model = self.fed, self.model
        Q, lam = fed.local_interval, fed.lam

        if self.do_global_agg:
            state = global_aggregation(state, fed, group_weights)

        def interval(state, _):
            state = exchange(
                model, state, data, fed,
                self.train.compression_k, self.train.quantization_bits,
                fused=self.fused_compression,
            )

            def sgd_step(state, _):
                lr = lr_fn(state.step)
                state, loss = local_sgd_step(model, state, lr)
                return state, loss

            state, losses = jax.lax.scan(sgd_step, state, None, length=Q)
            return state, losses

        state, losses = jax.lax.scan(interval, state, None, length=lam)
        return state, losses.reshape(-1)

    def run(self, state: HSGDState, data, group_weights, rounds: int,
            mesh: Optional[Mesh] = None):
        """Execute ``rounds`` global rounds; returns (state, per-step losses).

        Donates ``state`` (no double-buffering of the [M, A, ...] pytree).
        """
        lr_fn = halving_schedule(self.train.learning_rate, self.train.lr_halve_every)

        if mesh is not None and mesh.devices.size > 1:
            from repro.common.sharding import group_sharding

            state = jax.device_put(state, state_shardings(state, mesh))
            data = jax.device_put(
                data, jax.tree.map(lambda x: group_sharding(x.shape, mesh), data))
            group_weights = jax.device_put(group_weights, NamedSharding(mesh, P()))

        @partial(jax.jit, donate_argnums=(0,))
        def go(state, data, group_weights):
            def body(state, _):
                return self._round(state, data, group_weights, lr_fn)

            return jax.lax.scan(body, state, None, length=rounds)

        state, losses = go(state, data, group_weights)
        return state, losses.reshape(-1)


def make_group_weights(data) -> jnp.ndarray:
    """K_m weights from the per-group valid-sample counts."""
    return jnp.sum(data["valid"].astype(jnp.float32), axis=1)
