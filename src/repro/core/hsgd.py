"""Hybrid Stochastic Gradient Descent — the paper's Algorithm 1.

Training runs as a jitted 3-level loop mirroring the paper's timeline:

  scan over R global rounds                      (t mod P == 0 events)
    ├─ local agg (eq 1) + global agg (eq 2) + broadcasts (Alg. 1 lines 3–9)
    └─ scan over Λ = P/Q local intervals         (t mod Q == 0 events)
         ├─ local aggregation (eq 1, lines 10–12)
         ├─ A_m/ξ_m agreement + intermediate-result EXCHANGE (lines 13–21):
         │    ζ1 = h1(θ1; X1ξ), ζ2 = h2(θ2; X2ξ), stale θ0 snapshot
         │    (optionally top-k/quantize compressed — C-HSGD)
         └─ scan over Q SGD steps (lines 22–26):
              hospital: (θ0,θ1) step with FRESH ζ1, STALE ζ2   (eqs 5–6)
              devices:  θ2_n step with STALE θ0, STALE ζ1      (eq 7)

Only the sampled devices A_m are materialized ([M, A, ...]): unsampled
devices are reset to θ2_m at every local aggregation anyway (line 15), so
their state never influences the trajectory.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import FederationConfig, TrainConfig
from repro.common.pytree import tree_dot, tree_norm, tree_sub
from repro.core import federation as F
from repro.core.compression import compress_message_sort
from repro.models.split_model import HybridModel
from repro.optim import halving_schedule


class HSGDState(NamedTuple):
    theta0: Any  # [M, ...] combined models
    theta1: Any  # [M, ...] hospital towers
    theta2: Any  # [M, A, ...] sampled-device towers
    stale: Dict[str, Any]  # {"theta0": [M,...], "z1": [M,A,...], "z2": [M,A,...]}
    batch: Dict[str, jnp.ndarray]  # gathered ξ_m: x1,x2,y,valid [M,A,...]
    key: jnp.ndarray
    step: jnp.ndarray


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------


def _placeholder_ctx(model: HybridModel, theta1, theta2, data, M: int, A: int):
    """Placeholder (batch, z1, z2) shaped for A device slots per group.

    Every run/round exchanges before the first SGD step, so the placeholders
    are overwritten unread — shape them with eval_shape (zero FLOPs) instead
    of running real forward passes.
    """
    idx = jnp.zeros((M, A), jnp.int32)
    batch = F.gather_batch(data, idx)
    z_shapes = jax.eval_shape(
        lambda t1, t2, b: (
            _h1_groups(model, t1, b["x1"]),
            _h2_groups(model, F.local_aggregate(t2), b["x2"]),
        ),
        theta1, theta2, batch,
    )
    z1, z2 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), z_shapes)
    return batch, z1, z2


def init_state(key, model: HybridModel, fed: FederationConfig, data, dtype=jnp.float32) -> HSGDState:
    """All groups start from the same global model (Alg. 1 line 1)."""
    k_init, k_run = jax.random.split(key)
    params = model.init(k_init, dtype)
    M, A = fed.num_groups, fed.sampled_devices
    theta0 = F.broadcast_to_groups(params["theta0"], M)
    theta1 = F.broadcast_to_groups(params["theta1"], M)
    theta2 = F.broadcast_to_devices(F.broadcast_to_groups(params["theta2"], M), A)
    batch, z1, z2 = _placeholder_ctx(model, theta1, theta2, data, M, A)
    # distinct buffers from theta0: donation in run() must not see aliases
    stale = {"theta0": jax.tree.map(jnp.copy, theta0), "z1": z1, "z2": z2}
    return HSGDState(theta0, theta1, theta2, stale, batch, k_run, jnp.zeros((), jnp.int32))


def resize_cohort(state: HSGDState, model: HybridModel, data, A_new: int) -> HSGDState:
    """Re-bucket the device-slot axis A between rounds ([M, A, ...] -> [M, A_new, ...]).

    Valid only at a round boundary, where every cohort round has already
    checked its device towers back in (θ2 slots uniform: the executor ends
    with θ2 ← broadcast(masked eq. (1))), so collapsing the slot axis by eq.
    (1) and re-broadcasting is exact. The stale/batch placeholders are
    re-shaped the same way ``init_state`` shapes them — the next round's
    first exchange overwrites them unread.
    """
    M, A = jax.tree_util.tree_leaves(state.theta2)[0].shape[:2]
    if A == A_new:
        return state
    theta2_group = F.local_aggregate(state.theta2)
    theta2 = F.broadcast_to_devices(theta2_group, A_new)
    batch, z1, z2 = _placeholder_ctx(model, state.theta1, theta2, data, M, A_new)
    stale = {"theta0": state.stale["theta0"], "z1": z1, "z2": z2}
    return state._replace(theta2=theta2, stale=stale, batch=batch)


# ---------------------------------------------------------------------------
# Forward helpers (vmapped over groups / devices)
# ---------------------------------------------------------------------------


def _h1_groups(model, theta1, x1):
    """[M,...]θ1 × [M,A,...]x1 -> ζ1 [M,A,...]."""
    return jax.vmap(model.h1)(theta1, x1)


def _h2_groups(model, theta2_group, x2):
    """[M,...]θ2_m × [M,A,...]x2 -> ζ2 [M,A,...] (device outputs from θ2_m)."""
    return jax.vmap(model.h2)(theta2_group, x2)


# ---------------------------------------------------------------------------
# The three gradient rules (eqs. (5)–(7))
# ---------------------------------------------------------------------------


def _hospital_loss(model, theta0_m, theta1_m, batch_m, stale_z2_m):
    """Group-level loss with fresh ζ1(θ1), stale ζ2 — drives eqs. (5)(6)."""
    z1 = model.h1(theta1_m, batch_m["x1"])
    return model.loss(theta0_m, z1, jax.lax.stop_gradient(stale_z2_m), batch_m["y"])


def _device_loss(model, theta2_n, x2_n, y_n, stale_theta0_m, stale_z1_n):
    """Per-device loss with stale θ0, stale ζ1, fresh ζ2(θ2_n) — eq. (7)."""
    z2 = model.h2(theta2_n, x2_n[None])
    return model.loss(
        jax.lax.stop_gradient(stale_theta0_m),
        jax.lax.stop_gradient(stale_z1_n[None]),
        z2,
        y_n[None],
    )


def _local_grads(model: HybridModel, state: HSGDState):
    """Per-worker gradients of lines 22–26: (losses [M], g0 [M,...], g1 [M,...],
    g2 [M,A,...]). Shared by the plain step and the probe-collecting step."""

    def h_loss(t0_m, t1_m, b_m, z2_m):
        return _hospital_loss(model, t0_m, t1_m, b_m, z2_m)

    h_grads = jax.vmap(jax.value_and_grad(h_loss, argnums=(0, 1)))(
        state.theta0, state.theta1, state.batch, state.stale["z2"]
    )
    (losses, (g0, g1)) = h_grads

    def d_loss(t2_n, x2_n, y_n, t0_m, z1_n):
        return _device_loss(model, t2_n, x2_n, y_n, t0_m, z1_n)

    per_device = jax.vmap(  # over devices within a group
        jax.grad(d_loss), in_axes=(0, 0, 0, None, 0)
    )
    g2 = jax.vmap(per_device)(  # over groups
        state.theta2, state.batch["x2"], state.batch["y"], state.stale["theta0"], state.stale["z1"]
    )
    return losses, g0, g1, g2


def _apply_sgd(state: HSGDState, lr, g0, g1, g2) -> HSGDState:
    upd = lambda p, g: p - lr * g.astype(p.dtype)
    return state._replace(
        theta0=jax.tree.map(upd, state.theta0, g0),
        theta1=jax.tree.map(upd, state.theta1, g1),
        theta2=jax.tree.map(upd, state.theta2, g2),
        step=state.step + 1,
    )


def local_sgd_step(model: HybridModel, state: HSGDState, lr) -> Tuple[HSGDState, jnp.ndarray]:
    """One iteration of lines 22–26 for every group and sampled device."""
    losses, g0, g1, g2 = _local_grads(model, state)
    return _apply_sgd(state, lr, g0, g1, g2), jnp.mean(losses)


def _worker_dev2(g, gbar, lead: int):
    """Σ_leaves ||g_worker − ḡ||² per worker: [M, ...]→[M] (lead=1) or
    [M, A, ...]→[M, A] (lead=2)."""
    per = jax.tree.map(
        lambda x, m: jnp.sum((x - m.reshape((1,) * lead + m.shape)) ** 2,
                             axis=tuple(range(lead, x.ndim))), g, gbar)
    return sum(jax.tree_util.tree_leaves(per))


def local_sgd_step_stats(
    model: HybridModel, state: HSGDState, lr, group_weights
) -> Tuple[HSGDState, jnp.ndarray, Dict[str, Any]]:
    """``local_sgd_step`` + the §VI-B online probe statistics, reusing the
    step's own gradients (no extra forward/backward passes):

      gbar    — the global-gradient proxy ∇F(θ̃): weighted group mean of
                (g0, g1) and of the device means of g2 (eqs. (1)/(2) applied
                to gradients instead of parameters);
      gnorm2  — ‖gbar‖² (strategy 3's ‖∇F‖² input);
      delta2  — mean squared deviation of per-worker gradients around gbar
                (Assumption 2's δ² estimator).
    """
    losses, g0, g1, g2 = _local_grads(model, state)
    gbar = {
        "theta0": F.global_aggregate(g0, group_weights),
        "theta1": F.global_aggregate(g1, group_weights),
        "theta2": F.global_aggregate(F.local_aggregate(g2), group_weights),
    }
    gnorm2 = tree_dot(gbar, gbar)
    delta2 = (
        jnp.mean(_worker_dev2(g0, gbar["theta0"], 1)
                 + _worker_dev2(g1, gbar["theta1"], 1))
        + jnp.mean(_worker_dev2(g2, gbar["theta2"], 2))
    )
    new_state = _apply_sgd(state, lr, g0, g1, g2)
    aux = {"gbar": gbar, "gnorm2": gnorm2, "delta2": delta2}
    return new_state, jnp.mean(losses), aux


# ---------------------------------------------------------------------------
# Fault injection + compiled screening (the fault-tolerant step)
# ---------------------------------------------------------------------------


def _inject_grads(g2, grad_fault):
    """Add the per-device fault term where nonzero: [M, A] -> every g2 leaf.

    Selected through jnp.where, NOT a blanket ``g + fault``: adding 0.0 would
    flip -0.0 gradients to +0.0 and break the fault-free bit-identity pin.
    NaN fault terms select the faulty branch (NaN != 0 is True). The whole
    injection sits behind a lax.cond: an XLA conditional leaves fault-free
    steps' gradient pipeline untouched at runtime (the per-leaf selects were
    a measurable fraction of the step on small models), and the identity
    branch returns g2 itself — bit-identical by construction.
    """

    def add(g2):
        def leaf(g):
            f = grad_fault.reshape(
                grad_fault.shape + (1,) * (g.ndim - 2)).astype(g.dtype)
            return jnp.where(f != 0, g + f, g)

        return jax.tree.map(leaf, g2)

    return jax.lax.cond(jnp.any(grad_fault != 0), add, lambda g: g, g2)


def local_sgd_step_guarded(
    model: HybridModel,
    state: HSGDState,
    lr,
    pmask: jnp.ndarray,
    grad_fault: Optional[jnp.ndarray] = None,
    screen: bool = False,
    zmax: float = 8.0,
) -> Tuple[HSGDState, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """``local_sgd_step`` with optional fault injection and compiled screening.

    Screening is pure jnp.where masking — no host syncs, RP4-clean — and with
    every mask all-ones the applied update is bit-identical to the unguarded
    step. Per step it zeroes:

      * device updates whose g2 is non-finite, or whose gradient sq-norm
        exceeds ``zmax² ×`` the group's masked median device sq-norm
        (norm-outlier screen over the real, finite cohort slots);
      * group (θ0, θ1) updates whose hospital gradient is non-finite, or —
        with ≥ 3 groups — an outlier against the cross-group median norm.

    Returns (state, loss, dev_ok [M, A], grp_ok [M]); the reported loss
    averages only unflagged groups when any group is flagged.
    """
    losses, g0, g1, g2 = _local_grads(model, state)
    if grad_fault is not None:
        g2 = _inject_grads(g2, grad_fault)
    M = pmask.shape[0]
    if not screen:
        dev_ok = jnp.ones(pmask.shape, jnp.float32)
        grp_ok = jnp.ones((M,), jnp.float32)
        return _apply_sgd(state, lr, g0, g1, g2), jnp.mean(losses), dev_ok, grp_ok

    dn2 = F.worker_sqnorm(g2, lead=2)  # [M, A]
    finite_d = jnp.isfinite(dn2)
    med = F.masked_median_values(dn2, pmask * finite_d)  # [M]
    # Floor the screen scale with the fleet-wide median device norm: a ratio
    # cut against the per-group median alone falsely flags the one device
    # that still has signal once its peers converge (median -> ~0). The
    # floor only ever RAISES cuts, so NaN/Inf (isfinite) and scale faults
    # (x1e4 additive, x1e6 corruption — many orders above any fleet median)
    # are still caught.
    fleet = F.masked_median_values(
        dn2.reshape(1, -1), (pmask * finite_d).reshape(1, -1))[0]
    cut = (zmax * zmax) * jnp.maximum(jnp.maximum(med, fleet), 1e-30)
    dev_ok = (finite_d & (dn2 <= cut[:, None])).astype(jnp.float32)

    hn2 = F.worker_sqnorm(g0, lead=1) + F.worker_sqnorm(g1, lead=1)  # [M]
    grp_fin = jnp.isfinite(hn2)
    if M >= 3:  # the cross-group outlier cut needs a meaningful median
        gmed = F.masked_median_values(hn2[None, :], grp_fin[None, :].astype(jnp.float32))[0]
        # same converged-peer guard: floor with the fleet device-norm median
        gcut = (zmax * zmax) * jnp.maximum(jnp.maximum(gmed, fleet), 1e-30)
        grp_fin = grp_fin & (hn2 <= gcut)
    grp_ok = grp_fin.astype(jnp.float32)

    def mask_grp(g):
        ok = grp_ok.reshape((-1,) + (1,) * (g.ndim - 1))
        return jnp.where(ok > 0, g, jnp.zeros((), g.dtype))

    def mask_dev(g):
        ok = dev_ok.reshape(dev_ok.shape + (1,) * (g.ndim - 2))
        return jnp.where(ok > 0, g, jnp.zeros((), g.dtype))

    g0 = jax.tree.map(mask_grp, g0)
    g1 = jax.tree.map(mask_grp, g1)
    g2 = jax.tree.map(mask_dev, g2)

    n_ok = jnp.sum(grp_ok)
    loss_all = jnp.mean(losses)
    # where, not multiply: a flagged group's NaN loss would poison the sum
    loss_ok = jnp.sum(jnp.where(grp_ok > 0, losses, 0.0)) / jnp.maximum(n_ok, 1.0)
    loss = jnp.where(n_ok == M, loss_all, loss_ok)
    return _apply_sgd(state, lr, g0, g1, g2), loss, dev_ok, grp_ok


# ---------------------------------------------------------------------------
# Exchange + aggregations
# ---------------------------------------------------------------------------


def exchange(
    model: HybridModel,
    state: HSGDState,
    data,
    fed: FederationConfig,
    compression_k: float = 0.0,
    quant_levels: int = 0,
    fused: bool = True,
    idx: Optional[jnp.ndarray] = None,
    pmask: Optional[jnp.ndarray] = None,
    trust: Optional[jnp.ndarray] = None,
    msg_fault: Optional[jnp.ndarray] = None,
    screen: bool = False,
    dp_clip=None,
    dp_sigma=None,
    agg_masks=None,
) -> HSGDState:
    """Local aggregation (eq 1) + A_m/ξ_m agreement + ζ/θ0 exchange.

    With compression on, the whole exchange message (θ0 snapshot pytree + ζ1
    + ζ2) is compressed in ONE fused top-k+quantize row-matrix call (Pallas
    kernel on TPU, fused jnp elsewhere). ``fused=False`` keeps the pre-fusion
    leaf-wise sort-based path for benchmarking.

    The cohort path (see ``core/population.py``) pins the round's participants
    by passing ``idx`` ([M, A] data-row indices, padded to the bucket size by
    repeating real members) and ``pmask`` ([M, A], 0 on padding slots): the
    per-interval A_m draw is skipped and eq. (1) excludes the padding slots.

    The fault-tolerant path adds three optional legs, all pure jnp.where
    selections so the clean case is bit-identical to the plain path:
    ``trust`` ([M, A], 1.0 = slot's updates passed screening) switches eq. (1)
    to ``robust_local_aggregate`` per ``fed.robust_agg``; ``msg_fault`` ([M],
    0 = clean) multiplies the group's compressed ζ2 uplink (bit-flip
    corruption); ``screen`` zeroes non-finite message entries at the receiver.

    Privacy legs (both gated at the Python level — the plain trace is
    unchanged): ``dp_clip``/``dp_sigma`` (traced scalars) run the message
    through the fused per-row clip + Gaussian-noise stage of the compression
    kernel, drawing the precomputed noise rows from a key split off the
    threaded state key; ``agg_masks`` (a per-round int32 pytree from
    ``F.secure_agg_masks``) routes eq. (1) through the pairwise-mask secure-
    aggregation ring, where the masks cancel exactly in the server sum.
    """
    dp = dp_clip is not None
    if dp:  # extra split only on the DP trace: the plain key stream is untouched
        key, k_sample, k_dp = jax.random.split(state.key, 3)
    else:
        key, k_sample = jax.random.split(state.key)
        k_dp = None
    if trust is not None and pmask is not None:
        theta2_group = F.robust_local_aggregate(  # eq (1) under screening
            state.theta2, pmask, trust,
            method=fed.robust_agg, trim_frac=fed.trim_frac,
            agg_masks=agg_masks)
    elif agg_masks is not None:
        theta2_group = F.secure_local_aggregate(  # eq (1) over masked uplinks
            F.secure_mask_uplink(state.theta2, agg_masks), state.theta2, pmask)
    else:
        theta2_group = F.local_aggregate(state.theta2, pmask)  # eq (1)
    A = fed.sampled_devices if idx is None else idx.shape[1]
    theta2 = F.broadcast_to_devices(theta2_group, A)  # line 15

    if idx is None:
        idx = F.sample_participants(k_sample, fed)  # line 13
    batch = F.gather_batch(data, idx)

    z1 = _h1_groups(model, state.theta1, batch["x1"])
    z2 = _h2_groups(model, theta2_group, batch["x2"])
    stale_theta0 = state.theta0

    if compression_k or quant_levels or dp:
        msg = {"theta0": stale_theta0, "z1": z1, "z2": z2}
        if fused:
            from repro.kernels.compress import compress_pytree

            msg = compress_pytree(msg, compression_k or 1.0, quant_levels,
                                  dp_clip=dp_clip, dp_sigma=dp_sigma,
                                  dp_key=k_dp)
        else:
            if dp:
                raise ValueError(
                    "DP is fused into the batched compression kernel; "
                    "the legacy sort path does not support dp_clip/dp_sigma")
            comp = partial(compress_message_sort, k_frac=compression_k or 1.0,
                           levels=quant_levels)
            msg = jax.tree.map(comp, msg)
        stale_theta0, z1, z2 = msg["theta0"], msg["z1"], msg["z2"]

    if msg_fault is not None:  # corruption hits the compressed uplink payload
        def corrupt(z2):
            def leaf(x):
                f = msg_fault.reshape(
                    (-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
                return jnp.where(f != 0, x * f, x)

            return jax.tree.map(leaf, z2)

        # cond, not where: clean rounds skip the corruption kernels entirely
        z2 = jax.lax.cond(jnp.any(msg_fault != 0), corrupt, lambda z: z, z2)
    if screen:  # receiver-side screen: drop (zero) non-finite ζ2 entries.
        # Only the device uplink leg needs it: the fault model corrupts ζ2 in
        # flight, while θ0/ζ1 originate from hospital state that the per-step
        # group screen keeps finite — sweeping those (much larger) trees too
        # costs real step time for no detection.
        clean = lambda x: jnp.where(jnp.isfinite(x), x, jnp.zeros((), x.dtype))
        z2 = jax.tree.map(clean, z2)

    stale = {"theta0": stale_theta0, "z1": z1, "z2": z2}
    return state._replace(theta2=theta2, stale=stale, batch=batch, key=key)


def global_aggregation(state: HSGDState, fed: FederationConfig, group_weights) -> HSGDState:
    """Eq. (2) + broadcasts (Alg. 1 lines 3–9).

    The device-slot count is read off the state (not ``fed.sampled_devices``)
    so the cohort path, whose slot axis is the current bucket size, reuses
    this unchanged. Slots are uniform at round boundaries (check-in), so the
    unmasked eq. (1) here is exact.
    """
    M = fed.num_groups
    A = jax.tree_util.tree_leaves(state.theta2)[0].shape[1]
    theta2_group = F.local_aggregate(state.theta2)
    g0 = F.global_aggregate(state.theta0, group_weights)
    g1 = F.global_aggregate(state.theta1, group_weights)
    g2 = F.global_aggregate(theta2_group, group_weights)
    return state._replace(
        theta0=F.broadcast_to_groups(g0, M),
        theta1=F.broadcast_to_groups(g1, M),
        theta2=F.broadcast_to_devices(F.broadcast_to_groups(g2, M), A),
    )


def global_model(state: HSGDState, group_weights) -> Dict[str, Any]:
    """The observable global model θ̃ (eq. (2))."""
    return {
        "theta0": F.global_aggregate(state.theta0, group_weights),
        "theta1": F.global_aggregate(state.theta1, group_weights),
        "theta2": F.global_aggregate(F.local_aggregate(state.theta2), group_weights),
    }


# ---------------------------------------------------------------------------
# Full jitted training run
# ---------------------------------------------------------------------------


def state_shardings(state: HSGDState, mesh: Mesh, rules=None) -> HSGDState:
    """NamedShardings for an HSGDState: the leading group axis M rides the
    mesh's horizontal ("data"/"pod") axes via the logical "group" rule; key
    and step stay replicated. Non-divisible leaves fall back to replication,
    so a trivial mesh degrades to the single-device layout."""
    from repro.common.sharding import group_sharding

    repl = NamedSharding(mesh, P())
    grouped = lambda tree: jax.tree.map(lambda x: group_sharding(x.shape, mesh, rules), tree)
    return HSGDState(
        theta0=grouped(state.theta0),
        theta1=grouped(state.theta1),
        theta2=grouped(state.theta2),
        stale=grouped(state.stale),
        batch=grouped(state.batch),
        key=repl,
        step=repl,
    )


def _global_grad_zeros(state: HSGDState):
    """Zero template shaped like the global-gradient proxy (one model copy)."""
    return {
        "theta0": jax.tree.map(lambda x: jnp.zeros(x.shape[1:], x.dtype), state.theta0),
        "theta1": jax.tree.map(lambda x: jnp.zeros(x.shape[1:], x.dtype), state.theta1),
        "theta2": jax.tree.map(lambda x: jnp.zeros(x.shape[2:], x.dtype), state.theta2),
    }


def place_on_mesh(state: HSGDState, data, group_weights, mesh: Optional[Mesh]):
    """Shard (state, data, weights) for a non-trivial mesh; no-op otherwise."""
    if mesh is None or mesh.devices.size <= 1:
        return state, data, group_weights
    from repro.common.sharding import group_sharding

    state = jax.device_put(state, state_shardings(state, mesh))
    data = jax.device_put(
        data, jax.tree.map(lambda x: group_sharding(x.shape, mesh), data))
    group_weights = jax.device_put(group_weights, NamedSharding(mesh, P()))
    return state, data, group_weights


@dataclass(frozen=True)
class HSGDRunner:
    """Compiled HSGD trainer for a (model, federation, train) configuration.

    ``run`` donates the state argument: the full replicated [M, A, ...] pytree
    is updated in place instead of double-buffered, so the caller's input
    state is consumed (rebind the return value, as every call site does).
    Passing a non-trivial ``mesh`` shards every leading group axis over the
    mesh's horizontal axes, lowering the eq. (1)/(2) aggregations and
    broadcasts to collectives instead of replicated gathers.

    The adaptive controller drives single rounds through ``round_fn``, which
    stages the scan lengths per (P, Q, compression) bucket: each bucket
    compiles once into a donating jitted executor and is cached, so a run
    whose intervals vary round-to-round pays one compile per distinct bucket
    instead of one per round. η stays a traced scalar — re-picking the
    learning rate never recompiles.
    """

    model: HybridModel
    fed: FederationConfig
    train: TrainConfig
    do_global_agg: bool = True  # False reproduces TDCD's missing phase
    fused_compression: bool = True  # False keeps the pre-fusion sort path
    # (P, Q, k, b, collect) bucket -> compiled round executor
    _round_cache: Dict = field(default_factory=dict, compare=False, repr=False)

    def _round_impl(self, state: HSGDState, data, group_weights,
                    lr: Union[Callable, jnp.ndarray, float],
                    Q: int, lam: int, compression_k: float, quant_levels: int,
                    collect: bool, idx=None, pmask=None,
                    dp_clip=None, dp_sigma=None, agg_masks=None):
        """One global round with staged scan lengths (Λ intervals × Q steps).

        ``lr`` is either a step->η schedule (fixed-interval ``run`` path) or a
        traced scalar (adaptive path). With ``collect`` the inner scan carries
        the previous step's global-gradient proxy and emits per-step probe
        stats; ρ secants pair consecutive steps *within* an interval only
        (same batch ⇒ a clean Lipschitz quotient), so Q = 1 rounds yield no ρ
        samples and the controller keeps its EMA.
        """
        fed, model = self.fed, self.model
        if self.do_global_agg:
            state = global_aggregation(state, fed, group_weights)
        lr_of = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))
        do_exchange = partial(
            exchange, model, data=data, fed=fed,
            compression_k=compression_k, quant_levels=quant_levels,
            fused=self.fused_compression, idx=idx, pmask=pmask,
            dp_clip=dp_clip, dp_sigma=dp_sigma, agg_masks=agg_masks,
        )

        if not collect:
            def interval(state, _):
                state = do_exchange(state)

                def sgd_step(state, _):
                    state, loss = local_sgd_step(model, state, lr_of(state.step))
                    return state, loss

                state, losses = jax.lax.scan(sgd_step, state, None, length=Q)
                return state, losses

            state, losses = jax.lax.scan(interval, state, None, length=lam)
            return state, losses.reshape(-1)

        zeros_g = _global_grad_zeros(state)

        def interval(state, _):
            state = do_exchange(state)

            def sgd_step(carry, _):
                state, prev_g, prev_ok = carry
                lr_t = lr_of(state.step)
                state, loss, aux = local_sgd_step_stats(model, state, lr_t, group_weights)
                diff = tree_norm(tree_sub(aux["gbar"], prev_g))
                den = lr_t * tree_norm(prev_g)
                rho = jnp.where(prev_ok > 0.5, diff / jnp.maximum(den, 1e-12), 0.0)
                stats = {"loss": loss, "gnorm2": aux["gnorm2"],
                         "delta2": aux["delta2"], "rho": rho, "rho_ok": prev_ok}
                return (state, aux["gbar"], jnp.ones((), jnp.float32)), stats

            (state, _, _), stats = jax.lax.scan(
                sgd_step, (state, zeros_g, jnp.zeros((), jnp.float32)), None, length=Q)
            return state, stats

        state, stats = jax.lax.scan(interval, state, None, length=lam)
        stats = jax.tree.map(lambda x: x.reshape(-1), stats)  # [Λ, Q] -> [P]
        return state, stats

    def _round(self, state: HSGDState, data, group_weights, lr_fn):
        return self._round_impl(
            state, data, group_weights, lr_fn,
            self.fed.local_interval, self.fed.lam,
            self.train.compression_k, self.train.quantization_bits,
            collect=False,
        )

    def round_fn(self, P: int, Q: int, compression_k: Optional[float] = None,
                 quant_levels: Optional[int] = None, collect_stats: bool = True,
                 dp: bool = False, secure_agg: bool = False):
        """Compiled single-round executor for a (P, Q, compression) bucket.

        fn(state, data, group_weights, lr) -> (state, stats) with stats a dict
        of [P] per-step arrays (loss/gnorm2/delta2/rho/rho_ok) when
        ``collect_stats``, else (state, losses [P]). Donates ``state`` like
        ``run``. Cached per bucket — the adaptive controller's round-varying
        (P, Q, k, b) settings compile once each.

        ``dp``/``secure_agg`` extend the cache key by exactly one enable bit
        each; the executor then takes extra TRACED operands — fn(state, data,
        group_weights, lr, dp_clip, dp_sigma[, agg_masks]) — so re-picking
        clip/σ per round (the controller's DP governor) or re-keying the
        pairwise masks per round never recompiles, à la traced-η.
        """
        if P < 1 or Q < 1 or P % Q:
            raise ValueError(f"P={P} must be a positive multiple of Q={Q}")
        k = self.train.compression_k if compression_k is None else compression_k
        b = self.train.quantization_bits if quant_levels is None else quant_levels
        key = (P, Q, k, b, collect_stats)
        if dp or secure_agg:
            key = key + (dp, secure_agg)
        fn = self._round_cache.get(key)
        if fn is None:
            lam = P // Q

            if dp or secure_agg:
                @partial(jax.jit, donate_argnums=(0,))
                def hsgd_private_round(state, data, group_weights, lr,
                                       dp_clip=None, dp_sigma=None,
                                       agg_masks=None):
                    return self._round_impl(
                        state, data, group_weights, lr, Q, lam, k, b,
                        collect_stats,
                        dp_clip=dp_clip if dp else None,
                        dp_sigma=dp_sigma if dp else None,
                        agg_masks=agg_masks if secure_agg else None)

                fn = self._round_cache[key] = hsgd_private_round
                return fn

            # named so compile_guard can attribute compiles per executor
            @partial(jax.jit, donate_argnums=(0,))
            def hsgd_round(state, data, group_weights, lr):
                return self._round_impl(state, data, group_weights, lr,
                                        Q, lam, k, b, collect_stats)

            fn = self._round_cache[key] = hsgd_round
        return fn

    def cohort_round_fn(self, P: int, Q: int, cohort_size: int,
                        compression_k: Optional[float] = None,
                        quant_levels: Optional[int] = None,
                        collect_stats: bool = True):
        """Compiled round executor over a sampled cohort of device slots.

        fn(state, data, group_weights, lr, participants, pmask) -> (state,
        stats|losses). ``participants`` [M, cohort_size] are the round's data
        rows (padded to the power-of-two bucket by repeating real members),
        ``pmask`` [M, cohort_size] is 1 on real slots; ``group_weights`` is a
        traced [M] vector, so the semi-async scheduler's staleness-damped
        effective weights never trigger a recompile. The state's device axis
        must already equal ``cohort_size`` (see ``resize_cohort``).

        The round ends with a check-in — θ2 ← broadcast(masked eq. (1)) — so
        device slots leave the round uniform: padding slots never leak into
        the next round and re-bucketing between rounds stays exact.

        Cached per (P, Q, cohort_size, k, b, collect) bucket: a population run
        whose cohort sizes vary round-to-round compiles one executor per
        bucket, not one per round.
        """
        if P < 1 or Q < 1 or P % Q:
            raise ValueError(f"P={P} must be a positive multiple of Q={Q}")
        if cohort_size < 1:
            raise ValueError(f"cohort_size={cohort_size} must be >= 1")
        k = self.train.compression_k if compression_k is None else compression_k
        b = self.train.quantization_bits if quant_levels is None else quant_levels
        key = (P, Q, cohort_size, k, b, collect_stats)
        fn = self._round_cache.get(key)
        if fn is None:
            lam = P // Q
            A = cohort_size

            @partial(jax.jit, donate_argnums=(0,))
            def hsgd_cohort_round(state, data, group_weights, lr, participants, pmask):
                state, out = self._round_impl(
                    state, data, group_weights, lr, Q, lam, k, b,
                    collect_stats, idx=participants, pmask=pmask)
                theta2_group = F.local_aggregate(state.theta2, pmask)
                state = state._replace(
                    theta2=F.broadcast_to_devices(theta2_group, A))
                return state, out

            fn = self._round_cache[key] = hsgd_cohort_round
        return fn

    def _guarded_round_impl(self, state, data, group_weights, lr, Q: int,
                            lam: int, k: float, b: int, idx, pmask,
                            grad_fault, msg_fault, screen: bool):
        """Cohort round with fault injection and (optionally) the compiled
        defense: per-step screening masks, receiver-side message screening,
        and the ``fed.robust_agg`` aggregation over surviving slots. With all
        fault terms zero and screening on, every mask stays all-ones and the
        parameter trajectory is bit-identical to ``_round_impl``'s cohort
        path (pinned by a test; the reported loss scalar may differ in the
        final ULP — XLA fuses the cross-group mean reduction differently in
        this graph)."""
        fed, model = self.fed, self.model
        if self.do_global_agg:
            state = global_aggregation(state, fed, group_weights)
        lr_of = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))
        do_exchange = partial(
            exchange, model, data=data, fed=fed,
            compression_k=k, quant_levels=b, fused=self.fused_compression,
            idx=idx, pmask=pmask, msg_fault=msg_fault, screen=screen,
        )

        def interval(carry, _):
            state, trust = carry
            state = do_exchange(state, trust=trust if screen else None)

            def sgd_step(carry, _):
                state, trust = carry
                state, loss, dev_ok, _grp_ok = local_sgd_step_guarded(
                    model, state, lr_of(state.step), pmask,
                    grad_fault=grad_fault, screen=screen, zmax=fed.screen_zmax)
                # sticky within the round: a flagged device stays out of
                # every later aggregation (x1.0 is bitwise identity: the
                # clean path's trust never changes)
                trust = trust * dev_ok
                return (state, trust), loss

            (state, trust), losses = jax.lax.scan(
                sgd_step, (state, trust), None, length=Q)
            return (state, trust), losses

        trust0 = jnp.ones_like(pmask)
        (state, trust), losses = jax.lax.scan(
            interval, (state, trust0), None, length=lam)
        # check-in: device slots leave the round uniform (robust under screen)
        A = pmask.shape[1]
        if screen:
            theta2_group = F.robust_local_aggregate(
                state.theta2, pmask, trust,
                method=fed.robust_agg, trim_frac=fed.trim_frac)
        else:
            theta2_group = F.local_aggregate(state.theta2, pmask)
        state = state._replace(theta2=F.broadcast_to_devices(theta2_group, A))
        flagged = jnp.sum(pmask * (1.0 - trust))
        return state, losses.reshape(-1), flagged

    def fault_round_fn(self, P: int, Q: int, cohort_size: int,
                       compression_k: Optional[float] = None,
                       quant_levels: Optional[int] = None,
                       robust: bool = True):
        """Compiled fault-injectable round executor (the resilient runtime's
        work-horse).

        fn(state, data, group_weights, lr, participants, pmask, grad_fault,
        msg_fault) -> (state, losses [P], flagged). ``grad_fault`` [M, A] and
        ``msg_fault`` [M] are traced values (0 = clean) — re-drawing faults
        each round never recompiles. ``robust=True`` folds the compiled
        defense in (screening masks + ``fed.robust_agg`` aggregation);
        ``robust=False`` is the naive stack: same injection, no defense.
        ``flagged`` counts real slot-updates the screen rejected (always 0.0
        on the naive path).

        Cached per (P, Q, cohort_size, k, b, robust) bucket alongside the
        plain executors — same one-executor-per-bucket discipline.
        """
        if P < 1 or Q < 1 or P % Q:
            raise ValueError(f"P={P} must be a positive multiple of Q={Q}")
        if cohort_size < 1:
            raise ValueError(f"cohort_size={cohort_size} must be >= 1")
        k = self.train.compression_k if compression_k is None else compression_k
        b = self.train.quantization_bits if quant_levels is None else quant_levels
        key = (P, Q, cohort_size, k, b, "robust" if robust else "faulty")
        fn = self._round_cache.get(key)
        if fn is None:
            lam = P // Q

            if robust:
                @partial(jax.jit, donate_argnums=(0,))
                def hsgd_robust_round(state, data, group_weights, lr,
                                      participants, pmask, grad_fault, msg_fault):
                    return self._guarded_round_impl(
                        state, data, group_weights, lr, Q, lam, k, b,
                        participants, pmask, grad_fault, msg_fault, screen=True)

                fn = hsgd_robust_round
            else:
                @partial(jax.jit, donate_argnums=(0,))
                def hsgd_faulty_round(state, data, group_weights, lr,
                                      participants, pmask, grad_fault, msg_fault):
                    return self._guarded_round_impl(
                        state, data, group_weights, lr, Q, lam, k, b,
                        participants, pmask, grad_fault, msg_fault, screen=False)

                fn = hsgd_faulty_round
            self._round_cache[key] = fn
        return fn

    def run(self, state: HSGDState, data, group_weights, rounds: int,
            mesh: Optional[Mesh] = None):
        """Execute ``rounds`` global rounds; returns (state, per-step losses).

        Donates ``state`` (no double-buffering of the [M, A, ...] pytree).
        """
        lr_fn = halving_schedule(self.train.learning_rate, self.train.lr_halve_every)
        state, data, group_weights = place_on_mesh(state, data, group_weights, mesh)

        @partial(jax.jit, donate_argnums=(0,))
        def go(state, data, group_weights):
            def body(state, _):
                return self._round(state, data, group_weights, lr_fn)

            return jax.lax.scan(body, state, None, length=rounds)

        state, losses = go(state, data, group_weights)
        return state, losses.reshape(-1)

    def run_private(self, state: HSGDState, data, group_weights, rounds: int,
                    seed: int = 0, dp_clip: float = 0.0, dp_sigma: float = 0.0,
                    secure_agg: bool = False):
        """Fixed-interval run with the privacy legs on.

        A host round loop instead of ``run``'s scan: the secure-aggregation
        pairwise masks are host-generated (numpy, stream index 4) and re-keyed
        every round, which a traced scan cannot express. One executor compiles
        for the single (P, Q, k, b) bucket — clip/σ/masks are traced operands,
        so the loop never recompiles. η follows the halving schedule sampled
        at each round's first step (it is a per-round traced scalar here).

        Returns (state, per-step losses [rounds * P]).
        """
        dp = dp_clip > 0.0
        if dp_sigma > 0.0 and not dp:
            raise ValueError("dp_sigma > 0 requires a positive dp_clip")
        Q = self.fed.local_interval
        P = Q * self.fed.lam
        fn = self.round_fn(P, Q, collect_stats=False, dp=dp,
                           secure_agg=secure_agg)
        lr_fn = halving_schedule(self.train.learning_rate,
                                 self.train.lr_halve_every)
        losses, step = [], 0
        for r in range(rounds):
            kwargs = {}
            if dp:
                kwargs["dp_clip"] = jnp.asarray(dp_clip, jnp.float32)
                kwargs["dp_sigma"] = jnp.asarray(dp_sigma, jnp.float32)
            if secure_agg:
                kwargs["agg_masks"] = F.secure_agg_masks(state.theta2, seed, r)
            state, l = fn(state, data, group_weights, lr_fn(step), **kwargs)
            losses.append(l)
            step += P
        return state, jnp.concatenate([jnp.reshape(l, (-1,)) for l in losses])


def make_group_weights(data) -> jnp.ndarray:
    """K_m weights from the per-group valid-sample counts."""
    return jnp.sum(data["valid"].astype(jnp.float32), axis=1)


# checkpoint restores return a real HSGDState, not an anonymous namedtuple
from repro.checkpoint.ckpt import register_state_class as _register_state_class  # noqa: E402

_register_state_class(HSGDState)
