"""Hybrid Stochastic Gradient Descent — the paper's Algorithm 1.

Training runs as a jitted 3-level loop mirroring the paper's timeline:

  scan over R global rounds                      (t mod P == 0 events)
    ├─ local agg (eq 1) + global agg (eq 2) + broadcasts (Alg. 1 lines 3–9)
    └─ scan over Λ = P/Q local intervals         (t mod Q == 0 events)
         ├─ local aggregation (eq 1, lines 10–12)
         ├─ A_m/ξ_m agreement + intermediate-result EXCHANGE (lines 13–21):
         │    ζ1 = h1(θ1; X1ξ), ζ2 = h2(θ2; X2ξ), stale θ0 snapshot
         │    (optionally top-k/quantize compressed — C-HSGD)
         └─ scan over Q SGD steps (lines 22–26):
              hospital: (θ0,θ1) step with FRESH ζ1, STALE ζ2   (eqs 5–6)
              devices:  θ2_n step with STALE θ0, STALE ζ1      (eq 7)

Only the sampled devices A_m are materialized ([M, A, ...]): unsampled
devices are reset to θ2_m at every local aggregation anyway (line 15), so
their state never influences the trajectory.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import FederationConfig, TrainConfig
from repro.common.pytree import tree_dot, tree_norm, tree_sub
from repro.core import federation as F
from repro.core.compression import compress_message_sort
from repro.models.split_model import HybridModel
from repro.optim import halving_schedule


class HSGDState(NamedTuple):
    theta0: Any  # [M, ...] combined models
    theta1: Any  # [M, ...] hospital towers
    theta2: Any  # [M, A, ...] sampled-device towers
    stale: Dict[str, Any]  # {"theta0": [M,...], "z1": [M,A,...], "z2": [M,A,...]}
    batch: Dict[str, jnp.ndarray]  # gathered ξ_m: x1,x2,y,valid [M,A,...]
    key: jnp.ndarray
    step: jnp.ndarray


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------


def _placeholder_ctx(model: HybridModel, theta1, theta2, data, M: int, A: int):
    """Placeholder (batch, z1, z2) shaped for A device slots per group.

    Every run/round exchanges before the first SGD step, so the placeholders
    are overwritten unread — shape them with eval_shape (zero FLOPs) instead
    of running real forward passes.
    """
    idx = jnp.zeros((M, A), jnp.int32)
    batch = F.gather_batch(data, idx)
    z_shapes = jax.eval_shape(
        lambda t1, t2, b: (
            _h1_groups(model, t1, b["x1"]),
            _h2_groups(model, F.local_aggregate(t2), b["x2"]),
        ),
        theta1, theta2, batch,
    )
    z1, z2 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), z_shapes)
    return batch, z1, z2


def init_state(key, model: HybridModel, fed: FederationConfig, data, dtype=jnp.float32) -> HSGDState:
    """All groups start from the same global model (Alg. 1 line 1)."""
    k_init, k_run = jax.random.split(key)
    params = model.init(k_init, dtype)
    M, A = fed.num_groups, fed.sampled_devices
    theta0 = F.broadcast_to_groups(params["theta0"], M)
    theta1 = F.broadcast_to_groups(params["theta1"], M)
    theta2 = F.broadcast_to_devices(F.broadcast_to_groups(params["theta2"], M), A)
    batch, z1, z2 = _placeholder_ctx(model, theta1, theta2, data, M, A)
    # distinct buffers from theta0: donation in run() must not see aliases
    stale = {"theta0": jax.tree.map(jnp.copy, theta0), "z1": z1, "z2": z2}
    return HSGDState(theta0, theta1, theta2, stale, batch, k_run, jnp.zeros((), jnp.int32))


def resize_cohort(state: HSGDState, model: HybridModel, data, A_new: int) -> HSGDState:
    """Re-bucket the device-slot axis A between rounds ([M, A, ...] -> [M, A_new, ...]).

    Valid only at a round boundary, where every cohort round has already
    checked its device towers back in (θ2 slots uniform: the executor ends
    with θ2 ← broadcast(masked eq. (1))), so collapsing the slot axis by eq.
    (1) and re-broadcasting is exact. The stale/batch placeholders are
    re-shaped the same way ``init_state`` shapes them — the next round's
    first exchange overwrites them unread.
    """
    M, A = jax.tree_util.tree_leaves(state.theta2)[0].shape[:2]
    if A == A_new:
        return state
    theta2_group = F.local_aggregate(state.theta2)
    theta2 = F.broadcast_to_devices(theta2_group, A_new)
    batch, z1, z2 = _placeholder_ctx(model, state.theta1, theta2, data, M, A_new)
    stale = {"theta0": state.stale["theta0"], "z1": z1, "z2": z2}
    return state._replace(theta2=theta2, stale=stale, batch=batch)


# ---------------------------------------------------------------------------
# Forward helpers (vmapped over groups / devices)
# ---------------------------------------------------------------------------


def _h1_groups(model, theta1, x1):
    """[M,...]θ1 × [M,A,...]x1 -> ζ1 [M,A,...]."""
    return jax.vmap(model.h1)(theta1, x1)


def _h2_groups(model, theta2_group, x2):
    """[M,...]θ2_m × [M,A,...]x2 -> ζ2 [M,A,...] (device outputs from θ2_m)."""
    return jax.vmap(model.h2)(theta2_group, x2)


# ---------------------------------------------------------------------------
# The three gradient rules (eqs. (5)–(7))
# ---------------------------------------------------------------------------


def _hospital_loss(model, theta0_m, theta1_m, batch_m, stale_z2_m):
    """Group-level loss with fresh ζ1(θ1), stale ζ2 — drives eqs. (5)(6)."""
    z1 = model.h1(theta1_m, batch_m["x1"])
    return model.loss(theta0_m, z1, jax.lax.stop_gradient(stale_z2_m), batch_m["y"])


def _device_loss(model, theta2_n, x2_n, y_n, stale_theta0_m, stale_z1_n):
    """Per-device loss with stale θ0, stale ζ1, fresh ζ2(θ2_n) — eq. (7)."""
    z2 = model.h2(theta2_n, x2_n[None])
    return model.loss(
        jax.lax.stop_gradient(stale_theta0_m),
        jax.lax.stop_gradient(stale_z1_n[None]),
        z2,
        y_n[None],
    )


def _local_grads(model: HybridModel, state: HSGDState):
    """Per-worker gradients of lines 22–26: (losses [M], g0 [M,...], g1 [M,...],
    g2 [M,A,...]). Shared by the plain step and the probe-collecting step."""

    def h_loss(t0_m, t1_m, b_m, z2_m):
        return _hospital_loss(model, t0_m, t1_m, b_m, z2_m)

    h_grads = jax.vmap(jax.value_and_grad(h_loss, argnums=(0, 1)))(
        state.theta0, state.theta1, state.batch, state.stale["z2"]
    )
    (losses, (g0, g1)) = h_grads

    def d_loss(t2_n, x2_n, y_n, t0_m, z1_n):
        return _device_loss(model, t2_n, x2_n, y_n, t0_m, z1_n)

    per_device = jax.vmap(  # over devices within a group
        jax.grad(d_loss), in_axes=(0, 0, 0, None, 0)
    )
    g2 = jax.vmap(per_device)(  # over groups
        state.theta2, state.batch["x2"], state.batch["y"], state.stale["theta0"], state.stale["z1"]
    )
    return losses, g0, g1, g2


def _apply_sgd(state: HSGDState, lr, g0, g1, g2) -> HSGDState:
    upd = lambda p, g: p - lr * g.astype(p.dtype)
    return state._replace(
        theta0=jax.tree.map(upd, state.theta0, g0),
        theta1=jax.tree.map(upd, state.theta1, g1),
        theta2=jax.tree.map(upd, state.theta2, g2),
        step=state.step + 1,
    )


def local_sgd_step(model: HybridModel, state: HSGDState, lr) -> Tuple[HSGDState, jnp.ndarray]:
    """One iteration of lines 22–26 for every group and sampled device."""
    losses, g0, g1, g2 = _local_grads(model, state)
    return _apply_sgd(state, lr, g0, g1, g2), jnp.mean(losses)


def _worker_dev2(g, gbar, lead: int):
    """Σ_leaves ||g_worker − ḡ||² per worker: [M, ...]→[M] (lead=1) or
    [M, A, ...]→[M, A] (lead=2)."""
    per = jax.tree.map(
        lambda x, m: jnp.sum((x - m.reshape((1,) * lead + m.shape)) ** 2,
                             axis=tuple(range(lead, x.ndim))), g, gbar)
    return sum(jax.tree_util.tree_leaves(per))


def local_sgd_step_stats(
    model: HybridModel, state: HSGDState, lr, group_weights
) -> Tuple[HSGDState, jnp.ndarray, Dict[str, Any]]:
    """``local_sgd_step`` + the §VI-B online probe statistics, reusing the
    step's own gradients (no extra forward/backward passes):

      gbar    — the global-gradient proxy ∇F(θ̃): weighted group mean of
                (g0, g1) and of the device means of g2 (eqs. (1)/(2) applied
                to gradients instead of parameters);
      gnorm2  — ‖gbar‖² (strategy 3's ‖∇F‖² input);
      delta2  — mean squared deviation of per-worker gradients around gbar
                (Assumption 2's δ² estimator).
    """
    losses, g0, g1, g2 = _local_grads(model, state)
    gbar = {
        "theta0": F.global_aggregate(g0, group_weights),
        "theta1": F.global_aggregate(g1, group_weights),
        "theta2": F.global_aggregate(F.local_aggregate(g2), group_weights),
    }
    gnorm2 = tree_dot(gbar, gbar)
    delta2 = (
        jnp.mean(_worker_dev2(g0, gbar["theta0"], 1)
                 + _worker_dev2(g1, gbar["theta1"], 1))
        + jnp.mean(_worker_dev2(g2, gbar["theta2"], 2))
    )
    new_state = _apply_sgd(state, lr, g0, g1, g2)
    aux = {"gbar": gbar, "gnorm2": gnorm2, "delta2": delta2}
    return new_state, jnp.mean(losses), aux


# ---------------------------------------------------------------------------
# Exchange + aggregations
# ---------------------------------------------------------------------------


def exchange(
    model: HybridModel,
    state: HSGDState,
    data,
    fed: FederationConfig,
    compression_k: float = 0.0,
    quant_levels: int = 0,
    fused: bool = True,
    idx: Optional[jnp.ndarray] = None,
    pmask: Optional[jnp.ndarray] = None,
) -> HSGDState:
    """Local aggregation (eq 1) + A_m/ξ_m agreement + ζ/θ0 exchange.

    With compression on, the whole exchange message (θ0 snapshot pytree + ζ1
    + ζ2) is compressed in ONE fused top-k+quantize row-matrix call (Pallas
    kernel on TPU, fused jnp elsewhere). ``fused=False`` keeps the pre-fusion
    leaf-wise sort-based path for benchmarking.

    The cohort path (see ``core/population.py``) pins the round's participants
    by passing ``idx`` ([M, A] data-row indices, padded to the bucket size by
    repeating real members) and ``pmask`` ([M, A], 0 on padding slots): the
    per-interval A_m draw is skipped and eq. (1) excludes the padding slots.
    """
    key, k_sample = jax.random.split(state.key)
    theta2_group = F.local_aggregate(state.theta2, pmask)  # eq (1)
    A = fed.sampled_devices if idx is None else idx.shape[1]
    theta2 = F.broadcast_to_devices(theta2_group, A)  # line 15

    if idx is None:
        idx = F.sample_participants(k_sample, fed)  # line 13
    batch = F.gather_batch(data, idx)

    z1 = _h1_groups(model, state.theta1, batch["x1"])
    z2 = _h2_groups(model, theta2_group, batch["x2"])
    stale_theta0 = state.theta0

    if compression_k or quant_levels:
        msg = {"theta0": stale_theta0, "z1": z1, "z2": z2}
        if fused:
            from repro.kernels.compress import compress_pytree

            msg = compress_pytree(msg, compression_k or 1.0, quant_levels)
        else:
            comp = partial(compress_message_sort, k_frac=compression_k or 1.0,
                           levels=quant_levels)
            msg = jax.tree.map(comp, msg)
        stale_theta0, z1, z2 = msg["theta0"], msg["z1"], msg["z2"]

    stale = {"theta0": stale_theta0, "z1": z1, "z2": z2}
    return state._replace(theta2=theta2, stale=stale, batch=batch, key=key)


def global_aggregation(state: HSGDState, fed: FederationConfig, group_weights) -> HSGDState:
    """Eq. (2) + broadcasts (Alg. 1 lines 3–9).

    The device-slot count is read off the state (not ``fed.sampled_devices``)
    so the cohort path, whose slot axis is the current bucket size, reuses
    this unchanged. Slots are uniform at round boundaries (check-in), so the
    unmasked eq. (1) here is exact.
    """
    M = fed.num_groups
    A = jax.tree_util.tree_leaves(state.theta2)[0].shape[1]
    theta2_group = F.local_aggregate(state.theta2)
    g0 = F.global_aggregate(state.theta0, group_weights)
    g1 = F.global_aggregate(state.theta1, group_weights)
    g2 = F.global_aggregate(theta2_group, group_weights)
    return state._replace(
        theta0=F.broadcast_to_groups(g0, M),
        theta1=F.broadcast_to_groups(g1, M),
        theta2=F.broadcast_to_devices(F.broadcast_to_groups(g2, M), A),
    )


def global_model(state: HSGDState, group_weights) -> Dict[str, Any]:
    """The observable global model θ̃ (eq. (2))."""
    return {
        "theta0": F.global_aggregate(state.theta0, group_weights),
        "theta1": F.global_aggregate(state.theta1, group_weights),
        "theta2": F.global_aggregate(F.local_aggregate(state.theta2), group_weights),
    }


# ---------------------------------------------------------------------------
# Full jitted training run
# ---------------------------------------------------------------------------


def state_shardings(state: HSGDState, mesh: Mesh, rules=None) -> HSGDState:
    """NamedShardings for an HSGDState: the leading group axis M rides the
    mesh's horizontal ("data"/"pod") axes via the logical "group" rule; key
    and step stay replicated. Non-divisible leaves fall back to replication,
    so a trivial mesh degrades to the single-device layout."""
    from repro.common.sharding import group_sharding

    repl = NamedSharding(mesh, P())
    grouped = lambda tree: jax.tree.map(lambda x: group_sharding(x.shape, mesh, rules), tree)
    return HSGDState(
        theta0=grouped(state.theta0),
        theta1=grouped(state.theta1),
        theta2=grouped(state.theta2),
        stale=grouped(state.stale),
        batch=grouped(state.batch),
        key=repl,
        step=repl,
    )


def _global_grad_zeros(state: HSGDState):
    """Zero template shaped like the global-gradient proxy (one model copy)."""
    return {
        "theta0": jax.tree.map(lambda x: jnp.zeros(x.shape[1:], x.dtype), state.theta0),
        "theta1": jax.tree.map(lambda x: jnp.zeros(x.shape[1:], x.dtype), state.theta1),
        "theta2": jax.tree.map(lambda x: jnp.zeros(x.shape[2:], x.dtype), state.theta2),
    }


def place_on_mesh(state: HSGDState, data, group_weights, mesh: Optional[Mesh]):
    """Shard (state, data, weights) for a non-trivial mesh; no-op otherwise."""
    if mesh is None or mesh.devices.size <= 1:
        return state, data, group_weights
    from repro.common.sharding import group_sharding

    state = jax.device_put(state, state_shardings(state, mesh))
    data = jax.device_put(
        data, jax.tree.map(lambda x: group_sharding(x.shape, mesh), data))
    group_weights = jax.device_put(group_weights, NamedSharding(mesh, P()))
    return state, data, group_weights


@dataclass(frozen=True)
class HSGDRunner:
    """Compiled HSGD trainer for a (model, federation, train) configuration.

    ``run`` donates the state argument: the full replicated [M, A, ...] pytree
    is updated in place instead of double-buffered, so the caller's input
    state is consumed (rebind the return value, as every call site does).
    Passing a non-trivial ``mesh`` shards every leading group axis over the
    mesh's horizontal axes, lowering the eq. (1)/(2) aggregations and
    broadcasts to collectives instead of replicated gathers.

    The adaptive controller drives single rounds through ``round_fn``, which
    stages the scan lengths per (P, Q, compression) bucket: each bucket
    compiles once into a donating jitted executor and is cached, so a run
    whose intervals vary round-to-round pays one compile per distinct bucket
    instead of one per round. η stays a traced scalar — re-picking the
    learning rate never recompiles.
    """

    model: HybridModel
    fed: FederationConfig
    train: TrainConfig
    do_global_agg: bool = True  # False reproduces TDCD's missing phase
    fused_compression: bool = True  # False keeps the pre-fusion sort path
    # (P, Q, k, b, collect) bucket -> compiled round executor
    _round_cache: Dict = field(default_factory=dict, compare=False, repr=False)

    def _round_impl(self, state: HSGDState, data, group_weights,
                    lr: Union[Callable, jnp.ndarray, float],
                    Q: int, lam: int, compression_k: float, quant_levels: int,
                    collect: bool, idx=None, pmask=None):
        """One global round with staged scan lengths (Λ intervals × Q steps).

        ``lr`` is either a step->η schedule (fixed-interval ``run`` path) or a
        traced scalar (adaptive path). With ``collect`` the inner scan carries
        the previous step's global-gradient proxy and emits per-step probe
        stats; ρ secants pair consecutive steps *within* an interval only
        (same batch ⇒ a clean Lipschitz quotient), so Q = 1 rounds yield no ρ
        samples and the controller keeps its EMA.
        """
        fed, model = self.fed, self.model
        if self.do_global_agg:
            state = global_aggregation(state, fed, group_weights)
        lr_of = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))
        do_exchange = partial(
            exchange, model, data=data, fed=fed,
            compression_k=compression_k, quant_levels=quant_levels,
            fused=self.fused_compression, idx=idx, pmask=pmask,
        )

        if not collect:
            def interval(state, _):
                state = do_exchange(state)

                def sgd_step(state, _):
                    state, loss = local_sgd_step(model, state, lr_of(state.step))
                    return state, loss

                state, losses = jax.lax.scan(sgd_step, state, None, length=Q)
                return state, losses

            state, losses = jax.lax.scan(interval, state, None, length=lam)
            return state, losses.reshape(-1)

        zeros_g = _global_grad_zeros(state)

        def interval(state, _):
            state = do_exchange(state)

            def sgd_step(carry, _):
                state, prev_g, prev_ok = carry
                lr_t = lr_of(state.step)
                state, loss, aux = local_sgd_step_stats(model, state, lr_t, group_weights)
                diff = tree_norm(tree_sub(aux["gbar"], prev_g))
                den = lr_t * tree_norm(prev_g)
                rho = jnp.where(prev_ok > 0.5, diff / jnp.maximum(den, 1e-12), 0.0)
                stats = {"loss": loss, "gnorm2": aux["gnorm2"],
                         "delta2": aux["delta2"], "rho": rho, "rho_ok": prev_ok}
                return (state, aux["gbar"], jnp.ones((), jnp.float32)), stats

            (state, _, _), stats = jax.lax.scan(
                sgd_step, (state, zeros_g, jnp.zeros((), jnp.float32)), None, length=Q)
            return state, stats

        state, stats = jax.lax.scan(interval, state, None, length=lam)
        stats = jax.tree.map(lambda x: x.reshape(-1), stats)  # [Λ, Q] -> [P]
        return state, stats

    def _round(self, state: HSGDState, data, group_weights, lr_fn):
        return self._round_impl(
            state, data, group_weights, lr_fn,
            self.fed.local_interval, self.fed.lam,
            self.train.compression_k, self.train.quantization_bits,
            collect=False,
        )

    def round_fn(self, P: int, Q: int, compression_k: Optional[float] = None,
                 quant_levels: Optional[int] = None, collect_stats: bool = True):
        """Compiled single-round executor for a (P, Q, compression) bucket.

        fn(state, data, group_weights, lr) -> (state, stats) with stats a dict
        of [P] per-step arrays (loss/gnorm2/delta2/rho/rho_ok) when
        ``collect_stats``, else (state, losses [P]). Donates ``state`` like
        ``run``. Cached per bucket — the adaptive controller's round-varying
        (P, Q, k, b) settings compile once each.
        """
        if P < 1 or Q < 1 or P % Q:
            raise ValueError(f"P={P} must be a positive multiple of Q={Q}")
        k = self.train.compression_k if compression_k is None else compression_k
        b = self.train.quantization_bits if quant_levels is None else quant_levels
        key = (P, Q, k, b, collect_stats)
        fn = self._round_cache.get(key)
        if fn is None:
            lam = P // Q

            # named so compile_guard can attribute compiles per executor
            @partial(jax.jit, donate_argnums=(0,))
            def hsgd_round(state, data, group_weights, lr):
                return self._round_impl(state, data, group_weights, lr,
                                        Q, lam, k, b, collect_stats)

            fn = self._round_cache[key] = hsgd_round
        return fn

    def cohort_round_fn(self, P: int, Q: int, cohort_size: int,
                        compression_k: Optional[float] = None,
                        quant_levels: Optional[int] = None,
                        collect_stats: bool = True):
        """Compiled round executor over a sampled cohort of device slots.

        fn(state, data, group_weights, lr, participants, pmask) -> (state,
        stats|losses). ``participants`` [M, cohort_size] are the round's data
        rows (padded to the power-of-two bucket by repeating real members),
        ``pmask`` [M, cohort_size] is 1 on real slots; ``group_weights`` is a
        traced [M] vector, so the semi-async scheduler's staleness-damped
        effective weights never trigger a recompile. The state's device axis
        must already equal ``cohort_size`` (see ``resize_cohort``).

        The round ends with a check-in — θ2 ← broadcast(masked eq. (1)) — so
        device slots leave the round uniform: padding slots never leak into
        the next round and re-bucketing between rounds stays exact.

        Cached per (P, Q, cohort_size, k, b, collect) bucket: a population run
        whose cohort sizes vary round-to-round compiles one executor per
        bucket, not one per round.
        """
        if P < 1 or Q < 1 or P % Q:
            raise ValueError(f"P={P} must be a positive multiple of Q={Q}")
        if cohort_size < 1:
            raise ValueError(f"cohort_size={cohort_size} must be >= 1")
        k = self.train.compression_k if compression_k is None else compression_k
        b = self.train.quantization_bits if quant_levels is None else quant_levels
        key = (P, Q, cohort_size, k, b, collect_stats)
        fn = self._round_cache.get(key)
        if fn is None:
            lam = P // Q
            A = cohort_size

            @partial(jax.jit, donate_argnums=(0,))
            def hsgd_cohort_round(state, data, group_weights, lr, participants, pmask):
                state, out = self._round_impl(
                    state, data, group_weights, lr, Q, lam, k, b,
                    collect_stats, idx=participants, pmask=pmask)
                theta2_group = F.local_aggregate(state.theta2, pmask)
                state = state._replace(
                    theta2=F.broadcast_to_devices(theta2_group, A))
                return state, out

            fn = self._round_cache[key] = hsgd_cohort_round
        return fn

    def run(self, state: HSGDState, data, group_weights, rounds: int,
            mesh: Optional[Mesh] = None):
        """Execute ``rounds`` global rounds; returns (state, per-step losses).

        Donates ``state`` (no double-buffering of the [M, A, ...] pytree).
        """
        lr_fn = halving_schedule(self.train.learning_rate, self.train.lr_halve_every)
        state, data, group_weights = place_on_mesh(state, data, group_weights, mesh)

        @partial(jax.jit, donate_argnums=(0,))
        def go(state, data, group_weights):
            def body(state, _):
                return self._round(state, data, group_weights, lr_fn)

            return jax.lax.scan(body, state, None, length=rounds)

        state, losses = go(state, data, group_weights)
        return state, losses.reshape(-1)


def make_group_weights(data) -> jnp.ndarray:
    """K_m weights from the per-group valid-sample counts."""
    return jnp.sum(data["valid"].astype(jnp.float32), axis=1)


# checkpoint restores return a real HSGDState, not an anonymous namedtuple
from repro.checkpoint.ckpt import register_state_class as _register_state_class  # noqa: E402

_register_state_class(HSGDState)
