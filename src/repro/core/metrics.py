"""Evaluation metrics used by the paper: loss, accuracy, AUC of ROC,
precision, recall, F1 (macro, one-vs-rest for multi-class)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.split_model import HybridModel


def evaluate_global(model: HybridModel, params, x1, x2, y, batch: int = 512) -> Dict[str, float]:
    """Full-dataset metrics for a global model {theta0, theta1, theta2}."""
    n = len(y)
    scores = []
    loss_sum = 0.0

    @jax.jit
    def fwd(p, a, b):
        z1 = model.h1(p["theta1"], a)
        z2 = model.h2(p["theta2"], b)
        return model.predict(p["theta0"], z1, z2)

    for i in range(0, n, batch):
        logits = np.asarray(fwd(params, x1[i : i + batch], x2[i : i + batch]))
        scores.append(logits)
    logits = np.concatenate(scores)
    y = np.asarray(y)
    logp = logits - _logsumexp(logits)
    loss = float(-np.mean(logp[np.arange(n), y]))
    pred = np.argmax(logits, axis=-1)
    acc = float(np.mean(pred == y))
    out = {"loss": loss, "accuracy": acc}
    out.update(precision_recall_f1(y, pred, logits.shape[-1]))
    out["auc_roc"] = auc_roc_ovr(y, _softmax(logits))
    return out


def smoothed_losses(losses, window: int = 4) -> np.ndarray:
    """Trailing-mean smoothing of a per-step loss curve (window clamped to
    the prefix length at the start, so output[i] averages steps max(0, i-w+1)..i)."""
    losses = np.asarray(losses, np.float64)
    w = max(1, int(window))
    c = np.cumsum(np.concatenate([[0.0], losses]))
    idx = np.arange(1, len(losses) + 1)
    lo = np.maximum(idx - w, 0)
    return (c[idx] - c[lo]) / (idx - lo)


def steps_to_target(losses, target: float, window: int = 4):
    """First step index whose smoothed loss reaches ``target``; None if never.

    The bytes-to-target-loss metric of the adaptive benchmarks (paper Fig. 7's
    'communication cost to reach a target accuracy', in miniature) indexes a
    cumulative-bytes curve with this.
    """
    sm = smoothed_losses(losses, window)
    hits = np.flatnonzero(sm <= target)
    return int(hits[0]) if len(hits) else None


def _logsumexp(x):
    m = np.max(x, axis=-1, keepdims=True)
    return m + np.log(np.sum(np.exp(x - m), axis=-1, keepdims=True))


def _softmax(x):
    e = np.exp(x - np.max(x, axis=-1, keepdims=True))
    return e / np.sum(e, axis=-1, keepdims=True)


def precision_recall_f1(y_true, y_pred, n_classes: int) -> Dict[str, float]:
    """Macro precision/recall/F1. Macro-F1 is the MEAN OF PER-CLASS F1 scores
    (f1_c = 2·tp/(2·tp + fp + fn), over classes present in y_true or y_pred),
    not the harmonic mean of macro-precision and macro-recall — the two only
    coincide when every class has identical precision and recall."""
    precs, recs, f1s = [], [], []
    for c in range(n_classes):
        tp = np.sum((y_pred == c) & (y_true == c))
        fp = np.sum((y_pred == c) & (y_true != c))
        fn = np.sum((y_pred != c) & (y_true == c))
        if tp + fp > 0:
            precs.append(tp / (tp + fp))
        if tp + fn > 0:
            recs.append(tp / (tp + fn))
        if tp + fp + fn > 0:
            f1s.append(2.0 * tp / (2.0 * tp + fp + fn))
    p = float(np.mean(precs)) if precs else 0.0
    r = float(np.mean(recs)) if recs else 0.0
    f1 = float(np.mean(f1s)) if f1s else 0.0
    return {"precision": p, "recall": r, "f1": f1}


def auc_roc_ovr(y_true, probs) -> float:
    """Macro one-vs-rest AUC via the rank-statistic (Mann-Whitney) identity."""
    aucs = []
    for c in range(probs.shape[-1]):
        pos = probs[y_true == c, c]
        neg = probs[y_true != c, c]
        if len(pos) == 0 or len(neg) == 0:
            continue
        ranks = _rankdata(np.concatenate([pos, neg]))
        r_pos = np.sum(ranks[: len(pos)])
        auc = (r_pos - len(pos) * (len(pos) + 1) / 2) / (len(pos) * len(neg))
        aucs.append(auc)
    return float(np.mean(aucs)) if aucs else 0.5


def _rankdata(a):
    order = np.argsort(a, kind="mergesort")
    ranks = np.empty(len(a), float)
    sorted_a = a[order]
    # average ranks for ties
    i = 0
    rank = 1
    while i < len(a):
        j = i
        while j + 1 < len(a) and sorted_a[j + 1] == sorted_a[i]:
            j += 1
        avg = (rank + rank + (j - i)) / 2.0
        ranks[order[i : j + 1]] = avg
        rank += j - i + 1
        i = j + 1
    return ranks
