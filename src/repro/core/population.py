"""Population-scale federation: device registry, cohorts, semi-async rounds.

ROADMAP item 1. The paper's experiments materialize every group and march
them in lockstep; real e-health fleets (PAPERS.md: Nguyen et al. 2021,
Bharati et al. 2022) are large device populations with availability windows,
heterogeneous links, and stragglers. This module layers that population on
top of the existing partition/HSGD machinery *as a simulation*:

  DeviceRegistry      — per-group device traces drawn from a single seed:
                        latency and compute multipliers (lognormal) plus a
                        periodic availability window per device. Each device
                        holds one valid data row of ``data/partition.py``'s
                        non-IID split (several devices may hold the same row
                        when the simulated population outnumbers the rows).
  Cohort sampling     — each round samples the available devices of every
                        group (without replacement, capped at
                        ``target_cohort``), pads to the next power-of-two
                        bucket by repeating real members, and records a
                        participation mask + per-group straggler tails. The
                        compiled executors are cached per bucket
                        (``HSGDRunner.cohort_round_fn``), so varying cohorts
                        never recompile within a bucket.
  PopulationScheduler — the simulated clock. ``sync`` waits for the slowest
                        participating group; ``semi_async`` closes the round
                        at a duration quantile (the deadline) and applies
                        late groups' updates at the NEXT global aggregation
                        with staleness-damped weights (FedAsync-style
                        ``damping**staleness``; dropped past
                        ``max_staleness``) instead of blocking everyone.
  make_time_of        — the wall-clock model ``time_of(P, rung)`` the
                        adaptive controller's governor projects against
                        (``controller.plan_round``), built from the
                        registry's typical cohort tails so the loop optimizes
                        time-to-accuracy under stragglers, not bytes alone.

Everything is reproducible from ``PopulationConfig.seed`` alone: traces use
``default_rng([seed, 0])``-style streams and round r's cohort uses
``default_rng([seed, 1, r])``, so the same seed yields the identical
participant schedule and latency draws on every run (pinned by a test).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional

import numpy as np

from repro.common.buckets import pow2_ceil
from repro.common.config import FederationConfig, TrainConfig
from repro.core import comm_model as CM
from repro.core.hsgd import (
    HSGDRunner,
    HSGDState,
    init_state,
    make_group_weights,
    resize_cohort,
)


@dataclass(frozen=True)
class PopulationConfig:
    """Simulated-fleet knobs (all randomness derives from ``seed``)."""

    seed: int = 0
    devices_per_group: int = 64     # simulated population N per group
    target_cohort: int = 8          # devices sampled per group per round
    lat_sigma: float = 0.6          # lognormal sigma of device link multipliers
    comp_sigma: float = 0.4         # lognormal sigma of device compute multipliers
    duty_min: float = 0.5           # availability duty-cycle range
    duty_max: float = 0.95
    period: float = 600.0           # availability window period (sim seconds)
    deadline_quantile: float = 0.8  # semi-async: close the round here
    staleness_damping: float = 0.6  # late update weight *= damping**staleness
    max_staleness: int = 4          # older than this -> dropped
    # retry/backoff (fault tolerance): when a semi-async round's on-time
    # fraction falls below min_quorum, the deadline re-extends by
    # backoff_factor, up to max_retries times (capped at the slowest
    # participant); groups still late after the last retry go down the
    # usual staleness path and are dropped past max_staleness.
    min_quorum: float = 0.5
    max_retries: int = 2
    backoff_factor: float = 2.0

    def __post_init__(self):
        if self.devices_per_group < 1 or self.target_cohort < 1:
            raise ValueError(
                f"devices_per_group/target_cohort must be >= 1, got "
                f"{self.devices_per_group}/{self.target_cohort}")
        if not 0.0 < self.deadline_quantile <= 1.0:
            raise ValueError(
                f"deadline_quantile must be in (0, 1], got {self.deadline_quantile}")
        if not 0.0 <= self.min_quorum <= 1.0:
            raise ValueError(f"min_quorum must be in [0, 1], got {self.min_quorum}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_factor <= 1.0:
            raise ValueError(
                f"backoff_factor must be > 1, got {self.backoff_factor}")


class Cohort(NamedTuple):
    """One round's sampled participants, padded to a pow2 bucket."""

    idx: np.ndarray        # [M, A] data-row indices (pads repeat real members)
    pmask: np.ndarray      # [M, A] 1.0 on real slots, 0.0 on padding
    counts: np.ndarray     # [M] real members per group (0 = group absent)
    dev_tail: np.ndarray   # [M] max link multiplier over real members (1 if none)
    comp_tail: np.ndarray  # [M] max compute multiplier over real members


class DeviceRegistry:
    """Seeded per-device traces for M groups × N simulated devices.

    ``lat_mult``/``comp_mult`` [M, N] are fixed per-device multipliers on the
    nominal WAN link and compute times. ``duty``/``phase`` define a periodic
    availability window: device (m, j) is online at sim time t iff
    ``(t/period + phase) mod 1 < duty``. ``data_row`` [M, N] maps each device
    to a valid data row of the stacked partition.
    """

    def __init__(self, data: Dict[str, np.ndarray], cfg: PopulationConfig):
        valid = np.asarray(data["valid"], bool)
        M, K = valid.shape
        N = cfg.devices_per_group
        rng = np.random.default_rng([cfg.seed, 0])
        self.cfg = cfg
        self.num_groups, self.pop_per_group = M, N
        self.lat_mult = np.exp(rng.normal(0.0, cfg.lat_sigma, (M, N)))
        self.comp_mult = np.exp(rng.normal(0.0, cfg.comp_sigma, (M, N)))
        # devices never beat the nominal link/compute speed: the paper's
        # constants are the fleet's best case, multipliers only slow down
        self.lat_mult = np.maximum(self.lat_mult, 1.0)
        self.comp_mult = np.maximum(self.comp_mult, 1.0)
        self.duty = rng.uniform(cfg.duty_min, cfg.duty_max, (M, N))
        self.phase = rng.uniform(0.0, 1.0, (M, N))
        rows = np.zeros((M, N), np.int64)
        for m in range(M):
            vm = np.flatnonzero(valid[m])
            if vm.size == 0:
                vm = np.arange(K)
            rows[m] = vm[rng.integers(0, vm.size, N)]
        self.data_row = rows

    def available(self, now: float) -> np.ndarray:
        """[M, N] bool: which devices are inside their window at sim time now."""
        return ((now / self.cfg.period + self.phase) % 1.0) < self.duty

    def sample_cohort(self, round_idx: int, now: float) -> Cohort:
        """Round r's participants, deterministic in (seed, r, availability)."""
        cfg = self.cfg
        M = self.num_groups
        rng = np.random.default_rng([cfg.seed, 1, round_idx])
        avail = self.available(now)
        picks: List[np.ndarray] = []
        counts = np.zeros(M, np.int64)
        for m in range(M):
            cand = np.flatnonzero(avail[m])
            n_take = min(cfg.target_cohort, cand.size)
            picks.append(rng.choice(cand, size=n_take, replace=False)
                         if n_take else np.zeros(0, np.int64))
            counts[m] = n_take
        A = pow2_ceil(max(1, int(counts.max())))
        idx = np.zeros((M, A), np.int64)
        pmask = np.zeros((M, A), np.float32)
        dev_tail = np.ones(M)
        comp_tail = np.ones(M)
        for m in range(M):
            devs = picks[m]
            if devs.size:
                padded = devs[np.arange(A) % devs.size]  # pads repeat members
                idx[m] = self.data_row[m, padded]
                pmask[m, : devs.size] = 1.0
                dev_tail[m] = self.lat_mult[m, devs].max()
                comp_tail[m] = self.comp_mult[m, devs].max()
            else:
                idx[m] = self.data_row[m, 0]  # unread: pmask stays 0, weight 0
        return Cohort(idx, pmask, counts, dev_tail, comp_tail)

    def typical_tails(self, quantile: float, n_draws: int = 8):
        """Representative per-group cohort tails for the planner's time model:
        the mean over ``n_draws`` seeded cohort draws of the max multiplier in
        a ``target_cohort``-sized subset. Returns ([M] dev, [M] comp)."""
        cfg = self.cfg
        M, N = self.lat_mult.shape
        rng = np.random.default_rng([cfg.seed, 2])
        A = min(cfg.target_cohort, N)
        dev = np.zeros((n_draws, M))
        comp = np.zeros((n_draws, M))
        for d in range(n_draws):
            for m in range(M):
                pick = rng.choice(N, size=A, replace=False)
                dev[d, m] = self.lat_mult[m, pick].max()
                comp[d, m] = self.comp_mult[m, pick].max()
        return dev.mean(axis=0), comp.mean(axis=0)


def cohort_durations(cohort: Cohort, sizes, P: int, Q: int, t_compute: float,
                     links=CM.WAN) -> np.ndarray:
    """[M] simulated seconds for each group's round under its cohort's tails."""
    fed_pq = FederationConfig(local_interval=Q, global_interval=P)
    return np.array([
        CM.round_time_hetero(sizes, fed_pq, t_compute, links,
                             dev_tail=float(cohort.dev_tail[m]),
                             compute_tail=float(cohort.comp_tail[m]))
        for m in range(len(cohort.counts))
    ])


class PopulationScheduler:
    """Simulated clock + staleness ledger over a DeviceRegistry.

    Per round: sample a cohort at the current sim time, run the compiled
    round, then ``settle`` with the per-group durations. ``settle`` advances
    the clock by the round's deadline (max duration in ``sync`` mode, the
    ``deadline_quantile`` in ``semi_async``), updates per-group staleness
    (on-time -> 0, late -> +1), and returns the effective group weights the
    NEXT round's global aggregation applies to the updates just produced:
    ``base_w * damping**staleness``, zero for absent groups and for updates
    older than ``max_staleness``.
    """

    def __init__(self, registry: DeviceRegistry, base_weights: np.ndarray,
                 mode: str = "semi_async"):
        if mode not in ("sync", "semi_async"):
            raise ValueError(f"mode must be sync|semi_async, got {mode!r}")
        self.registry = registry
        self.cfg = registry.cfg
        self.base_w = np.asarray(base_weights, np.float64)
        self.mode = mode
        self.now = 0.0
        self.round = 0
        self.staleness = np.zeros(registry.num_groups, np.int64)
        self.stale_hist: Dict[int, int] = {}

    def next_cohort(self) -> Cohort:
        return self.registry.sample_cohort(self.round, self.now)

    def settle(self, cohort: Cohort, durations: np.ndarray):
        """Advance the clock; return (next-round weights [M], round record).

        Semi-async retry/backoff: when the quantile deadline leaves fewer
        than ``min_quorum`` of the participating groups on time (mass
        stragglers — e.g. injected latency spikes), the deadline re-extends
        by ``backoff_factor`` up to ``max_retries`` times, capped at the
        slowest participant. The extension seconds are realized sim time —
        they advance the clock, so the adaptive governor's wall-clock ledger
        is charged for every retry (``core.record(..., seconds=now-prev)``).
        Groups still late after the last retry follow the usual staleness
        path (damped, dropped past ``max_staleness``).
        """
        part = cohort.counts > 0
        dur = np.asarray(durations, np.float64)
        retries = 0
        base_deadline = 0.0
        if not part.any():
            deadline = 0.0
            on_time = part
        elif self.mode == "sync":
            deadline = float(dur[part].max())
            on_time = part
        else:
            deadline = float(np.quantile(dur[part], self.cfg.deadline_quantile))
            base_deadline = deadline
            on_time = part & (dur <= deadline)
            worst = float(dur[part].max())
            while (retries < self.cfg.max_retries
                   and on_time.sum() < self.cfg.min_quorum * part.sum()
                   and deadline < worst):
                deadline = min(deadline * self.cfg.backoff_factor, worst)
                retries += 1
                on_time = part & (dur <= deadline)
        self.staleness = np.where(on_time, 0, self.staleness + 1)
        for s in self.staleness[part]:
            self.stale_hist[int(s)] = self.stale_hist.get(int(s), 0) + 1
        damp = np.where(self.staleness > self.cfg.max_staleness, 0.0,
                        self.cfg.staleness_damping ** self.staleness)
        w = self.base_w * part * damp
        if w.sum() <= 0.0:  # nobody usable: fall back, never divide by zero
            w = self.base_w.copy()
        self.now += deadline
        self.round += 1
        rec = {
            "round": self.round - 1,
            "deadline": deadline,
            "now": self.now,
            "cohort_sizes": cohort.counts.tolist(),
            "bucket": int(cohort.pmask.shape[1]),
            "late": int((part & ~on_time).sum()),
            "staleness": self.staleness.tolist(),
            "retries": retries,
            "retry_seconds": max(deadline - base_deadline, 0.0) if retries else 0.0,
        }
        return w, rec

    def state_dict(self) -> Dict[str, Any]:
        """Ledger snapshot for checkpointing (everything ``settle`` mutates)."""
        return {
            "now": float(self.now),
            "round": int(self.round),
            "staleness": self.staleness.tolist(),
            "stale_hist": {str(k): int(v) for k, v in self.stale_hist.items()},
        }

    def load_state_dict(self, sd: Dict[str, Any]) -> None:
        self.now = float(sd["now"])
        self.round = int(sd["round"])
        self.staleness = np.asarray(sd["staleness"], np.int64)
        self.stale_hist = {int(k): int(v) for k, v in sd["stale_hist"].items()}


def make_time_of(sizes_of, ladder, registry: DeviceRegistry, t_compute: float,
                 mode: str = "semi_async", links=CM.WAN):
    """Build the controller's ``time_of(P, rung)`` wall-clock model.

    Projects one P = Q round's simulated seconds at a ladder rung using the
    registry's typical cohort tails — the semi-async deadline quantile across
    groups (or the max, in sync mode). This is what turns the byte governor
    into a time-to-accuracy governor: compression rungs shrink the
    device-gated exchange legs, larger P amortizes t_g, both visible to the
    planner through this one callback.
    """
    cfg = registry.cfg
    dev_t, comp_t = registry.typical_tails(cfg.deadline_quantile)

    def time_of(P: int, rung: int) -> float:
        k, b = ladder[rung]
        sizes = sizes_of(k, b)
        fed_pq = FederationConfig(local_interval=P, global_interval=P)
        dur = np.array([
            CM.round_time_hetero(sizes, fed_pq, t_compute, links,
                                 dev_tail=float(dev_t[m]),
                                 compute_tail=float(comp_t[m]))
            for m in range(registry.num_groups)
        ])
        if mode == "sync":
            return float(dur.max())
        return float(np.quantile(dur, cfg.deadline_quantile))

    return time_of


# ---------------------------------------------------------------------------
# Run loops (fixed-interval sync/semi-async, and the adaptive governor)
# ---------------------------------------------------------------------------


def _lr_at(train: TrainConfig, step: int) -> float:
    if train.lr_halve_every:
        return train.learning_rate * 0.5 ** (step // train.lr_halve_every)
    return train.learning_rate


def run_population(model, fed: FederationConfig, train: TrainConfig,
                   data, pop: PopulationConfig, rounds: int,
                   mode: str = "semi_async", t_compute: float = 0.05,
                   links=CM.WAN, key=None,
                   runner: Optional[HSGDRunner] = None) -> Dict[str, Any]:
    """Fixed-(P, Q) population run over ``rounds`` sampled-cohort rounds.

    Returns per-step losses, the sim-clock time at the END of each step's
    round (for time-to-target curves), the scheduler's round records, and the
    runner (so callers can assert the per-bucket compile discipline via
    ``len(runner._round_cache)``).
    """
    import jax

    from repro.core.controller import hsgd_sizes_of

    if key is None:
        key = jax.random.PRNGKey(pop.seed)
    runner = runner or HSGDRunner(model, fed, train)
    state = init_state(key, model, fed, data)
    base_w = np.asarray(make_group_weights(data))
    registry = DeviceRegistry(data, pop)
    sched = PopulationScheduler(registry, base_w, mode=mode)
    sizes_of = hsgd_sizes_of(state, fed)
    sizes = sizes_of(train.compression_k, train.quantization_bits)
    P, Q = fed.global_interval, fed.local_interval

    w = base_w.copy()
    losses: List[np.ndarray] = []
    times: List[float] = []
    history: List[Dict[str, Any]] = []
    step = 0
    for _ in range(rounds):
        cohort = sched.next_cohort()
        A = int(cohort.pmask.shape[1])
        state = resize_cohort(state, model, data, A)
        fn = runner.cohort_round_fn(P, Q, A, collect_stats=False)
        state, round_losses = fn(state, data, w.astype(np.float32),
                                 _lr_at(train, step), cohort.idx, cohort.pmask)
        dur = cohort_durations(cohort, sizes, P, Q, t_compute, links)
        w, rec = sched.settle(cohort, dur)
        losses.append(np.asarray(jax.device_get(round_losses)))
        times.extend([sched.now] * P)
        history.append(rec)
        step += P
    return {
        "losses": np.concatenate(losses) if losses else np.zeros(0),
        "times": np.asarray(times),
        "history": history,
        "staleness_hist": dict(sched.stale_hist),
        "sim_seconds": sched.now,
        "runner": runner,
        "state": state,
    }


class CoordinatorPreempted(RuntimeError):
    """The fault plan killed the coordinator at a round boundary. Re-run with
    ``resume=True`` to continue bit-identically from the last auto-checkpoint."""

    def __init__(self, round_idx: int, ckpt_dir: Optional[str]):
        super().__init__(
            f"coordinator preempted at round {round_idx}"
            + (f"; resume from {ckpt_dir}" if ckpt_dir else " (no checkpoint dir)"))
        self.round_idx = round_idx
        self.ckpt_dir = ckpt_dir


def run_population_resilient(model, fed: FederationConfig, train: TrainConfig,
                             data, pop: PopulationConfig, rounds: int,
                             faults=None, injector=None,
                             mode: str = "semi_async", robust: bool = True,
                             monitor: bool = True, t_compute: float = 0.05,
                             links=CM.WAN, key=None,
                             runner: Optional[HSGDRunner] = None,
                             ckpt_dir: Optional[str] = None,
                             ckpt_every: int = 0, resume: bool = False,
                             divergence_factor: float = 20.0,
                             eta_shrink: float = 0.5,
                             max_rollbacks: int = 3) -> Dict[str, Any]:
    """Fault-tolerant population run: seeded injection + the recovery loop.

    Per round, the injector realizes the plan's faults: dropped devices leave
    the participation mask, NaN/outlier gradient terms and corrupted uplink
    multipliers ride into the compiled executor as traced values, latency
    spikes stretch the settle durations (charging the retry/backoff machinery
    and the wall-clock ledger), and lost/duplicated round updates re-weight
    the next global aggregation. ``robust=True`` runs the screened executor
    (``HSGDRunner.fault_round_fn``) with ``fed.robust_agg`` aggregation;
    ``robust=False`` is the naive stack under the same faults.

    Recovery: every ``ckpt_every`` rounds the ``HSGDState`` plus the
    scheduler ledger, loss/time curves, and weights are checkpointed
    atomically; the divergence monitor (non-finite round loss, or a spike
    past ``divergence_factor`` × the best round loss) rolls back to the last
    checkpoint with the learning rate shrunk by ``eta_shrink`` (at most
    ``max_rollbacks`` times). A planned coordinator preemption raises
    ``CoordinatorPreempted`` at the round boundary; calling again with
    ``resume=True`` reloads everything and continues bit-identically (the
    injector redraws round r's faults from ``default_rng([seed, 3, r])``, so
    the fault schedule needs no serialized RNG state).
    """
    import jax

    from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
    from repro.core.controller import hsgd_sizes_of
    from repro.core.faults import FaultInjector, FaultPlan

    if key is None:
        key = jax.random.PRNGKey(pop.seed)
    if injector is None:
        injector = FaultInjector(faults or FaultPlan())
    runner = runner or HSGDRunner(model, fed, train)
    state = init_state(key, model, fed, data)
    base_w = np.asarray(make_group_weights(data))
    registry = DeviceRegistry(data, pop)
    sched = PopulationScheduler(registry, base_w, mode=mode)
    sizes_of = hsgd_sizes_of(state, fed)
    sizes = sizes_of(train.compression_k, train.quantization_bits)
    P, Q = fed.global_interval, fed.local_interval
    M = fed.num_groups

    w = base_w.copy()
    losses: List[np.ndarray] = []
    times: List[float] = []
    history: List[Dict[str, Any]] = []
    fault_log: List[Dict[str, Any]] = []
    step = 0
    lr_scale = 1.0
    best = float("inf")
    rollbacks = 0
    manifest = os.path.join(ckpt_dir, "manifest.json") if ckpt_dir else None
    have_ckpt = bool(manifest and os.path.exists(manifest))

    def save(tag: str):
        payload = {
            "state": state,
            "losses": (np.concatenate(losses).astype(np.float32)
                       if losses else np.zeros(0, np.float32)),
            "times": np.asarray(times, np.float64),
            "w": np.asarray(w, np.float64),
        }
        extra = {
            "sched": sched.state_dict(),
            "step": int(step),
            "lr_scale": float(lr_scale),
            "best": best if np.isfinite(best) else None,
            "rollbacks": int(rollbacks),
            "history": history,
            "tag": tag,
        }
        save_checkpoint(ckpt_dir, payload, step=step, extra=extra)

    def restore():
        nonlocal state, losses, times, w, step, lr_scale, best, rollbacks, history
        payload, _, extra = load_checkpoint(ckpt_dir)
        # back on device before re-entering the donating executors
        state = jax.tree.map(jax.numpy.asarray, payload["state"])
        arr = np.asarray(payload["losses"])
        losses = [arr] if arr.size else []
        times = list(np.asarray(payload["times"]))
        w = np.asarray(payload["w"], np.float64)
        sched.load_state_dict(extra["sched"])
        step = int(extra["step"])
        lr_scale = float(extra["lr_scale"])
        best = float("inf") if extra["best"] is None else float(extra["best"])
        rollbacks = int(extra["rollbacks"])
        history = list(extra["history"])

    if resume:
        if not have_ckpt:
            raise FileNotFoundError(
                f"resume requested but no checkpoint at {ckpt_dir!r}")
        restore()

    while sched.round < rounds:
        r = sched.round
        cohort = sched.next_cohort()
        A = int(cohort.pmask.shape[1])
        flt = injector.faults(r, M, A, cohort.pmask)
        if flt.preempt and not resume:
            raise CoordinatorPreempted(r, ckpt_dir)
        state = resize_cohort(state, model, data, A)
        pmask_eff = (cohort.pmask * (1.0 - flt.drop)).astype(np.float32)
        cohort_eff = cohort._replace(
            pmask=pmask_eff, counts=pmask_eff.sum(axis=1).astype(np.int64))
        fn = runner.fault_round_fn(P, Q, A, robust=robust)
        state, round_losses, flagged = fn(
            state, data, w.astype(np.float32), _lr_at(train, step) * lr_scale,
            cohort.idx, pmask_eff, flt.grad_fault, flt.msg_fault)
        dur = cohort_durations(cohort_eff, sizes, P, Q, t_compute, links)
        dur = dur * flt.latency_mult
        w, rec = sched.settle(cohort_eff, dur)
        # lost/duplicated round updates re-weight the NEXT global aggregation
        w = w * np.where(flt.lost, 0.0, 1.0) * np.where(flt.dup, 2.0, 1.0)
        rl = np.asarray(jax.device_get(round_losses))
        flagged = float(jax.device_get(flagged))
        fault_log.append({
            "round": r,
            "dropped": int(flt.drop.sum()),
            "grad_faulted": int((np.nan_to_num(flt.grad_fault, nan=1.0) != 0).sum()),
            "msg_faulted": int((np.nan_to_num(flt.msg_fault, nan=1.0) != 0).sum()),
            "lost": int(flt.lost.sum()), "dup": int(flt.dup.sum()),
            "latency_spikes": int((flt.latency_mult > 1.0).sum()),
            "flagged_updates": flagged,
            "retries": rec["retries"],
        })
        mean_loss = float(np.mean(rl)) if rl.size else float("nan")
        diverged = (not np.isfinite(mean_loss)
                    or (np.isfinite(best)
                        and mean_loss > divergence_factor * max(best, 1e-9)))
        if monitor and diverged and have_ckpt and rollbacks < max_rollbacks:
            # both survive the restore (which reloads the checkpoint's older
            # values): repeated rollbacks to the SAME checkpoint keep
            # compounding the η shrink instead of retrying at the same rate
            rb = rollbacks + 1
            ls = lr_scale * eta_shrink
            restore()
            rollbacks, lr_scale = rb, ls
            fault_log[-1]["rolled_back"] = True
            continue
        losses.append(rl)
        times.extend([sched.now] * P)
        history.append(rec)
        step += P
        if np.isfinite(mean_loss):
            best = min(best, mean_loss)
        if ckpt_dir and ckpt_every and sched.round % ckpt_every == 0:
            save(f"round-{sched.round}")
            have_ckpt = True

    final = np.concatenate(losses) if losses else np.zeros(0)
    return {
        "losses": final,
        "times": np.asarray(times),
        "history": history,
        "fault_log": fault_log,
        "staleness_hist": dict(sched.stale_hist),
        "sim_seconds": sched.now,
        "runner": runner,
        "state": state,
        "injector": injector,
        "rollbacks": rollbacks,
        "lr_scale": lr_scale,
        "recovered": bool(final.size and np.isfinite(final[-1])),
    }


def run_population_adaptive(model, fed: FederationConfig, train: TrainConfig,
                            data, pop: PopulationConfig, cfg,
                            t_compute: float = 0.05, links=CM.WAN,
                            key=None,
                            runner: Optional[HSGDRunner] = None) -> Dict[str, Any]:
    """Adaptive population run: ControllerCore + wall-clock governor.

    Each round the controller picks (P, Q, η, rung) against BOTH ledgers
    (bytes and simulated seconds, via ``make_time_of``), the scheduler samples
    a cohort, and the realized semi-async deadline is charged back with
    ``core.record(..., seconds=...)``. ``cfg`` is an
    ``controller.AdaptiveConfig`` (set ``time_budget`` to engage the
    wall-clock governor).
    """
    import jax

    from repro.core.controller import ControllerCore, hsgd_sizes_of

    if key is None:
        key = jax.random.PRNGKey(pop.seed)
    runner = runner or HSGDRunner(model, fed, train)
    state = init_state(key, model, fed, data)
    base_w = np.asarray(make_group_weights(data))
    registry = DeviceRegistry(data, pop)
    sched = PopulationScheduler(registry, base_w, mode="semi_async")
    sizes_of = hsgd_sizes_of(state, fed)
    time_of = make_time_of(sizes_of, cfg.ladder, registry, t_compute,
                           mode="semi_async", links=links)
    core = ControllerCore(cfg, fed, sizes_of, eta0=train.learning_rate,
                          time_of=time_of)

    w = base_w.copy()
    losses: List[np.ndarray] = []
    times: List[float] = []
    while not core.done:
        plan, (k_frac, levels) = core.plan()
        cohort = sched.next_cohort()
        A = int(cohort.pmask.shape[1])
        state = resize_cohort(state, model, data, A)
        fn = runner.cohort_round_fn(plan.P, plan.Q, A, k_frac, levels,
                                    collect_stats=True)
        state, stats = fn(state, data, w.astype(np.float32), plan.eta,
                          cohort.idx, cohort.pmask)
        stats = jax.device_get(stats)
        sizes = sizes_of(k_frac, levels)
        dur = cohort_durations(cohort, sizes, plan.P, plan.Q, t_compute, links)
        prev_now = sched.now
        w, _ = sched.settle(cohort, dur)
        # charge the realized semi-async deadline, not the planner's model
        core.record(plan, stats, seconds=sched.now - prev_now)
        losses.append(np.asarray(stats["loss"]))
        times.extend([sched.now] * plan.P)
    return {
        "losses": np.concatenate(losses) if losses else np.zeros(0),
        "times": np.asarray(times),
        "history": core.history,
        "staleness_hist": dict(sched.stale_hist),
        "sim_seconds": sched.now,
        "runner": runner,
        "state": state,
        "core": core,
    }
