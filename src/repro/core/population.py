"""Population-scale federation: device registry, cohorts, semi-async rounds.

ROADMAP item 1. The paper's experiments materialize every group and march
them in lockstep; real e-health fleets (PAPERS.md: Nguyen et al. 2021,
Bharati et al. 2022) are large device populations with availability windows,
heterogeneous links, and stragglers. This module layers that population on
top of the existing partition/HSGD machinery *as a simulation*:

  DeviceRegistry      — per-group device traces drawn from a single seed:
                        latency and compute multipliers (lognormal) plus a
                        periodic availability window per device. Each device
                        holds one valid data row of ``data/partition.py``'s
                        non-IID split (several devices may hold the same row
                        when the simulated population outnumbers the rows).
  Cohort sampling     — each round samples the available devices of every
                        group (without replacement, capped at
                        ``target_cohort``), pads to the next power-of-two
                        bucket by repeating real members, and records a
                        participation mask + per-group straggler tails. The
                        compiled executors are cached per bucket
                        (``HSGDRunner.cohort_round_fn``), so varying cohorts
                        never recompile within a bucket.
  PopulationScheduler — the simulated clock. ``sync`` waits for the slowest
                        participating group; ``semi_async`` closes the round
                        at a duration quantile (the deadline) and applies
                        late groups' updates at the NEXT global aggregation
                        with staleness-damped weights (FedAsync-style
                        ``damping**staleness``; dropped past
                        ``max_staleness``) instead of blocking everyone.
  make_time_of        — the wall-clock model ``time_of(P, rung)`` the
                        adaptive controller's governor projects against
                        (``controller.plan_round``), built from the
                        registry's typical cohort tails so the loop optimizes
                        time-to-accuracy under stragglers, not bytes alone.

Everything is reproducible from ``PopulationConfig.seed`` alone: traces use
``default_rng([seed, 0])``-style streams and round r's cohort uses
``default_rng([seed, 1, r])``, so the same seed yields the identical
participant schedule and latency draws on every run (pinned by a test).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional

import numpy as np

from repro.common.buckets import pow2_ceil
from repro.common.config import FederationConfig, TrainConfig
from repro.core import comm_model as CM
from repro.core.hsgd import (
    HSGDRunner,
    HSGDState,
    init_state,
    make_group_weights,
    resize_cohort,
)


@dataclass(frozen=True)
class PopulationConfig:
    """Simulated-fleet knobs (all randomness derives from ``seed``)."""

    seed: int = 0
    devices_per_group: int = 64     # simulated population N per group
    target_cohort: int = 8          # devices sampled per group per round
    lat_sigma: float = 0.6          # lognormal sigma of device link multipliers
    comp_sigma: float = 0.4         # lognormal sigma of device compute multipliers
    duty_min: float = 0.5           # availability duty-cycle range
    duty_max: float = 0.95
    period: float = 600.0           # availability window period (sim seconds)
    deadline_quantile: float = 0.8  # semi-async: close the round here
    staleness_damping: float = 0.6  # late update weight *= damping**staleness
    max_staleness: int = 4          # older than this -> dropped


class Cohort(NamedTuple):
    """One round's sampled participants, padded to a pow2 bucket."""

    idx: np.ndarray        # [M, A] data-row indices (pads repeat real members)
    pmask: np.ndarray      # [M, A] 1.0 on real slots, 0.0 on padding
    counts: np.ndarray     # [M] real members per group (0 = group absent)
    dev_tail: np.ndarray   # [M] max link multiplier over real members (1 if none)
    comp_tail: np.ndarray  # [M] max compute multiplier over real members


class DeviceRegistry:
    """Seeded per-device traces for M groups × N simulated devices.

    ``lat_mult``/``comp_mult`` [M, N] are fixed per-device multipliers on the
    nominal WAN link and compute times. ``duty``/``phase`` define a periodic
    availability window: device (m, j) is online at sim time t iff
    ``(t/period + phase) mod 1 < duty``. ``data_row`` [M, N] maps each device
    to a valid data row of the stacked partition.
    """

    def __init__(self, data: Dict[str, np.ndarray], cfg: PopulationConfig):
        valid = np.asarray(data["valid"], bool)
        M, K = valid.shape
        N = cfg.devices_per_group
        rng = np.random.default_rng([cfg.seed, 0])
        self.cfg = cfg
        self.num_groups, self.pop_per_group = M, N
        self.lat_mult = np.exp(rng.normal(0.0, cfg.lat_sigma, (M, N)))
        self.comp_mult = np.exp(rng.normal(0.0, cfg.comp_sigma, (M, N)))
        # devices never beat the nominal link/compute speed: the paper's
        # constants are the fleet's best case, multipliers only slow down
        self.lat_mult = np.maximum(self.lat_mult, 1.0)
        self.comp_mult = np.maximum(self.comp_mult, 1.0)
        self.duty = rng.uniform(cfg.duty_min, cfg.duty_max, (M, N))
        self.phase = rng.uniform(0.0, 1.0, (M, N))
        rows = np.zeros((M, N), np.int64)
        for m in range(M):
            vm = np.flatnonzero(valid[m])
            if vm.size == 0:
                vm = np.arange(K)
            rows[m] = vm[rng.integers(0, vm.size, N)]
        self.data_row = rows

    def available(self, now: float) -> np.ndarray:
        """[M, N] bool: which devices are inside their window at sim time now."""
        return ((now / self.cfg.period + self.phase) % 1.0) < self.duty

    def sample_cohort(self, round_idx: int, now: float) -> Cohort:
        """Round r's participants, deterministic in (seed, r, availability)."""
        cfg = self.cfg
        M = self.num_groups
        rng = np.random.default_rng([cfg.seed, 1, round_idx])
        avail = self.available(now)
        picks: List[np.ndarray] = []
        counts = np.zeros(M, np.int64)
        for m in range(M):
            cand = np.flatnonzero(avail[m])
            n_take = min(cfg.target_cohort, cand.size)
            picks.append(rng.choice(cand, size=n_take, replace=False)
                         if n_take else np.zeros(0, np.int64))
            counts[m] = n_take
        A = pow2_ceil(max(1, int(counts.max())))
        idx = np.zeros((M, A), np.int64)
        pmask = np.zeros((M, A), np.float32)
        dev_tail = np.ones(M)
        comp_tail = np.ones(M)
        for m in range(M):
            devs = picks[m]
            if devs.size:
                padded = devs[np.arange(A) % devs.size]  # pads repeat members
                idx[m] = self.data_row[m, padded]
                pmask[m, : devs.size] = 1.0
                dev_tail[m] = self.lat_mult[m, devs].max()
                comp_tail[m] = self.comp_mult[m, devs].max()
            else:
                idx[m] = self.data_row[m, 0]  # unread: pmask stays 0, weight 0
        return Cohort(idx, pmask, counts, dev_tail, comp_tail)

    def typical_tails(self, quantile: float, n_draws: int = 8):
        """Representative per-group cohort tails for the planner's time model:
        the mean over ``n_draws`` seeded cohort draws of the max multiplier in
        a ``target_cohort``-sized subset. Returns ([M] dev, [M] comp)."""
        cfg = self.cfg
        M, N = self.lat_mult.shape
        rng = np.random.default_rng([cfg.seed, 2])
        A = min(cfg.target_cohort, N)
        dev = np.zeros((n_draws, M))
        comp = np.zeros((n_draws, M))
        for d in range(n_draws):
            for m in range(M):
                pick = rng.choice(N, size=A, replace=False)
                dev[d, m] = self.lat_mult[m, pick].max()
                comp[d, m] = self.comp_mult[m, pick].max()
        return dev.mean(axis=0), comp.mean(axis=0)


def cohort_durations(cohort: Cohort, sizes, P: int, Q: int, t_compute: float,
                     links=CM.WAN) -> np.ndarray:
    """[M] simulated seconds for each group's round under its cohort's tails."""
    fed_pq = FederationConfig(local_interval=Q, global_interval=P)
    return np.array([
        CM.round_time_hetero(sizes, fed_pq, t_compute, links,
                             dev_tail=float(cohort.dev_tail[m]),
                             compute_tail=float(cohort.comp_tail[m]))
        for m in range(len(cohort.counts))
    ])


class PopulationScheduler:
    """Simulated clock + staleness ledger over a DeviceRegistry.

    Per round: sample a cohort at the current sim time, run the compiled
    round, then ``settle`` with the per-group durations. ``settle`` advances
    the clock by the round's deadline (max duration in ``sync`` mode, the
    ``deadline_quantile`` in ``semi_async``), updates per-group staleness
    (on-time -> 0, late -> +1), and returns the effective group weights the
    NEXT round's global aggregation applies to the updates just produced:
    ``base_w * damping**staleness``, zero for absent groups and for updates
    older than ``max_staleness``.
    """

    def __init__(self, registry: DeviceRegistry, base_weights: np.ndarray,
                 mode: str = "semi_async"):
        if mode not in ("sync", "semi_async"):
            raise ValueError(f"mode must be sync|semi_async, got {mode!r}")
        self.registry = registry
        self.cfg = registry.cfg
        self.base_w = np.asarray(base_weights, np.float64)
        self.mode = mode
        self.now = 0.0
        self.round = 0
        self.staleness = np.zeros(registry.num_groups, np.int64)
        self.stale_hist: Dict[int, int] = {}

    def next_cohort(self) -> Cohort:
        return self.registry.sample_cohort(self.round, self.now)

    def settle(self, cohort: Cohort, durations: np.ndarray):
        """Advance the clock; return (next-round weights [M], round record)."""
        part = cohort.counts > 0
        dur = np.asarray(durations, np.float64)
        if not part.any():
            deadline = 0.0
            on_time = part
        elif self.mode == "sync":
            deadline = float(dur[part].max())
            on_time = part
        else:
            deadline = float(np.quantile(dur[part], self.cfg.deadline_quantile))
            on_time = part & (dur <= deadline)
        self.staleness = np.where(on_time, 0, self.staleness + 1)
        for s in self.staleness[part]:
            self.stale_hist[int(s)] = self.stale_hist.get(int(s), 0) + 1
        damp = np.where(self.staleness > self.cfg.max_staleness, 0.0,
                        self.cfg.staleness_damping ** self.staleness)
        w = self.base_w * part * damp
        if w.sum() <= 0.0:  # nobody usable: fall back, never divide by zero
            w = self.base_w.copy()
        self.now += deadline
        self.round += 1
        rec = {
            "round": self.round - 1,
            "deadline": deadline,
            "now": self.now,
            "cohort_sizes": cohort.counts.tolist(),
            "bucket": int(cohort.pmask.shape[1]),
            "late": int((part & ~on_time).sum()),
            "staleness": self.staleness.tolist(),
        }
        return w, rec


def make_time_of(sizes_of, ladder, registry: DeviceRegistry, t_compute: float,
                 mode: str = "semi_async", links=CM.WAN):
    """Build the controller's ``time_of(P, rung)`` wall-clock model.

    Projects one P = Q round's simulated seconds at a ladder rung using the
    registry's typical cohort tails — the semi-async deadline quantile across
    groups (or the max, in sync mode). This is what turns the byte governor
    into a time-to-accuracy governor: compression rungs shrink the
    device-gated exchange legs, larger P amortizes t_g, both visible to the
    planner through this one callback.
    """
    cfg = registry.cfg
    dev_t, comp_t = registry.typical_tails(cfg.deadline_quantile)

    def time_of(P: int, rung: int) -> float:
        k, b = ladder[rung]
        sizes = sizes_of(k, b)
        fed_pq = FederationConfig(local_interval=P, global_interval=P)
        dur = np.array([
            CM.round_time_hetero(sizes, fed_pq, t_compute, links,
                                 dev_tail=float(dev_t[m]),
                                 compute_tail=float(comp_t[m]))
            for m in range(registry.num_groups)
        ])
        if mode == "sync":
            return float(dur.max())
        return float(np.quantile(dur, cfg.deadline_quantile))

    return time_of


# ---------------------------------------------------------------------------
# Run loops (fixed-interval sync/semi-async, and the adaptive governor)
# ---------------------------------------------------------------------------


def _lr_at(train: TrainConfig, step: int) -> float:
    if train.lr_halve_every:
        return train.learning_rate * 0.5 ** (step // train.lr_halve_every)
    return train.learning_rate


def run_population(model, fed: FederationConfig, train: TrainConfig,
                   data, pop: PopulationConfig, rounds: int,
                   mode: str = "semi_async", t_compute: float = 0.05,
                   links=CM.WAN, key=None,
                   runner: Optional[HSGDRunner] = None) -> Dict[str, Any]:
    """Fixed-(P, Q) population run over ``rounds`` sampled-cohort rounds.

    Returns per-step losses, the sim-clock time at the END of each step's
    round (for time-to-target curves), the scheduler's round records, and the
    runner (so callers can assert the per-bucket compile discipline via
    ``len(runner._round_cache)``).
    """
    import jax

    from repro.core.controller import hsgd_sizes_of

    if key is None:
        key = jax.random.PRNGKey(pop.seed)
    runner = runner or HSGDRunner(model, fed, train)
    state = init_state(key, model, fed, data)
    base_w = np.asarray(make_group_weights(data))
    registry = DeviceRegistry(data, pop)
    sched = PopulationScheduler(registry, base_w, mode=mode)
    sizes_of = hsgd_sizes_of(state, fed)
    sizes = sizes_of(train.compression_k, train.quantization_bits)
    P, Q = fed.global_interval, fed.local_interval

    w = base_w.copy()
    losses: List[np.ndarray] = []
    times: List[float] = []
    history: List[Dict[str, Any]] = []
    step = 0
    for _ in range(rounds):
        cohort = sched.next_cohort()
        A = int(cohort.pmask.shape[1])
        state = resize_cohort(state, model, data, A)
        fn = runner.cohort_round_fn(P, Q, A, collect_stats=False)
        state, round_losses = fn(state, data, w.astype(np.float32),
                                 _lr_at(train, step), cohort.idx, cohort.pmask)
        dur = cohort_durations(cohort, sizes, P, Q, t_compute, links)
        w, rec = sched.settle(cohort, dur)
        losses.append(np.asarray(jax.device_get(round_losses)))
        times.extend([sched.now] * P)
        history.append(rec)
        step += P
    return {
        "losses": np.concatenate(losses) if losses else np.zeros(0),
        "times": np.asarray(times),
        "history": history,
        "staleness_hist": dict(sched.stale_hist),
        "sim_seconds": sched.now,
        "runner": runner,
        "state": state,
    }


def run_population_adaptive(model, fed: FederationConfig, train: TrainConfig,
                            data, pop: PopulationConfig, cfg,
                            t_compute: float = 0.05, links=CM.WAN,
                            key=None,
                            runner: Optional[HSGDRunner] = None) -> Dict[str, Any]:
    """Adaptive population run: ControllerCore + wall-clock governor.

    Each round the controller picks (P, Q, η, rung) against BOTH ledgers
    (bytes and simulated seconds, via ``make_time_of``), the scheduler samples
    a cohort, and the realized semi-async deadline is charged back with
    ``core.record(..., seconds=...)``. ``cfg`` is an
    ``controller.AdaptiveConfig`` (set ``time_budget`` to engage the
    wall-clock governor).
    """
    import jax

    from repro.core.controller import ControllerCore, hsgd_sizes_of

    if key is None:
        key = jax.random.PRNGKey(pop.seed)
    runner = runner or HSGDRunner(model, fed, train)
    state = init_state(key, model, fed, data)
    base_w = np.asarray(make_group_weights(data))
    registry = DeviceRegistry(data, pop)
    sched = PopulationScheduler(registry, base_w, mode="semi_async")
    sizes_of = hsgd_sizes_of(state, fed)
    time_of = make_time_of(sizes_of, cfg.ladder, registry, t_compute,
                           mode="semi_async", links=links)
    core = ControllerCore(cfg, fed, sizes_of, eta0=train.learning_rate,
                          time_of=time_of)

    w = base_w.copy()
    losses: List[np.ndarray] = []
    times: List[float] = []
    while not core.done:
        plan, (k_frac, levels) = core.plan()
        cohort = sched.next_cohort()
        A = int(cohort.pmask.shape[1])
        state = resize_cohort(state, model, data, A)
        fn = runner.cohort_round_fn(plan.P, plan.Q, A, k_frac, levels,
                                    collect_stats=True)
        state, stats = fn(state, data, w.astype(np.float32), plan.eta,
                          cohort.idx, cohort.pmask)
        stats = jax.device_get(stats)
        sizes = sizes_of(k_frac, levels)
        dur = cohort_durations(cohort, sizes, plan.P, plan.Q, t_compute, links)
        prev_now = sched.now
        w, _ = sched.settle(cohort, dur)
        # charge the realized semi-async deadline, not the planner's model
        core.record(plan, stats, seconds=sched.now - prev_now)
        losses.append(np.asarray(stats["loss"]))
        times.extend([sched.now] * plan.P)
    return {
        "losses": np.concatenate(losses) if losses else np.zeros(0),
        "times": np.asarray(times),
        "history": core.history,
        "staleness_hist": dict(sched.stale_hist),
        "sim_seconds": sched.now,
        "runner": runner,
        "state": state,
        "core": core,
    }
