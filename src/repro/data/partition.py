"""The paper's 3-step hybrid partition (§VII-A2 "Data split"):

  (i)   horizontal, non-iid: M hospital-patient groups, each dominated by
        a few labels (label-skew: ``major`` samples of 2 labels + ``minor``
        samples of the others);
  (ii)  vertical: every sample's features split hospital/device;
  (iii) horizontal again: the device-side slices scatter across K_m wearable
        devices, one sample per device (paper assumption).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

from repro.common.config import FederationConfig
from repro.data.synthetic import DatasetSpec, flatten_for_tower, vertical_split


@dataclass
class GroupData:
    """Per-group arrays, already padded to a common K."""

    x1: np.ndarray  # [K, ...hospital slice]  (hospital holds all samples)
    x2: np.ndarray  # [K, ...device slice]    (row n lives on device n)
    y: np.ndarray  # [K]
    valid: np.ndarray  # [K] bool (padding mask)


@dataclass
class FederatedData:
    spec: DatasetSpec
    groups: List[GroupData]

    def stacked(self) -> Dict[str, np.ndarray]:
        """[M, K, ...] arrays — the layout the vmapped trainer consumes."""
        return {
            "x1": np.stack([g.x1 for g in self.groups]),
            "x2": np.stack([g.x2 for g in self.groups]),
            "y": np.stack([g.y for g in self.groups]),
            "valid": np.stack([g.valid for g in self.groups]),
        }


def non_iid_group_indices(
    y: np.ndarray, M: int, n_classes: int, labels_per_group: int, rng: np.random.RandomState
) -> List[np.ndarray]:
    """Label-skew split: group m is dominated by ``labels_per_group`` labels."""
    idx_by_class = [np.where(y == c)[0] for c in range(n_classes)]
    for a in idx_by_class:
        rng.shuffle(a)
    cursors = [0] * n_classes
    n = len(y)
    per_group = n // M
    major_frac = 0.85 if n_classes > labels_per_group else 1.0
    groups = []
    for m in range(M):
        major = [(m * labels_per_group + j) % n_classes for j in range(labels_per_group)]
        take = []
        n_major = int(per_group * major_frac)
        for j, c in enumerate(major):
            want = n_major // len(major)
            avail = idx_by_class[c][cursors[c] : cursors[c] + want]
            cursors[c] += len(avail)
            take.append(avail)
        n_rest = per_group - sum(len(t) for t in take)
        rest_pool = []
        for c in range(n_classes):
            if c in major:
                continue
            rest_pool.append(idx_by_class[c][cursors[c] :])
        rest_pool = np.concatenate(rest_pool) if rest_pool else np.array([], np.int64)
        rng.shuffle(rest_pool)
        chosen_rest = rest_pool[:n_rest]
        # advance cursors for chosen rest
        chosen_set = set(chosen_rest.tolist())
        for c in range(n_classes):
            a = idx_by_class[c]
            keep = np.array([i for i in a[cursors[c] :] if i not in chosen_set], np.int64)
            idx_by_class[c] = np.concatenate([a[: cursors[c]], keep])
        take.append(chosen_rest)
        groups.append(np.concatenate(take).astype(np.int64))
    return groups


def hybrid_partition(
    spec: DatasetSpec,
    X: np.ndarray,
    y: np.ndarray,
    fed: FederationConfig,
    seed: int = 0,
) -> FederatedData:
    rng = np.random.RandomState(seed)
    M = fed.num_groups
    gidx = non_iid_group_indices(y, M, spec.n_classes, fed.non_iid_labels_per_group, rng)
    K = max(len(g) for g in gidx)
    K = min(K, fed.devices_per_group) if fed.devices_per_group else K
    groups = []
    for g in gidx:
        g = g[:K]
        Xg, yg = X[g], y[g]
        X1, X2 = vertical_split(spec, Xg)
        X1 = flatten_for_tower(spec, X1)
        X2 = flatten_for_tower(spec, X2)
        pad = K - len(g)
        valid = np.ones(K, bool)
        if pad:
            X1 = np.concatenate([X1, np.zeros((pad,) + X1.shape[1:], X1.dtype)])
            X2 = np.concatenate([X2, np.zeros((pad,) + X2.shape[1:], X2.dtype)])
            yg = np.concatenate([yg, np.zeros(pad, yg.dtype)])
            valid[-pad:] = False
        groups.append(GroupData(X1, X2, yg, valid))
    return FederatedData(spec, groups)


def sample_minibatch(
    data: Dict[str, np.ndarray], batch: int, rng: np.random.RandomState
) -> Dict[str, np.ndarray]:
    """Per-group mini-batch ξ_m (same batch index set per group — paper uses a
    per-group mini-batch agreed between hospital and edge node).

    Sampling is restricted to ``valid`` rows: small groups are zero-padded to
    the common K by ``hybrid_partition``, and the padded (0, label-0) rows are
    fabricated data that must never enter a batch. Replacement only kicks in
    when the batch exceeds a group's valid count.
    """
    M, K = data["y"].shape
    valid = np.asarray(data["valid"], bool)
    rows = []
    for m in range(M):
        vm = np.flatnonzero(valid[m])
        if vm.size == 0:  # degenerate group: nothing real to sample
            vm = np.arange(K)
        rows.append(rng.choice(vm, size=batch, replace=batch > vm.size))
    idx = np.stack(rows)
    out = {}
    for k in ("x1", "x2", "y", "valid"):
        out[k] = np.take_along_axis(
            data[k], idx.reshape(M, batch, *([1] * (data[k].ndim - 2))), axis=1
        )
    out["idx"] = idx
    return out
