"""Synthetic dataset generators shaped like the paper's three datasets.

No network access in this container, so we generate class-conditional
synthetic data with the exact shapes/cardinalities of §VII-A2:
  * OrganAMNIST-like: 28x28 grayscale, 11 classes
  * MIMIC-III-like:   48 timesteps x 76 features, 2 classes
  * ESR-like:         178 features (treated as 178x1 time series), 5 classes

Class structure: each class has a random prototype; samples are prototype +
noise, so models can genuinely learn and convergence curves are meaningful.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_classes: int
    feature_shape: Tuple[int, ...]  # per-sample
    # vertical split sizes (hospital, device) along the split axis
    split_axis: int
    hospital_size: int
    raw_size_mb: float  # paper-reported raw dataset size (comm model)

    @property
    def device_size(self) -> int:
        return self.feature_shape[self.split_axis] - self.hospital_size


ORGANAMNIST = DatasetSpec("organamnist", 11, (28, 28), 0, 11, 63.0)
MIMIC3 = DatasetSpec("mimic3", 2, (48, 76), 1, 36, 42.3 * 1024)
ESR = DatasetSpec("esr", 5, (178, 1), 0, 89, 7.3)

DATASETS = {d.name: d for d in (ORGANAMNIST, MIMIC3, ESR)}


def make_dataset(spec: DatasetSpec, n_samples: int, seed: int = 0, noise: float = 0.7):
    """Returns (X [n, *feature_shape] float32, y [n] int32)."""
    rng = np.random.RandomState(seed)
    protos = rng.randn(spec.n_classes, *spec.feature_shape).astype(np.float32)
    y = rng.randint(0, spec.n_classes, size=n_samples).astype(np.int32)
    X = protos[y] + noise * rng.randn(n_samples, *spec.feature_shape).astype(np.float32)
    return X, y


def vertical_split(spec: DatasetSpec, X: np.ndarray):
    """Paper step (ii): split features between hospital (X1) and device (X2)."""
    h = spec.hospital_size
    if spec.split_axis == 0:
        X1, X2 = X[:, :h], X[:, h:]
    else:
        X1, X2 = X[:, :, :h], X[:, :, h:]
    return X1, X2


def flatten_for_tower(spec: DatasetSpec, X_part: np.ndarray) -> np.ndarray:
    """CNN towers consume flat pixel slices; LSTM towers keep [T, F_slice]."""
    if spec.name == "organamnist":
        return X_part.reshape(X_part.shape[0], -1)
    return X_part


# ---------------------------------------------------------------------------
# LLM-scale synthetic token streams (the llm_hybrid training workload)
# ---------------------------------------------------------------------------


def token_stream(rng: np.random.RandomState, vocab: int, batch: int, seq: int,
                 drift: int = 17, p_drift: float = 0.7):
    """Markov-ish synthetic tokens: the next token is correlated with the
    previous one, so the hybrid model genuinely learns (unlike uniform noise,
    whose loss floor is log V regardless of training)."""
    base = rng.randint(0, vocab, (batch, seq + 1))
    drifted = (base[:, :-1] + rng.randint(0, drift, (batch, seq))) % vocab
    mask = rng.rand(batch, seq) < p_drift
    return base[:, :-1], np.where(mask, drifted, base[:, 1:])


def llm_batch_fn(cfg, batch: int, seq: int, n_pods: int = 1, seed: int = 0):
    """Seeded per-exchange batch sampler for the LLM federated runner.

    Returns ``batch_fn(round_idx, lam)`` producing a fresh {x1, x2, y} pytree
    with leading [Λ, G, ...] axes — one resampled mini-batch per exchange
    interval per pod group, family-aware (text splits the sequence between the
    hospital and device towers; vlm/audio feed the modality frontend to the
    hospital side).
    """
    import jax.numpy as jnp

    rng = np.random.RandomState(seed)
    modality = cfg.family in ("vlm", "audio")
    enc = 8 if cfg.family == "vlm" else getattr(cfg, "encoder_seq", 0)

    def sample_one():
        if modality:
            x1 = rng.randn(batch, enc, cfg.d_model).astype(np.float32)
            x2_in, y = token_stream(rng, cfg.vocab_size, batch, seq)
            return x1, x2_in, y
        inp, tgt = token_stream(rng, cfg.vocab_size, batch, seq)
        s1 = seq // 2
        return inp[:, :s1], inp[:, s1:], tgt

    def batch_fn(round_idx: int, lam: int):
        del round_idx  # the shared rng advances monotonically across calls
        draws = [[sample_one() for _ in range(n_pods)] for _ in range(lam)]
        stack = lambda i: np.stack([[d[i] for d in pod] for pod in draws])
        x1 = stack(0)
        return {
            "x1": jnp.asarray(x1, np.float32 if modality else np.int32),
            "x2": jnp.asarray(stack(1), np.int32),
            "y": jnp.asarray(stack(2), np.int32),
        }

    return batch_fn
