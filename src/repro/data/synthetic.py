"""Synthetic dataset generators shaped like the paper's three datasets.

No network access in this container, so we generate class-conditional
synthetic data with the exact shapes/cardinalities of §VII-A2:
  * OrganAMNIST-like: 28x28 grayscale, 11 classes
  * MIMIC-III-like:   48 timesteps x 76 features, 2 classes
  * ESR-like:         178 features (treated as 178x1 time series), 5 classes

Class structure: each class has a random prototype; samples are prototype +
noise, so models can genuinely learn and convergence curves are meaningful.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_classes: int
    feature_shape: Tuple[int, ...]  # per-sample
    # vertical split sizes (hospital, device) along the split axis
    split_axis: int
    hospital_size: int
    raw_size_mb: float  # paper-reported raw dataset size (comm model)

    @property
    def device_size(self) -> int:
        return self.feature_shape[self.split_axis] - self.hospital_size


ORGANAMNIST = DatasetSpec("organamnist", 11, (28, 28), 0, 11, 63.0)
MIMIC3 = DatasetSpec("mimic3", 2, (48, 76), 1, 36, 42.3 * 1024)
ESR = DatasetSpec("esr", 5, (178, 1), 0, 89, 7.3)

DATASETS = {d.name: d for d in (ORGANAMNIST, MIMIC3, ESR)}


def make_dataset(spec: DatasetSpec, n_samples: int, seed: int = 0, noise: float = 0.7):
    """Returns (X [n, *feature_shape] float32, y [n] int32)."""
    rng = np.random.RandomState(seed)
    protos = rng.randn(spec.n_classes, *spec.feature_shape).astype(np.float32)
    y = rng.randint(0, spec.n_classes, size=n_samples).astype(np.int32)
    X = protos[y] + noise * rng.randn(n_samples, *spec.feature_shape).astype(np.float32)
    return X, y


def vertical_split(spec: DatasetSpec, X: np.ndarray):
    """Paper step (ii): split features between hospital (X1) and device (X2)."""
    h = spec.hospital_size
    if spec.split_axis == 0:
        X1, X2 = X[:, :h], X[:, h:]
    else:
        X1, X2 = X[:, :, :h], X[:, :, h:]
    return X1, X2


def flatten_for_tower(spec: DatasetSpec, X_part: np.ndarray) -> np.ndarray:
    """CNN towers consume flat pixel slices; LSTM towers keep [T, F_slice]."""
    if spec.name == "organamnist":
        return X_part.reshape(X_part.shape[0], -1)
    return X_part
