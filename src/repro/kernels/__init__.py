# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Kernels here:
#   compress.py       — fused top-k + b-level quantize (C-HSGD exchange
#                       hot path; ragged batched rows, backend autodetect)
#   topk_sparsify.py  — compat wrapper over compress.py (top-k only)
#   flash_attention.py, ssm_scan.py — LLM-scale tower blocks
# ops.py holds the jit'd public wrappers, ref.py the pure-jnp oracles.
