"""Pallas TPU kernel: fused top-k sparsify + b-level quantize (C-HSGD §VII-A1).

The communication hot-spot of C-HSGD/C-TDCD is the intermediate-result
exchange: every message row is top-k sparsified and b-level quantized before
it goes on the wire. Doing those as separate ops costs two full passes over
the message (and a sort, for a sort-based top-k). This kernel fuses both into
one VMEM-resident pass — one read, one write per row:

  1. threshold refinement: a fixed-iteration binary search on the magnitude
     threshold against the row max (pure elementwise VPU work + row
     reductions; no sort). 16 iterations give a threshold tight to
     max|x| / 2^16 — bit-identical to the jnp reference
     ``core/compression.py::compress_rows_ref`` (same op sequence).
  2. mask: entries below the threshold are zeroed (>= k survivors; the exact
     top-k support is always preserved, ties can add a few).
  3. b-level quantize/dequantize of the surviving row against its post-mask
     [min, max] grid, when ``levels > 1``.

Ragged rows: a per-row ``row_len`` (int32) marks the valid prefix so that
many pytree leaves of different widths can be padded to a common width and
compressed in ONE batched call (see ``compress_pytree``); padding columns are
excluded from every reduction and zeroed on write-back.

BlockSpec: rows are tiled by ``block_rows``; the full feature axis stays
resident in VMEM (messages are ζ embeddings / model-parameter rows — at most
a few thousand floats per row, well under the ~16 MB VMEM budget at fp32).
Per-row k and row_len ride along as [rows, 1] int32 operands tiled with the
same row index map.

Backend selection: ``interpret`` defaults to auto-detect — compiled Mosaic on
TPU, interpret mode elsewhere (``REPRO_PALLAS_COMPILED`` overrides). The
``compress_rows`` router additionally short-circuits to the fused jnp
reference off-TPU, where interpret-mode Pallas would only add overhead.
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common.backend import default_interpret  # noqa: F401  (re-export)
from repro.core.compression import N_REFINE, compress_rows_ref


def _compress_kernel(x_ref, k_ref, len_ref, o_ref, *, levels: int):
    # The kernel body IS the canonical math: compress_rows_ref traces into
    # the VMEM-resident block (elementwise VPU ops + row reductions only),
    # so the bit-identity contract with the oracle holds by construction.
    o_ref[...] = compress_rows_ref(
        x_ref[...],  # [block_rows, n]
        k_ref[...],  # [block_rows, 1] int32 per-row keep count
        levels,
        len_ref[...],  # [block_rows, 1] int32 valid prefix length
    ).astype(o_ref.dtype)


def _compress_dp_kernel(x_ref, k_ref, len_ref, noise_ref, clip_ref, sigma_ref,
                        o_ref, *, levels: int):
    # DP twin: same traced math plus the fused per-row clip+noise stage. The
    # noise rows ride in VMEM with the same row index map as x (precomputed
    # standard normals, so the kernel stays deterministic and bit-identical
    # to the jnp fallback); clip/σ are (1, 1) SMEM-friendly scalar operands.
    o_ref[...] = compress_rows_ref(
        x_ref[...],
        k_ref[...],
        levels,
        len_ref[...],
        dp_clip=clip_ref[0, 0],
        dp_sigma=sigma_ref[0, 0],
        dp_noise=noise_ref[...],  # [block_rows, n] standard-normal rows
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("levels", "block_rows", "interpret"))
def _fused_compress_call(x, k_arr, len_arr, levels: int, block_rows: int, interpret: bool):
    rows, n = x.shape
    block_rows = min(block_rows, rows)
    pad_rows = (-rows) % block_rows
    if pad_rows:
        x = jnp.pad(x, ((0, pad_rows), (0, 0)))
        k_arr = jnp.pad(k_arr, ((0, pad_rows), (0, 0)))
        len_arr = jnp.pad(len_arr, ((0, pad_rows), (0, 0)))
    grid = (x.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_compress_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, k_arr, len_arr)
    return out[:rows]


@functools.partial(jax.jit, static_argnames=("levels", "block_rows", "interpret"))
def _fused_compress_dp_call(x, k_arr, len_arr, noise, clip, sigma,
                            levels: int, block_rows: int, interpret: bool):
    # Separate jitted entry so the non-DP call keeps its exact trace (and
    # executor caches keyed on it stay warm); DP only adds a `dp_enabled` bit
    # upstream — clip/σ/noise are traced operands, never static.
    rows, n = x.shape
    block_rows = min(block_rows, rows)
    pad_rows = (-rows) % block_rows
    if pad_rows:
        x = jnp.pad(x, ((0, pad_rows), (0, 0)))
        k_arr = jnp.pad(k_arr, ((0, pad_rows), (0, 0)))
        len_arr = jnp.pad(len_arr, ((0, pad_rows), (0, 0)))
        noise = jnp.pad(noise, ((0, pad_rows), (0, 0)))
    grid = (x.shape[0] // block_rows,)
    clip = jnp.asarray(clip, jnp.float32).reshape(1, 1)
    sigma = jnp.asarray(sigma, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        functools.partial(_compress_dp_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, k_arr, len_arr, noise, clip, sigma)
    return out[:rows]


def fused_compress_pallas(
    x: jnp.ndarray,
    k: Union[int, jnp.ndarray],
    levels: int = 0,
    row_len: Optional[jnp.ndarray] = None,
    block_rows: int = 8,
    interpret: Optional[bool] = None,
    dp_clip=None,
    dp_sigma=None,
    dp_noise: Optional[jnp.ndarray] = None,
):
    """x: [rows, n] -> fused-compressed x, same shape/dtype.

    k: scalar or per-row [rows] keep count (k >= n is a per-row no-op).
    levels: b-level quantization grid size (<= 1 disables).
    row_len: optional per-row valid length for ragged/padded rows.
    interpret: None -> auto-detect (compiled on TPU, interpret elsewhere).
    dp_noise: optional [rows, n] precomputed standard-normal rows enabling the
    fused per-row L2-clip (``dp_clip``) + Gaussian noise (``dp_sigma``) stage.
    """
    rows, n = x.shape
    if interpret is None:
        interpret = default_interpret()
    k_arr = jnp.broadcast_to(jnp.asarray(k, jnp.int32).reshape(-1, 1), (rows, 1))
    if row_len is None:
        len_arr = jnp.full((rows, 1), n, jnp.int32)
    else:
        len_arr = jnp.asarray(row_len, jnp.int32).reshape(-1, 1)
    if dp_noise is not None:
        return _fused_compress_dp_call(
            x, k_arr, len_arr, dp_noise.astype(jnp.float32), dp_clip, dp_sigma,
            int(levels), block_rows, bool(interpret))
    return _fused_compress_call(x, k_arr, len_arr, int(levels), block_rows, bool(interpret))


# jitted fallback so eager call sites don't pay op-by-op dispatch; inside an
# outer jit this inlines.
_compress_rows_ref_jit = jax.jit(compress_rows_ref, static_argnames=("levels",))


def compress_rows(
    x: jnp.ndarray,
    k: Union[int, jnp.ndarray],
    levels: int = 0,
    row_len: Optional[jnp.ndarray] = None,
    dp_clip=None,
    dp_sigma=None,
    dp_noise: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Backend router for the fused compression op.

    On TPU (or with ``REPRO_PALLAS_COMPILED=1``) this launches the compiled
    Mosaic kernel; elsewhere it runs the bit-identical fused jnp reference —
    interpret-mode Pallas is for validation, not the hot path.
    """
    if not default_interpret():
        return fused_compress_pallas(x, k, levels, row_len, interpret=False,
                                     dp_clip=dp_clip, dp_sigma=dp_sigma,
                                     dp_noise=dp_noise)
    return _compress_rows_ref_jit(x, k, levels=levels, row_len=row_len,
                                  dp_clip=dp_clip, dp_sigma=dp_sigma,
                                  dp_noise=dp_noise)


def compress_pytree(tree, k_frac: float, levels: int = 0,
                    dp_clip=None, dp_sigma=None, dp_key=None):
    """Compress every leaf of a message pytree in ONE batched row-matrix call.

    Each leaf is viewed as rows of its trailing axis; rows are padded to the
    widest leaf and stacked so the whole exchange message (θ0 pytree + ζ1 +
    ζ2) costs a single kernel launch instead of one per leaf. Per-leaf k is
    ``max(1, round(k_frac * width))``; ragged masking keeps the result
    bit-identical to compressing each leaf separately.

    ``dp_key`` (a jax PRNG key) enables the fused DP stage: standard-normal
    noise rows for the whole stacked matrix are drawn once from the threaded
    key and ride into the kernel as an operand, with per-row L2 clip
    ``dp_clip`` and noise multiplier ``dp_sigma`` (std = σ·clip) — traced
    scalars, so re-picking them never recompiles.
    """
    do_topk = 0.0 < k_frac < 1.0
    dp = dp_key is not None
    if not do_topk and not (levels and levels > 1) and not dp:
        return tree
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    widths = [int(leaf.shape[-1]) if leaf.ndim else 1 for leaf in leaves]
    n_max = max(widths)
    mats, ks, lens, counts = [], [], [], []
    for leaf, n in zip(leaves, widths):
        m = leaf.astype(jnp.float32).reshape(-1, n)
        r = m.shape[0]
        mats.append(jnp.pad(m, ((0, 0), (0, n_max - n))) if n < n_max else m)
        k = max(1, int(round(k_frac * n))) if do_topk else n
        ks.append(jnp.full((r,), k, jnp.int32))
        lens.append(jnp.full((r,), n, jnp.int32))
        counts.append(r)
    mat = jnp.concatenate(mats, axis=0)
    noise = jax.random.normal(dp_key, mat.shape, jnp.float32) if dp else None
    out = compress_rows(
        mat,
        jnp.concatenate(ks),
        levels,
        jnp.concatenate(lens),
        dp_clip=dp_clip,
        dp_sigma=dp_sigma,
        dp_noise=noise,
    )
    new_leaves, off = [], 0
    for leaf, n, r in zip(leaves, widths, counts):
        block = out[off : off + r, :n]
        new_leaves.append(block.reshape(leaf.shape).astype(leaf.dtype))
        off += r
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
