"""Pallas TPU kernel: blocked (flash) causal attention with sliding window.

Grid = (batch*kv_heads*q_groups, num_q_blocks, num_kv_blocks); the kv axis is
the innermost ("arbitrary") dimension, so the online-softmax running state
(m, l, acc) persists in VMEM scratch across kv iterations and is flushed to
the output on the last one. Block shapes default to MXU-aligned (128, 128)
tiles with the full head_dim resident.

Sliding-window attention (gemma3 local layers, zamba2 shared block at
long_500k) masks per-element; fully-out-of-range blocks contribute zero via
the masked softmax, matching the pure-jnp oracle `ref.blockwise_attention`.
The window rides along as a (1, 1) int32 SMEM operand — NOT a static arg —
so the per-layer window array a `lax.scan` threads through the stacked
layers (a traced scalar) never forces a recompile per window value.

Backend selection: ``interpret=None`` auto-detects — compiled Mosaic on TPU,
interpret mode elsewhere (``REPRO_PALLAS_COMPILED`` overrides), the same
policy as the fused compression kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.backend import default_interpret

NEG_INF = -2.0e38


def _flash_kernel(win_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale  # [block_q, d]
    k = k_ref[0].astype(jnp.float32)  # [block_k, d]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = (k_pos <= q_pos) & (k_pos < seq_len) & (q_pos < seq_len)
    window = win_ref[0, 0]  # runtime scalar; <=0 means full causal
    ok &= jnp.where(window > 0, k_pos > (q_pos - window), True)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]  # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ()))
    )
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(
    q: jnp.ndarray,  # [BH, S, D] (batch*heads flattened; kv already expanded to q heads)
    k: jnp.ndarray,  # [BH, S, D]
    v: jnp.ndarray,
    scale: float | None = None,
    window=0,  # python int OR traced int scalar; <=0 = full causal
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = default_interpret()
    BH, S, D = q.shape
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    pad = (-S) % max(block_q, block_k)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    Sp = q.shape[1]
    win_arr = jnp.asarray(window, jnp.int32).reshape(1, 1)
    grid = (BH, Sp // block_q, Sp // block_k)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
            seq_len=S,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(win_arr, q, k, v)
    return out[:, :S]
