"""jit'd public wrappers around the Pallas kernels.

Backend selection is automatic: compiled Mosaic kernels on TPU, interpret
mode elsewhere (interpret executes the same kernel body for validation).
``REPRO_PALLAS_COMPILED=1/0`` forces the choice. The fused compression op
additionally short-circuits to its bit-identical jnp reference off-TPU —
interpret-mode Pallas is for validation, not the hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.compress import compress_rows, default_interpret, fused_compress_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas


def topk_sparsify(x: jnp.ndarray, k_frac: float) -> jnp.ndarray:
    """Row-wise top-k sparsification of a message tensor (any rank >= 1)."""
    return fused_compress(x, k_frac, levels=0)


def fused_compress(x: jnp.ndarray, k_frac: float, levels: int = 0) -> jnp.ndarray:
    """Fused top-k + b-level quantize along the last axis (any rank >= 1)."""
    if k_frac >= 1.0 and not (levels and levels > 1):
        return x
    shape = x.shape
    n = shape[-1]
    k = n if k_frac >= 1.0 else max(1, int(round(k_frac * n)))
    return compress_rows(x.reshape(-1, n), k, levels).reshape(shape)


def flash_attention(q, k, v, scale=None, window=0):
    """q,k,v: [B, S, H, D] (kv heads already repeated to H). Causal.

    ``window`` may be a python int OR a traced int scalar (the per-layer
    window a stacked-layer scan threads through) — it rides into the kernel
    as an SMEM operand, so varying it never recompiles. Backend autodetect
    (compiled Mosaic on TPU, interpret elsewhere) happens in the kernel.
    """
    B, S, H, D = q.shape
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    out = flash_attention_pallas(qf, kf, vf, scale=scale, window=window)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def ssm_scan(a, b, h0):
    """Linear recurrence for [B, T, ...] a/b with state [B, ...]: any trailing
    dims are folded into channels."""
    B, T = a.shape[:2]
    trail = a.shape[2:]
    C = 1
    for d in trail:
        C *= d
    hs, h_last = ssm_scan_pallas(a.reshape(B, T, C), b.reshape(B, T, C), h0.reshape(B, C),
                                 interpret=default_interpret())
    return hs.reshape((B, T) + trail), h_last.reshape((B,) + trail)
