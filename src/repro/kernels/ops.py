"""jit'd public wrappers around the Pallas kernels.

On this CPU container every kernel runs in interpret mode (the TPU lowering
is the target; interpret executes the same kernel body for validation). Set
``REPRO_PALLAS_COMPILED=1`` on a real TPU to compile the Mosaic kernels.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas
from repro.kernels.topk_sparsify import topk_sparsify_pallas

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILED", "0") != "1"


def topk_sparsify(x: jnp.ndarray, k_frac: float) -> jnp.ndarray:
    """Row-wise top-k sparsification of a message tensor (any rank >= 1)."""
    if k_frac >= 1.0:
        return x
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    k = max(1, int(round(k_frac * shape[-1])))
    out = topk_sparsify_pallas(x2, k, interpret=INTERPRET)
    return out.reshape(shape)


def flash_attention(q, k, v, scale=None, window: int = 0):
    """q,k,v: [B, S, H, D] (kv heads already repeated to H). Causal."""
    B, S, H, D = q.shape
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    out = flash_attention_pallas(qf, kf, vf, scale=scale, window=window, interpret=INTERPRET)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def ssm_scan(a, b, h0):
    """Linear recurrence for [B, T, ...] a/b with state [B, ...]: any trailing
    dims are folded into channels."""
    B, T = a.shape[:2]
    trail = a.shape[2:]
    C = 1
    for d in trail:
        C *= d
    hs, h_last = ssm_scan_pallas(a.reshape(B, T, C), b.reshape(B, T, C), h0.reshape(B, C),
                                 interpret=INTERPRET)
    return hs.reshape((B, T) + trail), h_last.reshape((B,) + trail)
