"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

The fused compression oracle is the canonical math in
``core/compression.py::compress_rows_ref`` — re-exported here so kernel
tests keep a single import site for every oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compression import compress_rows_ref  # noqa: F401  (fused oracle)

N_REFINE = 16
NEG_INF = -2.0e38


def topk_sparsify_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Threshold-refinement top-k (the fused kernel with quantization off)."""
    return compress_rows_ref(x, k, levels=0)


def topk_exact_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Exact top-k (jax.lax.top_k) — property-test target for the kernel."""
    mag = jnp.abs(x)
    thresh = jax.lax.top_k(mag, k)[0][..., -1:]
    return jnp.where(mag >= thresh, x, 0).astype(x.dtype)


def flash_attention_ref(q, k, v, scale=None, window: int = 0):
    """Naive attention: q,k,v [BH, S, D] causal (+ optional sliding window)."""
    BH, S, D = q.shape
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    pos = jnp.arange(S)
    ok = pos[None, :] <= pos[:, None]
    if window > 0:
        ok &= pos[None, :] > (pos[:, None] - window)
    s = jnp.where(ok[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssm_scan_ref(a, b, h0):
    """Sequential linear recurrence h_t = a_t*h_{t-1} + b_t; a,b [B,T,C]."""

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    aT = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    bT = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32), (aT, bT))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype), h_last.astype(h0.dtype)
