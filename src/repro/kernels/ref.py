"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

N_REFINE = 16
NEG_INF = -2.0e38


def topk_sparsify_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Same threshold-refinement algorithm as the kernel, in pure jnp."""
    mag = jnp.abs(x.astype(jnp.float32))
    hi = jnp.max(mag, axis=-1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def refine(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        count = jnp.sum((mag >= mid).astype(jnp.int32), axis=-1, keepdims=True)
        return jnp.where(count > k, mid, lo), jnp.where(count > k, hi, mid)

    lo, hi = jax.lax.fori_loop(0, N_REFINE, refine, (lo, hi))
    return jnp.where(mag >= lo, x, 0).astype(x.dtype)


def topk_exact_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Exact top-k (jax.lax.top_k) — property-test target for the kernel."""
    mag = jnp.abs(x)
    thresh = jax.lax.top_k(mag, k)[0][..., -1:]
    return jnp.where(mag >= thresh, x, 0).astype(x.dtype)


def flash_attention_ref(q, k, v, scale=None, window: int = 0):
    """Naive attention: q,k,v [BH, S, D] causal (+ optional sliding window)."""
    BH, S, D = q.shape
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    pos = jnp.arange(S)
    ok = pos[None, :] <= pos[:, None]
    if window > 0:
        ok &= pos[None, :] > (pos[:, None] - window)
    s = jnp.where(ok[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssm_scan_ref(a, b, h0):
    """Sequential linear recurrence h_t = a_t*h_{t-1} + b_t; a,b [B,T,C]."""

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    aT = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    bT = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32), (aT, bT))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype), h_last.astype(h0.dtype)
