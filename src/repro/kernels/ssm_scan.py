"""Pallas TPU kernel: blocked linear recurrence  h_t = a_t ⊙ h_{t-1} + b_t.

The compute hot-spot of the SSM architectures (falcon-mamba, zamba2): the
selective-scan recurrence over the time axis. The kernel keeps the running
state h for a channel tile resident in VMEM scratch and walks the time axis
in ``block_t`` slabs (grid axis 1, "arbitrary"), processing each slab with an
in-register sequential loop over its rows. Channels are tiled 128-wide
(lane-aligned); the caller folds the N state dimension into channels.

This is the TPU adaptation of the CUDA selective-scan: instead of a
warp-parallel prefix scan, VMEM-resident state + slab streaming keeps HBM
traffic at 2·T·C (read a,b; write h) — the memory-roofline optimum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, b_ref, h0_ref, o_ref, hlast_ref, state_scr, *, block_t: int):
    ti = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(ti == 0)
    def _init():
        state_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)  # [block_t, C]
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, state_scr[...], unroll=True)
    state_scr[...] = h

    @pl.when(ti == nt - 1)
    def _flush():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_c", "interpret"))
def ssm_scan_pallas(
    a: jnp.ndarray,  # [B, T, C]
    b: jnp.ndarray,  # [B, T, C]
    h0: jnp.ndarray,  # [B, C]
    block_t: int = 128,
    block_c: int = 128,
    interpret: bool = True,
):
    """Returns (h [B, T, C], h_final [B, C])."""
    B, T, C = a.shape
    block_t = min(block_t, T)
    block_c = min(block_c, C)
    pad_t = (-T) % block_t
    pad_c = (-C) % block_c
    if pad_t or pad_c:
        # pad with a=1, b=0 -> recurrence passes state through unchanged
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_c)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_c)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_c)))
    Tp, Cp = a.shape[1], a.shape[2]
    grid = (B * (Cp // block_c), Tp // block_t)

    a_r = a.reshape(B, Tp, Cp // block_c, block_c).transpose(0, 2, 1, 3).reshape(-1, Tp, block_c)
    b_r = b.reshape(B, Tp, Cp // block_c, block_c).transpose(0, 2, 1, 3).reshape(-1, Tp, block_c)
    h0_r = h0.reshape(-1, block_c)

    hs, h_last = pl.pallas_call(
        functools.partial(_scan_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_c), lambda g, t: (g, t, 0)),
            pl.BlockSpec((1, block_t, block_c), lambda g, t: (g, t, 0)),
            pl.BlockSpec((1, block_c), lambda g, t: (g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_c), lambda g, t: (g, t, 0)),
            pl.BlockSpec((1, block_c), lambda g, t: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(a_r.shape, a.dtype),
            jax.ShapeDtypeStruct(h0_r.shape, h0.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_c,), jnp.float32)],
        interpret=interpret,
    )(a_r, b_r, h0_r)

    hs = hs.reshape(B, Cp // block_c, Tp, block_c).transpose(0, 2, 1, 3).reshape(B, Tp, Cp)
    h_last = h_last.reshape(B, Cp)
    return hs[:, :T, :C], h_last[:, :C]
