"""Row-wise top-k magnitude sparsification — thin wrapper over the fused
compression kernel in ``kernels/compress.py`` (top-k only, quantization off).

Kept as a stable entry point: the threshold-refinement formulation (binary
search on the magnitude threshold — elementwise VPU work + row reductions, no
sort) now lives in the fused kernel, which also applies b-level quantization
in the same VMEM-resident pass when requested. See ``kernels/compress.py``
for the BlockSpec/backend story.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.compress import fused_compress_pallas

N_REFINE = 16


def topk_sparsify_pallas(
    x: jnp.ndarray, k: int, block_rows: int = 8, interpret: Optional[bool] = None
):
    """x: [rows, n] -> sparsified x, same shape/dtype (>= k survivors/row).

    ``interpret=None`` auto-detects the backend (interpret only off-TPU).
    """
    return fused_compress_pallas(x, k, levels=0, block_rows=block_rows, interpret=interpret)
