"""Pallas TPU kernel: row-wise top-k magnitude sparsification.

The communication hot-spot of C-HSGD/C-TDCD: before every intermediate-result
exchange, each message row keeps only its k largest-|x| entries. A sort-based
top-k maps poorly onto the TPU vector unit, so the kernel uses the TPU-native
formulation: a fixed-iteration *binary search over the magnitude threshold*
(log2-precision refinement against the row max), which is pure elementwise
VPU work + row reductions, and then applies the mask. 16 iterations give a
threshold tight to max|x| / 2^16 — bit-identical to the jnp oracle in
kernels/ref.py, which implements the same refinement.

BlockSpec: rows are tiled by ``block_rows``; the full feature axis stays
resident in VMEM (messages are ζ embeddings — ≤ a few thousand floats/row,
well under the ~16 MB VMEM budget at fp32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_REFINE = 16


def _topk_kernel(x_ref, o_ref, *, k: int):
    x = x_ref[...]  # [block_rows, n]
    mag = jnp.abs(x.astype(jnp.float32))
    hi = jnp.max(mag, axis=-1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def refine(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        count = jnp.sum((mag >= mid).astype(jnp.int32), axis=-1, keepdims=True)
        # too many survivors -> raise threshold; too few -> lower it
        new_lo = jnp.where(count > k, mid, lo)
        new_hi = jnp.where(count > k, hi, mid)
        return new_lo, new_hi

    lo, hi = jax.lax.fori_loop(0, N_REFINE, refine, (lo, hi))
    thresh = lo  # keeps at least k entries (count(lo) >= k >= count(hi))
    o_ref[...] = jnp.where(mag >= thresh, x, 0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def topk_sparsify_pallas(x: jnp.ndarray, k: int, block_rows: int = 8, interpret: bool = True):
    """x: [rows, n] -> sparsified x, same shape/dtype."""
    rows, n = x.shape
    block_rows = min(block_rows, rows)
    pad_rows = (-rows) % block_rows
    if pad_rows:
        x = jnp.pad(x, ((0, pad_rows), (0, 0)))
    grid = (x.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
    return out[:rows]
