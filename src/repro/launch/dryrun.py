import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture × input shape × mesh) lowers
and compiles, and extract the roofline terms from the compiled artifacts.

For training shapes, three programs are lowered (train_step / exchange /
global_agg) whose costs combine as the paper's C(P,Q):
    per-step = train_step + (1/Q)·exchange + (1/P)·global_agg.
Inference shapes lower a single serve_step.

Usage:
    python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Dict

import jax
import numpy as np

from repro.common.config import INPUT_SHAPES, get_config
from repro.common.io import atomic_write_json
from repro.common.sharding import mesh_context
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import LONG_CTX_OK, build_programs, build_shardings

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(f64|f32|s64|u64|s32|u32|bf16|f16|s16|u16|s8|u8|pred|f8e4m3fn|f8e5m2)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        els = 1
        for d in dims.split(","):
            if d:
                els *= int(d)
        total += els * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective kind (result-shape proxy),
    parsed from the post-SPMD optimized HLO."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # avoid double counting async pairs
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


def analyze_compiled(lowered, compiled) -> Dict:
    ca = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    coll_total = float(sum(coll.values()))
    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_total,
        "collectives": coll,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": coll_total / ICI_BW,
        "temp_bytes": int(mem.temp_size_in_bytes),
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
    }


def run_one(arch: str, shape_name: str, multi_pod: bool = False, mesh=None,
            verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k" and arch not in LONG_CTX_OK:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full attention is quadratic at 500k (DESIGN §4)"}
    if shape.kind == "decode" and cfg.is_encoder_decoder and shape_name == "long_500k":
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "enc-dec 500k decode N/A"}
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "n_chips": n_chips, "status": "ok", "programs": {},
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    progs = build_programs(cfg, shape, multi_pod=multi_pod)
    for name, (fn, sds, axes) in progs.entries.items():
        t0 = time.time()
        shardings = tuple(build_shardings(s, a, mesh) for s, a in zip(sds, axes))
        if name == "serve_step" and "caches" in sds[1]:
            donate = (1,)  # decode caches update in place
        elif name == "train_step":
            donate = (0,)  # params -> new params alias (no double buffering)
        else:
            donate = ()
        with mesh_context(mesh):
            lowered = jax.jit(fn, in_shardings=shardings, donate_argnums=donate).lower(*sds)  # reprolint: disable=RP1 — dry-run lowers each DISTINCT program once; nothing to cache
            compiled = lowered.compile()
            stats = analyze_compiled(lowered, compiled)
        # loop-aware analytic flops (cost_analysis drops nested-scan trip
        # counts — see launch/flops.py); per-device = global / chips
        from repro.launch.flops import traced_flops

        stats["traced_flops_per_device"] = traced_flops(fn, *sds) / n_chips
        stats["compute_s"] = stats["traced_flops_per_device"] / PEAK_FLOPS
        stats["lower_compile_s"] = round(time.time() - t0, 1)
        result["programs"][name] = stats
        if verbose:
            print(
                f"  {name:12s} flops/dev={stats['flops_per_device']:.3e} "
                f"bytes/dev={stats['bytes_per_device']:.3e} "
                f"coll/dev={stats['collective_bytes_per_device']:.3e} "
                f"temp={stats['temp_bytes']/1e9:.1f}GB "
                f"({stats['lower_compile_s']}s)"
            )
    return result


def roofline_summary(result: Dict, P: int = 8, Q: int = 4,
                     tokens_per_step: int | None = None) -> Dict:
    """Combine program terms with the paper's 1/P, 1/Q amortization."""
    if result.get("status") != "ok":
        return {}
    progs = result["programs"]
    if "train_step" in progs:
        terms = {}
        for key in ("compute_s", "memory_s", "collective_s"):
            terms[key] = (
                progs["train_step"][key]
                + progs["exchange"][key] / Q
                + progs["global_agg"][key] / P
            )
    else:
        terms = {k: progs["serve_step"][k] for k in ("compute_s", "memory_s", "collective_s")}
    dominant = max(terms, key=terms.get)
    out = dict(terms)
    out["dominant"] = dominant.replace("_s", "")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args(argv)

    from repro.configs import ASSIGNED

    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        tag = "multipod" if mp else "pod"
        for arch in archs:
            for shape in shapes:
                key = f"{arch}__{shape}__{tag}"
                path = os.path.join(args.out, key + ".json")
                if os.path.exists(path):
                    print(f"[skip cached] {key}")
                    continue
                print(f"[dry-run] {key}")
                try:
                    res = run_one(arch, shape, multi_pod=mp, mesh=mesh)
                    res["roofline"] = roofline_summary(res)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "error", "error": str(e)[-2000:]}
                    failures.append(key)
                atomic_write_json(path, res)
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
