"""Compiled serving engine: single-pass prefill, scan decode, continuous batching.

The trained global model θ̃ is what e-health institutions actually serve back
to devices and clinicians, so serving shares the hot-path discipline of the
training loop (PR 1-3): everything that runs per-request is a cached,
donating, jitted executor, compiled once per shape bucket.

Three compiled program kinds, each cached exactly like
``HSGDRunner.round_fn``'s per-(P, Q, k, b) executors:

* **prefill** — ONE forward through the train-path stacks per power-of-two
  token block, writing KV/SSM/latent caches with a single
  ``dynamic_update_slice`` per layer (``decode_step`` with [B, S] tokens),
  replacing S sequential single-token dispatches. Prompts whose length is a
  power of two prefill in ONE pass; others decompose into at most
  log2(S) blocks, so the executor cache stays bounded. Long blocks route
  through the Pallas flash-attention op on TPU (``fresh_cache``).
* **decode** — the whole generate loop for a block of tokens staged as one
  donating jitted ``lax.scan`` per (batch, cache-bucket, block): on-device
  sampling (traced temperature, threaded PRNG key) and per-slot cache write
  positions, so there is NO per-token host round-trip — one device sync per
  block, when the scheduler collects tokens.
* **insert** — continuous batching: one executor copies a prefilled
  request's cache rows into a freed decode slot, so new arrivals join a
  running batch without recompiling or restarting it.

``sequential_generate`` / ``sequential_prefill`` keep the reconstructed
pre-PR serving path (token-by-token prefill, one un-donated dispatch + host
sample per token) as the parity oracle and benchmark baseline.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.buckets import pow2_ceil as _pow2_at_least
from repro.common.buckets import pow2_floor as _pow2_at_most
from repro.common.config import ModelConfig
from repro.models import transformer as T


def sample_token(logits, key, temperature):
    """[B, V] logits -> [B] int32 next tokens, entirely on device.

    ``temperature`` is traced: ONE executor serves greedy (argmax at 0) and
    stochastic sampling — re-picking it never recompiles, and temperature
    applies from the FIRST generated token (the pre-PR loop always argmaxed
    the first one). ``lax.cond`` picks the branch at runtime, so greedy
    decode never pays the categorical's gumbel draw (~7x an argmax).
    """
    temp = jnp.asarray(temperature, jnp.float32)

    def hot(_):
        scaled = logits.astype(jnp.float32) / jnp.maximum(temp, 1e-6)
        return jax.random.categorical(key, scaled, axis=-1)

    def greedy(_):
        return jnp.argmax(logits, axis=-1)

    return jax.lax.cond(temp > 0, hot, greedy, None).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Requests + engine
# ---------------------------------------------------------------------------


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new: int
    extra_embeds: Optional[np.ndarray] = None  # audio: [enc_seq, d_model]
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    prefill_s: float = 0.0
    tokens: List[int] = field(default_factory=list)
    slot: int = -1

    @property
    def finished(self) -> bool:
        return len(self.tokens) >= self.max_new


class ServeEngine:
    """Continuous-batching scheduler over the compiled executors.

    Requests are packed into a padded decode batch of ``max_batch`` slots
    sharing one power-of-two cache bucket; freed slots are refilled from the
    waiting queue while the batch keeps decoding (parked slots write
    out-of-range, which the cache scatter drops). Per-request latency and
    aggregate tokens/s come back from :meth:`run`.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 cache_dtype=jnp.bfloat16, decode_block: int = 8,
                 temperature: float = 0.0, seed: int = 0,
                 max_prefill_block: int = 4096):
        self.cfg = cfg
        self.params = params
        self.max_batch = int(max_batch)
        self.cache_dtype = cache_dtype
        self.decode_block = int(decode_block)
        self.temperature = float(temperature)
        self.max_prefill_block = int(max_prefill_block)
        self.key = jax.random.PRNGKey(seed)
        self._prefill_fns: Dict = {}  # (Bp, block, first, cache_len) -> executor
        self._decode_fns: Dict = {}  # (B, cache_len, block) -> executor
        self._insert_fns: Dict = {}  # (Bp, B, cache_len) -> executor
        self._next_rid = 0
        self.waiting: List[Request] = []
        self.done: List[Request] = []
        self._state = None  # live decode batch: caches + host tok/pos/active
        self._cache_len = 0
        self._slots: List[Optional[Request]] = []

    # -- submission ---------------------------------------------------------

    def submit(self, prompt, max_new: int, extra_embeds=None) -> int:
        r = Request(
            self._next_rid, np.asarray(prompt, np.int32), int(max_new),
            None if extra_embeds is None else np.asarray(extra_embeds, np.float32),
            t_submit=time.perf_counter(),
        )
        self._next_rid += 1
        self.waiting.append(r)
        return r.rid

    def generate(self, prompts, max_new: int, extra_embeds=None):
        """Submit a batch, drain it, return (tokens per request, report)."""
        rids = [
            self.submit(p, max_new, None if extra_embeds is None else extra_embeds[i])
            for i, p in enumerate(prompts)
        ]
        report = self.run()
        by_id = {r.rid: r for r in self.done}
        return [by_id[rid].tokens for rid in rids], report

    # -- compiled executors (cached per shape bucket) -----------------------

    def _prefill_fn(self, Bp: int, block: int, first: bool, cache_len: int):
        # cache_len is part of the bucket: the donated caches' shapes depend
        # on it, and a silent re-jit would break *_buckets == *_compiles
        key = (Bp, block, first, cache_len)
        fn = self._prefill_fns.get(key)
        if fn is None:
            cfg, dtype = self.cfg, self.cache_dtype

            if first:
                # the FIRST block builds its own zero caches inside the jit
                # (no per-leaf host allocs), runs the audio encoder when the
                # family has one, and samples the candidate first token on
                # device — for pow2 prompts the whole prefill is ONE dispatch
                @jax.jit
                def fn(params, tokens, key, temperature, enc_embeds=None):
                    caches = T.init_decode_caches(cfg, Bp, cache_len, dtype)
                    if cfg.family == "audio":
                        enc = T.encode_audio(cfg, params, enc_embeds)
                        caches["enc_out"] = enc.astype(caches["enc_out"].dtype)
                    logits, caches = T.decode_step(cfg, params, tokens, caches,
                                                   jnp.int32(0), fresh_cache=True)
                    tok = sample_token(logits[:, -1], key, temperature)
                    return tok, caches
            else:

                @partial(jax.jit, donate_argnums=(1,))
                def fn(params, caches, tokens, index, key, temperature):
                    logits, caches = T.decode_step(cfg, params, tokens, caches, index)
                    tok = sample_token(logits[:, -1], key, temperature)
                    return tok, caches

            self._prefill_fns[key] = fn
        return fn

    def _decode_fn(self, B: int, cache_len: int, block: int):
        key = (B, cache_len, block)
        fn = self._decode_fns.get(key)
        if fn is None:
            cfg = self.cfg

            @partial(jax.jit, donate_argnums=(1,))
            def fn(params, caches, tok, pos, active, key, temperature):
                def step(carry, _):
                    caches, tok, pos, key = carry
                    # parked slots write at cache_len: out-of-range -> dropped
                    widx = jnp.where(active, pos, cache_len)
                    logits, caches = T.decode_step(cfg, params, tok, caches, widx)
                    key, k1 = jax.random.split(key)
                    nxt = sample_token(logits[:, -1], k1, temperature)
                    return (caches, nxt[:, None], pos + 1, key), nxt

                (caches, tok, pos, _), toks = jax.lax.scan(
                    step, (caches, tok, pos, key), None, length=block)
                return caches, tok, pos, toks  # toks: [block, B]

            self._decode_fns[key] = fn
        return fn

    def _insert_fn(self, Bp: int):
        key = (Bp, self.max_batch, self._cache_len)
        fn = self._insert_fns.get(key)
        if fn is None:
            bx = self._batch_axes(self.max_batch, self._cache_len)

            # ONE dispatch admits the whole prefilled group: row i of the
            # prefill caches lands in decode slot dst[i]; prefill pad rows
            # carry dst == max_batch (out of range) and are dropped
            @partial(jax.jit, donate_argnums=(0,))
            def fn(dec_caches, pre_caches, dst):
                def cp(d, p, ax):
                    d2 = jnp.moveaxis(d, ax, 0)
                    p2 = jnp.moveaxis(p, ax, 0)
                    d2 = d2.at[dst].set(p2.astype(d2.dtype), mode="drop")
                    return jnp.moveaxis(d2, 0, ax)

                return jax.tree.map(cp, dec_caches, pre_caches, bx)

            self._insert_fns[key] = fn
        return fn

    def _batch_axes(self, B: int, cache_len: int):
        """Pytree of ints: which axis of each cache leaf is the batch axis
        (kv/ssm leaves are layer-stacked, so it is NOT always axis 0)."""
        sds, axes = T.make_decode_caches(self.cfg, B, cache_len, self.cache_dtype)

        def is_ax(t):
            return isinstance(t, tuple) and all(e is None or isinstance(e, str) for e in t)

        ax_leaves = jax.tree_util.tree_flatten(axes, is_leaf=is_ax)[0]
        sd_leaves, treedef = jax.tree_util.tree_flatten(sds)
        if len(ax_leaves) != len(sd_leaves):
            raise AssertionError("cache specs and axes trees diverged")
        return jax.tree_util.tree_unflatten(
            treedef, [a.index("batch") for a in ax_leaves])

    def compile_counts(self) -> Dict[str, int]:
        """Executor-cache sizes + actual XLA compile counts (must agree: one
        compile per bucket is the whole point)."""

        def compiles(d):
            return sum(f._cache_size() for f in d.values())

        return {
            "prefill_buckets": len(self._prefill_fns),
            "prefill_compiles": compiles(self._prefill_fns),
            "decode_buckets": len(self._decode_fns),
            "decode_compiles": compiles(self._decode_fns),
            "insert_buckets": len(self._insert_fns),
            "insert_compiles": compiles(self._insert_fns),
        }

    # -- prefill ------------------------------------------------------------

    def _attn_ring_len(self, cache_len: int) -> Optional[int]:
        cfg = self.cfg
        if cfg.family == "hybrid" and cfg.sliding_window:
            return min(cache_len, cfg.sliding_window)
        return None

    def _prefill_group(self, group: List[Request], cache_len: int):
        """Single-pass prefill for same-length requests.

        Returns (sampled first token [Bp] device array, caches)."""
        cfg = self.cfg
        S = group[0].prompt.shape[0]
        Bp = _pow2_at_least(len(group))
        toks = np.zeros((Bp, S), np.int32)
        for i, r in enumerate(group):
            toks[i] = r.prompt
        toks[len(group):] = toks[0]  # pad rows replay request 0; discarded
        emb = None
        if cfg.family == "audio":
            emb = jnp.asarray(np.stack(
                [r.extra_embeds for r in group]
                + [group[0].extra_embeds] * (Bp - len(group))
            ).astype(np.float32))
        ring = self._attn_ring_len(cache_len)
        temp = jnp.float32(self.temperature)
        idx, tok, caches = 0, None, None
        while idx < S:
            blk = min(_pow2_at_most(S - idx), self.max_prefill_block)
            if ring is not None:
                # ring-buffered kv (hybrid): blocks may only fill VIRGIN ring
                # slots. Past the ring boundary a multi-token write would
                # evict keys still inside the window of the block's own early
                # queries (the sequential semantics evict ONE position per
                # token), so the wrapped tail decays to single-token steps.
                blk = min(blk, _pow2_at_most(ring - idx)) if idx < ring else 1
            fn = self._prefill_fn(Bp, blk, idx == 0, cache_len)
            self.key, k1 = jax.random.split(self.key)
            tb = jnp.asarray(toks[:, idx: idx + blk])
            if idx == 0:
                if cfg.family == "audio":
                    tok, caches = fn(self.params, tb, k1, temp, emb)
                else:
                    tok, caches = fn(self.params, tb, k1, temp)
            else:
                tok, caches = fn(self.params, caches, tb, jnp.int32(idx), k1, temp)
            idx += blk
        return tok, caches

    # -- scheduling ---------------------------------------------------------

    def _required_cache_len(self, r: Request) -> int:
        return _pow2_at_least(r.prompt.shape[0] + r.max_new)

    def _active_any(self) -> bool:
        return any(s is not None for s in self._slots)

    def _ensure_state(self, cache_len: int) -> None:
        if self._state is not None and self._cache_len == cache_len:
            return
        B = self.max_batch
        self._cache_len = cache_len
        self._state = {
            "caches": T.init_decode_caches(self.cfg, B, cache_len, self.cache_dtype),
            "tok": np.zeros((B, 1), np.int32),
            "pos": np.zeros((B,), np.int32),
            "active": np.zeros((B,), bool),
        }
        self._slots = [None] * B

    def _finish(self, r: Request, now: float) -> None:
        r.t_done = now
        self.done.append(r)
        if r.slot >= 0:
            self._slots[r.slot] = None
            self._state["active"][r.slot] = False
            r.slot = -1

    def _admit(self) -> None:
        if not self.waiting:
            return
        if self._state is None or not self._active_any():
            # empty batch: (re)size the cache bucket for the waiting set
            need = max(self._required_cache_len(r) for r in self.waiting)
            self._ensure_state(max(need, self._cache_len))
        free = [i for i, s in enumerate(self._slots) if s is None]
        fitting = [r for r in self.waiting
                   if self._required_cache_len(r) <= self._cache_len]
        if not free or not fitting:
            return
        # one same-length group per admission: they share ONE prefill pass
        S0 = fitting[0].prompt.shape[0]
        group = [r for r in fitting if r.prompt.shape[0] == S0][: len(free)]
        for r in group:
            self.waiting.remove(r)
        t0 = time.perf_counter()
        first_tok, pre_caches = self._prefill_group(group, self._cache_len)
        Bp = first_tok.shape[0]
        first = np.asarray(first_tok)  # the one prefill host sync
        st = self._state
        t1 = time.perf_counter()
        dst = np.full((Bp,), self.max_batch, np.int32)  # pad rows: dropped
        dst[: len(group)] = free[: len(group)]
        st["caches"] = self._insert_fn(Bp)(st["caches"], pre_caches, jnp.asarray(dst))
        for i, r in enumerate(group):
            slot = free[i]
            r.slot = slot
            r.t_admit, r.t_first, r.prefill_s = t0, t1, t1 - t0
            r.tokens.append(int(first[i]))
            self._slots[slot] = r
            st["tok"][slot, 0] = first[i]
            st["pos"][slot] = r.prompt.shape[0]
            st["active"][slot] = True
            if r.finished:  # max_new == 1: done at the prefill sample
                self._finish(r, t1)

    def _decode_block_run(self) -> None:
        st = self._state
        fn = self._decode_fn(self.max_batch, self._cache_len, self.decode_block)
        self.key, sub = jax.random.split(self.key)
        caches, tok, pos, toks = fn(
            self.params, st["caches"], jnp.asarray(st["tok"]),
            jnp.asarray(st["pos"]), jnp.asarray(st["active"]), sub,
            jnp.float32(self.temperature),
        )
        st["caches"] = caches
        toks_np = np.asarray(toks)  # the ONE host sync for this block
        st["tok"], st["pos"] = np.array(tok), np.array(pos)  # writable copies
        now = time.perf_counter()
        for b in range(toks_np.shape[0]):
            for r in list(self._slots):
                if r is None or r.finished:
                    continue
                r.tokens.append(int(toks_np[b, r.slot]))
                if r.finished:
                    self._finish(r, now)

    def run(self) -> Dict:
        """Drain the queue; reports the requests finished during THIS run
        (``self.done`` keeps accumulating across runs for lookups)."""
        t_start = time.perf_counter()
        done_before = len(self.done)
        while self.waiting or (self._state is not None and self._active_any()):
            self._admit()
            if self._state is not None and self._active_any():
                self._decode_block_run()
        return self.report(time.perf_counter() - t_start, self.done[done_before:])

    def report(self, wall_s: float, requests: Optional[List[Request]] = None) -> Dict:
        reqs, gen_total = [], 0
        for r in sorted(self.done if requests is None else requests,
                        key=lambda r: r.rid):
            gen_total += len(r.tokens)
            reqs.append({
                "id": r.rid,
                "prompt_len": int(r.prompt.shape[0]),
                "new_tokens": len(r.tokens),
                "queue_s": round(r.t_admit - r.t_submit, 6),
                "prefill_s": round(r.prefill_s, 6),
                "first_token_s": round(r.t_first - r.t_submit, 6),
                "total_s": round(r.t_done - r.t_submit, 6),
            })
        return {
            "requests": reqs,
            "wall_s": round(wall_s, 6),
            "generated_tokens": gen_total,
            "tokens_per_s": round(gen_total / max(wall_s, 1e-9), 1),
            "compiled_executors": self.compile_counts(),
        }


# ---------------------------------------------------------------------------
# Reconstructed pre-PR serving path (parity oracle + benchmark baseline)
# ---------------------------------------------------------------------------


def sequential_step_fn(cfg: ModelConfig):
    """The pre-PR per-token executor. Build it ONCE and pass it to repeated
    ``sequential_*`` calls — each `jax.jit(lambda ...)` is a fresh cache, so
    benchmarks that want to time steady state (compiles excluded) must share
    one across their warmup and measured runs."""
    return jax.jit(lambda p, t, c, i: T.decode_step(cfg, p, t, c, i))


def sequential_prefill(cfg: ModelConfig, params, prompts, cache_len: int,
                       extra_embeds=None, cache_dtype=jnp.float32, step=None):
    """Token-by-token prefill through jitted ``decode_step`` (S dispatches)."""
    B, S = prompts.shape
    caches = T.init_decode_caches(cfg, B, cache_len, cache_dtype)
    if cfg.family == "audio":
        enc = T.encode_audio(cfg, params, jnp.asarray(extra_embeds))
        caches["enc_out"] = enc.astype(caches["enc_out"].dtype)
    step = step or sequential_step_fn(cfg)
    logits = None
    for i in range(S):
        logits, caches = step(params, prompts[:, i: i + 1], caches, jnp.int32(i))
    return logits, caches


def sequential_decode(cfg: ModelConfig, params, logits, caches, start_pos: int,
                      gen: int, temperature: float = 0.0, seed: int = 0,
                      step=None):
    """The pre-PR decode loop, continuing from prefilled (logits, caches)."""
    key = jax.random.PRNGKey(seed)
    step = step or sequential_step_fn(cfg)
    out = []
    tok = None
    for i in range(gen):
        if i > 0:
            logits, caches = step(params, tok, caches, jnp.int32(start_pos + i - 1))
        key, k1 = jax.random.split(key)
        if temperature > 0:
            tok = jax.random.categorical(
                k1, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    return jnp.concatenate(out, axis=1)


def sequential_generate(cfg: ModelConfig, params, prompts, gen: int,
                        temperature: float = 0.0, seed: int = 0,
                        extra_embeds=None, cache_dtype=jnp.float32,
                        cache_len: Optional[int] = None, step=None):
    """One un-donated dispatch + host-side sample per token (the pre-PR loop,
    with the first-token temperature bug fixed so comparisons are fair)."""
    B, S = prompts.shape
    cache_len = cache_len or (S + gen)
    step = step or sequential_step_fn(cfg)
    logits, caches = sequential_prefill(cfg, params, prompts, cache_len,
                                        extra_embeds, cache_dtype, step=step)
    return sequential_decode(cfg, params, logits, caches, S, gen,
                             temperature, seed, step=step)
