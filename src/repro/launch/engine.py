"""Compiled serving engine: single-pass prefill, scan decode, continuous batching.

The trained global model θ̃ is what e-health institutions actually serve back
to devices and clinicians, so serving shares the hot-path discipline of the
training loop (PR 1-3): everything that runs per-request is a cached,
donating, jitted executor, compiled once per shape bucket.

Three compiled program kinds, each cached exactly like
``HSGDRunner.round_fn``'s per-(P, Q, k, b) executors:

* **prefill** — ONE forward through the train-path stacks per power-of-two
  token block, writing KV/SSM/latent caches with a single
  ``dynamic_update_slice`` per layer (``decode_step`` with [B, S] tokens),
  replacing S sequential single-token dispatches. Prompts whose length is a
  power of two prefill in ONE pass; others decompose into at most
  log2(S) blocks, so the executor cache stays bounded. Long blocks route
  through the Pallas flash-attention op on TPU (``fresh_cache``).
* **decode** — the whole generate loop for a block of tokens staged as one
  donating jitted ``lax.scan`` per (batch, cache-bucket, block): on-device
  sampling (traced temperature, threaded PRNG key) and per-slot cache write
  positions, so there is NO per-token host round-trip — one device sync per
  block, when the scheduler collects tokens.
* **insert** — continuous batching: one executor copies a prefilled
  request's cache rows into a freed decode slot, so new arrivals join a
  running batch without recompiling or restarting it.
* **spec** (PR 6, opt-in via ``spec_gamma``) — self-speculative decoding:
  each scan round drafts γ tokens with truncated-depth passes (the first
  ``spec_draft_layers`` of the stacked scan) and verifies them with ONE
  multi-token full pass, accepting the longest matching prefix. Every
  emitted token comes from the full model's argmax, so greedy output is
  losslessly identical; one executor per (batch, cache-bucket, block, γ,
  draft-layers).
* **harvest** (PR 6, opt-in via ``prefix_cache``) — prefix caching: after a
  prefill whose pow2 prompt head missed the store, one executor masks the
  cache back to exactly-p-tokens state; the rows land in a device-resident
  LRU store keyed by prompt-head digest, and later requests with the same
  head seed their caches from the store (a batch-axis concat, never a host
  round-trip) and skip recomputing those p tokens.

``sequential_generate`` / ``sequential_prefill`` keep the reconstructed
pre-PR serving path (token-by-token prefill, one un-donated dispatch + host
sample per token) as the parity oracle and benchmark baseline.
"""
from __future__ import annotations

import hashlib
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.buckets import pow2_ceil as _pow2_at_least
from repro.common.buckets import pow2_floor as _pow2_at_most
from repro.common.config import ModelConfig
from repro.models import transformer as T

CACHE_DTYPES = {
    "int8": jnp.int8,
    "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
    "f16": jnp.float16, "float16": jnp.float16,
    "f32": jnp.float32, "float32": jnp.float32,
}


def parse_cache_dtype(value):
    """CLI string (or dtype-like) -> cache dtype, failing FAST with the list
    of supported names instead of deep inside cache init."""
    if not isinstance(value, str):
        return value
    try:
        return CACHE_DTYPES[value.lower()]
    except KeyError:
        raise ValueError(
            f"unsupported cache dtype {value!r}; choose one of "
            f"{sorted(CACHE_DTYPES)}"
        ) from None


def sample_token(logits, key, temperature):
    """[B, V] logits -> [B] int32 next tokens, entirely on device.

    ``temperature`` is traced: ONE executor serves greedy (argmax at 0) and
    stochastic sampling — re-picking it never recompiles, and temperature
    applies from the FIRST generated token (the pre-PR loop always argmaxed
    the first one). ``lax.cond`` picks the branch at runtime, so greedy
    decode never pays the categorical's gumbel draw (~7x an argmax).
    """
    temp = jnp.asarray(temperature, jnp.float32)

    def hot(_):
        scaled = logits.astype(jnp.float32) / jnp.maximum(temp, 1e-6)
        return jax.random.categorical(key, scaled, axis=-1)

    def greedy(_):
        return jnp.argmax(logits, axis=-1)

    return jax.lax.cond(temp > 0, hot, greedy, None).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Requests + engine
# ---------------------------------------------------------------------------


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new: int
    extra_embeds: Optional[np.ndarray] = None  # audio: [enc_seq, d_model]
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    prefill_s: float = 0.0
    tokens: List[int] = field(default_factory=list)
    slot: int = -1

    @property
    def finished(self) -> bool:
        return len(self.tokens) >= self.max_new


class ServeEngine:
    """Continuous-batching scheduler over the compiled executors.

    Requests are packed into a padded decode batch of ``max_batch`` slots
    sharing one power-of-two cache bucket; freed slots are refilled from the
    waiting queue while the batch keeps decoding (parked slots write
    out-of-range, which the cache scatter drops). Per-request latency and
    aggregate tokens/s come back from :meth:`run`.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 cache_dtype=jnp.bfloat16, decode_block: int = 8,
                 temperature: float = 0.0, seed: int = 0,
                 max_prefill_block: int = 4096,
                 spec_gamma: int = 0, spec_draft_layers: Optional[int] = None,
                 prefix_cache: bool = False, prefix_min_len: int = 8,
                 prefix_store_max: int = 32):
        self.cfg = cfg
        self.params = params
        self.max_batch = int(max_batch)
        self.cache_dtype = parse_cache_dtype(cache_dtype)
        self.decode_block = int(decode_block)
        self.temperature = float(temperature)
        self.max_prefill_block = int(max_prefill_block)
        self.spec_gamma = int(spec_gamma)
        if self.spec_gamma:
            if not T.supports_self_speculation(cfg):
                raise ValueError(
                    f"speculative decoding unsupported for family "
                    f"{cfg.family!r}: recurrent state cannot roll back "
                    f"rejected drafts")
            if self.temperature > 0:
                raise ValueError(
                    "speculative decoding is greedy-only: lossless "
                    "acceptance compares against argmax targets")
        self.spec_draft_layers = (
            int(spec_draft_layers) if spec_draft_layers
            else max(1, cfg.num_layers // 2))
        self.prefix_cache = bool(prefix_cache)
        self.prefix_min_len = int(prefix_min_len)
        self.prefix_store_max = int(prefix_store_max)
        self.key = jax.random.PRNGKey(seed)
        self._prefill_fns: Dict = {}  # (Bp, block, first, cache_len) -> executor
        self._decode_fns: Dict = {}  # (B, cache_len, block) -> executor
        self._insert_fns: Dict = {}  # (Bp, B, cache_len) -> executor
        self._spec_fns: Dict = {}  # (B, cache_len, block, gamma, dk) -> executor
        self._harvest_fns: Dict = {}  # (Bp, p, cache_len) -> executor
        self._prefix_store: OrderedDict = OrderedDict()  # (digest, p, L) -> rows
        self._spec_stats = {"drafted": 0, "accepted": 0}
        self._prefix_stats = {"hits": 0, "misses": 0, "seeded_tokens": 0}
        self._next_rid = 0
        self.waiting: List[Request] = []
        self.done: List[Request] = []
        self._state = None  # live decode batch: caches + host tok/pos/active
        self._cache_len = 0
        self._slots: List[Optional[Request]] = []

    # -- submission ---------------------------------------------------------

    def submit(self, prompt, max_new: int, extra_embeds=None) -> int:
        r = Request(
            self._next_rid, np.asarray(prompt, np.int32), int(max_new),
            None if extra_embeds is None else np.asarray(extra_embeds, np.float32),
            t_submit=time.perf_counter(),
        )
        self._next_rid += 1
        self.waiting.append(r)
        return r.rid

    def generate(self, prompts, max_new: int, extra_embeds=None):
        """Submit a batch, drain it, return (tokens per request, report)."""
        rids = [
            self.submit(p, max_new, None if extra_embeds is None else extra_embeds[i])
            for i, p in enumerate(prompts)
        ]
        report = self.run()
        by_id = {r.rid: r for r in self.done}
        return [by_id[rid].tokens for rid in rids], report

    # -- compiled executors (cached per shape bucket) -----------------------

    def _prefill_fn(self, Bp: int, block: int, first: bool, cache_len: int):
        # cache_len is part of the bucket: the donated caches' shapes depend
        # on it, and a silent re-jit would break *_buckets == *_compiles
        key = (Bp, block, first, cache_len)
        fn = self._prefill_fns.get(key)
        if fn is None:
            cfg, dtype = self.cfg, self.cache_dtype

            if first:
                # the FIRST block builds its own zero caches inside the jit
                # (no per-leaf host allocs), runs the audio encoder when the
                # family has one, and samples the candidate first token on
                # device — for pow2 prompts the whole prefill is ONE dispatch
                @jax.jit
                def serve_prefill_first(params, tokens, key, temperature, enc_embeds=None):
                    caches = T.init_decode_caches(cfg, Bp, cache_len, dtype)
                    if cfg.family == "audio":
                        caches = T.seed_audio_caches(cfg, params, caches, enc_embeds)
                    logits, caches = T.decode_step(cfg, params, tokens, caches,
                                                   jnp.int32(0), fresh_cache=True)
                    tok = sample_token(logits[:, -1], key, temperature)
                    return tok, caches

                fn = serve_prefill_first
            else:

                @partial(jax.jit, donate_argnums=(1,))
                def serve_prefill(params, caches, tokens, index, key, temperature):
                    logits, caches = T.decode_step(cfg, params, tokens, caches, index)
                    tok = sample_token(logits[:, -1], key, temperature)
                    return tok, caches

                fn = serve_prefill

            self._prefill_fns[key] = fn
        return fn

    def _decode_fn(self, B: int, cache_len: int, block: int):
        key = (B, cache_len, block)
        fn = self._decode_fns.get(key)
        if fn is None:
            cfg = self.cfg

            @partial(jax.jit, donate_argnums=(1,))
            def serve_decode(params, caches, tok, pos, active, key, temperature):
                def step(carry, _):
                    caches, tok, pos, key = carry
                    # parked slots write at cache_len: out-of-range -> dropped
                    widx = jnp.where(active, pos, cache_len)
                    logits, caches = T.decode_step(cfg, params, tok, caches, widx)
                    key, k1 = jax.random.split(key)
                    nxt = sample_token(logits[:, -1], k1, temperature)
                    return (caches, nxt[:, None], pos + 1, key), nxt

                (caches, tok, pos, _), toks = jax.lax.scan(
                    step, (caches, tok, pos, key), None, length=block)
                return caches, tok, pos, toks  # toks: [block, B]

            fn = self._decode_fns[key] = serve_decode
        return fn

    def _insert_fn(self, Bp: int):
        key = (Bp, self.max_batch, self._cache_len)
        fn = self._insert_fns.get(key)
        if fn is None:
            bx = self._batch_axes(self.max_batch, self._cache_len)

            # ONE dispatch admits the whole prefilled group: row i of the
            # prefill caches lands in decode slot dst[i]; prefill pad rows
            # carry dst == max_batch (out of range) and are dropped
            @partial(jax.jit, donate_argnums=(0,))
            def serve_insert(dec_caches, pre_caches, dst):
                def cp(d, p, ax):
                    d2 = jnp.moveaxis(d, ax, 0)
                    p2 = jnp.moveaxis(p, ax, 0)
                    d2 = d2.at[dst].set(p2.astype(d2.dtype), mode="drop")
                    return jnp.moveaxis(d2, 0, ax)

                return jax.tree.map(cp, dec_caches, pre_caches, bx)

            fn = self._insert_fns[key] = serve_insert
        return fn

    def _spec_fn(self, B: int, cache_len: int, block: int, gamma: int, dk: int):
        key = (B, cache_len, block, gamma, dk)
        fn = self._spec_fns.get(key)
        if fn is None:
            cfg = self.cfg

            # One scan round = draft γ truncated-depth tokens + ONE full-model
            # verify over [last committed, d1..dγ]; every emitted token is the
            # full model's argmax (full_next[:, :n_acc + 1]), so greedy output
            # is bit-identical to plain decode. Rejected columns hold stale
            # K/V, but the cache column == sequence position here, and writes
            # precede reads, so each stale column is overwritten before any
            # query can attend it.
            @partial(jax.jit, donate_argnums=(1,))
            def serve_spec_decode(params, caches, tok, pos, active):
                def spec_round(carry, _):
                    caches, tok, pos = carry

                    def draft(c, _):
                        caches, t, p = c
                        widx = jnp.where(active, p, cache_len)
                        logits, caches = T.draft_decode_step(
                            cfg, params, t, caches, widx, dk)
                        nt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                        return (caches, nt[:, None], p + 1), nt

                    (caches, _, _), drafts = jax.lax.scan(
                        draft, (caches, tok, pos), None, length=gamma)
                    drafts = jnp.moveaxis(drafts, 0, 1)  # [B, gamma]
                    blk = jnp.concatenate([tok, drafts], axis=1)  # [B, gamma+1]
                    widx = jnp.where(active, pos, cache_len)
                    logits, caches = T.decode_step(cfg, params, blk, caches, widx)
                    full_next = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                    match = (drafts == full_next[:, :-1]).astype(jnp.int32)
                    n_acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)  # [B]
                    nxt = jnp.take_along_axis(full_next, n_acc[:, None], axis=1)
                    return (caches, nxt, pos + n_acc + 1), (full_next, n_acc + 1)

                (caches, tok, pos), (toks, n_emit) = jax.lax.scan(
                    spec_round, (caches, tok, pos), None, length=block)
                # toks: [block, B, gamma+1]; n_emit: [block, B]
                return caches, tok, pos, toks, n_emit

            fn = self._spec_fns[key] = serve_spec_decode
        return fn

    def _harvest_fn(self, Bp: int, p: int, cache_len: int):
        key = (Bp, p, cache_len)
        fn = self._harvest_fns.get(key)
        if fn is None:
            seq_ax = self._cache_axis(Bp, cache_len, "cache_seq")

            # roll the cache back to exactly-p-tokens state: columns >= p
            # revert to the init values (zeros; INT32_MAX position sentinel),
            # making the harvested rows a deterministic replay of the prefix
            @jax.jit
            def serve_harvest(caches):
                def mask(c, ax):
                    keep_shape = [1] * c.ndim
                    keep_shape[ax] = c.shape[ax]
                    keep = (jnp.arange(c.shape[ax]) < p).reshape(keep_shape)
                    init = jnp.iinfo(jnp.int32).max if c.dtype == jnp.int32 else 0
                    return jnp.where(keep, c, jnp.asarray(init, c.dtype))

                return jax.tree.map(mask, caches, seq_ax)

            fn = self._harvest_fns[key] = serve_harvest
        return fn

    def _cache_axis(self, B: int, cache_len: int, name: str):
        """Pytree of ints: which axis of each cache leaf carries logical axis
        ``name`` (kv/ssm leaves are layer-stacked, so it is NOT always 0)."""
        sds, axes = T.make_decode_caches(self.cfg, B, cache_len, self.cache_dtype)

        def is_ax(t):
            return isinstance(t, tuple) and all(e is None or isinstance(e, str) for e in t)

        ax_leaves = jax.tree_util.tree_flatten(axes, is_leaf=is_ax)[0]
        sd_leaves, treedef = jax.tree_util.tree_flatten(sds)
        if len(ax_leaves) != len(sd_leaves):
            raise AssertionError("cache specs and axes trees diverged")
        return jax.tree_util.tree_unflatten(
            treedef, [a.index(name) for a in ax_leaves])

    def _batch_axes(self, B: int, cache_len: int):
        return self._cache_axis(B, cache_len, "batch")

    def compile_counts(self) -> Dict[str, int]:
        """Executor-cache sizes + actual XLA compile counts (must agree: one
        compile per bucket is the whole point)."""

        def compiles(d):
            return sum(f._cache_size() for f in d.values())

        return {
            "prefill_buckets": len(self._prefill_fns),
            "prefill_compiles": compiles(self._prefill_fns),
            "decode_buckets": len(self._decode_fns),
            "decode_compiles": compiles(self._decode_fns),
            "insert_buckets": len(self._insert_fns),
            "insert_compiles": compiles(self._insert_fns),
            "spec_buckets": len(self._spec_fns),
            "spec_compiles": compiles(self._spec_fns),
            "harvest_buckets": len(self._harvest_fns),
            "harvest_compiles": compiles(self._harvest_fns),
        }

    # -- prefill ------------------------------------------------------------

    def _attn_ring_len(self, cache_len: int) -> Optional[int]:
        cfg = self.cfg
        if cfg.family == "hybrid" and cfg.sliding_window:
            return min(cache_len, cfg.sliding_window)
        return None

    # -- prefix caching -----------------------------------------------------

    def _prefix_enabled(self) -> bool:
        # attention families only: their cache rows are pure positional K/V.
        # SSM/hybrid states entangle the whole prefix; audio cross K/V depend
        # on per-request encoder input, so neither can share prompt heads.
        return self.prefix_cache and self.cfg.family in ("dense", "vlm", "moe")

    def _prefix_len(self, S: int) -> int:
        """pow2 prompt-head length to share; 0 when too short to bother.
        Strictly < S so at least one block still prefills (first-token
        logits must come from a real forward)."""
        p = _pow2_at_most(max(S - 1, 1))
        return p if self.prefix_min_len <= p < S else 0

    @staticmethod
    def _prefix_key(prompt: np.ndarray, p: int, cache_len: int):
        return (hashlib.sha1(prompt[:p].tobytes()).hexdigest(), p, cache_len)

    def _try_seed_prefix(self, group: List[Request], Bp: int, cache_len: int):
        """(p, seeded caches | None): caches covering the first p tokens,
        concatenated from stored DEVICE rows when EVERY row in the group
        hits; a single miss falls back to full prefill (p says what to
        harvest afterwards). Store rows never cross to the host — seeding
        and harvesting stay async device work, so a hit replaces p tokens
        of prefill compute with a batch-axis copy."""
        S = group[0].prompt.shape[0]
        p = self._prefix_len(S)
        if not p:
            return 0, None
        keys = [self._prefix_key(r.prompt, p, cache_len) for r in group]
        if any(k not in self._prefix_store for k in keys):
            self._prefix_stats["misses"] += len(group)
            return p, None
        rows = [self._prefix_store[k] for k in keys]
        for k in keys:
            self._prefix_store.move_to_end(k)
        self._prefix_stats["hits"] += len(group)
        self._prefix_stats["seeded_tokens"] += p * len(group)
        rows += [rows[0]] * (Bp - len(rows))  # pad rows replay request 0
        bx = self._batch_axes(Bp, cache_len)
        # jnp.copy for Bp == 1: a bare concatenate may alias the stored row,
        # and the prefill executor DONATES its cache argument — an aliased
        # buffer would be deleted out from under the store
        caches = jax.tree.map(
            lambda ax, *leaves: (jnp.concatenate(leaves, axis=ax)
                                 if len(leaves) > 1 else jnp.copy(leaves[0])),
            bx, *rows)
        return p, caches

    def _harvest_prefixes(self, group, Bp: int, p: int, cache_len: int, caches):
        """Store each row's exactly-p-tokens cache state (one compiled mask
        pass + per-row device slices per MISS group — no host sync; hits
        never pay this)."""
        masked = self._harvest_fn(Bp, p, cache_len)(caches)
        bx = self._batch_axes(Bp, cache_len)
        for i, r in enumerate(group):
            k = self._prefix_key(r.prompt, p, cache_len)
            self._prefix_store[k] = jax.tree.map(
                lambda c, ax: jax.lax.slice_in_dim(c, i, i + 1, axis=ax),
                masked, bx)
            self._prefix_store.move_to_end(k)
        while len(self._prefix_store) > self.prefix_store_max:
            self._prefix_store.popitem(last=False)  # LRU eviction

    def _prefill_group(self, group: List[Request], cache_len: int):
        """Single-pass prefill for same-length requests.

        Returns (sampled first token [Bp] device array, caches)."""
        cfg = self.cfg
        S = group[0].prompt.shape[0]
        Bp = _pow2_at_least(len(group))
        toks = np.zeros((Bp, S), np.int32)
        for i, r in enumerate(group):
            toks[i] = r.prompt
        toks[len(group):] = toks[0]  # pad rows replay request 0; discarded
        emb = None
        if cfg.family == "audio":
            emb = jnp.asarray(np.stack(
                [r.extra_embeds for r in group]
                + [group[0].extra_embeds] * (Bp - len(group))
            ).astype(np.float32))
        ring = self._attn_ring_len(cache_len)
        temp = jnp.float32(self.temperature)
        idx, tok, caches = 0, None, None
        harvest_p = 0
        if self._prefix_enabled():
            p, seeded = self._try_seed_prefix(group, Bp, cache_len)
            if seeded is not None:
                caches, idx = seeded, p
            else:
                harvest_p = p
        while idx < S:
            blk = min(_pow2_at_most(S - idx), self.max_prefill_block)
            if ring is not None:
                # ring-buffered kv (hybrid): blocks may only fill VIRGIN ring
                # slots. Past the ring boundary a multi-token write would
                # evict keys still inside the window of the block's own early
                # queries (the sequential semantics evict ONE position per
                # token), so the wrapped tail decays to single-token steps.
                blk = min(blk, _pow2_at_most(ring - idx)) if idx < ring else 1
            first = caches is None
            fn = self._prefill_fn(Bp, blk, first, cache_len)
            self.key, k1 = jax.random.split(self.key)
            tb = jnp.asarray(toks[:, idx: idx + blk])
            if first:
                if cfg.family == "audio":
                    tok, caches = fn(self.params, tb, k1, temp, emb)
                else:
                    tok, caches = fn(self.params, tb, k1, temp)
            else:
                tok, caches = fn(self.params, caches, tb, jnp.int32(idx), k1, temp)
            idx += blk
        if harvest_p:
            self._harvest_prefixes(group, Bp, harvest_p, cache_len, caches)
        return tok, caches

    # -- scheduling ---------------------------------------------------------

    def _required_cache_len(self, r: Request) -> int:
        # +gamma: a speculative verify block may overshoot the last token
        return _pow2_at_least(r.prompt.shape[0] + r.max_new + self.spec_gamma)

    def _active_any(self) -> bool:
        return any(s is not None for s in self._slots)

    def _ensure_state(self, cache_len: int) -> None:
        if self._state is not None and self._cache_len == cache_len:
            return
        B = self.max_batch
        self._cache_len = cache_len
        self._state = {
            "caches": T.init_decode_caches(self.cfg, B, cache_len, self.cache_dtype),
            "tok": np.zeros((B, 1), np.int32),
            "pos": np.zeros((B,), np.int32),
            "active": np.zeros((B,), bool),
        }
        self._slots = [None] * B

    def _finish(self, r: Request, now: float) -> None:
        r.t_done = now
        self.done.append(r)
        if r.slot >= 0:
            self._slots[r.slot] = None
            self._state["active"][r.slot] = False
            r.slot = -1

    def _admit(self) -> None:
        if not self.waiting:
            return
        if self._state is None or not self._active_any():
            # empty batch: (re)size the cache bucket for the waiting set
            need = max(self._required_cache_len(r) for r in self.waiting)
            self._ensure_state(max(need, self._cache_len))
        free = [i for i, s in enumerate(self._slots) if s is None]
        fitting = [r for r in self.waiting
                   if self._required_cache_len(r) <= self._cache_len]
        if not free or not fitting:
            return
        # one same-length group per admission: they share ONE prefill pass
        S0 = fitting[0].prompt.shape[0]
        group = [r for r in fitting if r.prompt.shape[0] == S0][: len(free)]
        for r in group:
            self.waiting.remove(r)
        t0 = time.perf_counter()
        first_tok, pre_caches = self._prefill_group(group, self._cache_len)
        Bp = first_tok.shape[0]
        first = np.asarray(first_tok)  # the one prefill host sync
        st = self._state
        t1 = time.perf_counter()
        dst = np.full((Bp,), self.max_batch, np.int32)  # pad rows: dropped
        dst[: len(group)] = free[: len(group)]
        st["caches"] = self._insert_fn(Bp)(st["caches"], pre_caches, jnp.asarray(dst))
        for i, r in enumerate(group):
            slot = free[i]
            r.slot = slot
            r.t_admit, r.t_first, r.prefill_s = t0, t1, t1 - t0
            r.tokens.append(int(first[i]))
            self._slots[slot] = r
            st["tok"][slot, 0] = first[i]
            st["pos"][slot] = r.prompt.shape[0]
            st["active"][slot] = True
            if r.finished:  # max_new == 1: done at the prefill sample
                self._finish(r, t1)

    def _decode_block_run(self) -> None:
        st = self._state
        fn = self._decode_fn(self.max_batch, self._cache_len, self.decode_block)
        self.key, sub = jax.random.split(self.key)
        caches, tok, pos, toks = fn(
            self.params, st["caches"], jnp.asarray(st["tok"]),
            jnp.asarray(st["pos"]), jnp.asarray(st["active"]), sub,
            jnp.float32(self.temperature),
        )
        st["caches"] = caches
        toks_np = np.asarray(toks)  # the ONE host sync for this block
        st["tok"], st["pos"] = np.array(tok), np.array(pos)  # writable copies
        now = time.perf_counter()
        for b in range(toks_np.shape[0]):
            for r in list(self._slots):
                if r is None or r.finished:
                    continue
                r.tokens.append(int(toks_np[b, r.slot]))
                if r.finished:
                    self._finish(r, now)

    def _spec_block_run(self) -> None:
        st = self._state
        fn = self._spec_fn(self.max_batch, self._cache_len, self.decode_block,
                           self.spec_gamma, self.spec_draft_layers)
        caches, tok, pos, toks, n_emit = fn(
            self.params, st["caches"], jnp.asarray(st["tok"]),
            jnp.asarray(st["pos"]), jnp.asarray(st["active"]))
        st["caches"] = caches
        toks_np = np.asarray(toks)  # the ONE host sync for this block
        n_np = np.asarray(n_emit)
        st["tok"], st["pos"] = np.array(tok), np.array(pos)  # writable copies
        now = time.perf_counter()
        for b in range(toks_np.shape[0]):
            for r in list(self._slots):
                if r is None or r.finished:
                    continue
                n = int(n_np[b, r.slot])
                self._spec_stats["drafted"] += self.spec_gamma
                self._spec_stats["accepted"] += n - 1
                for t in toks_np[b, r.slot, :n]:
                    r.tokens.append(int(t))
                    if r.finished:
                        break
                if r.finished:
                    self._finish(r, now)

    # -- public driving API --------------------------------------------------

    def pending(self) -> int:
        """Requests not yet finished: queued + occupying a decode slot."""
        return len(self.waiting) + sum(1 for s in self._slots if s is not None)

    def step(self) -> None:
        """ONE scheduler tick: admit whatever fits, then run one decode
        block. The load generator drives this directly so arrivals can be
        interleaved with decoding at wall-clock trace times."""
        self._admit()
        if self._state is not None and self._active_any():
            if self.spec_gamma:
                self._spec_block_run()
            else:
                self._decode_block_run()

    def run(self) -> Dict:
        """Drain the queue; reports the requests finished during THIS run
        (``self.done`` keeps accumulating across runs for lookups)."""
        t_start = time.perf_counter()
        done_before = len(self.done)
        while self.pending():
            self.step()
        return self.report(time.perf_counter() - t_start, self.done[done_before:])

    def report(self, wall_s: float, requests: Optional[List[Request]] = None) -> Dict:
        reqs, gen_total = [], 0
        for r in sorted(self.done if requests is None else requests,
                        key=lambda r: r.rid):
            gen_total += len(r.tokens)
            reqs.append({
                "id": r.rid,
                "prompt_len": int(r.prompt.shape[0]),
                "new_tokens": len(r.tokens),
                "queue_s": round(r.t_admit - r.t_submit, 6),
                "prefill_s": round(r.prefill_s, 6),
                "first_token_s": round(r.t_first - r.t_submit, 6),
                "total_s": round(r.t_done - r.t_submit, 6),
            })
        out = {
            "requests": reqs,
            "wall_s": round(wall_s, 6),
            "generated_tokens": gen_total,
            "tokens_per_s": round(gen_total / max(wall_s, 1e-9), 1),
            "compiled_executors": self.compile_counts(),
        }
        if self.spec_gamma:
            d = self._spec_stats
            out["speculative"] = {
                "gamma": self.spec_gamma,
                "draft_layers": self.spec_draft_layers,
                "drafted": d["drafted"],
                "accepted": d["accepted"],
                "acceptance": round(d["accepted"] / max(d["drafted"], 1), 4),
            }
        if self.prefix_cache:
            out["prefix_cache"] = dict(self._prefix_stats)
        return out


# ---------------------------------------------------------------------------
# Reconstructed pre-PR serving path (parity oracle + benchmark baseline)
# ---------------------------------------------------------------------------


def sequential_step_fn(cfg: ModelConfig):
    """The pre-PR per-token executor. Build it ONCE and pass it to repeated
    ``sequential_*`` calls — each `jax.jit(lambda ...)` is a fresh cache, so
    benchmarks that want to time steady state (compiles excluded) must share
    one across their warmup and measured runs."""
    return jax.jit(lambda p, t, c, i: T.decode_step(cfg, p, t, c, i))


def sequential_prefill(cfg: ModelConfig, params, prompts, cache_len: int,
                       extra_embeds=None, cache_dtype=jnp.float32, step=None):
    """Token-by-token prefill through jitted ``decode_step`` (S dispatches)."""
    B, S = prompts.shape
    caches = T.init_decode_caches(cfg, B, cache_len, cache_dtype)
    if cfg.family == "audio":
        caches = T.seed_audio_caches(cfg, params, caches, jnp.asarray(extra_embeds))
    step = step or sequential_step_fn(cfg)
    logits = None
    for i in range(S):
        logits, caches = step(params, prompts[:, i: i + 1], caches, jnp.int32(i))
    return logits, caches


def sequential_decode(cfg: ModelConfig, params, logits, caches, start_pos: int,
                      gen: int, temperature: float = 0.0, seed: int = 0,
                      step=None):
    """The pre-PR decode loop, continuing from prefilled (logits, caches)."""
    key = jax.random.PRNGKey(seed)
    step = step or sequential_step_fn(cfg)
    out = []
    tok = None
    for i in range(gen):
        if i > 0:
            logits, caches = step(params, tok, caches, jnp.int32(start_pos + i - 1))
        key, k1 = jax.random.split(key)
        if temperature > 0:
            tok = jax.random.categorical(
                k1, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    return jnp.concatenate(out, axis=1)


def sequential_generate(cfg: ModelConfig, params, prompts, gen: int,
                        temperature: float = 0.0, seed: int = 0,
                        extra_embeds=None, cache_dtype=jnp.float32,
                        cache_len: Optional[int] = None, step=None):
    """One un-donated dispatch + host-side sample per token (the pre-PR loop,
    with the first-token temperature bug fixed so comparisons are fair)."""
    B, S = prompts.shape
    cache_len = cache_len or (S + gen)
    step = step or sequential_step_fn(cfg)
    logits, caches = sequential_prefill(cfg, params, prompts, cache_len,
                                        extra_embeds, cache_dtype, step=step)
    return sequential_decode(cfg, params, logits, caches, S, gen,
                             temperature, seed, step=step)
