"""Loop-aware analytic FLOP counting from jaxprs.

XLA's ``compiled.cost_analysis()`` multiplies only the OUTERMOST while-loop's
trip count — flops inside nested scans (blockwise attention kv loop, SSM
chunk scans, remat-in-scan backward) are counted once (verified empirically:
a scan-in-scan matmul reports 1/inner_length of its true flops). This module
traverses the jaxpr instead, scaling by every ``scan``'s static length, so
the roofline's compute term reflects the mathematics actually executed.

Counted: dot_general (2·B·M·N·K), conv, plus elementwise/cumulative ops at
1 flop/element (the SSM recurrence is elementwise-dominated). The count is
GLOBAL (pre-partitioning); divide by chip count for per-device terms — which
deliberately charges SPMD-redundant compute to every chip the same way the
6ND reference does.
"""
from __future__ import annotations

from functools import reduce
from operator import mul
from typing import Any

import jax
import numpy as np

ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh", "logistic",
    "rsqrt", "sqrt", "pow", "integer_pow", "neg", "abs", "sign", "floor",
    "cos", "sin", "erf", "expm1", "log1p", "select_n", "clamp", "nextafter",
}
REDUCTIONS = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
              "cumsum", "cumlogsumexp", "cummax", "cumprod", "argmax", "argmin"}


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:  # pragma: no cover
        return 1


def _dot_flops(eqn) -> int:
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    k = reduce(mul, (lhs.shape[d] for d in lc), 1)
    b = reduce(mul, (lhs.shape[d] for d in lb), 1)
    m = reduce(mul, (lhs.shape[d] for d in range(len(lhs.shape)) if d not in set(lc) | set(lb)), 1)
    n = reduce(mul, (rhs.shape[d] for d in range(len(rhs.shape)) if d not in set(rc) | set(rb)), 1)
    return 2 * b * m * n * k


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval  # kernel
    return 2 * _size(out) * int(np.prod(rhs.shape[:-1]))


def count_jaxpr_flops(jaxpr, scale: int = 1) -> int:
    total = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += scale * _dot_flops(eqn)
        elif name == "conv_general_dilated":
            total += scale * _conv_flops(eqn)
        elif name == "scan":
            inner = eqn.params["jaxpr"].jaxpr
            total += count_jaxpr_flops(inner, scale * int(eqn.params["length"]))
        elif name == "while":
            # no static trip count: charge the body once (rare in this code)
            total += count_jaxpr_flops(eqn.params["body_jaxpr"].jaxpr, scale)
        elif name == "cond":
            branches = eqn.params["branches"]
            total += max(count_jaxpr_flops(b.jaxpr, scale) for b in branches)
        elif name in ELEMENTWISE and not _has_subjaxpr(eqn):
            total += scale * _size(eqn.outvars[0].aval)
        elif name in REDUCTIONS:
            total += scale * _size(eqn.invars[0].aval)
        else:
            # generic recursion: pjit / remat2 / custom_vjp / named_call / ...
            for sub in _subjaxprs(eqn):
                total += count_jaxpr_flops(sub, scale)
    return total


def _subjaxprs(eqn):
    for v in eqn.params.values():
        if hasattr(v, "eqns"):
            yield v
        elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
            yield v.jaxpr
        elif isinstance(v, (list, tuple)):
            for item in v:
                if hasattr(item, "eqns"):
                    yield item
                elif hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
                    yield item.jaxpr


def _has_subjaxpr(eqn) -> bool:
    return next(iter(_subjaxprs(eqn)), None) is not None


def traced_flops(fn, *example_args) -> int:
    """Global analytic flops of fn on ShapeDtypeStruct inputs."""
    jaxpr = jax.make_jaxpr(fn)(*example_args)
    return count_jaxpr_flops(jaxpr.jaxpr)
