"""Poisson/trace-driven load generator for the serving engine.

The steady-state benchmark (bench_serve.py) measures a fixed batch decoding
in lockstep; real e-health traffic is arrival-driven — requests land on the
scheduler at random times, queue for a slot, and care about first-token
latency, not just aggregate tokens/s. This module closes that gap:

* ``poisson_trace`` builds a seeded, reproducible trace (exponential
  inter-arrival gaps at a target request rate, optional shared prompt head
  to exercise the prefix cache) that can be saved/loaded as JSON.
* ``run_load`` replays a trace against a :class:`ServeEngine` in real wall
  clock — submitting each request at its timestamp while the engine keeps
  decoding via the public ``step()``/``pending()`` API — and reports
  p50/p99 queue, first-token and total latency, sustained tokens/s, and
  SLO attainment (fraction of requests under the first-token deadline).

  PYTHONPATH=src python -m repro.launch.loadgen --arch gemma3-1b --smoke \
      --requests 20 --rate 20 --seed 0
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import asdict, dataclass
from typing import Dict, List

import numpy as np

from repro.common.io import atomic_write_json


@dataclass
class TraceRequest:
    rid: int
    t_arrival: float  # seconds from trace start
    prompt: List[int]
    max_new: int


def poisson_trace(n: int, rate: float, prompt_len: int, max_new: int,
                  vocab_size: int, seed: int = 0,
                  shared_prefix_frac: float = 0.0) -> List[TraceRequest]:
    """Seeded Poisson arrivals: n requests at ``rate`` req/s on average.

    ``shared_prefix_frac`` of each prompt is drawn ONCE and shared by every
    request (the common system-prompt head that prefix caching exploits);
    the tail stays per-request random. For the prefix cache to hit, the
    shared head must cover the engine's pow2 prefix block —
    ``pow2_floor(prompt_len - 1)`` tokens — so fractions below ~0.75 of a
    non-pow2 prompt length produce misses by construction. The first
    arrival is at t=0 so a replay never starts with dead air.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), size=n)
    arrivals = np.cumsum(gaps) - gaps[0]
    shared_len = int(prompt_len * shared_prefix_frac)
    shared = rng.integers(1, vocab_size, size=shared_len)
    out = []
    for i in range(n):
        tail = rng.integers(1, vocab_size, size=prompt_len - shared_len)
        prompt = np.concatenate([shared, tail]).astype(np.int32)
        out.append(TraceRequest(i, float(arrivals[i]), prompt.tolist(), max_new))
    return out


def save_trace(path: str, trace: List[TraceRequest]) -> None:
    atomic_write_json(path, [asdict(r) for r in trace], indent=None)


def load_trace(path: str) -> List[TraceRequest]:
    with open(path) as f:
        return [TraceRequest(**d) for d in json.load(f)]


def _pct(vals, q):
    return round(float(np.percentile(np.asarray(vals), q)), 6) if vals else 0.0


def _latency(vals) -> Dict:
    return {"p50": _pct(vals, 50), "p99": _pct(vals, 99)}


def load_report(finished, slo_first_token_s: float) -> Dict:
    """Latency/SLO summary over finished engine Requests (percentile
    definitions documented in benchmarks/README.md)."""
    queue = [r.t_admit - r.t_submit for r in finished]
    first = [r.t_first - r.t_submit for r in finished]
    total = [r.t_done - r.t_submit for r in finished]
    gen = sum(len(r.tokens) for r in finished)
    span = (max(r.t_done for r in finished) - min(r.t_submit for r in finished)
            if finished else 0.0)
    met = sum(1 for f in first if f <= slo_first_token_s)
    return {
        "requests": len(finished),
        "generated_tokens": gen,
        "span_s": round(span, 6),
        "sustained_tokens_per_s": round(gen / max(span, 1e-9), 1),
        "queue_s": _latency(queue),
        "first_token_s": _latency(first),
        "total_s": _latency(total),
        "slo_first_token_s": slo_first_token_s,
        "slo_attainment": round(met / max(len(finished), 1), 4),
    }


def run_load(engine, trace: List[TraceRequest],
             slo_first_token_s: float = 1.0, time_scale: float = 1.0) -> Dict:
    """Replay ``trace`` against ``engine`` in real wall clock.

    Each request is submitted once its (scaled) arrival time has passed;
    between arrivals the engine keeps stepping — admissions interleave with
    decode blocks exactly as they would under live traffic. Returns the
    load report plus the engine's own run report (compile counts, spec /
    prefix stats).
    """
    trace = sorted(trace, key=lambda r: r.t_arrival)
    done_before = len(engine.done)
    i = 0
    t0 = time.perf_counter()
    while i < len(trace) or engine.pending():
        now = time.perf_counter() - t0
        while i < len(trace) and trace[i].t_arrival * time_scale <= now:
            engine.submit(np.asarray(trace[i].prompt, np.int32), trace[i].max_new)
            i += 1
        if engine.pending():
            engine.step()
        elif i < len(trace):
            # idle until the next arrival (engine fully drained)
            time.sleep(min(trace[i].t_arrival * time_scale - now, 0.05))
    wall = time.perf_counter() - t0
    finished = engine.done[done_before:]
    rep = load_report(finished, slo_first_token_s)
    rep["wall_s"] = round(wall, 6)
    rep["engine"] = engine.report(wall, finished)
    return rep


def main(argv=None):
    import jax.numpy as jnp

    from repro.common.config import get_config
    from repro.launch.engine import ServeEngine, parse_cache_dtype
    from repro.launch.serve import build_inputs

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--rate", type=float, default=20.0, help="mean req/s")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shared-prefix-frac", type=float, default=0.75)
    ap.add_argument("--trace", default="", help="load arrivals from JSON instead")
    ap.add_argument("--save-trace", default="", help="write the trace JSON")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--decode-block", type=int, default=8)
    ap.add_argument("--cache-dtype", default="f32")
    ap.add_argument("--spec-gamma", type=int, default=0)
    ap.add_argument("--spec-draft-layers", type=int, default=0)
    ap.add_argument("--prefix-cache", action="store_true")
    ap.add_argument("--slo-first-token-s", type=float, default=1.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params, _, _ = build_inputs(cfg, 1, args.prompt_len, args.seed)
    if args.trace:
        trace = load_trace(args.trace)
    else:
        trace = poisson_trace(args.requests, args.rate, args.prompt_len,
                              args.gen, cfg.vocab_size, args.seed,
                              args.shared_prefix_frac)
    if args.save_trace:
        save_trace(args.save_trace, trace)
    engine = ServeEngine(
        cfg, params, max_batch=args.max_batch,
        cache_dtype=parse_cache_dtype(args.cache_dtype),
        decode_block=args.decode_block, temperature=0.0, seed=args.seed,
        spec_gamma=args.spec_gamma,
        spec_draft_layers=args.spec_draft_layers or None,
        prefix_cache=args.prefix_cache,
    )
    rep = run_load(engine, trace, args.slo_first_token_s)
    print(json.dumps(rep, indent=1))
    return rep


if __name__ == "__main__":
    main()
