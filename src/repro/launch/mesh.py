"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

In the HSGD mapping (DESIGN §2): "pod" carries the hospital-patient groups
(tier-3 horizontal — aggregated every P steps), "data" carries batch/FSDP
within a group (tier-1 — the intra-group device aggregation), and "model"
carries the vertical partition + tensor parallelism (tier-2 — the ζ exchange
every Q steps).

Defined as functions, never module-level constants: importing this module
must not touch jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, multi_pod: bool = False):
    """Small mesh for CI-sized dry-run tests (requires >= n devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
