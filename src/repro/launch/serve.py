"""Serving launcher: batched autoregressive decoding with a KV/SSM cache.

Runs a (reduced) architecture through prefill + N decode steps for a batch of
requests, reporting per-token latency. This is the serve-side end-to-end
driver; the production decode path is the same ``decode_step`` the dry-run
lowers at 32k/500k.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import get_config
from repro.models import layers as L
from repro.models import transformer as T


def prefill_into_cache(cfg, params, tokens, cache_len, extra_embeds=None):
    """Sequential prefill through decode_step (simple, cache-exact)."""
    B, S = tokens.shape
    caches = T.init_decode_caches(cfg, B, cache_len, jnp.float32)
    if cfg.family == "audio":
        caches["enc_out"] = encode_audio(cfg, params, extra_embeds)
    step = jax.jit(lambda p, tok, c, i: T.decode_step(cfg, p, tok, c, i))
    logits = None
    for i in range(S):
        logits, caches = step(params, tokens[:, i : i + 1], caches, jnp.int32(i))
    return logits, caches, S


def encode_audio(cfg, params, enc_embeds):
    B = enc_embeds.shape[0]
    enc_pos = jnp.broadcast_to(jnp.arange(enc_embeds.shape[1], dtype=jnp.int32), (B, enc_embeds.shape[1]))
    x = enc_embeds

    def enc_body(h, layer):
        p, _ = layer
        hn = L.apply_norm(cfg.norm, p["norm1"], h)
        a = T.cross_attention(p["attn"], hn, hn, enc_pos, enc_pos, cfg)
        h = h + a
        hn = L.apply_norm(cfg.norm, p["norm2"], h)
        from repro.models.mlp import mlp_forward

        h = h + mlp_forward(p["mlp"], hn, cfg)
        return h, None

    zero_w = jnp.zeros((cfg.encoder_layers,), jnp.int32)
    x, _ = jax.lax.scan(enc_body, x, (params["enc_layers"], zero_w))
    return L.apply_norm(cfg.norm, params["enc_norm"], x)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    params = L.init_params(T.model_specs(cfg), key, jnp.float32)
    rng = np.random.RandomState(args.seed)
    B = args.batch
    prompts = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, args.prompt_len)), jnp.int32)
    extra = None
    if cfg.family == "audio":
        extra = jnp.asarray(rng.randn(B, cfg.encoder_seq, cfg.d_model), jnp.float32)

    cache_len = args.prompt_len + args.gen
    t0 = time.time()
    logits, caches, pos = prefill_into_cache(cfg, params, prompts, cache_len, extra)
    t_prefill = time.time() - t0

    step = jax.jit(lambda p, tok, c, i: T.decode_step(cfg, p, tok, c, i))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        key, k = jax.random.split(key)
        logits, caches = step(params, tok, caches, jnp.int32(pos + i))
        if args.temperature > 0:
            tok = jax.random.categorical(k, logits[:, -1] / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    out_tokens = jnp.concatenate(generated, axis=1)
    report = {
        "arch": args.arch,
        "batch": B,
        "prefill_s": round(t_prefill, 3),
        "decode_tok_per_s": round(B * (args.gen - 1) / max(t_decode, 1e-9), 1),
        "ms_per_decode_step": round(1000 * t_decode / max(args.gen - 1, 1), 2),
        "sample_output": np.asarray(out_tokens[0, :8]).tolist(),
    }
    print(json.dumps(report, indent=1))
    return report


if __name__ == "__main__":
    main()
