"""Serving launcher: thin CLI over the compiled serving engine.

Runs a (reduced) architecture through the continuous-batching engine —
batched single-pass prefill + scan-based donated decode with on-device
sampling — and reports per-request latency, aggregate tokens/s, and the
executor-cache compile counts. ``--sequential`` runs the reconstructed
pre-PR token-by-token path instead (the benchmark baseline).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import get_config
from repro.launch.engine import (ServeEngine, sequential_decode,
                                 sequential_prefill, sequential_step_fn)
from repro.models import layers as L
from repro.models import transformer as T

CACHE_DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32}


def build_inputs(cfg, batch: int, prompt_len: int, seed: int = 0):
    """(params, prompts, extra_embeds) for a serve run — shared with
    benchmarks/bench_serve.py so the CLI and the benchmark can't diverge."""
    key = jax.random.PRNGKey(seed)
    params = L.init_params(T.model_specs(cfg), key, jnp.float32)
    rng = np.random.RandomState(seed)
    prompts = rng.randint(0, cfg.vocab_size, (batch, prompt_len)).astype(np.int32)
    extra = None
    if cfg.family == "audio":
        extra = rng.randn(batch, cfg.encoder_seq, cfg.d_model).astype(np.float32)
    return params, prompts, extra


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-dtype", choices=sorted(CACHE_DTYPES), default="bf16")
    ap.add_argument("--decode-block", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=0,
                    help="decode slots (0 = --batch)")
    ap.add_argument("--sequential", action="store_true",
                    help="run the reconstructed pre-PR token-by-token path")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params, prompts, extra = build_inputs(cfg, args.batch, args.prompt_len, args.seed)

    if args.sequential:
        step = sequential_step_fn(cfg)
        t0 = time.perf_counter()
        logits, caches = sequential_prefill(
            cfg, params, jnp.asarray(prompts), args.prompt_len + args.gen,
            extra, CACHE_DTYPES[args.cache_dtype], step=step)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        t0 = time.perf_counter()
        toks = sequential_decode(cfg, params, logits, caches, args.prompt_len,
                                 args.gen, args.temperature, args.seed, step=step)
        t_decode = max(time.perf_counter() - t0, 1e-9)
        report = {
            "arch": args.arch,
            "mode": "sequential",
            "batch": args.batch,
            "prefill_s": round(t_prefill, 3),
            "decode_tok_per_s": round(args.batch * args.gen / t_decode, 1),
            "ms_per_decode_step": round(1000 * t_decode / max(args.gen, 1), 2),
            "wall_s": round(t_prefill + t_decode, 3),
            "sample_output": np.asarray(toks[0, :8]).tolist(),
        }
        print(json.dumps(report, indent=1))
        return report

    engine = ServeEngine(
        cfg, params, max_batch=args.max_batch or args.batch,
        cache_dtype=CACHE_DTYPES[args.cache_dtype],
        decode_block=args.decode_block, temperature=args.temperature,
        seed=args.seed,
    )
    toks, rep = engine.generate(list(prompts), args.gen, extra_embeds=extra)
    prefill_s = max((r["prefill_s"] for r in rep["requests"]), default=0.0)
    decode_s = max(rep["wall_s"] - prefill_s, 1e-9)
    report = {
        "arch": args.arch,
        "mode": "engine",
        "batch": args.batch,
        "prefill_s": round(prefill_s, 3),
        # decode-only rate (same basis as ms_per_decode_step and
        # bench_serve.py); end-to-end throughput is tokens_per_s_e2e
        "decode_tok_per_s": round(rep["generated_tokens"] / decode_s, 1),
        "tokens_per_s_e2e": rep["tokens_per_s"],
        "ms_per_decode_step": round(1000 * decode_s / max(args.gen, 1), 2),
        "wall_s": rep["wall_s"],
        "requests": rep["requests"],
        "compiled_executors": rep["compiled_executors"],
        "sample_output": toks[0][:8],
    }
    print(json.dumps(report, indent=1))
    return report


if __name__ == "__main__":
    main()
