"""Serving launcher: thin CLI over the compiled serving engine.

Runs a (reduced) architecture through the continuous-batching engine —
batched single-pass prefill + scan-based donated decode with on-device
sampling — and reports per-request latency, aggregate tokens/s, and the
executor-cache compile counts. ``--sequential`` runs the reconstructed
pre-PR token-by-token path instead (the benchmark baseline).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import get_config
from repro.launch.engine import (CACHE_DTYPES, ServeEngine, parse_cache_dtype,
                                 sequential_decode, sequential_prefill,
                                 sequential_step_fn)
from repro.models import layers as L
from repro.models import transformer as T


def build_inputs(cfg, batch: int, prompt_len: int, seed: int = 0):
    """(params, prompts, extra_embeds) for a serve run — shared with
    benchmarks/bench_serve.py so the CLI and the benchmark can't diverge."""
    key = jax.random.PRNGKey(seed)
    params = L.init_params(T.model_specs(cfg), key, jnp.float32)
    rng = np.random.RandomState(seed)
    prompts = rng.randint(0, cfg.vocab_size, (batch, prompt_len)).astype(np.int32)
    extra = None
    if cfg.family == "audio":
        extra = rng.randn(batch, cfg.encoder_seq, cfg.d_model).astype(np.float32)
    return params, prompts, extra


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-dtype", default="bf16",
                    help=f"one of {sorted(CACHE_DTYPES)} (int8 = quantized caches)")
    ap.add_argument("--decode-block", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=0,
                    help="decode slots (0 = --batch)")
    ap.add_argument("--spec-gamma", type=int, default=0,
                    help="self-speculative draft length (0 = off; greedy only)")
    ap.add_argument("--spec-draft-layers", type=int, default=0,
                    help="truncated-depth draft layers (0 = num_layers // 2)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="seed caches from previously-seen pow2 prompt heads")
    ap.add_argument("--sequential", action="store_true",
                    help="run the reconstructed pre-PR token-by-token path")
    args = ap.parse_args(argv)

    # validate EARLY with the supported-name list, not a jnp.dtype traceback
    # from deep inside cache init
    try:
        cache_dtype = parse_cache_dtype(args.cache_dtype)
    except ValueError as e:
        ap.error(str(e))

    cfg = get_config(args.arch, smoke=args.smoke)
    params, prompts, extra = build_inputs(cfg, args.batch, args.prompt_len, args.seed)

    if args.sequential:
        step = sequential_step_fn(cfg)
        t0 = time.perf_counter()
        logits, caches = sequential_prefill(
            cfg, params, jnp.asarray(prompts), args.prompt_len + args.gen,
            extra, cache_dtype, step=step)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        t0 = time.perf_counter()
        toks = sequential_decode(cfg, params, logits, caches, args.prompt_len,
                                 args.gen, args.temperature, args.seed, step=step)
        t_decode = max(time.perf_counter() - t0, 1e-9)
        report = {
            "arch": args.arch,
            "mode": "sequential",
            "batch": args.batch,
            "prefill_s": round(t_prefill, 3),
            "decode_tok_per_s": round(args.batch * args.gen / t_decode, 1),
            "ms_per_decode_step": round(1000 * t_decode / max(args.gen, 1), 2),
            "wall_s": round(t_prefill + t_decode, 3),
            "sample_output": np.asarray(toks[0, :8]).tolist(),
        }
        print(json.dumps(report, indent=1))
        return report

    engine = ServeEngine(
        cfg, params, max_batch=args.max_batch or args.batch,
        cache_dtype=cache_dtype,
        decode_block=args.decode_block, temperature=args.temperature,
        seed=args.seed, spec_gamma=args.spec_gamma,
        spec_draft_layers=args.spec_draft_layers or None,
        prefix_cache=args.prefix_cache,
    )
    toks, rep = engine.generate(list(prompts), args.gen, extra_embeds=extra)
    prefill_s = max((r["prefill_s"] for r in rep["requests"]), default=0.0)
    decode_s = max(rep["wall_s"] - prefill_s, 1e-9)
    report = {
        "arch": args.arch,
        "mode": "engine",
        "batch": args.batch,
        "prefill_s": round(prefill_s, 3),
        # decode-only rate (same basis as ms_per_decode_step and
        # bench_serve.py); end-to-end throughput is tokens_per_s_e2e
        "decode_tok_per_s": round(rep["generated_tokens"] / decode_s, 1),
        "tokens_per_s_e2e": rep["tokens_per_s"],
        "ms_per_decode_step": round(1000 * decode_s / max(args.gen, 1), 2),
        "wall_s": rep["wall_s"],
        "requests": rep["requests"],
        "compiled_executors": rep["compiled_executors"],
        "sample_output": toks[0][:8],
    }
    for k in ("speculative", "prefix_cache"):
        if k in rep:
            report[k] = rep[k]
    print(json.dumps(report, indent=1))
    return report


if __name__ == "__main__":
    main()
