"""Step builders + input specs for every (architecture × input shape).

Three lowered programs per training shape (their roofline terms combine as
  cost/step = train_step + (1/Q)·exchange_step + (1/P)·global_agg
— exactly the paper's C(P,Q) decomposition):

  * ``hsgd_train_step``  — one HSGD iteration (eqs. 5–7): hospital update with
    fresh ζ1/stale ζ2, device update with stale θ0/ζ1. Runs every step, no
    cross-tier communication beyond the within-group batch reduce.
  * ``exchange_step``    — recompute + exchange ζ1, ζ2 and snapshot θ0
    (fired every Q steps; optionally top-k compressed).
  * ``global_agg``       — eq. (2) across groups (pods), fired every P steps.

Inference shapes lower the plain architecture (federation is a training
construct): ``prefill_step`` and ``decode_step``.

TPU adaptation of tier-1 (documented in DESIGN §2): the within-group device
aggregation (eq. 1) is realized by the batch-mean over the data axis that the
gradient computation already performs — on a pod this reduction is the
standard within-replica gradient sync, so Q amortizes the *vertical exchange*
while P amortizes the *cross-pod model sync*.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import InputShape, ModelConfig
from repro.common.sharding import DEFAULT_RULES, divisible_spec, logical_to_spec
from repro.core.compression import compress_message
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.split_model import HybridModel, llm_hybrid

VIS_PATCHES = 1024  # stubbed vision patches prepended for the VLM arch

# long_500k needs sub-quadratic attention: run only where that holds.
LONG_CTX_OK = {"gemma3-1b", "gemma3-4b", "zamba2-2.7b", "falcon-mamba-7b"}


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------


def build_shardings(shapes_tree, axes_tree, mesh: Mesh, rules=None):
    """ShapeDtypeStruct tree + logical-axes tree -> NamedSharding tree."""
    rules = rules or DEFAULT_RULES

    def one(sds, axes):
        if axes is None:
            return NamedSharding(mesh, P())
        spec = logical_to_spec(axes, rules, mesh)
        spec = divisible_spec(sds.shape, spec, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(
        one, shapes_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------


def hybrid_train_inputs(cfg: ModelConfig, shape: InputShape):
    """ShapeDtypeStructs + logical axes for the HSGD training batch."""
    B, S = shape.global_batch, shape.seq_len
    dt = _dtype(cfg)
    tok_axes = ("batch", "seq")
    emb_axes = ("batch", "seq", None)
    if cfg.family == "vlm":
        pv = VIS_PATCHES
        sds = {
            "x1": jax.ShapeDtypeStruct((B, pv, cfg.d_model), dt),
            "x2": jax.ShapeDtypeStruct((B, S - pv), jnp.int32),
            "y": jax.ShapeDtypeStruct((B, S - pv), jnp.int32),
        }
        axes = {"x1": emb_axes, "x2": tok_axes, "y": tok_axes}
    elif cfg.family == "audio":
        sds = {
            "x1": jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt),
            "x2": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "y": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        axes = {"x1": emb_axes, "x2": tok_axes, "y": tok_axes}
    else:
        s1 = S // 2
        sds = {
            "x1": jax.ShapeDtypeStruct((B, s1), jnp.int32),
            "x2": jax.ShapeDtypeStruct((B, S - s1), jnp.int32),
            "y": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        axes = {"x1": tok_axes, "x2": tok_axes, "y": tok_axes}
    return sds, axes


def hybrid_stale_inputs(model: HybridModel, cfg: ModelConfig, batch_sds):
    """Shapes of the stale exchange context (ζ1, ζ2, θ0 snapshot)."""
    dt = _dtype(cfg)
    t1 = L.abstract_params(model.specs1, dt)
    t2 = L.abstract_params(model.specs2, dt)
    z1 = jax.eval_shape(model.h1, t1, batch_sds["x1"])
    z2 = jax.eval_shape(model.h2, t2, batch_sds["x2"])
    t0 = L.abstract_params(model.specs0, dt)
    sds = {"theta0": t0, "z1": z1, "z2": z2}
    axes = {
        "theta0": L.axes_tree(model.specs0),
        "z1": ("batch", "seq", None),
        "z2": ("batch", "seq", None),
    }
    return sds, axes


def inference_inputs(cfg: ModelConfig, shape: InputShape, force_window: bool):
    """(prefill | decode) inputs for the plain architecture."""
    B, S = shape.global_batch, shape.seq_len
    dt = _dtype(cfg)
    if shape.kind == "prefill":
        sds: Dict[str, Any] = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        axes: Dict[str, Any] = {"tokens": ("batch", "seq")}
        if cfg.family == "vlm":
            sds["tokens"] = jax.ShapeDtypeStruct((B, S - VIS_PATCHES), jnp.int32)
            sds["extra_embeds"] = jax.ShapeDtypeStruct((B, VIS_PATCHES, cfg.d_model), dt)
            axes["extra_embeds"] = ("batch", "seq", None)
        elif cfg.family == "audio":
            sds["extra_embeds"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt)
            axes["extra_embeds"] = ("batch", "seq", None)
        return sds, axes
    # decode: one token + caches
    cache_len = S
    if force_window and cfg.sliding_window:
        cache_len = min(S, cfg.sliding_window)
    cache_sds, cache_axes = T.make_decode_caches(cfg, B, cache_len, dt)
    sds = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32), "caches": cache_sds}
    axes = {"tokens": ("batch", None), "caches": cache_axes}
    return sds, axes


# ---------------------------------------------------------------------------
# HSGD step builders (training shapes)
# ---------------------------------------------------------------------------


def make_hybrid(cfg: ModelConfig, n_tower: int = 2, remat: bool = True) -> HybridModel:
    return llm_hybrid(cfg, n_tower=n_tower, remat=remat)


def make_hsgd_train_step(model: HybridModel, lr: float = 1e-3) -> Callable:
    def step(params, stale, batch):
        def hosp_loss(t0, t1):
            z1 = model.h1(t1, batch["x1"])
            return model.loss(t0, z1, jax.lax.stop_gradient(stale["z2"]), batch["y"])

        loss, (g0, g1) = jax.value_and_grad(hosp_loss, argnums=(0, 1))(
            params["theta0"], params["theta1"]
        )

        def dev_loss(t2):
            z2 = model.h2(t2, batch["x2"])
            return model.loss(
                jax.lax.stop_gradient(stale["theta0"]),
                jax.lax.stop_gradient(stale["z1"]),
                z2,
                batch["y"],
            )

        g2 = jax.grad(dev_loss)(params["theta2"])
        upd = lambda p, g: p - lr * g.astype(p.dtype)
        new = {
            "theta0": jax.tree.map(upd, params["theta0"], g0),
            "theta1": jax.tree.map(upd, params["theta1"], g1),
            "theta2": jax.tree.map(upd, params["theta2"], g2),
        }
        return new, loss

    return step


def make_exchange_step(model: HybridModel, compression_k: float = 0.0, quant: int = 0) -> Callable:
    def exchange(params, batch):
        z1 = model.h1(params["theta1"], batch["x1"])
        z2 = model.h2(params["theta2"], batch["x2"])
        if compression_k or quant:
            z1 = compress_message(z1, compression_k or 1.0, quant)
            z2 = compress_message(z2, compression_k or 1.0, quant)
        return {"theta0": params["theta0"], "z1": z1, "z2": z2}

    return exchange


def make_global_agg() -> Callable:
    """Eq. (2) over the leading group (pod) dim: mean + broadcast back."""

    def agg(params):
        def m(x):
            g = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True).astype(x.dtype)
            return jnp.broadcast_to(g, x.shape)

        return jax.tree.map(m, params)

    return agg


# ---------------------------------------------------------------------------
# Plain (non-federated) steps
# ---------------------------------------------------------------------------


def make_plain_train_step(cfg: ModelConfig, lr: float = 1e-3, force_window=False) -> Callable:
    """Baseline sync-DP training step (beyond-paper comparison point)."""

    def step(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.lm_loss(cfg, p, batch, remat=True, force_window=force_window)
        )(params)
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new, loss

    return step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def step(params, batch):
        hidden, _ = T.forward(
            cfg, params, batch["tokens"], extra_embeds=batch.get("extra_embeds"), remat=True
        )
        logits = T.logits_from_hidden(cfg, params, hidden[:, -1:])
        return logits

    return step


def make_decode_step(cfg: ModelConfig, force_window: bool = False) -> Callable:
    from repro.common.sharding import weight_mode

    def step(params, batch):
        index = jnp.asarray(batch_index_default(batch), jnp.int32)
        with weight_mode("fsdp"):  # decode: weights stay sharded (§Perf it. 2)
            logits, new_caches = T.decode_step(
                cfg, params, batch["tokens"], batch["caches"], index, force_window=force_window
            )
        return logits, new_caches

    return step


def batch_index_default(batch):
    """Decode write position: mid-cache (static for the dry-run)."""
    caches = batch["caches"]
    leaves = jax.tree_util.tree_leaves(caches)
    # cache length lives on axis 2 of stacked kv ([L, B, S, ...]) or ssm state
    for leaf in leaves:
        if leaf.ndim >= 3:
            return leaf.shape[2] // 2
    return 0


# ---------------------------------------------------------------------------
# Assembled program set per (arch, shape)
# ---------------------------------------------------------------------------


@dataclass
class Programs:
    """Callables + (input SDS, axes) per lowered program."""

    entries: Dict[str, Tuple[Callable, Tuple, Tuple]]  # name -> (fn, sds, axes)


def build_programs(cfg: ModelConfig, shape: InputShape, *, n_tower: int = 2,
                   multi_pod: bool = False) -> Programs:
    dt = _dtype(cfg)
    entries: Dict[str, Tuple[Callable, Tuple, Tuple]] = {}
    force_window = shape.name == "long_500k"

    if shape.kind == "train":
        model = make_hybrid(cfg, n_tower=n_tower)
        p_sds = {k: L.abstract_params(s, dt) for k, s in model.specs().items()}
        p_axes = {k: L.axes_tree(s) for k, s in model.specs().items()}
        b_sds, b_axes = hybrid_train_inputs(cfg, shape)
        if multi_pod:
            # per-group (per-pod) batch: global batch split across G groups
            b_sds = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((s.shape[0] // 2,) + s.shape[1:], s.dtype),
                b_sds, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            )
        s_sds, s_axes = hybrid_stale_inputs(model, cfg, b_sds)

        step = make_hsgd_train_step(model)
        exch = make_exchange_step(model)
        agg = make_global_agg()

        if multi_pod:
            G = 2

            def stack(tree, axes_tree_, lead):
                sds = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((G,) + s.shape, s.dtype), tree,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
                )
                axes = jax.tree.map(
                    lambda a: (lead,) + tuple(a), axes_tree_,
                    is_leaf=lambda a: isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a),
                )
                return sds, axes

            p_sds, p_axes = stack(p_sds, p_axes, "pod_group")
            s_sds, s_axes = stack(s_sds, s_axes, "pod_group")
            b_sds, b_axes = stack(b_sds, b_axes, "pod_group")  # already per-group batch
            entries["train_step"] = (jax.vmap(step), (p_sds, s_sds, b_sds), (p_axes, s_axes, b_axes))
            entries["exchange"] = (jax.vmap(exch), (p_sds, b_sds), (p_axes, b_axes))
            entries["global_agg"] = (agg, (p_sds,), (p_axes,))
        else:
            entries["train_step"] = (step, (p_sds, s_sds, b_sds), (p_axes, s_axes, b_axes))
            entries["exchange"] = (exch, (p_sds, b_sds), (p_axes, b_axes))
            # single-pod global agg: degenerate (one group) — still lowered for
            # completeness with a leading dim of 1
            g_sds = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((1,) + s.shape, s.dtype), p_sds,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            )
            g_axes = jax.tree.map(
                lambda a: (None,) + tuple(a), p_axes,
                is_leaf=lambda a: isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a),
            )
            entries["global_agg"] = (agg, (g_sds,), (g_axes,))
        return Programs(entries)

    # inference shapes: plain architecture
    p_sds = L.abstract_params(T.model_specs(cfg), dt)
    p_axes = L.axes_tree(T.model_specs(cfg))
    b_sds, b_axes = inference_inputs(cfg, shape, force_window)
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg)
    else:
        fn = make_decode_step(cfg, force_window)
    if multi_pod:
        # inference scale-out across pods: batch sharded over pod too
        b_axes = jax.tree.map(
            lambda a: tuple(("pod_batch" if x == "batch" else x) for x in a), b_axes,
            is_leaf=lambda a: isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a),
        )
    entries["serve_step"] = (fn, (p_sds, b_sds), (p_axes, b_axes))
    return Programs(entries)
