"""Step builders + input specs for every (architecture × input shape), and
the LLM-scale compiled federated runner built from them.

Three lowered programs per training shape (their roofline terms combine as
  cost/step = train_step + (1/Q)·exchange_step + (1/P)·global_agg
— exactly the paper's C(P,Q) decomposition):

  * ``hsgd_train_step``  — one HSGD iteration (eqs. 5–7): hospital update with
    fresh ζ1/stale ζ2, device update with stale θ0/ζ1. Runs every step, no
    cross-tier communication beyond the within-group batch reduce.
  * ``exchange_step``    — recompute + exchange ζ1, ζ2 and snapshot θ0
    (fired every Q steps; optionally top-k compressed).
  * ``global_agg``       — eq. (2) across groups (pods), fired every P steps.

Inference shapes lower the plain architecture (federation is a training
construct): ``prefill_step`` and ``decode_step``.

TPU adaptation of tier-1 (documented in DESIGN §2): the within-group device
aggregation (eq. 1) is realized by the batch-mean over the data axis that the
gradient computation already performs — on a pod this reduction is the
standard within-replica gradient sync, so Q amortizes the *vertical exchange*
while P amortizes the *cross-pod model sync*.

``LLMRoundRunner`` assembles those three programs into ONE donating, jitted,
scan-based executor per (P, Q, k, b) bucket — the LLM-scale mirror of
``core/hsgd.HSGDRunner.round_fn`` — and ``AdaptiveLLMRunner`` drives the §VI
plan/probe/governor loop (``core/controller.ControllerCore``) over those
compiled rounds, closing the adaptive loop on the ``llm_hybrid`` path.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import FederationConfig, InputShape, ModelConfig
from repro.common.sharding import DEFAULT_RULES, divisible_spec, logical_to_spec
from repro.common.pytree import tree_dot, tree_norm, tree_size, tree_sub
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.split_model import HybridModel, llm_hybrid

VIS_PATCHES = 1024  # stubbed vision patches prepended for the VLM arch

# long_500k needs sub-quadratic attention: run only where that holds.
LONG_CTX_OK = {"gemma3-1b", "gemma3-4b", "zamba2-2.7b", "falcon-mamba-7b"}


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------


def build_shardings(shapes_tree, axes_tree, mesh: Mesh, rules=None):
    """ShapeDtypeStruct tree + logical-axes tree -> NamedSharding tree."""
    rules = rules or DEFAULT_RULES

    def one(sds, axes):
        if axes is None:
            return NamedSharding(mesh, P())
        spec = logical_to_spec(axes, rules, mesh)
        spec = divisible_spec(sds.shape, spec, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(
        one, shapes_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------


def hybrid_train_inputs(cfg: ModelConfig, shape: InputShape):
    """ShapeDtypeStructs + logical axes for the HSGD training batch."""
    B, S = shape.global_batch, shape.seq_len
    dt = _dtype(cfg)
    tok_axes = ("batch", "seq")
    emb_axes = ("batch", "seq", None)
    if cfg.family == "vlm":
        pv = VIS_PATCHES
        sds = {
            "x1": jax.ShapeDtypeStruct((B, pv, cfg.d_model), dt),
            "x2": jax.ShapeDtypeStruct((B, S - pv), jnp.int32),
            "y": jax.ShapeDtypeStruct((B, S - pv), jnp.int32),
        }
        axes = {"x1": emb_axes, "x2": tok_axes, "y": tok_axes}
    elif cfg.family == "audio":
        sds = {
            "x1": jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt),
            "x2": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "y": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        axes = {"x1": emb_axes, "x2": tok_axes, "y": tok_axes}
    else:
        s1 = S // 2
        sds = {
            "x1": jax.ShapeDtypeStruct((B, s1), jnp.int32),
            "x2": jax.ShapeDtypeStruct((B, S - s1), jnp.int32),
            "y": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        axes = {"x1": tok_axes, "x2": tok_axes, "y": tok_axes}
    return sds, axes


def hybrid_stale_inputs(model: HybridModel, cfg: ModelConfig, batch_sds):
    """Shapes of the stale exchange context (ζ1, ζ2, θ0 snapshot)."""
    dt = _dtype(cfg)
    t1 = L.abstract_params(model.specs1, dt)
    t2 = L.abstract_params(model.specs2, dt)
    z1 = jax.eval_shape(model.h1, t1, batch_sds["x1"])
    z2 = jax.eval_shape(model.h2, t2, batch_sds["x2"])
    t0 = L.abstract_params(model.specs0, dt)
    sds = {"theta0": t0, "z1": z1, "z2": z2}
    axes = {
        "theta0": L.axes_tree(model.specs0),
        "z1": ("batch", "seq", None),
        "z2": ("batch", "seq", None),
    }
    return sds, axes


def inference_inputs(cfg: ModelConfig, shape: InputShape, force_window: bool):
    """(prefill | decode) inputs for the plain architecture."""
    B, S = shape.global_batch, shape.seq_len
    dt = _dtype(cfg)
    if shape.kind == "prefill":
        sds: Dict[str, Any] = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        axes: Dict[str, Any] = {"tokens": ("batch", "seq")}
        if cfg.family == "vlm":
            sds["tokens"] = jax.ShapeDtypeStruct((B, S - VIS_PATCHES), jnp.int32)
            sds["extra_embeds"] = jax.ShapeDtypeStruct((B, VIS_PATCHES, cfg.d_model), dt)
            axes["extra_embeds"] = ("batch", "seq", None)
        elif cfg.family == "audio":
            sds["extra_embeds"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt)
            axes["extra_embeds"] = ("batch", "seq", None)
        return sds, axes
    # decode: one token + caches
    cache_len = S
    if force_window and cfg.sliding_window:
        cache_len = min(S, cfg.sliding_window)
    cache_sds, cache_axes = T.make_decode_caches(cfg, B, cache_len, dt)
    sds = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32), "caches": cache_sds}
    axes = {"tokens": ("batch", None), "caches": cache_axes}
    return sds, axes


# ---------------------------------------------------------------------------
# HSGD step builders (training shapes)
# ---------------------------------------------------------------------------


def make_hybrid(cfg: ModelConfig, n_tower: int = 2, remat: bool = True) -> HybridModel:
    return llm_hybrid(cfg, n_tower=n_tower, remat=remat)


def hybrid_grads(model: HybridModel, params, stale, batch):
    """The eqs. (5)–(7) gradients for one worker: hospital (θ0, θ1) with fresh
    ζ1/stale ζ2, device θ2 with stale θ0/ζ1. Shared by the plain train step
    and the probe-collecting stats step."""

    def hosp_loss(t0, t1):
        z1 = model.h1(t1, batch["x1"])
        return model.loss(t0, z1, jax.lax.stop_gradient(stale["z2"]), batch["y"])

    loss, (g0, g1) = jax.value_and_grad(hosp_loss, argnums=(0, 1))(
        params["theta0"], params["theta1"]
    )

    def dev_loss(t2):
        z2 = model.h2(t2, batch["x2"])
        return model.loss(
            jax.lax.stop_gradient(stale["theta0"]),
            jax.lax.stop_gradient(stale["z1"]),
            z2,
            batch["y"],
        )

    g2 = jax.grad(dev_loss)(params["theta2"])
    return loss, {"theta0": g0, "theta1": g1, "theta2": g2}


def _apply_update(params, grads, lr):
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)


def make_hsgd_train_step(model: HybridModel, lr: float = 1e-3) -> Callable:
    """step(params, stale, batch, lr=lr) — ``lr`` may be a traced scalar, so
    the adaptive runner re-picks η without recompiling."""

    def step(params, stale, batch, lr=lr):
        loss, grads = hybrid_grads(model, params, stale, batch)
        return _apply_update(params, grads, lr), loss

    return step


def make_hsgd_step_stats(model: HybridModel, n_shards: int = 2) -> Callable:
    """Probe-collecting twin of ``make_hsgd_train_step`` (the LLM-path
    analogue of ``core/hsgd.local_sgd_step_stats``).

    The mini-batch is split into ``n_shards`` equal worker shards along the
    batch axis; each shard's eqs. (5)–(7) gradients are computed and averaged,
    which IS the full-batch gradient (the losses are example means), so the
    parameter update is unchanged while the per-shard spread yields the §VI-B
    δ² estimate for free. Returns (new_params, loss, {gbar, gnorm2, delta2}).
    """

    def step(params, stale, batch, lr):
        B = batch["y"].shape[0]
        if n_shards > 1 and B % n_shards:
            # a silent 1-shard fallback would make δ² identically zero and
            # the controller would stop adapting to gradient noise unnoticed
            raise ValueError(
                f"probe-collecting step needs batch size divisible by "
                f"n_shards={n_shards}, got {B}")
        ns = n_shards
        split = lambda x: x.reshape((ns, x.shape[0] // ns) + x.shape[1:])

        def shard_grads(z1_s, z2_s, batch_s):
            stale_s = {"theta0": stale["theta0"], "z1": z1_s, "z2": z2_s}
            return hybrid_grads(model, params, stale_s, batch_s)

        losses, g = jax.vmap(shard_grads)(
            split(stale["z1"]), split(stale["z2"]), jax.tree.map(split, batch))
        gbar = jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32), axis=0), g)
        dev = jax.tree.map(
            lambda x, m: jnp.sum((x.astype(jnp.float32) - m[None]) ** 2,
                                 axis=tuple(range(1, x.ndim))), g, gbar)
        delta2 = jnp.mean(sum(jax.tree_util.tree_leaves(dev)))
        new = _apply_update(params, gbar, lr)
        aux = {"gbar": gbar, "gnorm2": tree_dot(gbar, gbar), "delta2": delta2}
        return new, jnp.mean(losses), aux

    return step


def make_exchange_step(model: HybridModel, compression_k: float = 0.0, quant: int = 0,
                       dp: bool = False) -> Callable:
    """ζ1/ζ2 recompute + θ0 snapshot — the C-HSGD wire message.

    The WHOLE {θ0, ζ1, ζ2} message is compressed in one ``compress_pytree``
    call, matching ``core/hsgd.exchange`` and the ``comm_model.message_sizes``
    byte accounting (which bills θ0 as compressed). A previous version
    compressed only ζ1/ζ2 and transmitted θ0 dense, silently diverging from
    the eq. (19) bill on the LLM path.

    ``dp=True`` (a Python-level gate — the plain trace is unchanged) turns on
    the fused per-row L2-clip + Gaussian-noise stage inside the same kernel
    call; the step then takes traced ``dp_clip``/``dp_sigma`` scalars and a
    ``dp_key`` for the precomputed noise rows.
    """

    def exchange(params, batch, dp_clip=None, dp_sigma=None, dp_key=None):
        z1 = model.h1(params["theta1"], batch["x1"])
        z2 = model.h2(params["theta2"], batch["x2"])
        msg = {"theta0": params["theta0"], "z1": z1, "z2": z2}
        if compression_k or quant or dp:
            from repro.kernels.compress import compress_pytree

            msg = compress_pytree(msg, compression_k or 1.0, quant,
                                  dp_clip=dp_clip if dp else None,
                                  dp_sigma=dp_sigma if dp else None,
                                  dp_key=dp_key if dp else None)
        return msg

    return exchange


def make_global_agg() -> Callable:
    """Eq. (2) over the leading group (pod) dim: mean + broadcast back.

    ``pod_weights`` (optional traced [G]) makes it the weighted eq. (2) —
    the pod-scale hook for the population layer's semi-async aggregation,
    where a late pod group's update is applied with a staleness-damped
    weight instead of blocking the round. None keeps the equal-weight mean,
    and since the weights are traced, varying them never recompiles.
    """

    def agg(params, pod_weights=None):
        if pod_weights is None:
            def m(x):
                g = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True).astype(x.dtype)
                return jnp.broadcast_to(g, x.shape)

            return jax.tree.map(m, params)
        w = pod_weights.astype(jnp.float32)
        w = w / jnp.sum(w)

        def m(x):
            wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
            g = jnp.sum(x.astype(jnp.float32) * wb, axis=0, keepdims=True).astype(x.dtype)
            return jnp.broadcast_to(g, x.shape)

        return jax.tree.map(m, params)

    return agg


# ---------------------------------------------------------------------------
# Plain (non-federated) steps
# ---------------------------------------------------------------------------


def make_plain_train_step(cfg: ModelConfig, lr: float = 1e-3, force_window=False) -> Callable:
    """Baseline sync-DP training step (beyond-paper comparison point)."""

    def step(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.lm_loss(cfg, p, batch, remat=True, force_window=force_window)
        )(params)
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new, loss

    return step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def step(params, batch):
        hidden, _ = T.forward(
            cfg, params, batch["tokens"], extra_embeds=batch.get("extra_embeds"), remat=True
        )
        logits = T.logits_from_hidden(cfg, params, hidden[:, -1:])
        return logits

    return step


def make_decode_step(cfg: ModelConfig, force_window: bool = False) -> Callable:
    from repro.common.sharding import weight_mode

    def step(params, batch):
        index = jnp.asarray(batch_index_default(batch), jnp.int32)
        with weight_mode("fsdp"):  # decode: weights stay sharded (§Perf it. 2)
            logits, new_caches = T.decode_step(
                cfg, params, batch["tokens"], batch["caches"], index, force_window=force_window
            )
        return logits, new_caches

    return step


def batch_index_default(batch):
    """Decode write position: mid-cache (static for the dry-run)."""
    caches = batch["caches"]
    leaves = jax.tree_util.tree_leaves(caches)
    # cache length lives on axis 2 of stacked kv ([L, B, S, ...]) or ssm state
    for leaf in leaves:
        if leaf.ndim >= 3:
            return leaf.shape[2] // 2
    return 0


# ---------------------------------------------------------------------------
# Assembled program set per (arch, shape)
# ---------------------------------------------------------------------------


@dataclass
class Programs:
    """Callables + (input SDS, axes) per lowered program."""

    entries: Dict[str, Tuple[Callable, Tuple, Tuple]]  # name -> (fn, sds, axes)


def build_programs(cfg: ModelConfig, shape: InputShape, *, n_tower: int = 2,
                   multi_pod: bool = False) -> Programs:
    dt = _dtype(cfg)
    entries: Dict[str, Tuple[Callable, Tuple, Tuple]] = {}
    force_window = shape.name == "long_500k"

    if shape.kind == "train":
        model = make_hybrid(cfg, n_tower=n_tower)
        p_sds = {k: L.abstract_params(s, dt) for k, s in model.specs().items()}
        p_axes = {k: L.axes_tree(s) for k, s in model.specs().items()}
        b_sds, b_axes = hybrid_train_inputs(cfg, shape)
        if multi_pod:
            # per-group (per-pod) batch: global batch split across G groups
            b_sds = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((s.shape[0] // 2,) + s.shape[1:], s.dtype),
                b_sds, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            )
        s_sds, s_axes = hybrid_stale_inputs(model, cfg, b_sds)

        step = make_hsgd_train_step(model)
        exch = make_exchange_step(model)
        agg = make_global_agg()

        if multi_pod:
            G = 2

            def stack(tree, axes_tree_, lead):
                sds = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((G,) + s.shape, s.dtype), tree,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
                )
                axes = jax.tree.map(
                    lambda a: (lead,) + tuple(a), axes_tree_,
                    is_leaf=lambda a: isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a),
                )
                return sds, axes

            p_sds, p_axes = stack(p_sds, p_axes, "pod_group")
            s_sds, s_axes = stack(s_sds, s_axes, "pod_group")
            b_sds, b_axes = stack(b_sds, b_axes, "pod_group")  # already per-group batch
            entries["train_step"] = (jax.vmap(step), (p_sds, s_sds, b_sds), (p_axes, s_axes, b_axes))
            entries["exchange"] = (jax.vmap(exch), (p_sds, b_sds), (p_axes, b_axes))
            entries["global_agg"] = (agg, (p_sds,), (p_axes,))
        else:
            entries["train_step"] = (step, (p_sds, s_sds, b_sds), (p_axes, s_axes, b_axes))
            entries["exchange"] = (exch, (p_sds, b_sds), (p_axes, b_axes))
            # single-pod global agg: degenerate (one group) — still lowered for
            # completeness with a leading dim of 1
            g_sds = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((1,) + s.shape, s.dtype), p_sds,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            )
            g_axes = jax.tree.map(
                lambda a: (None,) + tuple(a), p_axes,
                is_leaf=lambda a: isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a),
            )
            entries["global_agg"] = (agg, (g_sds,), (g_axes,))
        return Programs(entries)

    # inference shapes: plain architecture
    p_sds = L.abstract_params(T.model_specs(cfg), dt)
    p_axes = L.axes_tree(T.model_specs(cfg))
    b_sds, b_axes = inference_inputs(cfg, shape, force_window)
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg)
    else:
        fn = make_decode_step(cfg, force_window)
    if multi_pod:
        # inference scale-out across pods: batch sharded over pod too
        b_axes = jax.tree.map(
            lambda a: tuple(("pod_batch" if x == "batch" else x) for x in a), b_axes,
            is_leaf=lambda a: isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a),
        )
    entries["serve_step"] = (fn, (p_sds, b_sds), (p_axes, b_axes))
    return Programs(entries)


# ---------------------------------------------------------------------------
# LLM-scale compiled federated rounds
# ---------------------------------------------------------------------------


def init_llm_params(key, model: HybridModel, n_pods: int = 1, dtype=jnp.float32):
    """Alg. 1 line 1 at pod scale: every pod group starts from one global
    model. Leaves carry a leading [G] pod axis (G = 1 collapses to the
    single-group path at negligible vmap cost)."""
    params = model.init(key, dtype)
    return jax.tree.map(lambda x: jnp.stack([x] * n_pods), params)


def global_llm_params(params):
    """Collapse the pod axis to the observable global model (eq. (2), equal
    pod weights) — the flat {θ0, θ1, θ2} layout that checkpoints store and
    that ``model.h1/h2/loss`` and the serve-step specs consume."""
    return jax.tree.map(
        lambda x: jnp.mean(x.astype(jnp.float32), axis=0).astype(x.dtype),
        params)


@dataclass(frozen=True)
class LLMRoundRunner:
    """Compiled HSGD rounds over the ``llm_hybrid`` program set.

    One global round = [global_agg across pod groups] + Λ × [exchange +
    Q × hsgd_train_step], staged exactly like ``HSGDRunner._round_impl``:
    ``round_fn(P, Q, k, b)`` compiles ONE donating jitted scan executor per
    bucket (cached on the runner), η rides through as a traced scalar so the
    adaptive controller re-picks it for free, and the exchange compresses the
    whole {θ0, ζ1, ζ2} message in one fused ``compress_pytree`` call.

    Params carry a leading [G] pod axis (``init_llm_params``); per-round
    batches carry [Λ, G, ...] — one fresh token-stream batch per exchange
    interval per pod, so every exchange resamples instead of training on a
    frozen batch.
    """

    model: HybridModel
    n_pods: int = 1
    n_shards: int = 2  # δ²-probe worker shards per pod (stats rounds)
    # (P, Q, k, b, collect) bucket -> compiled round executor
    _round_cache: Dict = field(default_factory=dict, compare=False, repr=False)

    def _round_impl(self, params, batches, eta, Q: int, lam: int,
                    compression_k: float, quant_levels: int, collect: bool,
                    pod_weights=None, dp_clip=None, dp_sigma=None, dp_key=None):
        model = self.model
        if self.n_pods > 1:
            # eq. (2) across pod groups; pod_weights = the population layer's
            # staleness-damped semi-async weights (None = synchronous mean)
            params = make_global_agg()(params, pod_weights)
        dp = dp_key is not None
        if dp:
            # per-interval, per-pod noise keys folded off the threaded round
            # key — deterministic, and fresh normals every exchange
            exch_dp = jax.vmap(
                make_exchange_step(model, compression_k, quant_levels, dp=True),
                in_axes=(0, 0, None, None, 0))
            ikeys = jax.vmap(lambda i: jax.random.fold_in(dp_key, i))(
                jnp.arange(lam))
            xs = (batches, ikeys)
            batch_of = lambda xs_i: xs_i[0]
            stale_of = lambda params, xs_i: exch_dp(
                params, xs_i[0], dp_clip, dp_sigma,
                jax.random.split(xs_i[1], self.n_pods))
        else:
            exch = jax.vmap(make_exchange_step(model, compression_k, quant_levels))
            xs = batches
            batch_of = lambda xs_i: xs_i
            stale_of = lambda params, xs_i: exch(params, xs_i)

        if not collect:
            step = jax.vmap(make_hsgd_train_step(model), in_axes=(0, 0, 0, None))

            def interval(params, xs_i):
                batch_i = batch_of(xs_i)
                stale = stale_of(params, xs_i)

                def sgd_step(params, _):
                    params, losses = step(params, stale, batch_i, eta)
                    return params, jnp.mean(losses)

                return jax.lax.scan(sgd_step, params, None, length=Q)

            params, losses = jax.lax.scan(interval, params, xs, length=lam)
            return params, losses.reshape(-1)

        stepf = jax.vmap(make_hsgd_step_stats(model, self.n_shards),
                         in_axes=(0, 0, 0, None))
        # template for the previous step's global-gradient proxy (fp32, one
        # model copy — the per-pod gbar mean)
        zeros_g = jax.tree.map(lambda x: jnp.zeros(x.shape[1:], jnp.float32), params)

        def interval(params, xs_i):
            batch_i = batch_of(xs_i)
            stale = stale_of(params, xs_i)

            def sgd_step(carry, _):
                params, prev_g, prev_ok = carry
                params, loss_pods, aux = stepf(params, stale, batch_i, eta)
                gbar = jax.tree.map(lambda x: jnp.mean(x, axis=0), aux["gbar"])
                # law of total variance: worker spread = within-pod shard
                # spread + pod-mean spread around the global mean
                pod_dev = jax.tree.map(
                    lambda x, m: jnp.sum((x - m[None]) ** 2,
                                         axis=tuple(range(1, x.ndim))),
                    aux["gbar"], gbar)
                delta2 = jnp.mean(aux["delta2"]) + jnp.mean(
                    sum(jax.tree_util.tree_leaves(pod_dev)))
                diff = tree_norm(tree_sub(gbar, prev_g))
                den = eta * tree_norm(prev_g)
                rho = jnp.where(prev_ok > 0.5, diff / jnp.maximum(den, 1e-12), 0.0)
                stats = {"loss": jnp.mean(loss_pods),
                         "gnorm2": tree_dot(gbar, gbar),
                         "delta2": delta2, "rho": rho, "rho_ok": prev_ok}
                return (params, gbar, jnp.ones((), jnp.float32)), stats

            (params, _, _), stats = jax.lax.scan(
                sgd_step, (params, zeros_g, jnp.zeros((), jnp.float32)),
                None, length=Q)
            return params, stats

        params, stats = jax.lax.scan(interval, params, xs, length=lam)
        stats = jax.tree.map(lambda x: x.reshape(-1), stats)  # [Λ, Q] -> [P]
        return params, stats

    def round_fn(self, P: int, Q: int, compression_k: float = 0.0,
                 quant_levels: int = 0, collect_stats: bool = True,
                 dp: bool = False):
        """Compiled single-round executor for a (P, Q, k, b) bucket.

        fn(params, batches, eta, pod_weights=None) -> (params, stats|losses).
        ``batches`` leaves lead with [Λ = P/Q, G, ...]; ``params`` is donated;
        ``eta`` and ``pod_weights`` (the semi-async staleness weights, when
        given) are traced. Cached per bucket — a run whose cadence varies
        round-to-round pays one compile per distinct bucket, not one per
        round.

        ``dp`` adds exactly one enable bit to the cache key; the executor then
        takes traced (dp_clip, dp_sigma, dp_key) after ``eta`` — re-picking σ
        or re-keying the round noise never recompiles (traced-η discipline).
        """
        if P < 1 or Q < 1 or P % Q:
            raise ValueError(f"P={P} must be a positive multiple of Q={Q}")
        key = (P, Q, compression_k, quant_levels, collect_stats)
        if dp:
            key = key + (True,)
        fn = self._round_cache.get(key)
        if fn is None:
            lam = P // Q

            if dp:
                # name keeps the llm_round prefix so compile_guard budgets
                # tracking r"llm_round" attribute this executor too
                @functools.partial(jax.jit, donate_argnums=(0,))
                def llm_round_dp(params, batches, eta, dp_clip, dp_sigma,
                                 dp_key, pod_weights=None):
                    return self._round_impl(params, batches, eta, Q, lam,
                                            compression_k, quant_levels,
                                            collect_stats, pod_weights,
                                            dp_clip=dp_clip, dp_sigma=dp_sigma,
                                            dp_key=dp_key)

                fn = self._round_cache[key] = llm_round_dp
                return fn

            # named so compile_guard can attribute compiles per executor
            @functools.partial(jax.jit, donate_argnums=(0,))
            def llm_round(params, batches, eta, pod_weights=None):
                return self._round_impl(params, batches, eta, Q, lam,
                                        compression_k, quant_levels,
                                        collect_stats, pod_weights)

            fn = self._round_cache[key] = llm_round
        return fn

    def run_fixed(self, params, batch_fn, steps: int, P: int, Q: int, lr: float,
                  compression_k: float = 0.0, quant_levels: int = 0):
        """Fixed-cadence driver (the pre-§VI baseline): exchange every Q,
        global agg every P, for ``steps / P`` whole compiled rounds.

        ``steps`` must be a positive multiple of P — rounds are compiled
        whole, and silently training more or fewer steps than asked would
        desynchronize trajectories, byte bills, and checkpoints (same
        no-silent-flooring rule as ``FederationConfig``). Callers with a free
        step budget round it themselves (see ``launch/train.py::run_llm``)."""
        if steps < P or steps % P:
            raise ValueError(
                f"steps={steps} must be a positive multiple of P={P} "
                f"(whole compiled rounds; round your budget explicitly)")
        fn = self.round_fn(P, Q, compression_k, quant_levels, collect_stats=False)
        losses = []
        for r in range(steps // P):
            params, l = fn(params, batch_fn(r, P // Q), lr)
            losses.append(np.asarray(jax.device_get(l)))
        return params, np.concatenate(losses)


class AdaptiveLLMRunner:
    """Closed-loop §VI controller over ``LLMRoundRunner`` — the same
    plan/probe/governor loop as ``core/controller.AdaptiveHSGDRunner``,
    rebased onto the LLM-scale state representation.

    * probes come from the LLM step's own gradients
      (``make_hsgd_step_stats``: δ² from per-shard/per-pod gradient spread,
      ‖∇F‖² from the pod-mean gradient, ρ from within-interval secants);
    * ``message_sizes`` is built from the ``llm_hybrid`` specs and the live
      ζ1/ζ2 token-stream shapes (``eval_shape`` on the actual batch);
    * the byte governor walks the same ``COMPRESSION_LADDER`` ratchet.
    """

    def __init__(self, model: HybridModel, cfg=None, n_pods: int = 1,
                 learning_rate: float = 1e-3, n_shards: int = 2):
        from repro.core.controller import AdaptiveConfig

        self.model = model
        self.cfg = cfg or AdaptiveConfig()
        self.n_pods = n_pods
        self.lr0 = learning_rate
        self.runner = LLMRoundRunner(model, n_pods=n_pods, n_shards=n_shards)
        # eq. (19) view of the pod topology: each pod group is one
        # hospital-device pair exchanging over the modeled links
        self.fed = FederationConfig(num_groups=n_pods, devices_per_group=1,
                                    alpha=1.0)

    def _sizes_of(self, params, batch):
        """``sizes_of(k, b)`` governor callback; ζ1/ζ2 element counts read off
        the live token-stream shapes via ``eval_shape`` (zero FLOPs)."""
        from repro.core import comm_model as CM

        pod_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), params)
        b_pod = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[2:], x.dtype), batch)
        z1 = jax.eval_shape(self.model.h1, pod_sds["theta1"], b_pod["x1"])
        z2 = jax.eval_shape(self.model.h2, pod_sds["theta2"], b_pod["x2"])
        z1_el, z2_el = tree_size(z1), tree_size(z2)

        def sizes_of(k_frac: float, levels: int):
            return CM.message_sizes(pod_sds, z1_el, z2_el,
                                    self.fed.sampled_devices, k_frac, levels)

        return sizes_of

    def _seed_probe(self, params, batches):
        """§VI-B pre-training probe, LLM-path flavour: two stats steps on one
        sampled stream (same batch ⇒ a clean ρ secant) yield the initial
        {ρ, δ, F0, ‖∇F‖²}. Compiled OUTSIDE the round cache and WITHOUT
        donation, so no training state is consumed and the one-executor-per-
        executed-bucket contract is untouched. ``cfg.probe_batch`` does not
        apply here — the probe batch is whatever ``batch_fn`` samples."""
        from repro.core.controller import probe_from_stats

        def llm_probe_round(p, b, eta):
            return self.runner._round_impl(p, b, eta, 2, 1, 0.0, 0, True)

        fn = jax.jit(llm_probe_round)
        _, stats = fn(params, batches, self.lr0)
        return probe_from_stats(jax.device_get(stats), Q=2)

    def run(self, params, batch_fn, probe=None):
        """Drive ``cfg.total_steps`` iterations adaptively.

        ``params`` is the pod-stacked pytree from ``init_llm_params`` (donated
        round-by-round — rebind the return value). ``batch_fn(round_idx, lam)``
        must return a fresh batch pytree with leading [Λ, G, ...] axes; it is
        called once per round plus once up front for shape inference and (with
        ``cfg.init_probe``) the seed probe, so it should be cheap and
        stateless-ish (a seeded sampler). Returns (params, per-step losses,
        per-round history).
        """
        from repro.core.controller import ControllerCore

        peek = batch_fn(0, 1)
        sizes_of = self._sizes_of(params, peek)
        if probe is None and self.cfg.init_probe:
            probe = self._seed_probe(params, peek)
        core = ControllerCore(self.cfg, self.fed, sizes_of, eta0=self.lr0,
                              probe=probe)
        losses = []
        while not core.done:
            plan, (k_frac, levels) = core.plan()
            batches = batch_fn(len(core.history), plan.P // plan.Q)
            fn = self.runner.round_fn(plan.P, plan.Q, k_frac, levels,
                                      collect_stats=True)
            params, stats = fn(params, batches, plan.eta)
            stats = jax.device_get(stats)
            losses.append(np.asarray(stats["loss"]))
            core.record(plan, stats)
        return params, np.concatenate(losses), core.history
