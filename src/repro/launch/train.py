"""Training launcher — the end-to-end driver for the HSGD federation.

Two modes:
  * e-health simulation (paper reproduction): --model paper-cnn|paper-lstm
    with --dataset organamnist|mimic3|esr, runs Algorithm 1 on the 3-tier
    partitioned synthetic data and reports the paper's metrics.
  * LLM-scale federation: --arch <assigned arch> (reduced via --smoke) runs
    the compiled HSGD rounds (hospital/device towers + combined backbone,
    exchange every Q, pod-group agg every P) on resampled synthetic token
    streams. ``--adaptive`` closes the §VI loop on this path too: the
    controller re-picks P = Q and η every round from the LLM step's own
    gradient probes, and the byte governor ratchets the compression ladder
    until --byte-budget-mb is honored. --pods simulates G pod groups.

Examples:
  PYTHONPATH=src python -m repro.launch.train --model paper-cnn --rounds 50
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke --steps 10
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke \
      --adaptive --steps 16 --byte-budget-mb 8 --max-interval 8
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.common.config import FederationConfig, TrainConfig, get_config
from repro.core import metrics as MET
from repro.core.baselines import make_runner, merge_groups_for_tdcd
from repro.core.controller import (
    AdaptiveConfig,
    AdaptiveHSGDRunner,
    epsilon_of,
    gaussian_rho,
    ladder_from,
)
from repro.core.hsgd import global_model, init_state, make_group_weights
from repro.data.partition import hybrid_partition
from repro.data.synthetic import DATASETS, flatten_for_tower, make_dataset, vertical_split
from repro.models.split_model import cnn_hybrid, llm_hybrid, lstm_hybrid


def make_paper_model(name: str, dataset: str):
    if name == "paper-cnn":
        return cnn_hybrid(h_rows=11, n_classes=DATASETS[dataset].n_classes)
    spec = DATASETS[dataset]
    if spec.name == "esr":
        return lstm_hybrid(n_features=178, hospital_features=89, n_classes=spec.n_classes)
    return lstm_hybrid(n_features=76, hospital_features=36, n_classes=spec.n_classes)


def run_ehealth(args) -> dict:
    spec = DATASETS[args.dataset]
    fed = FederationConfig(
        num_groups=args.groups,
        devices_per_group=args.devices,
        alpha=args.alpha,
        local_interval=args.q,
        global_interval=args.p,
        robust_agg=args.robust_agg,
        trim_frac=args.trim_frac,
    )
    train = TrainConfig(
        learning_rate=args.lr,
        lr_halve_every=args.lr_halve_every,
        compression_k=args.compression_k,
        quantization_bits=args.quantization,
    )
    model = make_paper_model(args.model, args.dataset)
    X, y = make_dataset(spec, args.samples, seed=args.seed)
    fdata = hybrid_partition(spec, X, y, fed, seed=args.seed)
    raw = fdata.stacked()
    algo = args.algorithm
    if algo in ("tdcd", "c-tdcd"):
        raw = merge_groups_for_tdcd(raw)
    data = {k: jnp.asarray(v) for k, v in raw.items()}
    w = make_group_weights(data)

    dp = args.dp_clip > 0.0 and args.dp_sigma > 0.0
    private = dp or args.dp_clip > 0.0 or args.secure_agg
    if private and algo not in ("hsgd", "c-hsgd"):
        raise SystemExit(
            f"--dp-clip/--dp-sigma/--secure-agg drive the HSGD exchange; "
            f"got --algorithm {algo}")

    if args.population:
        if algo != "hsgd":
            raise SystemExit(
                f"--population drives the HSGD cohort loop; got --algorithm {algo}")
        if private:
            raise SystemExit(
                "--population does not combine with the privacy flags yet; "
                "use the fixed-interval or --adaptive e-health path")
        return _run_population_cli(args, model, fed, train, data)

    runner, eff_fed = make_runner(algo, model, fed, train)
    key = jax.random.PRNGKey(args.seed)
    if algo == "jfl":
        state = runner.init(key)
    else:
        state = init_state(key, model, eff_fed, data)

    history = None
    t0 = time.time()
    if args.adaptive:
        if algo not in ("hsgd", "c-hsgd"):
            raise SystemExit(f"--adaptive drives the HSGD loop; got --algorithm {algo}")
        eff_train = runner.train  # c-hsgd defaults (k=0.25, b=128) applied
        acfg = AdaptiveConfig(
            total_steps=args.rounds * fed.global_interval,
            target_bound=args.target_bound,
            byte_budget=args.byte_budget_mb * 1e6,
            max_interval=args.max_interval,
            eta_max=max(args.lr * 10, 0.05),
            # explicit --compression-k/--quantization (or c-hsgd defaults)
            # become the governor's rung 0 — never silently loosened
            ladder=ladder_from(eff_train.compression_k, eff_train.quantization_bits),
            privacy_budget=args.epsilon,
            privacy_delta=args.delta,
            dp_clip=args.dp_clip,
            dp_sigma=args.dp_sigma,
            secure_agg=args.secure_agg,
        )
        controller = AdaptiveHSGDRunner(model, fed, eff_train, acfg)
        state, losses, history = controller.run(
            state, data, w, probe_key=jax.random.PRNGKey(args.seed + 1))
        runner = controller.runner  # executor-cache accounting reads this
        for h in history:
            eps = (f" σ={h['dp_sigma']:.3g} ε={h['epsilon_total']:.3g}"
                   if h.get("dp_sigma") else "")
            print(f"[adaptive] round {h['round']:3d}: P=Q={h['P']:3d} "
                  f"eta={h['eta']:.4g} rung={h['rung']} Γ={h['gamma']:.3g} "
                  f"bytes={h['bytes_total'] / 1e6:.2f}MB "
                  f"loss={h['loss_last']:.4f}{eps}")
    elif private:
        state, losses = runner.run_private(
            state, data, w, rounds=args.rounds, seed=args.seed,
            dp_clip=args.dp_clip, dp_sigma=args.dp_sigma,
            secure_agg=args.secure_agg)
    else:
        state, losses = runner.run(state, data, w, rounds=args.rounds)
    dt = time.time() - t0
    gm = runner.global_model(state, w) if algo == "jfl" else global_model(state, w)

    X1, X2 = vertical_split(spec, X)
    m = MET.evaluate_global(
        model, gm, flatten_for_tower(spec, X1), flatten_for_tower(spec, X2), y
    )
    m["train_loss_final"] = float(losses[-1]) if len(losses) else float("nan")
    m["steps"] = int(len(losses))
    m["wall_s"] = round(dt, 2)
    if history is not None:
        m["adaptive_rounds"] = len(history)
        m["adaptive_bytes_total"] = history[-1]["bytes_total"]
        m["adaptive_final_PQ"] = history[-1]["P"]
        if dp and history:
            m["epsilon"] = history[-1]["epsilon_total"]
            m["delta"] = args.delta
    elif dp:
        # fixed-interval ledger: one Gaussian release per exchange, Λ = P/Q
        # exchanges per round (zCDP composition, same math as the controller)
        releases = args.rounds * eff_fed.lam
        m["epsilon"] = epsilon_of(releases * gaussian_rho(args.dp_sigma),
                                  args.delta)
        m["delta"] = args.delta
    if private:
        m["secure_agg"] = bool(args.secure_agg)
        m["executors_compiled"] = len(runner._round_cache)
    print(json.dumps(m, indent=1))
    if args.checkpoint:
        save_checkpoint(args.checkpoint, gm, step=len(losses), extra={"metrics": m})
        print(f"checkpoint -> {args.checkpoint}")
    return m


def _fault_plan_of(args):
    """The CLI's FaultPlan, or None when every fault knob is at its default
    (fault-free runs stay on the plain population executors)."""
    from repro.core.faults import FaultPlan

    plan = FaultPlan(
        seed=args.fault_seed if args.fault_seed is not None else args.seed,
        dropout_rate=args.fault_dropout,
        nan_rate=args.fault_nan,
        outlier_rate=args.fault_outlier,
        msg_corrupt_rate=args.fault_msg_corrupt,
        msg_loss_rate=args.fault_msg_loss,
        msg_dup_rate=args.fault_msg_dup,
        latency_spike_rate=args.fault_latency,
        preempt_round=args.preempt_round,
    )
    return None if plan.empty else plan


def _run_population_cli(args, model, fed, train, data) -> dict:
    """Population-scale cohort run (ROADMAP item 1): simulated device fleet,
    per-round cohort sampling, sync / semi-async / adaptive wall-clock modes.
    Any fault/checkpoint/resume flag routes to the resilient runtime."""
    from repro.core.population import (
        PopulationConfig,
        run_population,
        run_population_adaptive,
        run_population_resilient,
    )

    pop = PopulationConfig(
        seed=args.trace_seed if args.trace_seed is not None else args.seed,
        devices_per_group=args.pop_devices,
        target_cohort=args.cohort,
        deadline_quantile=args.deadline_quantile,
        staleness_damping=args.staleness_damping,
        max_staleness=args.max_staleness,
        min_quorum=args.min_quorum,
        max_retries=args.max_retries,
        backoff_factor=args.backoff_factor,
    )
    plan = _fault_plan_of(args)
    resilient = plan is not None or args.ckpt_every > 0 or args.resume
    t0 = time.time()
    if resilient:
        if args.population == "adaptive":
            raise SystemExit(
                "--population adaptive does not combine with fault injection /"
                " checkpoint-resume; use sync or semi_async")
        res = run_population_resilient(
            model, fed, train, data, pop, rounds=args.rounds,
            faults=plan, mode=args.population, robust=not args.no_defense,
            t_compute=args.t_compute, ckpt_dir=args.checkpoint,
            ckpt_every=args.ckpt_every, resume=args.resume,
        )
        fl = res["fault_log"]
        out = {
            "mode": args.population,
            "trace_seed": pop.seed,
            "steps": int(len(res["losses"])),
            "loss_first": float(res["losses"][0]),
            "loss_last": float(res["losses"][-1]),
            "sim_seconds": res["sim_seconds"],
            "recovered": res["recovered"],
            "rollbacks": res["rollbacks"],
            "devices_dropped": int(sum(r["dropped"] for r in fl)),
            "grad_faults": int(sum(r["grad_faulted"] for r in fl)),
            "msg_faults": int(sum(r["msg_faulted"] for r in fl)),
            "updates_flagged": float(sum(r["flagged_updates"] for r in fl)),
            "round_retries": int(sum(r["retries"] for r in fl)),
            "executors_compiled": len(res["runner"]._round_cache),
            "wall_s": round(time.time() - t0, 2),
        }
        print(json.dumps(out, indent=1))
        if args.fault_trace:
            res["injector"].save_trace(args.fault_trace)
            print(f"fault trace -> {args.fault_trace}")
        if args.checkpoint and args.ckpt_every == 0:
            # no periodic cadence: persist the final state the classic way
            save_checkpoint(args.checkpoint, res["state"],
                            step=len(res["losses"]),
                            extra={"sim_seconds": res["sim_seconds"]})
            print(f"checkpoint -> {args.checkpoint}")
        return out
    if args.population == "adaptive":
        acfg = AdaptiveConfig(
            total_steps=args.rounds * fed.global_interval,
            target_bound=args.target_bound,
            byte_budget=args.byte_budget_mb * 1e6,
            time_budget=args.time_budget,
            max_interval=args.max_interval,
            eta_max=max(args.lr * 10, 0.05),
            ladder=ladder_from(args.compression_k, args.quantization),
            init_probe=False,
        )
        res = run_population_adaptive(model, fed, train, data, pop, acfg,
                                      t_compute=args.t_compute)
    else:
        res = run_population(model, fed, train, data, pop, rounds=args.rounds,
                             mode=args.population, t_compute=args.t_compute)
    out = {
        "mode": args.population,
        "trace_seed": pop.seed,
        "steps": int(len(res["losses"])),
        "loss_first": float(res["losses"][0]),
        "loss_last": float(res["losses"][-1]),
        "sim_seconds": res["sim_seconds"],
        "staleness_hist": {str(k): v for k, v in res["staleness_hist"].items()},
        "executors_compiled": len(res["runner"]._round_cache),
        "wall_s": round(time.time() - t0, 2),
    }
    print(json.dumps(out, indent=1))
    if args.checkpoint:
        save_checkpoint(args.checkpoint, res["state"], step=len(res["losses"]),
                        extra={"sim_seconds": res["sim_seconds"]})
        print(f"checkpoint -> {args.checkpoint}")
    return out


def run_llm(args) -> dict:
    """LLM-scale federation on synthetic token streams (compiled rounds).

    The previous hand loop had two bugs this runner retires: the exchange ran
    TWICE at step 0 (once before the loop and again at t % q == 0 with t = 0),
    and the whole run trained on one frozen batch — now every exchange
    interval resamples a fresh stream, inside one donating jitted executor
    per (P, Q, k, b) bucket.
    """
    from repro.core.controller import AdaptiveConfig, ladder_from
    from repro.data.synthetic import llm_batch_fn
    from repro.launch.steps import (
        AdaptiveLLMRunner,
        LLMRoundRunner,
        global_llm_params,
        init_llm_params,
    )

    cfg = get_config(args.arch, smoke=args.smoke)
    model = llm_hybrid(cfg, n_tower=1, remat=False)
    params = init_llm_params(jax.random.PRNGKey(args.seed), model, n_pods=args.pods)
    batch_fn = llm_batch_fn(cfg, args.batch, args.seq, n_pods=args.pods,
                            seed=args.seed)

    t0 = time.time()
    history = None
    if args.adaptive:
        acfg = AdaptiveConfig(
            total_steps=args.steps,
            target_bound=args.target_bound,
            byte_budget=args.byte_budget_mb * 1e6,
            max_interval=args.max_interval,
            eta_max=max(args.lr * 10, 0.05),
            ladder=ladder_from(args.compression_k, args.quantization),
        )
        runner = AdaptiveLLMRunner(model, acfg, n_pods=args.pods,
                                   learning_rate=args.lr)
        params, losses, history = runner.run(params, batch_fn)
        for h in history:
            print(f"[adaptive] round {h['round']:3d}: P=Q={h['P']:3d} "
                  f"eta={h['eta']:.4g} rung={h['rung']} Γ={h['gamma']:.3g} "
                  f"bytes={h['bytes_total'] / 1e6:.2f}MB loss={h['loss_last']:.4f}")
    else:
        steps = max(1, args.steps // args.p) * args.p  # whole compiled rounds
        if steps != args.steps:
            print(f"# rounding --steps {args.steps} -> {steps} (whole P={args.p} rounds)")
        runner = LLMRoundRunner(model, n_pods=args.pods)
        params, losses = runner.run_fixed(
            params, batch_fn, steps=steps, P=args.p, Q=args.q, lr=args.lr,
            compression_k=args.compression_k, quant_levels=args.quantization)
        for t in range(0, len(losses), max(1, len(losses) // 10)):
            print(f"step {t:4d} loss {float(losses[t]):.4f}")

    out = {"arch": args.arch, "pods": args.pods,
           "loss_first": float(losses[0]), "loss_last": float(losses[-1]),
           "steps": int(len(losses)), "wall_s": round(time.time() - t0, 2)}
    if history is not None:
        out["adaptive_rounds"] = len(history)
        out["adaptive_bytes_total"] = history[-1]["bytes_total"]
        out["adaptive_final_PQ"] = history[-1]["P"]
    print(json.dumps(out))
    if args.checkpoint:
        # flat {θ0, θ1, θ2} global model (pod mean) — the pre-PR-3 format
        save_checkpoint(args.checkpoint, global_llm_params(params),
                        step=len(losses))
        print(f"checkpoint -> {args.checkpoint}")
    return out


def _validate_args(ap, args):
    """Fail fast, at the CLI boundary, with an argparse error — not deep in
    a dataclass __post_init__ after data generation and model init."""
    for flag in ("fault_dropout", "fault_nan", "fault_outlier",
                 "fault_msg_corrupt", "fault_msg_loss", "fault_msg_dup",
                 "fault_latency"):
        v = getattr(args, flag)
        if not 0.0 <= v <= 1.0:
            ap.error(f"--{flag.replace('_', '-')} must be in [0, 1], got {v}")
    if args.max_retries < 0:
        ap.error(f"--max-retries must be >= 0, got {args.max_retries}")
    if args.backoff_factor <= 1.0:
        ap.error(f"--backoff-factor must be > 1, got {args.backoff_factor}")
    if not 0.0 <= args.min_quorum <= 1.0:
        ap.error(f"--min-quorum must be in [0, 1], got {args.min_quorum}")
    if not 0.0 <= args.trim_frac < 0.5:
        ap.error(f"--trim-frac must be in [0, 0.5), got {args.trim_frac}")
    if args.preempt_round < -1:
        ap.error(f"--preempt-round must be >= 0 (or -1 = never), "
                 f"got {args.preempt_round}")
    if args.ckpt_every < 0:
        ap.error(f"--ckpt-every must be >= 0, got {args.ckpt_every}")
    if (args.resume or args.ckpt_every > 0) and not args.checkpoint:
        ap.error("--resume/--ckpt-every need --checkpoint <dir> to hold the "
                 "checkpoints")
    if args.dp_clip < 0.0:
        ap.error(f"--dp-clip must be >= 0, got {args.dp_clip}")
    if args.dp_sigma < 0.0:
        ap.error(f"--dp-sigma must be >= 0, got {args.dp_sigma}")
    if args.dp_sigma > 0.0 and args.dp_clip <= 0.0:
        ap.error("--dp-sigma > 0 needs --dp-clip > 0 (noise std is σ·C)")
    if not 0.0 < args.delta < 1.0:
        ap.error(f"--delta must be in (0, 1), got {args.delta}")
    if args.epsilon <= 0.0:
        ap.error(f"--epsilon must be > 0, got {args.epsilon}")
    if (args.dp_clip > 0.0 or args.secure_agg) and args.arch:
        ap.error("the privacy flags drive the e-health HSGD path, not --arch")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default=None, choices=["paper-cnn", "paper-lstm"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--dataset", default="organamnist", choices=list(DATASETS))
    ap.add_argument("--algorithm", default="hsgd",
                    choices=["hsgd", "c-hsgd", "jfl", "tdcd", "c-tdcd", "centralized"])
    ap.add_argument("--groups", type=int, default=10)
    ap.add_argument("--devices", type=int, default=64)
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--samples", type=int, default=2048)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--p", type=int, default=4)
    ap.add_argument("--q", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--lr-halve-every", type=int, default=0)
    ap.add_argument("--compression-k", type=float, default=0.0)
    ap.add_argument("--quantization", type=int, default=0)
    ap.add_argument("--pods", type=int, default=1,
                    help="pod groups G on the LLM path (global agg every P)")
    ap.add_argument("--adaptive", action="store_true",
                    help="closed-loop §VI controller: re-picks P/Q/eta and "
                         "tightens compression online (e-health hsgd/c-hsgd "
                         "and the --arch LLM path)")
    ap.add_argument("--byte-budget-mb", type=float, default=float("inf"),
                    help="modeled comm budget for the whole run, MB (all groups)")
    ap.add_argument("--target-bound", type=float, default=float("inf"),
                    help="Theorem-1 target Ξ the controller keeps Γ(P,Q) under")
    ap.add_argument("--max-interval", type=int, default=32,
                    help="cap on the adaptive P = Q")
    ap.add_argument("--population", default=None,
                    choices=["sync", "semi_async", "adaptive"],
                    help="population-scale cohort run over a simulated device "
                         "fleet: sync (barrier rounds), semi_async (deadline "
                         "quantile + staleness-damped late updates), or "
                         "adaptive (semi_async + the wall-clock governor)")
    ap.add_argument("--pop-devices", type=int, default=64,
                    help="simulated population size per group (registry N)")
    ap.add_argument("--cohort", type=int, default=8,
                    help="devices sampled per group per round")
    ap.add_argument("--deadline-quantile", type=float, default=0.8,
                    help="semi-async round deadline as a duration quantile")
    ap.add_argument("--staleness-damping", type=float, default=0.6,
                    help="late update weight multiplier per round of staleness")
    ap.add_argument("--max-staleness", type=int, default=4,
                    help="updates older than this are dropped, not damped")
    ap.add_argument("--t-compute", type=float, default=0.05,
                    help="nominal per-iteration device compute time (s)")
    ap.add_argument("--time-budget", type=float, default=float("inf"),
                    help="simulated wall-clock budget (s) for the adaptive "
                         "population governor")
    ap.add_argument("--trace-seed", type=int, default=None,
                    help="population trace seed (defaults to --seed)")
    # -- fault-tolerant runtime (population path) ---------------------------
    ap.add_argument("--robust-agg", default="mean",
                    choices=["mean", "median", "trimmed"],
                    help="aggregation over screened device updates when a "
                         "round flags faults (clean rounds always use the "
                         "plain masked mean, bit-identically)")
    ap.add_argument("--trim-frac", type=float, default=0.1,
                    help="per-side trim fraction for --robust-agg trimmed")
    ap.add_argument("--no-defense", action="store_true",
                    help="disable compiled screening + robust aggregation "
                         "(naive executor; faults hit the plain masked mean)")
    ap.add_argument("--fault-dropout", type=float, default=0.0,
                    help="P(device vanishes mid-round)")
    ap.add_argument("--fault-nan", type=float, default=0.0,
                    help="P(device emits NaN gradients in a round)")
    ap.add_argument("--fault-outlier", type=float, default=0.0,
                    help="P(device emits outlier-scaled gradients)")
    ap.add_argument("--fault-msg-corrupt", type=float, default=0.0,
                    help="P(group uplink payload bit-flip corrupted)")
    ap.add_argument("--fault-msg-loss", type=float, default=0.0,
                    help="P(group round update lost)")
    ap.add_argument("--fault-msg-dup", type=float, default=0.0,
                    help="P(group round update duplicated)")
    ap.add_argument("--fault-latency", type=float, default=0.0,
                    help="P(group link stalls for a round)")
    ap.add_argument("--preempt-round", type=int, default=-1,
                    help="coordinator dies at this round (-1 = never); "
                         "resume with --resume from the --checkpoint dir")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="fault schedule seed (defaults to --seed)")
    ap.add_argument("--fault-trace", default=None,
                    help="write the realized fault schedule to this JSON file")
    ap.add_argument("--min-quorum", type=float, default=0.5,
                    help="semi-async: fraction of the cohort that must land "
                         "on time before the deadline stops extending")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="semi-async: deadline re-extensions per round")
    ap.add_argument("--backoff-factor", type=float, default=2.0,
                    help="semi-async: deadline multiplier per retry (> 1)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint state + ledgers to --checkpoint every N "
                         "rounds (0 = only a final checkpoint)")
    ap.add_argument("--resume", action="store_true",
                    help="resume a --population run from the --checkpoint dir")
    # -- privacy-hardened exchange (e-health hsgd/c-hsgd path) ---------------
    ap.add_argument("--dp-clip", type=float, default=0.0,
                    help="per-row L2 clip C of the fused DP stage (0 = off)")
    ap.add_argument("--dp-sigma", type=float, default=0.0,
                    help="Gaussian noise multiplier σ (noise std = σ·C); "
                         "requires --dp-clip > 0")
    ap.add_argument("--epsilon", type=float, default=float("inf"),
                    help="(ε, δ) privacy budget; with --adaptive the "
                         "controller raises σ / amortizes P and refuses "
                         "rounds that would bust it")
    ap.add_argument("--delta", type=float, default=1e-5,
                    help="δ of the (ε, δ) guarantee")
    ap.add_argument("--secure-agg", action="store_true",
                    help="pairwise-mask the eq. (1) uplink (fixed-point ring; "
                         "single uplinks are uninformative, sums are exact)")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    _validate_args(ap, args)
    if args.arch:
        return run_llm(args)
    if not args.model:
        args.model = "paper-cnn"
    return run_ehealth(args)


if __name__ == "__main__":
    main()
