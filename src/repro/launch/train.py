"""Training launcher — the end-to-end driver for the HSGD federation.

Two modes:
  * e-health simulation (paper reproduction): --model paper-cnn|paper-lstm
    with --dataset organamnist|mimic3|esr, runs Algorithm 1 on the 3-tier
    partitioned synthetic data and reports the paper's metrics.
  * LLM-scale federation: --arch <assigned arch> (reduced via --smoke) runs
    the HSGD hybrid step (hospital/device towers + combined backbone) on
    synthetic token streams.

Examples:
  PYTHONPATH=src python -m repro.launch.train --model paper-cnn --rounds 50
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke --steps 10
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.common.config import FederationConfig, TrainConfig, get_config
from repro.core import metrics as MET
from repro.core.baselines import make_runner, merge_groups_for_tdcd
from repro.core.controller import AdaptiveConfig, AdaptiveHSGDRunner, ladder_from
from repro.core.hsgd import global_model, init_state, make_group_weights
from repro.data.partition import hybrid_partition
from repro.data.synthetic import DATASETS, flatten_for_tower, make_dataset, vertical_split
from repro.models.split_model import cnn_hybrid, llm_hybrid, lstm_hybrid


def make_paper_model(name: str, dataset: str):
    if name == "paper-cnn":
        return cnn_hybrid(h_rows=11, n_classes=DATASETS[dataset].n_classes)
    spec = DATASETS[dataset]
    if spec.name == "esr":
        return lstm_hybrid(n_features=178, hospital_features=89, n_classes=spec.n_classes)
    return lstm_hybrid(n_features=76, hospital_features=36, n_classes=spec.n_classes)


def run_ehealth(args) -> dict:
    spec = DATASETS[args.dataset]
    fed = FederationConfig(
        num_groups=args.groups,
        devices_per_group=args.devices,
        alpha=args.alpha,
        local_interval=args.q,
        global_interval=args.p,
    )
    train = TrainConfig(
        learning_rate=args.lr,
        lr_halve_every=args.lr_halve_every,
        compression_k=args.compression_k,
        quantization_bits=args.quantization,
    )
    model = make_paper_model(args.model, args.dataset)
    X, y = make_dataset(spec, args.samples, seed=args.seed)
    fdata = hybrid_partition(spec, X, y, fed, seed=args.seed)
    raw = fdata.stacked()
    algo = args.algorithm
    if algo in ("tdcd", "c-tdcd"):
        raw = merge_groups_for_tdcd(raw)
    data = {k: jnp.asarray(v) for k, v in raw.items()}
    w = make_group_weights(data)

    runner, eff_fed = make_runner(algo, model, fed, train)
    key = jax.random.PRNGKey(args.seed)
    if algo == "jfl":
        state = runner.init(key)
    else:
        state = init_state(key, model, eff_fed, data)

    history = None
    t0 = time.time()
    if args.adaptive:
        if algo not in ("hsgd", "c-hsgd"):
            raise SystemExit(f"--adaptive drives the HSGD loop; got --algorithm {algo}")
        eff_train = runner.train  # c-hsgd defaults (k=0.25, b=128) applied
        acfg = AdaptiveConfig(
            total_steps=args.rounds * fed.global_interval,
            target_bound=args.target_bound,
            byte_budget=args.byte_budget_mb * 1e6,
            max_interval=args.max_interval,
            eta_max=max(args.lr * 10, 0.05),
            # explicit --compression-k/--quantization (or c-hsgd defaults)
            # become the governor's rung 0 — never silently loosened
            ladder=ladder_from(eff_train.compression_k, eff_train.quantization_bits),
        )
        controller = AdaptiveHSGDRunner(model, fed, eff_train, acfg)
        state, losses, history = controller.run(
            state, data, w, probe_key=jax.random.PRNGKey(args.seed + 1))
        for h in history:
            print(f"[adaptive] round {h['round']:3d}: P=Q={h['P']:3d} "
                  f"eta={h['eta']:.4g} rung={h['rung']} Γ={h['gamma']:.3g} "
                  f"bytes={h['bytes_total'] / 1e6:.2f}MB loss={h['loss_last']:.4f}")
    else:
        state, losses = runner.run(state, data, w, rounds=args.rounds)
    dt = time.time() - t0
    gm = runner.global_model(state, w) if algo == "jfl" else global_model(state, w)

    X1, X2 = vertical_split(spec, X)
    m = MET.evaluate_global(
        model, gm, flatten_for_tower(spec, X1), flatten_for_tower(spec, X2), y
    )
    m["train_loss_final"] = float(losses[-1])
    m["steps"] = int(len(losses))
    m["wall_s"] = round(dt, 2)
    if history is not None:
        m["adaptive_rounds"] = len(history)
        m["adaptive_bytes_total"] = history[-1]["bytes_total"]
        m["adaptive_final_PQ"] = history[-1]["P"]
    print(json.dumps(m, indent=1))
    if args.checkpoint:
        save_checkpoint(args.checkpoint, gm, step=len(losses), extra={"metrics": m})
        print(f"checkpoint -> {args.checkpoint}")
    return m


def run_llm(args) -> dict:
    cfg = get_config(args.arch, smoke=args.smoke)
    model = llm_hybrid(cfg, n_tower=1, remat=False)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    B, S = args.batch, args.seq
    rng = np.random.RandomState(args.seed)
    if cfg.family == "vlm":
        x1 = jnp.asarray(rng.randn(B, 8, cfg.d_model), jnp.float32)
    elif cfg.family == "audio":
        x1 = jnp.asarray(rng.randn(B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    else:
        x1 = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S // 2)), jnp.int32)
    x2 = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S - (0 if cfg.family in ("vlm", "audio") else S // 2))), jnp.int32)
    ylen = x2.shape[1] if cfg.family in ("vlm", "audio") else S
    yy = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, ylen)), jnp.int32)

    from repro.launch.steps import make_exchange_step, make_hsgd_train_step

    step = jax.jit(make_hsgd_train_step(model, lr=args.lr))
    exch = jax.jit(make_exchange_step(model))
    batch = {"x1": x1, "x2": x2, "y": yy}
    losses = []
    stale = exch(params, batch)
    t0 = time.time()
    for t in range(args.steps):
        if t % args.q == 0:
            stale = exch(params, batch)
        params, loss = step(params, stale, batch)
        losses.append(float(loss))
        if t % max(1, args.steps // 10) == 0:
            print(f"step {t:4d} loss {float(loss):.4f}")
    out = {"arch": args.arch, "loss_first": losses[0], "loss_last": losses[-1],
           "steps": args.steps, "wall_s": round(time.time() - t0, 2)}
    print(json.dumps(out))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default=None, choices=["paper-cnn", "paper-lstm"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--dataset", default="organamnist", choices=list(DATASETS))
    ap.add_argument("--algorithm", default="hsgd",
                    choices=["hsgd", "c-hsgd", "jfl", "tdcd", "c-tdcd", "centralized"])
    ap.add_argument("--groups", type=int, default=10)
    ap.add_argument("--devices", type=int, default=64)
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--samples", type=int, default=2048)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--p", type=int, default=4)
    ap.add_argument("--q", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--lr-halve-every", type=int, default=0)
    ap.add_argument("--compression-k", type=float, default=0.0)
    ap.add_argument("--quantization", type=int, default=0)
    ap.add_argument("--adaptive", action="store_true",
                    help="closed-loop §VI controller: re-picks P/Q/eta and "
                         "tightens compression online (hsgd/c-hsgd only)")
    ap.add_argument("--byte-budget-mb", type=float, default=float("inf"),
                    help="modeled comm budget for the whole run, MB (all groups)")
    ap.add_argument("--target-bound", type=float, default=float("inf"),
                    help="Theorem-1 target Ξ the controller keeps Γ(P,Q) under")
    ap.add_argument("--max-interval", type=int, default=32,
                    help="cap on the adaptive P = Q")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.arch:
        return run_llm(args)
    if not args.model:
        args.model = "paper-cnn"
    return run_ehealth(args)


if __name__ == "__main__":
    main()
