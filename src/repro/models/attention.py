"""Attention: GQA/MHA, MLA (DeepSeek latent), sliding-window, blockwise, KV cache.

Blockwise (online-softmax) attention is the pure-JAX twin of the Pallas flash
kernel (kernels/flash_attention.py) and is used whenever the score matrix
would not fit memory (long prefill); XLA-native einsum attention is used for
short sequences. Decode paths attend one query token against a cached K/V.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.common.sharding import constrain, use_weight
from repro.common.backend import default_interpret
from repro.models import layers as L
from repro.models.quant import dequantize_rows, is_int8, quantize_rows

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def gqa_specs(cfg: ModelConfig) -> Dict[str, L.Spec]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    s = {
        "wq": L.Spec((d, cfg.num_heads, hd), ("embed", "heads", "head_dim")),
        "wk": L.Spec((d, cfg.num_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wv": L.Spec((d, cfg.num_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wo": L.Spec((cfg.num_heads, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = L.Spec((hd,), ("head_dim",), "ones")
        s["k_norm"] = L.Spec((hd,), ("head_dim",), "ones")
    return s


def mla_specs(cfg: ModelConfig) -> Dict[str, L.Spec]:
    """DeepSeek-V3 Multi-head Latent Attention."""
    d = cfg.d_model
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    qk_nope, qk_rope, vd = cfg.resolved_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": L.Spec((d, qr), ("embed", None)),
        "q_a_norm": L.Spec((qr,), (None,), "ones"),
        "wq_b": L.Spec((qr, cfg.num_heads, qk_nope + qk_rope), (None, "heads", "head_dim")),
        "wkv_a": L.Spec((d, kvr + qk_rope), ("embed", None)),
        "kv_a_norm": L.Spec((kvr,), (None,), "ones"),
        "wkv_b": L.Spec((kvr, cfg.num_heads, qk_nope + vd), (None, "heads", "head_dim")),
        "wo": L.Spec((cfg.num_heads, vd, d), ("heads", "head_dim", "embed")),
    }


def attention_specs(cfg: ModelConfig) -> Dict[str, L.Spec]:
    return mla_specs(cfg) if cfg.attention == "mla" else gqa_specs(cfg)


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------


def _window_ok(q_pos_col, k_pos_row, window):
    """window may be a traced int scalar; <=0 means full causal attention."""
    window = jnp.asarray(window, jnp.int32)
    in_window = k_pos_row > (q_pos_col - window)
    return jnp.where(window > 0, in_window, True)


def causal_mask_bias(q_pos, k_pos, window=0):
    """Additive bias [..., Sq, Sk]; window>0 adds a sliding-window band."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    ok &= _window_ok(q_pos[..., :, None], k_pos[..., None, :], window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def window_from_flag(cfg: ModelConfig, is_global) -> jnp.ndarray:
    """Per-layer window scalar: 0 = full attention, else sliding window."""
    win = cfg.sliding_window or 0
    return jnp.where(is_global, jnp.int32(0), jnp.int32(win))


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def _sdpa(q, k, v, bias, scale):
    """q:[B,Sq,H,D] k,v:[B,Sk,KH,D] -> [B,Sq,H,D]; bias:[B?,Sq,Sk] additive."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, Sq, KH, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)) * scale
    scores = scores + bias[:, None, None] if bias.ndim == 3 else scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def _blockwise_sdpa(q, k, v, q_pos, k_pos, scale, window: int, kv_block: int = 1024):
    """Online-softmax attention, scanning over KV blocks (flash-style, pure JAX).

    Memory O(Sq * kv_block) instead of O(Sq * Sk).
    """
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = H // KH
    nblk = (Sk + kv_block - 1) // kv_block
    pad = nblk * kv_block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=jnp.iinfo(jnp.int32).max)
    kb = k.reshape(B, nblk, kv_block, KH, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, kv_block, KH, D).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(B, nblk, kv_block).transpose(1, 0, 2)

    qg = (q * scale).reshape(B, Sq, KH, G, D).astype(jnp.float32)

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, pc = blk
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc.astype(jnp.float32))
        ok = pc[:, None, None, None, :] <= q_pos[:, None, None, :, None]
        ok &= _window_ok(q_pos[:, None, None, :, None], pc[:, None, None, None, :], window)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KH, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KH, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KH, G, Sq, D), jnp.float32)
    # remat the kv-block body: backward recomputes the [.., Sq, kv_block]
    # score slab instead of saving an f32 stack per block (§Perf iteration 7;
    # the Pallas flash kernel does the same in-register on real TPUs)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA forward (train/prefill and decode)
# ---------------------------------------------------------------------------

BLOCKWISE_THRESHOLD = 2048  # use online-softmax above this Sk (memory roofline)


def _long_prefill_attention(q, k, v, positions, scale, window):
    """Attention for a long contiguous SERVING prefill block at position 0.

    Routed to the Pallas flash kernel when a compiled Mosaic backend is
    available (TPU — same ``default_interpret()`` autodetect the compression
    kernel uses); the pure-JAX online-softmax twin runs elsewhere, where
    interpret-mode Pallas would only add overhead. ``window`` may be a traced
    per-layer scalar — the kernel takes it as an SMEM operand.

    Inference-only (reached via ``fresh_cache``): the forward-only kernel has
    no VJP, so the TRAIN path (no kv_cache) must stay on the differentiable
    ``_blockwise_sdpa`` twin.
    """
    if not default_interpret():
        from repro.kernels.ops import flash_attention

        G = q.shape[2] // k.shape[2]
        kr = jnp.repeat(k, G, axis=2) if G > 1 else k
        vr = jnp.repeat(v, G, axis=2) if G > 1 else v
        return flash_attention(q, kr, vr, scale=scale, window=window)
    return _blockwise_sdpa(q, k, v, positions, positions, scale, window)


def _cache_write(cache, update, index):
    """Write ``update`` into ``cache`` at ``index`` along axis 1.

    A scalar index writes a contiguous [B, S, ...] span (multi-token prefill,
    one ``dynamic_update_slice`` per leaf); an int32 [B] vector writes S
    tokens per batch row starting at per-slot positions (continuous batching
    — freed decode slots sit at different offsets; S > 1 is the speculative
    verify block). Out-of-range vector indices are dropped, which lets the
    serving engine park inactive slots at ``cache_len`` instead of masking.
    """
    if jnp.ndim(index) == 1:
        b = jnp.arange(cache.shape[0])[:, None]
        cols = index[:, None] + jnp.arange(update.shape[1], dtype=index.dtype)
        return cache.at[b, cols].set(update.astype(cache.dtype), mode="drop")
    start = (0, index) + (0,) * (cache.ndim - 2)
    return jax.lax.dynamic_update_slice(cache, update.astype(cache.dtype), start)


def _write_kv_cache(kv_cache, k, v, positions, index):
    """Write (k, v, positions) into the cache; return it plus read views.

    A 3-tuple cache is full precision. A 5-tuple is the int8 layout
    ``(k_codes, v_codes, k_scale, v_scale, pos)``: the update rows are
    quantized per (batch, position, kv_head) row before the write, and the
    read views are dequantized copies — the persistent cache stays int8 (the
    memory win), the transient f32 view lives only inside the executor.
    """
    if len(kv_cache) == 5:
        ck, cv, cks, cvs, cpos = kv_cache
        kq, ksc = quantize_rows(k)
        vq, vsc = quantize_rows(v)
        ck, cks = _cache_write(ck, kq, index), _cache_write(cks, ksc, index)
        cv, cvs = _cache_write(cv, vq, index), _cache_write(cvs, vsc, index)
        cpos = _cache_write(cpos, positions, index)
        new_cache = (ck, cv, cks, cvs, cpos)
        return new_cache, dequantize_rows(ck, cks, k.dtype), dequantize_rows(cv, cvs, v.dtype), cpos
    ck, cv, cpos = kv_cache
    ck = _cache_write(ck, k, index)
    cv = _cache_write(cv, v, index)
    cpos = _cache_write(cpos, positions, index)
    return (ck, cv, cpos), ck, cv, cpos


def gqa_forward(
    params,
    x,
    positions,
    cfg: ModelConfig,
    window: int = 0,
    positions_3d=None,
    kv_cache: Optional[Tuple] = None,
    cache_index=None,
    fresh_cache: bool = False,
):
    """Returns (out, new_kv) — new_kv only when kv_cache is given (decode)."""
    hd = cfg.resolved_head_dim
    wq = use_weight(params["wq"], ("embed", "heads", "head_dim"))
    wk = use_weight(params["wk"], ("embed", "kv_heads", "head_dim"))
    wv = use_weight(params["wv"], ("embed", "kv_heads", "head_dim"))
    q = jnp.einsum("bsd,dhk->bshk", x, wq.astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, wk.astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, wv.astype(x.dtype))
    if cfg.qk_norm:
        q = _head_rms(q, params["q_norm"])
        k = _head_rms(k, params["k_norm"])
    if cfg.mrope_sections:
        p3 = positions_3d if positions_3d is not None else L.text_positions_3d(positions)
        q = L.apply_mrope(q, p3, cfg.mrope_sections, cfg.rope_theta)
        k = L.apply_mrope(k, p3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "heads", None))
    scale = hd ** -0.5

    if kv_cache is not None:
        new_cache, ck, cv, cpos = _write_kv_cache(kv_cache, k, v, positions, cache_index)
        Sq, Sk = k.shape[1], ck.shape[1]
        if fresh_cache:
            # single-pass prefill into an empty cache: nothing precedes this
            # block, so attend within the freshly projected K/V — the cache
            # tail is all masked-out sentinels whose softmax terms are exact
            # zeros, so skipping it is bit-identical AND O(Sq²) not
            # O(Sq · cache_len). Long blocks go flash/online-softmax.
            if Sq > BLOCKWISE_THRESHOLD:
                out = _long_prefill_attention(q, k, v, positions, scale, window)
            else:
                bias = causal_mask_bias(positions, positions, window)
                out = _sdpa(q, k, v, bias, scale)
        elif Sq > 1 and Sq * Sk > BLOCKWISE_THRESHOLD ** 2:
            # later prefill blocks attend against earlier cache content too —
            # online-softmax over the cache keeps memory O(Sq * kv_block)
            # (sentinel positions mask the unwritten tail exactly)
            out = _blockwise_sdpa(q, ck, cv, positions, cpos, scale, window)
        else:
            bias = _decode_bias(positions, cpos, window)
            out = _sdpa(q, ck, cv, bias, scale)
    else:
        Sk = k.shape[1]
        if Sk > BLOCKWISE_THRESHOLD:
            # train path: must stay differentiable (jax.grad flows through)
            out = _blockwise_sdpa(q, k, v, positions, positions, scale, window)
        else:
            bias = causal_mask_bias(positions, positions, window)
            out = _sdpa(q, k, v, bias, scale)
        new_cache = None

    wo = use_weight(params["wo"], ("heads", "head_dim", "embed"))
    out = jnp.einsum("bshk,hkd->bsd", out, wo.astype(out.dtype))
    out = constrain(out, ("batch", "seq", "embed"))
    return out, new_cache


def _head_rms(x, scale, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def _decode_bias(q_pos, k_pos, window):
    ok = k_pos[:, None, :] <= q_pos[:, :, None]
    ok &= _window_ok(q_pos[:, :, None], k_pos[:, None, :], window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# MLA forward — caches the compressed latent (DeepSeek-V3 style)
# ---------------------------------------------------------------------------


def mla_forward(
    params,
    x,
    positions,
    cfg: ModelConfig,
    window: int = 0,
    kv_cache: Optional[Tuple] = None,
    cache_index=None,
    fresh_cache: bool = False,
    **_,
):
    nope, rope_d, vd = cfg.resolved_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank

    wq_a = use_weight(params["wq_a"], ("embed", None))
    qa = jnp.einsum("bsd,dr->bsr", x, wq_a.astype(x.dtype))
    qa = L.rmsnorm({"scale": params["q_a_norm"]}, qa)
    wq_b = use_weight(params["wq_b"], (None, "heads", "head_dim"))
    q = jnp.einsum("bsr,rhk->bshk", qa, wq_b.astype(x.dtype))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    wkv_a = use_weight(params["wkv_a"], ("embed", None))
    kv_a = jnp.einsum("bsd,dr->bsr", x, wkv_a.astype(x.dtype))
    latent, k_rope_flat = kv_a[..., :kvr], kv_a[..., kvr:]
    latent = L.rmsnorm({"scale": params["kv_a_norm"]}, latent)
    k_rope = L.apply_rope(k_rope_flat[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    scale = (nope + rope_d) ** -0.5
    wkv_b = use_weight(params["wkv_b"], (None, "heads", "head_dim"))
    wo = use_weight(params["wo"], ("heads", "head_dim", "embed"))

    if kv_cache is not None:
        # ---- ABSORBED decode (§Perf iteration 1) --------------------------
        # Never expand the latent cache to per-head K/V: fold wkv_b's K-half
        # into the query and its V-half into the attention output, so the
        # per-step cost is O(B·H·S·r) instead of O(B·S·r·H·(d_n+d_v)).
        idx = cache_index
        if len(kv_cache) == 5:
            c_lat, c_rope, c_lat_s, c_rope_s, cpos = kv_cache
            lq, lsc = quantize_rows(latent)
            rq, rsc = quantize_rows(k_rope)
            c_lat, c_lat_s = _cache_write(c_lat, lq, idx), _cache_write(c_lat_s, lsc, idx)
            c_rope, c_rope_s = _cache_write(c_rope, rq, idx), _cache_write(c_rope_s, rsc, idx)
            cpos = _cache_write(cpos, positions, idx)
            new_cache = (c_lat, c_rope, c_lat_s, c_rope_s, cpos)
            c_lat = dequantize_rows(c_lat, c_lat_s, latent.dtype)
            c_rope = dequantize_rows(c_rope, c_rope_s, k_rope.dtype)
        else:
            c_lat, c_rope, cpos = kv_cache
            c_lat = _cache_write(c_lat, latent, idx)
            c_rope = _cache_write(c_rope, k_rope, idx)
            cpos = _cache_write(cpos, positions, idx)
            new_cache = (c_lat, c_rope, cpos)

        wk_abs = wkv_b[..., :nope]  # [r, H, nope]
        wv_abs = wkv_b[..., nope:]  # [r, H, vd]
        q_abs = jnp.einsum("bqhk,rhk->bqhr", q_nope, wk_abs.astype(x.dtype))
        # accumulate in f32 WITHOUT materializing an f32 copy of the cache
        s = jnp.einsum("bqhr,bsr->bhqs", q_abs, c_lat,
                       preferred_element_type=jnp.float32)
        s += jnp.einsum("bqhk,bsk->bhqs", q_rope, c_rope,
                        preferred_element_type=jnp.float32)
        s *= scale
        ok = cpos[:, None, None, :] <= positions[:, None, :, None]
        ok &= _window_ok(positions[:, None, :, None], cpos[:, None, None, :], window)
        s = jnp.where(ok, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out_lat = jnp.einsum("bhqs,bsr->bqhr", p.astype(c_lat.dtype), c_lat,
                             preferred_element_type=jnp.float32)
        out = jnp.einsum("bqhr,rhv->bqhv", out_lat, wv_abs.astype(jnp.float32)).astype(x.dtype)
        out = jnp.einsum("bshv,hvd->bsd", out, wo.astype(x.dtype))
        return constrain(out, ("batch", "seq", "embed")), new_cache

    # ---- prefill/train: expansion amortizes over the full sequence --------
    kv = jnp.einsum("bsr,rhk->bshk", latent, wkv_b.astype(x.dtype))
    k_nope, vv = kv[..., :nope], kv[..., nope:]
    s = jnp.einsum("bqhk,bshk->bhqs", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
    s += jnp.einsum("bqhk,bsk->bhqs", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
    s *= scale
    ok = positions[:, None, None, :] <= positions[:, None, :, None]
    ok &= _window_ok(positions[:, None, :, None], positions[:, None, None, :], window)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqs,bshv->bqhv", p, vv.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bshv,hvd->bsd", out, wo.astype(x.dtype))
    return constrain(out, ("batch", "seq", "embed")), None


def attention_forward(params, x, positions, cfg: ModelConfig, **kw):
    if cfg.attention == "mla":
        return mla_forward(params, x, positions, cfg, **kw)
    return gqa_forward(params, x, positions, cfg, **kw)


# ---------------------------------------------------------------------------
# KV cache construction
# ---------------------------------------------------------------------------


def make_kv_cache_specs(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    """Per-layer cache ShapeDtypeStructs + logical axes for one layer.

    int8 caches carry two extra leaves per tuple — f32 per-row scales for the
    K and V codes — laid out ``(k, v, k_scale, v_scale, pos)`` so the int32
    position track stays the last leaf in both layouts.
    """
    quant = is_int8(dtype)
    if cfg.attention == "mla":
        kvr, rope_d = cfg.kv_lora_rank, cfg.qk_rope_head_dim
        shapes = [
            jax.ShapeDtypeStruct((batch, cache_len, kvr), dtype),
            jax.ShapeDtypeStruct((batch, cache_len, rope_d), dtype),
        ]
        axes = [("batch", "cache_seq", None), ("batch", "cache_seq", None)]
        if quant:
            shapes += [jax.ShapeDtypeStruct((batch, cache_len), jnp.float32)] * 2
            axes += [("batch", "cache_seq")] * 2
    else:
        hd = cfg.resolved_head_dim
        shapes = [
            jax.ShapeDtypeStruct((batch, cache_len, cfg.num_kv_heads, hd), dtype),
            jax.ShapeDtypeStruct((batch, cache_len, cfg.num_kv_heads, hd), dtype),
        ]
        axes = [
            ("batch", "cache_seq", "kv_heads", None),
            ("batch", "cache_seq", "kv_heads", None),
        ]
        if quant:
            shapes += [jax.ShapeDtypeStruct(
                (batch, cache_len, cfg.num_kv_heads), jnp.float32)] * 2
            axes += [("batch", "cache_seq", "kv_heads")] * 2
    shapes.append(jax.ShapeDtypeStruct((batch, cache_len), jnp.int32))
    axes.append(("batch", "cache_seq"))
    return tuple(shapes), tuple(axes)
