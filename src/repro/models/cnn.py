"""The paper's CNN model (Fig. 10): hospital-side + device-side conv towers
(no FC) whose outputs (intermediate results ζ) feed a combined model.

Used for the OrganAMNIST reproduction: each 28x28 image is vertically split
by rows; the hospital holds the top ``h_rows`` rows (≈300 px), the device the
rest (≈484 px).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


def conv_specs(k: int, c_in: int, c_out: int, name_scale=None) -> Dict[str, L.Spec]:
    return {
        "w": L.Spec((k, k, c_in, c_out), (None, None, None, None), "normal", name_scale),
        "b": L.Spec((c_out,), (None,), "zeros"),
    }


def conv2d(params, x, stride: int = 1):
    """SAME conv. Stride 1 uses an im2col + GEMM formulation: the HSGD hot
    path differentiates towers under vmap over groups/devices, and the
    batched-filter conv backward lowers to grouped convolutions that fall off
    XLA:CPU's fast path (and off the TPU MXU). Shifted-slice patches + a
    batched matmul keep both forward and backward on plain dot_general."""
    w = params["w"].astype(x.dtype)
    # even kernels pad asymmetrically under SAME ((k-1)//2, k//2) — the
    # symmetric im2col shift below only matches for odd k
    if stride != 1 or w.shape[0] % 2 == 0:
        y = jax.lax.conv_general_dilated(
            x, w,
            window_strides=(stride, stride),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        return y + params["b"].astype(x.dtype)
    k, _, c_in, c_out = w.shape
    B, H, W, _ = x.shape
    p = k // 2
    xp = jnp.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))
    patches = jnp.concatenate(
        [xp[:, i:i + H, j:j + W, :] for i in range(k) for j in range(k)], axis=-1)
    y = patches @ w.reshape(k * k * c_in, c_out)
    return y + params["b"].astype(x.dtype)


def max_pool_2x2(x):
    """2x2/2 VALID max pool as crop + reshape + max.

    Bit-identical to ``lax.reduce_window`` (same window set: positions
    0,2,... up to the last full window) but its backward is a cheap masked
    add instead of the single-threaded SelectAndScatter op."""
    b, h, w, c = x.shape
    return x[:, : h // 2 * 2, : w // 2 * 2, :].reshape(
        b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def tower_specs(in_rows: int, width: int = 28, channels: Tuple[int, ...] = (16, 32), embed_dim: int = 64):
    s: Dict = {}
    c_prev = 1
    for i, c in enumerate(channels):
        s[f"conv{i}"] = conv_specs(3, c_prev, c)
        c_prev = c
    rows, cols = in_rows, width
    for _ in channels:
        rows, cols = max(1, rows // 2), max(1, cols // 2)
    s["proj"] = L.dense_specs(rows * cols * c_prev, embed_dim, (None, None))
    return s


def tower_forward(params, x_flat, in_rows: int, width: int = 28, n_conv: int = 2):
    """x_flat: [B, in_rows*width] pixel slice -> ζ [B, embed]."""
    B = x_flat.shape[0]
    x = x_flat.reshape(B, in_rows, width, 1)
    for i in range(n_conv):
        x = jax.nn.relu(conv2d(params[f"conv{i}"], x))
        x = max_pool_2x2(x)
    x = x.reshape(B, -1)
    return L.dense(params["proj"], x)


def combined_specs(embed_dim: int, n_classes: int, hidden: int = 128):
    return {
        "fc1": L.dense_specs(2 * embed_dim, hidden, (None, None)),
        "fc1_b": L.Spec((hidden,), (None,), "zeros"),
        "fc2": L.dense_specs(hidden, n_classes, (None, None)),
        "fc2_b": L.Spec((n_classes,), (None,), "zeros"),
    }


def combined_forward(params, z1, z2):
    x = jnp.concatenate([z1, z2], axis=-1)
    x = jax.nn.relu(L.dense(params["fc1"], x) + params["fc1_b"].astype(x.dtype))
    return L.dense(params["fc2"], x) + params["fc2_b"].astype(x.dtype)


def classification_loss(logits, labels, weight_decay: float = 0.0, params=None):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    loss = -jnp.mean(ll)
    if weight_decay and params is not None:
        sq = sum(jnp.sum(jnp.square(p)) for p in jax.tree_util.tree_leaves(params))
        loss = loss + 0.5 * weight_decay * sq
    return loss
