"""Core layer primitives + the Spec param-declaration system.

Every layer declares its parameters once as a nested dict of ``Spec``s
(shape, logical axes, initializer). From that single source of truth we derive
  * ``init_params``   — concrete PRNG-initialized arrays,
  * ``abstract_params`` — ShapeDtypeStructs (dry-run, no allocation),
  * ``axes_tree``     — logical-axis tuples -> NamedShardings via common.sharding.
Apply functions are plain JAX functions over the params dict.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | embed
    scale: Optional[float] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _fan_in(shape) -> int:
    return int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]


def init_params(specs, key, dtype=jnp.float32):
    """Initialize a pytree of Specs into concrete arrays."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dtype)
        elif spec.init == "embed":
            s = spec.scale if spec.scale is not None else 1.0
            arr = (jax.random.normal(k, spec.shape) * s).astype(dtype)
        else:  # truncated-normal fan-in scaled (lecun)
            s = spec.scale if spec.scale is not None else 1.0 / math.sqrt(max(_fan_in(spec.shape), 1))
            arr = (jax.random.truncated_normal(k, -2.0, 2.0, spec.shape) * s).astype(dtype)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(specs, dtype=jnp.float32):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs, is_leaf=is_spec
    )


def axes_tree(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_specs(d: int) -> Dict[str, Spec]:
    return {"scale": Spec((d,), ("embed",), "ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    # (§Perf iteration 8, refuted: keeping x in bf16 through the norm did NOT
    # remove XLA's hoisted f32 stack conversion and cost ~1% extra bytes —
    # the standard f32-upcast norm is retained.)
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_specs(d: int) -> Dict[str, Spec]:
    return {"scale": Spec((d,), ("embed",), "ones"), "bias": Spec((d,), ("embed",), "zeros")}


def layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


def norm_specs(kind: str, d: int):
    return rmsnorm_specs(d) if kind == "rmsnorm" else layernorm_specs(d)


def apply_norm(kind: str, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# ---------------------------------------------------------------------------
# Dense / embedding
# ---------------------------------------------------------------------------


def dense_specs(d_in: int, d_out: int, axes: Tuple[Optional[str], Optional[str]], scale=None):
    return {"w": Spec((d_in, d_out), axes, "normal", scale)}


def dense(params, x):
    w = params["w"]
    return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))


def embed_specs(vocab: int, d: int):
    # vocab shards over "model"; d replicated (a data-sharded d here would
    # force XLA to un-shard the batch at every lookup/unembed — see DESIGN).
    return {"table": Spec((vocab, d), ("vocab", None), "embed", 0.02)}


def embed(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def unembed(params, x):
    """Tied-embedding readout."""
    t = params["table"].astype(x.dtype)
    return jnp.einsum("...d,vd->...v", x, t)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "squared_relu": squared_relu,
}


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_3d, sections: Tuple[int, ...], theta: float = 10000.0):
    """Qwen2-VL multimodal RoPE.

    positions_3d: [..., S, 3] (temporal, height, width position ids).
    sections: split of head_dim/2 frequency slots over the 3 position kinds.
    """
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # [D/2]
    # build per-slot position selector
    sec = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )  # [D/2]
    # gather: pos[..., s, j] = positions_3d[..., s, sec[j]]
    pos = jnp.einsum(
        "...sk,jk->...sj",
        positions_3d.astype(jnp.float32),
        jax.nn.one_hot(sec, 3, dtype=jnp.float32),
    )  # [..., S, D/2]
    angles = pos * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def text_positions_3d(positions):
    """Text-only M-RoPE degenerates to identical ids on all 3 channels."""
    return jnp.stack([positions, positions, positions], axis=-1)
