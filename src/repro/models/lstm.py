"""The paper's LSTM model for MIMIC-III / ESR: hospital & device LSTM towers
over their vertical feature slices; final hidden states are the intermediate
results ζ consumed by the combined classifier.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L


def lstm_specs(d_in: int, d_hidden: int) -> Dict[str, L.Spec]:
    # gates: i, f, g, o stacked
    return {
        "wx": L.Spec((d_in, 4 * d_hidden), (None, None)),
        "wh": L.Spec((d_hidden, 4 * d_hidden), (None, None)),
        "b": L.Spec((4 * d_hidden,), (None,), "zeros"),
    }


def lstm_forward(params, x):
    """x: [B, T, F] -> last hidden state [B, H]."""
    B = x.shape[0]
    H = params["wh"].shape[0]
    xg = jnp.einsum("btf,fk->btk", x, params["wx"].astype(x.dtype)) + params["b"].astype(x.dtype)
    xg = jnp.moveaxis(xg, 1, 0)  # [T, B, 4H]

    def step(carry, g_x):
        h, c = carry
        gates = g_x + jnp.einsum("bh,hk->bk", h, params["wh"].astype(h.dtype))
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    h0 = jnp.zeros((B, H), x.dtype)
    (h, _), _ = jax.lax.scan(step, (h0, h0), xg)
    return h


def tower_specs(d_in: int, d_hidden: int = 64, embed_dim: int = 64) -> Dict:
    return {
        "lstm": lstm_specs(d_in, d_hidden),
        "proj": L.dense_specs(d_hidden, embed_dim, (None, None)),
    }


def tower_forward(params, x):
    """x: [B, T, F_slice] -> ζ [B, embed]."""
    h = lstm_forward(params["lstm"], x)
    return L.dense(params["proj"], h)
