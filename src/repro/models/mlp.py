"""Feed-forward variants: SwiGLU / GeGLU (gated), squared-ReLU, plain GELU."""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.common.sharding import constrain, use_weight
from repro.models import layers as L


def mlp_specs(cfg: ModelConfig, d_ff: int = 0) -> Dict[str, L.Spec]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": L.Spec((d, f), ("embed", "mlp")),
            "w_up": L.Spec((d, f), ("embed", "mlp")),
            "w_down": L.Spec((f, d), ("mlp", "embed")),
        }
    return {
        "w_up": L.Spec((d, f), ("embed", "mlp")),
        "w_down": L.Spec((f, d), ("mlp", "embed")),
    }


def mlp_forward(params, x, cfg: ModelConfig):
    if cfg.mlp == "swiglu":
        act = L.ACTIVATIONS["silu"]
    elif cfg.mlp == "geglu":
        act = L.ACTIVATIONS["gelu"]
    elif cfg.mlp == "squared_relu":
        act = L.squared_relu
    else:
        act = L.ACTIVATIONS["gelu"]

    if cfg.mlp in ("swiglu", "geglu"):
        wg = use_weight(params["w_gate"], ("embed", "mlp"))
        wu = use_weight(params["w_up"], ("embed", "mlp"))
        g = jnp.einsum("...d,df->...f", x, wg.astype(x.dtype))
        u = jnp.einsum("...d,df->...f", x, wu.astype(x.dtype))
        h = act(g) * u
    else:
        wu = use_weight(params["w_up"], ("embed", "mlp"))
        h = act(jnp.einsum("...d,df->...f", x, wu.astype(x.dtype)))
    h = constrain(h, ("batch", "seq", "mlp"))
    wd = use_weight(params["w_down"], ("mlp", "embed"))
    out = jnp.einsum("...f,fd->...d", h, wd.astype(x.dtype))
    return constrain(out, ("batch", "seq", "embed"))
