"""Mixture-of-Experts: top-k router + capacity-based scatter dispatch.

Dispatch uses scatter/gather (memory O(E*C*D)) rather than dense one-hot
einsums (O(T*E*C)), so it scales to DeepSeek-V3's 256 experts at 1M-token
batches. Expert weights carry logical axes ("experts","embed","mlp") so the
default rules give expert-parallelism over the model axis + FSDP over data.
Under pjit, the token->expert scatter crossing the (data -> model) sharding
boundary is where XLA materializes the all-to-all — that is the collective
the roofline's MoE term tracks.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.common.sharding import constrain, use_weight
from repro.models import layers as L
from repro.models.mlp import mlp_forward, mlp_specs

CAPACITY_FACTOR = 1.25


def moe_specs(cfg: ModelConfig) -> Dict[str, L.Spec]:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    s: Dict[str, L.Spec] = {
        "router": L.Spec((d, E), ("embed", "experts"), "normal", 0.02),
        "w_gate": L.Spec((E, d, f), ("experts", "embed", "mlp")),
        "w_up": L.Spec((E, d, f), ("experts", "embed", "mlp")),
        "w_down": L.Spec((E, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.num_shared_experts:
        s["shared"] = mlp_specs(cfg, d_ff=cfg.num_shared_experts * f)
    return s


def _capacity(num_tokens: int, E: int, k: int) -> int:
    c = int(num_tokens * k * CAPACITY_FACTOR / E) + 1
    # round to 128: MXU-aligned AND divisible by the 16-wide data axis, so the
    # capacity dim's sharding is never dropped (§Perf iteration 5 — a
    # non-divisible C silently replicated every expert buffer)
    return max(128, -(-c // 128) * 128)


def moe_forward(params, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    C = _capacity(T, E, k)

    flat = x.reshape(T, D)
    router = use_weight(params["router"], ("embed", "experts"))
    logits = jnp.einsum("td,de->te", flat.astype(jnp.float32), router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # [T, k]
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux_loss = E * jnp.sum(density * density_proxy)

    # position of each assignment within its expert (capacity bookkeeping)
    flat_idx = idx.reshape(-1)  # [T*k] expert ids, token-major
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)  # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) * onehot  # rank within expert, 1-based
    pos = jnp.sum(pos, axis=-1) - 1  # [T*k]
    keep = pos < C

    # scatter tokens into expert buffers [E, C, D]
    tok_rep = jnp.repeat(jnp.arange(T), k)
    safe_pos = jnp.where(keep, pos, C - 1)
    buf = jnp.zeros((E, C, D), x.dtype)
    contrib = jnp.where(keep[:, None], flat[tok_rep], 0).astype(x.dtype)
    buf = buf.at[flat_idx, safe_pos].add(contrib, mode="drop")
    buf = constrain(buf, ("experts", "expert_tokens", None))

    # expert computation (batched over experts)
    act = L.ACTIVATIONS["silu" if cfg.mlp in ("swiglu", "geglu") else "gelu"]
    wg = use_weight(params["w_gate"], ("experts", "embed", "mlp"))
    wu = use_weight(params["w_up"], ("experts", "embed", "mlp"))
    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(x.dtype))
    h = act(g) * u
    # keep the natural (experts, tokens->data, mlp->model) sharding — forcing
    # mlp unsharded here made XLA all-gather the full [E,C,F] hidden
    # (1.37 TB/step on grok — §Perf iteration 4)
    h = constrain(h, ("experts", "expert_tokens", "mlp"))
    wd = use_weight(params["w_down"], ("experts", "mlp", "embed"))
    eout = jnp.einsum("ecf,efd->ecd", h, wd.astype(x.dtype))
    eout = constrain(eout, ("experts", "expert_tokens", None))

    # gather back + gate-weighted combine
    picked = eout[flat_idx, safe_pos]  # [T*k, D]
    picked = jnp.where(keep[:, None], picked, 0)
    weighted = picked * gate.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype).at[tok_rep].add(weighted)

    if cfg.num_shared_experts:
        out = out + mlp_forward(params["shared"], flat, cfg)

    return out.reshape(B, S, D), aux_loss
