"""Int8 row-quantization for decode caches (KV, MLA latent, SSM state).

Serving caches are write-once/read-many (attention KV, cross-attention KV)
or read-modify-write (SSM state), and their rows are small (head_dim, latent
rank, or state width). Symmetric per-row int8 — one f32 scale per cache row,
codes = round(x / scale) with scale = amax(|row|) / 127 — halves cache bytes
vs bf16 (quarter vs f32) at a bounded logit drift, which is what lets
``ServeEngine``'s ``max_batch`` grow on a fixed memory budget.

The quantized representation is plain extra pytree leaves (codes int8 +
scales f32) so it donates, scatters, and shards exactly like the full-
precision caches: ``_cache_write`` works unchanged on both leaves because a
scale row is just a cache row with zero trailing dims.
"""
from __future__ import annotations

import jax.numpy as jnp

QMAX = 127.0
# floor on the per-row scale: rows of exact zeros (virgin cache) quantize to
# zero codes / zero scale and dequantize back to exact zeros
SCALE_EPS = 1e-30


def is_int8(x) -> bool:
    """True for int8 dtypes and arrays (cache-leaf dispatch)."""
    return jnp.dtype(getattr(x, "dtype", x)) == jnp.int8


def quantize_rows(x):
    """[..., D] -> (codes int8 [..., D], scale f32 [...]) per-row symmetric."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = amax / QMAX
    codes = jnp.round(xf / jnp.maximum(scale, SCALE_EPS)[..., None])
    return codes.astype(jnp.int8), scale


def dequantize_rows(codes, scale, dtype=jnp.float32):
    """(codes int8 [..., D], scale f32 [...]) -> values [..., D]."""
    return (codes.astype(jnp.float32) * scale[..., None]).astype(dtype)
