"""The paper's hybrid decomposition θ = [θ0 (combined), θ1 (hospital), θ2 (device)]
as a uniform wrapper over every model family.

A ``HybridModel`` exposes exactly the objects Algorithm 1 manipulates:
  h1(θ1, X1) -> ζ1      hospital tower
  h2(θ2, X2) -> ζ2      device tower
  loss(θ0, ζ1, ζ2, y)   combined model + loss

Instantiations:
  * cnn_hybrid / lstm_hybrid — the paper's own e-health models, with the
    exact vertical feature split of §VII-A (image rows / time-series features).
  * llm_hybrid — the assigned LLM-scale architectures. The vertical partition
    is over the sequence: the hospital holds the clinical-record segment, the
    device holds the wearable-log segment (for VLM/audio, the hospital side is
    the modality-frontend embedding — its natural VFL role). Towers are
    ``n_tower`` family-consistent blocks; the combined model is the assigned
    architecture's full backbone + head.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import cnn as C
from repro.models import layers as L
from repro.models import lstm as R
from repro.models import transformer as T


@dataclass(frozen=True)
class HybridModel:
    name: str
    specs0: Any  # combined θ0
    specs1: Any  # hospital θ1
    specs2: Any  # device θ2
    h1: Callable  # (θ1, x1) -> ζ1
    h2: Callable  # (θ2, x2) -> ζ2
    loss: Callable  # (θ0, ζ1, ζ2, y) -> scalar
    predict: Callable  # (θ0, ζ1, ζ2) -> outputs

    def specs(self) -> Dict[str, Any]:
        return {"theta0": self.specs0, "theta1": self.specs1, "theta2": self.specs2}

    def init(self, key, dtype=jnp.float32):
        k0, k1, k2 = jax.random.split(key, 3)
        return {
            "theta0": L.init_params(self.specs0, k0, dtype),
            "theta1": L.init_params(self.specs1, k1, dtype),
            "theta2": L.init_params(self.specs2, k2, dtype),
        }

    def full_loss(self, params, x1, x2, y):
        """Centralized view: fresh towers + combined (used by baselines/tests)."""
        z1 = self.h1(params["theta1"], x1)
        z2 = self.h2(params["theta2"], x2)
        return self.loss(params["theta0"], z1, z2, y)


# ---------------------------------------------------------------------------
# Paper models
# ---------------------------------------------------------------------------


def cnn_hybrid(
    h_rows: int = 11,
    width: int = 28,
    n_classes: int = 11,
    embed_dim: int = 64,
) -> HybridModel:
    """OrganAMNIST: hospital holds top h_rows rows (≈300px), device the rest."""
    d_rows = width - h_rows

    def h1(t, x1):
        return C.tower_forward(t, x1, h_rows, width)

    def h2(t, x2):
        return C.tower_forward(t, x2, d_rows, width)

    def predict(t0, z1, z2):
        return C.combined_forward(t0, z1, z2)

    def loss(t0, z1, z2, y):
        return C.classification_loss(predict(t0, z1, z2), y)

    return HybridModel(
        name="paper_cnn",
        specs0=C.combined_specs(embed_dim, n_classes),
        specs1=C.tower_specs(h_rows, width, embed_dim=embed_dim),
        specs2=C.tower_specs(d_rows, width, embed_dim=embed_dim),
        h1=h1,
        h2=h2,
        loss=loss,
        predict=predict,
    )


def lstm_hybrid(
    n_features: int = 76,
    hospital_features: int = 36,
    n_classes: int = 2,
    d_hidden: int = 64,
    embed_dim: int = 64,
) -> HybridModel:
    """MIMIC-III / ESR: per-timestep feature split (36/40 for MIMIC)."""
    dev_features = n_features - hospital_features

    def h1(t, x1):
        return R.tower_forward(t, x1)

    def h2(t, x2):
        return R.tower_forward(t, x2)

    def predict(t0, z1, z2):
        return C.combined_forward(t0, z1, z2)

    def loss(t0, z1, z2, y):
        return C.classification_loss(predict(t0, z1, z2), y)

    return HybridModel(
        name="paper_lstm",
        specs0=C.combined_specs(embed_dim, n_classes),
        specs1=R.tower_specs(hospital_features, d_hidden, embed_dim),
        specs2=R.tower_specs(dev_features, d_hidden, embed_dim),
        h1=h1,
        h2=h2,
        loss=loss,
        predict=predict,
    )


# ---------------------------------------------------------------------------
# LLM-scale hybrid (assigned architectures)
# ---------------------------------------------------------------------------


def _tower_cfg(cfg: ModelConfig, n_tower: int) -> ModelConfig:
    """Family-consistent tower blocks at full width, shallow depth."""
    kw = dict(num_layers=n_tower, first_dense_layers=0, num_experts=0,
              experts_per_token=0, num_shared_experts=0)
    if cfg.family in ("ssm", "hybrid"):
        return cfg.replace(family="ssm", **kw)
    if cfg.d_ff == 0:  # attention-free cfg needs an ff for dense tower blocks
        kw["d_ff"] = 4 * cfg.d_model
    return cfg.replace(family="dense", attention=cfg.attention,
                       hybrid_attn_every=0, **kw)


def _tower_stack_specs(cfg: ModelConfig, n_tower: int, with_embed: bool):
    tcfg = _tower_cfg(cfg, n_tower)
    kind = "mamba" if tcfg.family == "ssm" else "attn_mlp"
    s = {"layers": T.stack_specs(tcfg, n_tower, kind), "norm": L.norm_specs(cfg.norm, cfg.d_model)}
    if with_embed:
        s["embed"] = L.embed_specs(cfg.vocab_size, cfg.d_model)
    return s, tcfg


def _tower_forward(tcfg: ModelConfig, params, x_or_tokens, remat=True):
    if "embed" in params:
        x = L.embed(params["embed"], x_or_tokens)
        x = x * jnp.asarray(jnp.sqrt(jnp.float32(tcfg.d_model)), x.dtype)
    else:
        x = x_or_tokens
    x, _ = T.backbone_forward(tcfg, {"layers": params["layers"]}, x, remat=remat)
    return L.apply_norm(tcfg.norm, params["norm"], x)


def llm_hybrid(cfg: ModelConfig, n_tower: int = 2, remat: bool = True) -> HybridModel:
    """Wrap an assigned architecture into the paper's hybrid decomposition."""
    modality = cfg.family in ("audio", "vlm")
    # hospital tower: modality embeddings for audio/vlm, token segment otherwise
    s1, tcfg1 = _tower_stack_specs(cfg, n_tower, with_embed=not modality)
    s2, tcfg2 = _tower_stack_specs(cfg, n_tower, with_embed=True)

    specs0 = T.model_specs(cfg)
    del specs0["embed"]  # combined model consumes ζ, not tokens
    specs0["head"] = L.dense_specs(cfg.d_model, cfg.vocab_size, (None, "vocab"), scale=0.02)

    def h1(t1, x1):
        return _tower_forward(tcfg1, t1, x1, remat)

    def h2(t2, x2):
        return _tower_forward(tcfg2, t2, x2, remat)

    def hidden_fn(t0, z1, z2):
        if cfg.family == "audio":
            x = T.audio_forward(t0, z2, z1, None, cfg, remat)
        else:
            x = jnp.concatenate([z1.astype(z2.dtype), z2], axis=1)
            x, _ = T.backbone_forward(cfg, t0, x, remat=remat)
        return L.apply_norm(cfg.norm, t0["final_norm"], x)

    def predict(t0, z1, z2):
        return L.dense(t0["head"], hidden_fn(t0, z1, z2))

    def loss(t0, z1, z2, y):
        hidden = hidden_fn(t0, z1, z2)
        # labels cover the token region (device segment + hospital segment for
        # text-text splits; decoder tokens for enc-dec/vlm)
        Sy = y.shape[1]
        hidden = hidden[:, -Sy:]
        # fused chunked head+CE — full logits never materialize (§Perf it. 6)
        head_cfg = cfg.replace(tie_embeddings=False)
        return T.chunked_lm_head_loss(head_cfg, t0, hidden, y, remat)

    return HybridModel(
        name=f"hybrid_{cfg.name}",
        specs0=specs0,
        specs1=s1,
        specs2=s2,
        h1=h1,
        h2=h2,
        loss=loss,
        predict=predict,
    )
