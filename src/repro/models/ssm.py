"""Selective state-space blocks: Mamba-1 (falcon-mamba) and Mamba-2 (zamba2).

Training/prefill uses a chunked linear recurrence: within a chunk the
recurrence h_t = a_t * h_{t-1} + b_t is solved with cumulative products
(associative-scan identity), and chunk boundary states are carried with
``lax.scan``. This keeps activation memory O(T/chunks * state) and is the
pure-JAX twin of kernels/ssm_scan.py. Decode is a single recurrence step on a
carried state — O(1) per token, which is what makes long_500k tractable for
the SSM/hybrid architectures.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.common.sharding import constrain, use_weight
from repro.models import layers as L
from repro.models.quant import dequantize_rows, is_int8, quantize_rows

CHUNK = 256


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def mamba_specs(cfg: ModelConfig) -> Dict[str, L.Spec]:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    N = cfg.ssm_state
    conv = cfg.ssm_conv
    if cfg.ssm_version == 1:
        dt_rank = max(1, d // 16)
        return {
            "w_in": L.Spec((d, 2 * d_in), ("embed", "ssm_inner")),
            "conv_w": L.Spec((conv, d_in), ("conv", "ssm_inner"), "normal", 0.5),
            "conv_b": L.Spec((d_in,), ("ssm_inner",), "zeros"),
            "w_bcdt": L.Spec((d_in, 2 * N + dt_rank), ("ssm_inner", None)),
            "w_dt": L.Spec((dt_rank, d_in), (None, "ssm_inner"), "normal", 0.1),
            "dt_bias": L.Spec((d_in,), ("ssm_inner",), "zeros"),
            "a_log": L.Spec((d_in, N), ("ssm_inner", "ssm_state"), "zeros"),
            "d_skip": L.Spec((d_in,), ("ssm_inner",), "ones"),
            "w_out": L.Spec((d_in, d), ("ssm_inner", "embed")),
        }
    # mamba2 (SSD): scalar decay per head
    H = d_in // cfg.ssm_headdim
    return {
        "w_in": L.Spec((d, 2 * d_in + 2 * N + H), ("embed", "ssm_inner")),
        "conv_w": L.Spec((conv, d_in + 2 * N), ("conv", "ssm_inner"), "normal", 0.5),
        "conv_b": L.Spec((d_in + 2 * N,), ("ssm_inner",), "zeros"),
        "dt_bias": L.Spec((H,), (None,), "zeros"),
        "a_log": L.Spec((H,), (None,), "zeros"),
        "d_skip": L.Spec((H,), (None,), "ones"),
        "norm": L.Spec((d_in,), ("ssm_inner",), "ones"),
        "w_out": L.Spec((d_in, d), ("ssm_inner", "embed")),
    }


def mamba_state_specs(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    """Decode-time carried state (per layer): (conv_buffer, ssm_state).

    int8 appends per-row f32 scales — ``(conv, h, conv_scale, h_scale)`` —
    quantized on every state write and dequantized on read (the recurrence
    itself always runs in f32).
    """
    d_in = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    conv = cfg.ssm_conv
    if cfg.ssm_version == 1:
        shapes = [
            jax.ShapeDtypeStruct((batch, conv - 1, d_in), dtype),
            jax.ShapeDtypeStruct((batch, d_in, N), dtype),
        ]
        axes = [("batch", None, "ssm_inner"), ("batch", "ssm_inner", "ssm_state")]
        scale_shapes = [(batch, conv - 1), (batch, d_in)]
        scale_axes = [("batch", None), ("batch", "ssm_inner")]
    else:
        H = d_in // cfg.ssm_headdim
        shapes = [
            jax.ShapeDtypeStruct((batch, conv - 1, d_in + 2 * N), dtype),
            jax.ShapeDtypeStruct((batch, H, cfg.ssm_headdim, N), dtype),
        ]
        axes = [("batch", None, "ssm_inner"), ("batch", None, None, "ssm_state")]
        scale_shapes = [(batch, conv - 1), (batch, H, cfg.ssm_headdim)]
        scale_axes = [("batch", None), ("batch", None, None)]
    if is_int8(dtype):
        shapes += [jax.ShapeDtypeStruct(s, jnp.float32) for s in scale_shapes]
        axes += scale_axes
    return tuple(shapes), tuple(axes)


def _state_unpack(state):
    """(conv, h) read views — dequantized f32 when the state is int8."""
    if len(state) == 4:
        conv, h, conv_s, h_s = state
        return dequantize_rows(conv, conv_s), dequantize_rows(h, h_s)
    return state[0], state[1]


def _state_pack(template, conv, h):
    """Re-pack (conv, h) in the layout of ``template`` (quantizing for int8)."""
    if len(template) == 4:
        cq, cs = quantize_rows(conv)
        hq, hs = quantize_rows(h)
        return (cq, hq, cs, hs)
    return (conv, h)


# ---------------------------------------------------------------------------
# Chunked linear recurrence: h_t = a_t * h_{t-1} + b_t
# ---------------------------------------------------------------------------


def chunked_linear_recurrence(a, b, h0, project=None, aux=None):
    """a, b: [B, T, ...]; h0: [B, ...]. Returns (outputs over T, final state).

    Within a chunk: h_t = (prod_{i<=t} a_i) * (h0 + sum_{j<=t} b_j / prod_{i<=j} a_i)
    computed stably in log-space for a (a > 0 guaranteed: a = exp(-softplus)).

    ``project(hs_chunk, aux_chunk)`` (optional) is fused into each chunk so the
    state history [B, T, C, N] is never materialized — only the projected
    output [B, T, C] leaves the scan. Without it, returns the raw states.
    """
    B, T = a.shape[0], a.shape[1]
    K = min(CHUNK, T)  # never pad a short sequence (decode: T=1) up to CHUNK
    nchunk = (T + K - 1) // K
    pad = nchunk * K - T
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad)) + ((0, 0),) * (b.ndim - 2))
        if aux is not None:
            aux = jnp.pad(aux, ((0, 0), (0, pad)) + ((0, 0),) * (aux.ndim - 2))
    a = a.reshape((B, nchunk, K) + a.shape[2:])
    b = b.reshape((B, nchunk, K) + b.shape[2:])
    a = jnp.moveaxis(a, 1, 0)  # [nchunk, B, K, ...]
    b = jnp.moveaxis(b, 1, 0)
    if aux is not None:
        aux = jnp.moveaxis(aux.reshape((B, nchunk, K) + aux.shape[2:]), 1, 0)

    def chunk_step(h, xs):
        hs, h_last = _chunk_recurrence(xs[0], xs[1], h)
        out = project(hs, xs[2]) if project is not None else hs
        return h_last, out

    xs = (a, b) if aux is None else (a, b, aux)
    body = chunk_step if aux is not None else (lambda h, ab: chunk_step(h, ab))
    h_final, outs = jax.lax.scan(body, h0, xs)
    outs = jnp.moveaxis(outs, 0, 1)
    outs = outs.reshape((B, nchunk * K) + outs.shape[3:])
    return outs[:, :T], h_final



def _chunk_recurrence(ac, bc, h):
    """Solve h_t = a_t*h_{t-1} + b_t within one chunk. ac,bc: [B,K,...].

    Exact sequential scan: the log-space cumulative-product shortcut
    overflows exp(-cum) for strong decay (a << 1), so the pure-JAX path
    stays exact and the in-register sequential Pallas kernel
    (kernels/ssm_scan.py) — which has the same recurrence structure — is
    the performance path on hardware.
    """
    aT = jnp.moveaxis(ac, 1, 0)
    bT = jnp.moveaxis(bc, 1, 0)

    def step(hc, ab):
        at, bt = ab
        hc = at * hc + bt
        return hc, hc

    h_last, hs = jax.lax.scan(step, h, (aT, bT))
    return jnp.moveaxis(hs, 0, 1), h_last


def _to_chunks(x, nchunk, pad, chunk=CHUNK):
    """[B, T, ...] -> [nchunk, B, chunk, ...] (pad with zeros)."""
    B = x.shape[0]
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    x = x.reshape((B, nchunk, chunk) + x.shape[2:])
    return jnp.moveaxis(x, 1, 0)


def _causal_conv(x, w, b, state: Optional[jnp.ndarray] = None):
    """x: [B, T, C]; w: [K, C] depthwise; state: [B, K-1, C] carried context."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype) for i in range(K))
    new_state = xp[:, -(K - 1) :, :] if K > 1 else jnp.zeros_like(x[:, :0])
    return out + b.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba-1 forward
# ---------------------------------------------------------------------------


def mamba1_forward(params, x, cfg: ModelConfig, state: Optional[Tuple] = None):
    """x: [B, T, D]. state: (conv_state, h) for decode; None for train/prefill."""
    B, T, D = x.shape
    d_in = cfg.ssm_expand * D
    N = cfg.ssm_state
    dt_rank = max(1, D // 16)

    w_in = use_weight(params["w_in"], ("embed", "ssm_inner"))
    proj = jnp.einsum("btd,dk->btk", x, w_in.astype(x.dtype))
    xz, z = proj[..., :d_in], proj[..., d_in:]
    conv_state, h_read = _state_unpack(state) if state is not None else (None, None)
    xc, new_conv = _causal_conv(xz, params["conv_w"], params["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    xc = constrain(xc, ("batch", "seq", "ssm_inner"))

    bcdt = jnp.einsum("btc,ck->btk", xc, params["w_bcdt"].astype(x.dtype))
    Bm, Cm, dt_in = bcdt[..., :N], bcdt[..., N : 2 * N], bcdt[..., 2 * N :]
    dt = jax.nn.softplus(
        jnp.einsum("btr,rc->btc", dt_in, params["w_dt"].astype(x.dtype))
        + params["dt_bias"].astype(x.dtype)
    ).astype(jnp.float32)  # [B, T, d_in]
    A = -jnp.exp(params["a_log"].astype(jnp.float32))  # [d_in, N]
    h0 = h_read.astype(jnp.float32) if state is not None else jnp.zeros((B, d_in, N), jnp.float32)

    # chunked scan with a/bx construction fused INSIDE the chunk: the state
    # history [B, T, d_in, N] never exists — only [B, K, d_in, N] does. K
    # tracks T downward so a single decode token (T=1) is ONE recurrence
    # step, not a 256-step padded scan — the serve-path hot loop.
    K = min(CHUNK, T)
    nchunk = (T + K - 1) // K
    pad = nchunk * K - T
    xcf = xc.astype(jnp.float32)

    def chunk_body(h, xs):
        dtc, xcc, Bc, Cc = xs  # [B,K,d_in] [B,K,d_in] [B,K,N] [B,K,N]
        ac = jnp.exp(dtc[..., None] * A[None, None])
        bxc = (dtc * xcc)[..., None] * Bc[:, :, None, :]
        hs, hl = _chunk_recurrence(ac, bxc, h)
        yc = jnp.einsum("bkcn,bkn->bkc", hs, Cc)
        return hl, yc

    xs = tuple(_to_chunks(v, nchunk, pad, K) for v in
               (dt, xcf, Bm.astype(jnp.float32), Cm.astype(jnp.float32)))
    h_final, ys = jax.lax.scan(chunk_body, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nchunk * K, d_in)[:, :T]
    y = y + params["d_skip"].astype(jnp.float32) * xcf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    w_out = use_weight(params["w_out"], ("ssm_inner", "embed"))
    out = jnp.einsum("btc,cd->btd", y, w_out.astype(x.dtype))
    out = constrain(out, ("batch", "seq", "embed"))
    new_state = _state_pack(state, new_conv, h_final) if state is not None else None
    return out, new_state


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) forward — scalar decay per head
# ---------------------------------------------------------------------------


def mamba2_forward(params, x, cfg: ModelConfig, state: Optional[Tuple] = None):
    B, T, D = x.shape
    d_in = cfg.ssm_expand * D
    N = cfg.ssm_state
    P = cfg.ssm_headdim
    H = d_in // P

    w_in = use_weight(params["w_in"], ("embed", "ssm_inner"))
    proj = jnp.einsum("btd,dk->btk", x, w_in.astype(x.dtype))
    z = proj[..., :d_in]
    xBC = proj[..., d_in : 2 * d_in + 2 * N]
    dt_in = proj[..., 2 * d_in + 2 * N :]  # [B, T, H]
    conv_state, h_read = _state_unpack(state) if state is not None else (None, None)
    xBC, new_conv = _causal_conv(xBC, params["conv_w"], params["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)
    xs = xBC[..., :d_in].reshape(B, T, H, P)
    Bm = xBC[..., d_in : d_in + N]
    Cm = xBC[..., d_in + N :]

    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))  # [B,T,H]
    A = -jnp.exp(params["a_log"].astype(jnp.float32))  # [H]
    h0 = (
        h_read.astype(jnp.float32)
        if state is not None
        else jnp.zeros((B, H, P, N), jnp.float32)
    )
    K = min(CHUNK, T)  # T=1 decode: one recurrence step, not a padded CHUNK
    nchunk = (T + K - 1) // K
    pad = nchunk * K - T
    xsf = xs.astype(jnp.float32)

    def chunk_body(h, cs):
        dtc, xcc, Bc, Cc = cs  # [B,K,H] [B,K,H,P] [B,K,N] [B,K,N]
        ac = jnp.broadcast_to(
            jnp.exp(dtc * A[None, None])[..., None, None],
            dtc.shape + (P, N),
        )
        bxc = dtc[..., None, None] * xcc[..., None] * Bc[:, :, None, None, :]
        hs, hl = _chunk_recurrence(ac, bxc, h)
        yc = jnp.einsum("bkhpn,bkn->bkhp", hs, Cc)
        return hl, yc

    cs = tuple(_to_chunks(v, nchunk, pad, K) for v in
               (dt, xsf, Bm.astype(jnp.float32), Cm.astype(jnp.float32)))
    h_final, ys = jax.lax.scan(chunk_body, h0, cs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nchunk * K, H, P)[:, :T]
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * xsf
    y = y.reshape(B, T, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.rmsnorm({"scale": params["norm"]}, y.astype(x.dtype))
    w_out = use_weight(params["w_out"], ("ssm_inner", "embed"))
    out = jnp.einsum("btc,cd->btd", y, w_out.astype(x.dtype))
    out = constrain(out, ("batch", "seq", "embed"))
    new_state = _state_pack(state, new_conv, h_final) if state is not None else None
    return out, new_state


def mamba_forward(params, x, cfg: ModelConfig, state=None):
    if cfg.ssm_version == 1:
        return mamba1_forward(params, x, cfg, state)
    return mamba2_forward(params, x, cfg, state)
