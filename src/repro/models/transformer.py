"""Model assembly for all assigned families.

Layer parameters are stacked along a leading "stack" dimension and driven by
``jax.lax.scan`` (+ remat) so that 61–80-layer models lower to compact HLO —
essential for the 512-device dry-runs. Heterogeneous layer schedules
(gemma3's 5 local : 1 global attention, deepseek's first-k-dense, zamba2's
shared attention block) are expressed with per-layer metadata arrays or
super-block loops, never by unrolling all layers.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.common.sharding import constrain
from repro.models import attention as A
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.mlp import mlp_forward, mlp_specs
from repro.models.moe import moe_forward, moe_specs
from repro.models.quant import dequantize_rows, is_int8, quantize_rows


# ---------------------------------------------------------------------------
# Per-layer specs by family
# ---------------------------------------------------------------------------


def block_specs(cfg: ModelConfig, kind: str) -> Dict:
    """kind: attn_mlp | attn_moe | mamba | encdec."""
    d = cfg.d_model
    if kind == "mamba":
        return {
            "norm": L.norm_specs(cfg.norm, d),
            "mamba": S.mamba_specs(cfg),
        }
    if kind == "attn_moe":
        return {
            "norm1": L.norm_specs(cfg.norm, d),
            "attn": A.attention_specs(cfg),
            "norm2": L.norm_specs(cfg.norm, d),
            "moe": moe_specs(cfg),
        }
    return {
        "norm1": L.norm_specs(cfg.norm, d),
        "attn": A.attention_specs(cfg),
        "norm2": L.norm_specs(cfg.norm, d),
        "mlp": mlp_specs(cfg),
    }


def stack_specs(cfg: ModelConfig, n_layers: int, kind: str) -> Dict:
    """Stack per-layer specs along a leading layer dim."""
    one = block_specs(cfg, kind)
    return jax.tree.map(
        lambda s: L.Spec((n_layers,) + s.shape, ("stack",) + s.axes, s.init, s.scale),
        one,
        is_leaf=L.is_spec,
    )


# ---------------------------------------------------------------------------
# Block forwards
# ---------------------------------------------------------------------------


def attn_mlp_block(params, x, positions, cfg, window, kv_cache=None, cache_index=None,
                   positions_3d=None, fresh_cache=False):
    h = L.apply_norm(cfg.norm, params["norm1"], x)
    a, new_cache = A.attention_forward(
        params["attn"], h, positions, cfg, window=window,
        kv_cache=kv_cache, cache_index=cache_index, positions_3d=positions_3d,
        fresh_cache=fresh_cache,
    )
    x = x + a
    h = L.apply_norm(cfg.norm, params["norm2"], x)
    x = x + mlp_forward(params["mlp"], h, cfg)
    return x, new_cache


def attn_moe_block(params, x, positions, cfg, window, kv_cache=None, cache_index=None,
                   fresh_cache=False):
    h = L.apply_norm(cfg.norm, params["norm1"], x)
    a, new_cache = A.attention_forward(
        params["attn"], h, positions, cfg, window=window,
        kv_cache=kv_cache, cache_index=cache_index, fresh_cache=fresh_cache,
    )
    x = x + a
    h = L.apply_norm(cfg.norm, params["norm2"], x)
    m, aux = moe_forward(params["moe"], h, cfg)
    x = x + m
    return x, new_cache, aux


def mamba_block(params, x, cfg, state=None):
    h = L.apply_norm(cfg.norm, params["norm"], x)
    m, new_state = S.mamba_forward(params["mamba"], h, cfg, state)
    return x + m, new_state


# ---------------------------------------------------------------------------
# Stacked-scan drivers
# ---------------------------------------------------------------------------


def _remat(f, enabled: bool):
    return jax.checkpoint(f) if enabled else f


def dense_stack_forward(params, x, positions, cfg, windows, remat=True, positions_3d=None):
    """windows: int32 [L] per-layer sliding window (0 = full)."""

    def body(xc, layer):
        p, win = layer
        y, _ = attn_mlp_block(p, xc, positions, cfg, win, positions_3d=positions_3d)
        return y, None

    x, _ = jax.lax.scan(_remat(body, remat), x, (params, windows))
    return x


def moe_stack_forward(params, x, positions, cfg, windows, remat=True):
    def body(carry, layer):
        xc, aux = carry
        p, win = layer
        y, _, a = attn_moe_block(p, xc, positions, cfg, win)
        return (y, aux + a), None

    (x, aux), _ = jax.lax.scan(_remat(body, remat), (x, jnp.float32(0.0)), (params, windows))
    return x, aux


def mamba_stack_forward(params, x, cfg, remat=True):
    def body(xc, p):
        y, _ = mamba_block(p, xc, cfg)
        return y, None

    x, _ = jax.lax.scan(_remat(body, remat), x, params)
    return x


# decode variants: scan threads the per-layer cache --------------------------


def dense_stack_decode(params, x, positions, cfg, windows, caches, cache_index, fresh_cache=False):
    def body(xc, layer):
        p, win, cache = layer
        y, new_cache = attn_mlp_block(p, xc, positions, cfg, win, kv_cache=cache,
                                      cache_index=cache_index, fresh_cache=fresh_cache)
        return y, new_cache

    x, new_caches = jax.lax.scan(body, x, (params, windows, caches))
    return x, new_caches


def moe_stack_decode(params, x, positions, cfg, windows, caches, cache_index, fresh_cache=False):
    def body(xc, layer):
        p, win, cache = layer
        y, new_cache, _ = attn_moe_block(p, xc, positions, cfg, win, kv_cache=cache,
                                         cache_index=cache_index, fresh_cache=fresh_cache)
        return y, new_cache

    x, new_caches = jax.lax.scan(body, x, (params, windows, caches))
    return x, new_caches


def mamba_stack_decode(params, x, cfg, states):
    def body(xc, layer):
        p, st = layer
        y, new_st = mamba_block(p, xc, cfg, state=st)
        return y, new_st

    x, new_states = jax.lax.scan(body, x, (params, states))
    return x, new_states


# ---------------------------------------------------------------------------
# Layer schedules
# ---------------------------------------------------------------------------


def layer_windows(cfg: ModelConfig, n_layers: int, force_window: bool = False) -> jnp.ndarray:
    """Per-layer window array. gemma3: 5 local (sliding) : 1 global (full)."""
    win = cfg.sliding_window or 0
    if win == 0:
        return jnp.zeros((n_layers,), jnp.int32)
    if cfg.local_global_ratio > 0 and not force_window:
        period = cfg.local_global_ratio + 1
        flags = np.array(
            [0 if (i % period) == cfg.local_global_ratio else win for i in range(n_layers)],
            np.int32,
        )
        return jnp.asarray(flags)
    return jnp.full((n_layers,), win, jnp.int32)


# ---------------------------------------------------------------------------
# Full models
# ---------------------------------------------------------------------------


def model_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    s: Dict = {"embed": L.embed_specs(cfg.vocab_size, d)}
    if cfg.family in ("dense", "vlm"):
        s["layers"] = stack_specs(cfg, cfg.num_layers, "attn_mlp")
    elif cfg.family == "moe":
        nd = cfg.first_dense_layers
        if nd:
            s["dense_layers"] = stack_specs(cfg, nd, "attn_mlp")
        s["layers"] = stack_specs(cfg, cfg.num_layers - nd, "attn_moe")
    elif cfg.family == "ssm":
        s["layers"] = stack_specs(cfg, cfg.num_layers, "mamba")
    elif cfg.family == "hybrid":
        s["layers"] = stack_specs(cfg, cfg.num_layers, "mamba")
        s["shared_attn"] = block_specs(cfg, "attn_mlp")  # zamba2 shared block
    elif cfg.family == "audio":
        enc_cfg = cfg
        s["enc_layers"] = stack_specs(enc_cfg, cfg.encoder_layers, "attn_mlp")
        s["enc_norm"] = L.norm_specs(cfg.norm, d)
        s["layers"] = stack_specs(cfg, cfg.num_layers, "attn_mlp")  # decoder self-attn
        s["cross_layers"] = stack_specs(cfg, cfg.num_layers, "attn_mlp")  # cross-attn + mlp reuse
    else:
        raise ValueError(cfg.family)
    s["final_norm"] = L.norm_specs(cfg.norm, d)
    if not cfg.tie_embeddings:
        s["head"] = L.dense_specs(d, cfg.vocab_size, (None, "vocab"), scale=0.02)
    return s


def _vlm_inputs(cfg, params, tokens, vision_embeds):
    """qwen2-vl: prepend stubbed patch embeddings to the token embeddings."""
    x_txt = L.embed(params["embed"], tokens) * jnp.sqrt(jnp.float32(cfg.d_model)).astype(jnp.bfloat16)
    if vision_embeds is None:
        return x_txt, None
    B, P, _ = vision_embeds.shape
    x = jnp.concatenate([vision_embeds.astype(x_txt.dtype), x_txt], axis=1)
    # M-RoPE 3D positions: vision patches get (t=0, h, w) grid; text continues 1D
    side = max(1, int(np.sqrt(P)))
    hh = (jnp.arange(P) // side).astype(jnp.int32)
    ww = (jnp.arange(P) % side).astype(jnp.int32)
    p_vis = jnp.stack([jnp.zeros((P,), jnp.int32), hh, ww], axis=-1)
    t_txt = jnp.arange(tokens.shape[1], dtype=jnp.int32) + jnp.max(hh) + 1
    p_txt = jnp.stack([t_txt, t_txt, t_txt], axis=-1)
    p3 = jnp.concatenate([p_vis, p_txt], axis=0)[None].repeat(B, 0)
    return x, p3


def forward(
    cfg: ModelConfig,
    params,
    tokens,
    *,
    extra_embeds=None,
    remat: bool = True,
    force_window: bool = False,
):
    """Training/prefill forward -> (hidden [B,S,D], aux_loss)."""
    aux = jnp.float32(0.0)
    positions_3d = None
    if cfg.family == "vlm":
        x, positions_3d = _vlm_inputs(cfg, params, tokens, extra_embeds)
    elif cfg.family == "audio":
        x = L.embed(params["embed"], tokens)
    else:
        x = L.embed(params["embed"], tokens)
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    x = constrain(x, ("batch", "seq", "embed"))
    if cfg.family == "audio":
        x = audio_forward(params, x, extra_embeds, None, cfg, remat)
    else:
        x, aux = backbone_forward(cfg, params, x, remat=remat, force_window=force_window, positions_3d=positions_3d)
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    return x, aux


def backbone_forward(cfg: ModelConfig, params, x, *, remat=True, force_window=False, positions_3d=None):
    """Run the layer stacks over already-embedded inputs x [B, S, D]."""
    aux = jnp.float32(0.0)
    B, Stot = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Stot, dtype=jnp.int32), (B, Stot))
    windows = layer_windows(cfg, cfg.num_layers, force_window)

    if cfg.family in ("dense", "vlm"):
        x = dense_stack_forward(params["layers"], x, positions, cfg, windows, remat, positions_3d)
    elif cfg.family == "moe":
        nd = cfg.first_dense_layers
        if nd:
            x = dense_stack_forward(params["dense_layers"], x, positions, cfg, windows[:nd], remat)
        x, aux = moe_stack_forward(params["layers"], x, positions, cfg, windows[nd:], remat)
    elif cfg.family == "ssm":
        x = mamba_stack_forward(params["layers"], x, cfg, remat)
    elif cfg.family == "hybrid":
        x = hybrid_forward(params, x, positions, cfg, windows, remat, force_window)
    else:
        raise ValueError(cfg.family)
    return x, aux


def hybrid_forward(params, x, positions, cfg, windows, remat=True, force_window=False):
    """zamba2: mamba super-blocks with one SHARED attention block between them."""
    period = cfg.hybrid_attn_every or cfg.num_layers
    n_sb = cfg.num_layers // period
    win = jnp.int32(cfg.sliding_window if (cfg.sliding_window and force_window) else 0)
    shared = params["shared_attn"]

    def run_sb(xc, sb_params):
        def body(h, p):
            y, _ = mamba_block(p, h, cfg)
            return y, None

        xc, _ = jax.lax.scan(_remat(body, remat), xc, sb_params)
        return xc

    for i in range(n_sb):
        sb = jax.tree.map(lambda a: jax.lax.slice_in_dim(a, i * period, (i + 1) * period, axis=0), params["layers"])
        x = run_sb(x, sb)
        y, _ = attn_mlp_block(shared, x, positions, cfg, win)
        x = y
    rem = cfg.num_layers - n_sb * period
    if rem:
        sb = jax.tree.map(lambda a: jax.lax.slice_in_dim(a, n_sb * period, cfg.num_layers, axis=0), params["layers"])
        x = run_sb(x, sb)
    return x


def encode_audio(cfg, params, enc_embeds, remat=False):
    """whisper encoder over stubbed frame embeddings -> [B, Se, D].

    The ONE encoder entry point: the training forward (``audio_forward``) and
    the serving engine's prefill both run it. For serving, the encoder output
    is immediately projected to per-layer cross-attention K/V
    (``seed_audio_caches``) and carried in the decode caches as ``cross`` —
    decode never re-touches the encoder output itself.
    """
    B, Se = enc_embeds.shape[0], enc_embeds.shape[1]
    enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    zero_w = jnp.zeros((cfg.encoder_layers,), jnp.int32)

    def enc_body(h, layer):
        p, _ = layer
        hn = L.apply_norm(cfg.norm, p["norm1"], h)
        # bidirectional self-attention == unmasked cross-attention with itself
        a = cross_attention(p["attn"], hn, hn, enc_pos, enc_pos, cfg)
        h = h + a
        hn = L.apply_norm(cfg.norm, p["norm2"], h)
        h = h + mlp_forward(p["mlp"], hn, cfg)
        return h, None

    enc, _ = jax.lax.scan(_remat(enc_body, remat), enc_embeds, (params["enc_layers"], zero_w))
    return L.apply_norm(cfg.norm, params["enc_norm"], enc)


def audio_forward(params, dec_tokens_embedded, enc_embeds, positions, cfg, remat=True):
    """whisper: encoder over stubbed frames, decoder w/ interleaved cross-attn."""
    B = dec_tokens_embedded.shape[0]
    Se = enc_embeds.shape[1]
    enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    enc = encode_audio(cfg, params, enc_embeds.astype(dec_tokens_embedded.dtype), remat)

    x = dec_tokens_embedded
    Sd = x.shape[1]
    dpos = jnp.broadcast_to(jnp.arange(Sd, dtype=jnp.int32), (B, Sd))

    def dec_body(h, layer):
        p_self, p_cross = layer
        hn = L.apply_norm(cfg.norm, p_self["norm1"], h)
        a, _ = A.attention_forward(p_self["attn"], hn, dpos, cfg, window=0)
        h = h + a
        hn = L.apply_norm(cfg.norm, p_self["norm2"], h)
        h = h + mlp_forward(p_self["mlp"], hn, cfg)
        # cross-attention: queries from decoder, kv from encoder
        hn = L.apply_norm(cfg.norm, p_cross["norm1"], h)
        c = cross_attention(p_cross["attn"], hn, enc, dpos, enc_pos, cfg)
        h = h + c
        hn = L.apply_norm(cfg.norm, p_cross["norm2"], h)
        h = h + mlp_forward(p_cross["mlp"], hn, cfg)
        return h, None

    x, _ = jax.lax.scan(_remat(dec_body, remat), x, (params["layers"], params["cross_layers"]))
    return x


def cross_attention(params, xq, xkv, q_pos, k_pos, cfg):
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"].astype(xq.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["wk"].astype(xq.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["wv"].astype(xq.dtype))
    bias = jnp.zeros((q_pos.shape[0], q_pos.shape[1], k_pos.shape[1]), jnp.float32)
    out = A._sdpa(q, k, v, bias, hd ** -0.5)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(xq.dtype))


def cross_attention_cached(params, xq, k, v, cfg):
    """Cross-attention against PRE-PROJECTED encoder K/V ([B, Se, KH, hd]).

    The decode-path twin of ``cross_attention``: only the query projection
    runs per step — the K/V einsums that used to dominate whisper decode
    (satellite bugfix: the 1.2× decode ratio) happen once at prefill.
    """
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"].astype(xq.dtype))
    bias = jnp.zeros((xq.shape[0], xq.shape[1], k.shape[1]), jnp.float32)
    out = A._sdpa(q, k.astype(xq.dtype), v.astype(xq.dtype), bias, hd ** -0.5)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(xq.dtype))


def audio_cross_kv(cfg, params, enc):
    """Project encoder output to stacked per-layer cross K/V.

    enc [B, Se, D] -> (k, v) each [L, B, Se, KH, hd]: one einsum over the
    layer-stacked weights instead of L per-step projections.
    """
    wk = params["cross_layers"]["attn"]["wk"]
    wv = params["cross_layers"]["attn"]["wv"]
    k = jnp.einsum("bsd,ldhk->lbshk", enc, wk.astype(enc.dtype))
    v = jnp.einsum("bsd,ldhk->lbshk", enc, wv.astype(enc.dtype))
    return k, v


def seed_audio_caches(cfg, params, caches, enc_embeds):
    """Run the encoder and fill the read-only ``cross`` K/V cache leaves.

    Serving prefill entry point: quantizes per row when the cache layout is
    int8 (4 leaves), otherwise casts to the cache dtype.
    """
    enc = encode_audio(cfg, params, enc_embeds)
    k, v = audio_cross_kv(cfg, params, enc)
    cross = caches["cross"]
    if len(cross) == 4:
        kq, ks = quantize_rows(k)
        vq, vs = quantize_rows(v)
        new_cross = (kq, vq, ks, vs)
    else:
        new_cross = (k.astype(cross[0].dtype), v.astype(cross[1].dtype))
    return {**caches, "cross": new_cross}


def logits_from_hidden(cfg: ModelConfig, params, hidden):
    if cfg.tie_embeddings:
        out = L.unembed(params["embed"], hidden)
    else:
        out = L.dense(params["head"], hidden)
    return constrain(out, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(logits, labels, z_loss: float = 0.0):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


CE_CHUNK = 512


def chunked_lm_head_loss(cfg: ModelConfig, params, hidden, labels, remat=True):
    """Fused head-matmul + cross-entropy over sequence chunks (§Perf it. 6).

    The full [B, S, V] logits tensor (and its fp32 copies inside logsumexp)
    never materializes: each scan step computes a [B, CE_CHUNK, V] slab,
    reduces it to a scalar, and is rematerialized in the backward pass.
    """
    B, S, D = hidden.shape
    chunk = min(CE_CHUNK, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nch = hidden.shape[1] // chunk
    h_c = jnp.moveaxis(hidden.reshape(B, nch, chunk, D), 1, 0)
    y_c = jnp.moveaxis(labels.reshape(B, nch, chunk), 1, 0)

    def body(acc, xs):
        hc, yc = xs
        logits = logits_from_hidden(cfg, params, hc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(yc, 0)[..., None], axis=-1)[..., 0]
        valid = (yc >= 0).astype(jnp.float32)
        return acc + jnp.sum((lse - ll) * valid), None

    body = _remat(body, remat)
    total, _ = jax.lax.scan(body, jnp.float32(0.0), (h_c, y_c))
    return total / (B * S)


def lm_loss(cfg: ModelConfig, params, batch, remat=True, aux_weight=0.01, force_window=False):
    hidden, aux = forward(
        cfg, params, batch["tokens"], extra_embeds=batch.get("extra_embeds"),
        remat=remat, force_window=force_window,
    )
    if cfg.family == "vlm" and batch.get("extra_embeds") is not None:
        hidden = hidden[:, batch["extra_embeds"].shape[1] :]
    return chunked_lm_head_loss(cfg, params, hidden, batch["labels"], remat) + aux_weight * aux


# ---------------------------------------------------------------------------
# Decode (serve_step core)
# ---------------------------------------------------------------------------


def make_decode_caches(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for stacked per-layer caches + logical axes trees.

    ``dtype=int8`` selects the quantized cache layouts (extra f32 scale
    leaves; see models/quant.py). SSM states stay f32 for every non-int8
    dtype — the recurrence is precision-sensitive — but adopt the quantized
    layout under int8 so the whole cache tree shrinks together.
    """
    # SSM recurrences carry f32 state unless explicitly quantized to int8
    sdtype = dtype if is_int8(dtype) else jnp.float32
    if cfg.family in ("dense", "vlm", "moe"):
        shapes, axes = A.make_kv_cache_specs(cfg, batch, cache_len, dtype)
        Lx = cfg.num_layers
        stacked = tuple(jax.ShapeDtypeStruct((Lx,) + s.shape, s.dtype) for s in shapes)
        st_axes = tuple(("stack",) + a for a in axes)
        return {"kv": stacked}, {"kv": st_axes}
    if cfg.family == "ssm":
        shapes, axes = S.mamba_state_specs(cfg, batch, sdtype)
        Lx = cfg.num_layers
        stacked = tuple(jax.ShapeDtypeStruct((Lx,) + s.shape, s.dtype) for s in shapes)
        st_axes = tuple(("stack",) + a for a in axes)
        return {"ssm": stacked}, {"ssm": st_axes}
    if cfg.family == "hybrid":
        sshapes, saxes = S.mamba_state_specs(cfg, batch, sdtype)
        Lx = cfg.num_layers
        ssm_stacked = tuple(jax.ShapeDtypeStruct((Lx,) + s.shape, s.dtype) for s in sshapes)
        ssm_axes = tuple(("stack",) + a for a in saxes)
        period = cfg.hybrid_attn_every or cfg.num_layers
        n_sb = cfg.num_layers // period
        win = cfg.sliding_window or cache_len
        attn_len = min(cache_len, win)
        kshapes, kaxes = A.make_kv_cache_specs(cfg, batch, attn_len, dtype)
        kv_stacked = tuple(jax.ShapeDtypeStruct((n_sb,) + s.shape, s.dtype) for s in kshapes)
        kv_axes = tuple(("stack",) + a for a in kaxes)
        return {"ssm": ssm_stacked, "kv": kv_stacked}, {"ssm": ssm_axes, "kv": kv_axes}
    if cfg.family == "audio":
        kshapes, kaxes = A.make_kv_cache_specs(cfg, batch, cache_len, dtype)
        Lx = cfg.num_layers
        self_kv = tuple(jax.ShapeDtypeStruct((Lx,) + s.shape, s.dtype) for s in kshapes)
        self_axes = tuple(("stack",) + a for a in kaxes)
        # cross-attention K/V, projected ONCE from the encoder output at
        # prefill (seed_audio_caches) and read-only during decode — replaces
        # the old raw ``enc_out`` leaf that forced a re-projection per step
        KH, hd, Se = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.encoder_seq
        cshapes = [jax.ShapeDtypeStruct((Lx, batch, Se, KH, hd), dtype)] * 2
        caxes = [("stack", "batch", None, "kv_heads", None)] * 2
        if is_int8(dtype):
            cshapes += [jax.ShapeDtypeStruct((Lx, batch, Se, KH), jnp.float32)] * 2
            caxes += [("stack", "batch", None, "kv_heads")] * 2
        return (
            {"kv": self_kv, "cross": tuple(cshapes)},
            {"kv": self_axes, "cross": tuple(caxes)},
        )
    raise ValueError(cfg.family)


def init_decode_caches(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    """Concrete zero caches with the position track set to the INT32_MAX
    sentinel so unwritten slots never pass the causal mask."""
    sds, _ = make_decode_caches(cfg, batch, cache_len, dtype)

    def init_one(s):
        if s.dtype == jnp.int32:
            return jnp.full(s.shape, jnp.iinfo(jnp.int32).max, jnp.int32)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree.map(init_one, sds, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def decode_step(cfg: ModelConfig, params, tokens, caches, index, force_window=False,
                fresh_cache=False):
    """One cache-threading forward: single decode token OR a whole prefill block.

    tokens: [B, S] token ids (S == 1 for classic decode). ``index`` is either
    a scalar cache write position — the S tokens land contiguously at
    [index, index + S) with ONE ``dynamic_update_slice`` per layer (batched
    single-pass prefill) — or an int32 [B] vector of per-slot positions
    (the serving engine's continuous batching, where freed slots sit at
    different depths; S > 1 with a vector index is the speculative verify
    block — each row writes S tokens at [index[b], index[b] + S)).
    ``fresh_cache`` (static) asserts nothing precedes this write in the
    cache, routing long prefill blocks through the flash attention path
    instead of cache-wide scores.

    Returns (logits [B, S, V], new_caches).
    """
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if jnp.ndim(index) == 1:
        if S != 1 and cfg.family == "hybrid":
            # ring-buffer attention caches wrap write positions with a
            # remainder; the vector multi-token write drops instead of
            # wrapping, so spans crossing the ring edge would be lost
            raise ValueError("hybrid ring caches take single-token vector writes only")
        positions = jnp.asarray(index, jnp.int32)[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    else:
        positions = jnp.broadcast_to(
            jnp.asarray(index, jnp.int32) + jnp.arange(S, dtype=jnp.int32), (B, S)
        )
    windows = layer_windows(cfg, cfg.num_layers, force_window)

    if cfg.family in ("dense", "vlm"):
        x, new_kv = dense_stack_decode(params["layers"], x, positions, cfg, windows,
                                       caches["kv"], index, fresh_cache)
        new_caches = {"kv": new_kv}
    elif cfg.family == "moe":
        nd = cfg.first_dense_layers
        kv = caches["kv"]
        if nd:
            head_kv = jax.tree.map(lambda a: a[:nd], kv)
            tail_kv = jax.tree.map(lambda a: a[nd:], kv)
            x, new_head = dense_stack_decode(params["dense_layers"], x, positions, cfg,
                                             windows[:nd], head_kv, index, fresh_cache)
            x, new_tail = moe_stack_decode(params["layers"], x, positions, cfg,
                                           windows[nd:], tail_kv, index, fresh_cache)
            new_kv = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0), new_head, new_tail)
        else:
            x, new_kv = moe_stack_decode(params["layers"], x, positions, cfg, windows,
                                         kv, index, fresh_cache)
        new_caches = {"kv": new_kv}
    elif cfg.family == "ssm":
        x, new_ssm = mamba_stack_decode(params["layers"], x, cfg, caches["ssm"])
        new_caches = {"ssm": new_ssm}
    elif cfg.family == "hybrid":
        x, new_caches = _hybrid_decode(cfg, params, x, positions, caches, index)
    elif cfg.family == "audio":
        x, new_caches = _audio_decode(cfg, params, x, positions, caches, index, fresh_cache)
    else:
        raise ValueError(cfg.family)

    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    return logits_from_hidden(cfg, params, x), new_caches


def supports_self_speculation(cfg: ModelConfig) -> bool:
    """Self-speculative decoding needs (a) a homogeneous stacked layer scan to
    truncate and (b) caches that can be safely overwritten on rejection.
    Attention caches qualify — a rejected slot is rewritten before it is ever
    attended (writes precede reads and positions advance monotonically) — but
    SSM/hybrid recurrent state cannot roll back, so those families are out.
    """
    return cfg.family in ("dense", "vlm", "moe")


def draft_decode_step(cfg: ModelConfig, params, tokens, caches, index, draft_layers: int):
    """Truncated-depth (early-exit self-speculative) draft pass.

    Runs only the FIRST ``draft_layers`` of the stacked scan and reads draft
    logits off the shared residual trunk (final_norm + lm head). tokens:
    [B, 1]; ``index``: int32 [B] per-slot write positions. The draft's cache
    writes for layers < draft_layers are identical to what the verify pass
    will rewrite (same trunk, same inputs), so speculation never corrupts the
    cache. Returns (logits [B, 1, V], new_caches) with the updated layer-head
    caches spliced back into the full stack.
    """
    if not supports_self_speculation(cfg):
        raise ValueError(f"self-speculation unsupported for family {cfg.family!r}")
    if not (0 < draft_layers < cfg.num_layers):
        raise ValueError(f"draft_layers must be in (0, {cfg.num_layers}), got {draft_layers}")
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    positions = jnp.asarray(index, jnp.int32)[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    windows = layer_windows(cfg, cfg.num_layers)
    kv = caches["kv"]
    head_kv = jax.tree.map(lambda a: a[:draft_layers], kv)

    if cfg.family in ("dense", "vlm"):
        sub = jax.tree.map(lambda a: a[:draft_layers], params["layers"])
        x, new_head = dense_stack_decode(sub, x, positions, cfg, windows[:draft_layers],
                                         head_kv, index)
    else:  # moe: dense head layers first, then truncated moe stack
        nd = cfg.first_dense_layers
        k1 = min(draft_layers, nd)
        new_parts = []
        if k1:
            sub = jax.tree.map(lambda a: a[:k1], params["dense_layers"])
            x, nh = dense_stack_decode(sub, x, positions, cfg, windows[:k1],
                                       jax.tree.map(lambda a: a[:k1], head_kv), index)
            new_parts.append(nh)
        k2 = draft_layers - k1
        if k2:
            sub = jax.tree.map(lambda a: a[:k2], params["layers"])
            x, nt = moe_stack_decode(sub, x, positions, cfg, windows[nd : nd + k2],
                                     jax.tree.map(lambda a: a[k1:], head_kv), index)
            new_parts.append(nt)
        new_head = (
            jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0), *new_parts)
            if len(new_parts) > 1 else new_parts[0]
        )

    new_kv = jax.tree.map(
        lambda full, nh: jax.lax.dynamic_update_slice_in_dim(full, nh, 0, axis=0),
        kv, new_head,
    )
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    return logits_from_hidden(cfg, params, x), {"kv": new_kv}


def _hybrid_decode(cfg, params, x, positions, caches, index):
    period = cfg.hybrid_attn_every or cfg.num_layers
    n_sb = cfg.num_layers // period
    win = cfg.sliding_window or 0
    attn_len = caches["kv"][0].shape[2]
    widx = jnp.remainder(index, attn_len) if win else index
    new_ssm, new_kv = [], []
    ssm, kv = caches["ssm"], caches["kv"]
    for i in range(n_sb):
        sb = jax.tree.map(lambda a: jax.lax.slice_in_dim(a, i * period, (i + 1) * period, axis=0), params["layers"])
        st = jax.tree.map(lambda a: jax.lax.slice_in_dim(a, i * period, (i + 1) * period, axis=0), ssm)
        x, st_new = mamba_stack_decode(sb, x, cfg, st)
        new_ssm.append(st_new)
        cache_i = jax.tree.map(lambda a: a[i], kv)
        x, kv_new = _shared_attn_decode(cfg, params["shared_attn"], x, positions, cache_i, widx, win)
        new_kv.append(kv_new)
    rem = cfg.num_layers - n_sb * period
    if rem:
        sb = jax.tree.map(lambda a: jax.lax.slice_in_dim(a, n_sb * period, cfg.num_layers, axis=0), params["layers"])
        st = jax.tree.map(lambda a: jax.lax.slice_in_dim(a, n_sb * period, cfg.num_layers, axis=0), ssm)
        x, st_new = mamba_stack_decode(sb, x, cfg, st)
        new_ssm.append(st_new)
    new_ssm = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_ssm) if len(new_ssm) > 1 else new_ssm[0]
    new_kv = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_kv)
    return x, {"ssm": new_ssm, "kv": new_kv}


def _shared_attn_decode(cfg, p, x, positions, cache, write_idx, window):
    h = L.apply_norm(cfg.norm, p["norm1"], x)
    a, new_cache = A.gqa_forward(p["attn"], h, positions, cfg, window=window, kv_cache=cache, cache_index=write_idx)
    x = x + a
    h = L.apply_norm(cfg.norm, p["norm2"], x)
    x = x + mlp_forward(p["mlp"], h, cfg)
    return x, new_cache


def _audio_decode(cfg, params, x, positions, caches, index, fresh_cache=False):
    cross = caches["cross"]
    quant = len(cross) == 4

    def body(xc, layer):
        p_self, p_cross, cache = layer[:3]
        if quant:
            ck = dequantize_rows(layer[3], layer[5], xc.dtype)
            cv = dequantize_rows(layer[4], layer[6], xc.dtype)
        else:
            ck, cv = layer[3], layer[4]
        h = L.apply_norm(cfg.norm, p_self["norm1"], xc)
        a, new_cache = A.gqa_forward(p_self["attn"], h, positions, cfg, window=0,
                                     kv_cache=cache, cache_index=index, fresh_cache=fresh_cache)
        xc = xc + a
        h = L.apply_norm(cfg.norm, p_self["norm2"], xc)
        xc = xc + mlp_forward(p_self["mlp"], h, cfg)
        h = L.apply_norm(cfg.norm, p_cross["norm1"], xc)
        c = cross_attention_cached(p_cross["attn"], h, ck, cv, cfg)
        xc = xc + c
        h = L.apply_norm(cfg.norm, p_cross["norm2"], xc)
        xc = xc + mlp_forward(p_cross["mlp"], h, cfg)
        return xc, new_cache

    xs = (params["layers"], params["cross_layers"], caches["kv"]) + tuple(cross)
    x, new_kv = jax.lax.scan(body, x, xs)
    return x, {"kv": new_kv, "cross": cross}
