from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adam,
    halving_schedule,
    make_optimizer,
    momentum,
    sgd,
)
