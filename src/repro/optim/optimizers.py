"""Optimizers. The paper's algorithm is plain SGD (HSGD = hybrid SGD) with a
learning rate halved every T0 iterations (§VII-A3); momentum/Adam are provided
as beyond-paper options for the framework.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params, lr):
        new_state = jax.tree.map(lambda m, g: beta * m + g.astype(m.dtype), state, grads)
        new = jax.tree.map(lambda p, m: p - lr * m, params, new_state)
        return new, new_state

    return Optimizer(init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.zeros_like, z), "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new = jax.tree.map(
            lambda p, m_, v_: (p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)).astype(p.dtype),
            params, m, v,
        )
        return new, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def make_optimizer(name: str) -> Optimizer:
    if name == "sgd":
        return sgd()
    if name == "momentum":
        return momentum()
    if name == "adam":
        return adam()
    raise ValueError(f"unknown optimizer {name}")


def halving_schedule(base_lr: float, halve_every: int) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Paper §VII-A3: initial η decays halved per T0 iterations."""

    def lr(step):
        if halve_every <= 0:
            return jnp.asarray(base_lr, jnp.float32)
        return base_lr * 0.5 ** jnp.floor(step / halve_every).astype(jnp.float32)

    return lr
