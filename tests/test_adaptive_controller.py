"""Closed-loop adaptive controller: governor logic, compile-cache staging,
probe plumbing, and the paper's Fig. 7 claim in miniature (slow)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FederationConfig, TrainConfig
from repro.core import comm_model as CM
from repro.core.adaptive import estimate_rho_delta
from repro.core.compression import COMPRESSION_LADDER
from repro.core.controller import (
    AdaptiveConfig,
    AdaptiveHSGDRunner,
    ladder_from,
    plan_round,
)
from repro.core.hsgd import HSGDRunner, init_state, make_group_weights
from repro.core.metrics import smoothed_losses
from repro.data.partition import hybrid_partition
from repro.data.synthetic import MIMIC3, ORGANAMNIST, make_dataset
from repro.models.split_model import cnn_hybrid, lstm_hybrid


def _mini_cnn(M=2, K=8, q=2, p=4):
    fed = FederationConfig(num_groups=M, devices_per_group=K, alpha=0.5,
                           local_interval=q, global_interval=p)
    X, y = make_dataset(ORGANAMNIST, M * K, seed=0)
    fd = hybrid_partition(ORGANAMNIST, X, y, fed, seed=0)
    data = {k: jnp.asarray(v) for k, v in fd.stacked().items()}
    return cnn_hybrid(h_rows=11), fed, data


def _sizes_of_const(k_frac, levels):
    """Constant message sizes for pure planning tests."""
    n = 10_000
    comp = CM.compressed_bytes(n, k_frac or 1.0, levels) if (k_frac or levels) else n * 4
    return CM.MessageSizes(theta0=comp, theta1=4e4, theta2=1e4,
                           z1=comp / 10, z2=comp / 10, n_active=4)


PROBE = {"rho": 2.0, "delta": 0.5, "F0": 1.0, "grad_norm_sq": 1.0}


# ---------------------------------------------------------------------------
# plan_round: strategies + governor (pure, no training)
# ---------------------------------------------------------------------------


def test_plan_byte_governor_tightens_to_fit_budget():
    fed = FederationConfig(num_groups=4)
    cfg = AdaptiveConfig(total_steps=100, byte_budget=1.0)  # impossible budget
    plan = plan_round(PROBE, 0, 0.0, 0, 0.01, cfg, fed, _sizes_of_const)
    assert plan.rung == len(COMPRESSION_LADDER) - 1  # ratcheted to tightest

    cfg = AdaptiveConfig(total_steps=100, byte_budget=math.inf)
    plan = plan_round(PROBE, 0, 0.0, 0, 0.01, cfg, fed, _sizes_of_const)
    assert plan.rung == 0  # no pressure, message stays uncompressed


def test_plan_governor_projection_monotone_in_rung():
    """Each ladder rung strictly shrinks the projected bill (sanity of the
    ladder ordering the ratchet relies on)."""
    fed = FederationConfig(num_groups=4)
    per_iter = [CM.comm_cost_per_iteration(_sizes_of_const(k, b),
                                           FederationConfig(local_interval=2,
                                                            global_interval=2))
                for k, b in COMPRESSION_LADDER]
    assert all(b < a for a, b in zip(per_iter, per_iter[1:]))


def test_ladder_from_user_compression():
    """An explicitly requested (k, b) becomes rung 0 and the ladder only ever
    tightens from it (the c-hsgd --adaptive contract)."""
    lad = ladder_from(0.25, 128)
    assert lad[0] == (0.25, 128)
    n = 1 << 20
    wire = [CM.compressed_bytes(n, k or 1.0, b) for k, b in lad]
    assert all(b < a for a, b in zip(wire, wire[1:]))  # strictly tighter
    assert ladder_from(0.0, 0) == COMPRESSION_LADDER  # no request -> default


def test_eta_floor_yields_to_theorem_cap():
    """cfg.eta_min must not push η above 1/(8Pρ) — the Γ guard's formula is
    only valid under Theorem 1's step-size condition."""
    from repro.core.adaptive import max_learning_rate

    fed = FederationConfig(num_groups=4)
    probe = dict(PROBE, rho=50.0)  # cap at P=32: 1/(8*32*50) ≈ 7.8e-5 < eta_min
    cfg = AdaptiveConfig(total_steps=1000, max_interval=32, eta_min=1e-3)
    plan = plan_round(probe, 0, 0.0, 0, 0.01, cfg, fed, _sizes_of_const)
    assert plan.eta <= max_learning_rate(plan.P, probe["rho"]) * (1 + 1e-12)


def test_plan_theorem1_guard_shrinks_interval():
    fed = FederationConfig(num_groups=4)
    loose = AdaptiveConfig(total_steps=1000, target_bound=math.inf, max_interval=64)
    tight = AdaptiveConfig(total_steps=1000, target_bound=1e-6, max_interval=64)
    p_loose = plan_round(PROBE, 0, 0.0, 0, 0.01, loose, fed, _sizes_of_const)
    p_tight = plan_round(PROBE, 0, 0.0, 0, 0.01, tight, fed, _sizes_of_const)
    assert p_tight.P <= p_loose.P
    assert p_tight.P == 1  # an unreachable Ξ degrades to per-step sync


def test_plan_respects_caps_and_strategy1():
    fed = FederationConfig(num_groups=4)
    cfg = AdaptiveConfig(total_steps=6, max_interval=64)
    plan = plan_round(PROBE, 0, 0.0, 0, 0.01, cfg, fed, _sizes_of_const)
    assert plan.Q == plan.P  # strategy 1: Λ = 1
    assert plan.P <= 6  # never overshoots the remaining step budget
    assert plan.P & (plan.P - 1) == 0  # power-of-two bucket
    assert cfg.eta_min <= plan.eta <= cfg.eta_max


# ---------------------------------------------------------------------------
# round_fn: per-(P,Q,k,b) staging
# ---------------------------------------------------------------------------


def test_round_fn_compile_cache_and_validation():
    model, fed, data = _mini_cnn()
    runner = HSGDRunner(model, fed, TrainConfig(learning_rate=0.02))
    f1 = runner.round_fn(4, 2, 0.25, 128)
    assert runner.round_fn(4, 2, 0.25, 128) is f1  # bucket cached
    assert runner.round_fn(4, 4, 0.25, 128) is not f1
    assert runner.round_fn(4, 2, 0.0, 0) is not f1
    with pytest.raises(ValueError):
        runner.round_fn(4, 3)  # P not a multiple of Q
    with pytest.raises(ValueError):
        runner.round_fn(0, 1)


def test_round_fn_matches_fixed_run():
    """The staged one-round executor is the same computation as run(rounds=1)
    at the same (P, Q, η) — the adaptive path can't silently diverge."""
    model, fed, data = _mini_cnn()
    train = TrainConfig(learning_rate=0.02)
    runner = HSGDRunner(model, fed, train)
    w = make_group_weights(data)
    s1 = init_state(jax.random.PRNGKey(0), model, fed, data)
    s2 = init_state(jax.random.PRNGKey(0), model, fed, data)
    _, l_run = runner.run(s1, data, w, rounds=1)
    fn = runner.round_fn(fed.global_interval, fed.local_interval,
                         collect_stats=False)
    _, l_round = fn(s2, data, w, train.learning_rate)
    np.testing.assert_allclose(np.asarray(l_run), np.asarray(l_round), rtol=1e-6)


def test_round_fn_stats_shapes_and_rho_validity():
    model, fed, data = _mini_cnn(q=2, p=4)
    runner = HSGDRunner(model, fed, TrainConfig(learning_rate=0.02))
    w = make_group_weights(data)
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    fn = runner.round_fn(4, 2, collect_stats=True)
    state, stats = fn(state, data, w, 0.02)
    assert {"loss", "gnorm2", "delta2", "rho", "rho_ok"} <= set(stats)
    for v in stats.values():
        assert np.asarray(v).shape == (4,)
    ok = np.asarray(stats["rho_ok"])
    # Q=2 intervals: first step of each interval has no within-interval pair
    np.testing.assert_array_equal(ok, [0.0, 1.0, 0.0, 1.0])
    assert (np.asarray(stats["rho"])[ok > 0.5] > 0).all()
    assert (np.asarray(stats["delta2"]) >= 0).all()


# ---------------------------------------------------------------------------
# controller loop
# ---------------------------------------------------------------------------


def test_controller_accounting_and_ratchet():
    model, fed, data = _mini_cnn()
    w = make_group_weights(data)
    cfg = AdaptiveConfig(total_steps=12, byte_budget=1e6, max_interval=4,
                         init_probe=False)
    ctl = AdaptiveHSGDRunner(model, fed, TrainConfig(learning_rate=0.02), cfg)
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    state, losses, history = ctl.run(state, data, w)
    assert len(losses) == cfg.total_steps
    assert sum(h["P"] for h in history) == cfg.total_steps
    assert all(h["Q"] == h["P"] for h in history)  # strategy 1 throughout
    bytes_curve = [h["bytes_total"] for h in history]
    assert all(b > a for a, b in zip(bytes_curve, bytes_curve[1:]))  # cumulative
    rungs = [h["rung"] for h in history]
    assert all(b >= a for a, b in zip(rungs, rungs[1:]))  # ladder is a ratchet
    assert np.isfinite(losses).all()


def test_estimate_rho_delta_batch_guard():
    """batch > M*K used to crash jax.random.choice(replace=False); now the
    probe clamps to the population size."""
    model, fed, data = _mini_cnn(M=2, K=4)  # only 8 samples
    params = model.init(jax.random.PRNGKey(0))
    probe = estimate_rho_delta(model, params, data, jax.random.PRNGKey(1),
                               n_probes=3, batch=64)
    assert probe["rho"] > 0 and probe["F0"] > 0
    assert math.isfinite(probe["delta"]) and math.isfinite(probe["grad_norm_sq"])


# ---------------------------------------------------------------------------
# Fig. 7 in miniature (slow): same step budget, better loss, fewer bytes
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_adaptive_matches_fixed_loss_with_fewer_bytes():
    """Seeded regression of the paper's headline claim: the closed-loop
    controller reaches the fixed-(P=Q=1) baseline's loss while spending
    strictly less modeled communication."""
    steps = 24
    fed = FederationConfig(num_groups=2, devices_per_group=16, alpha=0.25,
                           local_interval=1, global_interval=1)
    train = TrainConfig(learning_rate=0.01)
    X, y = make_dataset(MIMIC3, 256, seed=0)
    fd = hybrid_partition(MIMIC3, X, y, fed, seed=0)
    data = {k: jnp.asarray(v) for k, v in fd.stacked().items()}
    model = lstm_hybrid(n_features=76, hospital_features=36,
                        n_classes=MIMIC3.n_classes)
    w = make_group_weights(data)

    # fixed baseline + its modeled bill
    runner = HSGDRunner(model, fed, train)
    s = init_state(jax.random.PRNGKey(0), model, fed, data)
    s, fixed_losses = runner.run(s, data, w, rounds=steps)
    fixed_losses = np.asarray(jax.device_get(fixed_losses))
    params = model.init(jax.random.PRNGKey(0))
    z_el = fed.sampled_devices * 64
    sizes = CM.message_sizes(params, z_el, z_el, fed.sampled_devices)
    fixed_bytes = CM.comm_cost_per_iteration(sizes, fed) * fed.num_groups * steps

    # adaptive under half the fixed bill
    cfg = AdaptiveConfig(total_steps=steps, byte_budget=0.5 * fixed_bytes,
                         max_interval=8, eta_max=0.05)
    ctl = AdaptiveHSGDRunner(model, fed, train, cfg)
    s2 = init_state(jax.random.PRNGKey(0), model, fed, data)
    s2, ad_losses, history = ctl.run(s2, data, w,
                                     probe_key=jax.random.PRNGKey(1))
    ad_bytes = history[-1]["bytes_total"]

    fixed_final = float(smoothed_losses(fixed_losses, 4)[-1])
    ad_final = float(smoothed_losses(ad_losses, 4)[-1])
    assert ad_final <= fixed_final  # (a) at least the baseline's quality
    assert ad_bytes < fixed_bytes  # (b) strictly cheaper, modeled via eq. (19)