"""Per-assigned-architecture smoke tests: a REDUCED variant of each family
runs one forward/train step and one decode step on CPU, asserting output
shapes and the absence of NaNs (full configs are exercised via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import get_config
from repro.configs import ASSIGNED
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.split_model import llm_hybrid


def _batch_for(cfg, B=2, S=16):
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["extra_embeds"] = jnp.ones((B, 4, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["extra_embeds"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = L.init_params(T.model_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(lambda p: T.lm_loss(cfg, p, batch, remat=False))(params)
    assert np.isfinite(float(loss))
    # one SGD step changes the loss
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2 = T.lm_loss(cfg, params2, batch, remat=False)
    assert np.isfinite(float(loss2)) and float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_serve_step(arch):
    cfg = get_config(arch, smoke=True)
    B, cache_len = 2, 24
    params = L.init_params(T.model_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    caches = T.init_decode_caches(cfg, B, cache_len, jnp.float32)
    if cfg.family == "audio":
        enc = jnp.ones((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        caches = T.seed_audio_caches(cfg, params, caches, enc)
    logits, new_caches = T.decode_step(cfg, params, jnp.ones((B, 1), jnp.int32),
                                       caches, jnp.int32(2))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_hsgd_hybrid_step(arch):
    """The paper's technique applied to each architecture (reduced config)."""
    from repro.launch.steps import make_exchange_step, make_hsgd_train_step

    cfg = get_config(arch, smoke=True)
    model = llm_hybrid(cfg, n_tower=1, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    if cfg.family == "vlm":
        x1 = jnp.ones((B, 4, cfg.d_model), jnp.float32)
        x2 = jnp.ones((B, S), jnp.int32)
        y = jnp.ones((B, S), jnp.int32)
    elif cfg.family == "audio":
        x1 = jnp.ones((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        x2 = jnp.ones((B, S), jnp.int32)
        y = jnp.ones((B, S), jnp.int32)
    else:
        x1 = jnp.ones((B, S // 2), jnp.int32)
        x2 = jnp.ones((B, S // 2), jnp.int32)
        y = jnp.ones((B, S), jnp.int32)
    batch = {"x1": x1, "x2": x2, "y": y}
    exch = make_exchange_step(model)
    step = make_hsgd_train_step(model, lr=0.01)
    stale = exch(params, batch)
    new_params, loss = step(params, stale, batch)
    assert np.isfinite(float(loss))
    # parameters actually moved on all three components
    for part in ("theta0", "theta1", "theta2"):
        moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                             params[part], new_params[part])
        assert max(jax.tree_util.tree_leaves(moved)) > 0


def test_param_counts_in_expected_band():
    """Analytic counts should land near the published sizes."""
    expected = {
        "gemma3-1b": (0.7e9, 1.4e9),
        "gemma3-4b": (3.0e9, 5.0e9),
        "stablelm-1.6b": (1.2e9, 2.0e9),
        "nemotron-4-15b": (13e9, 18e9),
        "zamba2-2.7b": (1.8e9, 3.3e9),
        "falcon-mamba-7b": (5.5e9, 8.5e9),
        "whisper-medium": (0.5e9, 1.3e9),
        "deepseek-v3-671b": (600e9, 760e9),
        "grok-1-314b": (280e9, 350e9),
        "qwen2-vl-72b": (62e9, 82e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]"
