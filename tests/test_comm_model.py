"""§VII-A3 wall-time model: hand-computed WAN expectations + property sweeps.

The hypothesis-powered twins of the property sweeps live in
``test_properties.py`` (the repo's optional-hypothesis module); the seeded
grid sweeps here always run, so the invariants stay covered even where
hypothesis isn't installed.
"""
import dataclasses

import numpy as np
import pytest

from repro.common.config import FederationConfig
from repro.core.comm_model import (
    MBIT,
    WAN,
    MessageSizes,
    round_time,
    round_time_hetero,
    time_to_step,
)

SIZES = MessageSizes(theta0=4e5, theta1=8e5, theta2=1e5, z1=6e4, z2=8e4, n_active=4)


def test_round_time_matches_hand_computed_wan():
    """t = t_g + Λ(t_l + t_e) + P·t_c, every term recomputed by hand from the
    paper's WAN constants (mobile 110/14 Mbps down/up, broadband 204/74)."""
    P, Q, t_c = 8, 2, 0.05
    fed = FederationConfig(local_interval=Q, global_interval=P)
    dev_up, dev_down = 14 * 1e6 / 8, 110 * 1e6 / 8
    bb_up, bb_down = 74 * 1e6 / 8, 204 * 1e6 / 8
    up = 4e5 + 8e5 + 1e5
    t_g = up / bb_up + up / bb_down
    t_l = 1e5 / dev_up + 1e5 / dev_down
    t_e = (8e4 / 4) / dev_up + (4e5 + 6e4) / dev_down + (6e4 + 8e4 + 4e5) / bb_up
    expect = t_g + (P // Q) * (t_l + t_e) + P * t_c
    assert round_time(SIZES, fed, t_c, WAN) == pytest.approx(expect, rel=1e-12)


def test_wan_constants_are_the_papers():
    assert WAN.dev_up == 14 * MBIT and WAN.dev_down == 110 * MBIT
    assert WAN.bb_up == 74 * MBIT and WAN.bb_down == 204 * MBIT


def test_time_to_step_scales_rounds_and_adds_upfront():
    fed = FederationConfig(local_interval=2, global_interval=4)
    rt = round_time(SIZES, fed, 0.05)
    assert time_to_step(SIZES, fed, 0.05, steps=12) == pytest.approx(3 * rt)
    # partial rounds pro-rate
    assert time_to_step(SIZES, fed, 0.05, steps=6) == pytest.approx(1.5 * rt)
    with_raw = dataclasses.replace(SIZES, raw_upfront=7.4e6)
    t = time_to_step(with_raw, fed, 0.05, steps=12)
    assert t == pytest.approx(3 * round_time(with_raw, fed, 0.05) + 7.4e6 / WAN.bb_up)
    assert time_to_step(with_raw, fed, 0.05, steps=12,
                        include_upfront=False) == pytest.approx(3 * rt)


def test_round_time_monotone_in_every_message_component():
    """Growing any single wire component can only slow the round down."""
    rng = np.random.RandomState(0)
    fed = FederationConfig(local_interval=2, global_interval=8)
    for _ in range(25):
        base = MessageSizes(*(float(x) for x in rng.uniform(1e3, 1e6, 5)),
                            n_active=int(rng.randint(1, 16)))
        t0 = round_time(base, fed, 0.05)
        for comp in ("theta0", "theta1", "theta2", "z1", "z2"):
            grown = dataclasses.replace(
                base, **{comp: getattr(base, comp) * rng.uniform(1.5, 4.0)})
            assert round_time(grown, fed, 0.05) > t0, comp


def test_round_time_decreasing_in_q_at_fixed_p():
    """Fewer exchange intervals (larger Q at fixed P) is never slower, and
    strictly faster whenever the exchange message is non-empty."""
    fed_p = 16
    for t_c in (0.0, 0.05):
        times = [round_time(SIZES, FederationConfig(local_interval=q,
                                                    global_interval=fed_p), t_c)
                 for q in (1, 2, 4, 8, 16)]
        assert all(a > b for a, b in zip(times, times[1:]))


def test_hetero_tails_reduce_to_paper_model_and_only_slow_down():
    fed = FederationConfig(local_interval=2, global_interval=8)
    sym = round_time(SIZES, fed, 0.05)
    assert round_time_hetero(SIZES, fed, 0.05) == pytest.approx(sym)
    rng = np.random.RandomState(1)
    for _ in range(10):
        dt, ct = 1.0 + rng.rand() * 5, 1.0 + rng.rand() * 5
        slow = round_time_hetero(SIZES, fed, 0.05, dev_tail=dt, compute_tail=ct)
        assert slow > sym
        # backbone legs are NOT device-gated: the slowdown is bounded by the
        # fully-scaled model (every term × max tail)
        assert slow < max(dt, ct) * sym + 1e-9


def test_hetero_tails_scale_only_their_terms():
    """dev_tail scales the Λ device legs, compute_tail the P·t_c term —
    verified by finite differencing each knob."""
    fed = FederationConfig(local_interval=2, global_interval=8)
    base = round_time_hetero(SIZES, fed, 0.05)
    d_dev = round_time_hetero(SIZES, fed, 0.05, dev_tail=2.0) - base
    d_cmp = round_time_hetero(SIZES, fed, 0.05, compute_tail=2.0) - base
    lam = fed.lam
    t_l = SIZES.theta2 / WAN.dev_up + SIZES.theta2 / WAN.dev_down
    t_e_dev = (SIZES.z2 / SIZES.n_active) / WAN.dev_up \
        + (SIZES.theta0 + SIZES.z1) / WAN.dev_down
    assert d_dev == pytest.approx(lam * (t_l + t_e_dev), rel=1e-9)
    assert d_cmp == pytest.approx(fed.global_interval * 0.05, rel=1e-9)
