"""Fault-tolerant federation runtime (ROADMAP robustness item): seeded
injection + trace replay, the compiled screening/robust-aggregation defense,
scheduler retry/backoff, and checkpoint-rollback / preemption recovery."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import compile_guard
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.common.config import FederationConfig, TrainConfig
from repro.core import federation as F
from repro.core.faults import FaultInjector, FaultPlan
from repro.core.hsgd import HSGDRunner, init_state, resize_cohort
from repro.core.population import (
    Cohort,
    CoordinatorPreempted,
    DeviceRegistry,
    PopulationConfig,
    PopulationScheduler,
    run_population,
    run_population_resilient,
)
from repro.data.partition import hybrid_partition
from repro.data.synthetic import ORGANAMNIST, make_dataset
from repro.models.split_model import cnn_hybrid


def _mini(M=3, K=16, q=1, p=2, robust_agg="median"):
    fed = FederationConfig(num_groups=M, devices_per_group=K, alpha=0.5,
                           local_interval=q, global_interval=p,
                           robust_agg=robust_agg)
    X, y = make_dataset(ORGANAMNIST, M * K, seed=0)
    fd = hybrid_partition(ORGANAMNIST, X, y, fed, seed=0)
    data = {k: jnp.asarray(v) for k, v in fd.stacked().items()}
    model = cnn_hybrid(h_rows=11)
    return model, fed, data


def _np_data(M=3, K=16):
    _, _, data = _mini(M=M, K=K)
    return {k: np.asarray(v) for k, v in data.items()}


POP = PopulationConfig(seed=7, devices_per_group=24, target_cohort=4,
                       period=100.0)

PLAN = FaultPlan(seed=11, dropout_rate=0.15, nan_rate=0.12,
                 outlier_rate=0.08, msg_corrupt_rate=0.2)


# ---------------------------------------------------------------------------
# FaultPlan / config validation (satellite: fail fast on bad knobs)
# ---------------------------------------------------------------------------


def test_fault_plan_validates_rates_and_empty_property():
    assert FaultPlan().empty
    assert not PLAN.empty
    assert not FaultPlan(preempt_round=0).empty
    with pytest.raises(ValueError):
        FaultPlan(dropout_rate=1.5)
    with pytest.raises(ValueError):
        FaultPlan(nan_rate=-0.1)
    with pytest.raises(ValueError):
        FaultPlan(latency_spike_mult=0.5)
    with pytest.raises(ValueError):
        FaultPlan(preempt_round=-3)


def test_population_config_validates_retry_knobs():
    with pytest.raises(ValueError):
        PopulationConfig(max_retries=-1)
    with pytest.raises(ValueError):
        PopulationConfig(backoff_factor=1.0)
    with pytest.raises(ValueError):
        PopulationConfig(min_quorum=1.2)


def test_federation_config_validates_robust_agg():
    with pytest.raises(ValueError):
        FederationConfig(robust_agg="mode")
    with pytest.raises(ValueError):
        FederationConfig(trim_frac=0.5)


# ---------------------------------------------------------------------------
# Injector: one seed -> one schedule; JSON trace replays verbatim
# ---------------------------------------------------------------------------


def test_injector_deterministic_from_seed_and_dropped_never_grad_fault():
    a, b = FaultInjector(PLAN), FaultInjector(PLAN)
    pmask = np.ones((3, 8), np.float32)
    pmask[1, 5:] = 0.0  # padding slots take no faults
    saw_fault = False
    for r in range(6):
        fa, fb = a.faults(r, 3, 8, pmask), b.faults(r, 3, 8, pmask)
        for x, y in zip(fa, fb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        # a dropped device's update never reaches the server: it can't ALSO
        # poison the aggregate with a faulty gradient
        assert not np.any((fa.drop > 0)
                          & (np.nan_to_num(fa.grad_fault, nan=1.0) != 0))
        assert not np.any(fa.drop[pmask == 0])
        assert not np.any(np.nan_to_num(fa.grad_fault, nan=1.0)[pmask == 0])
        saw_fault = saw_fault or fa.any_device_fault
    assert saw_fault  # the rates above actually realize faults in 6 rounds
    other = FaultInjector(dataclasses.replace(PLAN, seed=12)).faults(0, 3, 8)
    first = FaultInjector(PLAN).faults(0, 3, 8)
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(first, other))


def test_trace_roundtrip_replays_verbatim_including_nan(tmp_path):
    inj = FaultInjector(PLAN)
    drawn = [inj.faults(r, 3, 8) for r in range(5)]
    path = str(tmp_path / "faults.json")
    inj.save_trace(path)
    replay = FaultInjector.from_trace(path)
    assert replay.plan == PLAN
    for r, rf in enumerate(drawn):
        rr = replay.faults(r, 3, 8)
        for x, y in zip(rf, rr):  # assert_array_equal treats NaN == NaN
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # bucket-shape mismatch: the replay crops/pads onto the asked-for shape
    small = replay.faults(0, 2, 4)
    np.testing.assert_array_equal(small.drop, drawn[0].drop[:2, :4])
    big = replay.faults(0, 3, 16)
    np.testing.assert_array_equal(big.drop[:, :8], drawn[0].drop)
    assert not big.drop[:, 8:].any() and (big.latency_mult >= 1.0).all()
    # a round past the recorded trace is clean, not an error
    assert not replay.faults(99, 3, 8).any_device_fault


# ---------------------------------------------------------------------------
# Compiled defense: screening + robust aggregation inside the executor
# ---------------------------------------------------------------------------


def _fault_setup(robust_agg="median"):
    model, fed, data = _mini(robust_agg=robust_agg)
    train = TrainConfig(learning_rate=0.05)
    runner = HSGDRunner(model, fed, train)
    reg = DeviceRegistry({k: np.asarray(v) for k, v in data.items()},
                         PopulationConfig(seed=3, devices_per_group=16,
                                          target_cohort=4, period=100.0))
    cohort = reg.sample_cohort(0, 0.0)
    A = int(cohort.pmask.shape[1])
    state = resize_cohort(init_state(jax.random.PRNGKey(0), model, fed, data),
                          model, data, A)
    return model, fed, data, train, runner, cohort, state, A


def _finite_state(state):
    return all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree_util.tree_leaves(state))


def test_screen_survives_nan_outlier_and_corrupt_uplink():
    model, fed, data, train, runner, cohort, state, A = _fault_setup()
    M = fed.num_groups
    grad_fault = np.zeros((M, A), np.float32)
    grad_fault[0, 0] = np.nan    # sick client
    grad_fault[1, 1] = 1e4       # wildly-scaled update
    msg_fault = np.zeros(M, np.float32)
    msg_fault[2] = np.nan        # corrupted compressed uplink
    fn = runner.fault_round_fn(2, 1, A, robust=True)
    w = np.ones(M, np.float32) / M
    state, losses, flagged = fn(state, data, w, 0.05, cohort.idx,
                                cohort.pmask, grad_fault, msg_fault)
    assert _finite_state(state)
    assert np.isfinite(np.asarray(losses)).all()
    assert float(flagged) > 0  # the screen actually rejected slot-updates


def test_naive_executor_is_poisoned_by_the_same_faults():
    model, fed, data, train, runner, cohort, state, A = _fault_setup()
    M = fed.num_groups
    grad_fault = np.zeros((M, A), np.float32)
    grad_fault[0, 0] = np.nan
    fn = runner.fault_round_fn(2, 1, A, robust=False)
    w = np.ones(M, np.float32) / M
    state, _, flagged = fn(state, data, w, 0.05, cohort.idx,
                           cohort.pmask, grad_fault, np.zeros(M, np.float32))
    assert float(flagged) == 0.0  # no defense on the naive path
    assert not _finite_state(state)  # NaN propagates through the global agg


def test_robust_aggregate_all_trusted_is_bitwise_masked_mean():
    rng = np.random.RandomState(0)
    x = {"w": jnp.asarray(rng.randn(3, 4, 5).astype(np.float32)),
         "b": jnp.asarray(rng.randn(3, 4).astype(np.float32))}
    pmask = jnp.asarray(np.array([[1, 1, 0, 0], [1, 1, 1, 1], [1, 0, 0, 0]],
                                 np.float32))
    trust = jnp.ones((3, 4), jnp.float32)
    plain = F.local_aggregate(x, pmask)
    for method in ("mean", "median", "trimmed"):
        rob = F.robust_local_aggregate(x, pmask, trust, method=method,
                                       trim_frac=0.2)
        for a, b in zip(jax.tree_util.tree_leaves(rob),
                        jax.tree_util.tree_leaves(plain)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_robust_aggregate_ignores_flagged_slot():
    x = np.zeros((2, 4, 3), np.float32)
    x[0] = np.arange(4, dtype=np.float32)[:, None]  # slots 0..3
    x[0, 3] = 1e8                                   # poisoned slot
    pmask = jnp.ones((2, 4), jnp.float32)
    trust = np.ones((2, 4), np.float32)
    trust[0, 3] = 0.0
    out = np.asarray(F.robust_local_aggregate(
        {"w": jnp.asarray(x)}, pmask, jnp.asarray(trust), method="mean")["w"])
    np.testing.assert_allclose(out[0], 1.0, rtol=1e-6)  # mean of 0,1,2
    np.testing.assert_allclose(out[1], 0.0, atol=0)     # untouched group: plain


# ---------------------------------------------------------------------------
# Fault-free parity: empty plan + armed screen == the plain cohort stack,
# bit-identical parameters, one compile per bucket (compile_guard-pinned)
# ---------------------------------------------------------------------------


def test_fault_free_parity_robust_vs_plain_executor():
    model, fed, data = _mini()
    train = TrainConfig(learning_rate=0.05)
    with compile_guard(track=r"hsgd_(cohort|robust)_round") as g:
        ref = run_population(model, fed, train, data, POP, rounds=4)
        res = run_population_resilient(model, fed, train, data, POP, rounds=4,
                                       faults=None, robust=True, monitor=False)
    # one XLA compile per cohort bucket per stack — arming the screen and the
    # robust aggregation costs zero extra compiles
    buckets = ({h["bucket"] for h in ref["history"]},
               {h["bucket"] for h in res["history"]})
    assert g.total == len(buckets[0]) + len(buckets[1]), g.by_name
    assert len(res["runner"]._round_cache) == len(buckets[1])
    # the PARAMETER trajectory is bit-identical; the reported loss scalar may
    # differ in the final ULP (XLA fuses the cross-group mean differently)
    np.testing.assert_allclose(ref["losses"], res["losses"], rtol=1e-6, atol=0)
    for a, b in zip(jax.tree_util.tree_leaves(ref["state"]),
                    jax.tree_util.tree_leaves(res["state"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert sum(r["flagged_updates"] for r in res["fault_log"]) == 0.0
    np.testing.assert_array_equal(ref["times"], res["times"])


def test_robust_recovers_where_naive_diverges():
    model, fed, data = _mini()
    train = TrainConfig(learning_rate=0.05)
    naive = run_population_resilient(model, fed, train, data, POP, rounds=4,
                                     faults=PLAN, robust=False, monitor=False)
    robust = run_population_resilient(model, fed, train, data, POP, rounds=4,
                                      faults=PLAN, robust=True, monitor=False)
    assert not naive["recovered"]  # NaN gradients poison the naive stack
    assert robust["recovered"]
    assert np.isfinite(robust["losses"]).all()
    assert _finite_state(robust["state"])
    assert sum(r["flagged_updates"] for r in robust["fault_log"]) > 0


# ---------------------------------------------------------------------------
# Scheduler retry/backoff (tentpole part 3)
# ---------------------------------------------------------------------------


def _sched(mode="semi_async", **kw):
    cfg = PopulationConfig(seed=0, devices_per_group=8, target_cohort=4,
                           period=100.0, deadline_quantile=0.5, **kw)
    reg = DeviceRegistry(_np_data(), cfg)
    return PopulationScheduler(reg, np.ones(reg.num_groups), mode=mode)


def _cohort(M=3, A=4):
    return Cohort(idx=np.zeros((M, A), np.int64),
                  pmask=np.ones((M, A), np.float32),
                  counts=np.full(M, A, np.int64),
                  dev_tail=np.ones(M), comp_tail=np.ones(M))


def test_retry_backoff_extends_deadline_and_charges_the_clock():
    sched = _sched(min_quorum=0.9, max_retries=2, backoff_factor=2.0)
    dur = np.array([8.0, 9.0, 100.0])  # quantile(0.5) strands the last group
    w, rec = sched.settle(_cohort(), dur)
    assert rec["retries"] == 2  # 9 -> 18 -> 36, quorum still unmet, give up
    assert rec["deadline"] == pytest.approx(36.0)
    assert rec["retry_seconds"] == pytest.approx(27.0)
    assert sched.now == pytest.approx(36.0)  # retry time is realized sim time
    # the straggler went down the usual staleness path
    np.testing.assert_array_equal(sched.staleness, [0, 0, 1])
    assert w[0] == w[1] > w[2]


def test_retry_backoff_caps_at_the_slowest_participant():
    sched = _sched(min_quorum=1.0, max_retries=5, backoff_factor=10.0)
    dur = np.array([1.0, 9.0, 10.0])
    _, rec = sched.settle(_cohort(), dur)
    assert rec["retries"] == 1
    assert rec["deadline"] == pytest.approx(10.0)  # min(10 * 1e1, worst)
    np.testing.assert_array_equal(sched.staleness, [0, 0, 0])


def test_no_retry_when_quorum_met_or_mode_sync():
    sched = _sched(min_quorum=0.5, max_retries=2)
    _, rec = sched.settle(_cohort(), np.array([8.0, 9.0, 100.0]))
    assert rec["retries"] == 0 and rec["retry_seconds"] == 0.0
    sync = _sched(mode="sync", min_quorum=0.9, max_retries=2)
    _, rec = sync.settle(_cohort(), np.array([8.0, 9.0, 100.0]))
    assert rec["retries"] == 0  # sync waits for the slowest: nothing to retry
    assert rec["deadline"] == pytest.approx(100.0)


def test_scheduler_state_dict_roundtrip():
    a = _sched()
    for dur in ([3.0, 5.0, 7.0], [2.0, 60.0, 80.0]):
        a.settle(_cohort(), np.array(dur))
    b = _sched()
    b.load_state_dict(a.state_dict())
    assert b.now == a.now and b.round == a.round
    np.testing.assert_array_equal(b.staleness, a.staleness)
    assert b.stale_hist == a.stale_hist


def test_controller_core_state_dict_roundtrip():
    from repro.core.comm_model import MessageSizes
    from repro.core.controller import AdaptiveConfig, ControllerCore

    sizes_of = lambda k, b: MessageSizes(1e5, 1e4, 1e4, 1e3, 1e3, 4)
    fed = FederationConfig(local_interval=1, global_interval=2)
    core = ControllerCore(AdaptiveConfig(total_steps=32), fed, sizes_of,
                          eta0=0.05)
    plan, _ = core.plan()
    P = plan.P
    stats = {"loss": np.full(P, 0.5, np.float32),
             "gnorm2": np.full(P, 1.0, np.float32),
             "delta2": np.full(P, 0.5, np.float32),
             "rho": np.full(P, 1.0, np.float32),
             "rho_ok": np.ones(P, np.float32)}
    core.record(plan, stats, seconds=3.0)
    clone = ControllerCore(AdaptiveConfig(total_steps=32), fed, sizes_of,
                           eta0=0.05)
    clone.load_state_dict(core.state_dict())
    assert clone.steps_done == core.steps_done
    assert clone.bytes_spent == core.bytes_spent
    assert clone.seconds_spent == core.seconds_spent
    p1, r1 = core.plan()
    p2, r2 = clone.plan()
    assert p1 == p2 and r1 == r2


# ---------------------------------------------------------------------------
# Recovery: atomic checkpoints, torn saves, preemption resume (tentpole 4)
# ---------------------------------------------------------------------------


def test_torn_save_leaves_previous_checkpoint_loadable(tmp_path, monkeypatch):
    from repro.checkpoint import ckpt as C

    d = str(tmp_path / "ck")
    save_checkpoint(d, {"w": np.arange(4.0)}, step=1, extra={"tag": "one"})

    def torn(path, doc, **kw):  # die between the arrays write and the commit
        raise RuntimeError("preempted mid-save")

    monkeypatch.setattr(C, "atomic_write_json", torn)
    with pytest.raises(RuntimeError):
        save_checkpoint(d, {"w": np.arange(4.0) * 7}, step=2,
                        extra={"tag": "two"})
    monkeypatch.undo()
    payload, step, extra = load_checkpoint(d)  # previous ckpt still commits
    assert step == 1 and extra["tag"] == "one"
    np.testing.assert_array_equal(payload["w"], np.arange(4.0))
    # ...and the next successful save prunes the orphaned arrays file
    save_checkpoint(d, {"w": np.arange(4.0) * 9}, step=3, extra={"tag": "3"})
    payload, step, _ = load_checkpoint(d)
    assert step == 3
    np.testing.assert_array_equal(payload["w"], np.arange(4.0) * 9)


def test_preemption_resume_is_bit_identical(tmp_path):
    model, fed, data = _mini()
    train = TrainConfig(learning_rate=0.05)
    ref = run_population_resilient(model, fed, train, data, POP, rounds=5,
                                   faults=PLAN, robust=True, monitor=False)
    plan = dataclasses.replace(PLAN, preempt_round=3)
    d = str(tmp_path / "ck")
    with pytest.raises(CoordinatorPreempted) as ei:
        run_population_resilient(model, fed, train, data, POP, rounds=5,
                                 faults=plan, robust=True, monitor=False,
                                 ckpt_dir=d, ckpt_every=1)
    assert ei.value.round_idx == 3 and ei.value.ckpt_dir == d
    res = run_population_resilient(model, fed, train, data, POP, rounds=5,
                                   faults=plan, robust=True, monitor=False,
                                   ckpt_dir=d, ckpt_every=1, resume=True)
    # losses, parameters, AND the scheduler/wall-clock ledgers all land
    # exactly where the uninterrupted run does
    np.testing.assert_array_equal(ref["losses"], res["losses"])
    np.testing.assert_array_equal(ref["times"], res["times"])
    assert ref["sim_seconds"] == res["sim_seconds"]
    assert ref["staleness_hist"] == res["staleness_hist"]
    for a, b in zip(jax.tree_util.tree_leaves(ref["state"]),
                    jax.tree_util.tree_leaves(res["state"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert res["recovered"]


def test_resume_without_checkpoint_is_an_error(tmp_path):
    model, fed, data = _mini()
    train = TrainConfig(learning_rate=0.05)
    with pytest.raises(FileNotFoundError):
        run_population_resilient(model, fed, train, data, POP, rounds=2,
                                 ckpt_dir=str(tmp_path / "none"), resume=True)


def test_divergence_monitor_rolls_back_and_shrinks_eta(tmp_path):
    model, fed, data = _mini()
    train = TrainConfig(learning_rate=0.05)
    # pathologically tight spike threshold: once a checkpoint exists, every
    # round trips the monitor, so the loop must roll back to the last
    # checkpoint with a shrunk eta exactly max_rollbacks times and then
    # accept progress (never loop forever)
    res = run_population_resilient(model, fed, train, data, POP, rounds=4,
                                   faults=None, robust=True, monitor=True,
                                   ckpt_dir=str(tmp_path / "ck"), ckpt_every=1,
                                   divergence_factor=1e-9, eta_shrink=0.25,
                                   max_rollbacks=3)
    assert res["rollbacks"] == 3
    assert res["lr_scale"] == pytest.approx(0.25 ** 3)
    assert any(r.get("rolled_back") for r in res["fault_log"])
    assert res["recovered"] and np.isfinite(res["losses"]).all()


# ---------------------------------------------------------------------------
# CLI: early flag validation + fault smoke (satellites)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("argv", [
    ["--fault-nan", "1.5"],
    ["--fault-dropout", "-0.1"],
    ["--max-retries", "-1"],
    ["--backoff-factor", "1.0"],
    ["--min-quorum", "1.5"],
    ["--trim-frac", "0.6"],
    ["--preempt-round", "-3"],
    ["--ckpt-every", "-1"],
    ["--ckpt-every", "2"],          # checkpoint cadence without --checkpoint
    ["--resume"],                   # resume without --checkpoint
])
def test_cli_rejects_bad_flags_before_any_work(argv):
    from repro.launch import train as T

    with pytest.raises(SystemExit):
        T.main(argv)


def test_cli_fault_run_end_to_end_with_trace(tmp_path):
    from repro.launch import train as T

    trace = str(tmp_path / "faults.json")
    out = T.main([
        "--algorithm", "hsgd", "--population", "semi_async",
        "--dataset", "organamnist", "--samples", "48", "--groups", "2",
        "--devices", "8", "--rounds", "2", "--p", "2", "--q", "1",
        "--pop-devices", "8", "--cohort", "2", "--seed", "0",
        "--fault-nan", "0.2", "--fault-dropout", "0.1",
        "--robust-agg", "median", "--fault-trace", trace,
    ])
    assert out["recovered"] and math.isfinite(out["loss_last"])
    replay = FaultInjector.from_trace(trace)  # the trace round-trips
    assert replay.plan.nan_rate == pytest.approx(0.2)
    assert len(replay.trace) == 2
