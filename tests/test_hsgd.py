"""Algorithm-level HSGD tests: staleness semantics, intervals, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FederationConfig, TrainConfig
from repro.core import federation as F
from repro.core.hsgd import (
    HSGDRunner,
    exchange,
    global_aggregation,
    global_model,
    init_state,
    local_sgd_step,
    make_group_weights,
    state_shardings,
)
from repro.data.partition import hybrid_partition
from repro.data.synthetic import ORGANAMNIST, make_dataset
from repro.models.split_model import cnn_hybrid


def _mini(M=2, K=8, A_frac=0.5, q=2, p=4):
    fed = FederationConfig(num_groups=M, devices_per_group=K, alpha=A_frac,
                           local_interval=q, global_interval=p)
    X, y = make_dataset(ORGANAMNIST, M * K, seed=0)
    fd = hybrid_partition(ORGANAMNIST, X, y, fed, seed=0)
    data = {k: jnp.asarray(v) for k, v in fd.stacked().items()}
    model = cnn_hybrid(h_rows=11)
    return model, fed, data


def test_stale_context_frozen_within_interval():
    """ζ and θ0-snapshot must NOT change between exchanges (Alg. 1 reuse)."""
    model, fed, data = _mini()
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    state = exchange(model, state, data, fed)
    z1_before = jax.tree.map(jnp.copy, state.stale["z1"])
    for _ in range(3):
        state, _ = local_sgd_step(model, state, 0.05)
    np.testing.assert_array_equal(np.asarray(state.stale["z1"]), np.asarray(z1_before))


def test_exchange_refreshes_stale_context():
    model, fed, data = _mini()
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    state = exchange(model, state, data, fed)
    for _ in range(3):
        state, _ = local_sgd_step(model, state, 0.05)
    z2_old = np.asarray(state.stale["z2"])
    state = exchange(model, state, data, fed)
    assert np.abs(np.asarray(state.stale["z2"]) - z2_old).max() > 0


def test_local_aggregation_resets_devices_to_group_mean():
    model, fed, data = _mini()
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    state = exchange(model, state, data, fed)
    for _ in range(2):
        state, _ = local_sgd_step(model, state, 0.05)
    group_mean = F.local_aggregate(state.theta2)
    state2 = exchange(model, state, data, fed)
    # all devices now equal the pre-exchange group mean (eq 1 + line 15)
    for leaf_mean, leaf_dev in zip(jax.tree_util.tree_leaves(group_mean),
                                   jax.tree_util.tree_leaves(state2.theta2)):
        np.testing.assert_allclose(np.asarray(leaf_dev),
                                   np.broadcast_to(np.asarray(leaf_mean)[:, None],
                                                   leaf_dev.shape), rtol=1e-6)


def test_global_aggregation_makes_groups_identical():
    model, fed, data = _mini()
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    state = exchange(model, state, data, fed)
    for _ in range(2):
        state, _ = local_sgd_step(model, state, 0.1)
    w = make_group_weights(data)
    state = global_aggregation(state, fed, w)
    for leaf in jax.tree_util.tree_leaves(state.theta0):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]), rtol=1e-6)


def test_hospital_and_device_updates_touch_right_parts():
    """Eq (5)(6) update θ0,θ1 every step; eq (7) updates θ2; cross-terms frozen."""
    model, fed, data = _mini()
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    state = exchange(model, state, data, fed)
    s2, _ = local_sgd_step(model, state, 0.05)
    for part_old, part_new in ((state.theta0, s2.theta0), (state.theta1, s2.theta1),
                               (state.theta2, s2.theta2)):
        moved = max(jax.tree_util.tree_leaves(
            jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), part_old, part_new)))
        assert moved > 0


def test_compression_changes_exchange_but_training_still_converges():
    model, fed, data = _mini(M=2, K=16, q=1, p=2)
    train_c = TrainConfig(learning_rate=0.05, compression_k=0.25, quantization_bits=128)
    runner = HSGDRunner(model, fed, train_c)
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    w = make_group_weights(data)
    state, losses = runner.run(state, data, w, rounds=10)
    assert losses[-1] < losses[0]


def test_legacy_sort_path_still_converges():
    """The pre-fusion sort-based compression path (bench baseline) works."""
    model, fed, data = _mini(M=2, K=16, q=1, p=2)
    train_c = TrainConfig(learning_rate=0.05, compression_k=0.25, quantization_bits=128)
    runner = HSGDRunner(model, fed, train_c, fused_compression=False)
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    w = make_group_weights(data)
    state, losses = runner.run(state, data, w, rounds=10)
    assert losses[-1] < losses[0]


def test_run_donates_state_buffers():
    """run() consumes its input state: no double-buffering of [M, A, ...]."""
    model, fed, data = _mini()
    runner = HSGDRunner(model, fed, TrainConfig(learning_rate=0.01))
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    in_leaves = jax.tree_util.tree_leaves((state.theta0, state.theta1, state.theta2))
    w = make_group_weights(data)
    new_state, _ = runner.run(state, data, w, rounds=1)
    donated = [leaf.is_deleted() for leaf in in_leaves]
    if not any(donated):
        pytest.skip("buffer donation not supported on this backend")
    assert all(donated)
    # the returned state is live and usable
    assert np.isfinite(np.asarray(jax.tree_util.tree_leaves(new_state.theta0)[0])).all()


def test_run_with_trivial_mesh_matches_no_mesh():
    model, fed, data = _mini()
    runner = HSGDRunner(model, fed, TrainConfig(learning_rate=0.02))
    w = make_group_weights(data)
    s1 = init_state(jax.random.PRNGKey(0), model, fed, data)
    s2 = init_state(jax.random.PRNGKey(0), model, fed, data)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    _, l_plain = runner.run(s1, data, w, rounds=2)
    _, l_mesh = runner.run(s2, data, w, rounds=2, mesh=mesh)
    np.testing.assert_allclose(np.asarray(l_plain), np.asarray(l_mesh), rtol=1e-6)


def test_state_shardings_group_axis_and_replicated_scalars():
    model, fed, data = _mini()
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = state_shardings(state, mesh)
    theta0_spec = jax.tree_util.tree_leaves(sh.theta0)[0].spec
    assert theta0_spec and theta0_spec[0] in ("data", ("data",))  # M rides "data"
    assert sh.key.spec == () or all(s is None for s in sh.key.spec)  # replicated
    assert sh.step.spec == () or all(s is None for s in sh.step.spec)


# ---------------------------------------------------------------------------
# Sharded-exchange test matrix: {2, 4} fake devices × {compression on, off}
# × {do_global_agg on, off}. The device count must be fixed before jax
# initializes, hence ONE subprocess per device count (memoized) that runs all
# four configs and reports plain-vs-mesh loss curves as JSON; the parametrized
# tests then assert each combo to fp32 tolerance.
# ---------------------------------------------------------------------------

_SHARDED_MATRIX_CACHE = {}

_SHARDED_MATRIX_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(n_dev)d"
import sys, json
sys.path.insert(0, os.path.join(%(repo)r, "src"))
sys.path.insert(0, %(repo)r)
import jax, numpy as np
from tests.test_hsgd import _mini
from repro.common.config import TrainConfig
from repro.core.hsgd import HSGDRunner, init_state, make_group_weights
model, fed, data = _mini(M=4)  # M=4 divides both mesh sizes -> genuinely sharded
w = make_group_weights(data)
mesh = jax.make_mesh((%(n_dev)d, 1), ("data", "model"))
out = {}
for compression in (False, True):
    for do_agg in (False, True):
        train = TrainConfig(learning_rate=0.02,
                            compression_k=0.25 if compression else 0.0,
                            quantization_bits=128 if compression else 0)
        runner = HSGDRunner(model, fed, train, do_global_agg=do_agg)
        s1 = init_state(jax.random.PRNGKey(0), model, fed, data)
        s2 = init_state(jax.random.PRNGKey(0), model, fed, data)
        _, l_plain = runner.run(s1, data, w, rounds=2)
        st, l_mesh = runner.run(s2, data, w, rounds=2, mesh=mesh)
        leaf = jax.tree_util.tree_leaves(st.theta0)[0]
        out["%%s-%%s" %% (compression, do_agg)] = {
            "plain": np.asarray(l_plain).tolist(),
            "mesh": np.asarray(l_mesh).tolist(),
            "n_shards": len(leaf.sharding.device_set),
        }
print("RESULT::" + json.dumps(out))
"""


def _sharded_matrix(n_dev):
    """Run (once per device count) the full plain-vs-mesh config matrix."""
    if n_dev in _SHARDED_MATRIX_CACHE:
        return _SHARDED_MATRIX_CACHE[n_dev]
    import json
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = _SHARDED_MATRIX_CODE % {"n_dev": n_dev, "repo": repo}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    payload = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")]
    assert payload, out.stdout[-2000:]
    res = json.loads(payload[0][len("RESULT::"):])
    _SHARDED_MATRIX_CACHE[n_dev] = res
    return res


@pytest.mark.slow
@pytest.mark.parametrize("n_dev", [2, 4])
@pytest.mark.parametrize("compression", [False, True])
@pytest.mark.parametrize("do_global_agg", [False, True])
def test_group_sharded_run_matrix(n_dev, compression, do_global_agg):
    """Per-step losses of the mesh-sharded run must match the single-device
    run to fp32 tolerance, for every exchange configuration."""
    res = _sharded_matrix(n_dev)
    entry = res[f"{compression}-{do_global_agg}"]
    assert entry["n_shards"] == n_dev  # genuinely sharded, not replicated
    np.testing.assert_allclose(np.asarray(entry["plain"]),
                               np.asarray(entry["mesh"]), rtol=1e-5, atol=1e-6)


def test_sampled_participants_valid_and_distinct():
    fed = FederationConfig(num_groups=3, devices_per_group=10, alpha=0.4)
    idx = F.sample_participants(jax.random.PRNGKey(0), fed)
    assert idx.shape == (3, 4)
    a = np.asarray(idx)
    assert (a >= 0).all() and (a < 10).all()
    for row in a:
        assert len(set(row.tolist())) == len(row)  # without replacement


def test_q_interval_counts():
    """A run of R rounds yields exactly R*P loss entries (Q steps × Λ × R)."""
    model, fed, data = _mini(q=3, p=6)
    runner = HSGDRunner(model, fed, TrainConfig(learning_rate=0.01))
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    w = make_group_weights(data)
    state, losses = runner.run(state, data, w, rounds=4)
    assert len(losses) == 4 * 6
