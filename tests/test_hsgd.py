"""Algorithm-level HSGD tests: staleness semantics, intervals, compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FederationConfig, TrainConfig
from repro.core import federation as F
from repro.core.hsgd import (
    HSGDRunner,
    exchange,
    global_aggregation,
    global_model,
    init_state,
    local_sgd_step,
    make_group_weights,
)
from repro.data.partition import hybrid_partition
from repro.data.synthetic import ORGANAMNIST, make_dataset
from repro.models.split_model import cnn_hybrid


def _mini(M=2, K=8, A_frac=0.5, q=2, p=4):
    fed = FederationConfig(num_groups=M, devices_per_group=K, alpha=A_frac,
                           local_interval=q, global_interval=p)
    X, y = make_dataset(ORGANAMNIST, M * K, seed=0)
    fd = hybrid_partition(ORGANAMNIST, X, y, fed, seed=0)
    data = {k: jnp.asarray(v) for k, v in fd.stacked().items()}
    model = cnn_hybrid(h_rows=11)
    return model, fed, data


def test_stale_context_frozen_within_interval():
    """ζ and θ0-snapshot must NOT change between exchanges (Alg. 1 reuse)."""
    model, fed, data = _mini()
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    state = exchange(model, state, data, fed)
    z1_before = jax.tree.map(jnp.copy, state.stale["z1"])
    for _ in range(3):
        state, _ = local_sgd_step(model, state, 0.05)
    np.testing.assert_array_equal(np.asarray(state.stale["z1"]), np.asarray(z1_before))


def test_exchange_refreshes_stale_context():
    model, fed, data = _mini()
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    state = exchange(model, state, data, fed)
    for _ in range(3):
        state, _ = local_sgd_step(model, state, 0.05)
    z2_old = np.asarray(state.stale["z2"])
    state = exchange(model, state, data, fed)
    assert np.abs(np.asarray(state.stale["z2"]) - z2_old).max() > 0


def test_local_aggregation_resets_devices_to_group_mean():
    model, fed, data = _mini()
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    state = exchange(model, state, data, fed)
    for _ in range(2):
        state, _ = local_sgd_step(model, state, 0.05)
    group_mean = F.local_aggregate(state.theta2)
    state2 = exchange(model, state, data, fed)
    # all devices now equal the pre-exchange group mean (eq 1 + line 15)
    for leaf_mean, leaf_dev in zip(jax.tree_util.tree_leaves(group_mean),
                                   jax.tree_util.tree_leaves(state2.theta2)):
        np.testing.assert_allclose(np.asarray(leaf_dev),
                                   np.broadcast_to(np.asarray(leaf_mean)[:, None],
                                                   leaf_dev.shape), rtol=1e-6)


def test_global_aggregation_makes_groups_identical():
    model, fed, data = _mini()
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    state = exchange(model, state, data, fed)
    for _ in range(2):
        state, _ = local_sgd_step(model, state, 0.1)
    w = make_group_weights(data)
    state = global_aggregation(state, fed, w)
    for leaf in jax.tree_util.tree_leaves(state.theta0):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]), rtol=1e-6)


def test_hospital_and_device_updates_touch_right_parts():
    """Eq (5)(6) update θ0,θ1 every step; eq (7) updates θ2; cross-terms frozen."""
    model, fed, data = _mini()
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    state = exchange(model, state, data, fed)
    s2, _ = local_sgd_step(model, state, 0.05)
    for part_old, part_new in ((state.theta0, s2.theta0), (state.theta1, s2.theta1),
                               (state.theta2, s2.theta2)):
        moved = max(jax.tree_util.tree_leaves(
            jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), part_old, part_new)))
        assert moved > 0


def test_compression_changes_exchange_but_training_still_converges():
    model, fed, data = _mini(M=2, K=16, q=1, p=2)
    train_c = TrainConfig(learning_rate=0.05, compression_k=0.25, quantization_bits=128)
    runner = HSGDRunner(model, fed, train_c)
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    w = make_group_weights(data)
    state, losses = runner.run(state, data, w, rounds=10)
    assert losses[-1] < losses[0]


def test_sampled_participants_valid_and_distinct():
    fed = FederationConfig(num_groups=3, devices_per_group=10, alpha=0.4)
    idx = F.sample_participants(jax.random.PRNGKey(0), fed)
    assert idx.shape == (3, 4)
    a = np.asarray(idx)
    assert (a >= 0).all() and (a < 10).all()
    for row in a:
        assert len(set(row.tolist())) == len(row)  # without replacement


def test_q_interval_counts():
    """A run of R rounds yields exactly R*P loss entries (Q steps × Λ × R)."""
    model, fed, data = _mini(q=3, p=6)
    runner = HSGDRunner(model, fed, TrainConfig(learning_rate=0.01))
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    w = make_group_weights(data)
    state, losses = runner.run(state, data, w, rounds=4)
    assert len(losses) == 4 * 6
