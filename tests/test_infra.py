"""Infrastructure tests: checkpointing, config registry, comm model, sharding
helpers, and a small-mesh dry-run lowering (4 fake devices via subprocess)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.common.config import INPUT_SHAPES, get_config, list_configs
from repro.common.sharding import DEFAULT_RULES, divisible_spec, logical_to_spec
from repro.core.comm_model import ICI, WAN, MessageSizes, round_time, total_comm_cost
from repro.common.config import FederationConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_checkpoint_roundtrip(tmp_path):
    params = {"a": {"w": jnp.arange(6.0).reshape(2, 3)},
              "b": (jnp.ones((4,)), jnp.zeros((2, 2)))}
    save_checkpoint(str(tmp_path / "ck"), params, step=7, extra={"note": "x"})
    loaded, step, extra = load_checkpoint(str(tmp_path / "ck"))
    assert step == 7 and extra["note"] == "x"
    np.testing.assert_array_equal(loaded["a"]["w"], np.arange(6.0).reshape(2, 3))
    # sequences come back as the SAME container type, not __seq{i} dicts
    assert isinstance(loaded["b"], tuple) and len(loaded["b"]) == 2
    np.testing.assert_array_equal(loaded["b"][0], np.ones((4,)))
    np.testing.assert_array_equal(loaded["b"][1], np.zeros((2, 2)))


def test_registry_has_all_assigned():
    from repro.configs import ASSIGNED

    names = list_configs()
    for a in ASSIGNED:
        assert a in names
    assert len(ASSIGNED) == 10
    # smoke variants exist and are reduced
    for a in ASSIGNED:
        s = get_config(a, smoke=True)
        assert s.num_layers <= 4 and s.d_model <= 512


def test_input_shapes_assigned():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288


def test_federation_config_validates_interval_ratio():
    """P must be a positive multiple of Q at construction — no silent
    flooring of Λ anywhere downstream (round_time used to do P // Q)."""
    import pytest

    with pytest.raises(ValueError):
        FederationConfig(local_interval=3, global_interval=4)
    with pytest.raises(ValueError):
        FederationConfig(local_interval=0, global_interval=4)
    assert FederationConfig(local_interval=2, global_interval=6).lam == 3


def test_comm_model_paper_formula():
    """C(P,Q) matches eq. (19) hand-computed."""
    sizes = MessageSizes(theta0=100.0, theta1=200.0, theta2=50.0, z1=10.0, z2=20.0,
                         n_active=4)
    fed = FederationConfig(local_interval=2, global_interval=4)
    per_iter = 200.0 / 4 + (4 * 50.0 + 100.0 + 10.0 + 20.0) / 2
    assert abs(total_comm_cost(sizes, fed, 10) - per_iter * 10) < 1e-9


def test_round_time_positive_and_orders():
    sizes = MessageSizes(theta0=1e6, theta1=1e6, theta2=1e5, z1=1e5, z2=1e5, n_active=8)
    fed = FederationConfig(local_interval=1, global_interval=2)
    t_wan = round_time(sizes, fed, t_compute=0.05, links=WAN)
    t_ici = round_time(sizes, fed, t_compute=0.05, links=ICI)
    assert t_ici < t_wan  # pod links dwarf WAN
    assert t_wan > 0.1  # includes compute


def test_logical_to_spec_dedupes_axes():
    spec = logical_to_spec(("batch", "seq", "embed"), DEFAULT_RULES)
    flat = []
    for s in spec:
        if s is None:
            continue
        flat.extend(s if isinstance(s, tuple) else (s,))
    assert len(flat) == len(set(flat))  # no mesh axis used twice


def test_divisible_spec_drops_non_divisible():
    mesh = jax.make_mesh((1,), ("model",))
    from jax.sharding import PartitionSpec as P

    spec = divisible_spec((7, 16), P("model", "model"), mesh)
    assert spec[0] is None or 7 % mesh.shape["model"] == 0


@pytest.mark.slow
def test_small_mesh_dryrun_subprocess():
    """Lower + compile a reduced arch on a 2x2 debug mesh in a subprocess
    (device count must be set before jax init)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, os.path.join(%r, "src"))
import jax
from repro.common.config import get_config, INPUT_SHAPES, InputShape
from repro.common.sharding import mesh_context
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import build_programs, build_shardings

mesh = make_debug_mesh(2, 2)
cfg = get_config("gemma3-1b", smoke=True)
shape = InputShape("t", 64, 8, "train")
progs = build_programs(cfg, shape)
for name, (fn, sds, axes) in progs.entries.items():
    sh = tuple(build_shardings(s, a, mesh) for s, a in zip(sds, axes))
    with mesh_context(mesh):
        c = jax.jit(fn, in_shardings=sh).lower(*sds).compile()
        assert c.cost_analysis() is not None
print("OK")
""" % REPO
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_collective_byte_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %ag = f32[16,128]{1,0} all-gather(f32[4,128]{1,0} %x), dimensions={0}
  %ar = (bf16[64]{0}, bf16[32]{0}) all-reduce-start(...), replica_groups={}
  %d = bf16[64]{0} all-reduce-done(%ar)
  %cp = u32[8]{0} collective-permute(%y), source_target_pairs={{0,1}}
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 16 * 128 * 4
    assert got["all-reduce"] == 64 * 2 + 32 * 2
    assert got["collective-permute"] == 8 * 4
