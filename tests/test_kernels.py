"""Per-kernel validation: shape/dtype sweeps, interpret-mode kernel vs the
pure-jnp oracle in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.compress import compress_pytree, compress_rows, fused_compress_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas
from repro.kernels.topk_sparsify import topk_sparsify_pallas

# The hot path always runs the fused math under jit; eager jnp can differ by
# one ulp in the quantization arithmetic (FMA fusion), so the bit-exact
# oracle is the JITTED reference.
_oracle = jax.jit(ref.compress_rows_ref, static_argnames=("levels",))


# ---------------------------------------------------------------------------
# fused compress (top-k + b-level quantize)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,n", [(4, 64), (16, 300), (3, 1000), (1, 128)])
@pytest.mark.parametrize("levels,k_div", [(0, 10), (128, 10), (16, 3), (128, 0)])
def test_fused_compress_matches_oracle(rows, n, levels, k_div):
    """top-k only (levels=0), fused, and quantize only (k_div=0 -> k=n)."""
    x = jax.random.normal(jax.random.PRNGKey(rows * n + levels), (rows, n))
    k = n if k_div == 0 else max(1, n // k_div)
    out = fused_compress_pallas(x, k, levels=levels)
    oracle = _oracle(x, k, levels=levels)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_compress_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 256)).astype(dtype)
    out = fused_compress_pallas(x, 25, levels=128)
    oracle = _oracle(x, 25, levels=128)
    assert out.dtype == dtype
    np.testing.assert_array_equal(np.asarray(out, np.float32), np.asarray(oracle, np.float32))


@pytest.mark.parametrize("levels", [0, 128])
def test_fused_compress_ragged_rows(levels):
    """Rows padded to a common width + per-row valid length == compressing
    each unpadded row block separately (the compress_pytree batching path)."""
    widths = [64, 300, 129]
    rows = 5
    blocks = [jax.random.normal(jax.random.PRNGKey(i), (rows, w)) for i, w in enumerate(widths)]
    n_max = max(widths)
    padded = jnp.concatenate(
        [jnp.pad(b, ((0, 0), (0, n_max - w))) for b, w in zip(blocks, widths)], axis=0)
    k = jnp.concatenate([jnp.full((rows,), max(1, w // 10), jnp.int32) for w in widths])
    row_len = jnp.concatenate([jnp.full((rows,), w, jnp.int32) for w in widths])
    out = fused_compress_pallas(padded, k, levels=levels, row_len=row_len)
    for i, (b, w) in enumerate(zip(blocks, widths)):
        want = _oracle(b, max(1, w // 10), levels=levels)
        got = out[i * rows:(i + 1) * rows, :w]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # padding columns come back zeroed
        assert not np.asarray(out[i * rows:(i + 1) * rows, w:]).any()


def test_fused_compress_k_frac_one_noop():
    """k >= n with quantization off must return x unchanged (bitwise)."""
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 200))
    np.testing.assert_array_equal(np.asarray(fused_compress_pallas(x, 200, levels=0)),
                                  np.asarray(x))
    np.testing.assert_array_equal(np.asarray(ops.fused_compress(x, 1.0, 0)), np.asarray(x))
    # per-row no-op: k >= row width keeps every entry
    out = fused_compress_pallas(x, 1000, levels=0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_compress_rows_router_matches_kernel():
    """The backend router (jnp fallback off-TPU) agrees with the kernel."""
    x = jax.random.normal(jax.random.PRNGKey(4), (9, 320))
    out_router = jax.jit(lambda a: compress_rows(a, 32, 128))(x)
    out_kernel = fused_compress_pallas(x, 32, levels=128)
    np.testing.assert_array_equal(np.asarray(out_router), np.asarray(out_kernel))


def test_compress_pytree_matches_per_leaf():
    tree = {
        "w": jax.random.normal(jax.random.PRNGKey(5), (3, 4, 96)),
        "b": jax.random.normal(jax.random.PRNGKey(6), (3, 17)),
        "c": jax.random.normal(jax.random.PRNGKey(7), (2, 5, 8, 130)),
    }
    out = jax.jit(lambda t: compress_pytree(t, 0.25, 128))(tree)
    for name, leaf in tree.items():
        n = leaf.shape[-1]
        k = max(1, round(0.25 * n))
        want = _oracle(leaf.reshape(-1, n), k, levels=128).reshape(leaf.shape)
        np.testing.assert_array_equal(np.asarray(out[name]), np.asarray(want),
                                      err_msg=f"leaf {name}")
    # no-op settings return the tree untouched
    assert compress_pytree(tree, 1.0, 0) is tree


@pytest.mark.parametrize("rows,n,k", [(8, 256, 16), (5, 300, 7), (1, 128, 1)])
def test_quantized_rows_stay_sparse(rows, n, k):
    """Zero-anchor regression: sparsify-then-quantize must keep the zeros.

    Mixed-sign rows make the survivor min negative; the old all-valid-extrema
    grid then snapped every zeroed entry to round((0-qlo)/scale)*scale+qlo
    != 0, silently re-densifying the message the byte model bills as k
    values. Survivor-range quantization + re-masking keeps nnz <= k + ties."""
    x = jax.random.normal(jax.random.PRNGKey(rows + n + k), (rows, n))
    # force at least one large negative survivor per row
    x = x.at[:, 0].set(-10.0 - jnp.arange(rows, dtype=jnp.float32))
    for out in (fused_compress_pallas(x, k, levels=128),
                _oracle(x, k, levels=128)):
        nnz = (np.asarray(out) != 0).sum(axis=-1)
        assert nnz.max() <= k + 8, f"quantization re-densified: nnz={nnz}"
        assert nnz.min() >= 1
        # the forced negative survivor is still there, and still negative
        assert (np.asarray(out)[:, 0] < 0).all()


def test_legacy_quantize_zero_anchored():
    """Standalone quantize(): 0 -> exactly 0, error bound step/2 kept."""
    from repro.core.compression import quantize

    x = jnp.asarray([[-4.0, 0.0, 0.0, 1.0, 3.0], [0.5, 0.0, -0.5, 2.0, 0.0]])
    q = np.asarray(quantize(x, 128))
    np.testing.assert_array_equal(q[np.asarray(x) == 0.0], 0.0)
    step = (np.asarray(x).max(-1) - np.asarray(x).min(-1)) / 127
    assert np.abs(q - np.asarray(x)).max() <= (step.max() / 2) + 1e-7


@pytest.mark.parametrize("rows,n", [(4, 64), (16, 300), (3, 1000)])
@pytest.mark.parametrize("levels", [0, 128])
def test_fused_compress_dp_matches_oracle(rows, n, levels):
    """DP stage (clip + precomputed noise operands) kernel vs jitted ref."""
    kx, kn = jax.random.split(jax.random.PRNGKey(rows * n + levels))
    x = jax.random.normal(kx, (rows, n))
    noise = jax.random.normal(kn, (rows, n))
    k = max(1, n // 10)
    clip = jnp.asarray(0.5, jnp.float32)
    sigma = jnp.asarray(1.3, jnp.float32)
    out = fused_compress_pallas(x, k, levels=levels, dp_clip=clip,
                                dp_sigma=sigma, dp_noise=noise)
    oracle = _oracle(x, k, levels=levels, dp_clip=clip, dp_sigma=sigma,
                     dp_noise=noise)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


def test_fused_compress_dp_ragged_matches_oracle():
    """DP + ragged rows: per-row norms/noise respect the valid length."""
    widths = [64, 300, 129]
    rows = 4
    blocks = [jax.random.normal(jax.random.PRNGKey(i), (rows, w))
              for i, w in enumerate(widths)]
    noises = [jax.random.normal(jax.random.PRNGKey(10 + i), (rows, w))
              for i, w in enumerate(widths)]
    n_max = max(widths)
    pad = lambda bs: jnp.concatenate(
        [jnp.pad(b, ((0, 0), (0, n_max - w))) for b, w in zip(bs, widths)], axis=0)
    padded, noise = pad(blocks), pad(noises)
    k = jnp.concatenate([jnp.full((rows,), max(1, w // 10), jnp.int32) for w in widths])
    row_len = jnp.concatenate([jnp.full((rows,), w, jnp.int32) for w in widths])
    clip = jnp.asarray(1.0, jnp.float32)
    sigma = jnp.asarray(0.7, jnp.float32)
    out = fused_compress_pallas(padded, k, levels=128, row_len=row_len,
                                dp_clip=clip, dp_sigma=sigma, dp_noise=noise)
    for i, (b, nz, w) in enumerate(zip(blocks, noises, widths)):
        want = _oracle(b, max(1, w // 10), levels=128, dp_clip=clip,
                       dp_sigma=sigma, dp_noise=nz)
        got = out[i * rows:(i + 1) * rows, :w]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert not np.asarray(out[i * rows:(i + 1) * rows, w:]).any()


def test_dp_sigma0_large_clip_bit_identical():
    """σ=0 with a clip above every row norm is the exact non-DP pass: the
    stage multiplies by exactly 1.0 and adds exactly 0.0. (A FINITE clip —
    0*inf would poison the noise term with NaN.)"""
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(9), (8, 320))) + 0.01
    noise = jax.random.normal(jax.random.PRNGKey(10), (8, 320))
    plain = fused_compress_pallas(x, 32, levels=128)
    dp0 = fused_compress_pallas(x, 32, levels=128,
                                dp_clip=jnp.asarray(1e9, jnp.float32),
                                dp_sigma=jnp.asarray(0.0, jnp.float32),
                                dp_noise=noise)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(dp0))


def test_compress_pytree_dp_matches_per_leaf():
    """compress_pytree with dp_key draws ONE noise matrix for the stacked
    rows; each leaf must match the ref called with that leaf's noise slice."""
    tree = {
        "b": jax.random.normal(jax.random.PRNGKey(6), (3, 17)),
        "w": jax.random.normal(jax.random.PRNGKey(5), (3, 4, 96)),
    }
    clip = jnp.asarray(1.0, jnp.float32)
    sigma = jnp.asarray(0.5, jnp.float32)
    dp_key = jax.random.PRNGKey(42)
    out = jax.jit(lambda t: compress_pytree(t, 0.25, 128, dp_clip=clip,
                                            dp_sigma=sigma, dp_key=dp_key))(tree)
    for name, leaf in tree.items():
        assert out[name].shape == leaf.shape
        assert not np.array_equal(np.asarray(out[name]), np.asarray(leaf))
        nnz = (np.asarray(out[name]).reshape(-1, leaf.shape[-1]) != 0).sum(-1)
        kmax = max(1, round(0.25 * leaf.shape[-1]))
        assert nnz.max() <= kmax + 8  # sparsity survives DP + quantization


# ---------------------------------------------------------------------------
# topk_sparsify
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,n", [(4, 64), (16, 300), (3, 1000), (1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_matches_oracle(rows, n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(rows * n), (rows, n)).astype(dtype)
    k = max(1, n // 10)
    out = topk_sparsify_pallas(x, k)
    oracle = ref.topk_sparsify_ref(x, k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


@pytest.mark.parametrize("n,k", [(128, 13), (256, 1), (64, 64)])
def test_topk_contains_exact_support(n, k):
    """The threshold refinement keeps a superset of the exact top-k support
    (>= k survivors; all exact top-k entries kept)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, n))
    out = topk_sparsify_pallas(x, k)
    exact = ref.topk_exact_ref(x, k)
    kept = np.asarray(out) != 0
    exact_kept = np.asarray(exact) != 0
    assert (kept & exact_kept).sum(axis=-1).min() >= min(k, n) * 1  # exact support preserved
    assert (~kept & exact_kept).sum() == 0
    # survivor count close to k (ties can add a few)
    assert kept.sum(axis=-1).max() <= k + 8


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,D,window", [(128, 64, 0), (200, 32, 0), (256, 64, 32),
                                        (100, 128, 16), (64, 64, 64)])
def test_flash_attention_matches_oracle(S, D, window):
    BH = 3
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S + D), 3)
    q = jax.random.normal(k1, (BH, S, D))
    k = jax.random.normal(k2, (BH, S, D))
    v = jax.random.normal(k3, (BH, S, D))
    out = flash_attention_pallas(q, k, v, window=window, block_q=64, block_k=64)
    oracle = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    BH, S, D = 2, 128, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (BH, S, D)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (BH, S, D)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (BH, S, D)).astype(dtype)
    out = flash_attention_pallas(q, k, v)
    oracle = ref.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(oracle, np.float32),
                               rtol=tol, atol=tol)
    assert out.dtype == dtype


def test_flash_attention_gqa_wrapper():
    B, S, H, D = 2, 96, 4, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
    out = ops.flash_attention(q, k, v)
    assert out.shape == (B, S, H, D)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    oracle = ref.flash_attention_ref(qf, kf, vf).reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssm_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,C", [(2, 100, 50), (1, 256, 128), (3, 37, 7), (2, 512, 200)])
def test_ssm_scan_matches_oracle(B, T, C):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(B * T * C), 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, (B, T, C)))
    b = jax.random.normal(k2, (B, T, C))
    h0 = jax.random.normal(k3, (B, C))
    hs, hl = ssm_scan_pallas(a, b, h0, block_t=64, block_c=64)
    hs_r, hl_r = ref.ssm_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hl_r), rtol=1e-5, atol=1e-5)


def test_ssm_scan_folded_state_dims():
    B, T, C, N = 2, 64, 8, 4
    a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(0), (B, T, C, N)))
    b = jax.random.normal(jax.random.PRNGKey(1), (B, T, C, N))
    h0 = jnp.zeros((B, C, N))
    hs, hl = ops.ssm_scan(a, b, h0)
    assert hs.shape == (B, T, C, N) and hl.shape == (B, C, N)
    hs_r, hl_r = ref.ssm_scan_ref(a.reshape(B, T, -1), b.reshape(B, T, -1), h0.reshape(B, -1))
    np.testing.assert_allclose(np.asarray(hs.reshape(B, T, -1)), np.asarray(hs_r),
                               rtol=1e-5, atol=1e-5)


def test_ssm_scan_agrees_with_model_recurrence():
    """Kernel recurrence == the chunked recurrence used inside the models."""
    from repro.models.ssm import chunked_linear_recurrence

    B, T, C = 2, 130, 17
    a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(5), (B, T, C))) * 0.98 + 0.01
    b = jax.random.normal(jax.random.PRNGKey(6), (B, T, C))
    h0 = jax.random.normal(jax.random.PRNGKey(7), (B, C))
    hs_m, hl_m = chunked_linear_recurrence(a, b, h0)
    hs_k, hl_k = ssm_scan_pallas(a, b, h0, block_t=32, block_c=16)
    np.testing.assert_allclose(np.asarray(hs_m), np.asarray(hs_k), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hl_m), np.asarray(hl_k), rtol=2e-4, atol=2e-4)
