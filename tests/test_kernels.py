"""Per-kernel validation: shape/dtype sweeps, interpret-mode kernel vs the
pure-jnp oracle in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.compress import compress_pytree, compress_rows, fused_compress_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas
from repro.kernels.topk_sparsify import topk_sparsify_pallas

# The hot path always runs the fused math under jit; eager jnp can differ by
# one ulp in the quantization arithmetic (FMA fusion), so the bit-exact
# oracle is the JITTED reference.
_oracle = jax.jit(ref.compress_rows_ref, static_argnames=("levels",))


# ---------------------------------------------------------------------------
# fused compress (top-k + b-level quantize)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,n", [(4, 64), (16, 300), (3, 1000), (1, 128)])
@pytest.mark.parametrize("levels,k_div", [(0, 10), (128, 10), (16, 3), (128, 0)])
def test_fused_compress_matches_oracle(rows, n, levels, k_div):
    """top-k only (levels=0), fused, and quantize only (k_div=0 -> k=n)."""
    x = jax.random.normal(jax.random.PRNGKey(rows * n + levels), (rows, n))
    k = n if k_div == 0 else max(1, n // k_div)
    out = fused_compress_pallas(x, k, levels=levels)
    oracle = _oracle(x, k, levels=levels)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_compress_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 256)).astype(dtype)
    out = fused_compress_pallas(x, 25, levels=128)
    oracle = _oracle(x, 25, levels=128)
    assert out.dtype == dtype
    np.testing.assert_array_equal(np.asarray(out, np.float32), np.asarray(oracle, np.float32))


@pytest.mark.parametrize("levels", [0, 128])
def test_fused_compress_ragged_rows(levels):
    """Rows padded to a common width + per-row valid length == compressing
    each unpadded row block separately (the compress_pytree batching path)."""
    widths = [64, 300, 129]
    rows = 5
    blocks = [jax.random.normal(jax.random.PRNGKey(i), (rows, w)) for i, w in enumerate(widths)]
    n_max = max(widths)
    padded = jnp.concatenate(
        [jnp.pad(b, ((0, 0), (0, n_max - w))) for b, w in zip(blocks, widths)], axis=0)
    k = jnp.concatenate([jnp.full((rows,), max(1, w // 10), jnp.int32) for w in widths])
    row_len = jnp.concatenate([jnp.full((rows,), w, jnp.int32) for w in widths])
    out = fused_compress_pallas(padded, k, levels=levels, row_len=row_len)
    for i, (b, w) in enumerate(zip(blocks, widths)):
        want = _oracle(b, max(1, w // 10), levels=levels)
        got = out[i * rows:(i + 1) * rows, :w]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # padding columns come back zeroed
        assert not np.asarray(out[i * rows:(i + 1) * rows, w:]).any()


def test_fused_compress_k_frac_one_noop():
    """k >= n with quantization off must return x unchanged (bitwise)."""
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 200))
    np.testing.assert_array_equal(np.asarray(fused_compress_pallas(x, 200, levels=0)),
                                  np.asarray(x))
    np.testing.assert_array_equal(np.asarray(ops.fused_compress(x, 1.0, 0)), np.asarray(x))
    # per-row no-op: k >= row width keeps every entry
    out = fused_compress_pallas(x, 1000, levels=0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_compress_rows_router_matches_kernel():
    """The backend router (jnp fallback off-TPU) agrees with the kernel."""
    x = jax.random.normal(jax.random.PRNGKey(4), (9, 320))
    out_router = jax.jit(lambda a: compress_rows(a, 32, 128))(x)
    out_kernel = fused_compress_pallas(x, 32, levels=128)
    np.testing.assert_array_equal(np.asarray(out_router), np.asarray(out_kernel))


def test_compress_pytree_matches_per_leaf():
    tree = {
        "w": jax.random.normal(jax.random.PRNGKey(5), (3, 4, 96)),
        "b": jax.random.normal(jax.random.PRNGKey(6), (3, 17)),
        "c": jax.random.normal(jax.random.PRNGKey(7), (2, 5, 8, 130)),
    }
    out = jax.jit(lambda t: compress_pytree(t, 0.25, 128))(tree)
    for name, leaf in tree.items():
        n = leaf.shape[-1]
        k = max(1, round(0.25 * n))
        want = _oracle(leaf.reshape(-1, n), k, levels=128).reshape(leaf.shape)
        np.testing.assert_array_equal(np.asarray(out[name]), np.asarray(want),
                                      err_msg=f"leaf {name}")
    # no-op settings return the tree untouched
    assert compress_pytree(tree, 1.0, 0) is tree


# ---------------------------------------------------------------------------
# topk_sparsify
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,n", [(4, 64), (16, 300), (3, 1000), (1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_matches_oracle(rows, n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(rows * n), (rows, n)).astype(dtype)
    k = max(1, n // 10)
    out = topk_sparsify_pallas(x, k)
    oracle = ref.topk_sparsify_ref(x, k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


@pytest.mark.parametrize("n,k", [(128, 13), (256, 1), (64, 64)])
def test_topk_contains_exact_support(n, k):
    """The threshold refinement keeps a superset of the exact top-k support
    (>= k survivors; all exact top-k entries kept)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, n))
    out = topk_sparsify_pallas(x, k)
    exact = ref.topk_exact_ref(x, k)
    kept = np.asarray(out) != 0
    exact_kept = np.asarray(exact) != 0
    assert (kept & exact_kept).sum(axis=-1).min() >= min(k, n) * 1  # exact support preserved
    assert (~kept & exact_kept).sum() == 0
    # survivor count close to k (ties can add a few)
    assert kept.sum(axis=-1).max() <= k + 8


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,D,window", [(128, 64, 0), (200, 32, 0), (256, 64, 32),
                                        (100, 128, 16), (64, 64, 64)])
def test_flash_attention_matches_oracle(S, D, window):
    BH = 3
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S + D), 3)
    q = jax.random.normal(k1, (BH, S, D))
    k = jax.random.normal(k2, (BH, S, D))
    v = jax.random.normal(k3, (BH, S, D))
    out = flash_attention_pallas(q, k, v, window=window, block_q=64, block_k=64)
    oracle = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    BH, S, D = 2, 128, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (BH, S, D)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (BH, S, D)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (BH, S, D)).astype(dtype)
    out = flash_attention_pallas(q, k, v)
    oracle = ref.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(oracle, np.float32),
                               rtol=tol, atol=tol)
    assert out.dtype == dtype


def test_flash_attention_gqa_wrapper():
    B, S, H, D = 2, 96, 4, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
    out = ops.flash_attention(q, k, v)
    assert out.shape == (B, S, H, D)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    oracle = ref.flash_attention_ref(qf, kf, vf).reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssm_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,C", [(2, 100, 50), (1, 256, 128), (3, 37, 7), (2, 512, 200)])
def test_ssm_scan_matches_oracle(B, T, C):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(B * T * C), 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, (B, T, C)))
    b = jax.random.normal(k2, (B, T, C))
    h0 = jax.random.normal(k3, (B, C))
    hs, hl = ssm_scan_pallas(a, b, h0, block_t=64, block_c=64)
    hs_r, hl_r = ref.ssm_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hl_r), rtol=1e-5, atol=1e-5)


def test_ssm_scan_folded_state_dims():
    B, T, C, N = 2, 64, 8, 4
    a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(0), (B, T, C, N)))
    b = jax.random.normal(jax.random.PRNGKey(1), (B, T, C, N))
    h0 = jnp.zeros((B, C, N))
    hs, hl = ops.ssm_scan(a, b, h0)
    assert hs.shape == (B, T, C, N) and hl.shape == (B, C, N)
    hs_r, hl_r = ref.ssm_scan_ref(a.reshape(B, T, -1), b.reshape(B, T, -1), h0.reshape(B, -1))
    np.testing.assert_allclose(np.asarray(hs.reshape(B, T, -1)), np.asarray(hs_r),
                               rtol=1e-5, atol=1e-5)


def test_ssm_scan_agrees_with_model_recurrence():
    """Kernel recurrence == the chunked recurrence used inside the models."""
    from repro.models.ssm import chunked_linear_recurrence

    B, T, C = 2, 130, 17
    a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(5), (B, T, C))) * 0.98 + 0.01
    b = jax.random.normal(jax.random.PRNGKey(6), (B, T, C))
    h0 = jax.random.normal(jax.random.PRNGKey(7), (B, C))
    hs_m, hl_m = chunked_linear_recurrence(a, b, h0)
    hs_k, hl_k = ssm_scan_pallas(a, b, h0, block_t=32, block_c=16)
    np.testing.assert_allclose(np.asarray(hs_m), np.asarray(hs_k), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hl_m), np.asarray(hl_k), rtol=2e-4, atol=2e-4)
