"""Per-kernel validation: shape/dtype sweeps, interpret-mode kernel vs the
pure-jnp oracle in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas
from repro.kernels.topk_sparsify import topk_sparsify_pallas


# ---------------------------------------------------------------------------
# topk_sparsify
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,n", [(4, 64), (16, 300), (3, 1000), (1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_matches_oracle(rows, n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(rows * n), (rows, n)).astype(dtype)
    k = max(1, n // 10)
    out = topk_sparsify_pallas(x, k)
    oracle = ref.topk_sparsify_ref(x, k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


@pytest.mark.parametrize("n,k", [(128, 13), (256, 1), (64, 64)])
def test_topk_contains_exact_support(n, k):
    """The threshold refinement keeps a superset of the exact top-k support
    (>= k survivors; all exact top-k entries kept)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, n))
    out = topk_sparsify_pallas(x, k)
    exact = ref.topk_exact_ref(x, k)
    kept = np.asarray(out) != 0
    exact_kept = np.asarray(exact) != 0
    assert (kept & exact_kept).sum(axis=-1).min() >= min(k, n) * 1  # exact support preserved
    assert (~kept & exact_kept).sum() == 0
    # survivor count close to k (ties can add a few)
    assert kept.sum(axis=-1).max() <= k + 8


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,D,window", [(128, 64, 0), (200, 32, 0), (256, 64, 32),
                                        (100, 128, 16), (64, 64, 64)])
def test_flash_attention_matches_oracle(S, D, window):
    BH = 3
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S + D), 3)
    q = jax.random.normal(k1, (BH, S, D))
    k = jax.random.normal(k2, (BH, S, D))
    v = jax.random.normal(k3, (BH, S, D))
    out = flash_attention_pallas(q, k, v, window=window, block_q=64, block_k=64)
    oracle = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    BH, S, D = 2, 128, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (BH, S, D)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (BH, S, D)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (BH, S, D)).astype(dtype)
    out = flash_attention_pallas(q, k, v)
    oracle = ref.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(oracle, np.float32),
                               rtol=tol, atol=tol)
    assert out.dtype == dtype


def test_flash_attention_gqa_wrapper():
    B, S, H, D = 2, 96, 4, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
    out = ops.flash_attention(q, k, v)
    assert out.shape == (B, S, H, D)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    oracle = ref.flash_attention_ref(qf, kf, vf).reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssm_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,C", [(2, 100, 50), (1, 256, 128), (3, 37, 7), (2, 512, 200)])
def test_ssm_scan_matches_oracle(B, T, C):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(B * T * C), 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, (B, T, C)))
    b = jax.random.normal(k2, (B, T, C))
    h0 = jax.random.normal(k3, (B, C))
    hs, hl = ssm_scan_pallas(a, b, h0, block_t=64, block_c=64)
    hs_r, hl_r = ref.ssm_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hl_r), rtol=1e-5, atol=1e-5)


def test_ssm_scan_folded_state_dims():
    B, T, C, N = 2, 64, 8, 4
    a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(0), (B, T, C, N)))
    b = jax.random.normal(jax.random.PRNGKey(1), (B, T, C, N))
    h0 = jnp.zeros((B, C, N))
    hs, hl = ops.ssm_scan(a, b, h0)
    assert hs.shape == (B, T, C, N) and hl.shape == (B, C, N)
    hs_r, hl_r = ref.ssm_scan_ref(a.reshape(B, T, -1), b.reshape(B, T, -1), h0.reshape(B, -1))
    np.testing.assert_allclose(np.asarray(hs.reshape(B, T, -1)), np.asarray(hs_r),
                               rtol=1e-5, atol=1e-5)


def test_ssm_scan_agrees_with_model_recurrence():
    """Kernel recurrence == the chunked recurrence used inside the models."""
    from repro.models.ssm import chunked_linear_recurrence

    B, T, C = 2, 130, 17
    a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(5), (B, T, C))) * 0.98 + 0.01
    b = jax.random.normal(jax.random.PRNGKey(6), (B, T, C))
    h0 = jax.random.normal(jax.random.PRNGKey(7), (B, C))
    hs_m, hl_m = chunked_linear_recurrence(a, b, h0)
    hs_k, hl_k = ssm_scan_pallas(a, b, h0, block_t=32, block_c=16)
    np.testing.assert_allclose(np.asarray(hs_m), np.asarray(hs_k), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hl_m), np.asarray(hl_k), rtol=2e-4, atol=2e-4)
