"""LLM-scale federated runner: exchange/byte-model parity, compiled-round
equivalence, compile-cache bounds, and the adaptive loop's bookkeeping."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import compile_guard
from repro.common.config import ModelConfig
from repro.core import comm_model as CM
from repro.core.compression import COMPRESSION_LADDER, compressed_bytes
from repro.core.controller import AdaptiveConfig, ControllerCore, NEUTRAL_PROBE
from repro.data.synthetic import llm_batch_fn
from repro.launch.steps import (
    AdaptiveLLMRunner,
    LLMRoundRunner,
    global_llm_params,
    init_llm_params,
    make_exchange_step,
    make_hsgd_step_stats,
    make_hsgd_train_step,
)
from repro.models.split_model import llm_hybrid


def tiny_cfg(**kw) -> ModelConfig:
    base = dict(name="tiny-test", family="dense", num_layers=1, d_model=32,
                num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=64,
                mlp="swiglu", dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def tiny_model():
    return llm_hybrid(tiny_cfg(), n_tower=1, remat=False)


def _flat_batch(cfg, B=4, S=8, seed=0):
    rng = np.random.RandomState(seed)
    s1 = S // 2
    inp = rng.randint(0, cfg.vocab_size, (B, S))
    return {"x1": jnp.asarray(inp[:, :s1]), "x2": jnp.asarray(inp[:, s1:]),
            "y": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))}


# ---------------------------------------------------------------------------
# Satellite bugfix: the exchange message and the byte model must agree on
# WHAT is compressed — {θ0, ζ1, ζ2}, the whole wire message
# ---------------------------------------------------------------------------


def test_exchange_compresses_whole_message_matching_byte_model(tiny_model):
    """make_exchange_step used to compress only ζ1/ζ2 while message_sizes
    billed θ0 as compressed. Now every leaf of the {θ0, ζ1, ζ2} message goes
    through the canonical top-k math and the realized wire size matches the
    eq. (19) bill."""
    from repro.core.compression import compress_rows_ref

    cfg = tiny_cfg()
    params = tiny_model.init(jax.random.PRNGKey(0))
    batch = _flat_batch(cfg)
    k_frac = 0.25
    msg = make_exchange_step(tiny_model, k_frac, 0)(params, batch)
    raw = make_exchange_step(tiny_model)(params, batch)

    assert set(msg) == {"theta0", "z1", "z2"}  # exactly the billed components
    # every leaf — θ0 parameters included — equals the canonical per-leaf
    # compression (the old bug passed θ0 through untouched)
    for name in ("theta0", "z1", "z2"):
        for got, orig in zip(jax.tree_util.tree_leaves(msg[name]),
                             jax.tree_util.tree_leaves(raw[name])):
            n = orig.shape[-1]
            k = max(1, round(k_frac * n))
            want = compress_rows_ref(
                np.asarray(orig, np.float32).reshape(-1, n), k)
            np.testing.assert_allclose(
                np.asarray(got, np.float32).reshape(-1, n), want,
                rtol=1e-6, atol=0, err_msg=name)
    theta0_delta = max(
        float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
        for a, b in zip(jax.tree_util.tree_leaves(msg["theta0"]),
                        jax.tree_util.tree_leaves(params["theta0"])))
    assert theta0_delta > 0, "θ0 was transmitted dense (the old parity bug)"

    # realized wire bytes (kept values + 32-bit indices) vs the bill, up to
    # per-row rounding and tie rows (all-equal |x| rows stay dense by design)
    sds = {t: jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                           params[t]) for t in params}
    z1_el = int(np.prod(msg["z1"].shape))
    z2_el = int(np.prod(msg["z2"].shape))
    sizes = CM.message_sizes(sds, z1_el, z2_el, 1, k_frac, 0)
    for name, billed, rel in (("theta0", sizes.theta0, 0.2),
                              ("z1", sizes.z1, 0.05), ("z2", sizes.z2, 0.05)):
        actual = sum(
            float((np.asarray(l).reshape(-1, l.shape[-1]) != 0).sum()) * 8.0
            for l in jax.tree_util.tree_leaves(msg[name]))  # 4B value + 4B idx
        assert actual == pytest.approx(billed, rel=rel), name

    # REGRESSION (zero-anchor bug): on a QUANTIZED rung the sparsified zeros
    # must stay zero after quantization — the old anchor-shifted grid snapped
    # every pruned entry to a nonzero level, so the realized wire size
    # silently blew past the eq. (19) bill by ~1/k_frac.
    qmsg = make_exchange_step(tiny_model, k_frac, 128)(params, batch)
    for name in ("theta0", "z1", "z2"):
        for got, sparse in zip(jax.tree_util.tree_leaves(qmsg[name]),
                               jax.tree_util.tree_leaves(msg[name])):
            n = got.shape[-1]
            nnz_q = (np.asarray(got).reshape(-1, n) != 0).sum(axis=-1)
            nnz_s = (np.asarray(sparse).reshape(-1, n) != 0).sum(axis=-1)
            # per row: never above the sparsify-only count (tie rows stay
            # dense on BOTH paths, so the comparison absorbs them)
            assert (nnz_q <= nnz_s).all(), name
            assert nnz_q.min() >= 1, name  # top survivor never quantized away


def test_exchange_uncompressed_passthrough(tiny_model):
    cfg = tiny_cfg()
    params = tiny_model.init(jax.random.PRNGKey(0))
    batch = _flat_batch(cfg)
    msg = make_exchange_step(tiny_model)(params, batch)
    for a, b in zip(jax.tree_util.tree_leaves(msg["theta0"]),
                    jax.tree_util.tree_leaves(params["theta0"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Compiled rounds: equivalence with the hand loop + stats-path consistency
# ---------------------------------------------------------------------------


def test_compiled_round_matches_hand_loop(tiny_model):
    """run_fixed (donating scan executor) computes the same trajectory as the
    un-staged exchange/step loop it replaced."""
    cfg = tiny_cfg()
    lr, P, Q, steps = 0.05, 4, 2, 8
    bf = llm_batch_fn(cfg, 4, 8, n_pods=1, seed=3)
    runner = LLMRoundRunner(tiny_model)
    params = init_llm_params(jax.random.PRNGKey(1), tiny_model, n_pods=1)
    params, losses = runner.run_fixed(params, bf, steps=steps, P=P, Q=Q, lr=lr)

    # hand loop on flat params, identical batch sequence
    bf2 = llm_batch_fn(cfg, 4, 8, n_pods=1, seed=3)
    flat = tiny_model.init(jax.random.PRNGKey(1))
    step = make_hsgd_train_step(tiny_model, lr=lr)
    exch = make_exchange_step(tiny_model)
    ref = []
    for r in range(steps // P):
        batches = bf2(r, P // Q)
        for i in range(P // Q):
            batch = jax.tree.map(lambda x: x[i, 0], batches)
            stale = exch(flat, batch)
            for _ in range(Q):
                flat, loss = step(flat, stale, batch)
                ref.append(float(loss))
    np.testing.assert_allclose(np.asarray(losses), np.asarray(ref),
                               rtol=2e-4, atol=1e-5)


def test_stats_step_update_equals_plain_step(tiny_model):
    """The shard-split probe step's update (mean of shard gradients) IS the
    full-batch gradient step — probes are free, not a different algorithm."""
    cfg = tiny_cfg()
    params = tiny_model.init(jax.random.PRNGKey(0))
    batch = _flat_batch(cfg, B=4)
    stale = make_exchange_step(tiny_model)(params, batch)
    new_plain, loss_plain = make_hsgd_train_step(tiny_model)(params, stale, batch, 0.05)
    new_stats, loss_stats, aux = make_hsgd_step_stats(tiny_model, 2)(
        params, stale, batch, 0.05)
    assert float(loss_stats) == pytest.approx(float(loss_plain), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(new_plain),
                    jax.tree_util.tree_leaves(new_stats)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)
    assert float(aux["delta2"]) >= 0 and float(aux["gnorm2"]) > 0


def test_round_stats_shapes_and_rho_validity(tiny_model):
    runner = LLMRoundRunner(tiny_model, n_pods=2)
    params = init_llm_params(jax.random.PRNGKey(0), tiny_model, n_pods=2)
    batches = llm_batch_fn(tiny_cfg(), 4, 8, n_pods=2, seed=0)(0, 2)
    fn = runner.round_fn(4, 2, collect_stats=True)
    params, stats = fn(params, batches, 0.05)
    assert {"loss", "gnorm2", "delta2", "rho", "rho_ok"} <= set(stats)
    for v in stats.values():
        assert np.asarray(v).shape == (4,)
    # Q=2 intervals: the first step of each interval has no secant pair
    np.testing.assert_array_equal(np.asarray(stats["rho_ok"]), [0, 1, 0, 1])
    assert (np.asarray(stats["delta2"]) >= 0).all()
    assert np.isfinite(np.asarray(stats["loss"])).all()


def test_run_fixed_rejects_partial_rounds_and_odd_probe_batch(tiny_model):
    """No silent flooring: a step budget that doesn't decompose into whole
    compiled rounds is the caller's problem, loudly. Likewise the probe step
    refuses a batch it can't shard (a silent 1-shard fallback would zero δ²)."""
    cfg = tiny_cfg()
    runner = LLMRoundRunner(tiny_model)
    params = init_llm_params(jax.random.PRNGKey(0), tiny_model, n_pods=1)
    bf = llm_batch_fn(cfg, 4, 8, n_pods=1, seed=0)
    with pytest.raises(ValueError, match="multiple of P"):
        runner.run_fixed(params, bf, steps=10, P=4, Q=2, lr=0.01)
    batch = _flat_batch(cfg, B=3)
    stale = make_exchange_step(tiny_model)(tiny_model.init(jax.random.PRNGKey(0)), batch)
    with pytest.raises(ValueError, match="divisible by n_shards"):
        make_hsgd_step_stats(tiny_model, 2)(
            tiny_model.init(jax.random.PRNGKey(0)), stale, batch, 0.01)


def test_global_llm_params_restores_flat_checkpoint_format(tiny_model):
    """Checkpoints store the flat {θ0, θ1, θ2} global model — collapsing the
    pod axis must reproduce exactly what model.init emits (pods start equal)."""
    flat = tiny_model.init(jax.random.PRNGKey(0))
    stacked = init_llm_params(jax.random.PRNGKey(0), tiny_model, n_pods=2)
    collapsed = global_llm_params(stacked)
    for a, b in zip(jax.tree_util.tree_leaves(collapsed),
                    jax.tree_util.tree_leaves(flat)):
        assert a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_round_fn_cache_and_validation(tiny_model):
    runner = LLMRoundRunner(tiny_model)
    f1 = runner.round_fn(4, 2, 0.25, 128)
    assert runner.round_fn(4, 2, 0.25, 128) is f1  # bucket cached
    assert runner.round_fn(4, 4, 0.25, 128) is not f1
    assert runner.round_fn(4, 2, 0.0, 0) is not f1
    with pytest.raises(ValueError):
        runner.round_fn(4, 3)
    with pytest.raises(ValueError):
        runner.round_fn(0, 1)


# ---------------------------------------------------------------------------
# Adaptive loop: bookkeeping + the acceptance bound on compiled executors
# ---------------------------------------------------------------------------


def test_adaptive_llm_accounting_and_compile_bound(tiny_model):
    cfg = tiny_cfg()
    acfg = AdaptiveConfig(total_steps=12, byte_budget=1e5, max_interval=4,
                          eta_min=0.01, eta_max=0.05)
    ad = AdaptiveLLMRunner(tiny_model, acfg, n_pods=2, learning_rate=0.05)
    params = init_llm_params(jax.random.PRNGKey(0), tiny_model, n_pods=2)
    with compile_guard(track=r"llm_round") as g:
        params, losses, history = ad.run(
            params, llm_batch_fn(cfg, 4, 8, n_pods=2, seed=0))

    assert len(losses) == acfg.total_steps
    assert sum(h["P"] for h in history) == acfg.total_steps
    assert all(h["Q"] == h["P"] for h in history)  # strategy 1 throughout
    rungs = [h["rung"] for h in history]
    assert all(b >= a for a, b in zip(rungs, rungs[1:]))  # ladder ratchet
    bytes_curve = [h["bytes_total"] for h in history]
    assert all(b > a for a, b in zip(bytes_curve, bytes_curve[1:]))
    assert np.isfinite(losses).all()
    # ACCEPTANCE: at most one compiled executor per distinct (P, Q, k, b) —
    # asserted on the ACTUAL XLA compile events, not just cache bookkeeping
    buckets = {(h["P"], h["Q"], h["compression_k"], h["quant_levels"])
               for h in history}
    assert g.total <= len(buckets), g.by_name
    assert g.total == len(ad.runner._round_cache)  # every executor: 1 compile
    assert len(ad.runner._round_cache) <= len(buckets)


def test_adaptive_llm_byte_model_uses_live_shapes(tiny_model):
    """The governor's MessageSizes must reflect the llm_hybrid specs and the
    actual ζ token-stream shapes (B × S_tower × d_model per pod)."""
    cfg = tiny_cfg()
    ad = AdaptiveLLMRunner(tiny_model, AdaptiveConfig(total_steps=4))
    params = init_llm_params(jax.random.PRNGKey(0), tiny_model, n_pods=1)
    B, S = 4, 8
    batches = llm_batch_fn(cfg, B, S, n_pods=1, seed=0)(0, 1)
    sizes = ad._sizes_of(params, batches)(0.0, 0)
    z_el = B * (S // 2) * cfg.d_model  # per-tower ζ: [B, S/2, d]
    assert sizes.z1 == z_el * 4 and sizes.z2 == z_el * 4
    from repro.common.pytree import tree_bytes
    assert sizes.theta0 == tree_bytes(params["theta0"]) // 1  # G = 1 pod
    # compressed rung shrinks every billed component consistently
    c = ad._sizes_of(params, batches)(0.25, 128)
    assert c.theta0 < sizes.theta0 and c.z1 < sizes.z1 and c.z2 < sizes.z2


def test_controller_core_is_runner_agnostic():
    """The same ControllerCore drives both runners: with fixed probes and a
    stationary plan, its ledger equals plan_round's own projection."""
    sizes_of = lambda k, b: CM.MessageSizes(
        theta0=compressed_bytes(1000, k or 1.0, b) if (k or b) else 4000.0,
        theta1=8e3, theta2=2e3, z1=1e3, z2=1e3, n_active=1)
    from repro.common.config import FederationConfig

    cfg = AdaptiveConfig(total_steps=16, max_interval=4)
    core = ControllerCore(cfg, FederationConfig(num_groups=2), sizes_of,
                          eta0=0.01, probe={"rho": 2.0, "delta": 0.5,
                                            "F0": 1.0, "grad_norm_sq": 1.0})
    fake_stats = {"loss": np.full(16, 1.0), "gnorm2": np.full(16, 1.0),
                  "delta2": np.full(16, 0.25), "rho": np.full(16, 2.0),
                  "rho_ok": np.ones(16)}
    while not core.done:
        plan, _ = core.plan()
        stats = {k: v[:plan.P] for k, v in fake_stats.items()}
        rec = core.record(plan, stats)
    assert core.steps_done == cfg.total_steps
    assert rec["bytes_total"] == core.bytes_spent > 0
    assert [h["round"] for h in core.history] == list(range(len(core.history)))


def test_neutral_probe_defaults():
    from repro.common.config import FederationConfig

    core = ControllerCore(AdaptiveConfig(total_steps=1), FederationConfig(),
                          lambda k, b: CM.MessageSizes(1, 1, 1, 1, 1, 1),
                          eta0=0.01)
    assert core.probe == NEUTRAL_PROBE and core.probe is not NEUTRAL_PROBE


# ---------------------------------------------------------------------------
# CLI smoke: the tier-1 guard against the LLM-adaptive path rotting
# ---------------------------------------------------------------------------


def test_train_cli_llm_adaptive_smoke():
    from repro.launch import train as TR

    out = TR.main(["--arch", "gemma3-1b", "--smoke", "--adaptive",
                   "--steps", "4", "--batch", "2", "--seq", "16",
                   "--byte-budget-mb", "1", "--max-interval", "2"])
    assert out["steps"] == 4 and out["adaptive_rounds"] >= 1
    assert math.isfinite(out["loss_last"])
    assert out["adaptive_bytes_total"] > 0
