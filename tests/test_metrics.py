"""Regression tests for the evaluation metrics (paper Table II columns)."""
import numpy as np
import pytest

from repro.core.metrics import auc_roc_ovr, precision_recall_f1


def test_macro_f1_is_mean_of_per_class_f1():
    """Hand-computed 3-class reference. Per class (tp, fp, fn):
      c0: (1, 1, 1) -> p=1/2, r=1/2, f1=1/2
      c1: (2, 1, 0) -> p=2/3, r=1,   f1=4/5
      c2: (2, 0, 1) -> p=1,   r=2/3, f1=4/5
    macro-P = macro-R = 13/18, so the old harmonic-mean-of-macros bug ALSO
    returns 13/18 ≈ 0.7222 — while true macro-F1 = (1/2 + 4/5 + 4/5)/3 = 0.7.
    """
    y_true = np.array([0, 0, 1, 1, 2, 2, 2])
    y_pred = np.array([0, 1, 1, 1, 2, 0, 2])
    m = precision_recall_f1(y_true, y_pred, 3)
    assert m["precision"] == pytest.approx(13 / 18)
    assert m["recall"] == pytest.approx(13 / 18)
    assert m["f1"] == pytest.approx(0.7)
    assert m["f1"] != pytest.approx(13 / 18)  # the bug's value


def test_macro_f1_binary_hand_computed():
    """Both classes have f1 = 1/3 (tp=1, fp+fn=4 each), so macro-F1 = 1/3;
    the harmonic mean of macro-P = macro-R = 3/8 would be 3/8."""
    y_true = np.array([0, 0, 0, 0, 1, 1])
    y_pred = np.array([0, 1, 1, 1, 1, 0])
    m = precision_recall_f1(y_true, y_pred, 2)
    assert m["precision"] == pytest.approx(3 / 8)
    assert m["recall"] == pytest.approx(3 / 8)
    assert m["f1"] == pytest.approx(1 / 3)


def test_f1_skips_absent_classes_and_perfect_is_one():
    y = np.array([0, 1, 0, 1])
    m = precision_recall_f1(y, y, 3)  # class 2 never appears: excluded
    assert m["precision"] == m["recall"] == m["f1"] == 1.0
    # a class present only in predictions still counts (f1 = 0 for it):
    # c0 (tp=1, fn=1) -> 2/3, c1 (tp=2) -> 1, c2 (fp=1) -> 0
    m2 = precision_recall_f1(np.array([0, 0, 1, 1]), np.array([0, 2, 1, 1]), 3)
    assert m2["f1"] == pytest.approx((2 / 3 + 1.0 + 0.0) / 3)


def test_auc_perfect_separation():
    y = np.array([0, 0, 1, 1])
    probs = np.array([[0.9, 0.1], [0.8, 0.2], [0.1, 0.9], [0.2, 0.8]])
    assert auc_roc_ovr(y, probs) == pytest.approx(1.0)
