"""Model-family unit tests: forward, loss, decode for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T

BASE = dict(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=97)

CONFIGS = {
    "dense": ModelConfig(name="dense", family="dense", **BASE),
    "dense-sw": ModelConfig(name="dense-sw", family="dense", sliding_window=8,
                            local_global_ratio=5, qk_norm=True, **BASE),
    "moe": ModelConfig(name="moe", family="moe", num_experts=4, experts_per_token=2,
                       num_shared_experts=1, moe_d_ff=32, first_dense_layers=1, **BASE),
    "mla": ModelConfig(name="mla", family="moe", attention="mla", q_lora_rank=16,
                       kv_lora_rank=16, qk_rope_head_dim=8, v_head_dim=8, head_dim=8,
                       num_experts=4, experts_per_token=2, moe_d_ff=32, **BASE),
    "ssm": ModelConfig(name="ssm", family="ssm", ssm_state=8, ssm_version=1,
                       **{**BASE, "num_heads": 0, "num_kv_heads": 0, "d_ff": 0}),
    "hybrid": ModelConfig(name="hyb", family="hybrid", ssm_state=8, ssm_version=2,
                          ssm_headdim=16, hybrid_attn_every=1, sliding_window=16, **BASE),
    "vlm": ModelConfig(name="vlm", family="vlm", mrope_sections=(2, 1, 1), **BASE),
    "audio": ModelConfig(name="audio", family="audio", is_encoder_decoder=True,
                         encoder_layers=2, encoder_seq=8, **BASE),
}


def _extra(cfg):
    if cfg.family == "vlm":
        return jnp.ones((2, 4, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        return jnp.ones((2, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return None


@pytest.mark.parametrize("name", list(CONFIGS))
def test_forward_and_loss(name):
    cfg = CONFIGS[name]
    params = L.init_params(T.model_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    e = _extra(cfg)
    if e is not None:
        batch["extra_embeds"] = e
    loss = T.lm_loss(cfg, params, batch, remat=False)
    assert jnp.isfinite(loss)
    grads = jax.grad(lambda p: T.lm_loss(cfg, p, batch, remat=False))(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", list(CONFIGS))
def test_decode_step_shapes(name):
    cfg = CONFIGS[name]
    params = L.init_params(T.model_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    B, cache_len = 2, 32
    caches = T.init_decode_caches(cfg, B, cache_len, jnp.float32)
    if cfg.family == "audio":
        enc = jnp.ones((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        caches = T.seed_audio_caches(cfg, params, caches, enc)
    logits, new_caches = T.decode_step(cfg, params, jnp.ones((B, 1), jnp.int32),
                                       caches, jnp.int32(3))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    jax.tree.map(lambda a, b: None if a.shape == b.shape else pytest.fail("cache shape changed"),
                 caches, new_caches)


@pytest.mark.parametrize("name", ["dense", "dense-sw", "ssm", "hybrid"])
def test_decode_matches_forward(name):
    """Sequential decode logits must match the teacher-forced forward pass."""
    cfg = CONFIGS[name]
    params = L.init_params(T.model_specs(cfg), jax.random.PRNGKey(1), jnp.float32)
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    hidden, _ = T.forward(cfg, params, toks, remat=False)
    ref_logits = T.logits_from_hidden(cfg, params, hidden)

    caches = T.init_decode_caches(cfg, B, S, jnp.float32)
    outs = []
    for i in range(S):
        lg, caches = T.decode_step(cfg, params, toks[:, i : i + 1], caches, jnp.int32(i))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(ref_logits),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_masks_differ():
    cfg = CONFIGS["dense"]
    cfg_sw = cfg.replace(sliding_window=4)
    params = L.init_params(T.model_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab_size)
    h1, _ = T.forward(cfg, params, toks, remat=False)
    h2, _ = T.forward(cfg_sw, params, toks, remat=False, force_window=True)
    # early positions identical (window covers full history), late differ
    assert float(jnp.max(jnp.abs(h1[:, 1] - h2[:, 1]))) < 1e-5
    assert float(jnp.max(jnp.abs(h1[:, -1] - h2[:, -1]))) > 1e-6


def test_blockwise_attention_matches_dense():
    from repro.models import attention as A

    B, S, H, KH, D = 2, 64, 4, 2, 16
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, S, H, D))
    k = jax.random.normal(k2, (B, S, KH, D))
    v = jax.random.normal(k3, (B, S, KH, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    for window in (0, 16):
        bias = A.causal_mask_bias(pos, pos, window)
        dense_out = A._sdpa(q, k, v, bias, D ** -0.5)
        block_out = A._blockwise_sdpa(q, k, v, pos, pos, D ** -0.5, window, kv_block=16)
        np.testing.assert_allclose(np.asarray(dense_out), np.asarray(block_out),
                                   rtol=1e-5, atol=1e-5)


def test_mrope_text_equals_regular_rope_on_temporal_sections():
    """With all-equal 3D positions and sections spanning the full head dim,
    M-RoPE degenerates to regular RoPE."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    r1 = L.apply_rope(x, pos)
    r2 = L.apply_mrope(x, L.text_positions_3d(pos), (8, 0, 0))
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-5, atol=1e-6)


def test_ssm_decode_state_carries_information():
    cfg = CONFIGS["ssm"]
    params = L.init_params(T.model_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    B = 1
    z = T.init_decode_caches(cfg, B, 8, jnp.float32)
    tok = jnp.ones((B, 1), jnp.int32)
    l1, c1 = T.decode_step(cfg, params, tok, z, jnp.int32(0))
    l2, _ = T.decode_step(cfg, params, tok, c1, jnp.int32(1))
    # same token, different state -> different logits
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6
