"""Population layer: seeded traces, cohort sampling, semi-async scheduling,
the wall-clock governor, and the checkpoint/partition fixes that make long
population runs trustworthy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FederationConfig, TrainConfig
from repro.core import federation as F
from repro.core.comm_model import MessageSizes
from repro.core.controller import AdaptiveConfig, ControllerCore, plan_round
from repro.core.hsgd import HSGDState, init_state, resize_cohort
from repro.core.population import (
    DeviceRegistry,
    PopulationConfig,
    PopulationScheduler,
    cohort_durations,
    make_time_of,
    run_population,
)
from repro.data.partition import hybrid_partition, sample_minibatch
from repro.data.synthetic import ORGANAMNIST, make_dataset
from repro.models.split_model import cnn_hybrid


def _mini(M=3, K=16, q=1, p=2):
    fed = FederationConfig(num_groups=M, devices_per_group=K, alpha=0.5,
                           local_interval=q, global_interval=p)
    X, y = make_dataset(ORGANAMNIST, M * K, seed=0)
    fd = hybrid_partition(ORGANAMNIST, X, y, fed, seed=0)
    data = {k: jnp.asarray(v) for k, v in fd.stacked().items()}
    model = cnn_hybrid(h_rows=11)
    return model, fed, data


def _np_data(M=3, K=16):
    _, _, data = _mini(M=M, K=K)
    return {k: np.asarray(v) for k, v in data.items()}


POP = PopulationConfig(seed=7, devices_per_group=24, target_cohort=4,
                       period=100.0)


# ---------------------------------------------------------------------------
# Determinism (satellite): one seed -> one trace + one participant schedule
# ---------------------------------------------------------------------------


def test_trace_and_cohort_schedule_deterministic_from_seed():
    data = _np_data()
    a, b = DeviceRegistry(data, POP), DeviceRegistry(data, POP)
    for name in ("lat_mult", "comp_mult", "duty", "phase", "data_row"):
        np.testing.assert_array_equal(getattr(a, name), getattr(b, name))
    now = 0.0
    for r in range(5):
        ca, cb = a.sample_cohort(r, now), b.sample_cohort(r, now)
        np.testing.assert_array_equal(ca.idx, cb.idx)
        np.testing.assert_array_equal(ca.pmask, cb.pmask)
        np.testing.assert_array_equal(ca.dev_tail, cb.dev_tail)
        now += 13.7
    other = DeviceRegistry(data, PopulationConfig(seed=8, devices_per_group=24,
                                                  target_cohort=4, period=100.0))
    assert not np.array_equal(other.lat_mult, a.lat_mult)


def test_full_population_run_reproducible_from_seed():
    model, fed, data = _mini()
    train = TrainConfig(learning_rate=0.05)
    r1 = run_population(model, fed, train, data, POP, rounds=3)
    r2 = run_population(model, fed, train, data, POP, rounds=3)
    np.testing.assert_array_equal(r1["losses"], r2["losses"])
    np.testing.assert_array_equal(r1["times"], r2["times"])
    assert r1["staleness_hist"] == r2["staleness_hist"]


# ---------------------------------------------------------------------------
# Cohort sampling: pow2 buckets, padding, masks, tails
# ---------------------------------------------------------------------------


def test_cohort_pads_to_pow2_with_real_members_and_valid_rows():
    data = _np_data()
    cfg = PopulationConfig(seed=3, devices_per_group=16, target_cohort=5,
                           period=100.0)
    reg = DeviceRegistry(data, cfg)
    valid = data["valid"]
    for r in range(6):
        c = reg.sample_cohort(r, r * 17.0)
        M, A = c.idx.shape
        assert A == 1 << (A.bit_length() - 1)  # a power of two
        assert A >= max(1, c.counts.max())
        for m in range(M):
            n = int(c.counts[m])
            assert c.pmask[m].sum() == n
            if n:
                real = set(c.idx[m, :n].tolist())
                # padding repeats the round's REAL members only
                assert set(c.idx[m].tolist()) == real
                assert all(valid[m, i] for i in real)
                assert c.dev_tail[m] >= 1.0 and c.comp_tail[m] >= 1.0


def test_availability_windows_gate_sampling():
    data = _np_data()
    cfg = PopulationConfig(seed=5, devices_per_group=12, target_cohort=6,
                           duty_min=0.3, duty_max=0.6, period=50.0)
    reg = DeviceRegistry(data, cfg)
    c = reg.sample_cohort(0, 21.0)
    avail = reg.available(21.0)
    # every sampled device was available: its data row belongs to an
    # available device's row set
    for m in range(reg.num_groups):
        ok_rows = set(reg.data_row[m, avail[m]].tolist())
        n = int(c.counts[m])
        assert set(c.idx[m, :n].tolist()) <= ok_rows


# ---------------------------------------------------------------------------
# Masked eq. (1) + cohort-state plumbing
# ---------------------------------------------------------------------------


def test_masked_local_aggregate_excludes_padding():
    rng = np.random.RandomState(0)
    x = rng.randn(2, 4, 3).astype(np.float32)
    mask = np.array([[1, 1, 0, 0], [1, 1, 1, 1]], np.float32)
    out = F.local_aggregate({"w": jnp.asarray(x)}, jnp.asarray(mask))["w"]
    np.testing.assert_allclose(np.asarray(out[0]), x[0, :2].mean(0), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[1]), x[1].mean(0), rtol=1e-6)


def test_masked_local_aggregate_empty_group_falls_back_to_plain_mean():
    x = np.broadcast_to(np.arange(3, dtype=np.float32), (1, 4, 3)).copy()
    mask = np.zeros((1, 4), np.float32)
    out = F.local_aggregate({"w": jnp.asarray(x)}, jnp.asarray(mask))["w"]
    np.testing.assert_allclose(np.asarray(out[0]), x[0].mean(0), rtol=1e-6)
    assert np.isfinite(np.asarray(out)).all()


def test_resize_cohort_exact_when_slots_uniform():
    model, fed, data = _mini()
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    g_before = F.local_aggregate(state.theta2)
    for A_new in (2, 8, 4):
        state = resize_cohort(state, model, data, A_new)
        leaves = jax.tree_util.tree_leaves(state.theta2)
        assert all(l.shape[1] == A_new for l in leaves)
        g_after = F.local_aggregate(state.theta2)
        for a, b in zip(jax.tree_util.tree_leaves(g_before),
                        jax.tree_util.tree_leaves(g_after)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ---------------------------------------------------------------------------
# Executor-cache discipline (acceptance: one compile per cohort-size bucket)
# ---------------------------------------------------------------------------


def test_one_executor_per_cohort_bucket():
    from repro.analysis import compile_guard
    from repro.core.hsgd import HSGDRunner

    model, fed, data = _mini()
    train = TrainConfig(learning_rate=0.05)
    # revisiting a bucket NEVER builds a new executor — and building alone
    # compiles NOTHING (jit is lazy until the first call)
    runner = HSGDRunner(model, fed, train)
    with compile_guard(track=r"hsgd_cohort_round", exact=0):
        for A in (2, 4, 8, 4, 2, 8, 8, 2):
            runner.cohort_round_fn(2, 1, A, collect_stats=False)
    assert len(runner._round_cache) == 3
    # end-to-end: a population run triggers exactly ONE XLA compile per
    # cohort bucket it visits, regardless of how rounds revisit buckets
    pop = PopulationConfig(seed=2, devices_per_group=16, target_cohort=6,
                           duty_min=0.25, duty_max=0.9, period=7.0)
    with compile_guard(track=r"hsgd_cohort_round") as g:
        res = run_population(model, fed, train, data, pop, rounds=10)
    buckets = {h["bucket"] for h in res["history"]}
    assert g.total == len(buckets), g.by_name
    assert len(res["runner"]._round_cache) == len(buckets)


# ---------------------------------------------------------------------------
# Scheduler: deadlines, staleness damping, weight semantics
# ---------------------------------------------------------------------------


def _sched(mode="semi_async", **kw):
    data = _np_data(M=4)
    cfg = PopulationConfig(seed=1, devices_per_group=8, target_cohort=3,
                           **kw)
    reg = DeviceRegistry(data, cfg)
    return PopulationScheduler(reg, np.ones(4), mode=mode)


def test_semi_async_deadline_is_quantile_and_sync_is_max():
    dur = np.array([1.0, 2.0, 3.0, 10.0])
    semi = _sched("semi_async", deadline_quantile=0.5)
    sync = _sched("sync")
    cohort = semi.next_cohort()._replace(counts=np.ones(4, np.int64))
    _, rec_semi = semi.settle(cohort, dur)
    _, rec_sync = sync.settle(cohort, dur)
    assert rec_semi["deadline"] == pytest.approx(np.quantile(dur, 0.5))
    assert rec_sync["deadline"] == 10.0
    assert rec_semi["deadline"] < rec_sync["deadline"]
    assert rec_semi["late"] > 0 and rec_sync["late"] == 0


def test_staleness_damps_then_drops_late_groups():
    s = _sched("semi_async", deadline_quantile=0.5, staleness_damping=0.5,
               max_staleness=2)
    cohort = s.next_cohort()._replace(counts=np.ones(4, np.int64))
    dur = np.array([1.0, 1.0, 1.0, 50.0])  # group 3 always misses
    w1, _ = s.settle(cohort, dur)
    assert w1[3] == pytest.approx(0.5)      # one round stale -> damping^1
    w2, _ = s.settle(cohort, dur)
    assert w2[3] == pytest.approx(0.25)     # two rounds stale -> damping^2
    w3, _ = s.settle(cohort, dur)
    assert w3[3] == 0.0                     # past max_staleness -> dropped
    assert (w3[:3] == 1.0).all()            # on-time groups at full weight
    # an on-time round resets the counter
    w4, _ = s.settle(cohort, np.ones(4))
    assert w4[3] == 1.0 and (s.staleness == 0).all()


def test_absent_groups_get_zero_weight_and_all_absent_falls_back():
    s = _sched("semi_async")
    cohort = s.next_cohort()._replace(counts=np.array([2, 0, 1, 0]))
    w, rec = s.settle(cohort, np.ones(4))
    assert w[1] == 0.0 and w[3] == 0.0 and w[0] > 0 and w[2] > 0
    empty = cohort._replace(counts=np.zeros(4, np.int64))
    w0, rec0 = s.settle(empty, np.zeros(4))
    assert (w0 > 0).all()                   # never a 0/0 aggregation
    assert rec0["deadline"] == 0.0


# ---------------------------------------------------------------------------
# CI smoke (satellite): semi-async >= sync progress per simulated wall-clock
# ---------------------------------------------------------------------------


def test_semi_async_progress_per_wall_clock_beats_sync():
    """Same seeded trace, full duty (availability independent of the clock, so
    both modes see the identical cohort/duration schedule): the semi-async
    deadline is a quantile of the same durations sync takes the max of, hence
    strictly less simulated time for the same number of SGD steps whenever any
    round has duration spread — i.e. progress per wall-clock is >= sync's,
    and training still converges."""
    model, fed, data = _mini(M=2, K=16)
    train = TrainConfig(learning_rate=0.05)
    pop = PopulationConfig(seed=4, devices_per_group=16, target_cohort=4,
                           duty_min=1.0, duty_max=1.0)
    semi = run_population(model, fed, train, data, pop, rounds=4,
                          mode="semi_async")
    sync = run_population(model, fed, train, data, pop, rounds=4, mode="sync")
    assert len(semi["losses"]) == len(sync["losses"])  # same step count
    assert semi["sim_seconds"] < sync["sim_seconds"]
    assert semi["losses"][-1] < semi["losses"][0]
    assert sync["losses"][-1] < sync["losses"][0]


# ---------------------------------------------------------------------------
# Wall-clock governor
# ---------------------------------------------------------------------------


PROBE = {"rho": 1.0, "delta": 1.0, "F0": 1.0, "grad_norm_sq": 1.0}


def _sizes_of_const(k, b):
    comp = 1.0 if (k or b) else 4.0
    n = 250_000
    return MessageSizes(theta0=n * comp, theta1=4e5, theta2=1e5,
                        z1=n * comp / 5, z2=n * comp / 5, n_active=4)


def test_plan_round_without_time_model_matches_legacy():
    cfg = AdaptiveConfig(total_steps=64, byte_budget=1e9)
    fed = FederationConfig(num_groups=4)
    legacy = plan_round(PROBE, 0, 0.0, 0, 0.01, cfg, fed, _sizes_of_const)
    timed = plan_round(PROBE, 0, 0.0, 0, 0.01, cfg, fed, _sizes_of_const,
                       time_of=None, seconds_spent=123.0)
    assert legacy == timed
    assert legacy.projected_seconds == 0.0


def test_time_budget_ratchets_compression_and_grows_p():
    fed = FederationConfig(num_groups=4)

    def time_of(P, rung):
        k, b = AdaptiveConfig().ladder[rung]
        wire = 10.0 * (0.1 if (k or b) else 1.0)
        return 5.0 + wire * P + 0.05 * P  # t_g=5 amortizes over P steps

    loose = AdaptiveConfig(total_steps=64, time_budget=1e9)
    tight = AdaptiveConfig(total_steps=64, time_budget=300.0)
    p_loose = plan_round(PROBE, 0, 0.0, 0, 0.01, loose, fed, _sizes_of_const,
                         time_of=time_of)
    p_tight = plan_round(PROBE, 0, 0.0, 0, 0.01, tight, fed, _sizes_of_const,
                         time_of=time_of)
    assert p_loose.rung == 0
    assert p_tight.rung > p_loose.rung or p_tight.P > p_loose.P
    assert p_tight.projected_seconds < p_loose.projected_seconds
    assert p_loose.projected_seconds == pytest.approx(
        time_of(p_loose.P, 0) * (64 / p_loose.P))


def test_controller_core_seconds_ledger():
    fed = FederationConfig(num_groups=2)
    cfg = AdaptiveConfig(total_steps=4, max_interval=1, init_probe=False)
    time_of = lambda P, rung: 2.5 * P
    core = ControllerCore(cfg, fed, _sizes_of_const, eta0=0.01,
                          time_of=time_of)
    stats = {"loss": np.array([1.0]), "gnorm2": np.array([1.0]),
             "delta2": np.array([1.0]), "rho": np.array([0.0]),
             "rho_ok": np.array([0.0])}
    plan, _ = core.plan()
    core.record(plan, stats, seconds=7.0)       # realized time wins
    assert core.seconds_spent == 7.0
    plan, _ = core.plan()
    rec = core.record(plan, stats)              # falls back to the model
    assert rec["round_seconds"] == pytest.approx(2.5 * plan.P)
    assert core.seconds_spent == pytest.approx(7.0 + 2.5 * plan.P)
    assert rec["seconds_total"] == core.seconds_spent


def test_make_time_of_orders_rungs_and_amortizes_p():
    data = _np_data()
    reg = DeviceRegistry(data, POP)
    ladder = AdaptiveConfig().ladder
    time_of = make_time_of(_sizes_of_const, ladder, reg, t_compute=0.0)
    # tighter rung -> smaller message -> faster round at fixed P
    assert time_of(4, 1) < time_of(4, 0)
    # per-STEP time falls as P grows (t_g amortizes; Λ grows with P at Q=...)
    assert time_of(8, 0) / 8 < time_of(1, 0) / 1
    # straggler tails only slow things down vs a tail-free (sigma=0) fleet
    tailed = make_time_of(_sizes_of_const, ladder, reg, t_compute=0.05)
    sym = make_time_of(_sizes_of_const, ladder,
                       DeviceRegistry(data, PopulationConfig(
                           seed=0, devices_per_group=24, target_cohort=4,
                           lat_sigma=0.0, comp_sigma=0.0)),
                       t_compute=0.05)
    assert tailed(4, 0) > sym(4, 0) > 0


# ---------------------------------------------------------------------------
# Bugfix satellites: checkpoint structure + valid-row minibatches
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_restores_hsgd_state_and_ledger(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint

    model, fed, data = _mini()
    state = init_state(jax.random.PRNGKey(1), model, fed, data)
    ledger = {
        "bytes_spent": np.float64(123.5),
        "staleness": np.arange(3, dtype=np.int64),
        "probe": (np.float32(0.5), np.float32(2.0)),
        "history": [np.arange(2.0), np.arange(3.0)],
    }
    save_checkpoint(str(tmp_path / "ck"), {"state": state, "ledger": ledger},
                    step=11)
    loaded, step, _ = load_checkpoint(str(tmp_path / "ck"))
    assert step == 11
    st = loaded["state"]
    # the real class, not a dict of __seq keys or an anonymous lookalike
    assert isinstance(st, HSGDState) and type(st) is HSGDState
    assert isinstance(st.stale, dict) and isinstance(loaded["ledger"]["probe"], tuple)
    assert isinstance(loaded["ledger"]["history"], list)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(st)):
        a = np.asarray(a)
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(loaded["ledger"]["staleness"],
                                  ledger["staleness"])


def test_sample_minibatch_never_returns_padded_rows():
    # heavily padded group: 3 valid rows out of K=16
    data = {k: v.copy() for k, v in _np_data(M=2, K=16).items()}
    data["valid"][1, 3:] = False
    rng = np.random.RandomState(0)
    for batch in (2, 3, 8):  # below, at, and above the valid count
        mb = sample_minibatch(data, batch, rng)
        assert mb["valid"].all(), f"padded row sampled at batch={batch}"
        assert (mb["idx"][1] < 3).all()
        if batch <= 3:
            assert len(set(mb["idx"][1].tolist())) == batch  # no replacement


def test_cohort_durations_shape_and_tail_monotonicity():
    data = _np_data()
    reg = DeviceRegistry(data, POP)
    c = reg.sample_cohort(0, 0.0)
    sizes = _sizes_of_const(0.0, 0)
    dur = cohort_durations(c, sizes, P=2, Q=1, t_compute=0.05)
    assert dur.shape == (reg.num_groups,) and (dur > 0).all()
    # a cohort with larger tails can only be slower
    slower = c._replace(dev_tail=c.dev_tail * 2, comp_tail=c.comp_tail * 2)
    assert (cohort_durations(slower, sizes, 2, 1, 0.05) > dur).all()
