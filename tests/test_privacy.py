"""Privacy-hardened exchange: secure-aggregation ring, fused-DP runs, and
the controller's (ε, δ) ledger."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FederationConfig, TrainConfig
from repro.core import federation as F
from repro.core.comm_model import MessageSizes
from repro.core.controller import (
    AdaptiveConfig,
    ControllerCore,
    RoundPlan,
    epsilon_of,
    gaussian_rho,
)
from repro.core.hsgd import HSGDRunner, exchange, init_state, make_group_weights
from repro.data.partition import hybrid_partition
from repro.data.synthetic import ORGANAMNIST, make_dataset
from repro.models.split_model import cnn_hybrid


def _mini(M=2, K=8, A_frac=0.5, q=2, p=4):
    fed = FederationConfig(num_groups=M, devices_per_group=K, alpha=A_frac,
                           local_interval=q, global_interval=p)
    X, y = make_dataset(ORGANAMNIST, M * K, seed=0)
    fd = hybrid_partition(ORGANAMNIST, X, y, fed, seed=0)
    data = {k: jnp.asarray(v) for k, v in fd.stacked().items()}
    model = cnn_hybrid(h_rows=11)
    return model, fed, data


# ---------------------------------------------------------------------------
# Secure-aggregation ring (pairwise antisymmetric masks, ℤ_{2^32})
# ---------------------------------------------------------------------------


def test_masked_aggregate_bitwise_equals_unmasked():
    """The server-side sum over the full cohort cancels every pairwise mask
    EXACTLY — masked and zero-masked pipelines agree to the bit, and both
    land within fixed-point resolution of the float eq. (1) mean."""
    rng = np.random.RandomState(0)
    theta2 = {"w": jnp.asarray(rng.randn(3, 6, 5).astype(np.float32)),
              "b": jnp.asarray(rng.randn(3, 6).astype(np.float32))}
    masks = F.secure_agg_masks(theta2, seed=7, round_idx=2)
    zeros = jax.tree.map(jnp.zeros_like, masks)
    got = F.secure_local_aggregate(F.secure_mask_uplink(theta2, masks), theta2)
    want = F.secure_local_aggregate(F.secure_mask_uplink(theta2, zeros), theta2)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    plain = F.local_aggregate(theta2)
    for g, p_ in zip(jax.tree.leaves(got), jax.tree.leaves(plain)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(p_),
                                   atol=2.0 ** -15)


def test_single_masked_uplink_hides_the_payload():
    """Each device's wire payload carries a nonzero ring mask (for A >= 2):
    what leaves the device is NOT its fixed-point θ2 encoding."""
    rng = np.random.RandomState(1)
    theta2 = {"w": jnp.asarray(rng.randn(2, 4, 8).astype(np.float32))}
    masks = F.secure_agg_masks(theta2, seed=3, round_idx=0)
    masked = F.secure_mask_uplink(theta2, masks)
    bare = F.secure_mask_uplink(theta2, jax.tree.map(jnp.zeros_like, masks))
    diff = np.asarray(masked["w"]) != np.asarray(bare["w"])
    # every device slot is masked somewhere in its payload
    assert diff.any(axis=-1).all()


def test_masks_rekey_per_round_and_per_seed():
    rng = np.random.RandomState(2)
    theta2 = {"w": jnp.asarray(rng.randn(2, 4, 8).astype(np.float32))}
    m0 = np.asarray(F.secure_agg_masks(theta2, seed=5, round_idx=0)["w"])
    m1 = np.asarray(F.secure_agg_masks(theta2, seed=5, round_idx=1)["w"])
    m0b = np.asarray(F.secure_agg_masks(theta2, seed=5, round_idx=0)["w"])
    m0s = np.asarray(F.secure_agg_masks(theta2, seed=6, round_idx=0)["w"])
    np.testing.assert_array_equal(m0, m0b)  # deterministic in (seed, round)
    assert (m0 != m1).any() and (m0 != m0s).any()


def test_dropout_rekeying_cancels_over_survivors():
    """With a dropout pattern, masks are drawn only between ALIVE pairs, so
    the survivor-restricted aggregate still cancels to the bit."""
    rng = np.random.RandomState(3)
    M, A = 2, 6
    theta2 = {"w": jnp.asarray(rng.randn(M, A, 4).astype(np.float32))}
    alive = np.ones((M, A), bool)
    alive[0, 1] = alive[0, 4] = alive[1, 0] = False
    pmask = jnp.asarray(alive.astype(np.float32))
    masks = F.secure_agg_masks(theta2, seed=9, round_idx=0, alive=alive)
    zeros = jax.tree.map(jnp.zeros_like, masks)
    got = F.secure_local_aggregate(
        F.secure_mask_uplink(theta2, masks), theta2, pmask)
    want = F.secure_local_aggregate(
        F.secure_mask_uplink(theta2, zeros), theta2, pmask)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(want["w"]))
    # dead slots carry no mask at all: nothing survives to bias a retransmit
    assert (np.asarray(masks["w"])[~alive] == 0).all()


# ---------------------------------------------------------------------------
# Private runs: run_private / exchange legs
# ---------------------------------------------------------------------------


def _runner(model, fed, k=0.25, b=128, lr=0.05):
    return HSGDRunner(model, fed, TrainConfig(
        learning_rate=lr, compression_k=k, quantization_bits=b))


def test_run_private_plain_mode_bitwise_matches_run():
    """With every privacy leg off, the host-loop runner is BIT-IDENTICAL to
    the scan-based ``run`` — the private path costs nothing when unused."""
    model, fed, data = _mini()
    w = make_group_weights(data)
    st_a, la = _runner(model, fed).run(
        init_state(jax.random.PRNGKey(0), model, fed, data), data, w, rounds=3)
    st_b, lb = _runner(model, fed).run_private(
        init_state(jax.random.PRNGKey(0), model, fed, data), data, w, rounds=3)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for a, b_ in zip(jax.tree.leaves(st_a.theta0), jax.tree.leaves(st_b.theta0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_run_private_secure_agg_close_to_plain():
    """Masking alone perturbs the weights only by fixed-point roundoff
    (2^-15 per aggregate); within the first round that stays below 1e-2 of
    loss. (Later rounds drift apart — SGD amplifies any perturbation — so
    the bound is only asserted where it is a roundoff claim, not a
    stability claim.)"""
    model, fed, data = _mini()
    w = make_group_weights(data)
    _, la = _runner(model, fed).run_private(
        init_state(jax.random.PRNGKey(0), model, fed, data), data, w,
        rounds=2)
    runner = _runner(model, fed)
    _, lb = runner.run_private(
        init_state(jax.random.PRNGKey(0), model, fed, data), data, w,
        rounds=2, secure_agg=True)
    la, lb = np.asarray(la), np.asarray(lb)
    P = fed.local_interval * fed.lam
    np.testing.assert_allclose(la[:P], lb[:P], atol=1e-2)
    assert np.isfinite(lb).all()
    assert len(runner._round_cache) == 1  # one executor for the whole run


def test_run_private_dp_perturbs_and_compiles_one_executor():
    model, fed, data = _mini()
    w = make_group_weights(data)
    _, la = _runner(model, fed).run_private(
        init_state(jax.random.PRNGKey(0), model, fed, data), data, w,
        rounds=2)
    runner = _runner(model, fed)
    _, lb = runner.run_private(
        init_state(jax.random.PRNGKey(0), model, fed, data), data, w,
        rounds=2, dp_clip=1.0, dp_sigma=1.0, secure_agg=True)
    lb = np.asarray(lb)
    assert np.isfinite(lb).all()
    assert (np.asarray(la) != lb).any()  # the noise reaches the trajectory
    assert len(runner._round_cache) == 1  # clip/σ/masks are traced operands


def test_run_private_sigma_requires_clip():
    model, fed, data = _mini()
    w = make_group_weights(data)
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    with pytest.raises(ValueError, match="dp_clip"):
        _runner(model, fed).run_private(state, data, w, rounds=1,
                                        dp_sigma=1.0)


def test_exchange_legacy_sort_path_rejects_dp():
    """DP is fused into the batched kernel; the pre-fusion leaf-wise path
    must refuse rather than silently skip the clip+noise stage."""
    model, fed, data = _mini()
    state = init_state(jax.random.PRNGKey(0), model, fed, data)
    with pytest.raises(ValueError, match="fused"):
        exchange(model, state, data, fed, compression_k=0.25,
                 quant_levels=128, fused=False,
                 dp_clip=jnp.float32(1.0), dp_sigma=jnp.float32(1.0))


# ---------------------------------------------------------------------------
# (ε, δ) ledger: accounting, the σ ratchet, and plan refusal
# ---------------------------------------------------------------------------

_SIZES = lambda k, b: MessageSizes(1e5, 1e4, 1e4, 1e3, 1e3, 4)


def _fake_stats(P):
    return {"loss": np.full(P, 0.5, np.float32),
            "gnorm2": np.full(P, 1.0, np.float32),
            "delta2": np.full(P, 0.25, np.float32),
            "rho": np.full(P, 1.0, np.float32),
            "rho_ok": np.ones(P, np.float32)}


def _dp_core(total=32, budget=np.inf, sigma=1.0, **kw):
    cfg = AdaptiveConfig(total_steps=total, privacy_budget=budget,
                         dp_clip=1.0, dp_sigma=sigma, **kw)
    fed = FederationConfig(local_interval=1, global_interval=2)
    return ControllerCore(cfg, fed, _SIZES, eta0=0.05)


def test_ledger_charges_zcdp_per_round_and_epsilon_is_monotone():
    core = _dp_core(total=32, sigma=2.0)
    eps_seen, rho_expect = [], 0.0
    while not core.done:
        plan, _ = core.plan()
        assert plan.dp_sigma >= core.cfg.dp_sigma  # ladder only amplifies
        core.record(plan, _fake_stats(plan.P))
        rho_expect += (plan.P // plan.Q) * gaussian_rho(plan.dp_sigma)
        eps_seen.append(core.history[-1]["epsilon_total"])
    np.testing.assert_allclose(core.rho_spent, rho_expect, rtol=1e-12)
    np.testing.assert_allclose(
        eps_seen[-1], epsilon_of(rho_expect, core.cfg.privacy_delta))
    assert all(b >= a for a, b in zip(eps_seen, eps_seen[1:]))
    # the executed rounds honored their own projection
    assert all(h["epsilon_total"] <= h["projected_epsilon"] * (1 + 1e-9)
               for h in core.history)


def test_tight_budget_refuses_before_any_round_executes():
    core = _dp_core(total=64, budget=1e-3)
    plan, _ = core.plan()
    assert plan.dp_exhausted and core.privacy_exhausted and core.done
    assert core.rho_spent == 0.0 and core.history == []  # nothing ran


def test_moderate_budget_ratchets_sigma_up_instead_of_refusing():
    """When the base σ busts ε but a ladder rung fits, the governor climbs
    the rung — trading utility for the guarantee — rather than refusing."""
    loose = _dp_core(total=32, sigma=1.0)
    p0, _ = loose.plan()
    eps_base = p0.projected_epsilon
    core = _dp_core(total=32, sigma=1.0, budget=eps_base * 0.3)
    plan, _ = core.plan()
    assert not plan.dp_exhausted
    assert plan.dp_rung > 0 and plan.dp_sigma > core.cfg.dp_sigma
    assert plan.projected_epsilon <= core.cfg.privacy_budget
    # the rung is a ratchet: later plans never drop below it
    core.record(plan, _fake_stats(plan.P))
    if not core.done:
        plan2, _ = core.plan()
        assert plan2.dp_rung >= plan.dp_rung


def test_ledger_state_dict_roundtrip_and_legacy_checkpoints():
    core = _dp_core(total=32, sigma=2.0)
    plan, _ = core.plan()
    core.record(plan, _fake_stats(plan.P))
    sd = core.state_dict()
    clone = _dp_core(total=32, sigma=2.0)
    clone.load_state_dict(sd)
    assert clone.rho_spent == core.rho_spent
    assert clone.dp_rung == core.dp_rung
    assert clone.privacy_exhausted == core.privacy_exhausted
    assert clone.epsilon_spent == core.epsilon_spent
    # a pre-privacy checkpoint (no ledger keys) resumes with ε = 0 spent
    legacy = {k: v for k, v in sd.items()
              if k not in ("rho_spent", "dp_rung", "privacy_exhausted")}
    clone.load_state_dict(legacy)
    assert clone.rho_spent == 0.0 and clone.dp_rung == 0
    assert not clone.privacy_exhausted


def test_dp_off_plans_carry_no_privacy_fields():
    cfg = AdaptiveConfig(total_steps=8)
    fed = FederationConfig(local_interval=1, global_interval=2)
    core = ControllerCore(cfg, fed, _SIZES, eta0=0.05)
    plan, _ = core.plan()
    assert isinstance(plan, RoundPlan)
    assert plan.dp_sigma == 0.0 and plan.projected_epsilon == 0.0
    assert not plan.dp_exhausted
    core.record(plan, _fake_stats(plan.P))
    assert core.rho_spent == 0.0 and core.epsilon_spent == 0.0
