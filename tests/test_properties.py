"""Hypothesis property tests on the system's invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.common.config import FederationConfig
from repro.core import federation as F
from repro.core.adaptive import (
    convergence_bound,
    max_learning_rate,
    strategy1_lambda_lower_bound,
    strategy2_optimal_interval,
    strategy3_learning_rate,
)
from repro.core.compression import compress_message, compressed_bytes, quantize, topk_sparsify
from repro.core.comm_model import MessageSizes, comm_cost_per_iteration
from repro.data.partition import hybrid_partition, non_iid_group_indices
from repro.data.synthetic import ORGANAMNIST, make_dataset

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# Aggregation (eqs. 1-2)
# ---------------------------------------------------------------------------


@given(st.integers(1, 6), st.integers(1, 5), st.integers(1, 4))
@settings(**SETTINGS)
def test_global_aggregate_weighted_mean(M, K, dim):
    rng = np.random.RandomState(M * 10 + K)
    theta = {"w": jnp.asarray(rng.randn(M, dim, dim))}
    w = jnp.asarray(np.abs(rng.rand(M)) + 0.1)
    agg = F.global_aggregate(theta, w)
    manual = np.einsum("m,mij->ij", np.asarray(w / w.sum()), np.asarray(theta["w"]))
    np.testing.assert_allclose(np.asarray(agg["w"]), manual, rtol=1e-5, atol=1e-6)


@given(st.integers(1, 5), st.integers(2, 8))
@settings(**SETTINGS)
def test_aggregation_idempotent_on_equal_models(M, A):
    """Aggregating identical models is the identity (fixed point)."""
    theta2 = {"w": jnp.broadcast_to(jnp.arange(4.0), (M, A, 4))}
    agg = F.local_aggregate(theta2)
    np.testing.assert_allclose(np.asarray(agg["w"]), np.broadcast_to(np.arange(4.0), (M, 4)))


@given(st.integers(2, 6))
@settings(**SETTINGS)
def test_global_aggregate_preserves_convex_hull(M):
    rng = np.random.RandomState(M)
    x = rng.randn(M, 3)
    theta = {"w": jnp.asarray(x)}
    w = jnp.asarray(np.abs(rng.rand(M)) + 0.1)
    agg = np.asarray(F.global_aggregate(theta, w)["w"])
    assert (agg <= x.max(axis=0) + 1e-6).all() and (agg >= x.min(axis=0) - 1e-6).all()


# ---------------------------------------------------------------------------
# Compression
# ---------------------------------------------------------------------------


@given(st.integers(8, 200), st.floats(0.05, 0.95))
@settings(**SETTINGS)
def test_topk_keeps_at_least_k_and_largest(n, frac):
    x = jnp.asarray(np.random.RandomState(n).randn(4, n), jnp.float32)
    out = np.asarray(topk_sparsify(x, frac))
    k = max(1, int(round(frac * n)))
    nnz = (out != 0).sum(axis=-1)
    assert (nnz >= np.minimum(k, n)).all()
    # every kept value has magnitude >= every dropped value
    for row_in, row_out in zip(np.asarray(x), out):
        kept = np.abs(row_in[row_out != 0])
        dropped = np.abs(row_in[row_out == 0])
        if len(kept) and len(dropped):
            assert kept.min() >= dropped.max() - 1e-6


@given(st.integers(2, 10), st.sampled_from([2, 16, 128, 1024]))
@settings(**SETTINGS)
def test_quantize_error_bounded(rows, levels):
    x = jnp.asarray(np.random.RandomState(rows).randn(rows, 64), jnp.float32)
    q = np.asarray(quantize(x, levels))
    xn = np.asarray(x)
    step = (xn.max(-1) - xn.min(-1)) / (levels - 1)
    err = np.abs(q - xn).max(-1)
    assert (err <= step / 2 + 1e-5).all()


@given(st.floats(0.05, 1.0), st.sampled_from([0, 128]))
@settings(**SETTINGS)
def test_compressed_bytes_never_exceeds_dense(frac, levels):
    n = 1024
    dense = n * 4
    c = compressed_bytes(n, frac, levels)
    if frac < 1.0 or levels:
        assert c <= dense + n * 4  # values + indices bound
    if frac <= 0.5 and levels == 128:
        assert c < dense  # the paper's regime genuinely compresses


@given(st.integers(4, 64))
@settings(**SETTINGS)
def test_compress_idempotent(n):
    x = jnp.asarray(np.random.RandomState(n).randn(2, n), jnp.float32)
    once = compress_message(x, 0.5, 0)
    twice = compress_message(once, 0.5, 0)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice), atol=1e-6)


# ---------------------------------------------------------------------------
# Partitioning
# ---------------------------------------------------------------------------


@given(st.integers(2, 6), st.integers(20, 60))
@settings(**SETTINGS)
def test_partition_no_sample_duplication(M, per_group):
    n = M * per_group
    X, y = make_dataset(ORGANAMNIST, n, seed=M)
    rng = np.random.RandomState(0)
    groups = non_iid_group_indices(y, M, ORGANAMNIST.n_classes, 2, rng)
    all_idx = np.concatenate(groups)
    assert len(all_idx) == len(set(all_idx.tolist()))  # disjoint


@given(st.integers(2, 4))
@settings(**SETTINGS)
def test_vertical_split_reconstructs(M):
    """Concatenating X1 and X2 recovers every sample's full feature vector."""
    from repro.data.synthetic import vertical_split

    X, y = make_dataset(ORGANAMNIST, 40, seed=M)
    X1, X2 = vertical_split(ORGANAMNIST, X)
    np.testing.assert_array_equal(np.concatenate([X1, X2], axis=1), X)


@given(st.integers(2, 4), st.integers(8, 24))
@settings(**SETTINGS)
def test_hybrid_partition_shapes(M, K):
    fed = FederationConfig(num_groups=M, devices_per_group=K)
    X, y = make_dataset(ORGANAMNIST, M * K * 2, seed=1)
    fd = hybrid_partition(ORGANAMNIST, X, y, fed, seed=1)
    data = fd.stacked()
    assert data["x1"].shape[:2] == (M, K)
    assert data["x2"].shape[:2] == (M, K)
    assert data["x1"].shape[2] + data["x2"].shape[2] == 28 * 28


# ---------------------------------------------------------------------------
# Theorem 1 / strategies
# ---------------------------------------------------------------------------


@given(st.integers(1, 64), st.integers(1, 64), st.floats(1e-4, 1e-2))
@settings(**SETTINGS)
def test_bound_monotone_in_P_and_Q(P, Q, eta):
    """The convergence bound (17) is non-decreasing in P and in Q."""
    args = dict(F0=1.0, FT=0.0, rho=2.0, delta=0.5, eta=eta, T=1000)
    b = convergence_bound(P=P, Q=Q, **args)
    assert convergence_bound(P=P + 1, Q=Q, **args) >= b - 1e-12
    assert convergence_bound(P=P, Q=Q + 1, **args) >= b - 1e-12


@given(st.floats(0.1, 10.0), st.floats(0.1, 5.0), st.floats(1e-4, 0.05), st.integers(100, 100000))
@settings(**SETTINGS)
def test_strategy2_interval_positive_and_scales(F0, rho, eta, T):
    q = strategy2_optimal_interval(F0, rho, 0.5, eta, T)
    assert q >= 1
    q_bigger_noise = strategy2_optimal_interval(F0, rho, 5.0, eta, T)
    assert q_bigger_noise <= q  # more gradient noise -> more frequent sync


@given(st.integers(1, 32), st.integers(1, 32))
@settings(**SETTINGS)
def test_strategy3_eta_respects_theorem_cap(P, Q):
    eta = strategy3_learning_rate(P, Q, rho=2.0, delta=0.5, grad_norm_sq=1.0)
    assert 0 < eta <= max_learning_rate(P, 2.0) + 1e-12
    # strategy 3(i): eta decreases with P at fixed Q
    eta_bigger_P = strategy3_learning_rate(P + 8, Q, rho=2.0, delta=0.5, grad_norm_sq=1.0)
    assert eta_bigger_P <= eta + 1e-12


@given(st.integers(1, 16))
@settings(**SETTINGS)
def test_strategy3_eta_decreases_with_Q_at_fixed_ratio(lam):
    """Strategy 3(ii): with P/Q fixed, bigger Q -> smaller optimal eta."""
    e1 = strategy3_learning_rate(lam * 2, 2, rho=2.0, delta=0.5, grad_norm_sq=1.0)
    e2 = strategy3_learning_rate(lam * 8, 8, rho=2.0, delta=0.5, grad_norm_sq=1.0)
    assert e2 <= e1 + 1e-12


@given(st.integers(1, 64), st.integers(1, 64), st.floats(0.05, 50.0),
       st.floats(1e-3, 10.0), st.floats(1e-6, 1e3))
@settings(**SETTINGS)
def test_strategy3_never_exceeds_eta_cap(P, Q, rho, delta, gnorm2):
    """η* = min(η₂, 1/(8Pρ)) can NEVER exceed Theorem 1's step-size cap,
    for any (ρ, δ, ‖∇F‖²) the online probes might produce."""
    eta = strategy3_learning_rate(P, Q, rho, delta, gnorm2)
    assert 0.0 < eta <= max_learning_rate(P, rho) * (1 + 1e-12)


@given(st.floats(0.05, 5.0), st.floats(1.1, 8.0), st.integers(1, 32),
       st.integers(1, 32), st.floats(1e-4, 1e-2))
@settings(**SETTINGS)
def test_bound_monotone_in_delta(delta, factor, P, Q, eta):
    """Γ's noise terms are even powers of δ: more gradient noise can never
    tighten the bound."""
    args = dict(F0=1.0, FT=0.0, rho=2.0, eta=eta, P=P, Q=Q, T=1000)
    b_lo = convergence_bound(delta=delta, **args)
    b_hi = convergence_bound(delta=delta * factor, **args)
    assert b_hi >= b_lo - 1e-12


def _eta_star(F0, FT, rho, delta, P, Q, T):
    """Numeric minimizer of Γ(η) = A/η + Bη + Cη² (convex on η > 0):
    bisection on Γ'(η) = −A/η² + B + 2Cη, which is increasing in η."""
    A = 4.0 * (F0 - FT) / T
    B = 12.0 * P * rho * delta**2
    C = 96.0 * Q**2 * rho**2 * delta**2
    lo, hi = 1e-9, 1e9
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if B + 2.0 * C * mid - A / mid**2 < 0.0:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


@given(st.floats(0.1, 5.0), st.floats(0.2, 3.0), st.integers(1, 16),
       st.floats(1.0, 20.0), st.floats(1.0, 20.0))
@settings(**SETTINGS)
def test_bound_decreases_toward_eta_star_from_above(rho, delta, P, c_near, c_far):
    """Strategy 3's premise: above the minimizer η*, Γ is non-decreasing in η
    — so walking η down toward η* from above can only improve the bound."""
    F0, FT, Q, T = 1.0, 0.0, P, 1000
    eta_star = _eta_star(F0, FT, rho, delta, P, Q, T)
    near, far = sorted((c_near, c_far))
    b_near = convergence_bound(F0, FT, rho, delta, eta_star * near, P, Q, T)
    b_far = convergence_bound(F0, FT, rho, delta, eta_star * far, P, Q, T)
    assert b_far >= b_near * (1 - 1e-9)


@given(st.floats(0.01, 100.0), st.floats(0.1, 5.0), st.floats(0.1, 3.0),
       st.floats(1e-4, 1e-2), st.integers(1, 32), st.integers(100, 100000))
@settings(**SETTINGS)
def test_strategy1_lambda_inf_iff_target_infeasible(target, rho, delta, eta, P, T):
    """Prop. 1's Λ lower bound is inf EXACTLY when the target Ξ is below what
    any amount of communication can achieve at this (P, η)."""
    F0, FT = 1.0, 0.0
    lam = strategy1_lambda_lower_bound(F0, FT, rho, delta, eta, P, T, target)
    denom = target - 4.0 * (F0 - FT) / (eta * T) - 12.0 * P * rho * eta * delta**2
    assert math.isinf(lam) == (denom <= 0)
    if not math.isinf(lam):
        assert lam > 0


# ---------------------------------------------------------------------------
# Communication model (Prop. 1)
# ---------------------------------------------------------------------------


@given(st.integers(1, 16), st.integers(1, 16))
@settings(**SETTINGS)
def test_comm_cost_decreases_with_intervals(P_mult, Q):
    """C(P,Q) is non-increasing in both P and Q (eq. 19)."""
    P = Q * P_mult
    sizes = MessageSizes(theta0=1e4, theta1=2e4, theta2=5e3, z1=1e3, z2=1e3, n_active=4)
    fed = lambda p, q: FederationConfig(local_interval=q, global_interval=p)
    c = comm_cost_per_iteration(sizes, fed(P, Q))
    assert comm_cost_per_iteration(sizes, fed(P * 2, Q)) <= c + 1e-9
    assert comm_cost_per_iteration(sizes, fed(P * 2, Q * 2)) <= c + 1e-9


@given(st.integers(1, 8), st.integers(2, 8))
@settings(**SETTINGS)
def test_comm_cost_increases_with_lambda(Q, lam):
    """Prop. 1: at fixed Q, cost grows with Λ = P/Q... and at fixed P,
    splitting into more local intervals (smaller Q) costs more."""
    sizes = MessageSizes(theta0=1e4, theta1=2e4, theta2=5e3, z1=1e3, z2=1e3, n_active=4)
    P = Q * lam
    c_lam = comm_cost_per_iteration(sizes, FederationConfig(local_interval=Q, global_interval=P))
    c_eq = comm_cost_per_iteration(sizes, FederationConfig(local_interval=P, global_interval=P))
    assert c_eq <= c_lam + 1e-9  # P=Q minimizes at fixed P (strategy 1)


# ---------------------------------------------------------------------------
# Byte model monotonicity (the governor's ratchet relies on both)
# ---------------------------------------------------------------------------


@given(st.integers(64, 4096), st.floats(0.02, 0.98), st.floats(0.02, 0.98),
       st.sampled_from([0, 2, 16, 128, 1024]))
@settings(**SETTINGS)
def test_compressed_bytes_monotone_in_k(n, ka, kb, levels):
    """Within the top-k regime (0 < k < 1), keeping fewer entries can never
    cost more wire bytes, at any quantization depth."""
    from repro.core.compression import compressed_bytes

    lo, hi = sorted((ka, kb))
    assert compressed_bytes(n, lo, levels) <= compressed_bytes(n, hi, levels) + 1e-9


@given(st.integers(64, 4096), st.floats(0.02, 1.0),
       st.sampled_from([2, 4, 16, 128, 1024]), st.integers(1, 5))
@settings(**SETTINGS)
def test_compressed_bytes_monotone_in_b(n, k, b, factor):
    """Fewer quantization levels -> fewer (or equal: ceil(log2)) bits/value."""
    from repro.core.compression import compressed_bytes

    assert compressed_bytes(n, k, b) <= compressed_bytes(n, k, b * (2 ** factor)) + 1e-9


@given(st.floats(0.05, 0.95), st.floats(0.05, 0.95),
       st.sampled_from([(2, 16), (16, 128), (128, 1024)]))
@settings(**SETTINGS)
def test_message_sizes_monotone_in_k_and_b(ka, kb, bs):
    """Every compressed component of MessageSizes (θ0, ζ1, ζ2) shrinks (or
    stays) when k shrinks or when b shrinks — the ladder ordering the byte
    governor ratchets down is therefore well-founded."""
    import jax

    from repro.core.comm_model import message_sizes

    params = {
        "theta0": {"w": jax.ShapeDtypeStruct((64, 64), "float32")},
        "theta1": {"w": jax.ShapeDtypeStruct((32, 32), "float32")},
        "theta2": {"w": jax.ShapeDtypeStruct((16, 16), "float32")},
    }
    k_lo, k_hi = sorted((ka, kb))
    b_lo, b_hi = bs
    for b in (b_lo, b_hi):
        s_lo = message_sizes(params, 5000, 3000, 4, k_lo, b)
        s_hi = message_sizes(params, 5000, 3000, 4, k_hi, b)
        assert s_lo.theta0 <= s_hi.theta0 + 1e-9
        assert s_lo.z1 <= s_hi.z1 + 1e-9 and s_lo.z2 <= s_hi.z2 + 1e-9
    for k in (k_lo, k_hi):
        s_lo = message_sizes(params, 5000, 3000, 4, k, b_lo)
        s_hi = message_sizes(params, 5000, 3000, 4, k, b_hi)
        assert s_lo.theta0 <= s_hi.theta0 + 1e-9
        assert s_lo.z1 <= s_hi.z1 + 1e-9 and s_lo.z2 <= s_hi.z2 + 1e-9
    # uncompressed components never change with the rung
    assert message_sizes(params, 1, 1, 4, k_lo, b_lo).theta1 == \
        message_sizes(params, 1, 1, 4, k_hi, b_hi).theta1


# ---------------------------------------------------------------------------
# Governor ledger: projection == the bytes the controller actually books
# ---------------------------------------------------------------------------


@given(st.sampled_from([2, 4, 8]), st.integers(1, 6),
       st.sampled_from([float("inf"), 1e9, 1e6, 1e3]), st.integers(2, 6))
@settings(**SETTINGS)
def test_plan_projection_equals_booked_bytes_under_fixed_probes(
        max_interval, n_rounds, budget, groups):
    """With fixed probes the plan is stationary, so plan_round's end-of-run
    byte projection must EQUAL the sum of the per_round_bytes charges the
    controller books — round 0's projection is the whole run's bill, and the
    projection is invariant along the run (a martingale of the ledger)."""
    import math as _math

    from repro.core.comm_model import MessageSizes, per_round_bytes
    from repro.core.compression import compressed_bytes
    from repro.core.controller import AdaptiveConfig, plan_round

    def sizes_of(k, b):
        n = 10_000
        comp = compressed_bytes(n, k or 1.0, b) if (k or b) else n * 4.0
        return MessageSizes(theta0=comp, theta1=4e4, theta2=1e4,
                            z1=comp / 10, z2=comp / 10, n_active=4)

    # near-zero curvature/noise probes: strategy 2 saturates P at
    # min(max_interval, T_rem) every round -> a stationary plan
    probe = {"rho": 1e-3, "delta": 1e-3, "F0": 1.0, "grad_norm_sq": 1.0}
    T = max_interval * n_rounds
    cfg = AdaptiveConfig(total_steps=T, byte_budget=budget,
                         max_interval=max_interval)
    fed = FederationConfig(num_groups=groups)

    steps_done, booked, rung, eta_prev = 0, 0.0, 0, 0.01
    projections = []
    while steps_done < T:
        plan = plan_round(probe, steps_done, booked, rung, eta_prev,
                          cfg, fed, sizes_of)
        assert plan.P == max_interval  # stationary by construction
        projections.append(plan.projected_bytes)
        rung = plan.rung
        booked += per_round_bytes(sizes_of(*cfg.ladder[rung]),
                                  plan.P, plan.Q, fed.num_groups)
        steps_done += plan.P
        eta_prev = plan.eta
    assert _math.isclose(projections[0], booked, rel_tol=1e-9)
    for pr in projections[1:]:
        assert _math.isclose(pr, booked, rel_tol=1e-9)


# ---------------------------------------------------------------------------
# §VII-A3 round-time model (hypothesis twins of test_comm_model.py's sweeps)
# ---------------------------------------------------------------------------


@given(st.integers(0, 4), st.floats(1.5, 8.0),
       st.tuples(*[st.floats(1e3, 1e6) for _ in range(5)]),
       st.integers(1, 16))
@settings(**SETTINGS)
def test_round_time_monotone_in_message_components(comp_i, factor, comps, n_active):
    """Round time is monotone in EVERY message component."""
    import dataclasses

    from repro.core.comm_model import MessageSizes, round_time

    base = MessageSizes(*comps, n_active=n_active)
    fed = FederationConfig(local_interval=2, global_interval=8)
    name = ("theta0", "theta1", "theta2", "z1", "z2")[comp_i]
    grown = dataclasses.replace(base, **{name: getattr(base, name) * factor})
    assert round_time(grown, fed, 0.05) > round_time(base, fed, 0.05)


@given(st.integers(0, 4), st.tuples(*[st.floats(1e3, 1e6) for _ in range(5)]),
       st.floats(0.0, 0.2))
@settings(**SETTINGS)
def test_round_time_decreasing_in_q_at_fixed_p(log2_p, comps, t_c):
    """At fixed P, a larger Q (fewer exchange intervals) is strictly faster."""
    from repro.core.comm_model import MessageSizes, round_time

    P = 16
    sizes = MessageSizes(*comps, n_active=4)
    qs = [1 << i for i in range(5)]  # divisors of 16
    times = [round_time(sizes, FederationConfig(local_interval=q,
                                                global_interval=P), t_c)
             for q in qs]
    assert all(a > b for a, b in zip(times, times[1:]))


@given(st.floats(1.0, 8.0), st.floats(1.0, 8.0),
       st.tuples(*[st.floats(1e3, 1e6) for _ in range(5)]))
@settings(**SETTINGS)
def test_round_time_hetero_bracketed_by_tails(dev_tail, compute_tail, comps):
    """Straggler tails only slow a round down, by at most the max tail —
    backbone legs are not device-gated, so full-scaling is an upper bound."""
    from repro.core.comm_model import MessageSizes, round_time, round_time_hetero

    sizes = MessageSizes(*comps, n_active=4)
    fed = FederationConfig(local_interval=2, global_interval=8)
    sym = round_time(sizes, fed, 0.05)
    het = round_time_hetero(sizes, fed, 0.05,
                            dev_tail=dev_tail, compute_tail=compute_tail)
    assert sym <= het <= max(dev_tail, compute_tail) * sym + 1e-9


# ---------------------------------------------------------------------------
# Fault tolerance: robust aggregation parity + seeded injection determinism
# ---------------------------------------------------------------------------


@given(st.integers(1, 5), st.integers(1, 6), st.integers(1, 4),
       st.sampled_from(["mean", "median", "trimmed"]),
       st.floats(0.0, 0.45), st.integers(0, 10**6))
@settings(**SETTINGS)
def test_robust_aggregate_full_trust_is_bitwise_masked_mean(
        M, A, dim, method, trim_frac, seed):
    """With nothing flagged, every robust method must select the EXACT masked
    mean — the fault-free path of the screened executor is bit-identical to
    the plain cohort stack by construction, not merely close."""
    rng = np.random.RandomState(seed % 2**31)
    x = {"w": jnp.asarray(rng.randn(M, A, dim).astype(np.float32))}
    pmask = jnp.asarray((rng.rand(M, A) < 0.7).astype(np.float32))
    trust = jnp.ones((M, A), jnp.float32)
    rob = F.robust_local_aggregate(x, pmask, trust, method=method,
                                  trim_frac=trim_frac)
    plain = F.local_aggregate(x, pmask)
    np.testing.assert_array_equal(np.asarray(rob["w"]), np.asarray(plain["w"]))


@given(st.integers(0, 2**20), st.floats(0.0, 1.0), st.floats(0.0, 1.0),
       st.integers(1, 4), st.integers(1, 8), st.integers(0, 12))
@settings(**SETTINGS)
def test_fault_injector_deterministic_and_drop_excludes_grad_fault(
        seed, d_rate, n_rate, M, A, r):
    from repro.core.faults import FaultInjector, FaultPlan

    plan = FaultPlan(seed=seed, dropout_rate=d_rate, nan_rate=n_rate)
    fa = FaultInjector(plan).faults(r, M, A)
    fb = FaultInjector(plan).faults(r, M, A)
    for x, y in zip(fa, fb):  # NaN == NaN under assert_array_equal
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # a dropped device's update never reaches the server, so it can't also
    # poison the aggregate with a faulty gradient
    assert not np.any((fa.drop > 0)
                      & (np.nan_to_num(fa.grad_fault, nan=1.0) != 0))


# ---------------------------------------------------------------------------
# Privacy: fused DP stage, secure-aggregation ring, (ε, δ) ledger
# ---------------------------------------------------------------------------


@given(st.integers(1, 8), st.integers(4, 100), st.floats(0.01, 10.0),
       st.integers(0, 10**6))
@settings(**SETTINGS)
def test_dp_clip_bounds_row_l2(rows, n, clip, seed):
    """With σ=0 and k=n, the fused DP stage is exactly per-row L2 clipping:
    every output row norm is ≤ min(‖x‖₂, clip) up to roundoff."""
    from repro.core.compression import compress_rows_ref

    rng = np.random.RandomState(seed % 2**31)
    x = jnp.asarray(rng.randn(rows, n).astype(np.float32)) * 3.0
    noise = jnp.zeros_like(x)  # σ=0: the noise operand is inert
    out = np.asarray(compress_rows_ref(
        x, n, levels=0, dp_clip=jnp.float32(clip),
        dp_sigma=jnp.float32(0.0), dp_noise=noise))
    norms = np.linalg.norm(out, axis=-1)
    orig = np.linalg.norm(np.asarray(x), axis=-1)
    assert (norms <= np.minimum(orig, clip) * (1 + 1e-5) + 1e-6).all()


@given(st.integers(1, 6), st.integers(4, 80), st.integers(2, 10),
       st.sampled_from([0, 128]), st.integers(0, 10**6))
@settings(**SETTINGS)
def test_dp_sigma0_large_clip_is_identity(rows, n, k_div, levels, seed):
    """σ=0 with a finite clip above every row norm is BIT-IDENTICAL to the
    non-DP pass (×1.0 and +0.0 change no bits on finite inputs)."""
    from repro.core.compression import compress_rows_ref

    _jref = jax.jit(compress_rows_ref, static_argnames=("levels",))
    rng = np.random.RandomState(seed % 2**31)
    x = jnp.asarray(np.abs(rng.randn(rows, n)).astype(np.float32))  # no -0.0
    noise = jnp.asarray(rng.randn(rows, n).astype(np.float32))
    k = max(1, n // k_div)
    plain = _jref(x, k, levels=levels)
    dp0 = _jref(x, k, levels=levels, dp_clip=jnp.float32(1e9),
                dp_sigma=jnp.float32(0.0), dp_noise=noise)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(dp0))


@given(st.integers(1, 3), st.integers(2, 6), st.integers(2, 5),
       st.integers(0, 10**6), st.integers(0, 50))
@settings(**SETTINGS)
def test_secure_agg_masks_cancel_for_any_cohort(M, A, dim, seed, round_idx):
    """Pairwise ring masks cancel TO THE BIT in the aggregate, for every
    cohort size, dropout pattern, and round — wrapping int32 sums are exact,
    so masked and zero-masked pipelines agree bitwise."""
    rng = np.random.RandomState(seed % 2**31)
    theta2 = {"w": jnp.asarray(rng.randn(M, A, dim).astype(np.float32))}
    alive = (rng.rand(M, A) < 0.7)
    alive[:, 0] = True  # at least one survivor per group
    pmask = jnp.asarray(alive.astype(np.float32))
    masks = F.secure_agg_masks(theta2, seed % 2**31, round_idx,
                               alive=np.asarray(alive))
    zeros = jax.tree.map(jnp.zeros_like, masks)
    got = F.secure_local_aggregate(
        F.secure_mask_uplink(theta2, masks), theta2, pmask)
    want = F.secure_local_aggregate(
        F.secure_mask_uplink(theta2, zeros), theta2, pmask)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(want["w"]))
    # and the ring pipeline lands within fixed-point resolution of the float
    # masked mean
    plain = F.local_aggregate(theta2, pmask)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(plain["w"]),
                               atol=2.0 ** -15)


@given(st.integers(1, 200), st.floats(0.3, 8.0), st.floats(1.1, 4.0),
       st.sampled_from([1e-5, 1e-6, 1e-8]))
@settings(**SETTINGS)
def test_epsilon_monotone_in_rounds_decreasing_in_sigma(rounds, sigma,
                                                        factor, delta):
    """ε grows with composed rounds and shrinks with a larger σ — the two
    monotonicities the privacy governor's ratchet relies on."""
    from repro.core.controller import epsilon_of, gaussian_rho

    e = epsilon_of(rounds * gaussian_rho(sigma), delta)
    e_more_rounds = epsilon_of((rounds + 1) * gaussian_rho(sigma), delta)
    e_more_noise = epsilon_of(rounds * gaussian_rho(sigma * factor), delta)
    assert e > 0
    assert e_more_rounds >= e * (1 - 1e-12)
    assert e_more_noise <= e * (1 + 1e-12)
