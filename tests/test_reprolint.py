"""reprolint: one good/bad fixture pair per rule, suppression semantics,
baseline round-trip, and compile_guard budget enforcement."""
import json
import textwrap

import pytest

from repro.analysis import RULES, lint_paths, lint_source
from repro.analysis.linter import (apply_baseline, fingerprint, load_baseline,
                                   write_baseline)


def findings_for(rule_id, source, path="src/x.py"):
    return [f for f in lint_source(textwrap.dedent(source), path)
            if f.rule == rule_id]


# ---------------------------------------------------------------------------
# Fixture matrix: for each rule, BAD must fire and GOOD must not
# ---------------------------------------------------------------------------

FIXTURES = {
    "RP1": {
        "bad": """
            import jax
            def train(steps):
                for _ in range(steps):
                    fn = jax.jit(lambda x: x + 1)
                    fn(1.0)
        """,
        "bad2": """
            import jax
            from functools import partial
            def train(steps):
                while steps:
                    @partial(jax.jit, donate_argnums=(0,))
                    def step(s):
                        return s
                    steps -= 1
        """,
        "good": """
            import jax
            def train(steps):
                fn = jax.jit(lambda x: x + 1)
                for _ in range(steps):
                    fn(1.0)
        """,
        # a def INSIDE a loop whose body jits is fine: the body runs later
        "good2": """
            import jax
            def build(buckets):
                out = {}
                for b in buckets:
                    def make(bb=b):
                        return jax.jit(lambda x: x * bb)
                    out[b] = make
                return out
        """,
    },
    "RP2": {
        "bad": """
            import jax
            from functools import partial
            def run(state, data):
                @partial(jax.jit, donate_argnums=(0,))
                def step(s, d):
                    return s
                out = step(state, data)
                return state, out
        """,
        "good": """
            import jax
            from functools import partial
            def run(state, data):
                @partial(jax.jit, donate_argnums=(0,))
                def step(s, d):
                    return s
                state = step(state, data)
                return state
        """,
        # rebind on the SAME line as the donating call is the idiom
        "good2": """
            import jax
            from functools import partial
            def run(state, data, rounds):
                @partial(jax.jit, donate_argnums=(0,))
                def step(s, d):
                    return s, 0.0
                for _ in range(rounds):
                    state, loss = step(state, data)
                return state, loss
        """,
    },
    "RP3": {
        "bad": """
            import jax
            def train(data, etas):
                for eta in etas:
                    pass

                @jax.jit
                def step(x):
                    return x * eta
                return step(data)
        """,
        "good": """
            import jax
            def train(data, etas):
                @jax.jit
                def step(x, eta):
                    return x * eta
                for eta in etas:
                    data = step(data, eta)
                return data
        """,
    },
    "RP4": {
        "bad": """
            import jax
            import numpy as np
            @jax.jit
            def step(x):
                return np.asarray(x) + 1
        """,
        "bad2": """
            import jax
            class Engine:
                def step(self):
                    self._decode()
                def _decode(self):
                    toks = self.fn()
                    return toks.item()
        """,
        "good": """
            import jax
            import jax.numpy as jnp
            @jax.jit
            def step(x):
                return jnp.asarray(x) + 1
        """,
        "good2": """
            import numpy as np
            def postprocess(x):
                return np.asarray(x)  # host code, not a compiled body
        """,
    },
    "RP5": {
        "bad": """
            import numpy as np
            def make_batch(n):
                return np.random.randn(n)
        """,
        "bad2": """
            import numpy as np
            def make_rng():
                return np.random.default_rng()
        """,
        "good": """
            import numpy as np
            def make_batch(n, seed):
                rng = np.random.default_rng(seed)
                return rng.standard_normal(n)
        """,
    },
    "RP6": {
        "bad": """
            import time
            import jax
            def bench(fn, x):
                t0 = time.time()
                fn(x)
                return time.time() - t0
        """,
        "good": """
            import time
            import jax
            def bench(fn, x):
                t0 = time.time()
                jax.block_until_ready(fn(x))
                return time.time() - t0
        """,
    },
    "RP7": {
        "bad": """
            def accumulate(x, out=[]):
                out.append(x)
                return out
        """,
        "bad2": """
            import jax.numpy as jnp
            from dataclasses import dataclass
            @dataclass
            class Config:
                weights: object = jnp.zeros(3)
        """,
        "good": """
            from dataclasses import dataclass, field
            import jax.numpy as jnp
            def accumulate(x, out=None):
                out = [] if out is None else out
                out.append(x)
                return out
            @dataclass
            class Config:
                weights: object = field(default_factory=lambda: jnp.zeros(3))
        """,
    },
    "RP8": {
        "bad": """
            from typing import NamedTuple
            class TrainState(NamedTuple):
                step: int
        """,
        "good": """
            from typing import NamedTuple
            from repro.checkpoint.ckpt import register_state_class
            class TrainState(NamedTuple):
                step: int
            register_state_class(TrainState)
        """,
        # non-state NamedTuples are exempt: the registry is for checkpoints
        "good2": """
            from typing import NamedTuple
            class Metrics(NamedTuple):
                loss: float
        """,
    },
    "RP9": {
        "bad": """
            import json
            def dump_results(path, results):
                with open(path, "w") as f:
                    json.dump(results, f, indent=1)
        """,
        # .json path constant, even without a visible json.dump
        "bad2": """
            def write_manifest(payload):
                with open("out/manifest.json", "w") as f:
                    f.write(payload)
        """,
        "good": """
            from repro.common.io import atomic_write_json
            def dump_results(path, results):
                atomic_write_json(path, results)
        """,
        # staging to a temp file + os.replace commit is the atomic pattern
        "good2": """
            import json, os
            def dump_results(path, results):
                tmp = path + ".tmp"
                with open(tmp, "w") as f:
                    json.dump(results, f)
                os.replace(tmp, path)
        """,
        # plain text writes that are not run artifacts stay out of scope
        "good3": """
            def write_log(path, lines):
                with open(path, "w") as f:
                    f.write("\\n".join(lines))
        """,
    },
    "RP10": {
        "bad": """
            import numpy as np
            def draw_faults(seed, r):
                rng = np.random.default_rng([seed, 7, r])
                return rng.integers(0, 10)
        """,
        # a variable stream index defeats the registry audit entirely
        "bad2": """
            import numpy as np
            def draw(seed, widx):
                rng = np.random.default_rng([seed, widx])
                return rng.integers(0, 10)
        """,
        "good": """
            import numpy as np
            def draw_faults(seed, r):
                rng = np.random.default_rng([seed, 3, r])
                return rng.integers(0, 10)
        """,
        # a *_STREAM module constant documents its registry entry
        "good2": """
            import numpy as np
            SECURE_AGG_STREAM = 4
            def masks(seed, r):
                rng = np.random.default_rng([seed, SECURE_AGG_STREAM, r])
                return rng.integers(0, 2**31)
        """,
        # plain scalar seeds carry no stream index to audit
        "good3": """
            import numpy as np
            def make_rng(seed):
                return np.random.default_rng(seed)
        """,
    },
}

_CASES = [(rid, kind) for rid, fx in FIXTURES.items() for kind in fx]


@pytest.mark.parametrize("rule_id,kind", _CASES,
                         ids=[f"{r}-{k}" for r, k in _CASES])
def test_fixture_matrix(rule_id, kind):
    src = FIXTURES[rule_id][kind]
    path = "benchmarks/x.py" if rule_id == "RP6" else "src/x.py"
    hits = findings_for(rule_id, src, path=path)
    if kind.startswith("bad"):
        assert hits, f"{rule_id} missed its {kind} fixture"
        assert all(f.rule == rule_id and f.line > 0 for f in hits)
    else:
        assert not hits, f"{rule_id} false-positive on {kind}: {hits}"


def test_every_rule_has_fixtures_and_registry_entry():
    assert set(FIXTURES) == set(RULES)
    assert len(RULES) == 10
    for rid, r in RULES.items():
        assert r.id == rid and r.title and r.doc


# ---------------------------------------------------------------------------
# Path scoping
# ---------------------------------------------------------------------------


def test_rp5_exempts_data_fixtures():
    src = "import numpy as np\nx = np.random.randn(3)\n"
    assert findings_for("RP5", src, path="src/repro/data/synthetic.py") == []
    assert findings_for("RP5", src, path="src/repro/core/hsgd.py")


def test_rp6_only_applies_to_benchmarks_importing_jax():
    src = FIXTURES["RP6"]["bad"]
    assert findings_for("RP6", src, path="src/x.py") == []  # not benchmarks/
    no_jax = textwrap.dedent(src).replace("import jax\n", "")
    assert [f for f in lint_source(no_jax, "benchmarks/x.py")
            if f.rule == "RP6"] == []


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------


def test_line_suppression():
    src = ("import numpy as np\n"
           "x = np.random.randn(3)  # reprolint: disable=RP5\n"
           "y = np.random.randn(3)\n")
    hits = [f for f in lint_source(src, "src/x.py") if f.rule == "RP5"]
    assert [f.line for f in hits] == [3]


def test_line_suppression_all_rules_and_multi():
    src = ("import numpy as np\n"
           "x = np.random.randn(3)  # reprolint: disable\n"
           "y = np.random.randn(3)  # reprolint: disable=RP1,RP5\n")
    assert [f for f in lint_source(src, "src/x.py") if f.rule == "RP5"] == []


def test_file_suppression():
    src = ("# reprolint: disable-file=RP5\n"
           "import numpy as np\n"
           "x = np.random.randn(3)\n"
           "y = np.random.randn(3)\n")
    assert [f for f in lint_source(src, "src/x.py") if f.rule == "RP5"] == []


def test_syntax_error_is_a_finding_not_a_crash():
    hits = lint_source("def broken(:\n", "src/x.py")
    assert len(hits) == 1 and hits[0].rule == "SYNTAX"


# ---------------------------------------------------------------------------
# Baseline round-trip
# ---------------------------------------------------------------------------


def test_baseline_round_trip(tmp_path):
    src = "import numpy as np\nx = np.random.randn(3)\n"
    f = tmp_path / "src" / "mod.py"
    f.parent.mkdir()
    f.write_text(src)
    findings = lint_paths([str(tmp_path / "src")])
    assert [x.rule for x in findings] == ["RP5"]

    bl_path = tmp_path / "baseline.json"
    write_baseline(str(bl_path), findings)
    baseline = load_baseline(str(bl_path))
    assert set(baseline) == {fingerprint(findings[0])}

    # baselined finding no longer reported as new
    new, stale = apply_baseline(findings, baseline)
    assert new == [] and stale == []

    # fingerprints survive line drift: same source, different line
    drifted = lint_source("# a new comment line\n" + src, findings[0].path)
    new, stale = apply_baseline(drifted, baseline)
    assert new == [] and stale == []

    # fixing the violation makes the baseline entry stale
    new, stale = apply_baseline([], baseline)
    assert new == [] and len(stale) == 1

    data = json.loads(bl_path.read_text())
    assert data["findings"][0]["rule"] == "RP5"


def test_repo_baseline_matches_tree():
    """The checked-in baseline covers the tree exactly: no new findings, no
    stale entries, and it stays within the accepted-suppression budget."""
    findings = lint_paths(["src", "benchmarks", "examples"])
    baseline = load_baseline("reprolint_baseline.json")
    assert len(baseline) <= 10
    new, stale = apply_baseline(findings, baseline)
    assert new == [], f"non-baselined findings: {new}"
    assert stale == [], f"stale baseline entries: {stale}"


# ---------------------------------------------------------------------------
# compile_guard budgets
# ---------------------------------------------------------------------------


def test_compile_guard_counts_and_budgets():
    jax = pytest.importorskip("jax")
    jnp = jax.numpy
    from repro.analysis import CompileBudgetError, compile_guard

    with compile_guard(track=r"guard_probe") as g:

        @jax.jit
        def guard_probe(x):
            return x * 2

        guard_probe(jnp.ones(3))
        guard_probe(jnp.ones(3))  # cache hit: no new compile
        guard_probe(jnp.ones(4))  # new shape: one more
    assert g.total == 2 and g.count(r"guard_probe") == 2
    assert g.by_name == {"guard_probe": 2}
    # config restored after the region
    assert not jax.config.jax_log_compiles

    with pytest.raises(CompileBudgetError):
        with compile_guard(track=r"guard_probe2", exact=2):
            @jax.jit
            def guard_probe2(x):
                return x + 1

            guard_probe2(jnp.ones(3))  # only 1 compile, budget says 2

    with pytest.raises(CompileBudgetError):
        with compile_guard(track=r"guard_probe3", max_compiles=1):
            @jax.jit
            def guard_probe3(x):
                return x + 1

            guard_probe3(jnp.ones(3))
            guard_probe3(jnp.ones(4))

    # dict budgets pin counts per executor name
    with compile_guard(track=r"guard_", exact={"guard_probe4": 1}):
        @jax.jit
        def guard_probe4(x):
            return x - 1

        guard_probe4(jnp.ones(3))


def test_compile_guard_nests():
    jax = pytest.importorskip("jax")
    jnp = jax.numpy
    from repro.analysis import compile_guard

    with compile_guard(track=r"guard_nest") as outer:
        with compile_guard(track=r"guard_nest", exact=1) as inner:
            @jax.jit
            def guard_nest_a(x):
                return x * 3

            guard_nest_a(jnp.ones(2))

        @jax.jit
        def guard_nest_b(x):
            return x * 5

        guard_nest_b(jnp.ones(2))
    assert inner.total == 1
    assert outer.total == 2
    assert not jax.config.jax_log_compiles
