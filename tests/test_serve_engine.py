"""Serving-engine tests (PR 4): batched single-pass prefill parity against
the sequential decode_step reference, scan-decode vs the Python loop,
continuous-batching slot reuse, bounded per-bucket executor caches, and
first-token temperature sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ModelConfig, get_config
from repro.launch.engine import ServeEngine, _pow2_at_least, sequential_generate
from repro.models import layers as L
from repro.models import transformer as T

BASE = dict(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=97)

CONFIGS = {
    "dense-sw": ModelConfig(name="dense-sw", family="dense", sliding_window=8,
                            local_global_ratio=5, qk_norm=True, **BASE),
    "moe-mla": ModelConfig(name="mla", family="moe", attention="mla", q_lora_rank=16,
                           kv_lora_rank=16, qk_rope_head_dim=8, v_head_dim=8, head_dim=8,
                           num_experts=4, experts_per_token=2, moe_d_ff=32, **BASE),
    "ssm": ModelConfig(name="ssm", family="ssm", ssm_state=8, ssm_version=1,
                       **{**BASE, "num_heads": 0, "num_kv_heads": 0, "d_ff": 0}),
    "hybrid": ModelConfig(name="hyb", family="hybrid", ssm_state=8, ssm_version=2,
                          ssm_headdim=16, hybrid_attn_every=1, sliding_window=16, **BASE),
    "audio": ModelConfig(name="audio", family="audio", is_encoder_decoder=True,
                         encoder_layers=2, encoder_seq=8, **BASE),
}


def _init(cfg, seed=0):
    return L.init_params(T.model_specs(cfg), jax.random.PRNGKey(seed), jnp.float32)


def _caches_with_enc(cfg, params, B, cache_len, rng):
    caches = T.init_decode_caches(cfg, B, cache_len, jnp.float32)
    enc_embeds = None
    if cfg.family == "audio":
        enc_embeds = jnp.asarray(rng.randn(B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        caches = T.seed_audio_caches(cfg, params, caches, enc_embeds)
    return caches, enc_embeds


@pytest.mark.parametrize("name", list(CONFIGS))
def test_batched_prefill_bit_identical(name):
    """ONE multi-token decode_step == S sequential single-token calls.

    Attention-family caches/logits must match bit for bit (the cache write is
    pure value placement and masked softmax zeros are exact). The mamba1
    recurrent state is ulp-tight instead: XLA tiles the [B, T, d] projection
    matmuls differently for T=8 vs T=1, reordering f32 reductions.
    """
    cfg = CONFIGS[name]
    params = _init(cfg)
    B, S, cache_len = 2, 8, 16
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)

    seq_caches, _ = _caches_with_enc(cfg, params, B, cache_len, np.random.RandomState(1))
    step = jax.jit(lambda p, t, c, i: T.decode_step(cfg, p, t, c, i))
    seq_logits = None
    for i in range(S):
        seq_logits, seq_caches = step(params, toks[:, i: i + 1], seq_caches, jnp.int32(i))

    bat_caches, _ = _caches_with_enc(cfg, params, B, cache_len, np.random.RandomState(1))
    bat_logits, bat_caches = jax.jit(
        lambda p, t, c: T.decode_step(cfg, p, t, c, jnp.int32(0)))(params, toks, bat_caches)

    if name == "ssm":
        check = lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    else:
        check = lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    jax.tree.map(check, seq_caches, bat_caches)
    check(seq_logits[:, -1], bat_logits[:, -1])


def test_vector_index_decode_matches_scalar():
    """Per-slot [B] write positions == the scalar index when they coincide."""
    cfg = CONFIGS["dense-sw"]
    params = _init(cfg)
    B, cache_len = 2, 16
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, 4)), jnp.int32)
    caches = T.init_decode_caches(cfg, B, cache_len, jnp.float32)
    _, caches = T.decode_step(cfg, params, toks, caches, jnp.int32(0))
    nxt = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, 1)), jnp.int32)
    l_scalar, c_scalar = T.decode_step(cfg, params, nxt, caches, jnp.int32(4))
    l_vec, c_vec = T.decode_step(cfg, params, nxt, caches, jnp.full((B,), 4, jnp.int32))
    np.testing.assert_array_equal(np.asarray(l_scalar), np.asarray(l_vec))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        c_scalar, c_vec)


@pytest.mark.parametrize("arch", ["gemma3-1b", "falcon-mamba-7b", "whisper-medium"])
def test_engine_matches_sequential_greedy(arch):
    """Scan decode + blocked prefill reproduce the Python-loop tokens at
    temperature 0 (non-power-of-two prompt exercises the block decomposition,
    gen > blocks exercises the finished-slot discard)."""
    cfg = get_config(arch, smoke=True)
    params = _init(cfg)
    B, S, gen = 3, 12, 10
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32)
    extra = None
    if cfg.family == "audio":
        extra = rng.randn(B, cfg.encoder_seq, cfg.d_model).astype(np.float32)
    cache_len = _pow2_at_least(S + gen)
    ref = sequential_generate(cfg, params, jnp.asarray(prompts), gen,
                              temperature=0.0, extra_embeds=extra,
                              cache_dtype=jnp.float32, cache_len=cache_len)
    engine = ServeEngine(cfg, params, max_batch=B, cache_dtype=jnp.float32,
                         decode_block=4, temperature=0.0)
    toks, report = engine.generate(list(prompts), gen, extra_embeds=extra)
    assert toks == np.asarray(ref).tolist()
    assert report["generated_tokens"] == B * gen


def test_continuous_batching_slot_reuse():
    """4 requests through 2 slots with staggered lengths: freed slots are
    refilled mid-run and every request still reproduces its solo reference."""
    cfg = get_config("gemma3-1b", smoke=True)
    params = _init(cfg)
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab_size, (4, 8)).astype(np.int32)
    max_new = [2, 6, 4, 5]
    engine = ServeEngine(cfg, params, max_batch=2, cache_dtype=jnp.float32,
                         decode_block=2, temperature=0.0)
    rids = [engine.submit(p, n) for p, n in zip(prompts, max_new)]
    engine.run()
    by_id = {r.rid: r for r in engine.done}
    assert sorted(by_id) == sorted(rids)
    for rid, prompt, n in zip(rids, prompts, max_new):
        ref = sequential_generate(cfg, params, jnp.asarray(prompt[None]), n,
                                  temperature=0.0, cache_dtype=jnp.float32,
                                  cache_len=_pow2_at_least(8 + n))
        assert by_id[rid].tokens == np.asarray(ref[0]).tolist(), f"request {rid}"


def test_executor_cache_bounded():
    """One XLA compile per (batch, cache, block) bucket — repeat traffic
    reuses executors, a new cache bucket adds exactly one. compile_guard
    counts the actual compiles by executor name; ``compile_counts()``
    cross-checks the cache bookkeeping against them."""
    from repro.analysis import compile_guard

    cfg = get_config("gemma3-1b", smoke=True)
    params = _init(cfg)
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    engine = ServeEngine(cfg, params, max_batch=2, cache_dtype=jnp.float32,
                         decode_block=4, temperature=0.0)
    with compile_guard(track=r"serve_") as g1:
        engine.generate(list(prompts), 8)
    c1 = engine.compile_counts()
    assert c1["decode_buckets"] == 1 and c1["decode_compiles"] == 1
    assert c1["prefill_compiles"] == c1["prefill_buckets"]
    assert g1.count(r"serve_decode") == 1, g1.by_name
    assert g1.count(r"serve_prefill") == c1["prefill_buckets"]
    assert g1.count(r"serve_insert") == c1["insert_buckets"]
    # same bucket: zero new compiles of ANY serving executor
    with compile_guard(track=r"serve_", exact=0):
        engine.generate(list(prompts), 8)
    assert engine.compile_counts() == c1
    with compile_guard(track=r"serve_") as g3:
        engine.generate(list(prompts), 24)  # cache bucket 16 -> 32: one more
    c3 = engine.compile_counts()
    assert c3["decode_buckets"] == 2 and c3["decode_compiles"] == 2
    assert g3.count(r"serve_decode") == 1, g3.by_name  # exactly the new bucket
    # the resize must open NEW prefill/insert buckets, not silently re-jit
    # the old executors with differently-shaped caches
    assert c3["prefill_compiles"] == c3["prefill_buckets"]
    assert c3["insert_compiles"] == c3["insert_buckets"]
    assert g3.count(r"serve_prefill") == c3["prefill_buckets"] - c1["prefill_buckets"]
    assert g3.count(r"serve_insert") == c3["insert_buckets"] - c1["insert_buckets"]


def test_hybrid_ring_wrap_prefill_matches_sequential():
    """Hybrid prompt LONGER than the sliding window: past the ring boundary
    a multi-token block write would evict keys still in-window for the
    block's early queries, so the engine must decay to single-token steps —
    and reproduce the sequential oracle exactly."""
    cfg = CONFIGS["hybrid"]  # sliding_window 16
    params = _init(cfg)
    B, S, gen = 2, 24, 6
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32)
    cache_len = _pow2_at_least(S + gen)
    ref = sequential_generate(cfg, params, jnp.asarray(prompts), gen,
                              temperature=0.0, cache_dtype=jnp.float32,
                              cache_len=cache_len)
    engine = ServeEngine(cfg, params, max_batch=B, cache_dtype=jnp.float32,
                         decode_block=3, temperature=0.0)
    toks, _ = engine.generate(list(prompts), gen)
    assert toks == np.asarray(ref).tolist()


def test_cached_blockwise_prefill_matches_sdpa(monkeypatch):
    """A NON-first prefill block over a long cache routes through the
    online-softmax path (no [Sq, cache_len] score tensor); the result must
    match the dense cache-wide scores."""
    from repro.models import attention as A

    cfg = CONFIGS["dense-sw"]
    params = _init(cfg)
    B, cache_len = 2, 16
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, 8)), jnp.int32)

    def two_block_prefill():
        caches = T.init_decode_caches(cfg, B, cache_len, jnp.float32)
        _, caches = T.decode_step(cfg, params, toks[:, :4], caches, jnp.int32(0))
        return T.decode_step(cfg, params, toks[:, 4:], caches, jnp.int32(4))

    ref_logits, ref_caches = two_block_prefill()  # _sdpa against the cache
    monkeypatch.setattr(A, "BLOCKWISE_THRESHOLD", 2)  # force the routed path
    blk_logits, blk_caches = two_block_prefill()
    np.testing.assert_allclose(np.asarray(ref_logits), np.asarray(blk_logits),
                               rtol=1e-5, atol=1e-5)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=1e-5, atol=1e-5),
        ref_caches, blk_caches)


def test_first_token_respects_temperature():
    """The pre-PR loop always argmaxed the first generated token; the engine
    samples it (and is deterministic per seed)."""
    cfg = get_config("gemma3-1b", smoke=True)
    params = _init(cfg)
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab_size, (4, 8)).astype(np.int32)

    def first_tokens(temperature, seed):
        eng = ServeEngine(cfg, params, max_batch=4, cache_dtype=jnp.float32,
                          decode_block=2, temperature=temperature, seed=seed)
        toks, _ = eng.generate(list(prompts), 2)
        return [t[0] for t in toks]

    greedy = first_tokens(0.0, 0)
    hot = first_tokens(8.0, 1)
    assert hot != greedy  # vocab 512, temp 8: collision is ~impossible
    assert hot == first_tokens(8.0, 1)  # deterministic given the seed
