"""Serving-optimization tests (PR 6): int8 quantized decode caches,
self-speculative scan decode, prefix caching, per-slot sampling PRNG, and
the trace-driven load generator."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ModelConfig, get_config
from repro.launch.engine import (ServeEngine, _pow2_at_least, parse_cache_dtype,
                                 sequential_generate)
from repro.launch.loadgen import load_trace, poisson_trace, run_load, save_trace
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.quant import dequantize_rows, quantize_rows

BASE = dict(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=97)

CONFIGS = {
    "dense-sw": ModelConfig(name="dense-sw", family="dense", sliding_window=8,
                            local_global_ratio=5, qk_norm=True, **BASE),
    "moe-mla": ModelConfig(name="mla", family="moe", attention="mla", q_lora_rank=16,
                           kv_lora_rank=16, qk_rope_head_dim=8, v_head_dim=8, head_dim=8,
                           num_experts=4, experts_per_token=2, moe_d_ff=32, **BASE),
    "ssm": ModelConfig(name="ssm", family="ssm", ssm_state=8, ssm_version=1,
                       **{**BASE, "num_heads": 0, "num_kv_heads": 0, "d_ff": 0}),
    "hybrid": ModelConfig(name="hyb", family="hybrid", ssm_state=8, ssm_version=2,
                          ssm_headdim=16, hybrid_attn_every=1, sliding_window=16, **BASE),
    "audio": ModelConfig(name="audio", family="audio", is_encoder_decoder=True,
                         encoder_layers=2, encoder_seq=8, **BASE),
}


def _init(cfg, seed=0):
    return L.init_params(T.model_specs(cfg), jax.random.PRNGKey(seed), jnp.float32)


def _inputs(cfg, B, S, seed=0):
    rng = np.random.RandomState(seed)
    prompts = rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32)
    extra = None
    if cfg.family == "audio":
        extra = rng.randn(B, cfg.encoder_seq, cfg.d_model).astype(np.float32)
    return prompts, extra


# ---------------------------------------------------------------- int8 caches

def test_quantize_roundtrip_bounds():
    """Symmetric per-row int8: round-trip error <= scale/2 per element, zero
    rows come back as exact zeros (SCALE_EPS keeps 0/0 out of the divide)."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 16) * np.array([[1e-3], [1.0], [50.0], [0.0]]),
                    jnp.float32)
    codes, scale = quantize_rows(x)
    assert codes.dtype == jnp.int8 and scale.dtype == jnp.float32
    back = dequantize_rows(codes, scale)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert (err <= np.asarray(scale)[..., None] / 2 + 1e-9).all()
    np.testing.assert_array_equal(np.asarray(back[3]), 0.0)


@pytest.mark.parametrize("name", ["dense-sw", "moe-mla", "audio"])
def test_int8_engine_matches_int8_sequential(name):
    """Attention families: the engine with int8 caches reproduces the
    sequential oracle run with the SAME int8 caches exactly — K/V rows are
    quantized per position, so quantization is a cache property, not an
    engine property. (int8 vs f32 logit drift is measured separately by
    bench_serve.py and documented in benchmarks/README.md.)"""
    cfg = CONFIGS[name]
    params = _init(cfg)
    B, S, gen = 2, 12, 6
    prompts, extra = _inputs(cfg, B, S)
    ref = sequential_generate(cfg, params, jnp.asarray(prompts), gen,
                              temperature=0.0, extra_embeds=extra,
                              cache_dtype=jnp.int8,
                              cache_len=_pow2_at_least(S + gen))
    engine = ServeEngine(cfg, params, max_batch=B, cache_dtype=jnp.int8,
                         decode_block=4, temperature=0.0)
    toks, _ = engine.generate(list(prompts), gen, extra_embeds=extra)
    assert toks == np.asarray(ref).tolist()


@pytest.mark.parametrize("name", ["ssm", "hybrid"])
def test_int8_recurrent_state_block_invariant(name):
    """Recurrent-state families quantize the SSM state once per prefill
    block, not once per token, so exact parity against the token-by-token
    sequential loop is not defined. What must hold: the engine's own output
    is independent of executor shape (decode_block) and replays exactly."""
    cfg = CONFIGS[name]
    params = _init(cfg)
    B, S, gen = 2, 12, 6
    prompts, extra = _inputs(cfg, B, S)

    def run(block):
        eng = ServeEngine(cfg, params, max_batch=B, cache_dtype=jnp.int8,
                          decode_block=block, temperature=0.0)
        toks, _ = eng.generate(list(prompts), gen, extra_embeds=extra)
        return toks

    toks = run(2)
    assert toks == run(2), "same engine config must replay exactly"
    assert toks == run(6), "decode_block must not change int8 tokens"


@pytest.mark.parametrize("name", list(CONFIGS))
def test_int8_logit_drift_bounded(name):
    """int8 vs f32 cache logits stay within a small tolerance after a prefill
    + one decode step — the documented drift behind greedy near-parity."""
    cfg = CONFIGS[name]
    params = _init(cfg)
    B, S = 2, 8
    prompts, extra = _inputs(cfg, B, S)
    outs = []
    for dt in (jnp.float32, jnp.int8):
        caches = T.init_decode_caches(cfg, B, 16, dt)
        if cfg.family == "audio":
            caches = T.seed_audio_caches(cfg, params, caches, jnp.asarray(extra))
        logits, caches = T.decode_step(cfg, params, jnp.asarray(prompts), caches,
                                       jnp.int32(0), fresh_cache=True)
        nxt = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        logits2, _ = T.decode_step(cfg, params, nxt, caches,
                                   jnp.full((B,), S, jnp.int32))
        outs.append(np.asarray(logits2[:, -1], np.float32))
    assert np.abs(outs[0] - outs[1]).max() < 0.05


# ------------------------------------------------------- speculative decoding

@pytest.mark.parametrize("gamma", [1, 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int8], ids=["f32", "int8"])
def test_speculative_greedy_parity(gamma, dtype):
    """Self-speculative decode is LOSSLESS: every emitted token comes from
    the full model's argmax, so spec output == plain engine output exactly —
    including continuous batching through refilled slots and non-pow2
    prompts."""
    cfg = CONFIGS["dense-sw"]
    params = _init(cfg)
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in (7, 7, 11, 9)]
    max_new = [5, 9, 4, 7]

    def run(**kw):
        eng = ServeEngine(cfg, params, max_batch=2, cache_dtype=dtype,
                          decode_block=3, temperature=0.0, **kw)
        for p, n in zip(prompts, max_new):
            eng.submit(p, n)
        eng.run()
        return {r.rid: r.tokens for r in eng.done}, eng

    plain, _ = run()
    spec, eng = run(spec_gamma=gamma)
    assert spec == plain
    rep = eng.report(1.0, eng.done)
    assert rep["speculative"]["drafted"] > 0
    assert 0.0 <= rep["speculative"]["acceptance"] <= 1.0


def test_speculative_executor_bucket_bounded():
    """One spec executor per (batch, cache, block, gamma) bucket; repeat
    traffic adds zero compiles."""
    cfg = CONFIGS["dense-sw"]
    params = _init(cfg)
    prompts, _ = _inputs(cfg, 2, 8)
    engine = ServeEngine(cfg, params, max_batch=2, cache_dtype=jnp.float32,
                         decode_block=4, temperature=0.0, spec_gamma=2)
    engine.generate(list(prompts), 8)
    c1 = engine.compile_counts()
    assert c1["spec_buckets"] == 1 and c1["spec_compiles"] == 1
    engine.generate(list(prompts), 8)
    assert engine.compile_counts() == c1


def test_speculative_rejected_configs():
    """Speculation is greedy-only and needs a rollback-free cache family:
    SSM/hybrid state and temperature > 0 raise at init, not mid-decode."""
    dense = CONFIGS["dense-sw"]
    with pytest.raises(ValueError):
        ServeEngine(dense, _init(dense), max_batch=1, temperature=0.7,
                    spec_gamma=2)
    ssm = CONFIGS["ssm"]
    with pytest.raises(ValueError):
        ServeEngine(ssm, _init(ssm), max_batch=1, temperature=0.0, spec_gamma=2)


# --------------------------------------------------------------- prefix cache

def test_prefix_cache_hit_and_parity():
    """Requests sharing a pow2 prompt head seed their caches from the store
    (hits counted) and still reproduce their solo references exactly."""
    cfg = CONFIGS["dense-sw"]
    params = _init(cfg)
    rng = np.random.RandomState(2)
    S, gen = 12, 5  # prefix block p = pow2_floor(11) = 8 < S
    head = rng.randint(0, cfg.vocab_size, (8,))
    prompts = [np.concatenate([head, rng.randint(0, cfg.vocab_size, (S - 8,))])
               .astype(np.int32) for _ in range(4)]
    engine = ServeEngine(cfg, params, max_batch=2, cache_dtype=jnp.float32,
                         decode_block=2, temperature=0.0, prefix_cache=True)
    rids = [engine.submit(p, gen) for p in prompts]
    engine.run()
    stats = engine._prefix_stats
    assert stats["hits"] > 0 and stats["seeded_tokens"] == 8 * stats["hits"]
    by_id = {r.rid: r.tokens for r in engine.done}
    for rid, p in zip(rids, prompts):
        ref = sequential_generate(cfg, params, jnp.asarray(p[None]), gen,
                                  temperature=0.0, cache_dtype=jnp.float32,
                                  cache_len=_pow2_at_least(S + gen))
        assert by_id[rid] == np.asarray(ref[0]).tolist(), f"request {rid}"


def test_prefix_store_reuse_across_runs_and_eviction():
    """The store survives across generate() calls (a long-lived server) and
    LRU-evicts beyond prefix_store_max without breaking parity."""
    cfg = CONFIGS["dense-sw"]
    params = _init(cfg)
    rng = np.random.RandomState(3)
    prompt = rng.randint(0, cfg.vocab_size, (1, 12)).astype(np.int32)
    engine = ServeEngine(cfg, params, max_batch=1, cache_dtype=jnp.float32,
                         decode_block=2, temperature=0.0, prefix_cache=True,
                         prefix_store_max=1)
    t1, _ = engine.generate(list(prompt), 4)
    assert engine._prefix_stats == {"hits": 0, "misses": 1, "seeded_tokens": 0}
    t2, _ = engine.generate(list(prompt), 4)  # same head: a hit, same tokens
    assert engine._prefix_stats["hits"] == 1 and t2 == t1
    other = rng.randint(0, cfg.vocab_size, (1, 12)).astype(np.int32)
    engine.generate(list(other), 4)  # different head: miss + LRU eviction
    assert len(engine._prefix_store) == 1
    t3, _ = engine.generate(list(prompt), 4)  # evicted: miss again, same toks
    assert engine._prefix_stats["misses"] == 3 and t3 == t1


# ------------------------------------------------------------- sampling PRNG

def test_sample_token_per_slot_prng():
    """temperature > 0: identical prompts in different slots draw DIFFERENT
    tokens (per-slot key fold), a refilled slot gets a fresh key (its stream
    does not replay the previous occupant's), and a same-seed engine replays
    the whole run exactly."""
    cfg = CONFIGS["dense-sw"]
    params = _init(cfg)
    prompt = np.full((8,), 5, np.int32)

    def run(seed):
        eng = ServeEngine(cfg, params, max_batch=2, cache_dtype=jnp.float32,
                          decode_block=2, temperature=1.0, seed=seed)
        rids = [eng.submit(prompt, 8) for _ in range(4)]  # 4 reqs, 2 slots
        eng.run()
        by_id = {r.rid: r.tokens for r in eng.done}
        return [by_id[r] for r in rids]

    toks = run(0)
    seqs = {tuple(t) for t in toks}
    assert len(seqs) == len(toks), "identical prompts must not share a stream"
    assert toks == run(0), "same seed must replay exactly"
    assert toks != run(1), "different seed must change the draws"


# ------------------------------------------------------------------- loadgen

def test_poisson_trace_deterministic(tmp_path):
    t1 = poisson_trace(6, 50.0, 12, 4, 97, seed=7, shared_prefix_frac=0.75)
    t2 = poisson_trace(6, 50.0, 12, 4, 97, seed=7, shared_prefix_frac=0.75)
    assert t1 == t2
    assert t1 != poisson_trace(6, 50.0, 12, 4, 97, seed=8,
                               shared_prefix_frac=0.75)
    assert t1[0].t_arrival == 0.0  # no dead air at the start
    shared = t1[0].prompt[:9]
    assert all(r.prompt[:9] == shared for r in t1)
    p = tmp_path / "trace.json"
    save_trace(str(p), t1)
    assert load_trace(str(p)) == t1


def test_run_load_report_schema():
    """A tiny trace replay drains every request and fills the documented
    report schema (percentiles, sustained rate, SLO attainment, engine
    sub-report)."""
    cfg = CONFIGS["dense-sw"]
    params = _init(cfg)
    trace = poisson_trace(5, 200.0, 12, 3, cfg.vocab_size, seed=0,
                          shared_prefix_frac=0.75)
    engine = ServeEngine(cfg, params, max_batch=2, cache_dtype=jnp.int8,
                         decode_block=2, temperature=0.0, spec_gamma=1,
                         prefix_cache=True)
    rep = run_load(engine, trace, slo_first_token_s=60.0)
    assert rep["requests"] == 5 and rep["generated_tokens"] == 15
    assert rep["slo_attainment"] == 1.0  # nothing misses a 60 s deadline
    for key in ("queue_s", "first_token_s", "total_s"):
        assert set(rep[key]) == {"p50", "p99"}
        assert rep[key]["p50"] <= rep[key]["p99"]
    assert rep["sustained_tokens_per_s"] > 0
    assert "compiled_executors" in rep["engine"]
    json.dumps(rep)  # the report must be JSON-serializable as-is


# ---------------------------------------------------------------- cache dtype

def test_parse_cache_dtype():
    assert parse_cache_dtype("int8") == jnp.int8
    assert parse_cache_dtype("bf16") == jnp.bfloat16
    assert parse_cache_dtype("f32") == jnp.float32
    assert parse_cache_dtype(jnp.float16) == jnp.float16  # passthrough
    with pytest.raises(ValueError, match="int8"):
        parse_cache_dtype("fp4")


def test_serve_cli_rejects_bad_cache_dtype(capsys):
    from repro.launch.serve import main
    with pytest.raises(SystemExit):
        main(["--cache-dtype", "fp4"])
    assert "fp4" in capsys.readouterr().err
